#!/usr/bin/env bash
# Shared harness for the sketchd CI smokes. Factors the serve-boot /
# poll-addr-file / assert / clean-shutdown choreography that used to be
# copy-pasted per workflow step into one place, and dispatches the
# scenarios:
#
#   smoke.sh wire        insert+query load over TCP, clean shutdown
#   smoke.sh qplane      8 concurrent singleton-query connections (coalescer)
#   smoke.sh replica     --replicas 2 vs --replicas 1: bit-identical answers
#   smoke.sh durability  checkpoint, kill -9, recover, keep serving
#   smoke.sh chaos       kill -9 mid-ingest x3 rounds, recover every time
#   smoke.sh metrics     query load, then scrape + Metrics op: key series nonzero
#   smoke.sh route       2 nodes behind `route`: ANN checksum == single process
#   smoke.sh tenants     2 collections in 1 process == 2 single-tenant twins
#
# Run from the rust/ directory (or set BIN). Fails fast; server logs are
# dumped on any boot failure.

set -euo pipefail

BIN=${BIN:-./target/release/sketchd}
TMP=${TMP:-/tmp}

SERVE_PID=""
SERVE_LOG=""
ADDR=""

# serve_bg NAME [serve args...] — boot a server on an ephemeral port in
# the background; sets ADDR / SERVE_PID / SERVE_LOG or dies with the log.
serve_bg() {
  local name=$1
  shift
  local addr_file="$TMP/sketchd_${name}.addr"
  SERVE_LOG="$TMP/sketchd_${name}.serve.log"
  rm -f "$addr_file"
  "$BIN" serve --listen 127.0.0.1:0 --addr-file "$addr_file" "$@" \
    > "$SERVE_LOG" 2>&1 &
  SERVE_PID=$!
  for _ in $(seq 1 100); do
    [ -s "$addr_file" ] && break
    sleep 0.2
  done
  if ! [ -s "$addr_file" ]; then
    echo "::error::server '$name' never wrote its address file"
    cat "$SERVE_LOG"
    exit 1
  fi
  ADDR=$(cat "$addr_file")
}

# await_clean_shutdown — the server must exit by itself (client sent
# Shutdown) and report a clean drain.
await_clean_shutdown() {
  wait "$SERVE_PID"
  cat "$SERVE_LOG"
  grep -q 'shutdown complete' "$SERVE_LOG"
}

smoke_wire() {
  serve_bg wire --dim 16 --n 50000 --shards 2
  "$BIN" client --connect "$ADDR" --n 2000 \
    --queries 128 --batch 64 --connections 2 --shutdown \
    | tee "$TMP/client_wire.log"
  grep -E 'ann: answered [1-9][0-9]*/' "$TMP/client_wire.log"
  grep -E 'inserts=2000' "$TMP/client_wire.log"
  await_clean_shutdown
}

smoke_qplane() {
  serve_bg qplane --dim 16 --n 50000 --shards 4
  "$BIN" client --connect "$ADDR" --query-load \
    --n 4000 --queries 1024 --batch 1 --connections 8 --shutdown \
    | tee "$TMP/client_qplane.log"
  grep -E 'ann: answered [1-9][0-9]*/1024' "$TMP/client_qplane.log"
  grep -E 'query-load [0-9]+ q/s' "$TMP/client_qplane.log"
  await_clean_shutdown
}

# Replica smoke: the SAME seeded load against --replicas 1 and
# --replicas 2 must produce the SAME order-independent answer checksum
# (replicated reads are bit-identical to single-copy reads), with 8
# concurrent query connections exercising the least-loaded picker, and
# both servers shutting down cleanly.
smoke_replica() {
  local sums=()
  for r in 1 2; do
    serve_bg "replica_r${r}" --dim 16 --n 50000 --shards 4 --replicas "$r"
    grep -Eq "replicas=${r}" "$SERVE_LOG" \
      || { echo "::error::server did not report replicas=${r}"; cat "$SERVE_LOG"; exit 1; }
    "$BIN" client --connect "$ADDR" --query-load --seed 77 \
      --n 4000 --queries 1024 --batch 1 --connections 8 --shutdown \
      | tee "$TMP/client_replica_r${r}.log"
    grep -E 'ann: answered [1-9][0-9]*/1024' "$TMP/client_replica_r${r}.log"
    sums+=("$(grep -oE 'ann checksum=[0-9a-f]+' "$TMP/client_replica_r${r}.log")")
    await_clean_shutdown
  done
  echo "replicas=1 ${sums[0]} | replicas=2 ${sums[1]}"
  if [ "${sums[0]}" != "${sums[1]}" ] || [ -z "${sums[0]}" ]; then
    echo "::error::replicated answers diverged from single-copy answers"
    exit 1
  fi
}

smoke_durability() {
  local data
  data=$(mktemp -d)
  serve_bg durability1 --dim 16 --n 50000 --shards 2 \
    --data-dir "$data" --fsync every:64
  "$BIN" client --connect "$ADDR" --n 2000 \
    --queries 64 --batch 64 --checkpoint | tee "$TMP/client_dur1.log"
  grep -E 'checkpoint cut, covering 2000 points' "$TMP/client_dur1.log"
  kill -9 "$SERVE_PID"
  wait "$SERVE_PID" || true

  serve_bg durability2 --dim 16 --n 50000 --shards 2 --data-dir "$data"
  grep -E 'recovered: inserts=2000 stored=2000' "$SERVE_LOG"
  "$BIN" client --connect "$ADDR" --n 1000 \
    --queries 64 --batch 64 --shutdown | tee "$TMP/client_dur2.log"
  grep -E 'ann: answered [1-9][0-9]*/' "$TMP/client_dur2.log"
  grep -E 'inserts=3000' "$TMP/client_dur2.log"
  await_clean_shutdown
}

# Chaos smoke: three rounds of SIGKILL landing mid-ingest (no shutdown,
# no checkpoint — the WAL tail is all there is), each restart on the same
# data dir. Every restart must report recovered state, torn tails and
# all, and the final recovery must carry a full clean client run. The
# client rounds run with explicit deadlines/retries, so a killed server
# costs the load generator a timely error, never a hang.
smoke_chaos() {
  local data round cpid
  data=$(mktemp -d)
  for round in 1 2 3; do
    serve_bg "chaos${round}" --dim 16 --n 200000 --shards 2 \
      --data-dir "$data" --fsync every:16
    if [ "$round" -gt 1 ]; then
      grep -E 'recovered: inserts=[0-9]+' "$SERVE_LOG" \
        || { echo "::error::round ${round} booted without recovering"; cat "$SERVE_LOG"; exit 1; }
    fi
    "$BIN" client --connect "$ADDR" --n 20000 --queries 32 --batch 32 \
      --timeout-ms 2000 --retries 1 > "$TMP/client_chaos${round}.log" 2>&1 &
    cpid=$!
    sleep 0.4
    kill -9 "$SERVE_PID"
    wait "$SERVE_PID" 2>/dev/null || true
    # The client may (and usually does) die on the cut socket — the point
    # is that it errors within its deadline instead of hanging the job.
    wait "$cpid" || true
  done
  serve_bg chaos_final --dim 16 --n 200000 --shards 2 --data-dir "$data"
  grep -E 'recovered: inserts=[1-9][0-9]*' "$SERVE_LOG" \
    || { echo "::error::final restart recovered nothing"; cat "$SERVE_LOG"; exit 1; }
  "$BIN" client --connect "$ADDR" --n 1000 --queries 64 --batch 64 \
    --timeout-ms 5000 --retries 2 --shutdown | tee "$TMP/client_chaos_final.log"
  grep -E 'ann: answered [1-9][0-9]*/' "$TMP/client_chaos_final.log"
  await_clean_shutdown
}

# Multi-node smoke: the SAME seeded query load against (a) one process
# holding 4 shards and (b) two 2-shard nodes behind a `sketchd route`
# front-end must produce the SAME order-independent ANN checksum —
# scatter/gather over raw per-shard partials is exact, not approximate.
# Parity preconditions: same seed everywhere, contiguous --shard-base
# ranges, and per-node --n sized so per-shard capacity matches the
# single process (20000/4 == 10000/2). One client Shutdown to the
# router must cascade: all three processes drain and exit cleanly.
smoke_route() {
  serve_bg route_single --dim 16 --n 20000 --shards 4
  "$BIN" client --connect "$ADDR" --query-load --seed 99 \
    --n 4000 --queries 1024 --batch 1 --connections 4 --shutdown \
    | tee "$TMP/client_route_single.log"
  grep -E 'ann: answered [1-9][0-9]*/1024' "$TMP/client_route_single.log"
  local want
  want=$(grep -oE 'ann checksum=[0-9a-f]+' "$TMP/client_route_single.log")
  await_clean_shutdown

  serve_bg route_n0 --dim 16 --n 10000 --shards 2 --shard-base 0
  local a0=$ADDR p0=$SERVE_PID l0=$SERVE_LOG
  serve_bg route_n1 --dim 16 --n 10000 --shards 2 --shard-base 2
  local a1=$ADDR p1=$SERVE_PID l1=$SERVE_LOG

  local raddr_file="$TMP/sketchd_route.addr" rlog="$TMP/sketchd_route.log" rpid
  rm -f "$raddr_file"
  "$BIN" route --listen 127.0.0.1:0 --addr-file "$raddr_file" \
    --nodes "$a0,$a1" --retries 2 > "$rlog" 2>&1 &
  rpid=$!
  for _ in $(seq 1 100); do
    [ -s "$raddr_file" ] && break
    sleep 0.2
  done
  if ! [ -s "$raddr_file" ]; then
    echo "::error::router never wrote its address file"
    cat "$rlog" "$l0" "$l1"
    exit 1
  fi
  grep -E 'shards=4 over 2 node' "$rlog"

  "$BIN" client --connect "$(cat "$raddr_file")" --query-load --seed 99 \
    --n 4000 --queries 1024 --batch 1 --connections 4 --shutdown \
    | tee "$TMP/client_route_multi.log"
  grep -E 'ann: answered [1-9][0-9]*/1024' "$TMP/client_route_multi.log"
  local got
  got=$(grep -oE 'ann checksum=[0-9a-f]+' "$TMP/client_route_multi.log")

  echo "single ${want} | routed ${got}"
  if [ "$want" != "$got" ] || [ -z "$want" ]; then
    echo "::error::routed answers diverged from the single-process reference"
    exit 1
  fi

  # One Shutdown, three clean exits: router drains first, its cascade
  # reaches both nodes, and every log reports a clean drain.
  wait "$rpid"
  cat "$rlog"
  grep -q 'shutdown complete' "$rlog"
  wait "$p0"
  wait "$p1"
  grep -q 'shutdown complete' "$l0"
  grep -q 'shutdown complete' "$l1"
}

# Multi-tenant smoke (protocol v6): two named collections with different
# dims hosted in ONE process must answer the SAME seeded query loads
# with the SAME order-independent ANN checksums as two isolated
# single-tenant servers whose geometry matches the collection specs
# (dim/shards/n_max/eta from the spec; everything else defaults) — and
# the loads run INTERLEAVED, two concurrent clients against the one
# process, so cross-tenant bleed would show up as a checksum mismatch.
# One client Shutdown tears the whole registry down cleanly.
smoke_tenants() {
  # Twin A: the `alpha` collection's geometry as a standalone process.
  local want_a want_b
  serve_bg tenants_twin_a --dim 16 --n 60000 --shards 4 --eta 0.0
  "$BIN" client --connect "$ADDR" --query-load --seed 501 \
    --n 3000 --queries 512 --batch 1 --connections 2 --shutdown \
    | tee "$TMP/client_tenants_twin_a.log"
  grep -E 'ann: answered [1-9][0-9]*/512' "$TMP/client_tenants_twin_a.log"
  want_a=$(grep -oE 'ann checksum=[0-9a-f]+' "$TMP/client_tenants_twin_a.log")
  await_clean_shutdown

  # Twin B: the `beta` collection's geometry (different dim).
  serve_bg tenants_twin_b --dim 8 --n 60000 --shards 4 --eta 0.0
  "$BIN" client --connect "$ADDR" --query-load --seed 502 \
    --n 3000 --queries 512 --batch 1 --connections 2 --shutdown \
    | tee "$TMP/client_tenants_twin_b.log"
  grep -E 'ann: answered [1-9][0-9]*/512' "$TMP/client_tenants_twin_b.log"
  want_b=$(grep -oE 'ann checksum=[0-9a-f]+' "$TMP/client_tenants_twin_b.log")
  await_clean_shutdown

  # One process: a 2-shard default tenant (deliberately different
  # geometry) plus alpha and beta boot-created at the twins' specs.
  serve_bg tenants_multi --dim 16 --n 50000 --shards 2 \
    --collections alpha:16:60000:0.0,beta:8:60000:0.0
  grep -E 'collection alpha id=1 dim=16 n_max=60000' "$SERVE_LOG"
  grep -E 'collection beta id=2 dim=8 n_max=60000' "$SERVE_LOG"

  # Interleaved per-tenant load: both clients run concurrently.
  local apid bpid
  "$BIN" client --connect "$ADDR" --query-load --collection alpha \
    --seed 501 --n 3000 --queries 512 --batch 1 --connections 2 \
    > "$TMP/client_tenants_alpha.log" 2>&1 &
  apid=$!
  "$BIN" client --connect "$ADDR" --query-load --collection beta \
    --seed 502 --n 3000 --queries 512 --batch 1 --connections 2 \
    > "$TMP/client_tenants_beta.log" 2>&1 &
  bpid=$!
  wait "$apid" || { cat "$TMP/client_tenants_alpha.log"; exit 1; }
  wait "$bpid" || { cat "$TMP/client_tenants_beta.log"; exit 1; }
  cat "$TMP/client_tenants_alpha.log" "$TMP/client_tenants_beta.log"
  grep -E 'ann: answered [1-9][0-9]*/512' "$TMP/client_tenants_alpha.log"
  grep -E 'ann: answered [1-9][0-9]*/512' "$TMP/client_tenants_beta.log"
  local got_a got_b
  got_a=$(grep -oE 'ann checksum=[0-9a-f]+' "$TMP/client_tenants_alpha.log")
  got_b=$(grep -oE 'ann checksum=[0-9a-f]+' "$TMP/client_tenants_beta.log")

  echo "alpha: twin ${want_a} | hosted ${got_a}"
  echo "beta:  twin ${want_b} | hosted ${got_b}"
  if [ "$want_a" != "$got_a" ] || [ -z "$want_a" ]; then
    echo "::error::collection alpha diverged from its single-tenant twin"
    exit 1
  fi
  if [ "$want_b" != "$got_b" ] || [ -z "$want_b" ]; then
    echo "::error::collection beta diverged from its single-tenant twin"
    exit 1
  fi

  # One Shutdown: the registry (default + alpha + beta) drains cleanly.
  "$BIN" client --connect "$ADDR" --n 1 --queries 1 --batch 1 --shutdown \
    > "$TMP/client_tenants_shutdown.log"
  await_clean_shutdown
}

# scrape MADDR OUT — fetch the Prometheus text body from the metrics
# endpoint, via curl when available, else bash's /dev/tcp.
scrape() {
  local maddr=$1 out=$2
  if command -v curl >/dev/null 2>&1; then
    curl -sS "http://${maddr}/metrics" > "$out"
  else
    exec 3<>"/dev/tcp/${maddr%:*}/${maddr#*:}"
    printf 'GET /metrics HTTP/1.0\r\n\r\n' >&3
    cat <&3 > "$out"
    exec 3>&- 3<&-
  fi
}

# Metrics smoke: boot with the scrape endpoint, drive singleton query
# load through the coalescer, then assert the key series are present and
# nonzero BOTH via an HTTP scrape and via the wire Metrics op — a single
# query load must light up every stage histogram of the read path.
smoke_metrics() {
  local maddr_file="$TMP/sketchd_metrics.maddr" maddr
  rm -f "$maddr_file"
  serve_bg metrics --dim 16 --n 50000 --shards 4 \
    --metrics-listen 127.0.0.1:0 --metrics-addr-file "$maddr_file" \
    --slow-query-ms 500
  for _ in $(seq 1 50); do
    [ -s "$maddr_file" ] && break
    sleep 0.2
  done
  [ -s "$maddr_file" ] \
    || { echo "::error::metrics address file never appeared"; cat "$SERVE_LOG"; exit 1; }
  maddr=$(cat "$maddr_file")
  grep -q 'metrics on' "$SERVE_LOG"

  "$BIN" client --connect "$ADDR" --query-load \
    --n 4000 --queries 512 --batch 1 --connections 4 \
    | tee "$TMP/client_metrics.log"
  grep -E 'ann: answered [1-9][0-9]*/512' "$TMP/client_metrics.log"

  scrape "$maddr" "$TMP/metrics_scrape.txt"
  "$BIN" client --connect "$ADDR" --metrics > "$TMP/metrics_op.txt"
  for body in "$TMP/metrics_scrape.txt" "$TMP/metrics_op.txt"; do
    grep -E 'sketchd_inserts_total [1-9]' "$body"
    grep -E 'sketchd_ann_queries_total [1-9]' "$body"
    grep -E 'sketchd_trace_ids_total [1-9]' "$body"
    grep -E 'sketchd_stored_points [1-9]' "$body"
    for stage in coalesce_wait scatter shard_service merge; do
      grep -E "sketchd_stage_${stage}_us_count [1-9]" "$body" \
        || { echo "::error::stage_${stage} recorded nothing in $body"; cat "$body"; exit 1; }
    done
    grep -E 'sketchd_op_ann_us_count [1-9]' "$body"
    grep -E 'sketchd_op_insert_us_count [1-9]' "$body"
  done

  "$BIN" client --connect "$ADDR" --n 1 --queries 1 --batch 1 --shutdown \
    > "$TMP/client_metrics_shutdown.log"
  await_clean_shutdown
}

case "${1:-}" in
  wire)       smoke_wire ;;
  qplane)     smoke_qplane ;;
  replica)    smoke_replica ;;
  durability) smoke_durability ;;
  chaos)      smoke_chaos ;;
  metrics)    smoke_metrics ;;
  route)      smoke_route ;;
  tenants)    smoke_tenants ;;
  *)
    echo "usage: smoke.sh wire|qplane|replica|durability|chaos|metrics|route|tenants" >&2
    exit 2
    ;;
esac
