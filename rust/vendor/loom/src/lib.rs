//! Vendored stand-in for [tokio-rs/loom](https://github.com/tokio-rs/loom).
//!
//! The build environment is fully offline (no crates.io), so the real
//! loom cannot be a dependency. This crate keeps the *API shape* the
//! repo's models are written against — `loom::model`, `loom::sync::*`,
//! `loom::sync::atomic::*`, `loom::thread` — but implements a much
//! simpler checker: every model closure is rerun `LOOM_ITERS` times
//! (default 128) under a seeded xorshift scheduler that injects
//! preemption points (`yield_now`, occasionally a short sleep) before
//! every atomic and lock operation. That randomizes OS-level
//! interleavings aggressively enough to catch lost-wakeup, double-release
//! and ordering bugs that a single lucky schedule hides, while staying
//! fast enough for CI.
//!
//! Divergences from real loom, all deliberate:
//!
//! - **Not exhaustive.** Real loom enumerates all interleavings under a
//!   bounded number of preemptions (CDSChecker-style, with DPOR). This
//!   stub samples schedules; a bug can survive a run. CI compensates
//!   with iteration counts well above the defaults.
//! - **No C11 weak-memory simulation.** Atomics here are the host's
//!   atomics, so an x86 CI host will not surface orderings that only a
//!   weaker architecture (or real loom's model) would produce. The repo
//!   pairs these models with a ThreadSanitizer job for the data-race
//!   half of that gap.
//! - **`const fn new` on atomics and locks.** Real loom's types
//!   allocate tracking state and cannot sit in `static`s; these wrappers
//!   can, so `durability::io`'s `static INJECTOR` keeps working under
//!   `--cfg loom`.
//! - **Std channels.** Real loom does not model `mpsc` at all; the
//!   `util::sync` facade pins channels to std under every cfg, and the
//!   models treat them as opaque mailboxes.
//!
//! Swapping the real crate in (networked toolchain): replace the
//! `[target.'cfg(loom)'.dependencies]` path entry in rust/Cargo.toml
//! with `loom = "0.7"` and delete this directory. Models that only use
//! `model`, `thread::spawn`, `sync::*` and `sync::atomic::*` (all of
//! ours) compile against both, except that real loom rejects statics
//! and `Instant`-based timeouts inside models — the affected models are
//! annotated at their definition sites in `tests/loom_models.rs`.

pub mod model;
pub mod sched;
pub mod sync;
pub mod thread;

pub use model::model;
