//! The model driver: rerun the closure under fresh scheduler seeds.

/// Iterations per model when `LOOM_ITERS` is unset. Each of the repo's
/// models spawns 2–4 threads and runs in well under a millisecond, so
/// this default keeps `cargo test --cfg loom` interactive; CI raises it.
const DEFAULT_ITERS: u64 = 128;

/// Run `f` repeatedly under the randomized scheduler. Panics propagate
/// out of the first failing iteration (the standard loom contract: a
/// model fails by asserting).
///
/// Environment knobs:
/// - `LOOM_ITERS`: iteration count (default 128).
/// - `LOOM_MAX_PREEMPTIONS`: accepted for CLI compatibility with real
///   loom and intentionally ignored — this stub has no preemption
///   budget; the scheduler hook fires throughout every iteration.
pub fn model<F>(f: F)
where
    F: Fn() + Send + Sync + 'static,
{
    let iters = std::env::var("LOOM_ITERS")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .filter(|&n| n > 0)
        .unwrap_or(DEFAULT_ITERS);
    for i in 0..iters {
        crate::sched::begin_iteration(
            0x5EED_0BAD_CAFE_F00D ^ i.wrapping_mul(0x9E37_79B9_7F4A_7C15),
        );
        f();
    }
}
