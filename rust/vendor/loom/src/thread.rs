//! `loom::thread`: std threads with one preemption point injected at
//! the top of every spawned closure (so a spawner that races its child
//! does not always win the first step).

pub use std::thread::{current, park, sleep, yield_now, JoinHandle};

pub fn spawn<F, T>(f: F) -> JoinHandle<T>
where
    F: FnOnce() -> T + Send + 'static,
    T: Send + 'static,
{
    std::thread::spawn(move || {
        crate::sched::hook();
        f()
    })
}
