//! The randomized scheduler: a per-thread xorshift64 stream, seeded per
//! model iteration, that decides at every sync operation whether to
//! inject a preemption point. Determinism is best-effort (thread seeds
//! depend on spawn order, and the OS still owns the actual schedule);
//! the point is *diversity* across iterations, not replayability.

use std::cell::Cell;
use std::sync::atomic::{AtomicU64, Ordering};

/// Seed of the current model iteration (set by [`begin_iteration`]).
static ITER_SEED: AtomicU64 = AtomicU64::new(0x5EED_0BAD_CAFE_F00D);

/// Salt handed to each thread the first time it draws randomness, so
/// sibling threads walk different streams of the same iteration.
static SPAWN_SALT: AtomicU64 = AtomicU64::new(0);

thread_local! {
    static RNG: Cell<u64> = const { Cell::new(0) };
}

fn next_u64() -> u64 {
    RNG.with(|cell| {
        let mut x = cell.get();
        if x == 0 {
            let salt = SPAWN_SALT.fetch_add(0x9E37_79B9_7F4A_7C15, Ordering::Relaxed);
            x = (ITER_SEED.load(Ordering::Relaxed) ^ salt) | 1;
        }
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        cell.set(x);
        x
    })
}

/// Reset the iteration seed and this (the model-driver) thread's stream.
pub(crate) fn begin_iteration(seed: u64) {
    ITER_SEED.store(seed | 1, Ordering::Relaxed);
    RNG.with(|cell| cell.set(seed | 1));
}

/// Maybe preempt: called before every atomic and lock operation. A ~25%
/// yield rate keeps threads interleaving at sub-statement granularity;
/// the rare short sleep lets a descheduled sibling take several steps,
/// which is what surfaces multi-operation windows (check-then-act races).
pub fn hook() {
    let r = next_u64();
    if r & 0b11 == 0 {
        std::thread::yield_now();
    }
    if r & 0xFF == 0 {
        std::thread::sleep(std::time::Duration::from_micros(r >> 56 & 0x1F));
    }
}
