//! `loom::sync`: thin wrappers over `std::sync` that call
//! [`crate::sched::hook`] before every operation, plus re-exports for
//! the types that need no instrumentation. Guard and error types are
//! std's own, so code written against the `util::sync` facade sees the
//! same signatures under both cfgs.

pub use std::sync::{
    Arc, Condvar, LockResult, MutexGuard, PoisonError, RwLockReadGuard, RwLockWriteGuard,
    TryLockError, TryLockResult, Weak,
};

pub mod mpsc {
    //! Real loom does not model channels; neither does this stub.
    pub use std::sync::mpsc::*;
}

/// Preemption-instrumented `std::sync::Mutex`. `const fn new` keeps
/// `static` mutexes working under `--cfg loom` (a divergence from real
/// loom, which tracks locks per model execution).
#[derive(Debug, Default)]
pub struct Mutex<T: ?Sized>(std::sync::Mutex<T>);

impl<T> Mutex<T> {
    pub const fn new(value: T) -> Self {
        Mutex(std::sync::Mutex::new(value))
    }

    pub fn into_inner(self) -> LockResult<T> {
        self.0.into_inner()
    }
}

impl<T: ?Sized> Mutex<T> {
    pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
        crate::sched::hook();
        self.0.lock()
    }

    pub fn try_lock(&self) -> TryLockResult<MutexGuard<'_, T>> {
        crate::sched::hook();
        self.0.try_lock()
    }

    pub fn get_mut(&mut self) -> LockResult<&mut T> {
        self.0.get_mut()
    }
}

/// Preemption-instrumented `std::sync::RwLock`.
#[derive(Debug, Default)]
pub struct RwLock<T: ?Sized>(std::sync::RwLock<T>);

impl<T> RwLock<T> {
    pub const fn new(value: T) -> Self {
        RwLock(std::sync::RwLock::new(value))
    }

    pub fn into_inner(self) -> LockResult<T> {
        self.0.into_inner()
    }
}

impl<T: ?Sized> RwLock<T> {
    pub fn read(&self) -> LockResult<RwLockReadGuard<'_, T>> {
        crate::sched::hook();
        self.0.read()
    }

    pub fn write(&self) -> LockResult<RwLockWriteGuard<'_, T>> {
        crate::sched::hook();
        self.0.write()
    }

    pub fn try_read(&self) -> TryLockResult<RwLockReadGuard<'_, T>> {
        crate::sched::hook();
        self.0.try_read()
    }

    pub fn try_write(&self) -> TryLockResult<RwLockWriteGuard<'_, T>> {
        crate::sched::hook();
        self.0.try_write()
    }

    pub fn get_mut(&mut self) -> LockResult<&mut T> {
        self.0.get_mut()
    }
}

pub mod atomic {
    //! Preemption-instrumented atomics. Operations delegate to the
    //! host's atomics (no weak-memory simulation — see the crate docs),
    //! so the requested `Ordering` is honored by hardware, and the hook
    //! in front of each call is what diversifies interleavings.
    pub use std::sync::atomic::Ordering;

    macro_rules! int_atomic {
        ($name:ident, $std:ident, $t:ty) => {
            #[derive(Debug, Default)]
            pub struct $name(std::sync::atomic::$std);

            impl $name {
                pub const fn new(value: $t) -> Self {
                    $name(std::sync::atomic::$std::new(value))
                }

                pub fn load(&self, order: Ordering) -> $t {
                    crate::sched::hook();
                    self.0.load(order)
                }

                pub fn store(&self, value: $t, order: Ordering) {
                    crate::sched::hook();
                    self.0.store(value, order)
                }

                pub fn swap(&self, value: $t, order: Ordering) -> $t {
                    crate::sched::hook();
                    self.0.swap(value, order)
                }

                pub fn fetch_add(&self, value: $t, order: Ordering) -> $t {
                    crate::sched::hook();
                    self.0.fetch_add(value, order)
                }

                pub fn fetch_sub(&self, value: $t, order: Ordering) -> $t {
                    crate::sched::hook();
                    self.0.fetch_sub(value, order)
                }

                pub fn fetch_max(&self, value: $t, order: Ordering) -> $t {
                    crate::sched::hook();
                    self.0.fetch_max(value, order)
                }

                pub fn compare_exchange(
                    &self,
                    current: $t,
                    new: $t,
                    success: Ordering,
                    failure: Ordering,
                ) -> Result<$t, $t> {
                    crate::sched::hook();
                    self.0.compare_exchange(current, new, success, failure)
                }

                pub fn into_inner(self) -> $t {
                    self.0.into_inner()
                }
            }
        };
    }

    int_atomic!(AtomicU8, AtomicU8, u8);
    int_atomic!(AtomicU32, AtomicU32, u32);
    int_atomic!(AtomicU64, AtomicU64, u64);
    int_atomic!(AtomicUsize, AtomicUsize, usize);

    #[derive(Debug, Default)]
    pub struct AtomicBool(std::sync::atomic::AtomicBool);

    impl AtomicBool {
        pub const fn new(value: bool) -> Self {
            AtomicBool(std::sync::atomic::AtomicBool::new(value))
        }

        pub fn load(&self, order: Ordering) -> bool {
            crate::sched::hook();
            self.0.load(order)
        }

        pub fn store(&self, value: bool, order: Ordering) {
            crate::sched::hook();
            self.0.store(value, order)
        }

        pub fn swap(&self, value: bool, order: Ordering) -> bool {
            crate::sched::hook();
            self.0.swap(value, order)
        }

        pub fn into_inner(self) -> bool {
            self.0.into_inner()
        }
    }
}
