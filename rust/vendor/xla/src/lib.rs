//! Offline stub of the `xla-rs` PJRT bindings.
//!
//! The real crate links the PJRT C API and executes the AOT artifacts
//! under `artifacts/`; this stub exposes the same API surface but every
//! entry point returns [`Error::Unavailable`], so `Executor::new` fails
//! cleanly and every caller falls back to the pure-Rust native mirrors
//! (`runtime::native`). Swap this path dependency for the real bindings
//! in `rust/Cargo.toml` on an image with the PJRT toolchain baked in —
//! no source change needed in `runtime::executor`.

use std::path::Path;

/// The stub's only error: PJRT is not linked into this build.
#[derive(Debug, Clone)]
pub enum Error {
    Unavailable(&'static str),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Unavailable(what) => {
                write!(f, "{what}: built against the offline xla stub (no PJRT runtime linked)")
            }
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// PJRT client handle (stub: construction always fails).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(Error::Unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "xla-stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::Unavailable("PjRtClient::compile"))
    }
}

/// Parsed HLO module (stub: parsing always fails).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<Self> {
        Err(Error::Unavailable("HloModuleProto::from_text_file"))
    }
}

/// XLA computation wrapper.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation { _private: () }
    }
}

/// Host literal (stub: carries no data; conversions fail).
#[derive(Clone)]
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn vec1<T: Copy>(_data: &[T]) -> Self {
        Literal { _private: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::Unavailable("Literal::reshape"))
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        Err(Error::Unavailable("Literal::to_tuple1"))
    }

    pub fn to_vec<T: Copy>(&self) -> Result<Vec<T>> {
        Err(Error::Unavailable("Literal::to_vec"))
    }
}

/// Device buffer produced by an execution.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::Unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable (stub: unobtainable, methods exist for typeck).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unavailable("PjRtLoadedExecutable::execute"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_reports_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let lit = Literal::vec1(&[1.0f32, 2.0]);
        assert!(lit.reshape(&[2]).is_err());
        assert!(lit.to_vec::<f32>().is_err());
        let err = PjRtClient::cpu().err().unwrap();
        assert!(format!("{err}").contains("offline xla stub"));
    }
}
