//! Offline minimal drop-in for the `anyhow` crate (the registry is
//! unreachable in this build environment). Implements exactly the surface
//! this workspace uses — [`Error`], [`Result`], [`anyhow!`], [`bail!`],
//! and the [`Context`] extension for `Result`/`Option` — with the same
//! semantics: any `std::error::Error` converts via `?`, context wraps the
//! message, and `Error` itself deliberately does NOT implement
//! `std::error::Error` (mirroring real anyhow, which keeps the blanket
//! `From` impl coherent).

use std::fmt;

/// A type-erased error: message plus optional source chain rendering.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from any displayable message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { msg: message.to_string() }
    }

    /// Prepend context, chaining the prior message like anyhow's report.
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Self {
        // Render the source chain eagerly; we do not retain the boxed
        // error (nothing in this workspace downcasts).
        let mut msg = err.to_string();
        let mut src = err.source();
        while let Some(s) = src {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            src = s.source();
        }
        Error { msg }
    }
}

/// `anyhow::Result<T>` with the same default error parameter as anyhow.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Context extension for `Result` and `Option` (anyhow's `Context`).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/real/path/xyz")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let err = io_fail().unwrap_err();
        assert!(!format!("{err}").is_empty());
    }

    #[test]
    fn bail_and_anyhow_format() {
        fn f(x: i32) -> Result<i32> {
            if x < 0 {
                bail!("negative input {x}");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{}", f(-2).unwrap_err()), "negative input -2");
        let e = anyhow!("code {}", 7);
        assert_eq!(format!("{e:?}"), "code 7");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> =
            Err(std::io::Error::new(std::io::ErrorKind::Other, "boom"));
        let e = r.with_context(|| "writing snapshot").unwrap_err();
        assert_eq!(format!("{e}"), "writing snapshot: boom");
        let o: Option<u8> = None;
        let e = o.context("missing artifact").unwrap_err();
        assert_eq!(format!("{e}"), "missing artifact");
    }
}
