//! 1-stable (Cauchy) LSH for the L1 metric — the other instantiation of
//! the \[DIIM04\] p-stable framework (p = 1), included to substantiate the
//! paper's "easy to generalize" claim (§1.2.1): every sketch in this crate
//! is generic over `LshFamily`, so S-ANN/RACE/SW-AKDE work over L1 by
//! swapping this family in.
//!
//! h_j(x) = ⌊(a_j · x + b_j)/w⌋ with a_j i.i.d. standard Cauchy. For two
//! points at L1 distance s and t = s/w the collision probability is
//!   P(t) = 2·atan(1/t)/π − t·ln(1 + 1/t²)/π,
//! monotone decreasing in s (DIIM04, eq. for p = 1).

use super::LshFamily;
use crate::util::{dot, rng::Rng};

/// A bank of independent Cauchy LSH functions with shared width `w`.
pub struct CauchyLsh {
    dim: usize,
    n_funcs: usize,
    w: f32,
    /// Flat [dim, n_funcs] artifact layout.
    proj: Vec<f32>,
    proj_rows: Vec<f32>,
    biases: Vec<f32>,
}

impl CauchyLsh {
    pub fn new(dim: usize, n_funcs: usize, w: f32, rng: &mut Rng) -> Self {
        assert!(w > 0.0);
        let mut proj_rows = vec![0.0f32; dim * n_funcs];
        for v in proj_rows.iter_mut() {
            *v = rng.cauchy() as f32;
        }
        let mut proj = vec![0.0f32; dim * n_funcs];
        for j in 0..n_funcs {
            for i in 0..dim {
                proj[i * n_funcs + j] = proj_rows[j * dim + i];
            }
        }
        let biases = (0..n_funcs).map(|_| rng.uniform_f32() * w).collect();
        CauchyLsh { dim, n_funcs, w, proj, proj_rows, biases }
    }

    pub fn width(&self) -> f32 {
        self.w
    }

    /// Collision probability at L1 distance `s` for width `w` (p = 1).
    pub fn collision_prob_for(s: f64, w: f64) -> f64 {
        if s <= 0.0 {
            return 1.0;
        }
        let t = s / w;
        let p = 2.0 * (1.0 / t).atan() / std::f64::consts::PI
            - t * (1.0 + 1.0 / (t * t)).ln() / std::f64::consts::PI;
        p.clamp(0.0, 1.0)
    }
}

/// L1 distance.
pub fn l1(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum()
}

impl LshFamily for CauchyLsh {
    fn dim(&self) -> usize {
        self.dim
    }

    fn n_funcs(&self) -> usize {
        self.n_funcs
    }

    #[inline]
    fn hash_one(&self, j: usize, x: &[f32]) -> i64 {
        let row = &self.proj_rows[j * self.dim..(j + 1) * self.dim];
        (((dot(row, x) + self.biases[j]) / self.w).floor()) as i64
    }

    fn hash_range(&self, j0: usize, x: &[f32], out: &mut [i64]) {
        self.hash_batch(j0, x, out);
    }

    fn hash_batch(&self, j0: usize, xs: &[f32], out: &mut [i64]) {
        let (biases, w) = (&self.biases, self.w);
        super::hash_batch_rows(&self.proj_rows, self.dim, j0, xs, out, |j, y| {
            ((y + biases[j]) / w).floor() as i64
        });
    }

    /// `d` is L1 distance.
    fn collision_prob(&self, d: f64) -> f64 {
        Self::collision_prob_for(d, self.w as f64)
    }

    fn projection(&self) -> &[f32] {
        &self.proj
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collision_prob_monotone_and_bounded() {
        let mut prev = 1.0;
        for i in 0..100 {
            let s = i as f64 * 0.25;
            let p = CauchyLsh::collision_prob_for(s, 2.0);
            assert!((0.0..=1.0).contains(&p));
            assert!(p <= prev + 1e-12, "s={s}");
            prev = p;
        }
        assert_eq!(CauchyLsh::collision_prob_for(0.0, 1.0), 1.0);
    }

    #[test]
    fn empirical_collision_matches_model() {
        let dim = 8;
        let fam = CauchyLsh::new(dim, 4000, 4.0, &mut Rng::new(1));
        let mut rng = Rng::new(2);
        let x: Vec<f32> = (0..dim).map(|_| rng.gaussian_f32()).collect();
        for &step in &[0.25f32, 1.0, 3.0] {
            // y at L1 distance dim*step (uniform perturbation)
            let y: Vec<f32> = x.iter().map(|v| v + step).collect();
            let s = l1(&x, &y) as f64;
            let hits = (0..fam.n_funcs())
                .filter(|&j| fam.hash_one(j, &x) == fam.hash_one(j, &y))
                .count();
            let emp = hits as f64 / fam.n_funcs() as f64;
            let model = fam.collision_prob(s);
            assert!(
                (emp - model).abs() < 0.05,
                "s={s}: emp={emp} model={model}"
            );
        }
    }

    #[test]
    fn race_generalizes_to_l1_kernel() {
        // The paper's "broadly applicable" claim: RACE over CauchyLsh
        // estimates the L1 collision kernel sum, unbiased up to rehash
        // debiasing — checked against the exact kernel.
        use crate::sketch::race::Race;
        let dim = 8;
        let (rows, p, range, w) = (256usize, 2usize, 64usize, 4.0f32);
        let fam = CauchyLsh::new(dim, rows * p, w, &mut Rng::new(3));
        let mut rng = Rng::new(4);
        let data: Vec<Vec<f32>> = (0..150)
            .map(|_| (0..dim).map(|_| rng.gaussian_f32()).collect())
            .collect();
        let mut race = Race::new(rows, range, p);
        for x in &data {
            race.add(&fam, x);
        }
        let q: Vec<f32> = (0..dim).map(|_| rng.gaussian_f32()).collect();
        let truth: f64 = data
            .iter()
            .map(|x| CauchyLsh::collision_prob_for(l1(x, &q) as f64, w as f64).powi(2))
            .sum();
        let est = race.query_debiased(&fam, &q);
        assert!(
            (est - truth).abs() < 0.35 * truth.max(1.0),
            "est={est} truth={truth}"
        );
    }

    #[test]
    fn l1_distance() {
        assert_eq!(l1(&[1.0, -2.0], &[3.0, 1.0]), 5.0);
    }
}
