//! Concatenated (amplified) hash functions — §2.1 / §2.2.
//!
//! * [`TableHasher`]: g_j = (h_{jk+1}, …, h_{jk+k}) → an unbounded u64 key
//!   for the S-ANN bucket tables (collision prob p^k). Keys are mixed from
//!   the raw slot tuple; "standard hashing" keeps only non-empty buckets
//!   (storage::hashtable).
//! * [`BoundedHasher`]: the same concatenation rehashed to a finite range
//!   [0, W) for RACE / SW-AKDE cells — the paper's "rehashing" of p-stable
//!   functions with unbounded range (§5.2 Implementation).

use super::LshFamily;

/// 64-bit mix (splitmix64 finalizer) — avalanches the raw slot tuple.
#[inline]
pub fn mix64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Combine a tuple of raw slots into one key; order-sensitive.
#[inline]
pub fn combine_slots(slots: &[i64]) -> u64 {
    let mut acc = 0x9E37_79B9_7F4A_7C15u64;
    for &s in slots {
        acc = mix64(acc ^ (s as u64).wrapping_mul(0xFF51_AFD7_ED55_8CCD));
        acc = acc.rotate_left(23).wrapping_add(0x2545_F491_4F6C_DD1D);
    }
    mix64(acc)
}

/// L concatenated functions of k raw hashes each, keys in u64.
pub struct TableHasher {
    pub k: usize,
    pub l: usize,
}

impl TableHasher {
    pub fn new(k: usize, l: usize) -> Self {
        assert!(k > 0 && l > 0);
        TableHasher { k, l }
    }

    /// Raw functions consumed (the family must expose at least this many).
    pub fn funcs_needed(&self) -> usize {
        self.k * self.l
    }

    /// Key of table `j` for point `x`.
    pub fn key<F: LshFamily + ?Sized>(&self, fam: &F, j: usize, x: &[f32], scratch: &mut Vec<i64>) -> u64 {
        debug_assert!(j < self.l);
        scratch.clear();
        scratch.resize(self.k, 0);
        fam.hash_range(j * self.k, x, scratch);
        combine_slots(scratch)
    }

    /// All L keys for `x` into `out`. One batched-kernel pass over the full
    /// [k·L, dim] projection block; `scratch` comes from the caller so the
    /// hot insert/query paths never allocate.
    pub fn keys<F: LshFamily + ?Sized>(
        &self,
        fam: &F,
        x: &[f32],
        out: &mut Vec<u64>,
        scratch: &mut Vec<i64>,
    ) {
        scratch.clear();
        scratch.resize(self.k * self.l, 0);
        fam.hash_range(0, x, scratch);
        self.keys_from_slots(scratch, out);
    }

    /// All L keys for each of the points in `xs` (row-major [n, dim]) via
    /// one GEMM-shaped `hash_batch` call; `out` becomes [n, L] row-major.
    pub fn keys_batch<F: LshFamily + ?Sized>(
        &self,
        fam: &F,
        xs: &[f32],
        out: &mut Vec<u64>,
        scratch: &mut Vec<i64>,
    ) {
        let d = fam.dim();
        debug_assert!(d > 0 && xs.len() % d == 0);
        let n = xs.len() / d;
        let h = self.k * self.l;
        scratch.clear();
        scratch.resize(n * h, 0);
        fam.hash_batch(0, xs, scratch);
        out.clear();
        out.reserve(n * self.l);
        for row in scratch.chunks_exact(h) {
            for j in 0..self.l {
                out.push(combine_slots(&row[j * self.k..(j + 1) * self.k]));
            }
        }
    }

    /// Combine a row of precomputed raw slots (from the PJRT hash artifact,
    /// laid out [H = k*L] per point) into the L table keys.
    pub fn keys_from_slots(&self, slots: &[i64], out: &mut Vec<u64>) {
        debug_assert!(slots.len() >= self.k * self.l);
        out.clear();
        for j in 0..self.l {
            out.push(combine_slots(&slots[j * self.k..(j + 1) * self.k]));
        }
    }
}

/// How a raw-slot tuple becomes a bounded cell index.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CellMap {
    /// Binary slots packed as bits — injective, range = 2^p. This is the
    /// exact RACE cell structure for SRP (collision ⇔ all p hashes agree),
    /// so the ACE unbiasedness theorem holds with no correction.
    PackBits,
    /// Mix-and-mod rehash — the paper's "rehashing" for unbounded p-stable
    /// slots (§5.2). Distinct tuples spuriously collide w.p. ≈ 1/range;
    /// see `Race::query_debiased` for the unbiased correction.
    Rehash,
}

/// R concatenated functions of p raw hashes each, mapped into [0, range).
pub struct BoundedHasher {
    pub p: usize,
    pub rows: usize,
    pub range: usize,
    pub map: CellMap,
}

impl BoundedHasher {
    /// Rehash mode (p-stable and other unbounded-range families).
    pub fn new(p: usize, rows: usize, range: usize) -> Self {
        assert!(p > 0 && rows > 0 && range > 0);
        BoundedHasher { p, rows, range, map: CellMap::Rehash }
    }

    /// Bit-packing mode for binary families (SRP): range is 2^p.
    pub fn new_packed(p: usize, rows: usize) -> Self {
        assert!(p > 0 && p < 32 && rows > 0);
        BoundedHasher { p, rows, range: 1 << p, map: CellMap::PackBits }
    }

    pub fn funcs_needed(&self) -> usize {
        self.p * self.rows
    }

    #[inline]
    fn map_tuple(&self, slots: &[i64]) -> usize {
        match self.map {
            CellMap::PackBits => {
                let mut cell = 0usize;
                for (i, &s) in slots.iter().enumerate() {
                    debug_assert!(s == 0 || s == 1, "PackBits needs binary slots");
                    cell |= (s as usize & 1) << i;
                }
                cell
            }
            CellMap::Rehash => (combine_slots(slots) % self.range as u64) as usize,
        }
    }

    /// Cell index of row `i` for point `x`.
    pub fn cell<F: LshFamily + ?Sized>(&self, fam: &F, i: usize, x: &[f32], scratch: &mut Vec<i64>) -> usize {
        debug_assert!(i < self.rows);
        scratch.clear();
        scratch.resize(self.p, 0);
        fam.hash_range(i * self.p, x, scratch);
        self.map_tuple(scratch)
    }

    /// All `rows` cell indices for `x` in one kernel pass over the full
    /// [rows·p, dim] projection block (instead of `rows` strided `cell`
    /// calls). `out` must have length `rows`.
    pub fn cells<F: LshFamily + ?Sized>(
        &self,
        fam: &F,
        x: &[f32],
        out: &mut [usize],
        scratch: &mut Vec<i64>,
    ) {
        debug_assert_eq!(out.len(), self.rows);
        scratch.clear();
        scratch.resize(self.rows * self.p, 0);
        fam.hash_range(0, x, scratch);
        for (i, o) in out.iter_mut().enumerate() {
            *o = self.cell_from_slots(i, scratch);
        }
    }

    /// Cell indices for a whole batch (xs row-major [n, dim]) via one
    /// GEMM-shaped `hash_batch` call; `out` becomes [n, rows] row-major.
    pub fn cells_batch<F: LshFamily + ?Sized>(
        &self,
        fam: &F,
        xs: &[f32],
        out: &mut Vec<usize>,
        scratch: &mut Vec<i64>,
    ) {
        let d = fam.dim();
        debug_assert!(d > 0 && xs.len() % d == 0);
        let n = xs.len() / d;
        let h = self.rows * self.p;
        scratch.clear();
        scratch.resize(n * h, 0);
        fam.hash_batch(0, xs, scratch);
        out.clear();
        out.reserve(n * self.rows);
        for row in scratch.chunks_exact(h) {
            for i in 0..self.rows {
                out.push(self.cell_from_slots(i, row));
            }
        }
    }

    /// Cell index from precomputed raw slots (PJRT artifact path).
    pub fn cell_from_slots(&self, row: usize, slots: &[i64]) -> usize {
        self.map_tuple(&slots[row * self.p..(row + 1) * self.p])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lsh::pstable::PStableLsh;
    use crate::lsh::srp::SrpLsh;
    use crate::util::rng::Rng;

    #[test]
    fn combine_is_order_sensitive() {
        assert_ne!(combine_slots(&[1, 2]), combine_slots(&[2, 1]));
        assert_ne!(combine_slots(&[0]), combine_slots(&[0, 0]));
    }

    #[test]
    fn equal_tuples_equal_keys() {
        assert_eq!(combine_slots(&[5, -3, 7]), combine_slots(&[5, -3, 7]));
    }

    #[test]
    fn table_keys_deterministic_and_distinct_across_tables() {
        let fam = PStableLsh::new(8, 4 * 6, 2.0, &mut Rng::new(1));
        let th = TableHasher::new(4, 6);
        let x: Vec<f32> = (0..8).map(|i| i as f32 * 0.3).collect();
        let mut a = Vec::new();
        let mut b = Vec::new();
        let mut scratch = Vec::new();
        th.keys(&fam, &x, &mut a, &mut scratch);
        th.keys(&fam, &x, &mut b, &mut scratch);
        assert_eq!(a, b);
        assert_eq!(a.len(), 6);
        let distinct: std::collections::HashSet<_> = a.iter().collect();
        assert!(distinct.len() >= 5, "tables should rarely share keys");
    }

    #[test]
    fn keys_from_slots_matches_native_path() {
        let fam = SrpLsh::new(10, 3 * 5, &mut Rng::new(2));
        let th = TableHasher::new(3, 5);
        let mut rng = Rng::new(3);
        let x: Vec<f32> = (0..10).map(|_| rng.gaussian_f32()).collect();
        let mut native = Vec::new();
        let mut scratch = Vec::new();
        th.keys(&fam, &x, &mut native, &mut scratch);
        // emulate the artifact: all raw slots precomputed in a row
        let mut slots = vec![0i64; 15];
        fam.hash_range(0, &x, &mut slots);
        let mut from_slots = Vec::new();
        th.keys_from_slots(&slots, &mut from_slots);
        assert_eq!(native, from_slots);
    }

    #[test]
    fn bounded_cells_in_range_and_well_spread() {
        // p-stable slots are unbounded, so the rehash should cover the range.
        let fam = PStableLsh::new(16, 4 * 8, 0.5, &mut Rng::new(4));
        let bh = BoundedHasher::new(4, 8, 64);
        let mut rng = Rng::new(5);
        let mut histogram = vec![0usize; 64];
        let mut scratch = Vec::new();
        for _ in 0..2000 {
            let x: Vec<f32> = (0..16).map(|_| rng.gaussian_f32()).collect();
            for i in 0..8 {
                let c = bh.cell(&fam, i, &x, &mut scratch);
                assert!(c < 64);
                histogram[c] += 1;
            }
        }
        let occupied = histogram.iter().filter(|&&c| c > 0).count();
        assert!(occupied > 48, "occupied={occupied}");
    }

    #[test]
    fn bounded_cells_srp_limited_alphabet() {
        // k SRP bits give at most 2^k distinct tuples -> at most 2^k cells;
        // all of them must land in range and identical tuples must agree.
        let fam = SrpLsh::new(16, 4 * 2, &mut Rng::new(14));
        let bh = BoundedHasher::new(4, 2, 64);
        let mut rng = Rng::new(15);
        let mut scratch = Vec::new();
        let mut distinct = std::collections::HashSet::new();
        for _ in 0..500 {
            let x: Vec<f32> = (0..16).map(|_| rng.gaussian_f32()).collect();
            for i in 0..2 {
                let c = bh.cell(&fam, i, &x, &mut scratch);
                assert!(c < 64);
                distinct.insert(c);
            }
        }
        assert!(distinct.len() <= 16, "distinct={}", distinct.len());
    }

    #[test]
    fn keys_batch_matches_per_point_keys() {
        let fam = PStableLsh::new(9, 3 * 7, 2.0, &mut Rng::new(40));
        let th = TableHasher::new(3, 7);
        let mut rng = Rng::new(41);
        let mut xs = vec![0.0f32; 11 * 9];
        rng.fill_gaussian_f32(&mut xs);
        let (mut batch, mut scratch) = (Vec::new(), Vec::new());
        th.keys_batch(&fam, &xs, &mut batch, &mut scratch);
        assert_eq!(batch.len(), 11 * 7);
        let mut single = Vec::new();
        for (pi, x) in xs.chunks_exact(9).enumerate() {
            th.keys(&fam, x, &mut single, &mut scratch);
            assert_eq!(&batch[pi * 7..(pi + 1) * 7], single.as_slice(), "point {pi}");
        }
    }

    #[test]
    fn cells_and_cells_batch_match_per_row_cell() {
        let fam = PStableLsh::new(12, 3 * 6, 1.0, &mut Rng::new(42));
        let bh = BoundedHasher::new(3, 6, 32);
        let mut rng = Rng::new(43);
        let mut xs = vec![0.0f32; 5 * 12];
        rng.fill_gaussian_f32(&mut xs);
        let mut scratch = Vec::new();
        let (mut batch, mut bscratch) = (Vec::new(), Vec::new());
        bh.cells_batch(&fam, &xs, &mut batch, &mut bscratch);
        assert_eq!(batch.len(), 5 * 6);
        let mut one = vec![0usize; 6];
        for (pi, x) in xs.chunks_exact(12).enumerate() {
            bh.cells(&fam, x, &mut one, &mut bscratch);
            for i in 0..6 {
                assert_eq!(bh.cell(&fam, i, x, &mut scratch), one[i]);
                assert_eq!(batch[pi * 6 + i], one[i]);
            }
        }
    }

    #[test]
    fn bounded_cell_from_slots_matches_native() {
        let fam = PStableLsh::new(6, 2 * 4, 1.5, &mut Rng::new(6));
        let bh = BoundedHasher::new(2, 4, 32);
        let mut rng = Rng::new(7);
        let x: Vec<f32> = (0..6).map(|_| rng.gaussian_f32()).collect();
        let mut slots = vec![0i64; 8];
        fam.hash_range(0, &x, &mut slots);
        let mut scratch = Vec::new();
        for i in 0..4 {
            assert_eq!(bh.cell(&fam, i, &x, &mut scratch), bh.cell_from_slots(i, &slots));
        }
    }

    #[test]
    fn nearby_points_share_more_table_keys_than_far_points() {
        let dim = 16;
        let fam = PStableLsh::new(dim, 2 * 32, 4.0, &mut Rng::new(8));
        let th = TableHasher::new(2, 32);
        let mut rng = Rng::new(9);
        let x: Vec<f32> = (0..dim).map(|_| rng.gaussian_f32()).collect();
        let near: Vec<f32> = x.iter().map(|v| v + 0.05).collect();
        let far: Vec<f32> = x.iter().map(|v| v + 10.0).collect();
        let (mut kx, mut kn, mut kf) = (Vec::new(), Vec::new(), Vec::new());
        let mut scratch = Vec::new();
        th.keys(&fam, &x, &mut kx, &mut scratch);
        th.keys(&fam, &near, &mut kn, &mut scratch);
        th.keys(&fam, &far, &mut kf, &mut scratch);
        let near_matches = kx.iter().zip(&kn).filter(|(a, b)| a == b).count();
        let far_matches = kx.iter().zip(&kf).filter(|(a, b)| a == b).count();
        assert!(near_matches > far_matches, "near={near_matches} far={far_matches}");
        assert_eq!(far_matches, 0);
    }
}
