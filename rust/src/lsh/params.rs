//! Parameter arithmetic for the S-ANN theorems (§3, Lemmas 3.2/3.3).
//!
//! Given an (r, cr, p₁, p₂)-sensitive family:
//!   ρ = log(1/p₁) / log(1/p₂)
//!   k = ⌈log_{1/p₂} n⌉            (Lemma 3.2: E₂ succeeds w.p. ≥ 1 − 1/(3nᵉ))
//!   L = ⌈nᵖ / p₁⌉                 (Lemma 3.3: E₁ succeeds w.p. ≥ (1−e^{−mp})(1−1/e))
//!
//! plus the failure-probability expressions of Theorems 3.1 and 3.3 so the
//! benches can print theory next to measurement.

use crate::lsh::pstable::PStableLsh;

/// Sensitivity of a p-stable family for a given (r, c, w).
#[derive(Clone, Copy, Debug)]
pub struct Sensitivity {
    pub r: f64,
    pub c: f64,
    pub w: f64,
    pub p1: f64,
    pub p2: f64,
}

impl Sensitivity {
    /// Evaluate p₁ = P(r), p₂ = P(cr) for the p-stable family.
    pub fn pstable(r: f64, c: f64, w: f64) -> Self {
        assert!(r > 0.0 && c > 1.0 && w > 0.0);
        let p1 = PStableLsh::collision_prob_for(r, w);
        let p2 = PStableLsh::collision_prob_for(c * r, w);
        Sensitivity { r, c, w, p1, p2 }
    }

    pub fn rho(&self) -> f64 {
        (1.0 / self.p1).ln() / (1.0 / self.p2).ln()
    }
}

/// Concrete table parameters for a stream bound n and sampling exponent η.
#[derive(Clone, Copy, Debug)]
pub struct AnnParams {
    pub n: usize,
    pub eta: f64,
    pub k: usize,
    pub l: usize,
    pub rho: f64,
    pub p1: f64,
    pub p2: f64,
    /// Bernoulli retention probability p = n^{−η}.
    pub keep_prob: f64,
}

impl AnnParams {
    /// Instantiate Lemmas 3.2/3.3 (with practical caps so experiments at
    /// modest n don't explode: k ≥ 1, L capped by `l_cap`).
    pub fn derive(sens: &Sensitivity, n: usize, eta: f64, l_cap: usize) -> Self {
        assert!(n > 1);
        assert!((0.0..=1.0).contains(&eta));
        let nf = n as f64;
        let rho = sens.rho();
        let k = (nf.ln() / (1.0 / sens.p2).ln()).ceil().max(1.0) as usize;
        let l_raw = (nf.powf(rho) / sens.p1).ceil().max(1.0) as usize;
        let l = l_raw.min(l_cap).max(1);
        AnnParams {
            n,
            eta,
            k,
            l,
            rho,
            p1: sens.p1,
            p2: sens.p2,
            keep_prob: nf.powf(-eta),
        }
    }

    /// Expected number of stored points, n^{1−η}.
    pub fn expected_stored(&self) -> f64 {
        (self.n as f64).powf(1.0 - self.eta)
    }

    /// Candidate cap from Algorithm 1 (3L).
    pub fn candidate_cap(&self) -> usize {
        3 * self.l
    }

    /// Theorem 3.1 failure bound: 1/(3nᵉ) + (e^{mp} + e − 1)/e^{mp+1},
    /// where m is the Poisson mean of points per r-ball and p = n^{−η}.
    pub fn failure_bound_streaming(&self, m: f64) -> f64 {
        let nf = self.n as f64;
        let mp = m * self.keep_prob;
        let e = std::f64::consts::E;
        let term2 = (mp.exp() + e - 1.0) / (mp + 1.0).exp();
        1.0 / (3.0 * nf.powf(self.eta)) + term2
    }

    /// Theorem 3.3 failure bound with ≤ d adversarial deletions per r-ball:
    /// 1/(3nᵉ) + 1/e + e^{d − mp + d ln(mp/d)} (1 − 1/e).
    pub fn failure_bound_turnstile(&self, m: f64, d: f64) -> f64 {
        let nf = self.n as f64;
        let mp = m * self.keep_prob;
        let e = std::f64::consts::E;
        let tail = if d <= 0.0 {
            (-mp).exp() // P(S <= 0) = e^{-mp}
        } else {
            assert!(d <= mp, "Lemma 3.4 requires d <= mp");
            (d - mp + d * (mp / d).ln()).exp()
        };
        1.0 / (3.0 * nf.powf(self.eta)) + 1.0 / e + tail * (1.0 - 1.0 / e)
    }

    /// Sketch word-space bound O(n^{1+ρ−η} / p₁) from Theorem 3.1.
    pub fn space_bound_words(&self) -> f64 {
        (self.n as f64).powf(1.0 + self.rho - self.eta) / self.p1
    }
}

/// Poisson tail bound of Lemma 3.4: P(S ≤ d) ≤ e^{d − λ + d ln(λ/d)}.
pub fn poisson_lower_tail_bound(lambda: f64, d: f64) -> f64 {
    assert!(lambda > 0.0);
    if d <= 0.0 {
        return (-lambda).exp();
    }
    assert!(d <= lambda);
    (d - lambda + d * (lambda / d).ln()).exp().min(1.0)
}

/// Search a bucket width w minimizing ρ subject to p₂ ≤ `p2_cap`.
///
/// The cap matters in practice: large w drives p₁, p₂ → 1, which can
/// shrink ρ slightly but explodes k = ⌈log_{1/p₂} n⌉ (k ≈ 110 at
/// p₂ = 0.92, n = 10⁴) and with it per-query hashing cost. Capping
/// p₂ ≈ 0.5 keeps k ≈ log₂ n. (The paper fixes w per run; this helper
/// picks the same kind of operating point automatically.)
pub fn tune_width_capped(r: f64, c: f64, candidates: &[f64], p2_cap: f64) -> Sensitivity {
    let mut best: Option<Sensitivity> = None;
    for &w in candidates {
        let s = Sensitivity::pstable(r, c, w);
        if s.p1 <= 0.0 || s.p2 <= 0.0 || s.p1 >= 1.0 || s.p2 > p2_cap {
            continue;
        }
        let better = match &best {
            None => true,
            Some(b) => s.rho() < b.rho(),
        };
        if better {
            best = Some(s);
        }
    }
    best.expect("no valid width candidate under the p2 cap")
}

/// Uncapped variant (minimizes ρ alone).
pub fn tune_width(r: f64, c: f64, candidates: &[f64]) -> Sensitivity {
    tune_width_capped(r, c, candidates, 1.0)
}

/// Default width grid (multiples of r) and p₂ cap for experiments.
pub fn default_width(r: f64, c: f64) -> Sensitivity {
    let grid: Vec<f64> = [0.5, 0.75, 1.0, 1.5, 2.0, 3.0, 4.0]
        .iter()
        .map(|m| m * r)
        .collect();
    tune_width_capped(r, c, &grid, 0.6)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sens() -> Sensitivity {
        Sensitivity::pstable(0.5, 2.0, 2.0)
    }

    #[test]
    fn sensitivity_orders_probabilities() {
        let s = sens();
        assert!(s.p1 > s.p2, "p1={} p2={}", s.p1, s.p2);
        assert!(s.rho() > 0.0 && s.rho() < 1.0, "rho={}", s.rho());
    }

    #[test]
    fn derive_matches_lemma_formulas() {
        let s = sens();
        let p = AnnParams::derive(&s, 10_000, 0.5, usize::MAX);
        let expect_k = ((10_000f64).ln() / (1.0 / s.p2).ln()).ceil() as usize;
        let expect_l = ((10_000f64).powf(s.rho()) / s.p1).ceil() as usize;
        assert_eq!(p.k, expect_k);
        assert_eq!(p.l, expect_l);
        assert!((p.keep_prob - 0.01).abs() < 1e-12);
        assert!((p.expected_stored() - 100.0).abs() < 1e-9);
    }

    #[test]
    fn l_cap_is_honored() {
        let p = AnnParams::derive(&sens(), 1_000_000, 0.3, 64);
        assert!(p.l <= 64);
        assert_eq!(p.candidate_cap(), 3 * p.l);
    }

    #[test]
    fn failure_bound_decreases_with_density() {
        let p = AnnParams::derive(&sens(), 10_000, 0.5, 256);
        // m >= C n^eta with growing C -> smaller failure bound
        let loose = p.failure_bound_streaming(1.0 * p.expected_stored());
        let tight = p.failure_bound_streaming(10.0 * p.expected_stored());
        assert!(tight < loose);
        assert!(tight < 1.0);
    }

    #[test]
    fn turnstile_bound_exceeds_streaming_and_grows_with_deletions() {
        let p = AnnParams::derive(&sens(), 10_000, 0.4, 256);
        let m = 5.0 * (10_000f64).powf(0.4);
        let mp = m * p.keep_prob;
        let b0 = p.failure_bound_turnstile(m, 0.0);
        let b1 = p.failure_bound_turnstile(m, (mp * 0.5).floor());
        let b2 = p.failure_bound_turnstile(m, mp.floor().max(1.0));
        assert!(b0 <= b1 && b1 <= b2, "b0={b0} b1={b1} b2={b2}");
    }

    #[test]
    fn poisson_tail_bound_sane() {
        assert!((poisson_lower_tail_bound(10.0, 0.0) - (-10.0f64).exp()).abs() < 1e-12);
        assert!(poisson_lower_tail_bound(10.0, 10.0) >= 0.99); // bound is weak at d=lambda
        assert!(poisson_lower_tail_bound(10.0, 2.0) < 0.1);
    }

    #[test]
    fn space_bound_is_sublinear_when_eta_exceeds_rho() {
        let s = sens();
        let rho = s.rho();
        let p = AnnParams::derive(&s, 100_000, rho + 0.2, usize::MAX);
        // n^{1+rho-eta} < n  ⇔  eta > rho
        assert!(p.space_bound_words() < 100_000.0 / s.p1);
    }

    #[test]
    fn tune_width_picks_minimal_rho() {
        let cands = [0.5, 1.0, 2.0, 4.0, 8.0];
        let best = tune_width(0.5, 2.0, &cands);
        for &w in &cands {
            let s = Sensitivity::pstable(0.5, 2.0, w);
            assert!(best.rho() <= s.rho() + 1e-12);
        }
    }
}
