//! p-stable Euclidean LSH — Datar–Immorlica–Indyk–Mirrokni \[DIIM04\], §2.1.
//!
//! h_j(x) = ⌊(a_j · x + b_j) / w⌋ with a_j ~ N(0, I) (2-stable) and
//! b_j ~ U[0, w). The collision probability at L2 distance s, with
//! t = s/w, is
//!
//!   P(t) = 1 − 2Φ(−1/t) − (2t/√(2π)) (1 − e^{−1/(2t²)}),
//!
//! monotonically decreasing in s — the (r, cr, p₁, p₂)-sensitivity the
//! S-ANN theorems instantiate, and the Euclidean collision kernel the KDE
//! experiments estimate (Figs 9a/9c).

use super::LshFamily;
use crate::util::{dot, rng::Rng};

/// A bank of independent p-stable functions with shared bucket width `w`.
pub struct PStableLsh {
    dim: usize,
    n_funcs: usize,
    w: f32,
    /// Flat [dim, n_funcs] artifact layout (column per function).
    proj: Vec<f32>,
    /// Row-major [n_funcs, dim] for native hashing.
    proj_rows: Vec<f32>,
    biases: Vec<f32>,
}

/// Standard normal CDF via erf (Abramowitz–Stegun 7.1.26 rational approx
/// is not enough for tail agreement with the jax oracle; use the same
/// erf-based formula as ref.py with a high-accuracy erf).
fn norm_cdf(z: f64) -> f64 {
    0.5 * (1.0 + erf(z / std::f64::consts::SQRT_2))
}

/// erf with ~1e-12 absolute error (Numerical Recipes erfc expansion).
pub fn erf(x: f64) -> f64 {
    1.0 - erfc(x)
}

pub fn erfc(x: f64) -> f64 {
    let z = x.abs();
    let t = 2.0 / (2.0 + z);
    let ty = 4.0 * t - 2.0;
    const COF: [f64; 28] = [
        -1.3026537197817094,
        6.4196979235649026e-1,
        1.9476473204185836e-2,
        -9.561514786808631e-3,
        -9.46595344482036e-4,
        3.66839497852761e-4,
        4.2523324806907e-5,
        -2.0278578112534e-5,
        -1.624290004647e-6,
        1.303655835580e-6,
        1.5626441722e-8,
        -8.5238095915e-8,
        6.529054439e-9,
        5.059343495e-9,
        -9.91364156e-10,
        -2.27365122e-10,
        9.6467911e-11,
        2.394038e-12,
        -6.886027e-12,
        8.94487e-13,
        3.13092e-13,
        -1.12708e-13,
        3.81e-16,
        7.106e-15,
        -1.523e-15,
        -9.4e-17,
        1.21e-16,
        -2.8e-17,
    ];
    let mut d = 0.0;
    let mut dd = 0.0;
    for j in (1..COF.len()).rev() {
        let tmp = d;
        d = ty * d - dd + COF[j];
        dd = tmp;
    }
    let ans = t * (-z * z + 0.5 * (COF[0] + ty * d) - dd).exp();
    if x >= 0.0 {
        ans
    } else {
        2.0 - ans
    }
}

impl PStableLsh {
    pub fn new(dim: usize, n_funcs: usize, w: f32, rng: &mut Rng) -> Self {
        assert!(w > 0.0, "bucket width must be positive");
        let mut proj_rows = vec![0.0f32; dim * n_funcs];
        rng.fill_gaussian_f32(&mut proj_rows);
        let mut proj = vec![0.0f32; dim * n_funcs];
        for j in 0..n_funcs {
            for i in 0..dim {
                proj[i * n_funcs + j] = proj_rows[j * dim + i];
            }
        }
        let biases = (0..n_funcs).map(|_| rng.uniform_f32() * w).collect();
        PStableLsh { dim, n_funcs, w, proj, proj_rows, biases }
    }

    pub fn width(&self) -> f32 {
        self.w
    }

    pub fn biases(&self) -> &[f32] {
        &self.biases
    }

    #[inline]
    fn row(&self, j: usize) -> &[f32] {
        &self.proj_rows[j * self.dim..(j + 1) * self.dim]
    }

    /// Collision probability of one function at L2 distance `s` for bucket
    /// width `w` (static so `params` can search over w before construction).
    pub fn collision_prob_for(s: f64, w: f64) -> f64 {
        if s <= 0.0 {
            return 1.0;
        }
        let t = s / w;
        let p = 1.0 - 2.0 * norm_cdf(-1.0 / t)
            - (2.0 * t / (2.0 * std::f64::consts::PI).sqrt())
                * (1.0 - (-1.0 / (2.0 * t * t)).exp());
        p.clamp(0.0, 1.0)
    }
}

impl LshFamily for PStableLsh {
    fn dim(&self) -> usize {
        self.dim
    }

    fn n_funcs(&self) -> usize {
        self.n_funcs
    }

    #[inline]
    fn hash_one(&self, j: usize, x: &[f32]) -> i64 {
        // floor semantics must match jnp.floor in the Pallas kernel:
        // compute in f32 like the artifact does, then floor.
        (((dot(self.row(j), x) + self.biases[j]) / self.w).floor()) as i64
    }

    fn hash_range(&self, j0: usize, x: &[f32], out: &mut [i64]) {
        self.hash_batch(j0, x, out);
    }

    fn hash_batch(&self, j0: usize, xs: &[f32], out: &mut [i64]) {
        let (biases, w) = (&self.biases, self.w);
        super::hash_batch_rows(&self.proj_rows, self.dim, j0, xs, out, |j, y| {
            ((y + biases[j]) / w).floor() as i64
        });
    }

    fn collision_prob(&self, d: f64) -> f64 {
        Self::collision_prob_for(d, self.w as f64)
    }

    fn projection(&self) -> &[f32] {
        &self.proj
    }

    fn as_any_pstable(&self) -> Option<&PStableLsh> {
        Some(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn erf_reference_values() {
        // against scipy.special.erf
        assert!((erf(0.0)).abs() < 1e-14);
        assert!((erf(0.5) - 0.5204998778130465).abs() < 1e-10);
        assert!((erf(1.0) - 0.8427007929497149).abs() < 1e-10);
        assert!((erf(2.0) - 0.9953222650189527).abs() < 1e-10);
        assert!((erf(-1.0) + 0.8427007929497149).abs() < 1e-10);
    }

    #[test]
    fn collision_prob_is_monotone_decreasing_in_distance() {
        let mut prev = 1.0;
        for i in 0..200 {
            let s = i as f64 * 0.1;
            let p = PStableLsh::collision_prob_for(s, 4.0);
            assert!(p <= prev + 1e-12, "s={s} p={p} prev={prev}");
            assert!((0.0..=1.0).contains(&p));
            prev = p;
        }
    }

    #[test]
    fn collision_prob_limits() {
        assert_eq!(PStableLsh::collision_prob_for(0.0, 1.0), 1.0);
        assert!(PStableLsh::collision_prob_for(1000.0, 1.0) < 0.01);
        // wider buckets collide more at fixed distance
        let narrow = PStableLsh::collision_prob_for(2.0, 1.0);
        let wide = PStableLsh::collision_prob_for(2.0, 8.0);
        assert!(wide > narrow);
    }

    #[test]
    fn identical_points_collide_on_all_functions() {
        let fam = PStableLsh::new(10, 32, 4.0, &mut Rng::new(7));
        let x: Vec<f32> = (0..10).map(|i| (i as f32).sqrt()).collect();
        for j in 0..32 {
            assert_eq!(fam.hash_one(j, &x), fam.hash_one(j, &x.clone()));
        }
    }

    #[test]
    fn floor_handles_negative_projections() {
        // A point far in the negative direction must get negative slots,
        // not truncate toward zero.
        let mut rng = Rng::new(8);
        let fam = PStableLsh::new(2, 8, 1.0, &mut rng);
        let x = [-100.0f32, -100.0];
        let any_negative = (0..8).any(|j| fam.hash_one(j, &x) < 0);
        assert!(any_negative);
    }

    #[test]
    fn bias_in_range() {
        let fam = PStableLsh::new(4, 64, 2.5, &mut Rng::new(9));
        for &b in fam.biases() {
            assert!((0.0..2.5).contains(&b));
        }
    }
}
