//! Locality-sensitive hashing substrate (paper §2.1).
//!
//! Two families, exactly the ones the paper evaluates: SRP/angular
//! (\[Cha02\], `srp`) and p-stable Euclidean (\[DIIM04\], `pstable`).
//! `concat` builds the amplified functions g = (h₁..h_k) used by S-ANN
//! tables and the bounded-range concatenations used by RACE/SW-AKDE cells.
//! `params` holds the ρ/k/L arithmetic from Lemmas 3.2/3.3.
//!
//! The raw projection matrices live here (generated from the experiment
//! seed) and are the *same* buffers handed to the PJRT artifacts, so the
//! native path and the AOT batch path hash identically.

pub mod cauchy;
pub mod concat;
pub mod params;
pub mod pstable;
pub mod srp;

/// A family of raw LSH functions h_j over f32 vectors.
///
/// Implementations expose `n_funcs` independent functions; callers group
/// them into k-wise concatenations (see [`concat`]).
pub trait LshFamily: Send + Sync {
    /// Input dimensionality.
    fn dim(&self) -> usize;
    /// Number of independent raw functions available.
    fn n_funcs(&self) -> usize;
    /// Raw slot of function `j` on point `x`.
    fn hash_one(&self, j: usize, x: &[f32]) -> i64;
    /// Raw slots of functions [j0, j0+out.len()) on `x`.
    fn hash_range(&self, j0: usize, x: &[f32], out: &mut [i64]) {
        for (i, o) in out.iter_mut().enumerate() {
            *o = self.hash_one(j0 + i, x);
        }
    }
    /// Single-function collision probability at distance/similarity `d`
    /// (metric interpretation is family-specific: L2 distance for p-stable,
    /// cosine similarity for SRP).
    fn collision_prob(&self, d: f64) -> f64;
    /// The projection matrix as a flat [dim, n_funcs] column-major-by-slot
    /// buffer for the PJRT artifacts (row i = input dim, col j = function).
    fn projection(&self) -> &[f32];
    /// Downcast hook: Some(self) when this is a p-stable family (callers
    /// need its bias/width to drive the `pstable_hash` artifact).
    fn as_any_pstable(&self) -> Option<&pstable::PStableLsh> {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::pstable::PStableLsh;
    use super::srp::SrpLsh;
    use super::LshFamily;
    use crate::util::rng::Rng;

    /// Empirical single-function collision rate matches the analytic model —
    /// the property every theorem in §3/§4 leans on.
    #[test]
    fn empirical_collision_matches_model_pstable() {
        let dim = 16;
        let fam = PStableLsh::new(dim, 256, 4.0, &mut Rng::new(9));
        let mut rng = Rng::new(10);
        for &dist in &[0.5f32, 2.0, 6.0] {
            let x: Vec<f32> = (0..dim).map(|_| rng.gaussian_f32()).collect();
            // y at exactly `dist` from x along a random direction
            let mut dir: Vec<f32> = (0..dim).map(|_| rng.gaussian_f32()).collect();
            let n = dir.iter().map(|v| v * v).sum::<f32>().sqrt();
            dir.iter_mut().for_each(|v| *v *= dist / n);
            let y: Vec<f32> = x.iter().zip(&dir).map(|(a, b)| a + b).collect();
            let hits = (0..fam.n_funcs())
                .filter(|&j| fam.hash_one(j, &x) == fam.hash_one(j, &y))
                .count();
            let emp = hits as f64 / fam.n_funcs() as f64;
            let model = fam.collision_prob(dist as f64);
            assert!(
                (emp - model).abs() < 0.12,
                "dist={dist} emp={emp} model={model}"
            );
        }
    }

    #[test]
    fn empirical_collision_matches_model_srp() {
        let dim = 24;
        let fam = SrpLsh::new(dim, 512, &mut Rng::new(21));
        let mut rng = Rng::new(22);
        let x: Vec<f32> = (0..dim).map(|_| rng.gaussian_f32()).collect();
        for &angle_frac in &[0.1f64, 0.3, 0.6] {
            // construct y at angle theta = angle_frac * pi from x
            let theta = angle_frac * std::f64::consts::PI;
            let mut perp: Vec<f32> = (0..dim).map(|_| rng.gaussian_f32()).collect();
            let xx = x.iter().map(|v| v * v).sum::<f32>();
            let px = x.iter().zip(&perp).map(|(a, b)| a * b).sum::<f32>();
            for i in 0..dim {
                perp[i] -= px / xx * x[i];
            }
            let pn = perp.iter().map(|v| v * v).sum::<f32>().sqrt();
            let xn = xx.sqrt();
            let y: Vec<f32> = (0..dim)
                .map(|i| {
                    (theta.cos() as f32) * x[i] / xn + (theta.sin() as f32) * perp[i] / pn
                })
                .collect();
            let hits = (0..fam.n_funcs())
                .filter(|&j| fam.hash_one(j, &x) == fam.hash_one(j, &y))
                .count();
            let emp = hits as f64 / fam.n_funcs() as f64;
            let cos = theta.cos();
            let model = fam.collision_prob(cos);
            assert!(
                (emp - model).abs() < 0.08,
                "angle={angle_frac}pi emp={emp} model={model}"
            );
        }
    }
}
