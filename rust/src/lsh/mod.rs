//! Locality-sensitive hashing substrate (paper §2.1).
//!
//! Two families, exactly the ones the paper evaluates: SRP/angular
//! (\[Cha02\], `srp`) and p-stable Euclidean (\[DIIM04\], `pstable`).
//! `concat` builds the amplified functions g = (h₁..h_k) used by S-ANN
//! tables and the bounded-range concatenations used by RACE/SW-AKDE cells.
//! `params` holds the ρ/k/L arithmetic from Lemmas 3.2/3.3.
//!
//! The raw projection matrices live here (generated from the experiment
//! seed) and are the *same* buffers handed to the PJRT artifacts, so the
//! native path and the AOT batch path hash identically.

pub mod cauchy;
pub mod concat;
pub mod params;
pub mod pstable;
pub mod srp;

/// A family of raw LSH functions h_j over f32 vectors.
///
/// Implementations expose `n_funcs` independent functions; callers group
/// them into k-wise concatenations (see [`concat`]).
pub trait LshFamily: Send + Sync {
    /// Input dimensionality.
    fn dim(&self) -> usize;
    /// Number of independent raw functions available.
    fn n_funcs(&self) -> usize;
    /// Raw slot of function `j` on point `x`.
    fn hash_one(&self, j: usize, x: &[f32]) -> i64;
    /// Raw slots of functions [j0, j0+out.len()) on `x`.
    fn hash_range(&self, j0: usize, x: &[f32], out: &mut [i64]) {
        for (i, o) in out.iter_mut().enumerate() {
            *o = self.hash_one(j0 + i, x);
        }
    }
    /// Batched hashing kernel: raw slots of functions [j0, j0+m) for each
    /// of the n points in `xs` (row-major [n, dim]), written to `out`
    /// (row-major [n, m], so m = out.len() / n). This is the GEMM shape of
    /// the sketch update (RACE's "one matrix–vector product" view): every
    /// implementor overrides it with a single blocked pass over the
    /// projection matrix instead of n·m strided dots, and the output must
    /// be bit-for-bit identical to the `hash_one` double loop.
    fn hash_batch(&self, j0: usize, xs: &[f32], out: &mut [i64]) {
        let d = self.dim();
        debug_assert!(d > 0 && xs.len() % d == 0);
        let n = xs.len() / d;
        if n == 0 {
            return;
        }
        debug_assert_eq!(out.len() % n, 0);
        let m = out.len() / n;
        if m == 0 {
            return;
        }
        for (x, o) in xs.chunks_exact(d).zip(out.chunks_exact_mut(m)) {
            self.hash_range(j0, x, o);
        }
    }
    /// Single-function collision probability at distance/similarity `d`
    /// (metric interpretation is family-specific: L2 distance for p-stable,
    /// cosine similarity for SRP).
    fn collision_prob(&self, d: f64) -> f64;
    /// The projection matrix as a flat [dim, n_funcs] column-major-by-slot
    /// buffer for the PJRT artifacts (row i = input dim, col j = function).
    fn projection(&self) -> &[f32];
    /// Downcast hook: Some(self) when this is a p-stable family (callers
    /// need its bias/width to drive the `pstable_hash` artifact).
    fn as_any_pstable(&self) -> Option<&pstable::PStableLsh> {
        None
    }
}

/// Shared blocked GEMV/GEMM core behind every family's `hash_batch`
/// override: one pass over the row-major projection block
/// `proj_rows[j0*d .. (j0+m)*d]`, row-blocked so a block of projection
/// rows stays cache-hot across all n points, with the 8-wide unrolled
/// [`crate::util::dot`] as the inner loop. `map(j, y)` converts function
/// j's raw projection y into its integer slot (sign for SRP, floored
/// bucket for the p-stable families) — monomorphized and inlined, so the
/// whole kernel autovectorizes.
#[inline]
pub(crate) fn hash_batch_rows<M: Fn(usize, f32) -> i64>(
    proj_rows: &[f32],
    d: usize,
    j0: usize,
    xs: &[f32],
    out: &mut [i64],
    map: M,
) {
    debug_assert!(d > 0 && xs.len() % d == 0);
    let n = xs.len() / d;
    if n == 0 || out.is_empty() {
        return;
    }
    debug_assert_eq!(out.len() % n, 0);
    let m = out.len() / n;
    debug_assert!((j0 + m) * d <= proj_rows.len());
    let rows = &proj_rows[j0 * d..(j0 + m) * d];
    // 16 rows of f32 at typical dims fit comfortably in L1 alongside x.
    const ROW_BLOCK: usize = 16;
    let mut j = 0;
    while j < m {
        let jb = ROW_BLOCK.min(m - j);
        let blk = &rows[j * d..(j + jb) * d];
        for (pi, x) in xs.chunks_exact(d).enumerate() {
            let orow = &mut out[pi * m + j..pi * m + j + jb];
            for (jj, row) in blk.chunks_exact(d).enumerate() {
                orow[jj] = map(j0 + j + jj, crate::util::dot(row, x));
            }
        }
        j += jb;
    }
}

#[cfg(test)]
mod tests {
    use super::pstable::PStableLsh;
    use super::srp::SrpLsh;
    use super::LshFamily;
    use crate::util::rng::Rng;

    /// The batched kernel must agree bit-for-bit with the scalar loop,
    /// including at sub-ranges (j0 > 0) and across the row-block boundary.
    #[test]
    fn hash_batch_matches_hash_one_grid() {
        let dim = 19; // off the 8-lane grid on purpose
        let n_funcs = 40; // crosses the 16-row block boundary
        let fam = PStableLsh::new(dim, n_funcs, 3.0, &mut Rng::new(31));
        let mut rng = Rng::new(32);
        for &(n, j0, m) in &[(1usize, 0usize, 40usize), (5, 0, 40), (7, 8, 17), (3, 39, 1)] {
            let mut xs = vec![0.0f32; n * dim];
            rng.fill_gaussian_f32(&mut xs);
            let mut got = vec![0i64; n * m];
            fam.hash_batch(j0, &xs, &mut got);
            for pi in 0..n {
                for jj in 0..m {
                    let want = fam.hash_one(j0 + jj, &xs[pi * dim..(pi + 1) * dim]);
                    assert_eq!(got[pi * m + jj], want, "n={n} j0={j0} pi={pi} jj={jj}");
                }
            }
        }
    }

    /// Empirical single-function collision rate matches the analytic model —
    /// the property every theorem in §3/§4 leans on.
    #[test]
    fn empirical_collision_matches_model_pstable() {
        let dim = 16;
        let fam = PStableLsh::new(dim, 256, 4.0, &mut Rng::new(9));
        let mut rng = Rng::new(10);
        for &dist in &[0.5f32, 2.0, 6.0] {
            let x: Vec<f32> = (0..dim).map(|_| rng.gaussian_f32()).collect();
            // y at exactly `dist` from x along a random direction
            let mut dir: Vec<f32> = (0..dim).map(|_| rng.gaussian_f32()).collect();
            let n = dir.iter().map(|v| v * v).sum::<f32>().sqrt();
            dir.iter_mut().for_each(|v| *v *= dist / n);
            let y: Vec<f32> = x.iter().zip(&dir).map(|(a, b)| a + b).collect();
            let hits = (0..fam.n_funcs())
                .filter(|&j| fam.hash_one(j, &x) == fam.hash_one(j, &y))
                .count();
            let emp = hits as f64 / fam.n_funcs() as f64;
            let model = fam.collision_prob(dist as f64);
            assert!(
                (emp - model).abs() < 0.12,
                "dist={dist} emp={emp} model={model}"
            );
        }
    }

    #[test]
    fn empirical_collision_matches_model_srp() {
        let dim = 24;
        let fam = SrpLsh::new(dim, 512, &mut Rng::new(21));
        let mut rng = Rng::new(22);
        let x: Vec<f32> = (0..dim).map(|_| rng.gaussian_f32()).collect();
        for &angle_frac in &[0.1f64, 0.3, 0.6] {
            // construct y at angle theta = angle_frac * pi from x
            let theta = angle_frac * std::f64::consts::PI;
            let mut perp: Vec<f32> = (0..dim).map(|_| rng.gaussian_f32()).collect();
            let xx = x.iter().map(|v| v * v).sum::<f32>();
            let px = x.iter().zip(&perp).map(|(a, b)| a * b).sum::<f32>();
            for i in 0..dim {
                perp[i] -= px / xx * x[i];
            }
            let pn = perp.iter().map(|v| v * v).sum::<f32>().sqrt();
            let xn = xx.sqrt();
            let y: Vec<f32> = (0..dim)
                .map(|i| {
                    (theta.cos() as f32) * x[i] / xn + (theta.sin() as f32) * perp[i] / pn
                })
                .collect();
            let hits = (0..fam.n_funcs())
                .filter(|&j| fam.hash_one(j, &x) == fam.hash_one(j, &y))
                .count();
            let emp = hits as f64 / fam.n_funcs() as f64;
            let cos = theta.cos();
            let model = fam.collision_prob(cos);
            assert!(
                (emp - model).abs() < 0.08,
                "angle={angle_frac}pi emp={emp} model={model}"
            );
        }
    }
}
