//! Sign-random-projection (angular) LSH — Charikar \[Cha02\], paper §2.1.
//!
//! h_j(x) = [a_j · x >= 0] with a_j ~ N(0, I). Collision probability for
//! points at angle θ is 1 − θ/π, so the collision *kernel* in terms of
//! cosine similarity s is 1 − arccos(s)/π — the angular kernel the SW-AKDE
//! experiments estimate (Figs 9b/9d/11).

use super::LshFamily;
use crate::util::{dot, rng::Rng};

/// A bank of `n_funcs` independent SRP functions over `dim`-d vectors.
pub struct SrpLsh {
    dim: usize,
    n_funcs: usize,
    /// Flat [dim, n_funcs]: column j is direction a_j (artifact layout).
    proj: Vec<f32>,
    /// Row-major copy [n_funcs, dim] for fast native hashing.
    proj_rows: Vec<f32>,
}

impl SrpLsh {
    pub fn new(dim: usize, n_funcs: usize, rng: &mut Rng) -> Self {
        let mut proj_rows = vec![0.0f32; dim * n_funcs];
        rng.fill_gaussian_f32(&mut proj_rows);
        let mut proj = vec![0.0f32; dim * n_funcs];
        for j in 0..n_funcs {
            for i in 0..dim {
                proj[i * n_funcs + j] = proj_rows[j * dim + i];
            }
        }
        SrpLsh { dim, n_funcs, proj, proj_rows }
    }

    #[inline]
    fn row(&self, j: usize) -> &[f32] {
        &self.proj_rows[j * self.dim..(j + 1) * self.dim]
    }
}

impl LshFamily for SrpLsh {
    fn dim(&self) -> usize {
        self.dim
    }

    fn n_funcs(&self) -> usize {
        self.n_funcs
    }

    #[inline]
    fn hash_one(&self, j: usize, x: &[f32]) -> i64 {
        // >= 0 convention matches the Pallas kernel (srp_hash) exactly.
        (dot(self.row(j), x) >= 0.0) as i64
    }

    fn hash_range(&self, j0: usize, x: &[f32], out: &mut [i64]) {
        self.hash_batch(j0, x, out);
    }

    fn hash_batch(&self, j0: usize, xs: &[f32], out: &mut [i64]) {
        super::hash_batch_rows(&self.proj_rows, self.dim, j0, xs, out, |_, y| (y >= 0.0) as i64);
    }

    /// `d` is cosine similarity in [-1, 1].
    fn collision_prob(&self, d: f64) -> f64 {
        1.0 - d.clamp(-1.0, 1.0).acos() / std::f64::consts::PI
    }

    fn projection(&self) -> &[f32] {
        &self.proj
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hash_is_binary_and_deterministic() {
        let fam = SrpLsh::new(8, 16, &mut Rng::new(1));
        let x: Vec<f32> = (0..8).map(|i| i as f32 - 3.5).collect();
        for j in 0..16 {
            let h = fam.hash_one(j, &x);
            assert!(h == 0 || h == 1);
            assert_eq!(h, fam.hash_one(j, &x));
        }
    }

    #[test]
    fn identical_points_always_collide() {
        let fam = SrpLsh::new(12, 64, &mut Rng::new(2));
        let x: Vec<f32> = (0..12).map(|i| (i as f32).sin()).collect();
        for j in 0..64 {
            assert_eq!(fam.hash_one(j, &x), fam.hash_one(j, &x.clone()));
        }
    }

    #[test]
    fn antipodal_points_never_collide() {
        let fam = SrpLsh::new(12, 64, &mut Rng::new(3));
        let x: Vec<f32> = (0..12).map(|i| (i as f32).cos() + 0.1).collect();
        let neg: Vec<f32> = x.iter().map(|v| -v).collect();
        let collisions = (0..64)
            .filter(|&j| fam.hash_one(j, &x) == fam.hash_one(j, &neg))
            .count();
        // sign(a.x) != sign(-a.x) unless the dot is exactly 0 (prob ~0)
        assert_eq!(collisions, 0);
    }

    #[test]
    fn collision_prob_endpoints() {
        let fam = SrpLsh::new(4, 4, &mut Rng::new(4));
        assert!((fam.collision_prob(1.0) - 1.0).abs() < 1e-12);
        assert!(fam.collision_prob(-1.0).abs() < 1e-12);
        assert!((fam.collision_prob(0.0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn projection_layout_is_column_per_function() {
        let fam = SrpLsh::new(3, 2, &mut Rng::new(5));
        let p = fam.projection();
        // column j, entry i lives at p[i * n_funcs + j]
        for j in 0..2 {
            for i in 0..3 {
                assert_eq!(p[i * 2 + j], fam.row(j)[i]);
            }
        }
    }

    #[test]
    fn scale_invariance() {
        let fam = SrpLsh::new(6, 32, &mut Rng::new(6));
        let x: Vec<f32> = (0..6).map(|i| i as f32 - 2.0).collect();
        let x2: Vec<f32> = x.iter().map(|v| v * 7.5).collect();
        for j in 0..32 {
            assert_eq!(fam.hash_one(j, &x), fam.hash_one(j, &x2));
        }
    }
}
