//! Structured leveled logging: one JSON object per line, to stderr or a
//! `--log-file`.
//!
//! The serving and durability paths call [`error`]/[`warn`]/[`info`]/
//! [`debug`] instead of `eprintln!` (enforced by the xtask
//! `no-raw-print` lint), so operational output is machine-parseable and
//! level-filterable. The sink is a process-wide write-once
//! [`OnceLock`]: `sketchd serve` calls [`init`] during boot; library
//! users and tests that never call it get a lazy default (stderr,
//! level from `SKETCHD_LOG`, `info` if unset).
//!
//! A line looks like:
//!
//! ```json
//! {"ts_ms":1754556000123,"level":"warn","target":"durability","msg":"torn WAL tail","shard":"3","dropped":"17"}
//! ```
//!
//! Keys `ts_ms`/`level`/`target`/`msg` are always present and first;
//! caller-supplied key/value pairs follow in argument order. Values are
//! JSON strings (callers format numbers themselves) so the writer never
//! needs to guess types.

use std::fs::{File, OpenOptions};
use std::io::Write as _;
use std::path::Path;
use std::time::{SystemTime, UNIX_EPOCH};

use crate::util::sync::{lock_unpoisoned, Mutex, OnceLock};

/// Severity, ordered so `level <= sink.level` means "emit".
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error,
    Warn,
    Info,
    Debug,
}

impl Level {
    pub fn as_str(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }

    /// Parse a `SKETCHD_LOG` value; unknown strings land on `Info` so a
    /// typo loosens nothing and silences nothing important.
    pub fn parse(s: &str) -> Level {
        match s.trim().to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "warn" | "warning" => Level::Warn,
            "debug" | "trace" => Level::Debug,
            _ => Level::Info,
        }
    }
}

struct Sink {
    level: Level,
    /// `None` = stderr. The file is behind a mutex so concurrent
    /// connection threads emit whole lines, never interleaved bytes.
    file: Option<Mutex<File>>,
}

static SINK: OnceLock<Sink> = OnceLock::new();

fn env_level() -> Level {
    match std::env::var("SKETCHD_LOG") {
        Ok(v) => Level::parse(&v),
        Err(_) => Level::Info,
    }
}

fn sink() -> &'static Sink {
    SINK.get_or_init(|| Sink {
        level: env_level(),
        file: None,
    })
}

/// Configure the process sink. Call once, before serving traffic; a
/// second call (or a call after the lazy default was taken) is a no-op
/// returning `false` — the first configuration wins, matching
/// `OnceLock` semantics. `level: None` defers to `SKETCHD_LOG`.
pub fn init(level: Option<Level>, file: Option<&Path>) -> std::io::Result<bool> {
    let file = match file {
        Some(path) => Some(Mutex::new(
            OpenOptions::new().create(true).append(true).open(path)?,
        )),
        None => None,
    };
    Ok(SINK
        .set(Sink {
            level: level.unwrap_or_else(env_level),
            file,
        })
        .is_ok())
}

/// Would a record at `level` be emitted? Lets callers skip formatting
/// work (e.g. per-query debug lines) when the sink is quieter.
pub fn enabled(level: Level) -> bool {
    level <= sink().level
}

pub fn error(target: &str, msg: &str, kv: &[(&str, String)]) {
    emit(Level::Error, target, msg, kv);
}

pub fn warn(target: &str, msg: &str, kv: &[(&str, String)]) {
    emit(Level::Warn, target, msg, kv);
}

pub fn info(target: &str, msg: &str, kv: &[(&str, String)]) {
    emit(Level::Info, target, msg, kv);
}

pub fn debug(target: &str, msg: &str, kv: &[(&str, String)]) {
    emit(Level::Debug, target, msg, kv);
}

fn emit(level: Level, target: &str, msg: &str, kv: &[(&str, String)]) {
    let s = sink();
    if level > s.level {
        return;
    }
    let line = render(level, target, msg, kv);
    match &s.file {
        Some(file) => {
            let mut f = lock_unpoisoned(file);
            // A full disk must not take the serving path down with it.
            let _ = f.write_all(line.as_bytes());
        }
        None => {
            let mut err = std::io::stderr().lock();
            let _ = err.write_all(line.as_bytes());
        }
    }
}

fn render(level: Level, target: &str, msg: &str, kv: &[(&str, String)]) -> String {
    use std::fmt::Write as _;
    let ts_ms = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_millis())
        .unwrap_or(0);
    let mut line = String::with_capacity(96 + 24 * kv.len());
    let _ = write!(
        line,
        "{{\"ts_ms\":{ts_ms},\"level\":\"{}\",\"target\":\"{}\",\"msg\":\"{}\"",
        level.as_str(),
        escape(target),
        escape(msg)
    );
    for (k, v) in kv {
        let _ = write!(line, ",\"{}\":\"{}\"", escape(k), escape(v));
    }
    line.push_str("}\n");
    line
}

/// JSON string escaping for the keys/values we emit (quotes, backslash,
/// and control characters; everything else passes through as UTF-8).
fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                use std::fmt::Write as _;
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

/// Format a `key=value` pair for the `kv` slice tersely at call sites.
#[macro_export]
macro_rules! kv {
    ($($k:ident = $v:expr),* $(,)?) => {
        &[$((stringify!($k), format!("{}", $v))),*]
    };
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;

    #[test]
    fn level_parse_and_order() {
        assert_eq!(Level::parse("DEBUG"), Level::Debug);
        assert_eq!(Level::parse("warn"), Level::Warn);
        assert_eq!(Level::parse("warning"), Level::Warn);
        assert_eq!(Level::parse("error"), Level::Error);
        assert_eq!(Level::parse("nonsense"), Level::Info);
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Info);
        assert!(Level::Info < Level::Debug);
    }

    #[test]
    fn render_produces_parseable_json_shape() {
        let line = render(
            Level::Warn,
            "durability",
            "torn \"tail\"",
            kv![shard = 3, dropped = 17],
        );
        assert!(line.starts_with("{\"ts_ms\":"));
        assert!(line.ends_with("}\n"));
        assert!(line.contains("\"level\":\"warn\""));
        assert!(line.contains("\"target\":\"durability\""));
        assert!(line.contains("\"msg\":\"torn \\\"tail\\\"\""));
        assert!(line.contains("\"shard\":\"3\""));
        assert!(line.contains("\"dropped\":\"17\""));
    }

    #[test]
    fn escape_handles_controls_and_backslashes() {
        assert_eq!(escape("a\\b"), "a\\\\b");
        assert_eq!(escape("a\nb"), "a\\nb");
        assert_eq!(escape("a\u{1}b"), "a\\u0001b");
        assert_eq!(escape("plain"), "plain");
    }

    #[test]
    fn init_to_file_writes_json_lines() {
        // The global sink is process-wide; this test may lose the
        // OnceLock race to another test's lazy default, so assert on
        // the return contract rather than global state, and exercise
        // the file writer through a private Sink directly.
        let dir = std::env::temp_dir().join(format!("sketchd_log_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).expect("mkdir");
        let path = dir.join("obs.log");
        let sink = Sink {
            level: Level::Info,
            file: Some(Mutex::new(
                OpenOptions::new()
                    .create(true)
                    .append(true)
                    .open(&path)
                    .expect("open log file"),
            )),
        };
        let line = render(Level::Info, "serve", "listening", kv![addr = "127.0.0.1:0"]);
        if let Some(file) = &sink.file {
            lock_unpoisoned(file)
                .write_all(line.as_bytes())
                .expect("write");
        }
        let got = std::fs::read_to_string(&path).expect("read back");
        assert!(got.contains("\"msg\":\"listening\""));
        assert!(got.contains("\"addr\":\"127.0.0.1:0\""));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
