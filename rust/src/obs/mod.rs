//! Observability: the structured logger ([`log`]) that replaces the
//! serving stack's raw `eprintln!` sites (the `metrics::registry`
//! series catalog is the numeric half of the same plane).
//!
//! This module is deliberately *outside* the `no-raw-print` lint scope
//! (`net/`, `coordinator/`, `durability/`): it is the one place allowed
//! to write the process's stderr directly.

pub mod log;
