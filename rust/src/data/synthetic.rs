//! The paper's own synthetic workloads.
//!
//! * [`poisson_process`] — syn-32 (§5.1): points drawn from a homogeneous
//!   Poisson point process, the distributional assumption of Theorem 3.1
//!   (ball occupancy ~ Poisson(m)).
//! * [`gaussian_blocks`] — the A-KDE Monte-Carlo stream (§5.2): 10k points
//!   of dimension 200, one multivariate gaussian per 1k-block, so the
//!   density drifts exactly when a block boundary crosses the window.

use crate::util::rng::Rng;

/// Homogeneous Poisson point process on the cube \[0, side\]^dim.
///
/// The number of points is Poisson(intensity · side^dim) and positions are
/// i.i.d. uniform — the standard construction. For the experiments we fix
/// the expected count `n_expected` and solve for the intensity, so ball
/// occupancy has Poisson mean m = n_expected · vol(B_r)/side^dim.
pub fn poisson_process(n_expected: usize, dim: usize, side: f64, rng: &mut Rng) -> Vec<Vec<f32>> {
    let n = rng.poisson(n_expected as f64) as usize;
    (0..n)
        .map(|_| (0..dim).map(|_| (rng.uniform() * side) as f32).collect())
        .collect()
}

/// Exactly-n uniform points on \[0, side\]^dim (conditioned PPP — given the
/// count, PPP positions are i.i.d. uniform; benches use this for fixed N).
pub fn uniform_cube(n: usize, dim: usize, side: f64, rng: &mut Rng) -> Vec<Vec<f32>> {
    (0..n)
        .map(|_| (0..dim).map(|_| (rng.uniform() * side) as f32).collect())
        .collect()
}

/// The A-KDE Monte-Carlo stream: `blocks` gaussians, `per_block` points
/// each, means resampled per block (paper: 10 gaussians × 1000 points,
/// dim 200). Returns points in stream order.
pub fn gaussian_blocks(
    blocks: usize,
    per_block: usize,
    dim: usize,
    mean_scale: f64,
    sigma: f64,
    rng: &mut Rng,
) -> Vec<Vec<f32>> {
    let mut out = Vec::with_capacity(blocks * per_block);
    for _ in 0..blocks {
        let mean: Vec<f64> = (0..dim).map(|_| rng.gaussian() * mean_scale).collect();
        for _ in 0..per_block {
            out.push(
                (0..dim)
                    .map(|i| (mean[i] + rng.gaussian() * sigma) as f32)
                    .collect(),
            );
        }
    }
    out
}

/// Mean r-ball occupancy of a PPP with `n` expected points on \[0,side\]^dim:
/// m = n · vol(B_r) / side^dim (needed to instantiate Theorem 3.1's m).
pub fn ppp_ball_mean(n: usize, dim: usize, side: f64, r: f64) -> f64 {
    // vol(B_r) in d dims = pi^{d/2} r^d / Gamma(d/2 + 1); use ln-gamma via
    // Stirling for stability at high d.
    let d = dim as f64;
    let ln_vol = (d / 2.0) * std::f64::consts::PI.ln() + d * r.ln() - ln_gamma(d / 2.0 + 1.0);
    n as f64 * (ln_vol - d * side.ln()).exp()
}

/// Lanczos ln-gamma (g=7, n=9), |err| < 1e-10 for x > 0.
pub fn ln_gamma(x: f64) -> f64 {
    const COEF: [f64; 9] = [
        0.999_999_999_999_809_93,
        676.520_368_121_885_1,
        -1_259.139_216_722_402_8,
        771.323_428_777_653_13,
        -176.615_029_162_140_6,
        12.507_343_278_686_905,
        -0.138_571_095_265_720_12,
        9.984_369_578_019_572e-6,
        1.505_632_735_149_311_6e-7,
    ];
    if x < 0.5 {
        // reflection
        return std::f64::consts::PI.ln()
            - (std::f64::consts::PI * x).sin().ln()
            - ln_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = COEF[0];
    let t = x + 7.5;
    for (i, &c) in COEF.iter().enumerate().skip(1) {
        a += c / (x + i as f64);
    }
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ln_gamma_reference_values() {
        // Gamma(1)=1, Gamma(2)=1, Gamma(3)=2, Gamma(0.5)=sqrt(pi)
        assert!(ln_gamma(1.0).abs() < 1e-9);
        assert!(ln_gamma(2.0).abs() < 1e-9);
        assert!((ln_gamma(3.0) - 2f64.ln()).abs() < 1e-9);
        assert!((ln_gamma(0.5) - std::f64::consts::PI.sqrt().ln()).abs() < 1e-9);
        assert!((ln_gamma(6.0) - 120f64.ln()).abs() < 1e-8);
    }

    #[test]
    fn ppp_count_is_poisson_like() {
        let mut rng = Rng::new(1);
        let counts: Vec<f64> = (0..200)
            .map(|_| poisson_process(1000, 4, 1.0, &mut rng).len() as f64)
            .collect();
        let mean = crate::util::stats::mean(&counts);
        let var = crate::util::stats::variance(&counts);
        assert!((mean - 1000.0).abs() < 15.0, "mean={mean}");
        // Poisson: var == mean
        assert!((var / mean - 1.0).abs() < 0.35, "var/mean={}", var / mean);
    }

    #[test]
    fn ppp_ball_occupancy_matches_theory() {
        // Empirical occupancy of r-balls around random interior anchors
        // should match m = n vol(B_r)/side^d.
        let (n, dim, side, r) = (20_000, 2, 10.0, 0.5);
        let m_theory = ppp_ball_mean(n, dim, side, r);
        let mut rng = Rng::new(2);
        let pts = uniform_cube(n, dim, side, &mut rng);
        let mut occ = Vec::new();
        for _ in 0..300 {
            let anchor: Vec<f32> = (0..dim)
                .map(|_| (r + rng.uniform() * (side - 2.0 * r)) as f32)
                .collect();
            let c = pts
                .iter()
                .filter(|p| crate::util::l2(p, &anchor) <= r as f32)
                .count();
            occ.push(c as f64);
        }
        let emp = crate::util::stats::mean(&occ);
        assert!(
            (emp - m_theory).abs() < 0.15 * m_theory,
            "emp={emp} theory={m_theory}"
        );
    }

    #[test]
    fn gaussian_blocks_shape_and_drift() {
        let mut rng = Rng::new(3);
        let pts = gaussian_blocks(10, 100, 20, 5.0, 1.0, &mut rng);
        assert_eq!(pts.len(), 1000);
        assert_eq!(pts[0].len(), 20);
        // Within-block spread << between-block mean distance.
        let d_within = crate::util::l2(&pts[0], &pts[50]);
        let d_across = crate::util::l2(&pts[0], &pts[550]);
        assert!(d_across > d_within, "within={d_within} across={d_across}");
    }

    #[test]
    fn deterministic_given_seed() {
        let a = gaussian_blocks(2, 10, 4, 1.0, 0.5, &mut Rng::new(9));
        let b = gaussian_blocks(2, 10, 4, 1.0, 0.5, &mut Rng::new(9));
        assert_eq!(a, b);
    }
}
