//! Workload data: the paper's synthetic processes and offline stand-ins
//! for its real datasets (DESIGN.md §2).

pub mod datasets;
pub mod synthetic;

pub use datasets::Dataset;
