//! Synthetic stand-ins for the paper's real datasets (offline environment;
//! substitution table in DESIGN.md §2). Each generator is deterministic in
//! its seed and calibrated to the geometric property the corresponding
//! experiment actually exercises:
//!
//! * `sift_like`  (128-d, sift1m):     clustered non-negative descriptors —
//!    local density / recall-vs-compression trade-offs.
//! * `fmnist_like` (784-d, fashion-mnist): 10 class prototypes + structured
//!    pixel noise in \[0,1\] — low intrinsic dimension inside high ambient.
//! * `news_like`  (384-d, MiniLM embeddings): unit-norm topic mixtures with
//!    temporal topic drift — cosine geometry + sliding-window dynamics.
//! * `rosis_like` (103-d, ROSIS hyperspectral): smooth per-material spectra
//!    — correlated channels, material clusters.

use crate::util::rng::Rng;

/// A generated dataset with stream order and query split.
pub struct Dataset {
    pub name: &'static str,
    pub dim: usize,
    pub points: Vec<Vec<f32>>,
}

impl Dataset {
    /// Split off the last `n_queries` points as queries (stream/query split
    /// used by the ANN experiments).
    pub fn split_queries(mut self, n_queries: usize) -> (Vec<Vec<f32>>, Vec<Vec<f32>>) {
        assert!(n_queries < self.points.len());
        let queries = self.points.split_off(self.points.len() - n_queries);
        (self.points, queries)
    }
}

/// sift1m-like: `clusters` centers in the positive orthant, heavy-tailed
/// cluster sizes, descriptor-ish coordinates (non-negative, bounded).
pub fn sift_like(n: usize, seed: u64) -> Dataset {
    let dim = 128;
    let clusters = 64;
    let mut rng = Rng::new(seed);
    let centers: Vec<Vec<f32>> = (0..clusters)
        .map(|_| (0..dim).map(|_| (rng.uniform() * 120.0) as f32).collect())
        .collect();
    let points = (0..n)
        .map(|_| {
            let c = &centers[rng.below(clusters as u64) as usize];
            (0..dim)
                .map(|i| (c[i] + rng.gaussian_f32() * 12.0).clamp(0.0, 255.0))
                .collect()
        })
        .collect();
    Dataset { name: "sift-like", dim, points }
}

/// fashion-mnist-like: 10 prototypes in \[0,1\]^784 with smooth "stroke"
/// noise (neighboring pixels correlated), flattened 28×28.
pub fn fmnist_like(n: usize, seed: u64) -> Dataset {
    let dim = 784;
    let classes = 10;
    let mut rng = Rng::new(seed);
    // Prototype = smoothed random mask (simulates garment silhouettes).
    let protos: Vec<Vec<f32>> = (0..classes)
        .map(|_| {
            let mut raw: Vec<f32> = (0..dim).map(|_| rng.uniform_f32()).collect();
            smooth_28x28(&mut raw);
            raw
        })
        .collect();
    let points = (0..n)
        .map(|_| {
            let p = &protos[rng.below(classes as u64) as usize];
            let mut v: Vec<f32> = (0..dim)
                .map(|i| (p[i] + rng.gaussian_f32() * 0.15).clamp(0.0, 1.0))
                .collect();
            smooth_28x28(&mut v);
            v
        })
        .collect();
    Dataset { name: "fmnist-like", dim, points }
}

fn smooth_28x28(img: &mut [f32]) {
    debug_assert_eq!(img.len(), 784);
    let src = img.to_vec();
    for y in 0..28 {
        for x in 0..28 {
            let mut acc = 0.0;
            let mut cnt = 0.0;
            for (dy, dx) in [(0i32, 0i32), (0, 1), (1, 0), (0, -1), (-1, 0)] {
                let (ny, nx) = (y as i32 + dy, x as i32 + dx);
                if (0..28).contains(&ny) && (0..28).contains(&nx) {
                    acc += src[(ny * 28 + nx) as usize];
                    cnt += 1.0;
                }
            }
            img[(y * 28 + x) as usize] = acc / cnt;
        }
    }
}

/// news-like: unit-norm 384-d "embeddings" as mixtures of `topics` topic
/// vectors; the active topic distribution drifts along the stream
/// (position-dependent), giving the sliding window something to track.
pub fn news_like(n: usize, seed: u64) -> Dataset {
    let dim = 384;
    let topics = 24;
    let mut rng = Rng::new(seed);
    let topic_vecs: Vec<Vec<f32>> = (0..topics)
        .map(|_| {
            let mut v: Vec<f32> = (0..dim).map(|_| rng.gaussian_f32()).collect();
            normalize(&mut v);
            v
        })
        .collect();
    let points = (0..n)
        .map(|t| {
            // Drift: the dominant topic rotates slowly with stream position.
            let phase = t as f64 / n.max(1) as f64 * topics as f64;
            let main = (phase as usize) % topics;
            let second = rng.below(topics as u64) as usize;
            let w = 0.6 + 0.3 * rng.uniform_f32();
            let mut v: Vec<f32> = (0..dim)
                .map(|i| {
                    w * topic_vecs[main][i]
                        + (1.0 - w) * topic_vecs[second][i]
                        + 0.25 * rng.gaussian_f32() / (dim as f32).sqrt()
                })
                .collect();
            normalize(&mut v);
            v
        })
        .collect();
    Dataset { name: "news-like", dim, points }
}

/// rosis-like: 103-channel spectra as smooth combinations of `materials`
/// basis curves (gaussian bumps over the band axis) + sensor noise.
pub fn rosis_like(n: usize, seed: u64) -> Dataset {
    let dim = 103;
    let materials = 9;
    let mut rng = Rng::new(seed);
    let bases: Vec<Vec<f32>> = (0..materials)
        .map(|_| {
            // Each material: 2-4 spectral bumps.
            let bumps = 2 + rng.below(3) as usize;
            let mut v = vec![0.0f32; dim];
            for _ in 0..bumps {
                let center = rng.uniform() * dim as f64;
                let width = 4.0 + rng.uniform() * 16.0;
                let amp = (0.3 + rng.uniform() * 0.7) as f32;
                for (i, vi) in v.iter_mut().enumerate() {
                    let z = (i as f64 - center) / width;
                    *vi += amp * (-0.5 * z * z).exp() as f32;
                }
            }
            v
        })
        .collect();
    let points = (0..n)
        .map(|_| {
            let m = &bases[rng.below(materials as u64) as usize];
            let gain = 0.7 + 0.6 * rng.uniform_f32();
            (0..dim)
                .map(|i| (m[i] * gain + rng.gaussian_f32() * 0.02).max(0.0))
                .collect()
        })
        .collect();
    Dataset { name: "rosis-like", dim, points }
}

/// syn-32: the paper's PPP dataset (delegates to `synthetic`).
pub fn syn32(n: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let points = super::synthetic::uniform_cube(n, 32, 10.0, &mut rng);
    Dataset { name: "syn-32", dim: 32, points }
}

/// KDE Monte-Carlo synthetic (10 gaussians × blocks, dim 200).
pub fn kde_synthetic(n: usize, seed: u64) -> Dataset {
    let per_block = n.div_ceil(10);
    let mut rng = Rng::new(seed);
    let mut points =
        super::synthetic::gaussian_blocks(10, per_block, 200, 4.0, 1.0, &mut rng);
    points.truncate(n);
    Dataset { name: "kde-synthetic", dim: 200, points }
}

fn normalize(v: &mut [f32]) {
    let n = v.iter().map(|x| x * x).sum::<f32>().sqrt();
    if n > 0.0 {
        v.iter_mut().for_each(|x| *x /= n);
    }
}

/// All ANN datasets at a given size (Fig 6–8 sweeps).
pub fn ann_suite(n: usize, seed: u64) -> Vec<Dataset> {
    vec![sift_like(n, seed), fmnist_like(n, seed ^ 1), syn32(n, seed ^ 2)]
}

/// All KDE datasets at a given size (Fig 9–11 sweeps).
pub fn kde_suite(n: usize, seed: u64) -> Vec<Dataset> {
    vec![news_like(n, seed), rosis_like(n, seed ^ 1), kde_synthetic(n, seed ^ 2)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dims_match_the_paper() {
        assert_eq!(sift_like(10, 1).dim, 128);
        assert_eq!(fmnist_like(10, 1).dim, 784);
        assert_eq!(news_like(10, 1).dim, 384);
        assert_eq!(rosis_like(10, 1).dim, 103);
        assert_eq!(syn32(10, 1).dim, 32);
        assert_eq!(kde_synthetic(10, 1).dim, 200);
    }

    #[test]
    fn deterministic_in_seed() {
        let a = sift_like(50, 7).points;
        let b = sift_like(50, 7).points;
        let c = sift_like(50, 8).points;
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn news_vectors_are_unit_norm() {
        for p in &news_like(100, 3).points {
            let n: f32 = p.iter().map(|x| x * x).sum::<f32>().sqrt();
            assert!((n - 1.0).abs() < 1e-4, "norm={n}");
        }
    }

    #[test]
    fn sift_values_in_descriptor_range() {
        for p in &sift_like(100, 4).points {
            assert!(p.iter().all(|&v| (0.0..=255.0).contains(&v)));
        }
    }

    #[test]
    fn fmnist_values_in_unit_range() {
        for p in &fmnist_like(20, 5).points {
            assert!(p.iter().all(|&v| (0.0..=1.0).contains(&v)));
        }
    }

    #[test]
    fn rosis_spectra_nonnegative_and_smooth() {
        for p in &rosis_like(50, 6).points {
            assert!(p.iter().all(|&v| v >= 0.0));
            // Smoothness: mean |channel diff| well below dynamic range.
            let diffs: f32 =
                p.windows(2).map(|w| (w[1] - w[0]).abs()).sum::<f32>() / (p.len() - 1) as f32;
            let range = p.iter().cloned().fold(0.0f32, f32::max);
            assert!(diffs < 0.3 * range.max(0.05), "diffs={diffs} range={range}");
        }
    }

    #[test]
    fn clustered_sets_have_structure() {
        // Nearest-neighbor distance should be much smaller than the mean
        // pairwise distance for clustered data.
        let pts = sift_like(300, 9).points;
        let nn = crate::baselines::ExactNn::from_points(128, &pts[1..].to_vec());
        let d_nn = nn.nn_dist(&pts[0]);
        let d_far = crate::util::l2(&pts[0], &pts[150]);
        assert!(d_nn < d_far, "nn={d_nn} random-pair={d_far}");
    }

    #[test]
    fn news_drift_separates_stream_ends() {
        let pts = news_like(2000, 10).points;
        // Average cosine between early-early pairs > early-late pairs.
        let mut early = 0.0;
        let mut cross = 0.0;
        for i in 0..50 {
            early += crate::util::cosine(&pts[i], &pts[i + 50]) as f64;
            cross += crate::util::cosine(&pts[i], &pts[1900 + i]) as f64;
        }
        assert!(early > cross, "early={early} cross={cross}");
    }

    #[test]
    fn split_queries_partitions() {
        let ds = syn32(100, 11);
        let (stream, queries) = ds.split_queries(20);
        assert_eq!(stream.len(), 80);
        assert_eq!(queries.len(), 20);
    }
}
