//! TOML-subset parser: `[section]` headers and `key = value` scalar lines,
//! `#` comments, quoted or bare values. Exactly what experiment configs
//! need; arrays/tables are out of scope by design.

use std::collections::BTreeMap;

/// Parsed sections → key → raw value string.
#[derive(Debug, Default)]
pub struct ConfigFile {
    sections: BTreeMap<String, BTreeMap<String, String>>,
}

impl ConfigFile {
    pub fn parse(src: &str) -> anyhow::Result<Self> {
        let mut out = ConfigFile::default();
        let mut current = String::new();
        for (lineno, raw) in src.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| anyhow::anyhow!("line {}: unclosed section", lineno + 1))?
                    .trim();
                if name.is_empty() {
                    anyhow::bail!("line {}: empty section name", lineno + 1);
                }
                current = name.to_string();
                out.sections.entry(current.clone()).or_default();
            } else if let Some((k, v)) = line.split_once('=') {
                let key = k.trim();
                if key.is_empty() {
                    anyhow::bail!("line {}: empty key", lineno + 1);
                }
                let val = unquote(v.trim());
                out.sections
                    .entry(current.clone())
                    .or_default()
                    .insert(key.to_string(), val);
            } else {
                anyhow::bail!("line {}: expected `key = value` or `[section]`", lineno + 1);
            }
        }
        Ok(out)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&str> {
        self.sections.get(section)?.get(key).map(String::as_str)
    }

    pub fn sections(&self) -> impl Iterator<Item = &str> {
        self.sections.keys().map(String::as_str)
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside quotes.
    let mut in_quote = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_quote = !in_quote,
            '#' if !in_quote => return &line[..i],
            _ => {}
        }
    }
    line
}

fn unquote(v: &str) -> String {
    if v.len() >= 2 && v.starts_with('"') && v.ends_with('"') {
        v[1..v.len() - 1].to_string()
    } else {
        v.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_keys_comments() {
        let f = ConfigFile::parse(
            "# top comment\n[a]\nx = 1 # trailing\ny = \"hash # inside\"\n\n[b]\nz = true\n",
        )
        .unwrap();
        assert_eq!(f.get("a", "x"), Some("1"));
        assert_eq!(f.get("a", "y"), Some("hash # inside"));
        assert_eq!(f.get("b", "z"), Some("true"));
        assert_eq!(f.get("a", "missing"), None);
        assert_eq!(f.get("missing", "x"), None);
        assert_eq!(f.sections().count(), 2);
    }

    #[test]
    fn top_level_keys_live_in_empty_section() {
        let f = ConfigFile::parse("k = v\n[s]\nk = w\n").unwrap();
        assert_eq!(f.get("", "k"), Some("v"));
        assert_eq!(f.get("s", "k"), Some("w"));
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(ConfigFile::parse("[unclosed\n").is_err());
        assert!(ConfigFile::parse("justaword\n").is_err());
        assert!(ConfigFile::parse("= novalue\n").is_err());
        assert!(ConfigFile::parse("[]\n").is_err());
    }

    #[test]
    fn last_duplicate_wins() {
        let f = ConfigFile::parse("[s]\nk = 1\nk = 2\n").unwrap();
        assert_eq!(f.get("s", "k"), Some("2"));
    }
}
