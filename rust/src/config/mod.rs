//! Configuration system: typed experiment/service configs loadable from a
//! TOML-subset file (sections, scalar keys; no serde offline — parser in
//! `file.rs`). Every knob the paper's experiments sweep is expressible
//! here, and the CLI maps flags onto the same structs.

pub mod file;

use crate::coordinator::{KdeKernel, KdeShardConfig, Overload, RoutePolicy, ServiceConfig};
use crate::sketch::ann::SAnnConfig;

use file::ConfigFile;

/// Typed view over a parsed config file with defaulting.
pub struct Config {
    file: ConfigFile,
}

impl Config {
    pub fn parse(src: &str) -> anyhow::Result<Self> {
        Ok(Config { file: ConfigFile::parse(src)? })
    }

    pub fn load(path: &std::path::Path) -> anyhow::Result<Self> {
        let src = std::fs::read_to_string(path)?;
        Self::parse(&src)
    }

    pub fn empty() -> Self {
        Config { file: ConfigFile::default() }
    }

    fn f64(&self, section: &str, key: &str, default: f64) -> f64 {
        self.file.get(section, key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    fn usize(&self, section: &str, key: &str, default: usize) -> usize {
        self.file.get(section, key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    fn u64(&self, section: &str, key: &str, default: u64) -> u64 {
        self.file.get(section, key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    fn str(&self, section: &str, key: &str, default: &str) -> String {
        self.file.get(section, key).map(str::to_string).unwrap_or_else(|| default.into())
    }

    fn bool(&self, section: &str, key: &str, default: bool) -> bool {
        self.file
            .get(section, key)
            .map(|v| v == "true" || v == "1")
            .unwrap_or(default)
    }

    /// `[ann]` section → S-ANN sketch parameters.
    pub fn ann(&self, dim: usize, n_max: usize) -> anyhow::Result<SAnnConfig> {
        let cfg = SAnnConfig {
            dim,
            n_max: self.usize("ann", "n_max", n_max),
            eta: self.f64("ann", "eta", 0.5),
            r: self.f64("ann", "r", 1.0),
            c: self.f64("ann", "c", 2.0),
            w: self.f64("ann", "w", 4.0),
            l_cap: self.usize("ann", "l_cap", 32),
            seed: self.u64("ann", "seed", 42),
        };
        if !(0.0..=1.0).contains(&cfg.eta) {
            anyhow::bail!("ann.eta must be in [0,1], got {}", cfg.eta);
        }
        if cfg.c <= 1.0 {
            anyhow::bail!("ann.c must be > 1, got {}", cfg.c);
        }
        if cfg.r <= 0.0 || cfg.w <= 0.0 {
            anyhow::bail!("ann.r and ann.w must be positive");
        }
        Ok(cfg)
    }

    /// `[kde]` section → SW-AKDE shard parameters.
    pub fn kde(&self) -> anyhow::Result<KdeShardConfig> {
        let kernel = match self.str("kde", "kernel", "angular").as_str() {
            "angular" => KdeKernel::Angular,
            "euclidean" => KdeKernel::Euclidean,
            other => anyhow::bail!("kde.kernel must be angular|euclidean, got {other:?}"),
        };
        let cfg = KdeShardConfig {
            kernel,
            rows: self.usize("kde", "rows", 64),
            p: self.usize("kde", "p", 3),
            range: self.usize("kde", "range", 64),
            width: self.f64("kde", "width", 4.0) as f32,
            eps_eh: self.f64("kde", "eps_eh", 0.1),
            window: self.u64("kde", "window", 1024),
        };
        if cfg.eps_eh <= 0.0 || cfg.eps_eh > 1.0 {
            anyhow::bail!("kde.eps_eh must be in (0,1], got {}", cfg.eps_eh);
        }
        if cfg.rows == 0 || cfg.p == 0 || cfg.window == 0 {
            anyhow::bail!("kde.rows, kde.p, kde.window must be positive");
        }
        Ok(cfg)
    }

    /// `[service]` section (+ `[ann]`/`[kde]`) → full service config.
    pub fn service(&self, dim: usize, n_max: usize) -> anyhow::Result<ServiceConfig> {
        let route = match self.str("service", "route", "hash").as_str() {
            "hash" => RoutePolicy::HashVector,
            "round_robin" => RoutePolicy::RoundRobin,
            other => anyhow::bail!("service.route must be hash|round_robin, got {other:?}"),
        };
        let overload = match self.str("service", "overload", "block").as_str() {
            "block" => Overload::Block,
            "shed" => Overload::Shed,
            other => anyhow::bail!("service.overload must be block|shed, got {other:?}"),
        };
        let fsync = match self.file.get("service", "fsync") {
            Some(v) => crate::durability::FsyncPolicy::parse(v)?,
            None => crate::durability::FsyncPolicy::default(),
        };
        let on_durability_loss = match self.file.get("service", "on_durability_loss") {
            Some(v) => crate::coordinator::DurabilityLossPolicy::parse(v)?,
            None => crate::coordinator::DurabilityLossPolicy::default(),
        };
        let every_points = self.u64("service", "checkpoint_every_points", 0);
        let every_secs = self.u64("service", "checkpoint_every_secs", 0);
        Ok(ServiceConfig {
            dim,
            shards: self.usize("service", "shards", 4).max(1),
            shard_base: self.usize("service", "shard_base", 0),
            replicas: self.usize("service", "replicas", 1).max(1),
            route,
            queue_cap: self.usize("service", "queue_cap", 1024).max(1),
            overload,
            ann: self.ann(dim, n_max)?,
            kde: self.kde()?,
            seed: self.u64("service", "seed", 42),
            use_pjrt: self.bool("service", "use_pjrt", false),
            data_dir: self
                .file
                .get("service", "data_dir")
                .map(std::path::PathBuf::from),
            fsync,
            checkpoint_every_points: (every_points > 0).then_some(every_points),
            checkpoint_every_secs: (every_secs > 0).then_some(every_secs),
            on_durability_loss,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# experiment config
[ann]
eta = 0.6
r = 0.5
c = 2.0
w = 4.0

[kde]
kernel = euclidean
rows = 128
window = 450

[service]
shards = 2
replicas = 3
route = round_robin
use_pjrt = true
"#;

    #[test]
    fn parses_sections_with_defaults() {
        let c = Config::parse(SAMPLE).unwrap();
        let ann = c.ann(32, 10_000).unwrap();
        assert_eq!(ann.eta, 0.6);
        assert_eq!(ann.r, 0.5);
        assert_eq!(ann.l_cap, 32, "default applies");
        let kde = c.kde().unwrap();
        assert_eq!(kde.kernel, KdeKernel::Euclidean);
        assert_eq!(kde.rows, 128);
        assert_eq!(kde.window, 450);
        assert_eq!(kde.p, 3, "default applies");
        let svc = c.service(32, 10_000).unwrap();
        assert_eq!(svc.shards, 2);
        assert_eq!(svc.replicas, 3);
        assert_eq!(svc.route, RoutePolicy::RoundRobin);
        assert!(svc.use_pjrt);
    }

    #[test]
    fn empty_config_is_all_defaults() {
        let c = Config::empty();
        let svc = c.service(16, 1000).unwrap();
        assert!(svc.data_dir.is_none(), "durability defaults off");
        assert!(svc.checkpoint_every_points.is_none());
        assert_eq!(svc.replicas, 1, "un-replicated by default");
    }

    #[test]
    fn replicas_zero_clamps_to_one() {
        let c = Config::parse("[service]\nreplicas = 0\n").unwrap();
        assert_eq!(c.service(8, 100).unwrap().replicas, 1);
    }

    #[test]
    fn durability_section_parses() {
        let c = Config::parse(
            "[service]\ndata_dir = \"/tmp/sk\"\nfsync = always\ncheckpoint_every_points = 5000\n",
        )
        .unwrap();
        let svc = c.service(8, 100).unwrap();
        assert_eq!(svc.data_dir.as_deref(), Some(std::path::Path::new("/tmp/sk")));
        assert_eq!(svc.fsync, crate::durability::FsyncPolicy::Always);
        assert_eq!(svc.checkpoint_every_points, Some(5000));
        assert_eq!(svc.checkpoint_every_secs, None);
        let bad = Config::parse("[service]\nfsync = banana\n").unwrap();
        assert!(bad.service(8, 100).is_err());
    }

    #[test]
    fn on_durability_loss_parses_and_defaults() {
        use crate::coordinator::DurabilityLossPolicy;
        let c = Config::empty();
        assert_eq!(
            c.service(8, 100).unwrap().on_durability_loss,
            DurabilityLossPolicy::Degrade,
            "degrade by default"
        );
        for (txt, want) in [
            ("degrade", DurabilityLossPolicy::Degrade),
            ("read_only", DurabilityLossPolicy::ReadOnly),
            ("read-only", DurabilityLossPolicy::ReadOnly),
            ("abort", DurabilityLossPolicy::Abort),
        ] {
            let c =
                Config::parse(&format!("[service]\non_durability_loss = {txt}\n")).unwrap();
            assert_eq!(c.service(8, 100).unwrap().on_durability_loss, want, "{txt}");
        }
        let bad = Config::parse("[service]\non_durability_loss = banana\n").unwrap();
        assert!(bad.service(8, 100).is_err());
    }

    #[test]
    fn validation_rejects_bad_values() {
        let c = Config::parse("[ann]\neta = 1.5\n").unwrap();
        assert!(c.ann(8, 100).is_err());
        let c = Config::parse("[ann]\nc = 0.5\n").unwrap();
        assert!(c.ann(8, 100).is_err());
        let c = Config::parse("[kde]\nkernel = banana\n").unwrap();
        assert!(c.kde().is_err());
        let c = Config::parse("[kde]\neps_eh = 0\n").unwrap();
        assert!(c.kde().is_err());
        let c = Config::parse("[service]\nroute = nowhere\n").unwrap();
        assert!(c.service(8, 100).is_err());
    }
}
