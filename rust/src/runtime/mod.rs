//! AOT runtime: loads `artifacts/*.hlo.txt` (lowered once by
//! `python/compile/aot.py`) and executes them on the PJRT CPU client from
//! the Rust hot path. `native` mirrors every artifact in pure Rust for
//! cross-checking and artifact-less operation.

pub mod executor;
pub mod manifest;
pub mod native;

pub use executor::{Arg, Executor, Tensor};
pub use manifest::{ArtifactSpec, DType, Manifest, TensorSpec};

/// PJRT platform smoke check.
pub fn platform_name() -> anyhow::Result<String> {
    let client = xla::PjRtClient::cpu()?;
    Ok(client.platform_name())
}
