//! PJRT executor: compiles the HLO-text artifacts once and runs them from
//! the serving hot path. Python never runs here — this is the AOT bridge
//! (see /opt/xla-example/load_hlo and DESIGN.md §1).
//!
//! The raw entry point is [`Executor::execute`]; the `*_tiled` helpers pad
//! and tile arbitrary batch sizes onto the fixed artifact shapes
//! (DESIGN.md §6) and reassemble full-size outputs.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use super::manifest::{ArtifactSpec, DType, Manifest};

/// A borrowed input tensor.
pub enum Arg<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
}

impl Arg<'_> {
    fn len(&self) -> usize {
        match self {
            Arg::F32(v) => v.len(),
            Arg::I32(v) => v.len(),
        }
    }
    fn dtype(&self) -> DType {
        match self {
            Arg::F32(_) => DType::F32,
            Arg::I32(_) => DType::I32,
        }
    }
}

/// An owned output tensor.
#[derive(Clone, Debug)]
pub enum Tensor {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Tensor {
    pub fn as_f32(&self) -> &[f32] {
        match self {
            Tensor::F32(v) => v,
            _ => panic!("expected f32 tensor"),
        }
    }
    pub fn as_i32(&self) -> &[i32] {
        match self {
            Tensor::I32(v) => v,
            _ => panic!("expected i32 tensor"),
        }
    }
}

/// Compiled-artifact cache over one PJRT CPU client.
pub struct Executor {
    client: xla::PjRtClient,
    manifest: Manifest,
    compiled: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Executions per artifact (perf accounting).
    pub exec_counts: HashMap<String, u64>,
}

impl Executor {
    /// Load the manifest and create the PJRT CPU client. Artifacts compile
    /// lazily on first use and stay cached.
    pub fn new(artifact_dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Executor { client, manifest, compiled: HashMap::new(), exec_counts: HashMap::new() })
    }

    /// Default artifact directory (env `SKETCH_ARTIFACTS` or ./artifacts).
    pub fn from_default_dir() -> Result<Self> {
        Self::new(&Manifest::default_dir())
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn ensure_compiled(&mut self, name: &str) -> Result<()> {
        if self.compiled.contains_key(name) {
            return Ok(());
        }
        let spec = self
            .manifest
            .find(name)
            .ok_or_else(|| anyhow!("unknown artifact {name:?}"))?;
        let proto = xla::HloModuleProto::from_text_file(&spec.file)
            .map_err(|e| anyhow!("parsing {:?}: {e:?}", spec.file))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        self.compiled.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute artifact `name` with exactly the manifest shapes.
    pub fn execute(&mut self, name: &str, args: &[Arg<'_>]) -> Result<Tensor> {
        self.ensure_compiled(name)?;
        let spec = self.manifest.find(name).unwrap().clone();
        if args.len() != spec.inputs.len() {
            bail!("{name}: expected {} inputs, got {}", spec.inputs.len(), args.len());
        }
        let mut literals = Vec::with_capacity(args.len());
        for (i, (a, t)) in args.iter().zip(&spec.inputs).enumerate() {
            if a.len() != t.elements() {
                bail!("{name} input {i}: expected {} elements, got {}", t.elements(), a.len());
            }
            if a.dtype() != t.dtype {
                bail!("{name} input {i}: dtype mismatch");
            }
            let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
            let lit = match a {
                Arg::F32(v) => xla::Literal::vec1(v),
                Arg::I32(v) => xla::Literal::vec1(v),
            };
            literals.push(lit.reshape(&dims).map_err(|e| anyhow!("reshape: {e:?}"))?);
        }
        let exe = self.compiled.get(name).unwrap();
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch {name}: {e:?}"))?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = result.to_tuple1().map_err(|e| anyhow!("untuple {name}: {e:?}"))?;
        *self.exec_counts.entry(name.to_string()).or_insert(0) += 1;
        match spec.output.dtype {
            DType::F32 => Ok(Tensor::F32(
                out.to_vec::<f32>().map_err(|e| anyhow!("read f32: {e:?}"))?,
            )),
            DType::I32 => Ok(Tensor::I32(
                out.to_vec::<i32>().map_err(|e| anyhow!("read i32: {e:?}"))?,
            )),
        }
    }

    fn variant(&self, kind: &str, dim: usize) -> Result<ArtifactSpec> {
        self.manifest
            .find_variant(kind, dim)
            .cloned()
            .with_context(|| format!("no {kind} artifact for dim {dim}"))
    }

    /// Pick the variant whose batch dim wastes the least padding for `m`
    /// rows: the smallest B >= m, else the largest available.
    fn variant_for_rows(&self, kind: &str, dim: usize, m: usize) -> Result<ArtifactSpec> {
        let vs = self.manifest.find_variants(kind, dim);
        if vs.is_empty() {
            anyhow::bail!("no {kind} artifact for dim {dim}");
        }
        Ok(vs
            .iter()
            .find(|a| a.inputs[0].shape[0] >= m)
            .unwrap_or_else(|| vs.last().unwrap())
            .to_owned()
            .clone())
    }

    /// Batched p-stable hashing of `m` points (row-major \[m, dim\]) against
    /// `h` hash slots (proj `\[dim, h\]`, bias `[h]`). Tiles over the artifact's
    /// fixed (B, H) shape, zero-padding rows and columns, and returns
    /// row-major i64 slots \[m, h\] ready for `TableHasher::keys_from_slots`.
    pub fn pstable_hash_tiled(
        &mut self,
        dim: usize,
        points: &[f32],
        proj: &[f32],
        bias: &[f32],
        inv_w: f32,
    ) -> Result<Vec<i64>> {
        let m = points.len() / dim;
        let spec = self.variant_for_rows("pstable_hash", dim, m)?;
        let (bb, hh) = (spec.inputs[0].shape[0], spec.inputs[1].shape[1]);
        let h = bias.len();
        assert_eq!(proj.len(), dim * h, "proj must be [dim, h]");
        let inv = [inv_w];
        let mut out = vec![0i64; m * h];
        let mut pts_tile = vec![0f32; bb * dim];
        let mut proj_tile = vec![0f32; dim * hh];
        let mut bias_tile = vec![0f32; hh];
        for c0 in (0..h).step_by(hh) {
            let cw = hh.min(h - c0);
            // column block of proj/bias, zero-padded to hh
            proj_tile.iter_mut().for_each(|v| *v = 0.0);
            for r in 0..dim {
                proj_tile[r * hh..r * hh + cw]
                    .copy_from_slice(&proj[r * h + c0..r * h + c0 + cw]);
            }
            bias_tile.iter_mut().for_each(|v| *v = 0.0);
            bias_tile[..cw].copy_from_slice(&bias[c0..c0 + cw]);
            for r0 in (0..m).step_by(bb) {
                let rw = bb.min(m - r0);
                pts_tile.iter_mut().for_each(|v| *v = 0.0);
                pts_tile[..rw * dim].copy_from_slice(&points[r0 * dim..(r0 + rw) * dim]);
                let t = self.execute(
                    &spec.name,
                    &[
                        Arg::F32(&pts_tile),
                        Arg::F32(&proj_tile),
                        Arg::F32(&bias_tile),
                        Arg::F32(&inv),
                    ],
                )?;
                let slots = t.as_i32();
                for r in 0..rw {
                    for c in 0..cw {
                        out[(r0 + r) * h + c0 + c] = slots[r * hh + c] as i64;
                    }
                }
            }
        }
        Ok(out)
    }

    /// Batched SRP hashing; same tiling contract as `pstable_hash_tiled`.
    pub fn srp_hash_tiled(
        &mut self,
        dim: usize,
        points: &[f32],
        proj: &[f32],
        h: usize,
    ) -> Result<Vec<i64>> {
        let spec = self.variant("srp_hash", dim)?;
        let (bb, hh) = (spec.inputs[0].shape[0], spec.inputs[1].shape[1]);
        let m = points.len() / dim;
        assert_eq!(proj.len(), dim * h, "proj must be [dim, h]");
        let mut out = vec![0i64; m * h];
        let mut pts_tile = vec![0f32; bb * dim];
        let mut proj_tile = vec![0f32; dim * hh];
        for c0 in (0..h).step_by(hh) {
            let cw = hh.min(h - c0);
            proj_tile.iter_mut().for_each(|v| *v = 0.0);
            for r in 0..dim {
                proj_tile[r * hh..r * hh + cw]
                    .copy_from_slice(&proj[r * h + c0..r * h + c0 + cw]);
            }
            for r0 in (0..m).step_by(bb) {
                let rw = bb.min(m - r0);
                pts_tile.iter_mut().for_each(|v| *v = 0.0);
                pts_tile[..rw * dim].copy_from_slice(&points[r0 * dim..(r0 + rw) * dim]);
                let t = self.execute(&spec.name, &[Arg::F32(&pts_tile), Arg::F32(&proj_tile)])?;
                let slots = t.as_i32();
                for r in 0..rw {
                    for c in 0..cw {
                        out[(r0 + r) * h + c0 + c] = slots[r * hh + c] as i64;
                    }
                }
            }
        }
        Ok(out)
    }

    /// Batched re-rank: `queries` row-major \[m, dim\], `cands[i]` the i-th
    /// query's candidate vectors (each `[dim]`); returns per-query squared
    /// distances aligned with the candidate lists. Candidate slots beyond
    /// each list are padding and are not returned.
    pub fn rerank_tiled(
        &mut self,
        dim: usize,
        queries: &[f32],
        cands: &[Vec<&[f32]>],
    ) -> Result<Vec<Vec<f32>>> {
        let spec = self.variant("rerank_l2", dim)?;
        let (bb, cc) = (spec.inputs[0].shape[0], spec.inputs[1].shape[1]);
        let m = queries.len() / dim;
        assert_eq!(cands.len(), m);
        let mut out: Vec<Vec<f32>> = cands.iter().map(|c| vec![0.0; c.len()]).collect();
        let mut q_tile = vec![0f32; bb * dim];
        let mut c_tile = vec![0f32; bb * cc * dim];
        for r0 in (0..m).step_by(bb) {
            let rw = bb.min(m - r0);
            q_tile.iter_mut().for_each(|v| *v = 0.0);
            q_tile[..rw * dim].copy_from_slice(&queries[r0 * dim..(r0 + rw) * dim]);
            c_tile.iter_mut().for_each(|v| *v = 0.0);
            for r in 0..rw {
                let list = &cands[r0 + r];
                assert!(
                    list.len() <= cc,
                    "candidate list {} exceeds artifact capacity {}",
                    list.len(),
                    cc
                );
                for (j, cand) in list.iter().enumerate() {
                    let off = (r * cc + j) * dim;
                    c_tile[off..off + dim].copy_from_slice(cand);
                }
            }
            let t = self.execute(&spec.name, &[Arg::F32(&q_tile), Arg::F32(&c_tile)])?;
            let d = t.as_f32();
            for r in 0..rw {
                let list_len = cands[r0 + r].len();
                out[r0 + r].copy_from_slice(&d[r * cc..r * cc + list_len]);
            }
        }
        Ok(out)
    }

    /// Shared-pool distance matrix: queries row-major [mq, dim] against a
    /// pool [p, dim]; returns row-major [mq, p] squared distances. Tiles
    /// over the artifact's fixed (Q, P) shape (zero rows in the padding
    /// produce distances to the origin, which callers never index).
    pub fn dist_matrix_tiled(
        &mut self,
        dim: usize,
        queries: &[f32],
        pool: &[f32],
    ) -> Result<Vec<f32>> {
        let spec = self.variant("dist_matrix", dim)?;
        let (qq, pp) = (spec.inputs[0].shape[0], spec.inputs[1].shape[0]);
        let mq = queries.len() / dim;
        let p = pool.len() / dim;
        let mut out = vec![0f32; mq * p];
        let mut q_tile = vec![0f32; qq * dim];
        let mut p_tile = vec![0f32; pp * dim];
        for r0 in (0..mq).step_by(qq) {
            let rw = qq.min(mq - r0);
            q_tile.iter_mut().for_each(|v| *v = 0.0);
            q_tile[..rw * dim].copy_from_slice(&queries[r0 * dim..(r0 + rw) * dim]);
            for c0 in (0..p).step_by(pp) {
                let cw = pp.min(p - c0);
                p_tile.iter_mut().for_each(|v| *v = 0.0);
                p_tile[..cw * dim].copy_from_slice(&pool[c0 * dim..(c0 + cw) * dim]);
                let t = self.execute(&spec.name, &[Arg::F32(&q_tile), Arg::F32(&p_tile)])?;
                let d = t.as_f32();
                for r in 0..rw {
                    out[(r0 + r) * p + c0..(r0 + r) * p + c0 + cw]
                        .copy_from_slice(&d[r * pp..r * pp + cw]);
                }
            }
        }
        Ok(out)
    }

    /// Exact KDE ground truth over a full dataset, streamed through the
    /// fixed (Q, N) kde artifact tiles. `kind` is "kde_angular" or
    /// "kde_pstable" (the latter takes the bucket width `w`).
    pub fn kde_tiled(
        &mut self,
        kind: &str,
        dim: usize,
        queries: &[f32],
        data: &[f32],
        w: Option<f32>,
        p: f32,
    ) -> Result<Vec<f64>> {
        let spec = self.variant(kind, dim)?;
        let (qq, nn) = (spec.inputs[0].shape[0], spec.inputs[1].shape[0]);
        let mq = queries.len() / dim;
        let n = data.len() / dim;
        let pv = [p];
        let wv = [w.unwrap_or(1.0)];
        let mut out = vec![0f64; mq];
        let mut q_tile = vec![0f32; qq * dim];
        let mut d_tile = vec![0f32; nn * dim];
        for r0 in (0..mq).step_by(qq) {
            let rw = qq.min(mq - r0);
            q_tile.iter_mut().for_each(|v| *v = 0.0);
            q_tile[..rw * dim].copy_from_slice(&queries[r0 * dim..(r0 + rw) * dim]);
            for n0 in (0..n).step_by(nn) {
                let nw = nn.min(n - n0);
                d_tile.iter_mut().for_each(|v| *v = 0.0); // zero rows are masked by the kernel
                d_tile[..nw * dim].copy_from_slice(&data[n0 * dim..(n0 + nw) * dim]);
                let args: Vec<Arg> = if kind == "kde_pstable" {
                    vec![Arg::F32(&q_tile), Arg::F32(&d_tile), Arg::F32(&wv), Arg::F32(&pv)]
                } else {
                    vec![Arg::F32(&q_tile), Arg::F32(&d_tile), Arg::F32(&pv)]
                };
                let t = self.execute(&spec.name, &args)?;
                let partial = t.as_f32();
                for r in 0..rw {
                    out[r0 + r] += partial[r] as f64;
                }
            }
        }
        Ok(out)
    }
}
