//! Artifact manifest: the shape/dtype registry `python/compile/aot.py`
//! writes next to the HLO text files. Parsed with the in-repo JSON
//! substrate (util::json).

use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// Element type of an artifact tensor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => anyhow::bail!("unknown dtype {other:?}"),
        }
    }
}

/// Shape + dtype of one artifact input/output.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> anyhow::Result<Self> {
        let shape = j
            .get("shape")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("missing shape"))?
            .iter()
            .map(|v| v.as_usize().ok_or_else(|| anyhow::anyhow!("bad dim")))
            .collect::<Result<Vec<_>, _>>()?;
        let dtype = DType::parse(
            j.get("dtype")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow::anyhow!("missing dtype"))?,
        )?;
        Ok(TensorSpec { shape, dtype })
    }
}

/// One AOT artifact entry.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    /// Function kind: pstable_hash | srp_hash | rerank_l2 | kde_angular | kde_pstable.
    pub kind: String,
    pub file: PathBuf,
    pub golden: bool,
    pub inputs: Vec<TensorSpec>,
    pub output: TensorSpec,
}

/// The parsed manifest.
#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let path = dir.join("manifest.json");
        let src = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("reading {path:?}: {e} (run `make artifacts`)"))?;
        let root = Json::parse(&src)?;
        let arts = root
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("manifest missing 'artifacts'"))?;
        let mut artifacts = Vec::with_capacity(arts.len());
        for a in arts {
            let name = a
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow::anyhow!("artifact missing name"))?
                .to_string();
            let kind = a
                .get("kind")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow::anyhow!("artifact missing kind"))?
                .to_string();
            let file = dir.join(
                a.get("file")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow::anyhow!("artifact missing file"))?,
            );
            let golden = a.get("golden").and_then(Json::as_bool).unwrap_or(false);
            let inputs = a
                .get("inputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow::anyhow!("artifact missing inputs"))?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>, _>>()?;
            let output = TensorSpec::from_json(
                a.get("output").ok_or_else(|| anyhow::anyhow!("missing output"))?,
            )?;
            artifacts.push(ArtifactSpec { name, kind, file, golden, inputs, output });
        }
        Ok(Manifest { dir: dir.to_path_buf(), artifacts })
    }

    pub fn find(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// The production (non-golden) artifact of `kind` whose first input's
    /// trailing dim equals `dim` (the hash/kde variant lookup).
    pub fn find_variant(&self, kind: &str, dim: usize) -> Option<&ArtifactSpec> {
        self.find_variants(kind, dim).into_iter().next()
    }

    /// All production variants of `kind` at `dim`, sorted by batch size
    /// ascending — the executor picks the smallest batch that fits.
    pub fn find_variants(&self, kind: &str, dim: usize) -> Vec<&ArtifactSpec> {
        let mut out: Vec<&ArtifactSpec> = self
            .artifacts
            .iter()
            .filter(|a| {
                !a.golden
                    && a.kind == kind
                    && a.inputs
                        .first()
                        .and_then(|t| t.shape.last())
                        .is_some_and(|&d| d == dim)
            })
            .collect();
        out.sort_by_key(|a| a.inputs[0].shape[0]);
        out
    }

    /// Default artifact directory: `$SKETCH_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("SKETCH_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), body).unwrap();
    }

    #[test]
    fn parses_minimal_manifest() {
        let dir = std::env::temp_dir().join("ss_manifest_test1");
        write_manifest(
            &dir,
            r#"{"version":1,"artifacts":[
              {"name":"pstable_hash_8","kind":"pstable_hash","file":"x.hlo.txt",
               "golden":false,
               "inputs":[{"shape":[4,8],"dtype":"f32"},{"shape":[8,16],"dtype":"f32"}],
               "output":{"shape":[4,16],"dtype":"i32"}}]}"#,
        );
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.artifacts.len(), 1);
        let a = m.find("pstable_hash_8").unwrap();
        assert_eq!(a.inputs[0].shape, vec![4, 8]);
        assert_eq!(a.output.dtype, DType::I32);
        assert_eq!(a.output.elements(), 64);
        assert!(m.find_variant("pstable_hash", 8).is_some());
        assert!(m.find_variant("pstable_hash", 99).is_none());
    }

    #[test]
    fn missing_manifest_mentions_make_artifacts() {
        let err = Manifest::load(Path::new("/nonexistent/dir")).unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn golden_variants_excluded_from_variant_lookup() {
        let dir = std::env::temp_dir().join("ss_manifest_test2");
        write_manifest(
            &dir,
            r#"{"artifacts":[
              {"name":"srp_hash_g","kind":"srp_hash","file":"g.hlo.txt","golden":true,
               "inputs":[{"shape":[8,16],"dtype":"f32"}],
               "output":{"shape":[8,32],"dtype":"i32"}}]}"#,
        );
        let m = Manifest::load(&dir).unwrap();
        assert!(m.find_variant("srp_hash", 16).is_none());
        assert!(m.find("srp_hash_g").unwrap().golden);
    }

    #[test]
    fn real_manifest_parses_if_built() {
        let dir = Manifest::default_dir();
        if !dir.join("manifest.json").exists() {
            return; // artifacts not built in this environment
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.artifacts.len() >= 20);
        for kind in ["pstable_hash", "rerank_l2", "kde_angular", "kde_pstable"] {
            assert!(
                m.artifacts.iter().any(|a| a.kind == kind),
                "missing kind {kind}"
            );
        }
    }
}
