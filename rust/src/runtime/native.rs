//! Pure-Rust mirrors of every artifact function.
//!
//! Two jobs: (1) cross-check the PJRT path numerically (integration tests
//! assert artifact ≡ native ≡ python-golden), and (2) keep the library
//! fully functional when `artifacts/` has not been built.

use crate::lsh::pstable::PStableLsh;
use crate::util::l2;

/// floor((x·proj_col + bias) * inv_w) per point per slot → \[m, h\] i64.
pub fn pstable_hash(
    dim: usize,
    points: &[f32],
    proj: &[f32], // [dim, h] column-per-slot
    bias: &[f32],
    inv_w: f32,
) -> Vec<i64> {
    let m = points.len() / dim;
    let h = bias.len();
    let mut out = vec![0i64; m * h];
    for r in 0..m {
        let x = &points[r * dim..(r + 1) * dim];
        for c in 0..h {
            let mut acc = 0.0f32;
            for i in 0..dim {
                acc += x[i] * proj[i * h + c];
            }
            out[r * h + c] = ((acc + bias[c]) * inv_w).floor() as i64;
        }
    }
    out
}

/// (x·proj_col >= 0) per point per slot → \[m, h\] i64 in {0, 1}.
pub fn srp_hash(dim: usize, points: &[f32], proj: &[f32], h: usize) -> Vec<i64> {
    let m = points.len() / dim;
    let mut out = vec![0i64; m * h];
    for r in 0..m {
        let x = &points[r * dim..(r + 1) * dim];
        for c in 0..h {
            let mut acc = 0.0f32;
            for i in 0..dim {
                acc += x[i] * proj[i * h + c];
            }
            out[r * h + c] = (acc >= 0.0) as i64;
        }
    }
    out
}

/// Full Q×P squared-distance matrix against a shared candidate pool
/// (mirror of the `dist_matrix_*` artifacts; row-major [mq, p]).
pub fn dist_matrix(dim: usize, queries: &[f32], pool: &[f32]) -> Vec<f32> {
    let mq = queries.len() / dim;
    let p = pool.len() / dim;
    let mut out = vec![0f32; mq * p];
    for r in 0..mq {
        let q = &queries[r * dim..(r + 1) * dim];
        for j in 0..p {
            let x = &pool[j * dim..(j + 1) * dim];
            out[r * p + j] = crate::util::l2_sq(q, x);
        }
    }
    out
}

/// Per-query squared distances to per-query candidate lists.
pub fn rerank_l2(dim: usize, queries: &[f32], cands: &[Vec<&[f32]>]) -> Vec<Vec<f32>> {
    let m = queries.len() / dim;
    (0..m)
        .map(|r| {
            let q = &queries[r * dim..(r + 1) * dim];
            cands[r].iter().map(|c| crate::util::l2_sq(q, c)).collect()
        })
        .collect()
}

/// Exact angular LSH-kernel density with zero-row masking (matches the
/// Pallas kernel's padding semantics).
pub fn kde_angular(dim: usize, queries: &[f32], data: &[f32], p: f32) -> Vec<f64> {
    let mq = queries.len() / dim;
    let n = data.len() / dim;
    (0..mq)
        .map(|r| {
            let q = &queries[r * dim..(r + 1) * dim];
            let mut acc = 0.0f64;
            for i in 0..n {
                let x = &data[i * dim..(i + 1) * dim];
                let xn2: f32 = x.iter().map(|v| v * v).sum();
                if xn2 == 0.0 {
                    continue; // padding row
                }
                let cos = crate::util::cosine(q, x) as f64;
                acc += (1.0 - cos.acos() / std::f64::consts::PI).powf(p as f64);
            }
            acc
        })
        .collect()
}

/// Exact p-stable LSH-kernel density with zero-row masking.
pub fn kde_pstable(dim: usize, queries: &[f32], data: &[f32], w: f32, p: f32) -> Vec<f64> {
    let mq = queries.len() / dim;
    let n = data.len() / dim;
    (0..mq)
        .map(|r| {
            let q = &queries[r * dim..(r + 1) * dim];
            let mut acc = 0.0f64;
            for i in 0..n {
                let x = &data[i * dim..(i + 1) * dim];
                let xn2: f32 = x.iter().map(|v| v * v).sum();
                if xn2 == 0.0 {
                    continue;
                }
                let d = l2(q, x) as f64;
                acc += PStableLsh::collision_prob_for(d, w as f64).powf(p as f64);
            }
            acc
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lsh::srp::SrpLsh;
    use crate::lsh::LshFamily;
    use crate::util::rng::Rng;

    #[test]
    fn pstable_native_matches_family_hashing() {
        // The family's hash_one and the flat native path must agree exactly
        // (both compute in f32 then floor).
        let (dim, h) = (6, 8);
        let mut rng = Rng::new(1);
        let fam = crate::lsh::pstable::PStableLsh::new(dim, h, 2.0, &mut rng);
        let mut rng2 = Rng::new(2);
        let x: Vec<f32> = (0..dim).map(|_| rng2.gaussian_f32() * 3.0).collect();
        let slots = pstable_hash(dim, &x, fam.projection(), fam.biases(), 1.0 / 2.0);
        for j in 0..h {
            assert_eq!(slots[j], fam.hash_one(j, &x), "slot {j}");
        }
    }

    #[test]
    fn srp_native_matches_family_hashing() {
        let (dim, h) = (10, 16);
        let fam = SrpLsh::new(dim, h, &mut Rng::new(3));
        let mut rng = Rng::new(4);
        let x: Vec<f32> = (0..dim).map(|_| rng.gaussian_f32()).collect();
        let slots = srp_hash(dim, &x, fam.projection(), h);
        for j in 0..h {
            assert_eq!(slots[j], fam.hash_one(j, &x), "slot {j}");
        }
    }

    #[test]
    fn kde_matches_baseline_oracles() {
        let dim = 8;
        let mut rng = Rng::new(5);
        let data: Vec<Vec<f32>> = (0..40)
            .map(|_| (0..dim).map(|_| rng.gaussian_f32()).collect())
            .collect();
        let q: Vec<f32> = (0..dim).map(|_| rng.gaussian_f32()).collect();
        let flat: Vec<f32> = data.iter().flatten().copied().collect();
        let a = kde_angular(dim, &q, &flat, 4.0)[0];
        let b = crate::baselines::exact_kde_angular(&data, &q, 4);
        assert!((a - b).abs() < 1e-6 * b.max(1.0), "a={a} b={b}");
        let c = kde_pstable(dim, &q, &flat, 2.0, 4.0)[0];
        let d = crate::baselines::exact_kde_pstable(&data, &q, 2.0, 4);
        assert!((c - d).abs() < 1e-6 * d.max(1.0), "c={c} d={d}");
    }

    #[test]
    fn rerank_matches_l2() {
        let dim = 4;
        let q = vec![0.0f32; 4];
        let c1 = [1.0f32, 0.0, 0.0, 0.0];
        let c2 = [3.0f32, 4.0, 0.0, 0.0];
        let out = rerank_l2(dim, &q, &[vec![&c1[..], &c2[..]]]);
        assert_eq!(out[0], vec![1.0, 25.0]);
    }
}
