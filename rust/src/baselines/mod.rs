//! Baselines the paper compares against: JL projection (§5.1) and exact
//! brute-force oracles used as ground truth in every experiment.

pub mod exact;
pub mod jl;

pub use exact::{exact_kde_angular, exact_kde_pstable, ExactNn};
pub use jl::JlBaseline;
