//! Johnson–Lindenstrauss projection baseline (§5.1).
//!
//! The paper's comparator: "the only known strict one-pass solution for
//! (c, r)-ANN". Every stream point is projected to k dimensions with a
//! gaussian matrix scaled by 1/√k and stored; queries brute-force scan the
//! projected points. Compression rate is k/d (all N points are kept, each
//! shrunk), versus S-ANN's n^{−η} point sampling at full dimensionality.

use crate::storage::VecStore;
use crate::util::{l2_sq, rng::Rng};

/// One-pass JL sketch: projected points + exhaustive scan queries.
pub struct JlBaseline {
    dim: usize,
    k: usize,
    /// Row-major [k, dim] projection, scaled by 1/sqrt(k).
    proj: Vec<f32>,
    store: VecStore,
    scratch: Vec<f32>,
}

impl JlBaseline {
    pub fn new(dim: usize, k: usize, seed: u64) -> Self {
        assert!(k >= 1);
        let mut rng = Rng::new(seed);
        let scale = 1.0 / (k as f32).sqrt();
        let mut proj = vec![0.0f32; k * dim];
        rng.fill_gaussian_f32(&mut proj);
        proj.iter_mut().for_each(|v| *v *= scale);
        JlBaseline { dim, k, proj, store: VecStore::new(k), scratch: vec![0.0; k] }
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn stored(&self) -> usize {
        self.store.live()
    }

    fn project_into(&self, x: &[f32], out: &mut [f32]) {
        debug_assert_eq!(x.len(), self.dim);
        for (j, o) in out.iter_mut().enumerate() {
            let row = &self.proj[j * self.dim..(j + 1) * self.dim];
            *o = crate::util::dot(row, x);
        }
    }

    /// Insert a stream point (projected; original is NOT kept).
    pub fn insert(&mut self, x: &[f32]) -> u32 {
        let mut p = vec![0.0f32; self.k];
        self.project_into(x, &mut p);
        self.store.push(&p)
    }

    /// Exhaustive top-k nearest ids in the projected space (partial
    /// selection, not a full sort — the scan dominates, as it should).
    pub fn query_topk(&mut self, q: &[f32], topk: usize) -> Vec<(u32, f32)> {
        let mut qp = std::mem::take(&mut self.scratch);
        self.project_into(q, &mut qp);
        let mut scored: Vec<(u32, f32)> = self
            .store
            .live_ids()
            .map(|id| (id, l2_sq(self.store.get(id), &qp)))
            .collect();
        let k = topk.min(scored.len());
        if k > 0 && k < scored.len() {
            scored.select_nth_unstable_by(k - 1, |a, b| a.1.partial_cmp(&b.1).unwrap());
        }
        scored.truncate(k);
        scored.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        self.scratch = qp;
        scored.iter_mut().for_each(|e| e.1 = e.1.sqrt());
        scored
    }

    /// Nearest projected neighbor.
    pub fn query(&mut self, q: &[f32]) -> Option<(u32, f32)> {
        self.query_topk(q, 1).first().copied()
    }

    /// Sketch bytes: projected points plus the projection matrix.
    pub fn memory_bytes(&self) -> usize {
        self.store.payload_bytes() + self.proj.len() * 4 + std::mem::size_of::<Self>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pts(rng: &mut Rng, n: usize, dim: usize) -> Vec<Vec<f32>> {
        (0..n)
            .map(|_| (0..dim).map(|_| rng.gaussian_f32()).collect())
            .collect()
    }

    #[test]
    fn identity_query_finds_itself() {
        let dim = 32;
        let mut jl = JlBaseline::new(dim, 16, 1);
        let mut rng = Rng::new(2);
        let data = pts(&mut rng, 100, dim);
        for p in &data {
            jl.insert(p);
        }
        // Distances contract approximately; the stored copy of the query
        // projects identically, so distance 0 is preserved exactly.
        let (id, d) = jl.query(&data[7]).unwrap();
        assert_eq!(id, 7);
        assert!(d < 1e-5);
    }

    #[test]
    fn k_equals_d_recovers_good_neighbors() {
        // With k=d the projection is a random rotation-ish map: the true
        // nearest neighbor should usually be ranked first.
        let dim = 16;
        let mut jl = JlBaseline::new(dim, dim, 3);
        let mut rng = Rng::new(4);
        let data = pts(&mut rng, 200, dim);
        for p in &data {
            jl.insert(p);
        }
        let mut agree = 0;
        for qi in 0..30 {
            let q: Vec<f32> = data[qi].iter().map(|v| v + 0.01 * rng.gaussian_f32()).collect();
            let (id, _) = jl.query(&q).unwrap();
            if id == qi as u32 {
                agree += 1;
            }
        }
        assert!(agree >= 27, "agree={agree}/30");
    }

    #[test]
    fn distance_distortion_is_bounded() {
        // JL lemma sanity: pairwise distances distort within ~(1±eps) for
        // k = O(log n / eps^2); check empirically at k=64.
        let dim = 128;
        let k = 64;
        let jl = JlBaseline::new(dim, k, 5);
        let mut rng = Rng::new(6);
        let data = pts(&mut rng, 40, dim);
        let mut max_ratio: f32 = 0.0;
        let mut min_ratio: f32 = f32::MAX;
        for i in 0..data.len() {
            for j in (i + 1)..data.len() {
                let true_d = crate::util::l2(&data[i], &data[j]);
                let mut pi = vec![0.0; k];
                let mut pj = vec![0.0; k];
                jl.project_into(&data[i], &mut pi);
                jl.project_into(&data[j], &mut pj);
                let proj_d = crate::util::l2(&pi, &pj);
                let ratio = proj_d / true_d;
                max_ratio = max_ratio.max(ratio);
                min_ratio = min_ratio.min(ratio);
            }
        }
        assert!(max_ratio < 1.6, "max={max_ratio}");
        assert!(min_ratio > 0.5, "min={min_ratio}");
    }

    #[test]
    fn memory_scales_with_k() {
        let dim = 64;
        let mut small = JlBaseline::new(dim, 8, 7);
        let mut large = JlBaseline::new(dim, 32, 7);
        let mut rng = Rng::new(8);
        for p in pts(&mut rng, 500, dim) {
            small.insert(&p);
            large.insert(&p);
        }
        let s = small.memory_bytes() as f64;
        let l = large.memory_bytes() as f64;
        assert!(l / s > 3.0 && l / s < 5.0, "ratio={}", l / s);
    }
}
