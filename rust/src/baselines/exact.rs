//! Exact (brute-force) oracles: ground truth for every experiment metric.
//!
//! * [`ExactNn`]: linear-scan nearest neighbors — truth for recall@k and
//!   (c, r)-accuracy.
//! * [`exact_kde_angular`] / [`exact_kde_pstable`]: the LSH-kernel density
//!   Σ_x k^p(x, q) that RACE/SW-AKDE estimate (CS20 Thm 2.3) — truth for
//!   the relative-error figures. The PJRT `kde_*` artifacts compute the
//!   same quantity tile-by-tile; `runtime::native` cross-checks both.

use crate::lsh::pstable::PStableLsh;
use crate::util::{cosine, l2, l2_sq};

/// Brute-force nearest-neighbor index.
pub struct ExactNn {
    dim: usize,
    data: Vec<f32>,
    n: usize,
}

impl ExactNn {
    pub fn new(dim: usize) -> Self {
        ExactNn { dim, data: Vec::new(), n: 0 }
    }

    pub fn from_points(dim: usize, pts: &[Vec<f32>]) -> Self {
        let mut s = Self::new(dim);
        for p in pts {
            s.insert(p);
        }
        s
    }

    pub fn insert(&mut self, x: &[f32]) {
        assert_eq!(x.len(), self.dim);
        self.data.extend_from_slice(x);
        self.n += 1;
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    #[inline]
    pub fn get(&self, i: usize) -> &[f32] {
        &self.data[i * self.dim..(i + 1) * self.dim]
    }

    /// Exact top-k: (index, distance) ascending.
    pub fn topk(&self, q: &[f32], k: usize) -> Vec<(usize, f32)> {
        let mut scored: Vec<(usize, f32)> =
            (0..self.n).map(|i| (i, l2_sq(self.get(i), q))).collect();
        scored.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        scored.truncate(k);
        scored.iter_mut().for_each(|e| e.1 = e.1.sqrt());
        scored
    }

    /// Exact nearest-neighbor distance (∞ when empty).
    pub fn nn_dist(&self, q: &[f32]) -> f32 {
        (0..self.n)
            .map(|i| l2_sq(self.get(i), q))
            .fold(f32::INFINITY, f32::min)
            .sqrt()
    }

    /// Whether any point lies within radius `r` of `q`.
    pub fn has_within(&self, q: &[f32], r: f32) -> bool {
        let r_sq = r * r;
        (0..self.n).any(|i| l2_sq(self.get(i), q) <= r_sq)
    }
}

/// Exact angular LSH-kernel density Σ_x (1 − θ(x,q)/π)^p.
pub fn exact_kde_angular(data: &[Vec<f32>], q: &[f32], p: u32) -> f64 {
    data.iter()
        .map(|x| {
            let cos = cosine(x, q) as f64;
            (1.0 - cos.acos() / std::f64::consts::PI).powi(p as i32)
        })
        .sum()
}

/// Exact p-stable LSH-kernel density Σ_x P(‖x−q‖; w)^p.
pub fn exact_kde_pstable(data: &[Vec<f32>], q: &[f32], w: f64, p: u32) -> f64 {
    data.iter()
        .map(|x| PStableLsh::collision_prob_for(l2(x, q) as f64, w).powi(p as i32))
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn pts(rng: &mut Rng, n: usize, dim: usize) -> Vec<Vec<f32>> {
        (0..n)
            .map(|_| (0..dim).map(|_| rng.gaussian_f32()).collect())
            .collect()
    }

    #[test]
    fn topk_is_sorted_and_exact() {
        let mut rng = Rng::new(1);
        let data = pts(&mut rng, 50, 4);
        let nn = ExactNn::from_points(4, &data);
        let q = vec![0.0f32; 4];
        let top = nn.topk(&q, 5);
        assert_eq!(top.len(), 5);
        for w in top.windows(2) {
            assert!(w[0].1 <= w[1].1);
        }
        // Exhaustive check of the minimum.
        let true_min = data
            .iter()
            .map(|p| crate::util::l2(p, &q))
            .fold(f32::INFINITY, f32::min);
        assert!((top[0].1 - true_min).abs() < 1e-6);
        assert!((nn.nn_dist(&q) - true_min).abs() < 1e-6);
    }

    #[test]
    fn has_within_boundary() {
        let nn = ExactNn::from_points(2, &[vec![3.0, 4.0]]);
        let q = vec![0.0f32, 0.0];
        assert!(nn.has_within(&q, 5.0));
        assert!(nn.has_within(&q, 5.0001));
        assert!(!nn.has_within(&q, 4.9999));
    }

    #[test]
    fn kde_self_point_contributes_one() {
        let mut rng = Rng::new(2);
        let data = pts(&mut rng, 1, 8);
        let q = data[0].clone();
        assert!((exact_kde_angular(&data, &q, 4) - 1.0).abs() < 1e-9);
        assert!((exact_kde_pstable(&data, &q, 2.0, 4) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn kde_bounds() {
        let mut rng = Rng::new(3);
        let data = pts(&mut rng, 64, 8);
        let q: Vec<f32> = (0..8).map(|_| rng.gaussian_f32()).collect();
        for p in [1u32, 2, 8] {
            let a = exact_kde_angular(&data, &q, p);
            let e = exact_kde_pstable(&data, &q, 4.0, p);
            assert!(a >= 0.0 && a <= 64.0);
            assert!(e >= 0.0 && e <= 64.0);
        }
        // Higher p concentrates the kernel: density can only shrink.
        assert!(exact_kde_angular(&data, &q, 8) <= exact_kde_angular(&data, &q, 1) + 1e-9);
    }

    #[test]
    fn empty_index_behaviour() {
        let nn = ExactNn::new(3);
        assert!(nn.is_empty());
        assert_eq!(nn.topk(&[0.0; 3], 5).len(), 0);
        assert_eq!(nn.nn_dist(&[0.0; 3]), f32::INFINITY);
        assert!(!nn.has_within(&[0.0; 3], 1e9));
    }
}
