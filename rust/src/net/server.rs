//! TCP front-end for a running [`SketchService`].
//!
//! One reader thread per connection, each holding a [`ServiceHandle`]
//! clone: inserts stream straight into the per-shard bounded mailboxes
//! (subject to the service's `Overload` policy), and ANN/KDE reads
//! execute ON the connection thread through the handle's `QueryPlane`
//! (native services), so K connections query concurrently. Singleton
//! queries additionally pass through a cross-connection
//! [`QueryCoalescer`]: wire clients that send one query per request get
//! their queries merged into one scatter across the shard set, the same
//! §3.3 batch amortization the ingest path gets from its `Batcher`.
//! Responses are framed by `net::frame`, so a malformed request body
//! costs one `Error` reply and the connection survives.
//!
//! [`SketchService`]: crate::coordinator::SketchService
//! [`ServiceHandle`]: crate::coordinator::ServiceHandle

use std::collections::HashMap;
use std::io::{BufReader, BufWriter};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::time::{Duration, Instant};

use crate::util::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use crate::util::sync::mpsc::{channel, RecvTimeoutError, Sender};
use crate::util::sync::{lock_unpoisoned, Arc, Mutex};

use anyhow::{Context, Result};

use crate::coordinator::{
    AnnAnswer, BatchPolicy, Batcher, CollectionInfo, ServiceHandle, Tenants, DEFAULT_COLLECTION,
};
use crate::metrics::registry::{MetricsSnapshot, Registry};
use crate::obs::log;

use super::frame::{read_frame, write_frame, Request, Response, PROTOCOL_VERSION};

/// Default coalescing policy for singleton wire queries: a batch flushes
/// at 64 pending queries, and `max_wait` CAPS the straggler self-flush
/// deadline — the live deadline is load-aware (see [`LoadAwareWait`]),
/// scaling between 0 when idle and this cap under sustained load.
/// Neither bound is a latency floor — a query with no scatter in flight
/// executes immediately (see [`QueryCoalescer`]).
pub fn default_query_policy() -> BatchPolicy {
    BatchPolicy { max_batch: 64, max_wait: Duration::from_micros(500) }
}

/// Cadence at which a parked query re-checks its lane (deadline expiry,
/// idle fallback) instead of trusting a successor wakeup that might
/// never come. Bounded polling: at most `cap / PARK_POLL` wakeups per
/// parked query.
const PARK_POLL: Duration = Duration::from_micros(100);

/// Load-aware coalescing deadline: scales the straggler self-flush wait
/// between **0 (idle)** and the configured cap (saturated) from two live
/// signals — the number of scatters currently in flight and an EWMA of
/// the recent query arrival rate.
///
/// Rationale: waiting only pays off if other queries arrive DURING the
/// wait (they join the next batch). The expected pickup from waiting a
/// full cap is `rate × cap`; when that is ≥ 1 the wait earns its
/// latency, when it is ~0 waiting is pure loss. Pileup (several scatters
/// already in flight) pushes the deadline to the cap directly — batches
/// should grow when the shard threads are the bottleneck. With nothing
/// in flight the deadline is 0: a parked query self-flushes immediately
/// instead of stranding, preserving the zero-added-latency floor for
/// idle traffic.
///
/// # Memory-ordering contract
///
/// All three atomics are heuristic gauges feeding a *deadline length*,
/// never a correctness decision: a stale read makes a parked query wait
/// a little longer or flush a little earlier, and the park loop's
/// `PARK_POLL` re-check bounds the damage either way. No gauge
/// publishes other memory, so every operation is `Relaxed`.
pub struct LoadAwareWait {
    cap: Duration,
    /// Scatters currently executing (`Relaxed` gauge; pairing of the
    /// increment/decrement is structural — both live in
    /// `CoalescingLane::run_tracked` — and model-checked under loom).
    in_flight: AtomicUsize,
    /// EWMA of the arrival rate (arrivals/sec; f64 bits). `Relaxed`,
    /// and the read-modify-write below is deliberately non-atomic as a
    /// whole: a lost update skews the estimate by one sample.
    rate_bits: AtomicU64,
    /// Nanos since `base` of the most recent arrival. `Relaxed`: feeds
    /// only the EWMA's inter-arrival delta.
    last_arrival_ns: AtomicU64,
    base: Instant,
}

impl LoadAwareWait {
    pub fn new(cap: Duration) -> Self {
        LoadAwareWait {
            cap,
            in_flight: AtomicUsize::new(0),
            rate_bits: AtomicU64::new(0f64.to_bits()),
            last_arrival_ns: AtomicU64::new(0),
            base: Instant::now(),
        }
    }

    /// Record one query arrival (call on every admission).
    pub fn note_arrival(&self) {
        self.arrival_at(self.base.elapsed().as_nanos() as u64);
    }

    fn arrival_at(&self, now_ns: u64) {
        let prev = self.last_arrival_ns.swap(now_ns, Ordering::Relaxed);
        let dt = now_ns.saturating_sub(prev).max(1);
        let inst = 1e9 / dt as f64;
        // EWMA, λ = 1/8: smooth enough to ride out a burst, fast enough
        // to decay back toward idle within a few arrivals. The racy
        // read-modify-write is deliberate — this is a heuristic gauge,
        // not an invariant.
        let old = f64::from_bits(self.rate_bits.load(Ordering::Relaxed));
        let new = old + (inst - old) * 0.125;
        self.rate_bits.store(new.to_bits(), Ordering::Relaxed);
    }

    pub fn scatter_started(&self) {
        self.in_flight.fetch_add(1, Ordering::Relaxed);
    }

    pub fn scatter_finished(&self) {
        self.in_flight.fetch_sub(1, Ordering::Relaxed);
    }

    /// True when no scatter is in flight anywhere — a parked query has
    /// no leader coming back for it.
    pub fn idle(&self) -> bool {
        self.in_flight.load(Ordering::Relaxed) == 0
    }

    /// The deadline THIS moment's load justifies: `cap × factor` with
    /// `factor = clamp(rate × cap + (in_flight − 1), 0, 1)`, and a hard
    /// 0 when nothing is in flight.
    pub fn current(&self) -> Duration {
        let in_flight = self.in_flight.load(Ordering::Relaxed);
        if in_flight == 0 {
            return Duration::ZERO;
        }
        let rate = f64::from_bits(self.rate_bits.load(Ordering::Relaxed));
        let cap_s = self.cap.as_secs_f64();
        let factor = (rate * cap_s + (in_flight as f64 - 1.0)).clamp(0.0, 1.0);
        if factor >= 1.0 {
            return self.cap; // exact at saturation (no float round-trip)
        }
        self.cap.mul_f64(factor)
    }
}

struct PendingAnn {
    q: Vec<f32>,
    reply: Sender<Result<Option<AnnAnswer>, String>>,
}

struct PendingKde {
    q: Vec<f32>,
    reply: Sender<Result<(f64, f64), String>>,
}

/// What a lane decides for an arriving query (decided under the lock,
/// executed outside it).
enum Admission<T> {
    /// Run this batch now (it contains the caller's own entry). `lead`
    /// records whether this thread took the lane's in-flight slot and
    /// must release it afterwards (a size-capped overflow batch runs
    /// concurrently without holding the slot — the plane is concurrent).
    Run { batch: Vec<T>, lead: bool },
    /// A scatter is already in flight; wait — the next leader (or the
    /// deadline fallback) takes the pending set, ours included.
    Wait,
}

/// One coalescing lane: pending queries + whether a scatter led from
/// this lane is currently in flight.
struct Lane<T> {
    pending: Batcher<T>,
    in_flight: bool,
}

impl<T> Lane<T> {
    /// Admit one query. No scatter in flight → lead immediately with
    /// everything pending (zero added latency — coalescing is never a
    /// delay, only a pickup of what accumulated during a scatter). A
    /// full batch runs regardless (bounded batches even under a pileup).
    /// `wait` is the load-scaled straggler deadline for anything that
    /// parks behind an in-flight scatter.
    fn admit(&mut self, item: T, wait: Duration) -> Admission<T> {
        self.pending.set_max_wait(wait);
        if let Some(full) = self.pending.push(item) {
            return Admission::Run { batch: full, lead: false };
        }
        if self.in_flight {
            Admission::Wait
        } else {
            self.in_flight = true;
            Admission::Run { batch: self.pending.flush(), lead: true }
        }
    }
}

/// Cross-connection query coalescing: singleton ANN/KDE queries from
/// independent wire connections share scatters over the shard set.
///
/// Group-commit model (no dedicated flusher thread, no latency floor):
/// a query arriving with NO scatter in flight leads immediately — it
/// takes everything pending (at least itself) and runs the scatter on
/// its own connection thread. Queries arriving WHILE a scatter runs
/// park in the lane; the next arrival after the leader finishes picks
/// them all up, so batch size adapts to scatter duration. A straggler
/// with no successor self-flushes on a **load-aware deadline**
/// ([`LoadAwareWait`]): 0 when the plane goes idle (no leader is coming
/// back — waiting buys nothing), scaling up to `policy.max_wait` under
/// sustained load where waiting demonstrably grows the next batch.
/// Every flush takes the whole pending set, so no query can be
/// stranded.
///
/// Correctness: per-query answers from a coalesced batch are
/// bit-identical to singleton execution (the shard `query_batch` paths
/// are batch/single equivalent, property-tested in
/// `tests/batch_equivalence.rs`), and a degraded scatter (dead shard)
/// errors every query in the batch rather than answering partially.
pub struct QueryCoalescer {
    handle: ServiceHandle,
    core: Arc<CoalescerCore>,
    ann: CoalescingLane<PendingAnn>,
    kde: CoalescingLane<PendingKde>,
}

/// The knobs one coalescer's lanes share: the batch policy and the
/// load gauge every lane's straggler deadline is scaled by (both lanes
/// feed ONE gauge — a KDE scatter in flight is load an ANN straggler
/// should wait out too).
pub struct CoalescerCore {
    policy: BatchPolicy,
    load: LoadAwareWait,
    /// Shared metrics registry; when wired, every flush-initiating
    /// thread records its admission→scatter-start delay into
    /// `stage_coalesce_wait`. `None` keeps the loom model (which drives
    /// the lane protocol with a recording runner) registry-free.
    registry: Option<Arc<Registry>>,
}

impl CoalescerCore {
    pub fn new(policy: BatchPolicy) -> Self {
        CoalescerCore {
            policy,
            load: LoadAwareWait::new(policy.max_wait),
            registry: None,
        }
    }

    /// Wire the shared registry (builder-style; the wire server does
    /// this, tests and models may not).
    pub fn with_registry(mut self, registry: Arc<Registry>) -> Self {
        self.registry = Some(registry);
        self
    }

    /// Live load signals (observability + tests).
    pub fn load(&self) -> &LoadAwareWait {
        &self.load
    }

    /// One sample per flush: the initiating thread's wait between lane
    /// admission and scatter start (parked entries picked up by that
    /// flush waited at most as long as the batch's oldest entry; the
    /// initiator's wait is the recorded proxy).
    fn observe_coalesce_wait(&self, waited: Duration) {
        if let Some(reg) = &self.registry {
            reg.stage_coalesce_wait.record(waited);
        }
    }
}

/// One lane of the coalescer, generic over the pending-query type AND
/// the runner — the ONE admission/wait/self-flush protocol lives here,
/// shared by the ANN and KDE lanes so a change to the coalescing rules
/// can't diverge them, and parametrized so the loom model in
/// `tests/loom_models.rs` can drive the real protocol with a recording
/// runner instead of a full `ServiceHandle`.
pub struct CoalescingLane<T> {
    core: Arc<CoalescerCore>,
    lane: Mutex<Lane<T>>,
}

impl<T> CoalescingLane<T> {
    pub fn new(core: Arc<CoalescerCore>) -> Self {
        CoalescingLane {
            lane: Mutex::new(Lane { pending: Batcher::new(core.policy), in_flight: false }),
            core,
        }
    }

    /// Run one batch with the in-flight scatter gauge held — the gauge
    /// is what scales every parked query's deadline.
    fn run_tracked(&self, batch: Vec<T>, run: &impl Fn(Vec<T>)) {
        self.core.load.scatter_started();
        run(batch);
        self.core.load.scatter_finished();
    }

    /// Admit one query, run or park per the group-commit model, and
    /// block until its reply arrives. `make` builds the pending entry
    /// around the reply sender; `run` executes a batch (every entry's
    /// reply MUST be sent — the module-level runners uphold this on
    /// both the success and error paths).
    pub fn one_shot<R>(
        &self,
        make: impl FnOnce(Sender<Result<R, String>>) -> T,
        run: impl Fn(Vec<T>),
    ) -> Result<R, String> {
        let admitted = Instant::now();
        self.core.load.note_arrival();
        let (tx, rx) = channel();
        let admission = {
            let mut l = lock_unpoisoned(&self.lane);
            // The straggler deadline is pinned at admission from the
            // CURRENT load — under pileup it stretches toward the cap
            // (bigger pickups), when traffic thins it collapses to ~0.
            l.admit(make(tx), self.core.load.current())
        };
        if let Admission::Run { batch, lead } = admission {
            self.core.observe_coalesce_wait(admitted.elapsed());
            self.run_tracked(batch, &run);
            if lead {
                lock_unpoisoned(&self.lane).in_flight = false;
            }
            // Our reply was sent by the runner; fall through to collect it.
        }
        loop {
            match rx.recv_timeout(self.core.policy.max_wait.min(PARK_POLL)) {
                Ok(res) => return res,
                Err(RecvTimeoutError::Timeout) => {
                    // Parked with the deadline expired — or with the
                    // plane gone idle, where no successor will ever
                    // lead: take whatever accumulated (ours included)
                    // ourselves.
                    let due = {
                        let mut l = lock_unpoisoned(&self.lane);
                        if l.pending.deadline_due() || self.core.load.idle() {
                            l.pending.flush()
                        } else {
                            Vec::new()
                        }
                    };
                    if !due.is_empty() {
                        self.core.observe_coalesce_wait(admitted.elapsed());
                        self.run_tracked(due, &run);
                    }
                }
                Err(RecvTimeoutError::Disconnected) => {
                    return Err("query dropped: coalescer batch was lost".into());
                }
            }
        }
    }
}

impl QueryCoalescer {
    pub fn new(handle: ServiceHandle, policy: BatchPolicy) -> Self {
        let core = Arc::new(
            CoalescerCore::new(policy).with_registry(Arc::clone(handle.registry())),
        );
        QueryCoalescer {
            handle,
            ann: CoalescingLane::new(Arc::clone(&core)),
            kde: CoalescingLane::new(Arc::clone(&core)),
            core,
        }
    }

    /// Live load signals (observability + tests).
    pub fn load(&self) -> &LoadAwareWait {
        self.core.load()
    }

    /// One ANN query, possibly answered as part of a coalesced batch.
    pub fn ann_one(&self, q: Vec<f32>) -> Result<Option<AnnAnswer>, String> {
        self.ann
            .one_shot(|reply| PendingAnn { q, reply }, |batch| run_ann(&self.handle, batch))
    }

    /// One KDE query → (kernel sum, density), possibly coalesced.
    pub fn kde_one(&self, q: Vec<f32>) -> Result<(f64, f64), String> {
        self.kde
            .one_shot(|reply| PendingKde { q, reply }, |batch| run_kde(&self.handle, batch))
    }
}

fn run_ann(handle: &ServiceHandle, batch: Vec<PendingAnn>) {
    let (qs, replies): (Vec<_>, Vec<_>) =
        batch.into_iter().map(|p| (p.q, p.reply)).unzip();
    match handle.query_batch(qs) {
        Ok(answers) => {
            for (reply, ans) in replies.into_iter().zip(answers) {
                let _ = reply.send(Ok(ans));
            }
        }
        Err(e) => {
            let msg = e.to_string();
            for reply in replies {
                let _ = reply.send(Err(msg.clone()));
            }
        }
    }
}

fn run_kde(handle: &ServiceHandle, batch: Vec<PendingKde>) {
    let (qs, replies): (Vec<_>, Vec<_>) =
        batch.into_iter().map(|p| (p.q, p.reply)).unzip();
    match handle.kde_batch(qs) {
        Ok((sums, densities)) => {
            for (reply, (s, d)) in
                replies.into_iter().zip(sums.into_iter().zip(densities))
            {
                let _ = reply.send(Ok((s, d)));
            }
        }
        Err(e) => {
            let msg = e.to_string();
            for reply in replies {
                let _ = reply.send(Err(msg.clone()));
            }
        }
    }
}

/// What the wire dispatch resolves a collection id to: the handle to
/// execute against, the id to pass DOWN that handle (nonzero only on a
/// fan-out router, whose member nodes resolve it themselves), the dim
/// to validate vectors against (`None` on a forwarded id — the member
/// owning the collection validates), and the coalescer for singleton
/// queries (absent on forwarded ids: the router cannot coalesce across
/// collections it does not host).
struct Resolved {
    handle: ServiceHandle,
    coll: u32,
    dim: Option<usize>,
    coalescer: Option<Arc<QueryCoalescer>>,
}

/// The serving mode of a [`WireServer`]: one service (possibly a
/// fan-out router) answering only the default collection, or a
/// [`Tenants`] registry answering every named collection.
pub(crate) enum Tenancy {
    Single {
        handle: ServiceHandle,
        coalescer: Arc<QueryCoalescer>,
    },
    Multi {
        tenants: Arc<Tenants>,
        /// Cached default-collection handle (id 0): the Hello shape,
        /// the trace-id mint, and the hot path skip the registry lock.
        default: ServiceHandle,
        /// Lazily-built per-collection coalescers (each wraps that
        /// tenant's own handle, so coalesced singletons stay inside
        /// their tenant). Entries die with their collection.
        coalescers: Mutex<HashMap<u32, Arc<QueryCoalescer>>>,
        policy: BatchPolicy,
    },
}

impl Tenancy {
    fn default_handle(&self) -> &ServiceHandle {
        match self {
            Tenancy::Single { handle, .. } => handle,
            Tenancy::Multi { default, .. } => default,
        }
    }

    /// The registry the wire layer itself observes into (trace ids, op
    /// histograms): the default collection's. Per-tenant point
    /// accounting lives in each tenant's own registry regardless.
    fn registry(&self) -> &Registry {
        self.default_handle().registry()
    }

    fn resolve(&self, coll: u32) -> Result<Resolved, Response> {
        match self {
            Tenancy::Single { handle, coalescer } => {
                if coll == 0 {
                    Ok(Resolved {
                        handle: handle.clone(),
                        coll: 0,
                        dim: Some(handle.dim()),
                        coalescer: Some(Arc::clone(coalescer)),
                    })
                } else if handle.is_fanout() {
                    // A router hosts no collections itself — forward the
                    // id; the member node owning it validates and serves.
                    Ok(Resolved { handle: handle.clone(), coll, dim: None, coalescer: None })
                } else {
                    Err(Response::Error(format!(
                        "unknown collection id {coll}: this server hosts only the default \
                         collection (id 0)"
                    )))
                }
            }
            Tenancy::Multi { tenants, default, coalescers, policy } => {
                let handle = if coll == 0 {
                    default.clone()
                } else {
                    match tenants.resolve(coll) {
                        Some(h) => h,
                        None => {
                            return Err(Response::Error(format!(
                                "unknown collection id {coll}"
                            )))
                        }
                    }
                };
                let coalescer = {
                    let mut m = lock_unpoisoned(coalescers);
                    Arc::clone(m.entry(coll).or_insert_with(|| {
                        Arc::new(QueryCoalescer::new(handle.clone(), *policy))
                    }))
                };
                Ok(Resolved {
                    dim: Some(handle.dim()),
                    handle,
                    coll: 0, // a tenant handle IS its collection
                    coalescer: Some(coalescer),
                })
            }
        }
    }

    /// Drop a dead collection's coalescer (its lanes wrap a handle
    /// whose service just shut down).
    fn forget_coalescer(&self, coll: u32) {
        if let Tenancy::Multi { coalescers, .. } = self {
            lock_unpoisoned(coalescers).remove(&coll);
        }
    }
}

/// A bound listener serving one `SketchService` — or a whole
/// multi-tenant [`Tenants`] registry — over TCP.
pub struct WireServer {
    listener: TcpListener,
    tenancy: Arc<Tenancy>,
    stop: Arc<AtomicBool>,
}

impl WireServer {
    /// Bind `addr` (e.g. `127.0.0.1:0` for an ephemeral port) with the
    /// default singleton-query coalescing policy.
    pub fn bind<A: ToSocketAddrs + std::fmt::Debug>(
        addr: A,
        handle: ServiceHandle,
    ) -> Result<Self> {
        Self::bind_with(addr, handle, default_query_policy())
    }

    /// Bind with an explicit coalescing policy (tests pin small batches
    /// and long deadlines to force coalescing deterministically).
    pub fn bind_with<A: ToSocketAddrs + std::fmt::Debug>(
        addr: A,
        handle: ServiceHandle,
        query_policy: BatchPolicy,
    ) -> Result<Self> {
        let listener =
            TcpListener::bind(&addr).with_context(|| format!("binding {addr:?}"))?;
        let coalescer = Arc::new(QueryCoalescer::new(handle.clone(), query_policy));
        Ok(WireServer {
            listener,
            tenancy: Arc::new(Tenancy::Single { handle, coalescer }),
            stop: Arc::new(AtomicBool::new(false)),
        })
    }

    /// Bind a MULTI-TENANT server: every collection in `tenants` is
    /// addressable by its wire id, v5-shaped frames land on the default
    /// collection, and `CreateCollection`/`DropCollection` mutate the
    /// registry live.
    pub fn bind_tenants<A: ToSocketAddrs + std::fmt::Debug>(
        addr: A,
        tenants: Arc<Tenants>,
    ) -> Result<Self> {
        Self::bind_tenants_with(addr, tenants, default_query_policy())
    }

    /// [`Self::bind_tenants`] with an explicit coalescing policy.
    pub fn bind_tenants_with<A: ToSocketAddrs + std::fmt::Debug>(
        addr: A,
        tenants: Arc<Tenants>,
        query_policy: BatchPolicy,
    ) -> Result<Self> {
        let listener =
            TcpListener::bind(&addr).with_context(|| format!("binding {addr:?}"))?;
        let default = tenants.default_handle();
        Ok(WireServer {
            listener,
            tenancy: Arc::new(Tenancy::Multi {
                tenants,
                default,
                coalescers: Mutex::new(HashMap::new()),
                policy: query_policy,
            }),
            stop: Arc::new(AtomicBool::new(false)),
        })
    }

    /// The actually-bound address (resolves `:0` ephemeral ports).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Accept and serve connections until a client sends `Shutdown`.
    /// Returns cleanly after the shutdown request; the caller still owns
    /// the service lifecycle (`handle.shutdown()` + join).
    pub fn run(self) -> Result<()> {
        let addr = self.local_addr()?;
        let mut conn_id = 0usize;
        for stream in self.listener.incoming() {
            // Acquire pairs with the Release store in `serve_conn`'s
            // shutdown arm (audit: was SeqCst — nothing here needs a
            // total order across unrelated atomics, only to observe the
            // flag and anything the storing thread wrote before it).
            if self.stop.load(Ordering::Acquire) {
                break;
            }
            let stream = match stream {
                Ok(s) => s,
                Err(_) => continue,
            };
            conn_id += 1;
            let tenancy = Arc::clone(&self.tenancy);
            let stop = Arc::clone(&self.stop);
            // Reader threads detach: they exit on peer close, and after
            // shutdown the service-side channels report errors instead of
            // hanging them.
            let _ = std::thread::Builder::new()
                .name(format!("wire-conn-{conn_id}"))
                .spawn(move || {
                    let _ = serve_conn(stream, tenancy, stop, addr, conn_id);
                });
        }
        Ok(())
    }
}

fn serve_conn(
    stream: TcpStream,
    tenancy: Arc<Tenancy>,
    stop: Arc<AtomicBool>,
    server_addr: SocketAddr,
    conn_id: usize,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    let mut buf = Vec::new();
    loop {
        if !read_frame(&mut reader, &mut buf)? {
            return Ok(()); // peer closed
        }
        match Request::decode(&buf) {
            Ok(mut req) => {
                let is_shutdown = matches!(req, Request::Shutdown);
                // Mint a trace id right after decode when the client
                // supplied none; op metadata is captured before dispatch
                // consumes the request.
                let traced = trace_request(&mut req, tenancy.registry());
                let t_op = Instant::now();
                let resp = dispatch(req, &tenancy);
                if let Some((op, batch, trace)) = traced {
                    observe_op(tenancy.registry(), op, batch, trace, conn_id, t_op.elapsed());
                }
                write_frame(&mut writer, &resp.encode())?;
                if is_shutdown {
                    // Release pairs with the Acquire load in `run`'s
                    // accept loop (see the audit note there).
                    stop.store(true, Ordering::Release);
                    // Poke the blocking accept() so run() observes `stop`.
                    // A wildcard bind (0.0.0.0/::) is not connectable on
                    // every platform — poke via the matching loopback.
                    let mut poke = server_addr;
                    if poke.ip().is_unspecified() {
                        poke.set_ip(match poke.ip() {
                            std::net::IpAddr::V4(_) => {
                                std::net::IpAddr::V4(std::net::Ipv4Addr::LOCALHOST)
                            }
                            std::net::IpAddr::V6(_) => {
                                std::net::IpAddr::V6(std::net::Ipv6Addr::LOCALHOST)
                            }
                        });
                    }
                    let _ = TcpStream::connect(poke);
                    return Ok(());
                }
            }
            // Framing stays aligned (length prefix), so a bad body is an
            // application-level error, not a connection error.
            Err(e) => {
                let resp = Response::Error(format!("bad request: {e}"));
                write_frame(&mut writer, &resp.encode())?;
            }
        }
    }
}

/// Validate remote vectors: right dimension, finite coordinates. A NaN
/// slipped into the pool would be unanswerable AND undeletable (NaN
/// compares unequal to itself), i.e. unreclaimable memory from untrusted
/// input — reject it at the edge. `dim` is the RESOLVED collection's
/// dimensionality; `None` (a router forwarding a collection it doesn't
/// host) skips the dim check — the owning member enforces it.
fn check_vectors(dim: Option<usize>, vs: &[Vec<f32>]) -> Result<(), Response> {
    for v in vs {
        if let Some(dim) = dim {
            if v.len() != dim {
                return Err(Response::Error(format!(
                    "vector of dim {} against a dim-{dim} collection",
                    v.len()
                )));
            }
        }
        if !v.iter().all(|x| x.is_finite()) {
            return Err(Response::Error(
                "vector has non-finite coordinates".to_string(),
            ));
        }
    }
    Ok(())
}

/// Take the query out of a singleton batch (the coalesced path), `None`
/// for real batches — which scatter directly from the connection thread.
fn single_query(qs: &mut Vec<Vec<f32>>) -> Option<Vec<f32>> {
    if qs.len() == 1 {
        qs.pop()
    } else {
        None
    }
}

/// Pre-dispatch observability for the ops that carry a latency
/// histogram: returns `(op name, batch size, trace id)` and mints a
/// server-side trace id for traced queries that arrived without one
/// (`trace == 0` on the wire means "server assigns").
fn trace_request(req: &mut Request, registry: &Registry) -> Option<(&'static str, usize, u64)> {
    match req {
        Request::Insert { .. } => Some(("insert", 1, 0)),
        Request::InsertBatch { xs, .. } => Some(("insert", xs.len(), 0)),
        Request::AnnQuery { queries, trace, .. } => {
            if *trace == 0 {
                *trace = registry.trace_ids.next();
            }
            Some(("ann", queries.len(), *trace))
        }
        Request::KdeQuery { queries, trace, .. } => {
            if *trace == 0 {
                *trace = registry.trace_ids.next();
            }
            Some(("kde", queries.len(), *trace))
        }
        Request::AnnPartial { queries, trace, .. } => {
            if *trace == 0 {
                *trace = registry.trace_ids.next();
            }
            Some(("ann_partial", queries.len(), *trace))
        }
        Request::KdePartial { queries, trace, .. } => {
            if *trace == 0 {
                *trace = registry.trace_ids.next();
            }
            Some(("kde_partial", queries.len(), *trace))
        }
        Request::Checkpoint { .. } => Some(("checkpoint", 0, 0)),
        _ => None,
    }
}

/// Post-dispatch observability: record the op's wall time into its
/// dispatch-layer histogram (so p50/p99 no longer depend on any client's
/// recorder) and emit the slow-query log line when a threshold is set
/// (`--slow-query-ms`, carried as the `slow_query_us` registry gauge).
fn observe_op(
    registry: &Registry,
    op: &'static str,
    batch: usize,
    trace: u64,
    conn_id: usize,
    elapsed: Duration,
) {
    let histo = match op {
        "insert" => &registry.op_insert,
        // Partial ops are the same read path minus the merge; they share
        // the query histograms so a routed node's p99 stays comparable.
        "ann" | "ann_partial" => &registry.op_ann,
        "kde" | "kde_partial" => &registry.op_kde,
        _ => &registry.op_checkpoint,
    };
    histo.record(elapsed);
    let threshold_us = registry.slow_query_us.get();
    let us = elapsed.as_micros() as u64;
    if threshold_us > 0 && us >= threshold_us {
        log::warn(
            "net::server",
            "slow query",
            crate::kv!(op = op, trace = trace, conn = conn_id, batch = batch, us = us),
        );
    }
}

fn dispatch(req: Request, tenancy: &Tenancy) -> Response {
    match req {
        Request::Hello => {
            let handle = tenancy.default_handle();
            Response::Hello {
                version: PROTOCOL_VERSION,
                dim: handle.dim() as u32,
                shards: handle.shards() as u32,
                replicas: handle.replicas() as u32,
                health: handle.health_worst() as u8,
                shard_base: handle.shard_base() as u64,
            }
        }
        Request::Insert { coll, x } => {
            let r = match tenancy.resolve(coll) {
                Ok(r) => r,
                Err(resp) => return resp,
            };
            if let Err(resp) = check_vectors(r.dim, std::slice::from_ref(&x)) {
                return resp;
            }
            Response::Ack { accepted: u64::from(r.handle.insert_in(r.coll, x)) }
        }
        Request::InsertBatch { coll, xs } => {
            let r = match tenancy.resolve(coll) {
                Ok(r) => r,
                Err(resp) => return resp,
            };
            if let Err(resp) = check_vectors(r.dim, &xs) {
                return resp;
            }
            Response::Ack { accepted: r.handle.insert_batch_in(r.coll, xs) as u64 }
        }
        Request::Delete { coll, x } => {
            let r = match tenancy.resolve(coll) {
                Ok(r) => r,
                Err(resp) => return resp,
            };
            if let Err(resp) = check_vectors(r.dim, std::slice::from_ref(&x)) {
                return resp;
            }
            Response::Deleted { removed: r.handle.delete_in(r.coll, x) }
        }
        Request::AnnQuery { coll, queries: mut qs, trace } => {
            let r = match tenancy.resolve(coll) {
                Ok(r) => r,
                Err(resp) => return resp,
            };
            if let Err(resp) = check_vectors(r.dim, &qs) {
                return resp;
            }
            // Singletons coalesce across connections (within their
            // collection); real batches are already amortized and
            // scatter directly from this thread, carrying the wire
            // trace id into the stage histograms.
            match (single_query(&mut qs), &r.coalescer) {
                (Some(q), Some(co)) => match co.ann_one(q) {
                    Ok(ans) => Response::AnnAnswers(vec![ans]),
                    Err(e) => Response::Error(e),
                },
                (single, _) => {
                    if let Some(q) = single {
                        qs.push(q); // forwarded singleton: no coalescer
                    }
                    match r.handle.query_batch_traced_in(r.coll, qs, trace) {
                        Ok(answers) => Response::AnnAnswers(answers),
                        Err(e) => Response::Error(e.to_string()),
                    }
                }
            }
        }
        Request::KdeQuery { coll, queries: mut qs, trace } => {
            let r = match tenancy.resolve(coll) {
                Ok(r) => r,
                Err(resp) => return resp,
            };
            if let Err(resp) = check_vectors(r.dim, &qs) {
                return resp;
            }
            match (single_query(&mut qs), &r.coalescer) {
                (Some(q), Some(co)) => match co.kde_one(q) {
                    Ok((s, d)) => {
                        Response::KdeAnswers { sums: vec![s], densities: vec![d] }
                    }
                    Err(e) => Response::Error(e),
                },
                (single, _) => {
                    if let Some(q) = single {
                        qs.push(q);
                    }
                    match r.handle.kde_batch_traced_in(r.coll, qs, trace) {
                        Ok((sums, densities)) => Response::KdeAnswers { sums, densities },
                        Err(e) => Response::Error(e.to_string()),
                    }
                }
            }
        }
        Request::AnnPartial { coll, queries: qs, trace } => {
            let r = match tenancy.resolve(coll) {
                Ok(r) => r,
                Err(resp) => return resp,
            };
            if let Err(resp) = check_vectors(r.dim, &qs) {
                return resp;
            }
            // Partials never coalesce: a front-end already batches, and
            // the reply must carry THIS request's shards only.
            match r.handle.ann_partials(r.coll, qs, trace) {
                Ok(parts) => Response::AnnPartials(parts),
                Err(e) => Response::Error(e.to_string()),
            }
        }
        Request::KdePartial { coll, queries: qs, trace } => {
            let r = match tenancy.resolve(coll) {
                Ok(r) => r,
                Err(resp) => return resp,
            };
            if let Err(resp) = check_vectors(r.dim, &qs) {
                return resp;
            }
            match r.handle.kde_partials(r.coll, qs, trace) {
                Ok(parts) => Response::KdePartials(parts),
                Err(e) => Response::Error(e.to_string()),
            }
        }
        Request::Stats { coll } => {
            let r = match tenancy.resolve(coll) {
                Ok(r) => r,
                Err(resp) => return resp,
            };
            match r.handle.stats_in(r.coll) {
                Ok(st) => Response::Stats(st),
                Err(e) => Response::Error(e.to_string()),
            }
        }
        Request::Metrics => Response::Metrics(full_snapshot(tenancy)),
        Request::Flush { coll } => {
            let r = match tenancy.resolve(coll) {
                Ok(r) => r,
                Err(resp) => return resp,
            };
            match r.handle.flush_in(r.coll) {
                Ok(()) => Response::Ack { accepted: 0 },
                Err(e) => Response::Error(e.to_string()),
            }
        }
        Request::Checkpoint { coll } => {
            let r = match tenancy.resolve(coll) {
                Ok(r) => r,
                Err(resp) => return resp,
            };
            match r.handle.checkpoint_in(r.coll) {
                Ok(points) => Response::Checkpointed { points },
                Err(e) => Response::Error(e.to_string()),
            }
        }
        Request::CreateCollection { name, spec } => match tenancy {
            Tenancy::Multi { tenants, .. } => match tenants.create(&name, &spec) {
                Ok(info) => Response::Collections(vec![info]),
                Err(e) => Response::Error(e.to_string()),
            },
            Tenancy::Single { handle, .. } if handle.is_fanout() => {
                match handle.create_collection_fanout(&name, &spec) {
                    Ok(info) => Response::Collections(vec![info]),
                    Err(e) => Response::Error(e.to_string()),
                }
            }
            Tenancy::Single { .. } => Response::Error(
                "this server hosts a single collection (started without a tenant registry)"
                    .to_string(),
            ),
        },
        Request::DropCollection { name } => match tenancy {
            Tenancy::Multi { tenants, .. } => {
                let id = tenants.resolve_name(&name).map(|(id, _)| id);
                match tenants.drop_collection(&name) {
                    Ok(()) => {
                        if let Some(id) = id {
                            tenancy.forget_coalescer(id);
                        }
                        Response::Ack { accepted: 0 }
                    }
                    Err(e) => Response::Error(e.to_string()),
                }
            }
            Tenancy::Single { handle, .. } if handle.is_fanout() => {
                match handle.drop_collection_fanout(&name) {
                    Ok(()) => Response::Ack { accepted: 0 },
                    Err(e) => Response::Error(e.to_string()),
                }
            }
            Tenancy::Single { .. } => Response::Error(
                "this server hosts a single collection (started without a tenant registry)"
                    .to_string(),
            ),
        },
        Request::ListCollections => match tenancy {
            Tenancy::Multi { tenants, .. } => Response::Collections(tenants.list()),
            Tenancy::Single { handle, .. } if handle.is_fanout() => {
                match handle.list_collections_fanout() {
                    Ok(cols) => Response::Collections(cols),
                    Err(e) => Response::Error(e.to_string()),
                }
            }
            Tenancy::Single { handle, .. } => {
                // One implicit collection: the default. Listing it keeps
                // `client.collection("default")` working everywhere.
                Response::Collections(vec![CollectionInfo {
                    id: 0,
                    name: DEFAULT_COLLECTION.to_string(),
                    dim: handle.dim() as u32,
                    shards: handle.shards() as u32,
                    replicas: handle.replicas() as u32,
                }])
            }
        },
        Request::Shutdown => Response::Ack { accepted: 0 },
    }
}

/// The full metrics exposition: the default collection's registry
/// unprefixed (exactly the single-tenant shape), every named tenant's
/// registry folded in under a `<name>_` prefix. Each tenant's shard
/// stats are drained first so sketch gauges are live; a failed drain
/// (tenant shutting down) still yields its counters.
fn full_snapshot(tenancy: &Tenancy) -> MetricsSnapshot {
    match tenancy {
        Tenancy::Single { handle, .. } => snapshot_of(handle, None),
        Tenancy::Multi { tenants, default, .. } => snapshot_of(default, Some(tenants)),
    }
}

fn snapshot_of(default: &ServiceHandle, tenants: Option<&Tenants>) -> MetricsSnapshot {
    let _ = default.stats();
    let mut snap = default.registry().snapshot();
    if let Some(tenants) = tenants {
        for info in tenants.list() {
            if info.id == 0 {
                continue;
            }
            if let Some(h) = tenants.resolve(info.id) {
                let _ = h.stats();
                snap.merge(h.registry().snapshot().prefixed(&info.name));
            }
        }
    }
    snap
}

/// A plaintext telemetry plane: binds its own port and answers every
/// connection with one Prometheus text-exposition snapshot (HTTP/1.0,
/// `Connection: close`), reusing the same thread-per-connection shape as
/// [`WireServer`]. Scrapers (curl, Prometheus) point at it directly; the
/// binary protocol's `Metrics` op serves the same snapshot to sketchd
/// clients.
pub struct MetricsListener {
    listener: TcpListener,
    source: ScrapeSource,
}

/// What a scrape reads: one service's registry, or a whole tenant
/// registry (default unprefixed + every named collection `<name>_…`).
enum ScrapeSource {
    Single(ServiceHandle),
    Tenants(Arc<Tenants>),
}

impl ScrapeSource {
    fn snapshot(&self) -> MetricsSnapshot {
        match self {
            ScrapeSource::Single(handle) => snapshot_of(handle, None),
            ScrapeSource::Tenants(t) => snapshot_of(&t.default_handle(), Some(t)),
        }
    }
}

impl MetricsListener {
    pub fn bind<A: ToSocketAddrs + std::fmt::Debug>(
        addr: A,
        handle: ServiceHandle,
    ) -> Result<Self> {
        let listener = TcpListener::bind(&addr)
            .with_context(|| format!("binding metrics listener {addr:?}"))?;
        Ok(MetricsListener { listener, source: ScrapeSource::Single(handle) })
    }

    /// Bind a scrape endpoint over a multi-tenant registry.
    pub fn bind_tenants<A: ToSocketAddrs + std::fmt::Debug>(
        addr: A,
        tenants: Arc<Tenants>,
    ) -> Result<Self> {
        let listener = TcpListener::bind(&addr)
            .with_context(|| format!("binding metrics listener {addr:?}"))?;
        Ok(MetricsListener { listener, source: ScrapeSource::Tenants(tenants) })
    }

    /// The actually-bound address (resolves `:0` ephemeral ports).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Accept and answer scrapes until the process exits. Runs on its
    /// own (detached) thread: each scrape drains shard stats through the
    /// service handle, so a hung service degrades scrapes to the last
    /// refreshed gauges instead of blocking the accept loop.
    pub fn run(self) {
        let mut scrape_id = 0usize;
        let source = Arc::new(self.source);
        for stream in self.listener.incoming() {
            let stream = match stream {
                Ok(s) => s,
                Err(_) => continue,
            };
            scrape_id += 1;
            let source = Arc::clone(&source);
            let _ = std::thread::Builder::new()
                .name(format!("metrics-scrape-{scrape_id}"))
                .spawn(move || {
                    let _ = serve_scrape(stream, &source);
                });
        }
    }
}

/// Answer one scrape connection: consume the request head (tolerating
/// both bare-TCP probes and HTTP GETs), refresh the sketch gauges, and
/// write the snapshot as an HTTP/1.0 response.
fn serve_scrape(stream: TcpStream, source: &ScrapeSource) -> std::io::Result<()> {
    use std::io::{BufRead, Write};
    stream.set_read_timeout(Some(Duration::from_millis(500))).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    // Drain header lines until the blank separator, EOF, or timeout —
    // bounded so a hostile peer cannot feed an endless head.
    let mut line = String::new();
    for _ in 0..64 {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) => break,
            Ok(_) if line == "\r\n" || line == "\n" => break,
            Ok(_) => continue,
            Err(_) => break, // timeout or reset: answer anyway
        }
    }
    // `snapshot()` refreshes each tenant's gauges; best-effort by design.
    let body = source.snapshot().to_prometheus();
    let mut writer = BufWriter::new(stream);
    write!(
        writer,
        "HTTP/1.0 200 OK\r\nContent-Type: text/plain; version=0.0.4\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    )?;
    writer.write_all(body.as_bytes())?;
    writer.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    const CAP: Duration = Duration::from_micros(500);

    #[test]
    fn idle_plane_has_zero_deadline() {
        let w = LoadAwareWait::new(CAP);
        assert_eq!(w.current(), Duration::ZERO, "no scatter in flight");
        // Even a hot arrival rate must not create a wait while idle:
        // the leader path runs immediately, waiting would be pure loss.
        for i in 1..100u64 {
            w.arrival_at(i * 1_000); // 1µs apart = 1M arrivals/s
        }
        assert_eq!(w.current(), Duration::ZERO);
        assert!(w.idle());
    }

    #[test]
    fn hot_arrivals_with_a_scatter_in_flight_reach_the_cap() {
        let w = LoadAwareWait::new(CAP);
        for i in 1..100u64 {
            w.arrival_at(i * 1_000); // 1M/s: rate × cap = 500 ≫ 1
        }
        w.scatter_started();
        assert_eq!(w.current(), CAP, "saturated load earns the full wait");
        w.scatter_finished();
        assert_eq!(w.current(), Duration::ZERO);
    }

    #[test]
    fn sparse_arrivals_earn_only_a_sliver_of_the_cap() {
        let w = LoadAwareWait::new(CAP);
        for i in 1..100u64 {
            w.arrival_at(i * 10_000_000); // 10ms apart = 100/s
        }
        w.scatter_started();
        let d = w.current();
        // rate × cap = 100/s × 500µs = 0.05 → ~25µs: waiting longer
        // would almost never pick up a second query.
        assert!(d > Duration::ZERO && d < CAP / 4, "got {d:?}");
        w.scatter_finished();
    }

    #[test]
    fn pileup_alone_forces_the_cap() {
        let w = LoadAwareWait::new(CAP);
        w.scatter_started();
        w.scatter_started(); // 2 in flight, rate ~0
        assert_eq!(w.current(), CAP, "pileup pressure saturates the factor");
        w.scatter_finished();
        w.scatter_finished();
        assert!(w.idle());
    }

    #[test]
    fn rate_ewma_decays_when_traffic_thins() {
        let w = LoadAwareWait::new(CAP);
        for i in 1..200u64 {
            w.arrival_at(i * 1_000); // hot burst
        }
        w.scatter_started();
        assert_eq!(w.current(), CAP);
        // Traffic thins to one arrival per 100ms; the EWMA must decay
        // the deadline well below the cap within a handful of arrivals.
        for i in 1..60u64 {
            w.arrival_at(200_000 + i * 100_000_000);
        }
        let d = w.current();
        assert!(d < CAP / 4, "decayed deadline, got {d:?}");
        w.scatter_finished();
    }
}
