//! TCP front-end for a running [`SketchService`].
//!
//! One reader thread per connection, each holding a [`ServiceHandle`]
//! clone: inserts stream straight into the per-shard bounded mailboxes
//! (subject to the service's `Overload` policy), and ANN/KDE reads
//! execute ON the connection thread through the handle's `QueryPlane`
//! (native services), so K connections query concurrently. Singleton
//! queries additionally pass through a cross-connection
//! [`QueryCoalescer`]: wire clients that send one query per request get
//! their queries merged into one scatter across the shard set, the same
//! §3.3 batch amortization the ingest path gets from its `Batcher`.
//! Responses are framed by `net::frame`, so a malformed request body
//! costs one `Error` reply and the connection survives.
//!
//! [`SketchService`]: crate::coordinator::SketchService
//! [`ServiceHandle`]: crate::coordinator::ServiceHandle

use std::io::{BufReader, BufWriter};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::coordinator::{AnnAnswer, BatchPolicy, Batcher, ServiceHandle};

use super::frame::{read_frame, write_frame, Request, Response, PROTOCOL_VERSION};

/// Default coalescing policy for singleton wire queries: a batch flushes
/// at 64 pending queries, and a straggler whose leader never came back
/// for it self-flushes after 500µs. Neither bound is a latency floor —
/// a query with no scatter in flight executes immediately (see
/// [`QueryCoalescer`]).
pub fn default_query_policy() -> BatchPolicy {
    BatchPolicy { max_batch: 64, max_wait: Duration::from_micros(500) }
}

struct PendingAnn {
    q: Vec<f32>,
    reply: Sender<Result<Option<AnnAnswer>, String>>,
}

struct PendingKde {
    q: Vec<f32>,
    reply: Sender<Result<(f64, f64), String>>,
}

/// What a lane decides for an arriving query (decided under the lock,
/// executed outside it).
enum Admission<T> {
    /// Run this batch now (it contains the caller's own entry). `lead`
    /// records whether this thread took the lane's in-flight slot and
    /// must release it afterwards (a size-capped overflow batch runs
    /// concurrently without holding the slot — the plane is concurrent).
    Run { batch: Vec<T>, lead: bool },
    /// A scatter is already in flight; wait — the next leader (or the
    /// deadline fallback) takes the pending set, ours included.
    Wait,
}

/// One coalescing lane: pending queries + whether a scatter led from
/// this lane is currently in flight.
struct Lane<T> {
    pending: Batcher<T>,
    in_flight: bool,
}

impl<T> Lane<T> {
    /// Admit one query. No scatter in flight → lead immediately with
    /// everything pending (zero added latency — coalescing is never a
    /// delay, only a pickup of what accumulated during a scatter). A
    /// full batch runs regardless (bounded batches even under a pileup).
    fn admit(&mut self, item: T) -> Admission<T> {
        if let Some(full) = self.pending.push(item) {
            return Admission::Run { batch: full, lead: false };
        }
        if self.in_flight {
            Admission::Wait
        } else {
            self.in_flight = true;
            Admission::Run { batch: self.pending.flush(), lead: true }
        }
    }
}

/// Cross-connection query coalescing: singleton ANN/KDE queries from
/// independent wire connections share scatters over the shard set.
///
/// Group-commit model (no dedicated flusher thread, no latency floor):
/// a query arriving with NO scatter in flight leads immediately — it
/// takes everything pending (at least itself) and runs the scatter on
/// its own connection thread. Queries arriving WHILE a scatter runs
/// park in the lane; the next arrival after the leader finishes picks
/// them all up, so batch size adapts to scatter duration. A straggler
/// with no successor self-flushes after `max_wait` — the only case
/// that ever waits. Every flush takes the whole pending set, so no
/// query can be stranded.
///
/// Correctness: per-query answers from a coalesced batch are
/// bit-identical to singleton execution (the shard `query_batch` paths
/// are batch/single equivalent, property-tested in
/// `tests/batch_equivalence.rs`), and a degraded scatter (dead shard)
/// errors every query in the batch rather than answering partially.
pub struct QueryCoalescer {
    handle: ServiceHandle,
    policy: BatchPolicy,
    ann: Mutex<Lane<PendingAnn>>,
    kde: Mutex<Lane<PendingKde>>,
}

impl QueryCoalescer {
    pub fn new(handle: ServiceHandle, policy: BatchPolicy) -> Self {
        QueryCoalescer {
            handle,
            policy,
            ann: Mutex::new(Lane { pending: Batcher::new(policy), in_flight: false }),
            kde: Mutex::new(Lane { pending: Batcher::new(policy), in_flight: false }),
        }
    }

    /// One ANN query, possibly answered as part of a coalesced batch.
    pub fn ann_one(&self, q: Vec<f32>) -> Result<Option<AnnAnswer>, String> {
        self.one_shot(&self.ann, |reply| PendingAnn { q, reply }, Self::run_ann)
    }

    /// One KDE query → (kernel sum, density), possibly coalesced.
    pub fn kde_one(&self, q: Vec<f32>) -> Result<(f64, f64), String> {
        self.one_shot(&self.kde, |reply| PendingKde { q, reply }, Self::run_kde)
    }

    /// The ONE admission/wait/self-flush protocol, shared by both lanes
    /// so a future change to the coalescing rules can't diverge them.
    fn one_shot<T, R>(
        &self,
        lane: &Mutex<Lane<T>>,
        make: impl FnOnce(Sender<Result<R, String>>) -> T,
        run: impl Fn(&Self, Vec<T>),
    ) -> Result<R, String> {
        let (tx, rx) = channel();
        let admission = lane.lock().unwrap().admit(make(tx));
        if let Admission::Run { batch, lead } = admission {
            run(self, batch);
            if lead {
                lane.lock().unwrap().in_flight = false;
            }
            // Our reply was sent by the runner; fall through to collect it.
        }
        loop {
            match rx.recv_timeout(self.policy.max_wait) {
                Ok(res) => return res,
                Err(RecvTimeoutError::Timeout) => {
                    // Parked past the deadline with no successor to lead:
                    // take whatever accumulated (ours included) ourselves.
                    let due = {
                        let mut l = lane.lock().unwrap();
                        if l.pending.deadline_due() {
                            l.pending.flush()
                        } else {
                            Vec::new()
                        }
                    };
                    if !due.is_empty() {
                        run(self, due);
                    }
                }
                Err(RecvTimeoutError::Disconnected) => {
                    return Err("query dropped: coalescer batch was lost".into());
                }
            }
        }
    }

    fn run_ann(&self, batch: Vec<PendingAnn>) {
        let (qs, replies): (Vec<_>, Vec<_>) =
            batch.into_iter().map(|p| (p.q, p.reply)).unzip();
        match self.handle.query_batch(qs) {
            Ok(answers) => {
                for (reply, ans) in replies.into_iter().zip(answers) {
                    let _ = reply.send(Ok(ans));
                }
            }
            Err(e) => {
                let msg = e.to_string();
                for reply in replies {
                    let _ = reply.send(Err(msg.clone()));
                }
            }
        }
    }

    fn run_kde(&self, batch: Vec<PendingKde>) {
        let (qs, replies): (Vec<_>, Vec<_>) =
            batch.into_iter().map(|p| (p.q, p.reply)).unzip();
        match self.handle.kde_batch(qs) {
            Ok((sums, densities)) => {
                for (reply, (s, d)) in
                    replies.into_iter().zip(sums.into_iter().zip(densities))
                {
                    let _ = reply.send(Ok((s, d)));
                }
            }
            Err(e) => {
                let msg = e.to_string();
                for reply in replies {
                    let _ = reply.send(Err(msg.clone()));
                }
            }
        }
    }
}

/// A bound listener serving one `SketchService` over TCP.
pub struct WireServer {
    listener: TcpListener,
    handle: ServiceHandle,
    coalescer: Arc<QueryCoalescer>,
    stop: Arc<AtomicBool>,
}

impl WireServer {
    /// Bind `addr` (e.g. `127.0.0.1:0` for an ephemeral port) with the
    /// default singleton-query coalescing policy.
    pub fn bind<A: ToSocketAddrs + std::fmt::Debug>(
        addr: A,
        handle: ServiceHandle,
    ) -> Result<Self> {
        Self::bind_with(addr, handle, default_query_policy())
    }

    /// Bind with an explicit coalescing policy (tests pin small batches
    /// and long deadlines to force coalescing deterministically).
    pub fn bind_with<A: ToSocketAddrs + std::fmt::Debug>(
        addr: A,
        handle: ServiceHandle,
        query_policy: BatchPolicy,
    ) -> Result<Self> {
        let listener =
            TcpListener::bind(&addr).with_context(|| format!("binding {addr:?}"))?;
        let coalescer = Arc::new(QueryCoalescer::new(handle.clone(), query_policy));
        Ok(WireServer {
            listener,
            handle,
            coalescer,
            stop: Arc::new(AtomicBool::new(false)),
        })
    }

    /// The actually-bound address (resolves `:0` ephemeral ports).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Accept and serve connections until a client sends `Shutdown`.
    /// Returns cleanly after the shutdown request; the caller still owns
    /// the service lifecycle (`handle.shutdown()` + join).
    pub fn run(self) -> Result<()> {
        let addr = self.local_addr()?;
        let mut conn_id = 0usize;
        for stream in self.listener.incoming() {
            if self.stop.load(Ordering::SeqCst) {
                break;
            }
            let stream = match stream {
                Ok(s) => s,
                Err(_) => continue,
            };
            conn_id += 1;
            let handle = self.handle.clone();
            let coalescer = Arc::clone(&self.coalescer);
            let stop = Arc::clone(&self.stop);
            // Reader threads detach: they exit on peer close, and after
            // shutdown the service-side channels report errors instead of
            // hanging them.
            let _ = std::thread::Builder::new()
                .name(format!("wire-conn-{conn_id}"))
                .spawn(move || {
                    let _ = serve_conn(stream, handle, coalescer, stop, addr);
                });
        }
        Ok(())
    }
}

fn serve_conn(
    stream: TcpStream,
    handle: ServiceHandle,
    coalescer: Arc<QueryCoalescer>,
    stop: Arc<AtomicBool>,
    server_addr: SocketAddr,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    let mut buf = Vec::new();
    loop {
        if !read_frame(&mut reader, &mut buf)? {
            return Ok(()); // peer closed
        }
        match Request::decode(&buf) {
            Ok(req) => {
                let is_shutdown = matches!(req, Request::Shutdown);
                let resp = dispatch(req, &handle, &coalescer);
                write_frame(&mut writer, &resp.encode())?;
                if is_shutdown {
                    stop.store(true, Ordering::SeqCst);
                    // Poke the blocking accept() so run() observes `stop`.
                    // A wildcard bind (0.0.0.0/::) is not connectable on
                    // every platform — poke via the matching loopback.
                    let mut poke = server_addr;
                    if poke.ip().is_unspecified() {
                        poke.set_ip(match poke.ip() {
                            std::net::IpAddr::V4(_) => {
                                std::net::IpAddr::V4(std::net::Ipv4Addr::LOCALHOST)
                            }
                            std::net::IpAddr::V6(_) => {
                                std::net::IpAddr::V6(std::net::Ipv6Addr::LOCALHOST)
                            }
                        });
                    }
                    let _ = TcpStream::connect(poke);
                    return Ok(());
                }
            }
            // Framing stays aligned (length prefix), so a bad body is an
            // application-level error, not a connection error.
            Err(e) => {
                let resp = Response::Error(format!("bad request: {e}"));
                write_frame(&mut writer, &resp.encode())?;
            }
        }
    }
}

/// Validate remote vectors: right dimension, finite coordinates. A NaN
/// slipped into the pool would be unanswerable AND undeletable (NaN
/// compares unequal to itself), i.e. unreclaimable memory from untrusted
/// input — reject it at the edge.
fn check_vectors(handle: &ServiceHandle, vs: &[Vec<f32>]) -> Result<(), Response> {
    let dim = handle.dim();
    for v in vs {
        if v.len() != dim {
            return Err(Response::Error(format!(
                "vector of dim {} against a dim-{dim} service",
                v.len()
            )));
        }
        if !v.iter().all(|x| x.is_finite()) {
            return Err(Response::Error(
                "vector has non-finite coordinates".to_string(),
            ));
        }
    }
    Ok(())
}

fn dispatch(req: Request, handle: &ServiceHandle, coalescer: &QueryCoalescer) -> Response {
    match req {
        Request::Hello => Response::Hello {
            version: PROTOCOL_VERSION,
            dim: handle.dim() as u32,
            shards: handle.shards() as u32,
        },
        Request::Insert(x) => {
            if let Err(resp) = check_vectors(handle, std::slice::from_ref(&x)) {
                return resp;
            }
            Response::Ack { accepted: u64::from(handle.insert(x)) }
        }
        Request::InsertBatch(vs) => {
            if let Err(resp) = check_vectors(handle, &vs) {
                return resp;
            }
            Response::Ack { accepted: handle.insert_batch(vs) as u64 }
        }
        Request::Delete(x) => {
            if let Err(resp) = check_vectors(handle, std::slice::from_ref(&x)) {
                return resp;
            }
            Response::Deleted { removed: handle.delete(x) }
        }
        Request::AnnQuery(mut qs) => {
            if let Err(resp) = check_vectors(handle, &qs) {
                return resp;
            }
            // Singletons coalesce across connections; real batches are
            // already amortized and scatter directly from this thread.
            if qs.len() == 1 {
                match coalescer.ann_one(qs.pop().expect("len checked")) {
                    Ok(ans) => Response::AnnAnswers(vec![ans]),
                    Err(e) => Response::Error(e),
                }
            } else {
                match handle.query_batch(qs) {
                    Ok(answers) => Response::AnnAnswers(answers),
                    Err(e) => Response::Error(e.to_string()),
                }
            }
        }
        Request::KdeQuery(mut qs) => {
            if let Err(resp) = check_vectors(handle, &qs) {
                return resp;
            }
            if qs.len() == 1 {
                match coalescer.kde_one(qs.pop().expect("len checked")) {
                    Ok((s, d)) => {
                        Response::KdeAnswers { sums: vec![s], densities: vec![d] }
                    }
                    Err(e) => Response::Error(e),
                }
            } else {
                match handle.kde_batch(qs) {
                    Ok((sums, densities)) => Response::KdeAnswers { sums, densities },
                    Err(e) => Response::Error(e.to_string()),
                }
            }
        }
        Request::Stats => match handle.stats() {
            Ok(st) => Response::Stats(st),
            Err(e) => Response::Error(e.to_string()),
        },
        Request::Flush => match handle.flush() {
            Ok(()) => Response::Ack { accepted: 0 },
            Err(e) => Response::Error(e.to_string()),
        },
        Request::Checkpoint => match handle.checkpoint() {
            Ok(points) => Response::Checkpointed { points },
            Err(e) => Response::Error(e.to_string()),
        },
        Request::Shutdown => Response::Ack { accepted: 0 },
    }
}
