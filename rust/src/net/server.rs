//! TCP front-end for a running [`SketchService`].
//!
//! One reader thread per connection, each holding a [`ServiceHandle`]
//! clone: inserts stream straight into the per-shard bounded mailboxes
//! (subject to the service's `Overload` policy), queries are `force`d to
//! the owning thread and answered in request order. Responses are framed
//! by `net::frame`, so a malformed request body costs one `Error` reply
//! and the connection survives.
//!
//! [`SketchService`]: crate::coordinator::SketchService
//! [`ServiceHandle`]: crate::coordinator::ServiceHandle

use std::io::{BufReader, BufWriter};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::coordinator::ServiceHandle;

use super::frame::{read_frame, write_frame, Request, Response, PROTOCOL_VERSION};

/// A bound listener serving one `SketchService` over TCP.
pub struct WireServer {
    listener: TcpListener,
    handle: ServiceHandle,
    stop: Arc<AtomicBool>,
}

impl WireServer {
    /// Bind `addr` (e.g. `127.0.0.1:0` for an ephemeral port).
    pub fn bind<A: ToSocketAddrs + std::fmt::Debug>(
        addr: A,
        handle: ServiceHandle,
    ) -> Result<Self> {
        let listener =
            TcpListener::bind(&addr).with_context(|| format!("binding {addr:?}"))?;
        Ok(WireServer {
            listener,
            handle,
            stop: Arc::new(AtomicBool::new(false)),
        })
    }

    /// The actually-bound address (resolves `:0` ephemeral ports).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Accept and serve connections until a client sends `Shutdown`.
    /// Returns cleanly after the shutdown request; the caller still owns
    /// the service lifecycle (`handle.shutdown()` + join).
    pub fn run(self) -> Result<()> {
        let addr = self.local_addr()?;
        let mut conn_id = 0usize;
        for stream in self.listener.incoming() {
            if self.stop.load(Ordering::SeqCst) {
                break;
            }
            let stream = match stream {
                Ok(s) => s,
                Err(_) => continue,
            };
            conn_id += 1;
            let handle = self.handle.clone();
            let stop = Arc::clone(&self.stop);
            // Reader threads detach: they exit on peer close, and after
            // shutdown the service-side channels report errors instead of
            // hanging them.
            let _ = std::thread::Builder::new()
                .name(format!("wire-conn-{conn_id}"))
                .spawn(move || {
                    let _ = serve_conn(stream, handle, stop, addr);
                });
        }
        Ok(())
    }
}

fn serve_conn(
    stream: TcpStream,
    handle: ServiceHandle,
    stop: Arc<AtomicBool>,
    server_addr: SocketAddr,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    let mut buf = Vec::new();
    loop {
        if !read_frame(&mut reader, &mut buf)? {
            return Ok(()); // peer closed
        }
        match Request::decode(&buf) {
            Ok(req) => {
                let is_shutdown = matches!(req, Request::Shutdown);
                let resp = dispatch(req, &handle);
                write_frame(&mut writer, &resp.encode())?;
                if is_shutdown {
                    stop.store(true, Ordering::SeqCst);
                    // Poke the blocking accept() so run() observes `stop`.
                    // A wildcard bind (0.0.0.0/::) is not connectable on
                    // every platform — poke via the matching loopback.
                    let mut poke = server_addr;
                    if poke.ip().is_unspecified() {
                        poke.set_ip(match poke.ip() {
                            std::net::IpAddr::V4(_) => {
                                std::net::IpAddr::V4(std::net::Ipv4Addr::LOCALHOST)
                            }
                            std::net::IpAddr::V6(_) => {
                                std::net::IpAddr::V6(std::net::Ipv6Addr::LOCALHOST)
                            }
                        });
                    }
                    let _ = TcpStream::connect(poke);
                    return Ok(());
                }
            }
            // Framing stays aligned (length prefix), so a bad body is an
            // application-level error, not a connection error.
            Err(e) => {
                let resp = Response::Error(format!("bad request: {e}"));
                write_frame(&mut writer, &resp.encode())?;
            }
        }
    }
}

/// Validate remote vectors: right dimension, finite coordinates. A NaN
/// slipped into the pool would be unanswerable AND undeletable (NaN
/// compares unequal to itself), i.e. unreclaimable memory from untrusted
/// input — reject it at the edge.
fn check_vectors(handle: &ServiceHandle, vs: &[Vec<f32>]) -> Result<(), Response> {
    let dim = handle.dim();
    for v in vs {
        if v.len() != dim {
            return Err(Response::Error(format!(
                "vector of dim {} against a dim-{dim} service",
                v.len()
            )));
        }
        if !v.iter().all(|x| x.is_finite()) {
            return Err(Response::Error(
                "vector has non-finite coordinates".to_string(),
            ));
        }
    }
    Ok(())
}

fn dispatch(req: Request, handle: &ServiceHandle) -> Response {
    match req {
        Request::Hello => Response::Hello {
            version: PROTOCOL_VERSION,
            dim: handle.dim() as u32,
            shards: handle.shards() as u32,
        },
        Request::Insert(x) => {
            if let Err(resp) = check_vectors(handle, std::slice::from_ref(&x)) {
                return resp;
            }
            Response::Ack { accepted: u64::from(handle.insert(x)) }
        }
        Request::InsertBatch(vs) => {
            if let Err(resp) = check_vectors(handle, &vs) {
                return resp;
            }
            Response::Ack { accepted: handle.insert_batch(vs) as u64 }
        }
        Request::Delete(x) => {
            if let Err(resp) = check_vectors(handle, std::slice::from_ref(&x)) {
                return resp;
            }
            Response::Deleted { removed: handle.delete(x) }
        }
        Request::AnnQuery(qs) => {
            if let Err(resp) = check_vectors(handle, &qs) {
                return resp;
            }
            match handle.query_batch(qs) {
                Ok(answers) => Response::AnnAnswers(answers),
                Err(e) => Response::Error(e.to_string()),
            }
        }
        Request::KdeQuery(qs) => {
            if let Err(resp) = check_vectors(handle, &qs) {
                return resp;
            }
            match handle.kde_batch(qs) {
                Ok((sums, densities)) => Response::KdeAnswers { sums, densities },
                Err(e) => Response::Error(e.to_string()),
            }
        }
        Request::Stats => match handle.stats() {
            Ok(st) => Response::Stats(st),
            Err(e) => Response::Error(e.to_string()),
        },
        Request::Flush => match handle.flush() {
            Ok(()) => Response::Ack { accepted: 0 },
            Err(e) => Response::Error(e.to_string()),
        },
        Request::Checkpoint => match handle.checkpoint() {
            Ok(points) => Response::Checkpointed { points },
            Err(e) => Response::Error(e.to_string()),
        },
        Request::Shutdown => Response::Ack { accepted: 0 },
    }
}
