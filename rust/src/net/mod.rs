//! L4 — the wire-serving layer: a versioned length-prefixed binary
//! protocol (`frame`), a thread-per-connection TCP server feeding the
//! coordinator through its [`ServiceHandle`] seam (`server`), and a
//! blocking client library (`client`) that doubles as the `sketchd
//! client` load generator.
//!
//! The sketches are exactly the kind of state that belongs behind a
//! network endpoint: RACE-style summaries are a few KB–MB for arbitrarily
//! long streams, so one process can absorb a firehose of remote inserts
//! while answering ANN/KDE queries with in-process semantics — the wire
//! encodes float bits verbatim, and the integration tests pin
//! byte-identical answers between a remote client and a local
//! [`SketchService`] fed the same stream.
//!
//! [`ServiceHandle`]: crate::coordinator::ServiceHandle
//! [`SketchService`]: crate::coordinator::SketchService

pub mod client;
pub mod frame;
pub mod server;

pub use client::{ClientOptions, Collection, SketchClient};
pub use frame::{Request, Response, COMPAT_PROTOCOL_VERSION, MAX_FRAME_BYTES, PROTOCOL_VERSION};
pub use server::{LoadAwareWait, MetricsListener, QueryCoalescer, WireServer};
