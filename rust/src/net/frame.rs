//! The sketchd wire protocol: versioned, length-prefixed binary frames.
//!
//! ```text
//! frame   := u32 LE payload length | payload
//! payload := u8 version (5 or 6) | u8 opcode | body
//! ```
//!
//! All integers are little-endian; floats are IEEE-754 bit patterns, so a
//! round trip is bit-exact and a remote query returns answers identical to
//! an in-process call. Vectors are `u32 len | len × f32` (length ≥ 1 —
//! zero-dimensional vectors are rejected); lists are `u32 count | items`.
//! Frames are capped at [`MAX_FRAME_BYTES`], every decoded count is
//! validated against the bytes actually present, and pre-allocations are
//! capped so a hostile length can never reserve more than the data it
//! ships — the decoder runs against untrusted peers.
//!
//! One request frame begets exactly one response frame, in order, per
//! connection; the length prefix keeps the stream aligned even when a
//! request body is rejected, so a malformed body costs an [`Response::Error`]
//! reply, not the connection.

use std::io::{Read, Write};

use anyhow::{bail, Result};

use crate::coordinator::{
    AnnAnswer, CollectionInfo, CollectionSpec, ServiceStats, ShardAnnResult, ShardKdeResult,
};
use crate::metrics::registry::{HistoSnapshot, MetricsSnapshot};

/// Protocol version (first payload byte of every frame). v2 added the
/// replica count to `Hello` and per-replica read depths to `Stats`; v3
/// added durability health to both (worst-shard byte in `Hello`, the
/// per-shard health vector plus `wal_errors`/`refused_writes` in `Stats`);
/// v4 added a client-suppliable u64 trace id to `AnnQuery`/`KdeQuery`
/// (0 = "mint one for me") and the `Metrics` op, whose reply carries a
/// full named-series [`MetricsSnapshot`]; v5 added the scatter/gather
/// ops `AnnPartial`/`KdePartial` (RAW per-shard partials for a
/// multi-node front-end to merge — f64 folds only happen at the
/// merging tier, so a routed answer stays bit-identical to an
/// in-process one) and the node's first global shard (`shard_base`) to
/// `Hello`; v6 added named collections — a u32 collection id LEADS the
/// body of every ingest/query/flush/checkpoint/stats op, plus
/// `CreateCollection`/`DropCollection`/`ListCollections` and their
/// [`Response::Collections`] reply. The decoder still accepts
/// [`COMPAT_PROTOCOL_VERSION`] frames: a v5 body has no collection id,
/// so it decodes as collection 0 (the default collection) and an old
/// client's semantics are preserved byte-for-byte under the old ops.
pub const PROTOCOL_VERSION: u8 = 6;

/// Oldest version the decoder still accepts. v5 frames carry no
/// collection id; every collection-scoped op decodes them as
/// collection 0.
pub const COMPAT_PROTOCOL_VERSION: u8 = 5;

/// Hard cap on one frame's payload (64 MiB).
pub const MAX_FRAME_BYTES: usize = 1 << 26;

/// Cap on any single `Vec::with_capacity` the decoder performs from a
/// claimed count — growth beyond this is paid for by bytes actually
/// decoded, never by the claim alone.
const DECODE_PREALLOC_CAP: usize = 4096;

mod op {
    pub(super) const HELLO: u8 = 1;
    pub(super) const INSERT: u8 = 2;
    pub(super) const INSERT_BATCH: u8 = 3;
    pub(super) const DELETE: u8 = 4;
    pub(super) const ANN_QUERY: u8 = 5;
    pub(super) const KDE_QUERY: u8 = 6;
    pub(super) const STATS: u8 = 7;
    pub(super) const FLUSH: u8 = 8;
    pub(super) const SHUTDOWN: u8 = 9;
    pub(super) const CHECKPOINT: u8 = 10;
    pub(super) const METRICS: u8 = 11;
    pub(super) const ANN_PARTIAL: u8 = 12;
    pub(super) const KDE_PARTIAL: u8 = 13;
    pub(super) const CREATE_COLLECTION: u8 = 14;
    pub(super) const DROP_COLLECTION: u8 = 15;
    pub(super) const LIST_COLLECTIONS: u8 = 16;

    pub(super) const R_HELLO: u8 = 128;
    pub(super) const R_ACK: u8 = 129;
    pub(super) const R_DELETED: u8 = 130;
    pub(super) const R_ANN: u8 = 131;
    pub(super) const R_KDE: u8 = 132;
    pub(super) const R_STATS: u8 = 133;
    pub(super) const R_ERROR: u8 = 134;
    pub(super) const R_CHECKPOINT: u8 = 135;
    pub(super) const R_METRICS: u8 = 136;
    pub(super) const R_ANN_PARTIAL: u8 = 137;
    pub(super) const R_KDE_PARTIAL: u8 = 138;
    pub(super) const R_COLLECTIONS: u8 = 139;
}

/// Client → server frames. Every collection-scoped op carries `coll`,
/// the u32 collection id LEADING its body (v6); a v5 frame has no id
/// byte and decodes as `coll: 0`, the default collection.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    /// Handshake: the reply carries protocol version + service shape
    /// (of the default collection).
    Hello,
    Insert { coll: u32, x: Vec<f32> },
    InsertBatch { coll: u32, xs: Vec<Vec<f32>> },
    Delete { coll: u32, x: Vec<f32> },
    /// `trace` 0 means "server, mint me a trace id"; any other value is
    /// echoed into the server's slow-query log so a client can correlate
    /// its own records with the server's stage timings (v4).
    AnnQuery { coll: u32, queries: Vec<Vec<f32>>, trace: u64 },
    KdeQuery { coll: u32, queries: Vec<Vec<f32>>, trace: u64 },
    /// v5 scatter/gather: answer with RAW per-shard ANN partials (in
    /// global shard order) instead of the merged answer, so a routing
    /// front-end can fold partials from many nodes exactly once. The
    /// trace id propagates across the hop — both tiers log the same id
    /// — and since v6 so does the collection id.
    AnnPartial { coll: u32, queries: Vec<Vec<f32>>, trace: u64 },
    /// v5 scatter/gather: RAW per-shard KDE partials (kernel sums +
    /// window population, no division) — f64 addition is not
    /// associative, so only the merging tier folds.
    KdePartial { coll: u32, queries: Vec<Vec<f32>>, trace: u64 },
    Stats { coll: u32 },
    /// Fetch the full metrics snapshot (every named series, v4). The
    /// snapshot is the default collection's registry; named tenants are
    /// scraped with a name prefix on the HTTP endpoint.
    Metrics,
    Flush { coll: u32 },
    /// Cut a durable checkpoint of ONE collection (WAL + sketch images
    /// — a consistent cut per collection).
    Checkpoint { coll: u32 },
    /// v6: create a named collection with its own config; replies with
    /// a one-entry [`Response::Collections`] carrying the assigned id.
    CreateCollection { name: String, spec: CollectionSpec },
    /// v6: drop a named collection and its `data_dir/<name>/` subtree.
    DropCollection { name: String },
    /// v6: list every live collection (the default one included).
    ListCollections,
    Shutdown,
}

/// Server → client frames.
#[derive(Clone, Debug, PartialEq)]
pub enum Response {
    Hello {
        version: u8,
        dim: u32,
        shards: u32,
        replicas: u32,
        /// Worst shard health at handshake time (`ShardHealth as u8`:
        /// 0 healthy, 1 durability-degraded, 2 read-only) — a client
        /// learns at connect whether writes will be refused.
        health: u8,
        /// First GLOBAL shard this node serves (v5): a routing
        /// front-end orders member nodes by their advertised
        /// contiguous ranges so its partial merge folds in global
        /// shard order. 0 on standalone services.
        shard_base: u64,
    },
    /// Insert/InsertBatch/Flush/Shutdown: points accepted (0 for the
    /// control frames).
    Ack { accepted: u64 },
    Deleted { removed: bool },
    AnnAnswers(Vec<Option<AnnAnswer>>),
    KdeAnswers { sums: Vec<f64>, densities: Vec<f64> },
    /// RAW per-shard ANN partials in this node's global shard order
    /// (v5 reply to `AnnPartial`). Answer shard ids are GLOBAL.
    AnnPartials(Vec<ShardAnnResult>),
    /// RAW per-shard KDE partials (v5 reply to `KdePartial`): kernel
    /// sums as IEEE-754 bit patterns plus each shard's live window
    /// population — bit-exact across the hop.
    KdePartials(Vec<ShardKdeResult>),
    Stats(ServiceStats),
    /// The full named-series snapshot (v4); the text rendering is
    /// [`MetricsSnapshot::to_prometheus`], this frame is the binary one.
    Metrics(MetricsSnapshot),
    /// Checkpoint cut; `points` is how many inserts it covers.
    Checkpointed { points: u64 },
    /// v6 reply to `CreateCollection` (one entry: the new collection)
    /// and `ListCollections` (every live collection, id order).
    Collections(Vec<CollectionInfo>),
    Error(String),
}

/// One field list for [`ServiceStats`] on the wire: the encoder and the
/// decoder are adjacent and share this ordering, so a new stats field
/// cannot silently drift between them (the roundtrip property test then
/// covers it for free).
fn put_stats(out: &mut Vec<u8>, st: &ServiceStats) {
    put_u64(out, st.inserts);
    put_u64(out, st.deletes);
    put_u64(out, st.ann_queries);
    put_u64(out, st.kde_queries);
    put_u64(out, st.shed);
    put_u64(out, st.stored_points as u64);
    put_u64(out, st.sketch_bytes as u64);
    put_u32(out, st.replicas);
    put_u32(out, st.replica_depths.len() as u32);
    for &d in &st.replica_depths {
        put_u32(out, d);
    }
    put_u32(out, st.health.len() as u32);
    out.extend_from_slice(&st.health);
    put_u64(out, st.wal_errors);
    put_u64(out, st.refused_writes);
}

fn read_stats(c: &mut Cursor<'_>) -> Result<ServiceStats> {
    let mut st = ServiceStats {
        inserts: c.u64()?,
        deletes: c.u64()?,
        ann_queries: c.u64()?,
        kde_queries: c.u64()?,
        shed: c.u64()?,
        stored_points: c.u64()? as usize,
        sketch_bytes: c.u64()? as usize,
        replicas: c.u32()?,
        replica_depths: Vec::new(),
        health: Vec::new(),
        wal_errors: 0,
        refused_writes: 0,
    };
    let n = c.count(4)?;
    st.replica_depths.reserve(n.min(DECODE_PREALLOC_CAP));
    for _ in 0..n {
        st.replica_depths.push(c.u32()?);
    }
    let n = c.count(1)?;
    st.health = c.take(n)?.to_vec();
    st.wal_errors = c.u64()?;
    st.refused_writes = c.u64()?;
    Ok(st)
}

/// The one optional-ANN-answer codec (`AnnAnswers` and v5
/// `AnnPartials` share it): u8 tag 0 = none, 1 = `shard | id | dist`.
fn put_ann_opt(out: &mut Vec<u8>, a: &Option<AnnAnswer>) {
    match a {
        None => out.push(0),
        Some(a) => {
            out.push(1);
            put_u32(out, a.shard as u32);
            put_u32(out, a.id);
            out.extend_from_slice(&a.dist.to_le_bytes());
        }
    }
}

fn read_ann_opt(c: &mut Cursor<'_>) -> Result<Option<AnnAnswer>> {
    match c.u8()? {
        0 => Ok(None),
        1 => Ok(Some(AnnAnswer {
            shard: c.u32()? as usize,
            id: c.u32()?,
            dist: c.f32()?,
        })),
        t => bail!("bad ANN answer tag {t}"),
    }
}

/// The one string codec every frame shares (`Error`, metrics series
/// names): u32 length | bytes, length validated against bytes present.
fn put_str(out: &mut Vec<u8>, s: &str) {
    let b = s.as_bytes();
    put_u32(out, b.len() as u32);
    out.extend_from_slice(b);
}

fn read_str(c: &mut Cursor<'_>) -> Result<String> {
    let n = c.count(1)?;
    Ok(String::from_utf8_lossy(c.take(n)?).into_owned())
}

/// [`put_stats`]-style single field list for [`MetricsSnapshot`]: the
/// encoder and decoder are adjacent and share this ordering, so a v4
/// metrics field cannot drift between them. Histogram quantiles travel
/// as IEEE-754 bit patterns (same discipline as KDE answers), so a
/// snapshot round-trips bit-exact.
fn put_histo(out: &mut Vec<u8>, h: &HistoSnapshot) {
    put_u64(out, h.count);
    for x in [h.sum_us, h.p50_us, h.p90_us, h.p99_us, h.max_us] {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn read_histo(c: &mut Cursor<'_>) -> Result<HistoSnapshot> {
    Ok(HistoSnapshot {
        count: c.u64()?,
        sum_us: c.f64()?,
        p50_us: c.f64()?,
        p90_us: c.f64()?,
        p99_us: c.f64()?,
        max_us: c.f64()?,
    })
}

fn put_metrics(out: &mut Vec<u8>, m: &MetricsSnapshot) {
    put_u32(out, m.counters.len() as u32);
    for (name, v) in &m.counters {
        put_str(out, name);
        put_u64(out, *v);
    }
    put_u32(out, m.gauges.len() as u32);
    for (name, v) in &m.gauges {
        put_str(out, name);
        put_u64(out, *v);
    }
    put_u32(out, m.histograms.len() as u32);
    for (name, h) in &m.histograms {
        put_str(out, name);
        put_histo(out, h);
    }
}

fn read_metrics(c: &mut Cursor<'_>) -> Result<MetricsSnapshot> {
    // Min item bytes: name length prefix (4) + u64 value (8) for the
    // scalar series, + 5 f64 quantile fields for histograms.
    let n = c.count(12)?;
    let mut counters = Vec::with_capacity(n.min(DECODE_PREALLOC_CAP));
    for _ in 0..n {
        let name = read_str(c)?;
        counters.push((name, c.u64()?));
    }
    let n = c.count(12)?;
    let mut gauges = Vec::with_capacity(n.min(DECODE_PREALLOC_CAP));
    for _ in 0..n {
        let name = read_str(c)?;
        gauges.push((name, c.u64()?));
    }
    let n = c.count(52)?;
    let mut histograms = Vec::with_capacity(n.min(DECODE_PREALLOC_CAP));
    for _ in 0..n {
        let name = read_str(c)?;
        histograms.push((name, read_histo(c)?));
    }
    Ok(MetricsSnapshot { counters, gauges, histograms })
}

/// [`put_stats`]-style single field list for [`CollectionSpec`] (the
/// `CreateCollection` body after the name): encoder and decoder are
/// adjacent and share the ordering, so a spec field cannot drift.
fn put_spec(out: &mut Vec<u8>, s: &CollectionSpec) {
    put_u32(out, s.dim);
    put_u32(out, s.shards);
    put_u32(out, s.replicas);
    put_u64(out, s.n_max);
    put_u64(out, s.window);
    out.extend_from_slice(&s.eta.to_le_bytes());
    out.push(s.overload);
    put_u64(out, s.seed);
}

fn read_spec(c: &mut Cursor<'_>) -> Result<CollectionSpec> {
    Ok(CollectionSpec {
        dim: c.u32()?,
        shards: c.u32()?,
        replicas: c.u32()?,
        n_max: c.u64()?,
        window: c.u64()?,
        eta: c.f64()?,
        overload: c.u8()?,
        seed: c.u64()?,
    })
}

fn put_collections(out: &mut Vec<u8>, cols: &[CollectionInfo]) {
    put_u32(out, cols.len() as u32);
    for info in cols {
        put_u32(out, info.id);
        put_str(out, &info.name);
        put_u32(out, info.dim);
        put_u32(out, info.shards);
        put_u32(out, info.replicas);
    }
}

fn read_collections(c: &mut Cursor<'_>) -> Result<Vec<CollectionInfo>> {
    // Min item bytes: id + name length prefix + dim + shards + replicas.
    let n = c.count(20)?;
    let mut cols = Vec::with_capacity(n.min(DECODE_PREALLOC_CAP));
    for _ in 0..n {
        cols.push(CollectionInfo {
            id: c.u32()?,
            name: read_str(c)?,
            dim: c.u32()?,
            shards: c.u32()?,
            replicas: c.u32()?,
        });
    }
    Ok(cols)
}

// ---------------------------------------------------------------- encode

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_vec_f32(out: &mut Vec<u8>, v: &[f32]) {
    put_u32(out, v.len() as u32);
    for &x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn put_vecs(out: &mut Vec<u8>, vs: &[Vec<f32>]) {
    put_u32(out, vs.len() as u32);
    for v in vs {
        put_vec_f32(out, v);
    }
}

fn payload(opcode: u8) -> Vec<u8> {
    vec![PROTOCOL_VERSION, opcode]
}

/// v6 collection-scoped payload: the collection id LEADS the body.
fn coll_payload(opcode: u8, coll: u32) -> Vec<u8> {
    let mut out = payload(opcode);
    put_u32(&mut out, coll);
    out
}

fn encode_vec_req(opcode: u8, coll: u32, v: &[f32]) -> Vec<u8> {
    let mut out = coll_payload(opcode, coll);
    put_vec_f32(&mut out, v);
    out
}

fn encode_vecs_req(opcode: u8, coll: u32, vs: &[Vec<f32>]) -> Vec<u8> {
    let mut out = coll_payload(opcode, coll);
    put_vecs(&mut out, vs);
    out
}

fn encode_traced_vecs_req(opcode: u8, coll: u32, vs: &[Vec<f32>], trace: u64) -> Vec<u8> {
    let mut out = coll_payload(opcode, coll);
    put_u64(&mut out, trace);
    put_vecs(&mut out, vs);
    out
}

/// Borrowed request encoders — the client hot path frames payloads
/// without first cloning them into an owned [`Request`]. `coll` is the
/// target collection id (0 = the default collection).
pub fn encode_insert(coll: u32, v: &[f32]) -> Vec<u8> {
    encode_vec_req(op::INSERT, coll, v)
}

pub fn encode_insert_batch(coll: u32, vs: &[Vec<f32>]) -> Vec<u8> {
    encode_vecs_req(op::INSERT_BATCH, coll, vs)
}

pub fn encode_delete(coll: u32, v: &[f32]) -> Vec<u8> {
    encode_vec_req(op::DELETE, coll, v)
}

pub fn encode_ann_query(coll: u32, vs: &[Vec<f32>]) -> Vec<u8> {
    encode_ann_query_traced(coll, vs, 0)
}

/// v4: carry a client-chosen trace id (0 = server mints one).
pub fn encode_ann_query_traced(coll: u32, vs: &[Vec<f32>], trace: u64) -> Vec<u8> {
    encode_traced_vecs_req(op::ANN_QUERY, coll, vs, trace)
}

pub fn encode_kde_query(coll: u32, vs: &[Vec<f32>]) -> Vec<u8> {
    encode_kde_query_traced(coll, vs, 0)
}

pub fn encode_kde_query_traced(coll: u32, vs: &[Vec<f32>], trace: u64) -> Vec<u8> {
    encode_traced_vecs_req(op::KDE_QUERY, coll, vs, trace)
}

/// v5: ask for RAW per-shard ANN partials (a front-end merges them).
pub fn encode_ann_partial(coll: u32, vs: &[Vec<f32>], trace: u64) -> Vec<u8> {
    encode_traced_vecs_req(op::ANN_PARTIAL, coll, vs, trace)
}

/// v5: ask for RAW per-shard KDE partials (sums + population, unfolded).
pub fn encode_kde_partial(coll: u32, vs: &[Vec<f32>], trace: u64) -> Vec<u8> {
    encode_traced_vecs_req(op::KDE_PARTIAL, coll, vs, trace)
}

impl Request {
    pub fn encode(&self) -> Vec<u8> {
        match self {
            Request::Hello => payload(op::HELLO),
            Request::Insert { coll, x } => encode_insert(*coll, x),
            Request::InsertBatch { coll, xs } => encode_insert_batch(*coll, xs),
            Request::Delete { coll, x } => encode_delete(*coll, x),
            Request::AnnQuery { coll, queries, trace } => {
                encode_ann_query_traced(*coll, queries, *trace)
            }
            Request::KdeQuery { coll, queries, trace } => {
                encode_kde_query_traced(*coll, queries, *trace)
            }
            Request::AnnPartial { coll, queries, trace } => {
                encode_ann_partial(*coll, queries, *trace)
            }
            Request::KdePartial { coll, queries, trace } => {
                encode_kde_partial(*coll, queries, *trace)
            }
            Request::Stats { coll } => coll_payload(op::STATS, *coll),
            Request::Metrics => payload(op::METRICS),
            Request::Flush { coll } => coll_payload(op::FLUSH, *coll),
            Request::Checkpoint { coll } => coll_payload(op::CHECKPOINT, *coll),
            Request::CreateCollection { name, spec } => {
                let mut out = payload(op::CREATE_COLLECTION);
                put_str(&mut out, name);
                put_spec(&mut out, spec);
                out
            }
            Request::DropCollection { name } => {
                let mut out = payload(op::DROP_COLLECTION);
                put_str(&mut out, name);
                out
            }
            Request::ListCollections => payload(op::LIST_COLLECTIONS),
            Request::Shutdown => payload(op::SHUTDOWN),
        }
    }

    pub fn decode(bytes: &[u8]) -> Result<Request> {
        let mut c = Cursor::new(bytes)?;
        let opcode = c.u8()?;
        let req = match opcode {
            op::HELLO => Request::Hello,
            op::INSERT => {
                let coll = c.coll()?;
                Request::Insert { coll, x: c.vec_f32()? }
            }
            op::INSERT_BATCH => {
                let coll = c.coll()?;
                Request::InsertBatch { coll, xs: c.vecs()? }
            }
            op::DELETE => {
                let coll = c.coll()?;
                Request::Delete { coll, x: c.vec_f32()? }
            }
            op::ANN_QUERY => {
                let coll = c.coll()?;
                let trace = c.u64()?;
                Request::AnnQuery { coll, queries: c.vecs()?, trace }
            }
            op::KDE_QUERY => {
                let coll = c.coll()?;
                let trace = c.u64()?;
                Request::KdeQuery { coll, queries: c.vecs()?, trace }
            }
            op::ANN_PARTIAL => {
                let coll = c.coll()?;
                let trace = c.u64()?;
                Request::AnnPartial { coll, queries: c.vecs()?, trace }
            }
            op::KDE_PARTIAL => {
                let coll = c.coll()?;
                let trace = c.u64()?;
                Request::KdePartial { coll, queries: c.vecs()?, trace }
            }
            op::STATS => Request::Stats { coll: c.coll()? },
            op::METRICS => Request::Metrics,
            op::FLUSH => Request::Flush { coll: c.coll()? },
            op::CHECKPOINT => Request::Checkpoint { coll: c.coll()? },
            op::CREATE_COLLECTION => {
                c.require_v6("CreateCollection")?;
                let name = read_str(&mut c)?;
                Request::CreateCollection { name, spec: read_spec(&mut c)? }
            }
            op::DROP_COLLECTION => {
                c.require_v6("DropCollection")?;
                Request::DropCollection { name: read_str(&mut c)? }
            }
            op::LIST_COLLECTIONS => {
                c.require_v6("ListCollections")?;
                Request::ListCollections
            }
            op::SHUTDOWN => Request::Shutdown,
            other => bail!("unknown request opcode {other}"),
        };
        c.finish()?;
        Ok(req)
    }
}

impl Response {
    pub fn encode(&self) -> Vec<u8> {
        match self {
            Response::Hello { version, dim, shards, replicas, health, shard_base } => {
                let mut out = payload(op::R_HELLO);
                out.push(*version);
                put_u32(&mut out, *dim);
                put_u32(&mut out, *shards);
                put_u32(&mut out, *replicas);
                out.push(*health);
                put_u64(&mut out, *shard_base);
                out
            }
            Response::Ack { accepted } => {
                let mut out = payload(op::R_ACK);
                put_u64(&mut out, *accepted);
                out
            }
            Response::Deleted { removed } => {
                let mut out = payload(op::R_DELETED);
                out.push(u8::from(*removed));
                out
            }
            Response::AnnAnswers(answers) => {
                let mut out = payload(op::R_ANN);
                put_u32(&mut out, answers.len() as u32);
                for a in answers {
                    put_ann_opt(&mut out, a);
                }
                out
            }
            Response::KdeAnswers { sums, densities } => {
                // One count covers both arrays; they are parallel by
                // construction (kde_batch) — fail at the source, not with
                // a trailing-bytes decode error on the client.
                debug_assert_eq!(sums.len(), densities.len());
                let mut out = payload(op::R_KDE);
                put_u32(&mut out, sums.len() as u32);
                for &s in sums {
                    out.extend_from_slice(&s.to_le_bytes());
                }
                for &d in densities {
                    out.extend_from_slice(&d.to_le_bytes());
                }
                out
            }
            Response::AnnPartials(parts) => {
                let mut out = payload(op::R_ANN_PARTIAL);
                put_u32(&mut out, parts.len() as u32);
                for p in parts {
                    put_u32(&mut out, p.best.len() as u32);
                    for a in &p.best {
                        put_ann_opt(&mut out, a);
                    }
                    put_u64(&mut out, p.scanned as u64);
                }
                out
            }
            Response::KdePartials(parts) => {
                let mut out = payload(op::R_KDE_PARTIAL);
                put_u32(&mut out, parts.len() as u32);
                for p in parts {
                    put_u32(&mut out, p.kernel_sums.len() as u32);
                    for &s in &p.kernel_sums {
                        out.extend_from_slice(&s.to_le_bytes());
                    }
                    put_u64(&mut out, p.population);
                }
                out
            }
            Response::Stats(st) => {
                let mut out = payload(op::R_STATS);
                put_stats(&mut out, st);
                out
            }
            Response::Metrics(m) => {
                let mut out = payload(op::R_METRICS);
                put_metrics(&mut out, m);
                out
            }
            Response::Checkpointed { points } => {
                let mut out = payload(op::R_CHECKPOINT);
                put_u64(&mut out, *points);
                out
            }
            Response::Collections(cols) => {
                let mut out = payload(op::R_COLLECTIONS);
                put_collections(&mut out, cols);
                out
            }
            Response::Error(msg) => {
                let mut out = payload(op::R_ERROR);
                put_str(&mut out, msg);
                out
            }
        }
    }

    pub fn decode(bytes: &[u8]) -> Result<Response> {
        let mut c = Cursor::new(bytes)?;
        let opcode = c.u8()?;
        let resp = match opcode {
            op::R_HELLO => Response::Hello {
                version: c.u8()?,
                dim: c.u32()?,
                shards: c.u32()?,
                replicas: c.u32()?,
                health: c.u8()?,
                shard_base: c.u64()?,
            },
            op::R_ACK => Response::Ack { accepted: c.u64()? },
            op::R_DELETED => Response::Deleted { removed: c.u8()? != 0 },
            op::R_ANN => {
                let n = c.count(1)?;
                let mut answers = Vec::with_capacity(n.min(DECODE_PREALLOC_CAP));
                for _ in 0..n {
                    answers.push(read_ann_opt(&mut c)?);
                }
                Response::AnnAnswers(answers)
            }
            op::R_ANN_PARTIAL => {
                // Min item bytes per shard: u32 answer count + u64 scanned.
                let n = c.count(12)?;
                let mut parts = Vec::with_capacity(n.min(DECODE_PREALLOC_CAP));
                for _ in 0..n {
                    let m = c.count(1)?;
                    let mut best = Vec::with_capacity(m.min(DECODE_PREALLOC_CAP));
                    for _ in 0..m {
                        best.push(read_ann_opt(&mut c)?);
                    }
                    parts.push(ShardAnnResult { best, scanned: c.u64()? as usize });
                }
                Response::AnnPartials(parts)
            }
            op::R_KDE_PARTIAL => {
                let n = c.count(12)?;
                let mut parts = Vec::with_capacity(n.min(DECODE_PREALLOC_CAP));
                for _ in 0..n {
                    let m = c.count(8)?;
                    let mut kernel_sums = Vec::with_capacity(m.min(DECODE_PREALLOC_CAP));
                    for _ in 0..m {
                        kernel_sums.push(c.f64()?);
                    }
                    parts.push(ShardKdeResult { kernel_sums, population: c.u64()? });
                }
                Response::KdePartials(parts)
            }
            op::R_KDE => {
                let n = c.count(16)?;
                let mut sums = Vec::with_capacity(n.min(DECODE_PREALLOC_CAP));
                for _ in 0..n {
                    sums.push(c.f64()?);
                }
                let mut densities = Vec::with_capacity(n.min(DECODE_PREALLOC_CAP));
                for _ in 0..n {
                    densities.push(c.f64()?);
                }
                Response::KdeAnswers { sums, densities }
            }
            op::R_STATS => Response::Stats(read_stats(&mut c)?),
            op::R_METRICS => Response::Metrics(read_metrics(&mut c)?),
            op::R_CHECKPOINT => Response::Checkpointed { points: c.u64()? },
            op::R_COLLECTIONS => Response::Collections(read_collections(&mut c)?),
            op::R_ERROR => Response::Error(read_str(&mut c)?),
            other => bail!("unknown response opcode {other}"),
        };
        c.finish()?;
        Ok(resp)
    }
}

// ---------------------------------------------------------------- decode

/// Bounds-checked reader over one frame payload. Verifies the version
/// byte up front (v5 and v6 both accepted, and which one is recorded so
/// [`Cursor::coll`] knows whether a collection id is present) and — via
/// [`Cursor::count`] — that any decoded count fits in the bytes that
/// are actually present, so a hostile length can never drive a large
/// allocation.
struct Cursor<'a> {
    b: &'a [u8],
    i: usize,
    version: u8,
}

impl<'a> Cursor<'a> {
    fn new(b: &'a [u8]) -> Result<Self> {
        let mut c = Cursor { b, i: 0, version: PROTOCOL_VERSION };
        let v = c.u8()?;
        if v != PROTOCOL_VERSION && v != COMPAT_PROTOCOL_VERSION {
            bail!(
                "protocol version {v} (this build speaks {PROTOCOL_VERSION}, \
                 compat down to {COMPAT_PROTOCOL_VERSION})"
            );
        }
        c.version = v;
        Ok(c)
    }

    /// The collection id leading a collection-scoped body: a u32 on v6
    /// frames, absent on v5 frames — which therefore address collection
    /// 0, preserving an old client's semantics byte-for-byte.
    fn coll(&mut self) -> Result<u32> {
        if self.version >= PROTOCOL_VERSION {
            self.u32()
        } else {
            Ok(0)
        }
    }

    /// Ops that did not exist before v6 reject v5 frames outright —
    /// there is no v5 shape to be compatible with.
    fn require_v6(&self, what: &str) -> Result<()> {
        if self.version < PROTOCOL_VERSION {
            bail!("{what} requires protocol v{PROTOCOL_VERSION} (frame is v{})", self.version);
        }
        Ok(())
    }

    fn remaining(&self) -> usize {
        self.b.len() - self.i
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if n > self.remaining() {
            bail!("frame truncated at byte {} (need {n} more)", self.i);
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// A count whose items occupy at least `min_item_bytes` each: rejected
    /// unless that many bytes are actually present.
    fn count(&mut self, min_item_bytes: usize) -> Result<usize> {
        let n = self.u32()? as usize;
        if n.saturating_mul(min_item_bytes) > self.remaining() {
            bail!(
                "count {n} (x{min_item_bytes}B) exceeds the {} bytes present",
                self.remaining()
            );
        }
        Ok(n)
    }

    fn vec_f32(&mut self) -> Result<Vec<f32>> {
        let n = self.count(4)?;
        if n == 0 {
            // No service accepts dim-0 vectors, and rejecting them bounds
            // list amplification: every list item costs ≥ 8 wire bytes.
            bail!("zero-dimensional vector");
        }
        let raw = self.take(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn vecs(&mut self) -> Result<Vec<Vec<f32>>> {
        let n = self.count(8)?;
        let mut out = Vec::with_capacity(n.min(DECODE_PREALLOC_CAP));
        for _ in 0..n {
            out.push(self.vec_f32()?);
        }
        Ok(out)
    }

    fn finish(&self) -> Result<()> {
        if self.remaining() != 0 {
            bail!("frame has {} trailing bytes", self.remaining());
        }
        Ok(())
    }
}

// ---------------------------------------------------------------- framing

/// Write one frame (length prefix + payload) and flush.
pub fn write_frame<W: Write>(w: &mut W, payload: &[u8]) -> Result<()> {
    if payload.len() > MAX_FRAME_BYTES {
        bail!("frame of {} bytes exceeds cap {MAX_FRAME_BYTES}", payload.len());
    }
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

/// Read one frame's payload into `buf`. Returns `Ok(false)` on a clean
/// EOF at a frame boundary (peer closed), `Err` on oversized/short frames.
pub fn read_frame<R: Read>(r: &mut R, buf: &mut Vec<u8>) -> Result<bool> {
    let mut lenb = [0u8; 4];
    if let Err(e) = r.read_exact(&mut lenb) {
        if e.kind() == std::io::ErrorKind::UnexpectedEof {
            return Ok(false);
        }
        return Err(e.into());
    }
    let len = u32::from_le_bytes(lenb) as usize;
    if len == 0 {
        bail!("empty frame");
    }
    if len > MAX_FRAME_BYTES {
        bail!("frame of {len} bytes exceeds cap {MAX_FRAME_BYTES}");
    }
    buf.clear();
    buf.resize(len, 0);
    r.read_exact(buf)?;
    Ok(true)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, Gen};

    fn gen_vec(g: &mut Gen, dim: usize) -> Vec<f32> {
        g.vector(dim, 2.0)
    }

    fn gen_vecs(g: &mut Gen) -> Vec<Vec<f32>> {
        let dim = g.usize_in(1, 16);
        (0..g.size(0, 20)).map(|_| gen_vec(g, dim)).collect()
    }

    fn gen_coll(g: &mut Gen) -> u32 {
        g.usize_in(0, 1 << 16) as u32
    }

    fn gen_spec(g: &mut Gen) -> CollectionSpec {
        CollectionSpec {
            dim: g.usize_in(1, 1024) as u32,
            shards: g.usize_in(1, 16) as u32,
            replicas: g.usize_in(1, 4) as u32,
            n_max: g.usize_in(1, 1 << 20) as u64,
            window: g.usize_in(1, 1 << 20) as u64,
            eta: g.f64_in(0.0, 1.0),
            overload: g.usize_in(0, 1) as u8,
            seed: g.usize_in(0, 1 << 40) as u64,
        }
    }

    fn gen_request(g: &mut Gen) -> Request {
        let pick = g.usize_in(0, 15);
        let dim = g.usize_in(1, 64);
        match pick {
            0 => Request::Hello,
            1 => Request::Insert { coll: gen_coll(g), x: gen_vec(g, dim) },
            2 => Request::InsertBatch { coll: gen_coll(g), xs: gen_vecs(g) },
            3 => Request::Delete { coll: gen_coll(g), x: gen_vec(g, dim) },
            4 => Request::AnnQuery {
                coll: gen_coll(g),
                queries: gen_vecs(g),
                trace: g.usize_in(0, 1 << 40) as u64,
            },
            5 => Request::KdeQuery {
                coll: gen_coll(g),
                queries: gen_vecs(g),
                trace: g.usize_in(0, 1 << 40) as u64,
            },
            6 => Request::Stats { coll: gen_coll(g) },
            7 => Request::Flush { coll: gen_coll(g) },
            8 => Request::Checkpoint { coll: gen_coll(g) },
            9 => Request::Metrics,
            10 => Request::AnnPartial {
                coll: gen_coll(g),
                queries: gen_vecs(g),
                trace: g.usize_in(0, 1 << 40) as u64,
            },
            11 => Request::KdePartial {
                coll: gen_coll(g),
                queries: gen_vecs(g),
                trace: g.usize_in(0, 1 << 40) as u64,
            },
            12 => Request::CreateCollection {
                name: format!("coll-{}", g.usize_in(0, 999)),
                spec: gen_spec(g),
            },
            13 => Request::DropCollection { name: format!("coll-{}", g.usize_in(0, 999)) },
            14 => Request::ListCollections,
            _ => Request::Shutdown,
        }
    }

    fn gen_ann_partial(g: &mut Gen) -> ShardAnnResult {
        ShardAnnResult {
            best: (0..g.size(0, 12))
                .map(|_| {
                    if g.bool() {
                        Some(crate::coordinator::AnnAnswer {
                            shard: g.usize_in(0, 63),
                            id: g.usize_in(0, 1 << 20) as u32,
                            dist: g.f64_in(0.0, 100.0) as f32,
                        })
                    } else {
                        None
                    }
                })
                .collect(),
            scanned: g.usize_in(0, 1 << 20),
        }
    }

    fn gen_metrics(g: &mut Gen) -> MetricsSnapshot {
        let series = |g: &mut Gen, prefix: &str, max: usize| -> Vec<(String, u64)> {
            (0..g.size(0, max))
                .map(|i| (format!("{prefix}_{i}"), g.usize_in(0, 1 << 40) as u64))
                .collect()
        };
        let counters = series(g, "ctr", 8);
        let gauges = series(g, "gauge", 8);
        let histograms = (0..g.size(0, 6))
            .map(|i| {
                (
                    format!("histo_{i}"),
                    HistoSnapshot {
                        count: g.usize_in(0, 1 << 30) as u64,
                        sum_us: g.f64_in(0.0, 1e12),
                        p50_us: g.f64_in(0.0, 1e6),
                        p90_us: g.f64_in(0.0, 1e6),
                        p99_us: g.f64_in(0.0, 1e6),
                        max_us: g.f64_in(0.0, 1e6),
                    },
                )
            })
            .collect();
        MetricsSnapshot { counters, gauges, histograms }
    }

    fn gen_response(g: &mut Gen) -> Response {
        match g.usize_in(0, 11) {
            0 => Response::Hello {
                version: PROTOCOL_VERSION,
                dim: g.usize_in(1, 1024) as u32,
                shards: g.usize_in(1, 64) as u32,
                replicas: g.usize_in(1, 8) as u32,
                health: g.usize_in(0, 2) as u8,
                shard_base: g.usize_in(0, 60) as u64,
            },
            1 => Response::Ack { accepted: g.usize_in(0, 1 << 20) as u64 },
            2 => Response::Deleted { removed: g.bool() },
            3 => Response::AnnAnswers(
                (0..g.size(0, 20))
                    .map(|_| {
                        if g.bool() {
                            Some(crate::coordinator::AnnAnswer {
                                shard: g.usize_in(0, 63),
                                id: g.usize_in(0, 1 << 20) as u32,
                                dist: g.f64_in(0.0, 100.0) as f32,
                            })
                        } else {
                            None
                        }
                    })
                    .collect(),
            ),
            4 => {
                let n = g.size(0, 20);
                Response::KdeAnswers {
                    sums: (0..n).map(|_| g.f64_in(0.0, 1e6)).collect(),
                    densities: (0..n).map(|_| g.f64_in(0.0, 1.0)).collect(),
                }
            }
            5 => Response::Stats(crate::coordinator::ServiceStats {
                inserts: g.usize_in(0, 1 << 30) as u64,
                deletes: g.usize_in(0, 1 << 20) as u64,
                ann_queries: g.usize_in(0, 1 << 20) as u64,
                kde_queries: g.usize_in(0, 1 << 20) as u64,
                shed: g.usize_in(0, 1 << 20) as u64,
                stored_points: g.usize_in(0, 1 << 20),
                sketch_bytes: g.usize_in(0, 1 << 30),
                replicas: g.usize_in(1, 4) as u32,
                replica_depths: (0..g.size(0, 16))
                    .map(|_| g.usize_in(0, 1 << 10) as u32)
                    .collect(),
                health: (0..g.size(0, 16)).map(|_| g.usize_in(0, 2) as u8).collect(),
                wal_errors: g.usize_in(0, 1 << 20) as u64,
                refused_writes: g.usize_in(0, 1 << 20) as u64,
            }),
            6 => Response::Checkpointed { points: g.usize_in(0, 1 << 40) as u64 },
            7 => Response::Metrics(gen_metrics(g)),
            8 => Response::AnnPartials(
                (0..g.size(0, 6)).map(|_| gen_ann_partial(g)).collect(),
            ),
            9 => Response::KdePartials(
                (0..g.size(0, 6))
                    .map(|_| ShardKdeResult {
                        kernel_sums: (0..g.size(0, 12)).map(|_| g.f64_in(0.0, 1e6)).collect(),
                        population: g.usize_in(0, 1 << 30) as u64,
                    })
                    .collect(),
            ),
            10 => Response::Collections(
                (0..g.size(0, 8))
                    .map(|i| CollectionInfo {
                        id: g.usize_in(0, 1 << 16) as u32,
                        name: format!("coll-{i}"),
                        dim: g.usize_in(1, 1024) as u32,
                        shards: g.usize_in(1, 16) as u32,
                        replicas: g.usize_in(1, 4) as u32,
                    })
                    .collect(),
            ),
            _ => Response::Error("frame \u{1F980} error".to_string()),
        }
    }

    #[test]
    fn property_request_roundtrip() {
        check("request_roundtrip", 200, |g| {
            let req = gen_request(g);
            let back = Request::decode(&req.encode()).map_err(|e| e.to_string())?;
            if back != req {
                return Err(format!("{req:?} != {back:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn property_response_roundtrip() {
        check("response_roundtrip", 200, |g| {
            let resp = gen_response(g);
            let back = Response::decode(&resp.encode()).map_err(|e| e.to_string())?;
            if back != resp {
                return Err(format!("{resp:?} != {back:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn property_truncation_never_panics() {
        // Any prefix of a valid payload must decode to a clean error (or,
        // for request prefixes that happen to be valid frames, an Ok).
        check("truncation_safe", 100, |g| {
            let full = gen_request(g).encode();
            let cut = g.usize_in(0, full.len());
            let _ = Request::decode(&full[..cut]);
            let _ = Response::decode(&full[..cut]);
            Ok(())
        });
    }

    #[test]
    fn wrong_version_is_rejected() {
        let mut bytes = Request::Stats { coll: 0 }.encode();
        bytes[0] = 42;
        let err = Request::decode(&bytes).unwrap_err().to_string();
        assert!(err.contains("version"), "{err}");
    }

    #[test]
    fn v5_frames_decode_as_the_default_collection() {
        // A v5 body has NO collection id: hand-build v5-shaped frames
        // for every collection-scoped op and require them to decode as
        // collection 0 with the payload untouched. This is the on-wire
        // compat contract for old clients.
        let v5 = |opcode: u8| vec![COMPAT_PROTOCOL_VERSION, opcode];
        let mut b = v5(super::op::INSERT);
        put_vec_f32(&mut b, &[1.0, 2.0]);
        assert_eq!(
            Request::decode(&b).unwrap(),
            Request::Insert { coll: 0, x: vec![1.0, 2.0] }
        );
        let mut b = v5(super::op::INSERT_BATCH);
        put_vecs(&mut b, &[vec![0.5; 3]]);
        assert_eq!(
            Request::decode(&b).unwrap(),
            Request::InsertBatch { coll: 0, xs: vec![vec![0.5; 3]] }
        );
        let mut b = v5(super::op::DELETE);
        put_vec_f32(&mut b, &[9.0]);
        assert_eq!(Request::decode(&b).unwrap(), Request::Delete { coll: 0, x: vec![9.0] });
        for (opcode, want_trace) in [
            (super::op::ANN_QUERY, 7u64),
            (super::op::KDE_QUERY, 8),
            (super::op::ANN_PARTIAL, 9),
            (super::op::KDE_PARTIAL, 0),
        ] {
            let mut b = v5(opcode);
            put_u64(&mut b, want_trace);
            put_vecs(&mut b, &[vec![1.0, 2.0]]);
            match Request::decode(&b).unwrap() {
                Request::AnnQuery { coll, trace, .. }
                | Request::KdeQuery { coll, trace, .. }
                | Request::AnnPartial { coll, trace, .. }
                | Request::KdePartial { coll, trace, .. } => {
                    assert_eq!(coll, 0, "opcode {opcode}");
                    assert_eq!(trace, want_trace, "opcode {opcode}");
                }
                other => panic!("opcode {opcode} decoded {other:?}"),
            }
        }
        assert_eq!(Request::decode(&v5(super::op::STATS)).unwrap(), Request::Stats { coll: 0 });
        assert_eq!(Request::decode(&v5(super::op::FLUSH)).unwrap(), Request::Flush { coll: 0 });
        assert_eq!(
            Request::decode(&v5(super::op::CHECKPOINT)).unwrap(),
            Request::Checkpoint { coll: 0 }
        );
        assert_eq!(Request::decode(&v5(super::op::HELLO)).unwrap(), Request::Hello);
        assert_eq!(Request::decode(&v5(super::op::SHUTDOWN)).unwrap(), Request::Shutdown);
    }

    #[test]
    fn collection_ops_reject_v5_frames() {
        // The collection-management ops are born in v6; a v5 frame
        // claiming one is a protocol error, not an empty-name create.
        for opcode in [
            super::op::CREATE_COLLECTION,
            super::op::DROP_COLLECTION,
            super::op::LIST_COLLECTIONS,
        ] {
            let mut b = vec![COMPAT_PROTOCOL_VERSION, opcode];
            put_str(&mut b, "tenant");
            put_spec(&mut b, &CollectionSpec::default());
            let err = Request::decode(&b).unwrap_err().to_string();
            assert!(err.contains("requires protocol v6"), "opcode {opcode}: {err}");
        }
    }

    #[test]
    fn unknown_opcode_is_rejected() {
        let bytes = vec![PROTOCOL_VERSION, 200];
        assert!(Request::decode(&bytes).is_err());
        let bytes = vec![PROTOCOL_VERSION, 3];
        assert!(Response::decode(&bytes).is_err(), "request op as response");
    }

    #[test]
    fn hostile_counts_are_rejected_before_allocation() {
        // Claim 2^32-1 vectors with a 12-byte body (after the coll id).
        let mut bytes = vec![PROTOCOL_VERSION, super::op::INSERT_BATCH];
        bytes.extend_from_slice(&0u32.to_le_bytes()); // coll 0
        bytes.extend_from_slice(&u32::MAX.to_le_bytes());
        bytes.extend_from_slice(&[0u8; 8]);
        let err = Request::decode(&bytes).unwrap_err().to_string();
        assert!(err.contains("exceeds"), "{err}");
        // Same for a single vector length, on a v5 frame (no coll id) —
        // the compat path shares the hostile-count guard.
        let mut bytes = vec![COMPAT_PROTOCOL_VERSION, super::op::INSERT];
        bytes.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(Request::decode(&bytes).is_err());
    }

    #[test]
    fn trailing_bytes_are_rejected() {
        let mut bytes = Request::Flush { coll: 0 }.encode();
        bytes.push(0);
        assert!(Request::decode(&bytes).is_err());
        let mut bytes = Request::Checkpoint { coll: 3 }.encode();
        bytes.push(7);
        assert!(Request::decode(&bytes).is_err(), "checkpoint body is the coll id alone");
    }

    #[test]
    fn checkpoint_op_roundtrips_and_survives_fuzzing() {
        // Exact roundtrip on both directions of the new op.
        let req = Request::Checkpoint { coll: 2 };
        assert_eq!(Request::decode(&req.encode()).unwrap(), req);
        let resp = Response::Checkpointed { points: 987_654_321 };
        assert_eq!(Response::decode(&resp.encode()).unwrap(), resp);
        // Fuzz-ish: every 1-byte mutation of either frame must decode to
        // a clean result (Ok of something else, or Err) — never a panic,
        // never an allocation driven by the mutated bytes alone.
        check("checkpoint_frame_mutation", 150, |g| {
            let base = if g.bool() {
                Request::Checkpoint { coll: gen_coll(g) }.encode()
            } else {
                Response::Checkpointed { points: g.usize_in(0, 1 << 40) as u64 }.encode()
            };
            let mut m = base.clone();
            let i = g.usize_in(0, m.len() - 1);
            m[i] ^= g.usize_in(1, 255) as u8;
            let _ = Request::decode(&m);
            let _ = Response::decode(&m);
            // Random garbage of arbitrary length, too.
            let junk: Vec<u8> = (0..g.size(0, 64)).map(|_| g.rng.next_u64() as u8).collect();
            let _ = Request::decode(&junk);
            let _ = Response::decode(&junk);
            Ok(())
        });
    }

    #[test]
    fn metrics_op_roundtrips_and_survives_fuzzing() {
        // Exact roundtrip of a populated snapshot straight off a live
        // registry — encoder and decoder share put_metrics/read_metrics,
        // so a field added to one side breaks this immediately.
        let reg = crate::metrics::registry::Registry::new();
        reg.inserts.add(42);
        reg.stored_points.set(40);
        reg.op_ann.record_us(133.7);
        reg.stage_merge.record_us(9.5);
        let resp = Response::Metrics(reg.snapshot());
        assert_eq!(Response::decode(&resp.encode()).unwrap(), resp);
        assert_eq!(Request::decode(&Request::Metrics.encode()).unwrap(), Request::Metrics);

        // Hostile input: 1-byte mutations of a real snapshot frame and
        // arbitrary junk must decode to a clean result, never a panic or
        // a claim-driven allocation.
        check("metrics_frame_mutation", 150, |g| {
            let base = if g.bool() {
                Response::Metrics(gen_metrics(g)).encode()
            } else {
                Request::Metrics.encode()
            };
            let mut m = base.clone();
            let i = g.usize_in(0, m.len() - 1);
            m[i] ^= g.usize_in(1, 255) as u8;
            let _ = Request::decode(&m);
            let _ = Response::decode(&m);
            let junk: Vec<u8> = (0..g.size(0, 64)).map(|_| g.rng.next_u64() as u8).collect();
            let _ = Request::decode(&junk);
            let _ = Response::decode(&junk);
            Ok(())
        });
    }

    #[test]
    fn partial_ops_roundtrip_and_survive_fuzzing() {
        // Exact roundtrip of the v5 scatter/gather ops: a partial reply
        // carries f64 sums and f32 distances as bit patterns, so what the
        // router decodes is byte-for-byte what the node computed.
        let req = Request::AnnPartial { coll: 1, queries: vec![vec![1.0, 2.0]], trace: 7 };
        assert_eq!(Request::decode(&req.encode()).unwrap(), req);
        let req = Request::KdePartial { coll: 0, queries: vec![vec![0.5; 3]], trace: 0 };
        assert_eq!(Request::decode(&req.encode()).unwrap(), req);
        let resp = Response::AnnPartials(vec![
            ShardAnnResult {
                best: vec![
                    Some(AnnAnswer { shard: 3, id: 9, dist: 0.125 }),
                    None,
                ],
                scanned: 17,
            },
            ShardAnnResult { best: vec![None, None], scanned: 0 },
        ]);
        assert_eq!(Response::decode(&resp.encode()).unwrap(), resp);
        let resp = Response::KdePartials(vec![ShardKdeResult {
            kernel_sums: vec![1.0 / 3.0, f64::MIN_POSITIVE],
            population: 41,
        }]);
        assert_eq!(Response::decode(&resp.encode()).unwrap(), resp);
        // The traced request layout matches the v4 query ops: coll id,
        // then trace id, then the vectors.
        match Request::decode(&encode_ann_partial(5, &[vec![1.0f32]], 0xBEEF)).unwrap() {
            Request::AnnPartial { coll, trace, .. } => {
                assert_eq!(coll, 5);
                assert_eq!(trace, 0xBEEF);
            }
            other => panic!("decoded {other:?}"),
        }
        // Hostile input: 1-byte mutations and junk never panic and never
        // allocate off the claim alone.
        check("partial_frame_mutation", 150, |g| {
            let base = match g.usize_in(0, 3) {
                0 => Request::AnnPartial { coll: gen_coll(g), queries: gen_vecs(g), trace: 1 }
                    .encode(),
                1 => Request::KdePartial { coll: gen_coll(g), queries: gen_vecs(g), trace: 2 }
                    .encode(),
                2 => Response::AnnPartials(
                    (0..g.size(0, 4)).map(|_| gen_ann_partial(g)).collect(),
                )
                .encode(),
                _ => Response::KdePartials(vec![ShardKdeResult {
                    kernel_sums: (0..g.size(0, 8)).map(|_| g.f64_in(0.0, 1e6)).collect(),
                    population: 9,
                }])
                .encode(),
            };
            let mut m = base.clone();
            let i = g.usize_in(0, m.len() - 1);
            m[i] ^= g.usize_in(1, 255) as u8;
            let _ = Request::decode(&m);
            let _ = Response::decode(&m);
            let junk: Vec<u8> = (0..g.size(0, 64)).map(|_| g.rng.next_u64() as u8).collect();
            let _ = Request::decode(&junk);
            let _ = Response::decode(&junk);
            Ok(())
        });
    }

    #[test]
    fn traced_query_carries_the_trace_id() {
        let qs = vec![vec![1.0f32, 2.0]];
        let enc = encode_ann_query_traced(3, &qs, 0xDEAD_BEEF);
        match Request::decode(&enc).unwrap() {
            Request::AnnQuery { coll, queries, trace } => {
                assert_eq!(coll, 3);
                assert_eq!(queries, qs);
                assert_eq!(trace, 0xDEAD_BEEF);
            }
            other => panic!("decoded {other:?}"),
        }
        // The untraced encoder writes trace 0 ("mint one for me").
        match Request::decode(&encode_kde_query(0, &qs)).unwrap() {
            Request::KdeQuery { trace, .. } => assert_eq!(trace, 0),
            other => panic!("decoded {other:?}"),
        }
    }

    #[test]
    fn collection_ops_roundtrip_and_survive_fuzzing() {
        // Exact roundtrip of the v6 collection-management ops.
        let spec = CollectionSpec {
            dim: 24,
            shards: 2,
            replicas: 1,
            n_max: 50_000,
            window: 4096,
            eta: 0.5,
            overload: 1,
            seed: 99,
        };
        let req = Request::CreateCollection { name: "news".into(), spec: spec.clone() };
        assert_eq!(Request::decode(&req.encode()).unwrap(), req);
        let req = Request::DropCollection { name: "news".into() };
        assert_eq!(Request::decode(&req.encode()).unwrap(), req);
        let req = Request::ListCollections;
        assert_eq!(Request::decode(&req.encode()).unwrap(), req);
        let resp = Response::Collections(vec![
            CollectionInfo { id: 0, name: "default".into(), dim: 16, shards: 4, replicas: 1 },
            CollectionInfo { id: 3, name: "news".into(), dim: 24, shards: 2, replicas: 1 },
        ]);
        assert_eq!(Response::decode(&resp.encode()).unwrap(), resp);

        // Hostile input: 1-byte mutations of real collection frames and
        // arbitrary junk must decode to a clean result, never a panic or
        // a claim-driven allocation.
        check("collection_frame_mutation", 150, |g| {
            let base = match g.usize_in(0, 3) {
                0 => Request::CreateCollection {
                    name: format!("c{}", g.usize_in(0, 99)),
                    spec: gen_spec(g),
                }
                .encode(),
                1 => Request::DropCollection { name: format!("c{}", g.usize_in(0, 99)) }.encode(),
                2 => Request::ListCollections.encode(),
                _ => Response::Collections(
                    (0..g.size(0, 4))
                        .map(|i| CollectionInfo {
                            id: g.usize_in(0, 1 << 16) as u32,
                            name: format!("c{i}"),
                            dim: g.usize_in(1, 64) as u32,
                            shards: g.usize_in(1, 8) as u32,
                            replicas: g.usize_in(1, 4) as u32,
                        })
                        .collect(),
                )
                .encode(),
            };
            let mut m = base.clone();
            let i = g.usize_in(0, m.len() - 1);
            m[i] ^= g.usize_in(1, 255) as u8;
            let _ = Request::decode(&m);
            let _ = Response::decode(&m);
            let junk: Vec<u8> = (0..g.size(0, 64)).map(|_| g.rng.next_u64() as u8).collect();
            let _ = Request::decode(&junk);
            let _ = Response::decode(&junk);
            Ok(())
        });
    }

    #[test]
    fn frame_io_roundtrip_and_eof() {
        let mut wire = Vec::new();
        write_frame(&mut wire, &Request::Hello.encode()).unwrap();
        write_frame(&mut wire, &Request::Stats { coll: 0 }.encode()).unwrap();
        let mut r = &wire[..];
        let mut buf = Vec::new();
        assert!(read_frame(&mut r, &mut buf).unwrap());
        assert_eq!(Request::decode(&buf).unwrap(), Request::Hello);
        assert!(read_frame(&mut r, &mut buf).unwrap());
        assert_eq!(Request::decode(&buf).unwrap(), Request::Stats { coll: 0 });
        assert!(!read_frame(&mut r, &mut buf).unwrap(), "clean EOF");
    }

    #[test]
    fn oversized_frame_header_is_rejected() {
        let mut wire = Vec::new();
        wire.extend_from_slice(&(u32::MAX).to_le_bytes());
        wire.extend_from_slice(&[0u8; 16]);
        let mut r = &wire[..];
        let mut buf = Vec::new();
        assert!(read_frame(&mut r, &mut buf).is_err());
    }
}
