//! Blocking client for the sketchd wire protocol.
//!
//! One request in flight per connection (the server answers in order);
//! for pipelined load, open several clients — the server runs one reader
//! thread per connection and the shard mailboxes do the fan-in.
//!
//! Multi-tenancy (v6): every data-plane request is scoped to a
//! collection id. The ergonomic surface is [`SketchClient::collection`],
//! which resolves a name to a [`Collection`] handle once and stamps the
//! id on every call; the flat pre-v6 methods survive as deprecated
//! shims against the default collection (id 0), so v5-era call sites
//! keep compiling and keep their exact semantics.
//!
//! Resilience: [`ClientOptions`] bounds every socket operation (connect,
//! read, write) with one deadline, so a hung or partitioned server costs
//! a timely error instead of a stuck caller. Idempotent requests
//! (queries and stats) additionally retry across a bounded number of
//! reconnects with deterministic jittered exponential backoff — a
//! transport fault mid-exchange desyncs the request/response stream, so
//! a retry always reconnects and re-handshakes before resending. A
//! `Response::Error` from the server is never retried: the server
//! answered, the answer was "no".

use std::io::{BufReader, BufWriter};
use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use crate::coordinator::{
    AnnAnswer, CollectionInfo, CollectionSpec, ServiceStats, ShardAnnResult, ShardKdeResult,
    DEFAULT_COLLECTION,
};
use crate::metrics::registry::MetricsSnapshot;

use super::frame::{
    encode_ann_partial, encode_ann_query, encode_ann_query_traced, encode_delete, encode_insert,
    encode_insert_batch, encode_kde_partial, encode_kde_query, read_frame, write_frame, Request,
    Response, PROTOCOL_VERSION,
};

/// Socket deadlines and retry budget for a [`SketchClient`].
#[derive(Clone, Copy, Debug)]
pub struct ClientOptions {
    /// Deadline for connect and for each read/write on the socket.
    /// `None` blocks forever (the pre-deadline behavior).
    pub timeout: Option<Duration>,
    /// How many reconnect-and-resend attempts an idempotent request gets
    /// after its first transport failure. Non-idempotent requests
    /// (inserts, deletes, control frames) never retry.
    pub retries: u32,
    /// Base delay of the exponential backoff between retries (doubles
    /// each attempt, plus up to +50% deterministic jitter).
    pub backoff: Duration,
    /// Seed of the jitter sequence (deterministic for reproducible runs;
    /// vary per client to avoid synchronized retry storms).
    pub seed: u64,
}

impl Default for ClientOptions {
    fn default() -> Self {
        ClientOptions {
            timeout: None,
            retries: 0,
            backoff: Duration::from_millis(50),
            seed: 0x5EED_CAFE,
        }
    }
}

impl ClientOptions {
    /// CLI mapping: `timeout_ms == 0` means "no deadline".
    pub fn from_cli(timeout_ms: u64, retries: u32) -> Self {
        ClientOptions {
            timeout: (timeout_ms > 0).then(|| Duration::from_millis(timeout_ms)),
            retries,
            ..ClientOptions::default()
        }
    }
}

/// A connected sketchd client (handshake done, dim known).
pub struct SketchClient {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    buf: Vec<u8>,
    addr: SocketAddr,
    opts: ClientOptions,
    jitter: u64,
    dim: usize,
    shards: usize,
    replicas: usize,
    health: u8,
    shard_base: u64,
}

impl SketchClient {
    /// Connect and handshake with default options (no deadlines, no
    /// retries); fails on a protocol-version mismatch.
    pub fn connect<A: ToSocketAddrs>(addr: A) -> Result<Self> {
        Self::connect_with(addr, ClientOptions::default())
    }

    /// Connect and handshake with explicit deadlines/retries. Tries each
    /// resolved address once, under the connect deadline.
    pub fn connect_with<A: ToSocketAddrs>(addr: A, opts: ClientOptions) -> Result<Self> {
        let mut last: Option<anyhow::Error> = None;
        for a in addr.to_socket_addrs()? {
            match Self::open(a, opts) {
                Ok(c) => return Ok(c),
                Err(e) => last = Some(e),
            }
        }
        Err(last.unwrap_or_else(|| anyhow!("address resolved to nothing")))
    }

    fn open(addr: SocketAddr, opts: ClientOptions) -> Result<Self> {
        let stream = match opts.timeout {
            Some(t) => TcpStream::connect_timeout(&addr, t)?,
            None => TcpStream::connect(addr)?,
        };
        stream.set_nodelay(true).ok();
        stream.set_read_timeout(opts.timeout)?;
        stream.set_write_timeout(opts.timeout)?;
        let mut client = SketchClient {
            reader: BufReader::new(stream.try_clone()?),
            writer: BufWriter::new(stream),
            buf: Vec::new(),
            addr,
            opts,
            jitter: opts.seed | 1,
            dim: 0,
            shards: 0,
            replicas: 1,
            health: 0,
            shard_base: 0,
        };
        match client.call(&Request::Hello)? {
            Response::Hello { version, dim, shards, replicas, health, shard_base } => {
                if version != PROTOCOL_VERSION {
                    bail!("server speaks protocol {version}, this build {PROTOCOL_VERSION}");
                }
                client.dim = dim as usize;
                client.shards = shards as usize;
                client.replicas = (replicas as usize).max(1);
                client.health = health;
                client.shard_base = shard_base;
            }
            other => bail!("handshake got {other:?}"),
        }
        Ok(client)
    }

    /// Drop the (possibly desynced) stream and open a fresh connection
    /// to the same address, re-handshaking. Keeps the jitter sequence so
    /// backoff stays deterministic across the client's lifetime.
    fn reconnect(&mut self) -> Result<()> {
        let jitter = self.jitter;
        let mut fresh = Self::open(self.addr, self.opts)?;
        fresh.jitter = jitter;
        *self = fresh;
        Ok(())
    }

    /// Vector dimensionality of the remote service's DEFAULT collection
    /// (named collections each carry their own dim — see
    /// [`Collection::dim`] after [`Self::collection`]).
    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Read replicas per shard on the remote service.
    pub fn replicas(&self) -> usize {
        self.replicas
    }

    /// Worst shard health the server reported at handshake
    /// (`ShardHealth as u8`: 0 healthy, 1 durability-degraded,
    /// 2 read-only). A snapshot from connect time, not live.
    pub fn server_health(&self) -> u8 {
        self.health
    }

    /// First GLOBAL shard the server serves (v5 Hello): nonzero only on
    /// member nodes of a routed deployment booted with `--shard-base`.
    pub fn shard_base(&self) -> u64 {
        self.shard_base
    }

    /// One exchange; errors here are TRANSPORT errors (socket, framing,
    /// decode) — a decoded `Response::Error` is returned as `Ok`.
    fn exchange(&mut self, payload: &[u8]) -> Result<Response> {
        write_frame(&mut self.writer, payload)?;
        if !read_frame(&mut self.reader, &mut self.buf)? {
            bail!("server closed the connection");
        }
        Response::decode(&self.buf)
    }

    fn call(&mut self, req: &Request) -> Result<Response> {
        self.call_raw(&req.encode())
    }

    /// One request/response exchange from an already-encoded payload
    /// (the borrowed-encoder hot path: no owned `Request` clone). No
    /// retries — the non-idempotent path.
    fn call_raw(&mut self, payload: &[u8]) -> Result<Response> {
        match self.exchange(payload)? {
            Response::Error(msg) => bail!("server error: {msg}"),
            resp => Ok(resp),
        }
    }

    /// Idempotent exchange: transport failures reconnect (the stream is
    /// desynced once a frame went missing) and resend, up to
    /// `opts.retries` times with jittered exponential backoff. Server
    /// `Error` replies fail immediately — they are answers, not faults.
    fn call_retry(&mut self, payload: &[u8]) -> Result<Response> {
        let mut err = match self.exchange(payload) {
            Ok(Response::Error(msg)) => bail!("server error: {msg}"),
            Ok(resp) => return Ok(resp),
            Err(e) => e,
        };
        for attempt in 1..=self.opts.retries {
            std::thread::sleep(self.backoff_delay(attempt));
            let res = match self.reconnect() {
                Ok(()) => self.exchange(payload),
                Err(e) => Err(e),
            };
            match res {
                Ok(Response::Error(msg)) => bail!("server error: {msg}"),
                Ok(resp) => return Ok(resp),
                Err(e) => err = e,
            }
        }
        Err(err.context(format!("after {} retries", self.opts.retries)))
    }

    fn backoff_delay(&mut self, attempt: u32) -> Duration {
        backoff_delay(&mut self.jitter, self.opts.backoff, attempt)
    }

    // ---- collection-scoped core (v6) -------------------------------

    /// Offer one point to collection `coll`; true iff accepted.
    pub fn insert_in(&mut self, coll: u32, x: &[f32]) -> Result<bool> {
        match self.call_raw(&encode_insert(coll, x))? {
            Response::Ack { accepted } => Ok(accepted == 1),
            other => bail!("insert got {other:?}"),
        }
    }

    /// Offer a batch to collection `coll`; returns points accepted.
    pub fn insert_batch_in(&mut self, coll: u32, batch: &[Vec<f32>]) -> Result<u64> {
        match self.call_raw(&encode_insert_batch(coll, batch))? {
            Response::Ack { accepted } => Ok(accepted),
            other => bail!("insert_batch got {other:?}"),
        }
    }

    /// Turnstile delete in collection `coll`; true iff a copy was removed.
    pub fn delete_in(&mut self, coll: u32, x: &[f32]) -> Result<bool> {
        match self.call_raw(&encode_delete(coll, x))? {
            Response::Deleted { removed } => Ok(removed),
            other => bail!("delete got {other:?}"),
        }
    }

    /// Batched (c, r)-ANN against collection `coll`; answers align with
    /// `queries`. Idempotent — retried under the retry budget.
    pub fn ann_query_in(
        &mut self,
        coll: u32,
        queries: &[Vec<f32>],
    ) -> Result<Vec<Option<AnnAnswer>>> {
        match self.call_retry(&encode_ann_query(coll, queries))? {
            Response::AnnAnswers(answers) => Ok(answers),
            other => bail!("ann_query got {other:?}"),
        }
    }

    /// [`Self::ann_query_in`] with a caller-chosen trace id: the server
    /// stamps its slow-query log with this id, so a client can tie its
    /// own latency record to the server's stage breakdown (v4).
    pub fn ann_query_traced_in(
        &mut self,
        coll: u32,
        queries: &[Vec<f32>],
        trace: u64,
    ) -> Result<Vec<Option<AnnAnswer>>> {
        match self.call_retry(&encode_ann_query_traced(coll, queries, trace))? {
            Response::AnnAnswers(answers) => Ok(answers),
            other => bail!("ann_query got {other:?}"),
        }
    }

    /// Batched sliding-window KDE against collection `coll`:
    /// (kernel sums, densities). Idempotent — retried.
    pub fn kde_query_in(
        &mut self,
        coll: u32,
        queries: &[Vec<f32>],
    ) -> Result<(Vec<f64>, Vec<f64>)> {
        match self.call_retry(&encode_kde_query(coll, queries))? {
            Response::KdeAnswers { sums, densities } => Ok((sums, densities)),
            other => bail!("kde_query got {other:?}"),
        }
    }

    /// v5/v6 scatter/gather: RAW per-shard ANN partials of collection
    /// `coll` in the node's global shard order, trace id propagated
    /// across the hop. This is the router's query primitive — a
    /// front-end merges partials from every member exactly once.
    /// Idempotent — retried under the client's retry budget.
    pub fn ann_partial(
        &mut self,
        coll: u32,
        queries: &[Vec<f32>],
        trace: u64,
    ) -> Result<Vec<ShardAnnResult>> {
        match self.call_retry(&encode_ann_partial(coll, queries, trace))? {
            Response::AnnPartials(parts) => Ok(parts),
            other => bail!("ann_partial got {other:?}"),
        }
    }

    /// v5/v6 scatter/gather: RAW per-shard KDE partials of collection
    /// `coll` (kernel sums + window population, no division — the
    /// merging tier folds). Idempotent — retried.
    pub fn kde_partial(
        &mut self,
        coll: u32,
        queries: &[Vec<f32>],
        trace: u64,
    ) -> Result<Vec<ShardKdeResult>> {
        match self.call_retry(&encode_kde_partial(coll, queries, trace))? {
            Response::KdePartials(parts) => Ok(parts),
            other => bail!("kde_partial got {other:?}"),
        }
    }

    /// Aggregate statistics of collection `coll` (drains mailboxes
    /// server-side). Idempotent — retried under the retry budget.
    pub fn stats_in(&mut self, coll: u32) -> Result<ServiceStats> {
        match self.call_retry(&Request::Stats { coll }.encode())? {
            Response::Stats(st) => Ok(st),
            other => bail!("stats got {other:?}"),
        }
    }

    /// Barrier on collection `coll`: everything this connection inserted
    /// into it is applied on return.
    pub fn flush_in(&mut self, coll: u32) -> Result<()> {
        match self.call(&Request::Flush { coll })? {
            Response::Ack { .. } => Ok(()),
            other => bail!("flush got {other:?}"),
        }
    }

    /// Cut a durable checkpoint of collection `coll` on the server
    /// (requires `--data-dir`). Returns the points it covers.
    pub fn checkpoint_in(&mut self, coll: u32) -> Result<u64> {
        match self.call(&Request::Checkpoint { coll })? {
            Response::Checkpointed { points } => Ok(points),
            other => bail!("checkpoint got {other:?}"),
        }
    }

    // ---- collection management (v6) --------------------------------

    /// Create a named collection with its own geometry; returns its
    /// assigned id. Names are `[A-Za-z0-9_-]`, 1–64 chars, first char
    /// alphanumeric or `_`; `"default"` is reserved.
    pub fn create_collection(&mut self, name: &str, spec: &CollectionSpec) -> Result<CollectionInfo> {
        let req = Request::CreateCollection { name: name.to_string(), spec: spec.clone() };
        match self.call(&req)? {
            Response::Collections(mut cols) => {
                cols.pop().ok_or_else(|| anyhow!("create_collection got an empty listing"))
            }
            other => bail!("create_collection got {other:?}"),
        }
    }

    /// Drop a named collection and its on-disk subtree. The default
    /// collection cannot be dropped.
    pub fn drop_collection(&mut self, name: &str) -> Result<()> {
        match self.call(&Request::DropCollection { name: name.to_string() })? {
            Response::Ack { .. } => Ok(()),
            other => bail!("drop_collection got {other:?}"),
        }
    }

    /// Every live collection, default included. Idempotent — retried.
    pub fn list_collections(&mut self) -> Result<Vec<CollectionInfo>> {
        match self.call_retry(&Request::ListCollections.encode())? {
            Response::Collections(cols) => Ok(cols),
            other => bail!("list_collections got {other:?}"),
        }
    }

    /// Resolve `name` to a [`Collection`] handle (one `ListCollections`
    /// round trip; `"default"` short-circuits to id 0). The handle
    /// borrows this client — drop it to get the client back.
    pub fn collection(&mut self, name: &str) -> Result<Collection<'_>> {
        if name == DEFAULT_COLLECTION {
            return Ok(self.default_collection());
        }
        let info = self
            .list_collections()?
            .into_iter()
            .find(|c| c.name == name)
            .ok_or_else(|| anyhow!("no collection named {name:?} on the server"))?;
        Ok(Collection { dim: info.dim as usize, id: info.id, client: self })
    }

    /// The default collection (id 0) — what every v5 client talked to.
    pub fn default_collection(&mut self) -> Collection<'_> {
        let dim = self.dim;
        Collection { dim, id: 0, client: self }
    }

    // ---- deprecated flat shims (pre-v6 surface, default collection) --

    /// Offer one point; true iff it was accepted (not shed).
    #[deprecated(note = "use `default_collection().insert(..)` or a named `collection(..)` handle")]
    pub fn insert(&mut self, x: &[f32]) -> Result<bool> {
        self.insert_in(0, x)
    }

    /// Offer a batch; returns the number of points accepted.
    #[deprecated(note = "use `default_collection().insert_batch(..)` or a named handle")]
    pub fn insert_batch(&mut self, batch: &[Vec<f32>]) -> Result<u64> {
        self.insert_batch_in(0, batch)
    }

    /// Turnstile delete; true iff a stored copy was removed.
    #[deprecated(note = "use `default_collection().delete(..)` or a named handle")]
    pub fn delete(&mut self, x: &[f32]) -> Result<bool> {
        self.delete_in(0, x)
    }

    /// Batched (c, r)-ANN; answers align with `queries`.
    #[deprecated(note = "use `default_collection().ann(..)` or a named handle")]
    pub fn ann_query(&mut self, queries: &[Vec<f32>]) -> Result<Vec<Option<AnnAnswer>>> {
        self.ann_query_in(0, queries)
    }

    /// Batched sliding-window KDE: (kernel sums, densities).
    #[deprecated(note = "use `default_collection().kde(..)` or a named handle")]
    pub fn kde_query(&mut self, queries: &[Vec<f32>]) -> Result<(Vec<f64>, Vec<f64>)> {
        self.kde_query_in(0, queries)
    }

    /// Traced batched ANN against the default collection.
    #[deprecated(note = "use `default_collection().ann_traced(..)` or a named handle")]
    pub fn ann_query_traced(
        &mut self,
        queries: &[Vec<f32>],
        trace: u64,
    ) -> Result<Vec<Option<AnnAnswer>>> {
        self.ann_query_traced_in(0, queries, trace)
    }

    /// One ANN query against the default collection.
    #[deprecated(note = "use `default_collection().ann_one(..)` or a named handle")]
    pub fn ann_query_one(&mut self, q: &[f32]) -> Result<Option<AnnAnswer>> {
        self.default_collection().ann_one(q)
    }

    /// One KDE query against the default collection → (sum, density).
    #[deprecated(note = "use `default_collection().kde_one(..)` or a named handle")]
    pub fn kde_query_one(&mut self, q: &[f32]) -> Result<(f64, f64)> {
        self.default_collection().kde_one(q)
    }

    /// Default-collection statistics.
    #[deprecated(note = "use `default_collection().stats()` or a named handle")]
    pub fn stats(&mut self) -> Result<ServiceStats> {
        self.stats_in(0)
    }

    /// Default-collection ingest barrier.
    #[deprecated(note = "use `default_collection().flush()` or a named handle")]
    pub fn flush(&mut self) -> Result<()> {
        self.flush_in(0)
    }

    /// Default-collection durable checkpoint.
    #[deprecated(note = "use `default_collection().checkpoint()` or a named handle")]
    pub fn checkpoint(&mut self) -> Result<u64> {
        self.checkpoint_in(0)
    }

    // ---- process-scoped ops (not collection-scoped) ----------------

    /// Full named-series metrics snapshot (counters, gauges, stage and
    /// per-op histograms), all collections included (named tenants'
    /// series carry a `<name>_` prefix). Idempotent — retried.
    pub fn metrics(&mut self) -> Result<MetricsSnapshot> {
        match self.call_retry(&Request::Metrics.encode())? {
            Response::Metrics(m) => Ok(m),
            other => bail!("metrics got {other:?}"),
        }
    }

    /// Ask the server process to stop accepting and shut down.
    pub fn shutdown_server(&mut self) -> Result<()> {
        match self.call(&Request::Shutdown)? {
            Response::Ack { .. } => Ok(()),
            other => bail!("shutdown got {other:?}"),
        }
    }
}

/// A collection-scoped view of a [`SketchClient`]: same connection, same
/// deadlines and retry budget, every request stamped with the
/// collection's id. Obtained from [`SketchClient::collection`] /
/// [`SketchClient::default_collection`]; borrows the client mutably, so
/// re-resolve (cheap for `"default"`, one round trip otherwise) when
/// interleaving tenants on one connection.
pub struct Collection<'a> {
    client: &'a mut SketchClient,
    id: u32,
    dim: usize,
}

impl Collection<'_> {
    /// Wire id of this collection (0 = default).
    pub fn id(&self) -> u32 {
        self.id
    }

    /// Vector dimensionality of THIS collection (named collections may
    /// differ from the default one's).
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Offer one point; true iff it was accepted (not shed).
    pub fn insert(&mut self, x: &[f32]) -> Result<bool> {
        self.client.insert_in(self.id, x)
    }

    /// Offer a batch; returns the number of points accepted.
    pub fn insert_batch(&mut self, batch: &[Vec<f32>]) -> Result<u64> {
        self.client.insert_batch_in(self.id, batch)
    }

    /// Turnstile delete; true iff a stored copy was removed.
    pub fn delete(&mut self, x: &[f32]) -> Result<bool> {
        self.client.delete_in(self.id, x)
    }

    /// Batched (c, r)-ANN; answers align with `queries`.
    pub fn ann(&mut self, queries: &[Vec<f32>]) -> Result<Vec<Option<AnnAnswer>>> {
        self.client.ann_query_in(self.id, queries)
    }

    /// [`Self::ann`] with a caller-chosen trace id for the server's
    /// slow-query log.
    pub fn ann_traced(
        &mut self,
        queries: &[Vec<f32>],
        trace: u64,
    ) -> Result<Vec<Option<AnnAnswer>>> {
        self.client.ann_query_traced_in(self.id, queries, trace)
    }

    /// One ANN query. Server-side, singletons from concurrent
    /// connections coalesce into shared scatters per collection.
    pub fn ann_one(&mut self, q: &[f32]) -> Result<Option<AnnAnswer>> {
        let mut answers = self.ann(&[q.to_vec()])?;
        match answers.pop() {
            Some(a) if answers.is_empty() => Ok(a),
            _ => bail!("ann_one expected exactly one answer"),
        }
    }

    /// Batched sliding-window KDE: (kernel sums, densities).
    pub fn kde(&mut self, queries: &[Vec<f32>]) -> Result<(Vec<f64>, Vec<f64>)> {
        self.client.kde_query_in(self.id, queries)
    }

    /// One KDE query → (kernel sum, density).
    pub fn kde_one(&mut self, q: &[f32]) -> Result<(f64, f64)> {
        let (sums, dens) = self.kde(&[q.to_vec()])?;
        match (sums.as_slice(), dens.as_slice()) {
            (&[s], &[d]) => Ok((s, d)),
            _ => bail!("kde_one expected exactly one answer"),
        }
    }

    /// Aggregate statistics of this collection.
    pub fn stats(&mut self) -> Result<ServiceStats> {
        self.client.stats_in(self.id)
    }

    /// Barrier: everything this connection inserted into this collection
    /// is applied on return.
    pub fn flush(&mut self) -> Result<()> {
        self.client.flush_in(self.id)
    }

    /// Cut a durable checkpoint of this collection (server must run with
    /// `--data-dir`). Returns the points it covers.
    pub fn checkpoint(&mut self) -> Result<u64> {
        self.client.checkpoint_in(self.id)
    }
}

/// Backoff for the given attempt (1-based): `base × 2^(attempt−1)`,
/// capped at ×64, plus up to +50% jitter from the xorshift state in
/// `jitter` (advanced in place — deterministic per seed).
fn backoff_delay(jitter: &mut u64, base: Duration, attempt: u32) -> Duration {
    let mut x = (*jitter).max(1);
    x ^= x << 13;
    x ^= x >> 7;
    x ^= x << 17;
    *jitter = x;
    let base = base.saturating_mul(1 << (attempt - 1).min(6));
    let span = (base.as_nanos() as u64).max(1);
    base + Duration::from_nanos((x % span) / 2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn options_from_cli_maps_zero_timeout_to_none() {
        let o = ClientOptions::from_cli(0, 3);
        assert!(o.timeout.is_none());
        assert_eq!(o.retries, 3);
        let o = ClientOptions::from_cli(250, 0);
        assert_eq!(o.timeout, Some(Duration::from_millis(250)));
        assert_eq!(o.retries, 0);
    }

    #[test]
    fn backoff_grows_and_jitters_deterministically() {
        let base = Duration::from_millis(10);
        let (mut ja, mut jb) = (7u64, 7u64);
        for attempt in 1..=4 {
            let da = backoff_delay(&mut ja, base, attempt);
            let db = backoff_delay(&mut jb, base, attempt);
            assert_eq!(da, db, "same seed, same sequence");
            let floor = base * (1 << (attempt - 1));
            assert!(da >= floor, "attempt {attempt}: {da:?} < {floor:?}");
            assert!(da <= floor + floor / 2, "attempt {attempt}: jitter > +50% ({da:?})");
        }
        // Different seeds desynchronize (no retry storms in lockstep).
        let (mut jc, mut jd) = (1u64, 2u64);
        assert_ne!(
            backoff_delay(&mut jc, base, 1),
            backoff_delay(&mut jd, base, 1)
        );
    }
}
