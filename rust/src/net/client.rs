//! Blocking client for the sketchd wire protocol.
//!
//! One request in flight per connection (the server answers in order);
//! for pipelined load, open several clients — the server runs one reader
//! thread per connection and the shard mailboxes do the fan-in.

use std::io::{BufReader, BufWriter};
use std::net::{TcpStream, ToSocketAddrs};

use anyhow::{bail, Result};

use crate::coordinator::{AnnAnswer, ServiceStats};

use super::frame::{
    encode_ann_query, encode_delete, encode_insert, encode_insert_batch, encode_kde_query,
    read_frame, write_frame, Request, Response, PROTOCOL_VERSION,
};

/// A connected sketchd client (handshake done, dim known).
pub struct SketchClient {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    buf: Vec<u8>,
    dim: usize,
    shards: usize,
    replicas: usize,
}

impl SketchClient {
    /// Connect and handshake; fails on a protocol-version mismatch.
    pub fn connect<A: ToSocketAddrs>(addr: A) -> Result<Self> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        let mut client = SketchClient {
            reader: BufReader::new(stream.try_clone()?),
            writer: BufWriter::new(stream),
            buf: Vec::new(),
            dim: 0,
            shards: 0,
            replicas: 1,
        };
        match client.call(&Request::Hello)? {
            Response::Hello { version, dim, shards, replicas } => {
                if version != PROTOCOL_VERSION {
                    bail!("server speaks protocol {version}, this build {PROTOCOL_VERSION}");
                }
                client.dim = dim as usize;
                client.shards = shards as usize;
                client.replicas = (replicas as usize).max(1);
            }
            other => bail!("handshake got {other:?}"),
        }
        Ok(client)
    }

    /// Vector dimensionality of the remote service.
    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Read replicas per shard on the remote service.
    pub fn replicas(&self) -> usize {
        self.replicas
    }

    fn call(&mut self, req: &Request) -> Result<Response> {
        self.call_raw(&req.encode())
    }

    /// One request/response exchange from an already-encoded payload
    /// (the borrowed-encoder hot path: no owned `Request` clone).
    fn call_raw(&mut self, payload: &[u8]) -> Result<Response> {
        write_frame(&mut self.writer, payload)?;
        if !read_frame(&mut self.reader, &mut self.buf)? {
            bail!("server closed the connection");
        }
        match Response::decode(&self.buf)? {
            Response::Error(msg) => bail!("server error: {msg}"),
            resp => Ok(resp),
        }
    }

    /// Offer one point; true iff it was accepted (not shed).
    pub fn insert(&mut self, x: &[f32]) -> Result<bool> {
        match self.call_raw(&encode_insert(x))? {
            Response::Ack { accepted } => Ok(accepted == 1),
            other => bail!("insert got {other:?}"),
        }
    }

    /// Offer a batch; returns the number of points accepted.
    pub fn insert_batch(&mut self, batch: &[Vec<f32>]) -> Result<u64> {
        match self.call_raw(&encode_insert_batch(batch))? {
            Response::Ack { accepted } => Ok(accepted),
            other => bail!("insert_batch got {other:?}"),
        }
    }

    /// Turnstile delete; true iff a stored copy was removed.
    pub fn delete(&mut self, x: &[f32]) -> Result<bool> {
        match self.call_raw(&encode_delete(x))? {
            Response::Deleted { removed } => Ok(removed),
            other => bail!("delete got {other:?}"),
        }
    }

    /// Batched (c, r)-ANN; answers align with `queries`.
    pub fn ann_query(&mut self, queries: &[Vec<f32>]) -> Result<Vec<Option<AnnAnswer>>> {
        match self.call_raw(&encode_ann_query(queries))? {
            Response::AnnAnswers(answers) => Ok(answers),
            other => bail!("ann_query got {other:?}"),
        }
    }

    /// Batched sliding-window KDE: (kernel sums, densities).
    pub fn kde_query(&mut self, queries: &[Vec<f32>]) -> Result<(Vec<f64>, Vec<f64>)> {
        match self.call_raw(&encode_kde_query(queries))? {
            Response::KdeAnswers { sums, densities } => Ok((sums, densities)),
            other => bail!("kde_query got {other:?}"),
        }
    }

    /// One ANN query. Server-side, singletons from concurrent
    /// connections coalesce into shared scatters — this is the request
    /// shape the query-load generator drives.
    pub fn ann_query_one(&mut self, q: &[f32]) -> Result<Option<AnnAnswer>> {
        let mut answers = self.ann_query(&[q.to_vec()])?;
        match answers.pop() {
            Some(a) if answers.is_empty() => Ok(a),
            _ => bail!("ann_query_one expected exactly one answer"),
        }
    }

    /// One KDE query → (kernel sum, density).
    pub fn kde_query_one(&mut self, q: &[f32]) -> Result<(f64, f64)> {
        let (sums, dens) = self.kde_query(&[q.to_vec()])?;
        match (sums.as_slice(), dens.as_slice()) {
            (&[s], &[d]) => Ok((s, d)),
            _ => bail!("kde_query_one expected exactly one answer"),
        }
    }

    /// Aggregate service statistics (drains mailboxes server-side).
    pub fn stats(&mut self) -> Result<ServiceStats> {
        match self.call(&Request::Stats)? {
            Response::Stats(st) => Ok(st),
            other => bail!("stats got {other:?}"),
        }
    }

    /// Barrier: everything this connection inserted is applied on return.
    pub fn flush(&mut self) -> Result<()> {
        match self.call(&Request::Flush)? {
            Response::Ack { .. } => Ok(()),
            other => bail!("flush got {other:?}"),
        }
    }

    /// Cut a durable whole-service checkpoint on the server (requires it
    /// to run with `--data-dir`). Returns the points it covers.
    pub fn checkpoint(&mut self) -> Result<u64> {
        match self.call(&Request::Checkpoint)? {
            Response::Checkpointed { points } => Ok(points),
            other => bail!("checkpoint got {other:?}"),
        }
    }

    /// Ask the server process to stop accepting and shut down.
    pub fn shutdown_server(&mut self) -> Result<()> {
        match self.call(&Request::Shutdown)? {
            Response::Ack { .. } => Ok(()),
            other => bail!("shutdown got {other:?}"),
        }
    }
}
