//! # sublinear-sketch
//!
//! Production-grade reproduction of *Sublinear Sketches for Approximate
//! Nearest Neighbor and Kernel Density Estimation* (Danait, Das, Bhore,
//! CS.LG 2025): the S-ANN streaming near-neighbor sketch (§3) and the
//! SW-AKDE sliding-window KDE sketch (§4), served by a Rust coordinator
//! with the dense compute paths AOT-compiled from JAX/Pallas and executed
//! through PJRT. See DESIGN.md for the system inventory and EXPERIMENTS.md
//! for the paper-vs-measured record.

#![forbid(unsafe_code)]
#![deny(rust_2018_idioms, unreachable_pub)]

pub mod baselines;
pub mod bench_support;
pub mod cli;
pub mod experiments;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod durability;
pub mod lsh;
pub mod metrics;
pub mod net;
pub mod obs;
pub mod runtime;
pub mod sketch;
pub mod storage;
pub mod util;
