//! Storage substrates: the point arena and the LSH bucket tables.

pub mod hashtable;
pub mod vecstore;

pub use hashtable::{BucketTable, TableSet};
pub use vecstore::VecStore;
