//! Point storage: a contiguous f32 arena with stable u32 ids and
//! tombstoned deletion (turnstile model support).
//!
//! The S-ANN sketch stores "a pointer to each p" in its buckets (§2.2);
//! the arena is where those pointers resolve. Memory accounting here feeds
//! the compression-rate metric (paper §5.1: relative to N·d·4/1024² MB).

/// Arena of fixed-dimension f32 vectors.
pub struct VecStore {
    dim: usize,
    data: Vec<f32>,
    /// Tombstone bitmap (true = deleted).
    dead: Vec<bool>,
    live: usize,
}

impl VecStore {
    pub fn new(dim: usize) -> Self {
        assert!(dim > 0);
        VecStore { dim, data: Vec::new(), dead: Vec::new(), live: 0 }
    }

    pub fn with_capacity(dim: usize, points: usize) -> Self {
        let mut s = Self::new(dim);
        s.data.reserve(points * dim);
        s.dead.reserve(points);
        s
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Total slots ever allocated (live + tombstoned).
    pub fn len(&self) -> usize {
        self.dead.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn live(&self) -> usize {
        self.live
    }

    /// Append a vector, returning its id.
    pub fn push(&mut self, x: &[f32]) -> u32 {
        assert_eq!(x.len(), self.dim, "dimension mismatch");
        let id = self.dead.len() as u32;
        self.data.extend_from_slice(x);
        self.dead.push(false);
        self.live += 1;
        id
    }

    /// The vector for `id` (valid even if tombstoned; callers check `is_live`).
    #[inline]
    pub fn get(&self, id: u32) -> &[f32] {
        let i = id as usize * self.dim;
        &self.data[i..i + self.dim]
    }

    #[inline]
    pub fn is_live(&self, id: u32) -> bool {
        !self.dead[id as usize]
    }

    /// Tombstone a point (idempotent). Returns whether it was live.
    pub fn delete(&mut self, id: u32) -> bool {
        let slot = &mut self.dead[id as usize];
        if *slot {
            false
        } else {
            *slot = true;
            self.live -= 1;
            true
        }
    }

    /// Iterate live ids.
    pub fn live_ids(&self) -> impl Iterator<Item = u32> + '_ {
        (0..self.dead.len() as u32).filter(move |&id| !self.dead[id as usize])
    }

    /// Resident bytes of vector payload (the paper's sketch-size metric
    /// counts stored vectors at 4 bytes/component).
    pub fn payload_bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }

    /// Full resident bytes including tombstones and headers.
    pub fn memory_bytes(&self) -> usize {
        std::mem::size_of::<Self>()
            + self.data.capacity() * std::mem::size_of::<f32>()
            + self.dead.capacity()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_get_roundtrip() {
        let mut s = VecStore::new(3);
        let a = s.push(&[1.0, 2.0, 3.0]);
        let b = s.push(&[4.0, 5.0, 6.0]);
        assert_eq!(s.get(a), &[1.0, 2.0, 3.0]);
        assert_eq!(s.get(b), &[4.0, 5.0, 6.0]);
        assert_eq!(s.len(), 2);
        assert_eq!(s.live(), 2);
    }

    #[test]
    #[should_panic(expected = "dimension mismatch")]
    fn wrong_dim_panics() {
        let mut s = VecStore::new(3);
        s.push(&[1.0]);
    }

    #[test]
    fn delete_is_tombstone_and_idempotent() {
        let mut s = VecStore::new(2);
        let a = s.push(&[1.0, 1.0]);
        let b = s.push(&[2.0, 2.0]);
        assert!(s.delete(a));
        assert!(!s.delete(a), "second delete is a no-op");
        assert!(!s.is_live(a));
        assert!(s.is_live(b));
        assert_eq!(s.live(), 1);
        assert_eq!(s.live_ids().collect::<Vec<_>>(), vec![b]);
        // payload still readable (bucket scans skip via is_live)
        assert_eq!(s.get(a), &[1.0, 1.0]);
    }

    #[test]
    fn payload_bytes_counts_vectors() {
        let mut s = VecStore::new(4);
        for i in 0..10 {
            s.push(&[i as f32; 4]);
        }
        assert_eq!(s.payload_bytes(), 10 * 4 * 4);
    }
}
