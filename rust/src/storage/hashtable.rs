//! Bucket tables for the S-ANN sketch (§2.2): only non-empty buckets are
//! materialized ("standard hashing" in \[HPIM12\]); each bucket is a posting
//! list of point ids.
//!
//! One `BucketTable` per amplified function g_j; `TableSet` owns the L of
//! them and provides the probe/insert/delete surface the sketch uses.

use std::collections::HashMap;

/// A single LSH table: u64 key → posting list of ids.
#[derive(Default)]
pub struct BucketTable {
    buckets: HashMap<u64, Vec<u32>>,
    entries: usize,
}

impl BucketTable {
    pub fn new() -> Self {
        Default::default()
    }

    pub fn insert(&mut self, key: u64, id: u32) {
        self.buckets.entry(key).or_default().push(id);
        self.entries += 1;
    }

    /// Remove one occurrence of `id` under `key`; true if found.
    pub fn remove(&mut self, key: u64, id: u32) -> bool {
        if let Some(list) = self.buckets.get_mut(&key) {
            if let Some(pos) = list.iter().position(|&x| x == id) {
                list.swap_remove(pos);
                self.entries -= 1;
                if list.is_empty() {
                    self.buckets.remove(&key);
                }
                return true;
            }
        }
        false
    }

    pub fn get(&self, key: u64) -> &[u32] {
        self.buckets.get(&key).map(Vec::as_slice).unwrap_or(&[])
    }

    pub fn num_buckets(&self) -> usize {
        self.buckets.len()
    }

    pub fn num_entries(&self) -> usize {
        self.entries
    }

    pub fn memory_bytes(&self) -> usize {
        // HashMap bookkeeping approximated at 1.5x the entry array; posting
        // lists counted at capacity.
        let map_overhead =
            (self.buckets.capacity() as f64 * 1.5) as usize * (8 + std::mem::size_of::<Vec<u32>>());
        let postings: usize = self.buckets.values().map(|v| v.capacity() * 4).sum();
        std::mem::size_of::<Self>() + map_overhead + postings
    }
}

/// The L tables of an S-ANN sketch.
pub struct TableSet {
    tables: Vec<BucketTable>,
}

impl TableSet {
    pub fn new(l: usize) -> Self {
        assert!(l > 0);
        TableSet { tables: (0..l).map(|_| BucketTable::new()).collect() }
    }

    pub fn l(&self) -> usize {
        self.tables.len()
    }

    /// Insert `id` under the per-table `keys` (len = L).
    pub fn insert(&mut self, keys: &[u64], id: u32) {
        debug_assert_eq!(keys.len(), self.tables.len());
        for (t, &k) in self.tables.iter_mut().zip(keys) {
            t.insert(k, id);
        }
    }

    /// Remove `id` from every table; returns how many tables held it.
    pub fn remove(&mut self, keys: &[u64], id: u32) -> usize {
        debug_assert_eq!(keys.len(), self.tables.len());
        self.tables
            .iter_mut()
            .zip(keys)
            .map(|(t, &k)| t.remove(k, id) as usize)
            .sum()
    }

    /// Posting list of table `j` under key `k`.
    pub fn probe(&self, j: usize, key: u64) -> &[u32] {
        self.tables[j].get(key)
    }

    pub fn num_entries(&self) -> usize {
        self.tables.iter().map(|t| t.num_entries()).sum()
    }

    pub fn num_buckets(&self) -> usize {
        self.tables.iter().map(|t| t.num_buckets()).sum()
    }

    pub fn memory_bytes(&self) -> usize {
        self.tables.iter().map(|t| t.memory_bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_probe_roundtrip() {
        let mut ts = TableSet::new(3);
        ts.insert(&[10, 20, 30], 7);
        ts.insert(&[10, 21, 30], 8);
        assert_eq!(ts.probe(0, 10), &[7, 8]);
        assert_eq!(ts.probe(1, 20), &[7]);
        assert_eq!(ts.probe(1, 21), &[8]);
        assert_eq!(ts.probe(2, 30), &[7, 8]);
        assert_eq!(ts.probe(0, 99), &[] as &[u32]);
        assert_eq!(ts.num_entries(), 6);
    }

    #[test]
    fn remove_clears_empty_buckets() {
        let mut t = BucketTable::new();
        t.insert(5, 1);
        t.insert(5, 2);
        assert!(t.remove(5, 1));
        assert_eq!(t.get(5), &[2]);
        assert!(t.remove(5, 2));
        assert_eq!(t.num_buckets(), 0, "empty bucket must be dropped");
        assert!(!t.remove(5, 2), "double remove is false");
    }

    #[test]
    fn tableset_remove_counts_tables() {
        let mut ts = TableSet::new(2);
        ts.insert(&[1, 2], 42);
        assert_eq!(ts.remove(&[1, 2], 42), 2);
        assert_eq!(ts.remove(&[1, 2], 42), 0);
        assert_eq!(ts.num_entries(), 0);
    }

    #[test]
    fn duplicate_ids_in_one_bucket_are_allowed() {
        // The same point inserted twice (turnstile re-insert) keeps both
        // postings; remove deletes one occurrence at a time.
        let mut t = BucketTable::new();
        t.insert(9, 4);
        t.insert(9, 4);
        assert_eq!(t.get(9).len(), 2);
        t.remove(9, 4);
        assert_eq!(t.get(9).len(), 1);
    }

    #[test]
    fn memory_grows_with_entries() {
        let mut t = BucketTable::new();
        let m0 = t.memory_bytes();
        for i in 0..1000 {
            t.insert(i % 50, i as u32);
        }
        assert!(t.memory_bytes() > m0);
    }
}
