//! Multi-node topology: which node owns each global shard.
//!
//! A routed deployment splits the global shard space `0..total` into
//! contiguous per-node ranges (`--shard-base` on each node). The router
//! keeps insert/delete routing identical to the single-process service:
//! `hash_vector(x) % total` picks a *global* shard, and the topology maps
//! that shard to the node whose range contains it. Because the global
//! shard count and the hash are the same on both sides, a routed
//! deployment and a single process fed the same stream place every point
//! in the same global shard — the foundation of the bit-identical
//! merge guarantee (see `EXPERIMENTS.md` §Multi-node).
//!
//! When nodes do not advertise distinct contiguous bases the router falls
//! back to rendezvous (HRW) hashing over the node names to fix a
//! deterministic order: every router given the same node set derives the
//! same assignment, no matter how the `--nodes` list was typed. HRW also
//! gives minimal relocation — growing a cluster from N to N+1 nodes
//! re-homes only ~1/(N+1) of the keys (property-tested below).
//!
//! Insert-side policy (partition + delete co-routing) lives in
//! [`super::router`]; this module only decides node placement.

/// Rendezvous (HRW) score of `node` for `key`.
///
/// FNV-1a over the node name seeds a per-node hash; the key is then mixed
/// in with a splitmix64 finalizer so nearby keys decorrelate.
fn hrw_score(node: &str, key: u64) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in node.as_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    let mut z = h ^ key.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z ^= z >> 30;
    z = z.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z ^= z >> 27;
    z = z.wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Index of the rendezvous winner among `nodes` for `key`.
///
/// Every caller with the same node set agrees on the winner, and adding a
/// node only steals the keys that node now wins — no other key moves.
pub fn hrw_node<S: AsRef<str>>(key: u64, nodes: &[S]) -> usize {
    assert!(!nodes.is_empty(), "hrw_node needs at least one node");
    let mut best = 0usize;
    let mut best_score = hrw_score(nodes[0].as_ref(), key);
    for (i, n) in nodes.iter().enumerate().skip(1) {
        let s = hrw_score(n.as_ref(), key);
        if s > best_score {
            best = i;
            best_score = s;
        }
    }
    best
}

/// Contiguous per-node shard ranges covering `0..total`.
///
/// Ranges are stored in global shard order: `ranges[k]` is `(base, count)`
/// for the k-th node along the shard axis.
pub struct Topology {
    ranges: Vec<(usize, usize)>,
    total: usize,
}

impl Topology {
    /// Build from node-advertised `(shard_base, shard_count)` pairs.
    ///
    /// Returns the topology plus the permutation that sorts the input
    /// into global shard order (`order[k]` = input index of the k-th
    /// range). `None` if the ranges do not tile `0..total` exactly —
    /// overlapping bases, gaps, or an empty node.
    pub fn from_advertised(advertised: &[(usize, usize)]) -> Option<(Topology, Vec<usize>)> {
        if advertised.is_empty() || advertised.iter().any(|&(_, c)| c == 0) {
            return None;
        }
        let mut order: Vec<usize> = (0..advertised.len()).collect();
        order.sort_by_key(|&i| advertised[i].0);
        let mut next = 0usize;
        let mut ranges = Vec::with_capacity(advertised.len());
        for &i in &order {
            let (base, count) = advertised[i];
            if base != next {
                return None;
            }
            ranges.push((base, count));
            next = base + count;
        }
        Some((Topology { ranges, total: next }, order))
    }

    /// Deterministic fallback when nodes do not advertise usable bases:
    /// order nodes by rendezvous score of their names and assign
    /// contiguous ranges in that order. Any router given the same node
    /// set (in any listing order) derives the same assignment.
    ///
    /// Returns the topology plus the permutation into global shard order.
    pub fn by_rendezvous<S: AsRef<str>>(names: &[S], counts: &[usize]) -> (Topology, Vec<usize>) {
        assert_eq!(names.len(), counts.len());
        assert!(!names.is_empty(), "topology needs at least one node");
        let mut order: Vec<usize> = (0..names.len()).collect();
        // Stable total order on (score, name) so duplicate scores cannot
        // make two routers disagree.
        order.sort_by(|&a, &b| {
            let (sa, sb) = (hrw_score(names[a].as_ref(), 0), hrw_score(names[b].as_ref(), 0));
            sb.cmp(&sa).then_with(|| names[a].as_ref().cmp(names[b].as_ref()))
        });
        let mut next = 0usize;
        let mut ranges = Vec::with_capacity(names.len());
        for &i in &order {
            assert!(counts[i] > 0, "every node must own at least one shard");
            ranges.push((next, counts[i]));
            next += counts[i];
        }
        (Topology { ranges, total: next }, order)
    }

    /// Total global shards across the deployment.
    pub fn total_shards(&self) -> usize {
        self.total
    }

    /// Per-node `(base, count)` ranges in global shard order.
    pub fn ranges(&self) -> &[(usize, usize)] {
        &self.ranges
    }

    /// Backend (in global order) owning global shard `g`.
    pub fn backend_for_shard(&self, g: usize) -> usize {
        assert!(g < self.total, "shard {g} out of range 0..{}", self.total);
        self.ranges.partition_point(|&(base, _)| base <= g).saturating_sub(1)
    }

    /// Backend owning the shard that `hash_vector(x)` routes to.
    pub fn backend_for_hash(&self, h: u64) -> usize {
        self.backend_for_shard(h as usize % self.total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::router::hash_vector;

    #[test]
    fn growing_by_one_node_relocates_about_one_over_n_plus_one() {
        for n in [2usize, 4, 7] {
            let before: Vec<String> = (0..n).map(|i| format!("node-{i}:7600")).collect();
            let mut after = before.clone();
            after.push(format!("node-{n}:7600"));
            let keys = 20_000u64;
            let mut moved = 0usize;
            for k in 0..keys {
                let a = hrw_node(k, &before);
                let b = hrw_node(k, &after);
                if a != b {
                    moved += 1;
                    // HRW minimality: a key only moves TO the new node.
                    assert_eq!(b, n, "key {k} moved between surviving nodes");
                }
            }
            let expect = keys as f64 / (n as f64 + 1.0);
            let frac = moved as f64 / expect;
            assert!(
                (0.8..1.2).contains(&frac),
                "n={n}: moved {moved}, expected ~{expect:.0}"
            );
        }
    }

    #[test]
    fn delete_co_routes_with_insert_across_nodes() {
        let (topo, _) = Topology::by_rendezvous(&["a:1", "b:2", "c:3"], &[2, 2, 2]);
        let mut rng = crate::util::rng::Rng::new(9);
        for _ in 0..200 {
            let x: Vec<f32> = (0..8).map(|_| rng.gaussian_f32()).collect();
            let h = hash_vector(&x);
            let shard = h as usize % topo.total_shards();
            let node = topo.backend_for_hash(h);
            // Re-deriving from the same bytes (the delete path) must land
            // on the same global shard and the same node.
            assert_eq!(hash_vector(&x) as usize % topo.total_shards(), shard);
            assert_eq!(topo.backend_for_hash(hash_vector(&x)), node);
            let (base, count) = topo.ranges()[node];
            assert!((base..base + count).contains(&shard));
        }
    }

    #[test]
    fn advertised_ranges_must_tile_the_shard_space() {
        // Out-of-order advertisement sorts into global order.
        let (topo, order) = Topology::from_advertised(&[(2, 2), (0, 2)]).expect("contiguous");
        assert_eq!(order, vec![1, 0]);
        assert_eq!(topo.ranges(), &[(0, 2), (2, 2)]);
        assert_eq!(topo.total_shards(), 4);
        assert_eq!(topo.backend_for_shard(1), 0);
        assert_eq!(topo.backend_for_shard(2), 1);
        // Gap, overlap, duplicate base, empty node, empty set: all rejected.
        assert!(Topology::from_advertised(&[(0, 2), (3, 2)]).is_none());
        assert!(Topology::from_advertised(&[(0, 3), (2, 2)]).is_none());
        assert!(Topology::from_advertised(&[(0, 2), (0, 2)]).is_none());
        assert!(Topology::from_advertised(&[(0, 2), (2, 0)]).is_none());
        assert!(Topology::from_advertised(&[]).is_none());
    }

    #[test]
    fn rendezvous_assignment_ignores_listing_order() {
        let names = ["alpha:7600", "beta:7600", "gamma:7600"];
        let shuffled = ["gamma:7600", "alpha:7600", "beta:7600"];
        let (t1, o1) = Topology::by_rendezvous(&names, &[2, 2, 2]);
        let (t2, o2) = Topology::by_rendezvous(&shuffled, &[2, 2, 2]);
        // Same name -> same (base, count) regardless of input order.
        let assign = |names: &[&str], t: &Topology, o: &[usize]| {
            let mut v: Vec<(String, (usize, usize))> = o
                .iter()
                .zip(t.ranges())
                .map(|(&i, &r)| (names[i].to_string(), r))
                .collect();
            v.sort();
            v
        };
        assert_eq!(assign(&names, &t1, &o1), assign(&shuffled, &t2, &o2));
        assert_eq!(t1.total_shards(), 6);
    }
}
