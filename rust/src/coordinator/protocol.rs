//! Wire types of the coordinator: commands shards accept and the replies
//! they produce. Channels are attached at the server layer; these types
//! stay plain data so they can be logged, tested and replayed.

use crate::metrics::registry::Registry;
use crate::util::sync::Arc;

/// A batch of query vectors shared across shards without copying.
pub type QueryBatch = Arc<Vec<Vec<f32>>>;

/// One ANN answer: the returned point (its stored vector) and distance.
#[derive(Clone, Debug, PartialEq)]
pub struct AnnAnswer {
    /// Global point id: (shard, local id).
    pub shard: usize,
    pub id: u32,
    pub dist: f32,
}

/// Per-shard partial result for one query batch. Crosses the wire raw
/// (protocol v5 `AnnPartial`) so a multi-node front-end merges exactly
/// what an in-process plane merges.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ShardAnnResult {
    /// One entry per query: best candidate on this shard, if any.
    pub best: Vec<Option<AnnAnswer>>,
    /// Candidates scanned (diagnostics).
    pub scanned: usize,
}

/// Per-shard partial KDE result: un-normalized kernel sums per query plus
/// the shard's live window population. Crosses the wire raw (protocol v5
/// `KdePartial`): f64 addition is not associative, so only the front-end
/// folds — in global shard order — keeping routed KDE bit-identical to a
/// single process.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ShardKdeResult {
    pub kernel_sums: Vec<f64>,
    pub population: u64,
}

/// Aggregate service statistics.
///
/// `shed` is POINT-denominated: an `InsertBatch` of 64 points that gets
/// dropped under `Overload::Shed` counts as 64, so
/// `inserts == stored_points + shed` reconciles exactly for η = 0 (the
/// command-denominated `BoundedSender::shed_count()` stays available as a
/// queue-level diagnostic but never feeds these stats).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ServiceStats {
    pub inserts: u64,
    pub deletes: u64,
    pub ann_queries: u64,
    pub kde_queries: u64,
    pub shed: u64,
    /// Points stored in ONE copy of the partition (replicas hold the
    /// same points, so this never multiplies with R).
    pub stored_points: usize,
    /// One copy's sketch footprint; total resident ≈ `replicas` × this.
    pub sketch_bytes: usize,
    /// Read replicas per shard (R ≥ 1; 0 only in partial snapshots that
    /// a service hasn't filled in yet).
    pub replicas: u32,
    /// In-flight read depth per replica at snapshot time, shard-major
    /// (`[shard * replicas + r]`) — the gauge the least-loaded picker
    /// steers by.
    pub replica_depths: Vec<u32>,
    /// Per-shard durability health (`ShardHealth as u8`: 0 healthy,
    /// 1 durability-degraded, 2 read-only). Empty only in partial
    /// snapshots a service hasn't filled in yet. Protocol v3.
    pub health: Vec<u8>,
    /// WAL/checkpoint I/O failures observed since startup.
    pub wal_errors: u64,
    /// Points refused by `ReadOnly` shards (also counted in `shed`, so
    /// point accounting keeps reconciling; this is the breakdown).
    pub refused_writes: u64,
}

impl ServiceStats {
    /// Counter-only snapshot from the [`Registry`] series the serving
    /// path records into (shard-resident fields — `stored_points`,
    /// `sketch_bytes`, `replicas`, `replica_depths` — are filled in by
    /// the service). This replaces the old `ServiceCounters::snapshot`:
    /// the live counters now live in `metrics::registry`, shared between
    /// the owning `SketchService` and every `ServiceHandle` clone via
    /// the registry `Arc`, with the same `Relaxed` per-field contract
    /// (the reconciliation invariant `inserts == stored + shed +
    /// refused` is still only checked at quiescence, where the
    /// happens-before edge comes from a join or a drained mailbox, not
    /// from the counters).
    pub fn from_registry(reg: &Registry) -> ServiceStats {
        ServiceStats {
            inserts: reg.inserts.get(),
            deletes: reg.deletes.get(),
            ann_queries: reg.ann_queries.get(),
            kde_queries: reg.kde_queries.get(),
            shed: reg.shed_points.get(),
            stored_points: 0,
            sketch_bytes: 0,
            replicas: 0,
            replica_depths: Vec::new(),
            health: Vec::new(),
            wal_errors: 0,
            refused_writes: 0,
        }
    }

    /// Merge the SHARD-RESIDENT fields of member-node stats for a
    /// multi-node front-end: stored points, sketch bytes, WAL errors and
    /// refused writes sum across the partition; health vectors and
    /// replica depths concatenate in member order (= global shard
    /// order); `replicas` reports the smallest member's R (the
    /// availability floor). The COUNTER fields (inserts, queries, shed)
    /// are left zero — a router reports its own counters via
    /// [`Self::from_registry`], because every member also counted the
    /// same fanned-out operations and summing would multiply them.
    pub fn merged(parts: &[ServiceStats]) -> ServiceStats {
        let mut out = ServiceStats::default();
        for p in parts {
            out.stored_points += p.stored_points;
            out.sketch_bytes += p.sketch_bytes;
            out.wal_errors += p.wal_errors;
            out.refused_writes += p.refused_writes;
            out.replica_depths.extend_from_slice(&p.replica_depths);
            out.health.extend_from_slice(&p.health);
            out.replicas = if out.replicas == 0 {
                p.replicas
            } else {
                out.replicas.min(p.replicas.max(1))
            };
        }
        out
    }
}

/// Merge ANN partials: per query, keep the globally nearest answer.
pub fn merge_ann(partials: &[ShardAnnResult], n_queries: usize) -> Vec<Option<AnnAnswer>> {
    let mut out: Vec<Option<AnnAnswer>> = vec![None; n_queries];
    for part in partials {
        for (i, ans) in part.best.iter().enumerate() {
            if let Some(a) = ans {
                if out[i].as_ref().map_or(true, |b| a.dist < b.dist) {
                    out[i] = Some(a.clone());
                }
            }
        }
    }
    out
}

/// Merge KDE partials: kernel sums add across the partition.
pub fn merge_kde(partials: &[ShardKdeResult], n_queries: usize) -> (Vec<f64>, u64) {
    let mut sums = vec![0.0; n_queries];
    let mut pop = 0u64;
    for part in partials {
        for (i, &s) in part.kernel_sums.iter().enumerate() {
            sums[i] += s;
        }
        pop += part.population;
    }
    (sums, pop)
}

/// Normalize merged kernel sums into densities over the live window
/// population (0.0 on an empty window). One definition shared by the
/// [`QueryPlane`] and the service so the estimate can't drift between
/// the owning-thread and calling-thread read paths.
///
/// [`QueryPlane`]: super::query::QueryPlane
pub fn kde_densities(sums: &[f64], pop: u64) -> Vec<f64> {
    sums.iter()
        .map(|&s| if pop > 0 { s / pop as f64 } else { 0.0 })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_ann_takes_global_min() {
        let a = ShardAnnResult {
            best: vec![
                Some(AnnAnswer { shard: 0, id: 1, dist: 2.0 }),
                None,
            ],
            scanned: 0,
        };
        let b = ShardAnnResult {
            best: vec![
                Some(AnnAnswer { shard: 1, id: 7, dist: 1.0 }),
                Some(AnnAnswer { shard: 1, id: 8, dist: 3.0 }),
            ],
            scanned: 0,
        };
        let merged = merge_ann(&[a, b], 2);
        assert_eq!(merged[0].as_ref().unwrap().id, 7);
        assert_eq!(merged[1].as_ref().unwrap().id, 8);
    }

    #[test]
    fn merge_ann_all_none() {
        let a = ShardAnnResult { best: vec![None, None], scanned: 0 };
        let merged = merge_ann(&[a], 2);
        assert!(merged.iter().all(Option::is_none));
    }

    #[test]
    fn stats_from_registry_reads_all_counter_fields() {
        let reg = Registry::new();
        reg.inserts.add(100);
        reg.shed(7);
        reg.ann_queries.add(3);
        let st = ServiceStats::from_registry(&reg);
        assert_eq!(st.inserts, 100);
        assert_eq!(st.shed, 7);
        assert_eq!(st.ann_queries, 3);
        assert_eq!(st.deletes, 0);
        assert_eq!(st.stored_points, 0, "shard fields left for the service");
        assert_eq!(reg.shed_points.get(), 7);
    }

    #[test]
    fn merged_stats_sum_shard_fields_and_skip_counters() {
        let a = ServiceStats {
            inserts: 100,
            stored_points: 40,
            sketch_bytes: 1000,
            replicas: 2,
            replica_depths: vec![0, 1],
            health: vec![0, 0],
            wal_errors: 1,
            refused_writes: 3,
            ..ServiceStats::default()
        };
        let b = ServiceStats {
            inserts: 50,
            stored_points: 20,
            sketch_bytes: 500,
            replicas: 1,
            replica_depths: vec![2],
            health: vec![1],
            ..ServiceStats::default()
        };
        let m = ServiceStats::merged(&[a, b]);
        assert_eq!(m.stored_points, 60);
        assert_eq!(m.sketch_bytes, 1500);
        assert_eq!(m.wal_errors, 1);
        assert_eq!(m.refused_writes, 3);
        assert_eq!(m.replica_depths, vec![0, 1, 2]);
        assert_eq!(m.health, vec![0, 0, 1], "member order = shard order");
        assert_eq!(m.replicas, 1, "availability floor across members");
        assert_eq!(m.inserts, 0, "counters belong to the router's registry");
    }

    #[test]
    fn merge_kde_sums_and_population() {
        let a = ShardKdeResult { kernel_sums: vec![1.0, 2.0], population: 10 };
        let b = ShardKdeResult { kernel_sums: vec![0.5, 0.5], population: 5 };
        let (sums, pop) = merge_kde(&[a, b], 2);
        assert_eq!(sums, vec![1.5, 2.5]);
        assert_eq!(pop, 15);
        assert_eq!(kde_densities(&sums, pop), vec![1.5 / 15.0, 2.5 / 15.0]);
        assert_eq!(kde_densities(&sums, 0), vec![0.0, 0.0], "empty window");
    }
}
