//! Bounded ingestion with load-shedding — the coordinator's backpressure
//! policy. A `BoundedSender` wraps `std::sync::mpsc::SyncSender` with an
//! explicit policy: `Block` (lossless, producer waits) or `Shed` (drop the
//! newest element and count it — the right behavior for best-effort
//! sketch maintenance under overload, since both sketches tolerate
//! subsampling by design: S-ANN *is* a sampler and RACE/SW-AKDE are
//! population estimators).

use crate::util::sync::atomic::{AtomicU64, Ordering};
use crate::util::sync::mpsc::{Receiver, SyncSender, TrySendError};
use crate::util::sync::Arc;

/// Overload policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Overload {
    /// Producer blocks until the queue drains (lossless).
    Block,
    /// Drop the element and count it (bounded-latency ingestion).
    Shed,
}

/// What happened to an offered element. `Shed` (queue full under the
/// `Shed` policy) is overload and counts toward shedding statistics;
/// `Disconnected` (receiver gone — the shard is shutting down or dead)
/// is NOT overload and must never be accounted as a shed point.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OfferOutcome {
    /// Delivered into the queue.
    Sent,
    /// Dropped by the `Shed` policy (queue full); counted in `shed_count`.
    Shed,
    /// The receiver is gone; nothing was counted.
    Disconnected,
}

/// Sender side of a bounded queue with shedding statistics.
///
/// Both counters are `Relaxed`-only diagnostics: the channel itself is
/// the synchronization (a `Sent` outcome happens-before the receiver's
/// `recv` of that element), and nothing branches on these counts.
pub struct BoundedSender<T> {
    tx: SyncSender<T>,
    policy: Overload,
    shed: Arc<AtomicU64>,
    sent: Arc<AtomicU64>,
}

impl<T> Clone for BoundedSender<T> {
    fn clone(&self) -> Self {
        BoundedSender {
            tx: self.tx.clone(),
            policy: self.policy,
            shed: Arc::clone(&self.shed),
            sent: Arc::clone(&self.sent),
        }
    }
}

/// Create a bounded channel with the given capacity and overload policy.
pub fn bounded<T>(cap: usize, policy: Overload) -> (BoundedSender<T>, Receiver<T>) {
    let (tx, rx) = crate::util::sync::mpsc::sync_channel(cap);
    (
        BoundedSender {
            tx,
            policy,
            shed: Arc::new(AtomicU64::new(0)),
            sent: Arc::new(AtomicU64::new(0)),
        },
        rx,
    )
}

impl<T> BoundedSender<T> {
    /// Offer an element under the configured policy. Returns false iff the
    /// element was shed (or the receiver is gone).
    pub fn offer(&self, item: T) -> bool {
        self.offer_outcome(item) == OfferOutcome::Sent
    }

    /// Like [`Self::offer`], but reports WHY an element was not
    /// delivered, so callers doing point-denominated accounting can
    /// distinguish overload (`Shed`) from shutdown (`Disconnected`).
    pub fn offer_outcome(&self, item: T) -> OfferOutcome {
        match self.policy {
            Overload::Block => {
                if self.tx.send(item).is_ok() {
                    self.sent.fetch_add(1, Ordering::Relaxed);
                    OfferOutcome::Sent
                } else {
                    OfferOutcome::Disconnected
                }
            }
            Overload::Shed => match self.tx.try_send(item) {
                Ok(()) => {
                    self.sent.fetch_add(1, Ordering::Relaxed);
                    OfferOutcome::Sent
                }
                Err(TrySendError::Full(_)) => {
                    self.shed.fetch_add(1, Ordering::Relaxed);
                    OfferOutcome::Shed
                }
                Err(TrySendError::Disconnected(_)) => OfferOutcome::Disconnected,
            },
        }
    }

    /// Deliver regardless of policy (control-plane messages: queries,
    /// stats, shutdown — these carry reply channels and must not be shed).
    /// Returns false only if the receiver is gone.
    pub fn force(&self, item: T) -> bool {
        self.force_or_return(item).is_ok()
    }

    /// Like [`Self::force`], but hands the item back when the receiver is
    /// gone — so a caller with somewhere else to send it (a read against
    /// a dead replica retrying a live one) doesn't lose the command.
    pub fn force_or_return(&self, item: T) -> Result<(), T> {
        match self.tx.send(item) {
            Ok(()) => {
                self.sent.fetch_add(1, Ordering::Relaxed);
                Ok(())
            }
            Err(e) => Err(e.0),
        }
    }

    pub fn shed_count(&self) -> u64 {
        self.shed.load(Ordering::Relaxed)
    }

    pub fn sent_count(&self) -> u64 {
        self.sent.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn shed_policy_drops_when_full() {
        let (tx, rx) = bounded::<u32>(2, Overload::Shed);
        assert!(tx.offer(1));
        assert!(tx.offer(2));
        assert!(!tx.offer(3), "queue full -> shed");
        assert_eq!(tx.shed_count(), 1);
        assert_eq!(tx.sent_count(), 2);
        assert_eq!(rx.recv().unwrap(), 1);
        assert!(tx.offer(4), "capacity freed");
    }

    #[test]
    fn block_policy_waits_for_drain() {
        let (tx, rx) = bounded::<u32>(1, Overload::Block);
        assert!(tx.offer(1));
        let t = std::thread::spawn(move || {
            // this blocks until the main thread drains
            assert!(tx.offer(2));
            tx.shed_count()
        });
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(rx.recv().unwrap(), 1);
        assert_eq!(t.join().unwrap(), 0, "block policy never sheds");
        assert_eq!(rx.recv().unwrap(), 2);
    }

    #[test]
    fn disconnected_receiver_reports_failure() {
        let (tx, rx) = bounded::<u32>(1, Overload::Shed);
        drop(rx);
        assert!(!tx.offer(1));
    }

    #[test]
    fn offer_outcome_distinguishes_shed_from_disconnect() {
        let (tx, rx) = bounded::<u32>(1, Overload::Shed);
        assert_eq!(tx.offer_outcome(1), OfferOutcome::Sent);
        assert_eq!(tx.offer_outcome(2), OfferOutcome::Shed);
        assert_eq!(tx.shed_count(), 1);
        drop(rx);
        assert_eq!(tx.offer_outcome(3), OfferOutcome::Disconnected);
        assert_eq!(tx.shed_count(), 1, "a dead receiver is not overload");

        let (tx, rx) = bounded::<u32>(1, Overload::Block);
        assert_eq!(tx.offer_outcome(1), OfferOutcome::Sent);
        drop(rx);
        assert_eq!(tx.offer_outcome(2), OfferOutcome::Disconnected);
        assert_eq!(tx.shed_count(), 0, "Block never sheds");
    }

    #[test]
    fn no_deadlock_under_concurrent_producers() {
        let (tx, rx) = bounded::<u64>(8, Overload::Shed);
        let producers: Vec<_> = (0..4)
            .map(|i| {
                let tx = tx.clone();
                std::thread::spawn(move || {
                    for j in 0..1000u64 {
                        tx.offer(i * 1000 + j);
                    }
                })
            })
            .collect();
        let consumer = std::thread::spawn(move || {
            let mut n = 0u64;
            while let Ok(_) = rx.recv_timeout(Duration::from_millis(200)) {
                n += 1;
            }
            n
        });
        for p in producers {
            p.join().unwrap();
        }
        drop(tx);
        let received = consumer.join().unwrap();
        assert!(received > 0);
        assert!(received <= 4000);
    }
}
