//! Dynamic query batcher (§3.3 batch queries): accumulate items until a
//! size cap or a deadline, whichever fires first, then hand the batch to a
//! processor. Both sketches answer batches far more efficiently than
//! singles — hashing and re-ranking become one PJRT artifact call — so the
//! batcher is the front door of the serving path.

use std::time::{Duration, Instant};

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Flush when this many items are pending.
    pub max_batch: usize,
    /// Flush when the oldest pending item has waited this long.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 64, max_wait: Duration::from_millis(2) }
    }
}

/// Accumulates items and reports when a flush is due.
pub struct Batcher<T> {
    policy: BatchPolicy,
    pending: Vec<T>,
    oldest: Option<Instant>,
    pub batches_flushed: u64,
    pub items_seen: u64,
}

impl<T> Batcher<T> {
    pub fn new(policy: BatchPolicy) -> Self {
        assert!(policy.max_batch > 0);
        Batcher { policy, pending: Vec::new(), oldest: None, batches_flushed: 0, items_seen: 0 }
    }

    pub fn len(&self) -> usize {
        self.pending.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// Add an item; returns a full batch if the size cap fired.
    pub fn push(&mut self, item: T) -> Option<Vec<T>> {
        if self.pending.is_empty() {
            self.oldest = Some(Instant::now());
        }
        self.pending.push(item);
        self.items_seen += 1;
        if self.pending.len() >= self.policy.max_batch {
            return Some(self.flush());
        }
        None
    }

    /// Retarget the flush deadline (load-aware coalescing: the caller
    /// scales `max_wait` with observed load). Applies to the CURRENT
    /// pending set too — `deadline_due` always compares against the live
    /// policy, so lowering the wait can make a parked batch due at once.
    pub fn set_max_wait(&mut self, d: Duration) {
        self.policy.max_wait = d;
    }

    /// The currently configured flush deadline.
    pub fn max_wait(&self) -> Duration {
        self.policy.max_wait
    }

    /// Whether the deadline has expired for the oldest pending item.
    pub fn deadline_due(&self) -> bool {
        self.oldest
            .map(|t| t.elapsed() >= self.policy.max_wait)
            .unwrap_or(false)
    }

    /// Time until the deadline fires (None when empty).
    pub fn time_to_deadline(&self) -> Option<Duration> {
        self.oldest
            .map(|t| self.policy.max_wait.saturating_sub(t.elapsed()))
    }

    /// Take the pending batch.
    pub fn flush(&mut self) -> Vec<T> {
        self.oldest = None;
        if !self.pending.is_empty() {
            self.batches_flushed += 1;
        }
        std::mem::take(&mut self.pending)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn size_cap_flushes() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 3, max_wait: Duration::from_secs(10) });
        assert!(b.push(1).is_none());
        assert!(b.push(2).is_none());
        let batch = b.push(3).expect("size cap");
        assert_eq!(batch, vec![1, 2, 3]);
        assert!(b.is_empty());
        assert_eq!(b.batches_flushed, 1);
    }

    #[test]
    fn deadline_fires_for_partial_batch() {
        let mut b = Batcher::new(BatchPolicy {
            max_batch: 100,
            max_wait: Duration::from_millis(5),
        });
        b.push(42);
        assert!(!b.deadline_due());
        std::thread::sleep(Duration::from_millis(8));
        assert!(b.deadline_due());
        assert_eq!(b.flush(), vec![42]);
        assert!(!b.deadline_due(), "empty batcher has no deadline");
    }

    #[test]
    fn never_exceeds_max_batch() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 4, max_wait: Duration::from_secs(1) });
        let mut sizes = Vec::new();
        for i in 0..21 {
            if let Some(batch) = b.push(i) {
                sizes.push(batch.len());
            }
        }
        sizes.push(b.flush().len());
        assert!(sizes.iter().all(|&s| s <= 4), "sizes={sizes:?}");
        assert_eq!(sizes.iter().sum::<usize>(), 21, "no item lost");
    }

    #[test]
    fn set_max_wait_retargets_the_pending_deadline() {
        let mut b = Batcher::new(BatchPolicy {
            max_batch: 100,
            max_wait: Duration::from_secs(60),
        });
        b.push(1);
        assert!(!b.deadline_due(), "a minute out");
        b.set_max_wait(Duration::ZERO);
        assert!(b.deadline_due(), "zero wait makes the pending item due now");
        assert_eq!(b.max_wait(), Duration::ZERO);
        assert_eq!(b.flush(), vec![1]);
    }

    #[test]
    fn flush_on_empty_is_empty_and_uncounted() {
        let mut b = Batcher::<u8>::new(BatchPolicy::default());
        assert!(b.flush().is_empty());
        assert_eq!(b.batches_flushed, 0);
    }
}
