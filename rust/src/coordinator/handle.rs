//! `ServiceHandle` — the concurrency seam between connection threads and
//! the data plane, now topology-agnostic.
//!
//! A handle fronts a list of [`ShardBackend`]s (one [`LocalBackend`] per
//! shard of an in-process service, or one [`RemoteBackend`] per member
//! node under `sketchd route`) and splits the API by what it needs:
//!
//! - **Ingest / deletes** touch only the router policy and the backends,
//!   both cloneable — so they run ON the calling thread and go straight
//!   into the per-shard bounded queues (or out the member-node sockets).
//!   A query can therefore never sit behind a backlog of queued inserts:
//!   backpressure lives in the backends, not in a service-wide command
//!   queue.
//! - **Native ANN/KDE queries** run ON the calling thread too, through a
//!   [`QueryPlane`] clone (scatter to backends, collect, merge) — K
//!   connection threads read concurrently, limited by the shards, not by
//!   a single service-wide reader.
//! - **PJRT queries, stats, flush, checkpoint** need an owner: on a
//!   single-process service they ship over an unbounded control channel
//!   to the owning thread ([`SketchService::run_cmd_loop`]); on a routed
//!   front-end control ops fan out to every member node and merge.
//!
//! All counting is shared through the metrics [`Registry`],
//! point-denominated. Only genuine overload counts as shed; a
//! disconnected backend (service shutting down, node gone) is a failed
//! offer but never a shed point.
//!
//! [`SketchService`]: super::server::SketchService
//! [`LocalBackend`]: super::backend::LocalBackend
//! [`RemoteBackend`]: super::backend::RemoteBackend

use crate::metrics::registry::Registry;
use crate::obs::log;
use crate::util::sync::atomic::{AtomicUsize, Ordering};
use crate::util::sync::mpsc::{channel, Sender};
use crate::util::sync::Arc;

use anyhow::{anyhow, bail, Result};

use super::backend::{local_backends, IngestOutcome, RemoteBackend, ShardBackend};
use super::health::{HealthBoard, ShardHealth};
use super::protocol::{AnnAnswer, ServiceStats, ShardAnnResult, ShardKdeResult};
use super::query::QueryPlane;
use super::replica::ReplicaSet;
use super::router::{hash_vector, RoutePolicy};
use super::NATIVE_BATCH_ROWS;

/// The ONE batched-ingest core, shared by `SketchService`'s batch path,
/// [`ServiceHandle::insert_batch`], and the router fan-out, so the wire
/// ⇔ in-process state-parity guarantee is structural, not
/// copy-maintained: identical chunking ([`NATIVE_BATCH_ROWS`]),
/// identical point-denominated counting. `offer(backend, chunk)` reports
/// the chunk's fate: accepted and shed points count where they landed —
/// a [`IngestOutcome::Disconnected`] backend's points never entered the
/// service and are un-counted from `inserts`, so `inserts == stored +
/// shed` stays exact even when backends die.
pub(super) fn ship_native_batch(
    registry: &Registry,
    per_backend: Vec<Vec<Vec<f32>>>,
    mut offer: impl FnMut(usize, Vec<Vec<f32>>) -> IngestOutcome,
) -> usize {
    let mut ok = 0;
    for (s, mut pts) in per_backend.into_iter().enumerate() {
        while !pts.is_empty() {
            let tail = pts.split_off(pts.len().min(NATIVE_BATCH_ROWS));
            let chunk = std::mem::replace(&mut pts, tail);
            let m = chunk.len();
            registry.inserts.add(m as u64);
            match offer(s, chunk) {
                IngestOutcome::Accepted { accepted, shed } => {
                    ok += accepted;
                    if shed > 0 {
                        registry.shed(shed as u64);
                    }
                }
                // Not overload: the points never entered the service —
                // un-count them so inserts == stored + shed stays exact.
                IngestOutcome::Disconnected => registry.inserts.sub(m as u64),
            }
        }
    }
    ok
}

/// Control-plane commands a handle sends to the service-owning thread.
/// Native reads never travel here anymore (they execute on the calling
/// thread via [`QueryPlane`]); `Ann` remains for PJRT services, whose
/// re-rank needs the thread-pinned executor. KDE never does — there is
/// no `Kde` command. The `Ann` reply carries a `Result` so a degraded
/// scatter (dead shard) surfaces as an error instead of a silently
/// partial answer.
pub enum ServiceCmd {
    Ann(Vec<Vec<f32>>, Sender<Result<Vec<Option<AnnAnswer>>, String>>),
    Stats(Sender<ServiceStats>),
    /// Barrier; the reply carries the WAL-sync outcome on durable
    /// services (a flush ack must not claim durability the disk refused).
    Flush(Sender<Result<(), String>>),
    /// Cut a whole-service checkpoint; replies with the number of points
    /// it covers (the inserts counter at checkpoint time). Errors travel
    /// as strings so the reply stays plain data.
    Checkpoint(Sender<Result<u64, String>>),
    Shutdown,
}

/// Who answers the control plane: the owning thread of one in-process
/// service, or a fan-out over member nodes (stats merge, flush and
/// checkpoint barrier every node, shutdown cascades).
enum Control {
    Service(Sender<ServiceCmd>),
    Fanout(Vec<Arc<RemoteBackend>>),
}

impl Clone for Control {
    fn clone(&self) -> Self {
        match self {
            Control::Service(tx) => Control::Service(tx.clone()),
            Control::Fanout(nodes) => Control::Fanout(nodes.clone()),
        }
    }
}

/// Cloneable, `Send` front to one running [`SketchService`] — or, built
/// via [`ServiceHandle::for_router`], to a whole fleet of them.
///
/// Routing caveat: under `RoutePolicy::RoundRobin` the handle's shared
/// cursor is independent of the service's own `Router` cursor, so mixing
/// direct service ingest with handle ingest round-robins each stream
/// separately (`HashVector`, the default, is stateless and unaffected).
/// The wire-vs-in-process parity tests pin `HashVector`.
///
/// PJRT caveat: handle ingest always ships native `InsertBatch` commands
/// (shard-side batched hashing) — the executor is pinned to the owning
/// thread, so its buffered GEMM-ingest path (`flush_shard_ingest`) only
/// serves direct `SketchService::insert_batch` callers. On a `use_pjrt`
/// service, the artifact accelerates the QUERY path for wire traffic.
///
/// [`SketchService`]: super::server::SketchService
pub struct ServiceHandle {
    backends: Vec<Arc<dyn ShardBackend>>,
    /// First global shard of each backend (prefix sums of their sizes):
    /// `backend_of` maps a routed global shard to its owner.
    bases: Vec<usize>,
    /// The raw replica sets behind local backends (empty on a router
    /// handle) — kept for the fault-injection crash/heal hooks, which
    /// are inherently in-process.
    sets: Vec<ReplicaSet>,
    route: RoutePolicy,
    /// Round-robin cursor shared across clones so the partition stays
    /// balanced no matter which connection inserts.
    rr_next: Arc<AtomicUsize>,
    registry: Arc<Registry>,
    /// Per-shard durability health, read lock-free (no service-thread
    /// round-trip) for Hello and degraded-mode serving decisions. On a
    /// router this is seeded from member handshakes and refreshed by
    /// stats polls.
    board: Arc<HealthBoard>,
    control: Control,
    /// Calling-thread native read path (scatter/collect/merge).
    plane: QueryPlane,
    /// When true, queries must run on the owning thread (the PJRT
    /// executor is pinned there), so they travel over the control
    /// channel.
    use_pjrt: bool,
    dim: usize,
    /// Total GLOBAL shards behind this handle.
    shards: usize,
    /// First global shard this handle's process serves (nonzero only on
    /// a member node of a routed deployment; advertised in Hello).
    shard_base: usize,
}

impl Clone for ServiceHandle {
    fn clone(&self) -> Self {
        ServiceHandle {
            backends: self.backends.clone(),
            bases: self.bases.clone(),
            sets: self.sets.clone(),
            route: self.route,
            rr_next: Arc::clone(&self.rr_next),
            registry: Arc::clone(&self.registry),
            board: Arc::clone(&self.board),
            control: self.control.clone(),
            plane: self.plane.clone(),
            use_pjrt: self.use_pjrt,
            dim: self.dim,
            shards: self.shards,
            shard_base: self.shard_base,
        }
    }
}

impl ServiceHandle {
    #[allow(clippy::too_many_arguments)]
    pub(super) fn new(
        sets: Vec<ReplicaSet>,
        route: RoutePolicy,
        dim: usize,
        shards: usize,
        shard_base: usize,
        registry: Arc<Registry>,
        board: Arc<HealthBoard>,
        cmd_tx: Sender<ServiceCmd>,
        use_pjrt: bool,
    ) -> Self {
        let backends = local_backends(sets.clone(), shard_base, Some(&board));
        let bases = (0..backends.len()).collect();
        let plane = QueryPlane::new(backends.clone(), Arc::clone(&registry));
        ServiceHandle {
            backends,
            bases,
            sets,
            route,
            rr_next: Arc::new(AtomicUsize::new(0)),
            registry,
            board,
            control: Control::Service(cmd_tx),
            plane,
            use_pjrt,
            dim,
            shards,
            shard_base,
        }
    }

    /// A front-end handle over member nodes: the same plane, the same
    /// merge folds, the same degradation contract — backends happen to
    /// be remote. The health board is seeded from each node's handshake
    /// (cells in member order = global shard order) and refreshed on
    /// stats polls.
    pub fn for_router(
        nodes: Vec<Arc<RemoteBackend>>,
        route: RoutePolicy,
        dim: usize,
        registry: Arc<Registry>,
    ) -> Self {
        let backends: Vec<Arc<dyn ShardBackend>> = nodes
            .iter()
            .map(|n| Arc::clone(n) as Arc<dyn ShardBackend>)
            .collect();
        let mut bases = Vec::with_capacity(backends.len());
        let mut shards = 0usize;
        for b in &backends {
            bases.push(shards);
            shards += b.shards();
        }
        let board = Arc::new(HealthBoard::new(shards));
        for (i, h) in backends.iter().flat_map(|b| b.health()).enumerate() {
            board.escalate(i, ShardHealth::from_u8(h));
        }
        let plane = QueryPlane::new(backends.clone(), Arc::clone(&registry));
        ServiceHandle {
            backends,
            bases,
            sets: Vec::new(),
            route,
            rr_next: Arc::new(AtomicUsize::new(0)),
            registry,
            board,
            control: Control::Fanout(nodes),
            plane,
            use_pjrt: false,
            dim,
            shards,
            shard_base: 0,
        }
    }

    /// Vector dimensionality the service was configured with.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// The shared metrics registry every clone records into (the wire
    /// dispatch layer reads per-op histograms and the slow-query
    /// threshold off it, and serves `Metrics` snapshots from it).
    pub fn registry(&self) -> &Arc<Registry> {
        &self.registry
    }

    /// Per-shard durability health vector (`ShardHealth as u8` each),
    /// read lock-free off the shared board.
    pub fn health_vector(&self) -> Vec<u8> {
        self.board.vector()
    }

    /// Worst shard health across the service (what `Hello` summarizes).
    pub fn health_worst(&self) -> ShardHealth {
        self.board.worst()
    }

    /// Total GLOBAL shards behind this handle.
    pub fn shards(&self) -> usize {
        self.shards
    }

    /// First global shard this process serves (v5 Hello advertisement;
    /// 0 everywhere except member nodes booted with `--shard-base`).
    pub fn shard_base(&self) -> usize {
        self.shard_base
    }

    /// Replicas per shard (R) the service was configured with.
    pub fn replicas(&self) -> usize {
        self.plane.replicas()
    }

    /// Fault-injection hook: panic one replica thread of one shard via
    /// the injected-crash command, simulating a replica death for the
    /// supervisor to detect and heal. Returns false if the mailbox was
    /// already closed (replica already dead).
    #[cfg(feature = "fault-injection")]
    pub fn crash_replica(&self, shard: usize, replica: usize) -> bool {
        self.sets[shard].crash_replica(replica)
    }

    /// Cumulative reads served per replica of one shard (diagnostics;
    /// the fault suite uses it to see reads land on a healed copy).
    #[cfg(feature = "fault-injection")]
    pub fn replica_reads(&self, shard: usize) -> Vec<u64> {
        self.sets[shard].reads_served()
    }

    /// Route one vector to a GLOBAL shard. On a member node "global"
    /// spans only its local shards — but because shard counts divide
    /// evenly and ranges are contiguous, `h % S_node` lands each point
    /// on exactly the shard `h % S_total` names globally (see
    /// EXPERIMENTS.md §Multi-node for the congruence argument).
    fn route(&self, x: &[f32]) -> usize {
        match self.route {
            RoutePolicy::HashVector => hash_vector(x) as usize % self.shards,
            RoutePolicy::RoundRobin => {
                self.rr_next.fetch_add(1, Ordering::Relaxed) % self.shards
            }
        }
    }

    /// Which backend owns global shard `g`.
    fn backend_of(&self, g: usize) -> usize {
        self.bases.partition_point(|&b| b <= g).saturating_sub(1)
    }

    /// Offer one stream element to the DEFAULT collection — see
    /// [`Self::insert_in`].
    pub fn insert(&self, x: Vec<f32>) -> bool {
        self.insert_in(0, x)
    }

    /// Offer one stream element of collection `coll` under the overload
    /// policy. Returns false if it was not delivered. Only a genuine
    /// shed (queue full) counts toward the shed statistic — a
    /// disconnected backend (service shutting down, node gone) fails the
    /// offer and rolls back its insert count instead of inventing
    /// overload. On a single-service handle the collection was resolved
    /// BEFORE this call (local backends ignore the id); on a router it
    /// crosses the wire to the member nodes.
    pub fn insert_in(&self, coll: u32, x: Vec<f32>) -> bool {
        let be = &self.backends[self.backend_of(self.route(&x))];
        self.registry.inserts.add(1);
        match be.offer(coll, vec![x]) {
            IngestOutcome::Accepted { accepted, shed } => {
                if shed > 0 {
                    self.registry.shed(shed as u64);
                }
                accepted == 1
            }
            IngestOutcome::Disconnected => {
                self.registry.inserts.sub(1);
                false
            }
        }
    }

    /// Batched ingest into the DEFAULT collection — see
    /// [`Self::insert_batch_in`].
    pub fn insert_batch(&self, batch: Vec<Vec<f32>>) -> usize {
        self.insert_batch_in(0, batch)
    }

    /// Batched ingest through [`ship_native_batch`] — the same core the
    /// service's native `insert_batch` path runs, so chunk boundaries and
    /// accounting are identical by construction. Returns accepted points.
    pub fn insert_batch_in(&self, coll: u32, batch: Vec<Vec<f32>>) -> usize {
        let mut per_backend: Vec<Vec<Vec<f32>>> = vec![Vec::new(); self.backends.len()];
        for x in batch {
            per_backend[self.backend_of(self.route(&x))].push(x);
        }
        ship_native_batch(&self.registry, per_backend, |s, chunk| {
            self.backends[s].offer(coll, chunk)
        })
    }

    /// Turnstile deletion from the DEFAULT collection — see
    /// [`Self::delete_in`].
    pub fn delete(&self, x: Vec<f32>) -> bool {
        self.delete_in(0, x)
    }

    /// Turnstile deletion (HashVector routing only); forced past the
    /// overload policy like every command carrying a reply channel.
    ///
    /// The `deletes` counter tracks commands the owning shard actually
    /// ACKNOWLEDGED: a force into a dead backend, or a shard dying before
    /// the ack, does not count — otherwise the counter drifts above the
    /// applied work and never reconciles with recovered state.
    pub fn delete_in(&self, coll: u32, x: Vec<f32>) -> bool {
        let Some(g) = (match self.route {
            RoutePolicy::HashVector => Some(hash_vector(&x) as usize % self.shards),
            RoutePolicy::RoundRobin => None,
        }) else {
            return false;
        };
        match self.backends[self.backend_of(g)].delete(coll, x) {
            Some(removed) => {
                self.registry.deletes.add(1);
                removed
            }
            None => false,
        }
    }

    fn call<T>(&self, make: impl FnOnce(Sender<T>) -> ServiceCmd) -> Result<T> {
        let Control::Service(cmd_tx) = &self.control else {
            bail!("router handles fan control ops out; no owning thread to call");
        };
        let (tx, rx) = channel();
        cmd_tx
            .send(make(tx))
            .map_err(|_| anyhow!("service thread is gone"))?;
        rx.recv()
            .map_err(|_| anyhow!("service thread dropped the reply"))
    }

    /// Batched (c, r)-ANN against the DEFAULT collection. On a native
    /// service this executes the whole scatter/collect/merge ON the
    /// calling thread via the [`QueryPlane`] — concurrent across
    /// handles/connections, never serialized through the owning thread.
    /// On a PJRT service the batch travels to the owning thread, where
    /// the executor lives. Either way a dead backend is an error, never
    /// a silently partial answer.
    pub fn query_batch(&self, queries: Vec<Vec<f32>>) -> Result<Vec<Option<AnnAnswer>>> {
        self.query_batch_traced(queries, 0)
    }

    /// [`Self::query_batch`] with the wire trace id carried to every
    /// backend (and across the router→node hop on a fanned deployment).
    pub fn query_batch_traced(
        &self,
        queries: Vec<Vec<f32>>,
        trace: u64,
    ) -> Result<Vec<Option<AnnAnswer>>> {
        self.query_batch_traced_in(0, queries, trace)
    }

    /// [`Self::query_batch_traced`] against collection `coll`. The PJRT
    /// re-rank path only exists behind a single-service control channel,
    /// where the collection was resolved before this call — so the id is
    /// only forwarded on the native plane.
    pub fn query_batch_traced_in(
        &self,
        coll: u32,
        queries: Vec<Vec<f32>>,
        trace: u64,
    ) -> Result<Vec<Option<AnnAnswer>>> {
        if self.use_pjrt {
            self.call(|tx| ServiceCmd::Ann(queries, tx))?
                .map_err(|e| anyhow!("ANN query failed: {e}"))
        } else {
            self.plane.ann_batch_traced(coll, queries, trace)
        }
    }

    /// Batched sliding-window KDE (kernel sums, densities) against the
    /// DEFAULT collection, always on the calling thread: KDE reads never
    /// touch the PJRT executor, so even on a PJRT service they scatter
    /// straight from here.
    pub fn kde_batch(&self, queries: Vec<Vec<f32>>) -> Result<(Vec<f64>, Vec<f64>)> {
        self.kde_batch_traced(queries, 0)
    }

    /// [`Self::kde_batch`] with the wire trace id carried through.
    pub fn kde_batch_traced(
        &self,
        queries: Vec<Vec<f32>>,
        trace: u64,
    ) -> Result<(Vec<f64>, Vec<f64>)> {
        self.kde_batch_traced_in(0, queries, trace)
    }

    /// [`Self::kde_batch_traced`] against collection `coll`.
    pub fn kde_batch_traced_in(
        &self,
        coll: u32,
        queries: Vec<Vec<f32>>,
        trace: u64,
    ) -> Result<(Vec<f64>, Vec<f64>)> {
        self.plane.kde_batch_traced(coll, queries, trace)
    }

    /// RAW per-shard ANN partials in global shard order (the wire
    /// `AnnPartial` op's spine): what a front-end merges is exactly what
    /// an in-process plane would merge. PJRT re-rank never applies here
    /// — partials are a native-path contract. The collection id crosses
    /// the router→node hop (protocol v6); v5 frames decode as 0.
    pub fn ann_partials(
        &self,
        coll: u32,
        queries: Vec<Vec<f32>>,
        trace: u64,
    ) -> Result<Vec<ShardAnnResult>> {
        self.plane.ann_partials(coll, queries, trace)
    }

    /// RAW per-shard KDE partials in global shard order (`KdePartial`).
    pub fn kde_partials(
        &self,
        coll: u32,
        queries: Vec<Vec<f32>>,
        trace: u64,
    ) -> Result<Vec<ShardKdeResult>> {
        self.plane.kde_partials(coll, queries, trace)
    }

    /// Aggregate statistics. Single service: drains shard mailboxes on
    /// the owning thread. Router: polls every member, merges the
    /// shard-resident fields in member order (= global shard order),
    /// reports the router's OWN counters (each member also counted the
    /// fanned ops; summing would double-count), and refreshes the
    /// router's occupancy gauges + health board from the merge.
    pub fn stats(&self) -> Result<ServiceStats> {
        self.stats_in(0)
    }

    /// [`Self::stats`] for collection `coll` (meaningful on a router,
    /// where the id is forwarded to every member node; a single-service
    /// handle already IS one collection and ignores it).
    pub fn stats_in(&self, coll: u32) -> Result<ServiceStats> {
        match &self.control {
            Control::Service(_) => self.call(ServiceCmd::Stats),
            Control::Fanout(nodes) => {
                let mut parts = Vec::with_capacity(nodes.len());
                for n in nodes {
                    parts.push(n.stats(coll).map_err(|e| anyhow!("stats failed: {e}"))?);
                }
                let mut out = ServiceStats::merged(&parts);
                let own = ServiceStats::from_registry(&self.registry);
                out.inserts = own.inserts;
                out.deletes = own.deletes;
                out.ann_queries = own.ann_queries;
                out.kde_queries = own.kde_queries;
                out.shed = own.shed;
                self.registry.stored_points.set(out.stored_points as u64);
                self.registry.sketch_bytes.set(out.sketch_bytes as u64);
                for (i, &h) in out.health.iter().enumerate() {
                    if i < self.shards {
                        self.board.escalate(i, ShardHealth::from_u8(h));
                    }
                }
                Ok(out)
            }
        }
    }

    /// Barrier: all inserts offered BEFORE this call (from this thread)
    /// are applied when it returns Ok — and, on a durable service, synced
    /// to the WAL (a sync failure surfaces here, never as a silent ack).
    /// On a router the barrier spans every member node.
    pub fn flush(&self) -> Result<()> {
        self.flush_in(0)
    }

    /// [`Self::flush`] for collection `coll` (forwarded on a router).
    pub fn flush_in(&self, coll: u32) -> Result<()> {
        match &self.control {
            Control::Service(_) => self
                .call(ServiceCmd::Flush)?
                .map_err(|e| anyhow!("flush failed: {e}")),
            Control::Fanout(nodes) => {
                for n in nodes {
                    n.flush(coll).map_err(|e| anyhow!("flush failed: {e}"))?;
                }
                Ok(())
            }
        }
    }

    /// Cut a whole-service checkpoint (durable services only). Returns
    /// the number of points the checkpoint covers; on a router, the sum
    /// over members (each checkpoints its own durability root).
    pub fn checkpoint(&self) -> Result<u64> {
        self.checkpoint_in(0)
    }

    /// [`Self::checkpoint`] for collection `coll` (forwarded on a
    /// router; each member cuts the named collection's own subtree).
    pub fn checkpoint_in(&self, coll: u32) -> Result<u64> {
        match &self.control {
            Control::Service(_) => self
                .call(ServiceCmd::Checkpoint)?
                .map_err(|e| anyhow!("checkpoint failed: {e}")),
            Control::Fanout(nodes) => {
                let mut covered = 0u64;
                for n in nodes {
                    covered +=
                        n.checkpoint(coll).map_err(|e| anyhow!("checkpoint failed: {e}"))?;
                }
                Ok(covered)
            }
        }
    }

    /// True when this handle fans out to member nodes (`sketchd route`):
    /// the wire dispatch then forwards collection ids through this
    /// handle instead of resolving them against a local tenant registry.
    pub fn is_fanout(&self) -> bool {
        matches!(self.control, Control::Fanout(_))
    }

    /// Router fan-out of `CreateCollection`: every member node must host
    /// the collection for partials to resolve. Returns the info from the
    /// FIRST node (ids are deterministic — every node allocates from the
    /// same monotonic sequence over the same create order — and the
    /// answer is validated against the rest so divergence is loud).
    pub fn create_collection_fanout(
        &self,
        name: &str,
        spec: &super::tenants::CollectionSpec,
    ) -> Result<super::tenants::CollectionInfo> {
        let Control::Fanout(nodes) = &self.control else {
            bail!("create_collection_fanout is a router-only operation");
        };
        let mut first: Option<super::tenants::CollectionInfo> = None;
        for n in nodes {
            let info = n
                .create_collection(name, spec)
                .map_err(|e| anyhow!("create collection failed: {e}"))?;
            match &first {
                None => first = Some(info),
                Some(f) if f.id != info.id => bail!(
                    "member nodes disagree on the id of collection {name:?} \
                     ({} vs {}): was a create applied to only part of the fleet?",
                    f.id,
                    info.id
                ),
                Some(_) => {}
            }
        }
        first.ok_or_else(|| anyhow!("router has no member nodes"))
    }

    /// Router fan-out of `DropCollection` (all members, first error wins).
    pub fn drop_collection_fanout(&self, name: &str) -> Result<()> {
        let Control::Fanout(nodes) = &self.control else {
            bail!("drop_collection_fanout is a router-only operation");
        };
        for n in nodes {
            n.drop_collection(name)
                .map_err(|e| anyhow!("drop collection failed: {e}"))?;
        }
        Ok(())
    }

    /// Router `ListCollections`: the first member's listing (members are
    /// kept in lockstep by the fan-out create/drop above).
    pub fn list_collections_fanout(&self) -> Result<Vec<super::tenants::CollectionInfo>> {
        let Control::Fanout(nodes) = &self.control else {
            bail!("list_collections_fanout is a router-only operation");
        };
        let Some(n) = nodes.first() else {
            bail!("router has no member nodes");
        };
        n.list_collections().map_err(|e| anyhow!("list collections failed: {e}"))
    }

    /// Ask the owning thread to shut the service down (idempotent,
    /// best-effort: a missing service thread is already shut down). On a
    /// router the shutdown CASCADES: every member node is asked to shut
    /// down too, so one client `Shutdown` tears the whole deployment
    /// down cleanly.
    pub fn shutdown(&self) {
        match &self.control {
            Control::Service(cmd_tx) => {
                let _ = cmd_tx.send(ServiceCmd::Shutdown);
            }
            Control::Fanout(nodes) => {
                for n in nodes {
                    if let Err(e) = n.shutdown_node() {
                        log::warn(
                            "coordinator::handle",
                            "member node did not acknowledge shutdown",
                            crate::kv!(node = n.addr(), err = e),
                        );
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::server::{ServiceConfig, SketchService};
    use super::super::shard::ShardCmd;
    use super::*;
    use crate::util::rng::Rng;

    fn cfg() -> ServiceConfig {
        let mut cfg = ServiceConfig::default_for(6, 500);
        cfg.shards = 2;
        cfg.ann.eta = 0.0;
        cfg.kde.rows = 8;
        cfg
    }

    #[test]
    fn concurrent_handles_do_not_lose_points() {
        let (handle, join) = SketchService::spawn(cfg()).unwrap();
        let writers: Vec<_> = (0..4)
            .map(|t| {
                let h = handle.clone();
                std::thread::spawn(move || {
                    let mut rng = Rng::new(100 + t);
                    for _ in 0..250 {
                        let p: Vec<f32> = (0..6).map(|_| rng.gaussian_f32()).collect();
                        assert!(h.insert(p), "Block policy never sheds");
                    }
                })
            })
            .collect();
        for w in writers {
            w.join().unwrap();
        }
        handle.flush().unwrap();
        let st = handle.stats().unwrap();
        assert_eq!(st.inserts, 1000);
        assert_eq!(st.shed, 0);
        assert_eq!(st.stored_points, 1000, "eta=0 stores all");
        handle.shutdown();
        join.join().unwrap();
    }

    #[test]
    fn queries_interleave_with_ingest() {
        let (handle, join) = SketchService::spawn(cfg()).unwrap();
        let h = handle.clone();
        let writer = std::thread::spawn(move || {
            let mut rng = Rng::new(9);
            for _ in 0..2000 {
                let p: Vec<f32> = (0..6).map(|_| rng.gaussian_f32()).collect();
                h.insert(p);
            }
        });
        let mut rng = Rng::new(10);
        for _ in 0..20 {
            let qs: Vec<Vec<f32>> = (0..8)
                .map(|_| (0..6).map(|_| rng.gaussian_f32()).collect())
                .collect();
            let ans = handle.query_batch(qs).unwrap();
            assert_eq!(ans.len(), 8, "every query answered mid-ingest");
        }
        writer.join().unwrap();
        handle.shutdown();
        join.join().unwrap();
    }

    #[test]
    fn handle_calls_fail_cleanly_after_shutdown() {
        let (handle, join) = SketchService::spawn(cfg()).unwrap();
        handle.shutdown();
        join.join().unwrap();
        assert!(handle.query_batch(vec![vec![0.0; 6]]).is_err());
        assert!(handle.kde_batch(vec![vec![0.0; 6]]).is_err());
        assert!(handle.stats().is_err());
        // Direct ingest into dead shards reports failure, no panic.
        assert!(!handle.insert(vec![0.0; 6]));
    }

    /// Build a handle over hand-made shard mailboxes (one replica per
    /// shard), with the control channel's receiving end DROPPED: if any
    /// native read were still routed through the owning thread, it would
    /// error immediately instead of reaching the fake shard.
    fn bare_handle(
        shard_txs: Vec<super::super::backpressure::BoundedSender<ShardCmd>>,
        registry: Arc<Registry>,
    ) -> ServiceHandle {
        let (cmd_tx, cmd_rx) = channel::<ServiceCmd>();
        drop(cmd_rx);
        let shards = shard_txs.len();
        ServiceHandle::new(
            shard_txs.into_iter().map(|tx| ReplicaSet::new(vec![tx])).collect(),
            RoutePolicy::HashVector,
            4,
            shards,
            0,
            registry,
            Arc::new(super::super::health::HealthBoard::new(shards)),
            cmd_tx,
            false,
        )
    }

    #[test]
    fn native_query_batches_overlap_not_serialized() {
        use super::super::backpressure::{bounded, Overload};
        use super::super::protocol::ShardAnnResult;
        use std::time::Duration;

        // The instrumented "shard" refuses to answer the FIRST batch
        // until the SECOND has arrived in its mailbox. Two handle
        // threads each issue one batch: this only completes if the
        // second scatter happens while the first is still in flight —
        // i.e. reads run on the calling threads, concurrently. A
        // serialized read path (the old owning-thread loop) would never
        // deliver batch 2 before batch 1's reply, and the recv_timeout
        // below turns that into a clean failure instead of a hang.
        let (tx, rx) = bounded::<ShardCmd>(16, Overload::Block);
        let registry = Arc::new(Registry::new());
        let handle = bare_handle(vec![tx], Arc::clone(&registry));

        let shard = std::thread::spawn(move || {
            let mut pending = Vec::new();
            for _ in 0..2 {
                match rx.recv_timeout(Duration::from_secs(10)) {
                    Ok(ShardCmd::AnnBatch(batch, reply)) => pending.push((batch.len(), reply)),
                    Ok(_) => panic!("unexpected shard command"),
                    Err(_) => return false, // batch 2 never scattered: serialized
                }
            }
            for (n, reply) in pending {
                let _ = reply.send(ShardAnnResult { best: vec![None; n], scanned: 0 });
            }
            true
        });

        let h2 = handle.clone();
        let q1 = std::thread::spawn(move || handle.query_batch(vec![vec![0.25; 4]]).unwrap());
        let q2 = std::thread::spawn(move || h2.query_batch(vec![vec![0.75; 4]]).unwrap());
        assert!(
            shard.join().unwrap(),
            "second batch must reach the shard while the first is unanswered"
        );
        assert_eq!(q1.join().unwrap(), vec![None]);
        assert_eq!(q2.join().unwrap(), vec![None]);
        assert_eq!(registry.ann_queries.get(), 2);
    }

    #[test]
    fn dead_shard_query_errors_instead_of_degrading() {
        use super::super::backpressure::{bounded, Overload};
        use super::super::protocol::{AnnAnswer, ShardAnnResult, ShardKdeResult};

        // Shard 0 is healthy and answers with a real hit; shard 1's
        // mailbox is closed. The old path skipped shard 1 and returned
        // shard 0's merge as a healthy answer — now the caller must see
        // an error naming the dead shard.
        let (tx0, rx0) = bounded::<ShardCmd>(16, Overload::Block);
        let (tx1, rx1) = bounded::<ShardCmd>(16, Overload::Block);
        drop(rx1);
        let responder = std::thread::spawn(move || {
            while let Ok(cmd) = rx0.recv() {
                match cmd {
                    ShardCmd::AnnBatch(batch, reply) => {
                        let best = (0..batch.len())
                            .map(|_| Some(AnnAnswer { shard: 0, id: 1, dist: 0.1 }))
                            .collect();
                        let _ = reply.send(ShardAnnResult { best, scanned: 1 });
                    }
                    ShardCmd::KdeBatch(batch, reply) => {
                        let _ = reply.send(ShardKdeResult {
                            kernel_sums: vec![1.0; batch.len()],
                            population: 5,
                        });
                    }
                    _ => break,
                }
            }
        });
        let handle = bare_handle(vec![tx0, tx1], Arc::new(Registry::new()));
        let err = handle.query_batch(vec![vec![0.0; 4]]).unwrap_err().to_string();
        assert!(err.contains("shard 1"), "{err}");
        let err = handle.kde_batch(vec![vec![0.0; 4]]).unwrap_err().to_string();
        assert!(err.contains("shard 1"), "{err}");
        drop(handle); // closes shard 0's mailbox; responder exits
        responder.join().unwrap();
    }

    #[test]
    fn failed_ops_do_not_inflate_counters() {
        use super::super::backpressure::{bounded, Overload};

        // Every mailbox is dead: inserts fail WITHOUT counting as shed
        // (a disconnect is not overload) and roll back their provisional
        // insert count, and deletes that never reach a shard must not
        // bump the deletes counter — so inserts == stored + shed (all
        // zero here) reconciles even with dead shards.
        let (tx, rx) = bounded::<ShardCmd>(4, Overload::Shed);
        drop(rx);
        let registry = Arc::new(Registry::new());
        let handle = bare_handle(vec![tx], Arc::clone(&registry));
        assert!(!handle.insert(vec![0.5; 4]));
        assert_eq!(handle.insert_batch(vec![vec![0.5; 4]; 10]), 0);
        assert!(!handle.delete(vec![0.5; 4]));
        let st = ServiceStats::from_registry(&registry);
        assert_eq!(st.inserts, 0, "disconnected offers roll back their count");
        assert_eq!(st.shed, 0, "a dead mailbox must not masquerade as overload");
        assert_eq!(st.deletes, 0, "unacknowledged deletes must not count");
    }
}
