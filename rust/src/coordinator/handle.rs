//! `ServiceHandle` — the concurrency seam between connection threads and
//! the owning [`SketchService`] thread.
//!
//! The service itself is `&mut self` everywhere and its PJRT executor is
//! pinned to one thread, so N connection threads cannot call it directly.
//! Instead a handle splits the API by what it needs:
//!
//! - **Ingest / deletes** touch only the router policy and the shard
//!   mailboxes, both cloneable — so they run ON the calling thread and go
//!   straight into the per-shard bounded queues (inserts under the
//!   configured [`Overload`] policy, deletes `force`d). A query can
//!   therefore never sit behind a backlog of queued inserts: backpressure
//!   lives in the shard mailboxes, not in a service-wide command queue.
//! - **Queries, stats, flush** need the service's own state (scatter/
//!   gather, PJRT re-rank, pending-ingest buffers), so they ship over an
//!   unbounded control channel to the owning thread
//!   ([`SketchService::run_cmd_loop`]) and block on a per-request reply.
//!
//! All counting is shared through [`ServiceCounters`], point-denominated.
//!
//! [`SketchService`]: super::server::SketchService
//! [`Overload`]: super::backpressure::Overload

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;

use anyhow::{anyhow, Result};

use super::backpressure::BoundedSender;
use super::protocol::{AnnAnswer, ServiceCounters, ServiceStats};
use super::router::{hash_vector, RoutePolicy};
use super::shard::ShardCmd;
use super::NATIVE_BATCH_ROWS;

/// The ONE native batched-ingest core, shared by `SketchService`'s batch
/// path and [`ServiceHandle::insert_batch`] so the wire ⇔ in-process
/// state-parity guarantee is structural, not copy-maintained: identical
/// chunking ([`NATIVE_BATCH_ROWS`]), identical point-denominated
/// counting. `offer(shard, chunk)` returns false iff the chunk was shed.
pub(super) fn ship_native_batch(
    counters: &ServiceCounters,
    per_shard: Vec<Vec<Vec<f32>>>,
    mut offer: impl FnMut(usize, Vec<Vec<f32>>) -> bool,
) -> usize {
    let mut ok = 0;
    for (s, mut pts) in per_shard.into_iter().enumerate() {
        while !pts.is_empty() {
            let tail = pts.split_off(pts.len().min(NATIVE_BATCH_ROWS));
            let chunk = std::mem::replace(&mut pts, tail);
            let m = chunk.len();
            ServiceCounters::add(&counters.inserts, m as u64);
            if offer(s, chunk) {
                ok += m;
            } else {
                ServiceCounters::add(&counters.shed_points, m as u64);
            }
        }
    }
    ok
}

/// Control-plane commands a handle sends to the service-owning thread.
pub enum ServiceCmd {
    Ann(Vec<Vec<f32>>, Sender<Vec<Option<AnnAnswer>>>),
    Kde(Vec<Vec<f32>>, Sender<(Vec<f64>, Vec<f64>)>),
    Stats(Sender<ServiceStats>),
    /// Barrier; the reply carries the WAL-sync outcome on durable
    /// services (a flush ack must not claim durability the disk refused).
    Flush(Sender<Result<(), String>>),
    /// Cut a whole-service checkpoint; replies with the number of points
    /// it covers (the inserts counter at checkpoint time). Errors travel
    /// as strings so the reply stays plain data.
    Checkpoint(Sender<Result<u64, String>>),
    Shutdown,
}

/// Cloneable, `Send` front to one running [`SketchService`].
///
/// Routing caveat: under `RoutePolicy::RoundRobin` the handle's shared
/// cursor is independent of the service's own `Router` cursor, so mixing
/// direct service ingest with handle ingest round-robins each stream
/// separately (`HashVector`, the default, is stateless and unaffected).
/// The wire-vs-in-process parity tests pin `HashVector`.
///
/// PJRT caveat: handle ingest always ships native `InsertBatch` commands
/// (shard-side batched hashing) — the executor is pinned to the owning
/// thread, so its buffered GEMM-ingest path (`flush_shard_ingest`) only
/// serves direct `SketchService::insert_batch` callers. On a `use_pjrt`
/// service, the artifact accelerates the QUERY path for wire traffic.
///
/// [`SketchService`]: super::server::SketchService
pub struct ServiceHandle {
    shard_txs: Vec<BoundedSender<ShardCmd>>,
    route: RoutePolicy,
    /// Round-robin cursor shared across clones so the partition stays
    /// balanced no matter which connection inserts.
    rr_next: Arc<AtomicUsize>,
    counters: Arc<ServiceCounters>,
    cmd_tx: Sender<ServiceCmd>,
    dim: usize,
    shards: usize,
}

impl Clone for ServiceHandle {
    fn clone(&self) -> Self {
        ServiceHandle {
            shard_txs: self.shard_txs.clone(),
            route: self.route,
            rr_next: Arc::clone(&self.rr_next),
            counters: Arc::clone(&self.counters),
            cmd_tx: self.cmd_tx.clone(),
            dim: self.dim,
            shards: self.shards,
        }
    }
}

impl ServiceHandle {
    pub(super) fn new(
        shard_txs: Vec<BoundedSender<ShardCmd>>,
        route: RoutePolicy,
        dim: usize,
        shards: usize,
        counters: Arc<ServiceCounters>,
        cmd_tx: Sender<ServiceCmd>,
    ) -> Self {
        ServiceHandle {
            shard_txs,
            route,
            rr_next: Arc::new(AtomicUsize::new(0)),
            counters,
            cmd_tx,
            dim,
            shards,
        }
    }

    /// Vector dimensionality the service was configured with.
    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    fn route(&self, x: &[f32]) -> usize {
        match self.route {
            RoutePolicy::HashVector => hash_vector(x) as usize % self.shard_txs.len(),
            RoutePolicy::RoundRobin => {
                self.rr_next.fetch_add(1, Ordering::Relaxed) % self.shard_txs.len()
            }
        }
    }

    /// Offer one stream element under the overload policy. Returns false
    /// if it was shed.
    pub fn insert(&self, x: Vec<f32>) -> bool {
        let s = self.route(&x);
        ServiceCounters::add(&self.counters.inserts, 1);
        let ok = self.shard_txs[s].offer(ShardCmd::Insert(x));
        if !ok {
            ServiceCounters::add(&self.counters.shed_points, 1);
        }
        ok
    }

    /// Batched ingest through [`ship_native_batch`] — the same core the
    /// service's native `insert_batch` path runs, so chunk boundaries and
    /// accounting are identical by construction. Returns accepted points.
    pub fn insert_batch(&self, batch: Vec<Vec<f32>>) -> usize {
        let mut per_shard: Vec<Vec<Vec<f32>>> = vec![Vec::new(); self.shard_txs.len()];
        for x in batch {
            per_shard[self.route(&x)].push(x);
        }
        ship_native_batch(&self.counters, per_shard, |s, chunk| {
            self.shard_txs[s].offer(ShardCmd::InsertBatch(chunk))
        })
    }

    /// Turnstile deletion (HashVector routing only); forced past the
    /// overload policy like every command carrying a reply channel.
    pub fn delete(&self, x: Vec<f32>) -> bool {
        let Some(s) = (match self.route {
            RoutePolicy::HashVector => Some(hash_vector(&x) as usize % self.shard_txs.len()),
            RoutePolicy::RoundRobin => None,
        }) else {
            return false;
        };
        ServiceCounters::add(&self.counters.deletes, 1);
        let (tx, rx) = channel();
        if !self.shard_txs[s].force(ShardCmd::Delete(x, tx)) {
            return false;
        }
        rx.recv().unwrap_or(false)
    }

    fn call<T>(&self, make: impl FnOnce(Sender<T>) -> ServiceCmd) -> Result<T> {
        let (tx, rx) = channel();
        self.cmd_tx
            .send(make(tx))
            .map_err(|_| anyhow!("service thread is gone"))?;
        rx.recv()
            .map_err(|_| anyhow!("service thread dropped the reply"))
    }

    /// Batched (c, r)-ANN through the owning thread.
    pub fn query_batch(&self, queries: Vec<Vec<f32>>) -> Result<Vec<Option<AnnAnswer>>> {
        self.call(|tx| ServiceCmd::Ann(queries, tx))
    }

    /// Batched sliding-window KDE (kernel sums, densities).
    pub fn kde_batch(&self, queries: Vec<Vec<f32>>) -> Result<(Vec<f64>, Vec<f64>)> {
        self.call(|tx| ServiceCmd::Kde(queries, tx))
    }

    /// Aggregate statistics (drains shard mailboxes first).
    pub fn stats(&self) -> Result<ServiceStats> {
        self.call(ServiceCmd::Stats)
    }

    /// Barrier: all inserts offered BEFORE this call (from this thread)
    /// are applied when it returns Ok — and, on a durable service, synced
    /// to the WAL (a sync failure surfaces here, never as a silent ack).
    pub fn flush(&self) -> Result<()> {
        self.call(ServiceCmd::Flush)?
            .map_err(|e| anyhow!("flush failed: {e}"))
    }

    /// Cut a whole-service checkpoint (durable services only). Returns
    /// the number of points the checkpoint covers.
    pub fn checkpoint(&self) -> Result<u64> {
        self.call(ServiceCmd::Checkpoint)?
            .map_err(|e| anyhow!("checkpoint failed: {e}"))
    }

    /// Ask the owning thread to shut the service down (idempotent,
    /// best-effort: a missing service thread is already shut down).
    pub fn shutdown(&self) {
        let _ = self.cmd_tx.send(ServiceCmd::Shutdown);
    }
}

#[cfg(test)]
mod tests {
    use super::super::server::{ServiceConfig, SketchService};
    use super::*;
    use crate::util::rng::Rng;

    fn cfg() -> ServiceConfig {
        let mut cfg = ServiceConfig::default_for(6, 500);
        cfg.shards = 2;
        cfg.ann.eta = 0.0;
        cfg.kde.rows = 8;
        cfg
    }

    #[test]
    fn concurrent_handles_do_not_lose_points() {
        let (handle, join) = SketchService::spawn(cfg()).unwrap();
        let writers: Vec<_> = (0..4)
            .map(|t| {
                let h = handle.clone();
                std::thread::spawn(move || {
                    let mut rng = Rng::new(100 + t);
                    for _ in 0..250 {
                        let p: Vec<f32> = (0..6).map(|_| rng.gaussian_f32()).collect();
                        assert!(h.insert(p), "Block policy never sheds");
                    }
                })
            })
            .collect();
        for w in writers {
            w.join().unwrap();
        }
        handle.flush().unwrap();
        let st = handle.stats().unwrap();
        assert_eq!(st.inserts, 1000);
        assert_eq!(st.shed, 0);
        assert_eq!(st.stored_points, 1000, "eta=0 stores all");
        handle.shutdown();
        join.join().unwrap();
    }

    #[test]
    fn queries_interleave_with_ingest() {
        let (handle, join) = SketchService::spawn(cfg()).unwrap();
        let h = handle.clone();
        let writer = std::thread::spawn(move || {
            let mut rng = Rng::new(9);
            for _ in 0..2000 {
                let p: Vec<f32> = (0..6).map(|_| rng.gaussian_f32()).collect();
                h.insert(p);
            }
        });
        let mut rng = Rng::new(10);
        for _ in 0..20 {
            let qs: Vec<Vec<f32>> = (0..8)
                .map(|_| (0..6).map(|_| rng.gaussian_f32()).collect())
                .collect();
            let ans = handle.query_batch(qs).unwrap();
            assert_eq!(ans.len(), 8, "every query answered mid-ingest");
        }
        writer.join().unwrap();
        handle.shutdown();
        join.join().unwrap();
    }

    #[test]
    fn handle_calls_fail_cleanly_after_shutdown() {
        let (handle, join) = SketchService::spawn(cfg()).unwrap();
        handle.shutdown();
        join.join().unwrap();
        assert!(handle.query_batch(vec![vec![0.0; 6]]).is_err());
        assert!(handle.stats().is_err());
        // Direct ingest into dead shards reports failure, no panic.
        assert!(!handle.insert(vec![0.0; 6]));
    }
}
