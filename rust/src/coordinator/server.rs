//! The sketch service: thread-per-shard coordinator with bounded
//! ingestion, scatter/gather batch queries, and an optional PJRT re-rank
//! stage (the L3 ↔ runtime seam).
//!
//! Data flow (serving path, Python nowhere):
//!
//! ```text
//! inserts ─ router ─ bounded mailbox ─▶ shard threads (S-ANN + SW-AKDE)
//! queries ─ batcher ─ scatter ────────▶ shards: probe buckets (3L cap)
//!            ◀─ gather candidates ──── candidates (ids + vectors)
//!            PJRT rerank_l2 artifact (or native fallback) → argmin → reply
//! ```

use std::path::PathBuf;
use crate::util::sync::mpsc::{channel, Receiver, RecvTimeoutError};
use crate::util::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::durability::{checkpoint, recovery, wal, FsyncPolicy};
use crate::metrics::registry::Registry;
use crate::obs::log;
use crate::runtime::Executor;
use crate::sketch::ann::SAnnConfig;

use super::backend::{local_backends, IngestOutcome};
use super::backpressure::{bounded, OfferOutcome, Overload};
use super::handle::{ServiceCmd, ServiceHandle};
use super::health::{DurabilityLossPolicy, HealthBoard};
use super::protocol::{AnnAnswer, ServiceStats};
use super::query::QueryPlane;
use super::replica::ReplicaSet;
use super::router::{RoutePolicy, Router};
use super::shard::{KdeShardConfig, Shard, ShardCmd};

/// Service construction parameters.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    pub dim: usize,
    pub shards: usize,
    /// Read replicas per shard (R ≥ 1). Writes fan out to every replica
    /// (identical state by construction); reads go to the least-loaded
    /// copy. Durability is per-shard: one WAL + one checkpoint image
    /// regardless of R.
    pub replicas: usize,
    pub route: RoutePolicy,
    /// Per-shard mailbox depth.
    pub queue_cap: usize,
    /// Insert overload policy (queries always block).
    pub overload: Overload,
    pub ann: SAnnConfig,
    pub kde: KdeShardConfig,
    pub seed: u64,
    /// First GLOBAL shard index this process serves (0 standalone). A
    /// member node of a routed deployment is booted with the base of its
    /// contiguous range so shard construction (index, seed) and answer
    /// ids are GLOBAL: the front-end's merge of member partials is then
    /// bit-identical to one process serving the whole range. Durability
    /// paths (WAL files, checkpoint images, health board) stay keyed by
    /// LOCAL index — a node's data_dir is its own.
    pub shard_base: usize,
    /// Re-rank gathered candidates through the PJRT artifact when true;
    /// pure-native otherwise.
    pub use_pjrt: bool,
    /// Durability root (WAL segments + checkpoints). `None` = in-memory
    /// only; `Some` makes startup recover the newest checkpoint + WAL and
    /// every applied mutation append to the log.
    pub data_dir: Option<PathBuf>,
    /// WAL fsync policy (ignored without `data_dir`).
    pub fsync: FsyncPolicy,
    /// Background checkpoint trigger: cut one after this many points
    /// since the last checkpoint (needs `data_dir`).
    pub checkpoint_every_points: Option<u64>,
    /// Background checkpoint trigger: cut one after this many seconds,
    /// if any new points arrived (needs `data_dir`).
    pub checkpoint_every_secs: Option<u64>,
    /// What a shard does when its WAL/checkpoint I/O fails mid-stream:
    /// keep serving undurably (`Degrade`, loud), refuse further writes
    /// (`ReadOnly`), or panic the shard thread (`Abort`).
    pub on_durability_loss: DurabilityLossPolicy,
}

/// Typed validation failure from [`ServiceConfigBuilder::build`]. Each
/// variant names the rejected knob (and carries the offending value), so
/// callers — the CLI, `CreateCollection` over the wire — can report
/// exactly which part of a config is bad instead of a stringly blob.
#[derive(Clone, Debug, PartialEq)]
pub enum ConfigError {
    /// `dim` must be ≥ 1 (a zero-dimensional stream has no geometry).
    ZeroDim,
    /// `shards` must be ≥ 1.
    ZeroShards,
    /// `replicas` must be ≥ 1 (R counts copies, not spares).
    ZeroReplicas,
    /// `queue_cap` must be ≥ 1 (a zero-depth mailbox admits nothing).
    ZeroQueueCap,
    /// `ann.n_max` must be ≥ 1 (the sketch sizes itself off it).
    ZeroNMax,
    /// `ann.eta` must lie in [0, 1].
    BadEta(f64),
    /// `ann.c` must be > 1 (the approximation factor).
    BadApproxC(f64),
    /// `ann.r` and `ann.w` must be positive.
    NonPositiveRadius { r: f64, w: f64 },
    /// `kde.eps_eh` must lie in (0, 1].
    BadEpsEh(f64),
    /// `kde.rows`, `kde.p` and `kde.window` must all be ≥ 1.
    ZeroKdeShape,
    /// A durability knob (named in the payload) was set without a
    /// `data_dir` — fsync cadence and checkpoint triggers act on a WAL
    /// that would not exist, which is a config contradiction, not a
    /// default to silently ignore.
    DurabilityWithoutDataDir(&'static str),
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::ZeroDim => write!(f, "dim must be >= 1"),
            ConfigError::ZeroShards => write!(f, "shards must be >= 1"),
            ConfigError::ZeroReplicas => write!(f, "replicas must be >= 1"),
            ConfigError::ZeroQueueCap => write!(f, "queue_cap must be >= 1"),
            ConfigError::ZeroNMax => write!(f, "ann.n_max must be >= 1"),
            ConfigError::BadEta(v) => write!(f, "ann.eta must be in [0,1], got {v}"),
            ConfigError::BadApproxC(v) => write!(f, "ann.c must be > 1, got {v}"),
            ConfigError::NonPositiveRadius { r, w } => {
                write!(f, "ann.r and ann.w must be positive, got r={r} w={w}")
            }
            ConfigError::BadEpsEh(v) => write!(f, "kde.eps_eh must be in (0,1], got {v}"),
            ConfigError::ZeroKdeShape => {
                write!(f, "kde.rows, kde.p and kde.window must all be >= 1")
            }
            ConfigError::DurabilityWithoutDataDir(knob) => {
                write!(f, "{knob} was set but data_dir is unset (nothing to make durable)")
            }
        }
    }
}

impl std::error::Error for ConfigError {}

/// Validating builder over [`ServiceConfig`]. Starts from
/// [`ServiceConfig::default_for`] (or any existing config via
/// [`ServiceConfig::to_builder`] — which is how CLI flags overlay a
/// loaded file: defaults < file < flags, last setter wins) and checks
/// every cross-field constraint in [`Self::build`], so an invalid combo
/// is a typed [`ConfigError`] at construction time instead of a panic
/// or a silently clamped value at serve time.
#[derive(Clone, Debug)]
pub struct ServiceConfigBuilder {
    cfg: ServiceConfig,
}

impl ServiceConfigBuilder {
    pub fn shards(mut self, n: usize) -> Self {
        self.cfg.shards = n;
        self
    }

    pub fn replicas(mut self, r: usize) -> Self {
        self.cfg.replicas = r;
        self
    }

    pub fn route(mut self, route: RoutePolicy) -> Self {
        self.cfg.route = route;
        self
    }

    pub fn queue_cap(mut self, cap: usize) -> Self {
        self.cfg.queue_cap = cap;
        self
    }

    pub fn overload(mut self, policy: Overload) -> Self {
        self.cfg.overload = policy;
        self
    }

    pub fn eta(mut self, eta: f64) -> Self {
        self.cfg.ann.eta = eta;
        self
    }

    pub fn ann(mut self, ann: SAnnConfig) -> Self {
        self.cfg.ann = ann;
        self
    }

    pub fn kde(mut self, kde: KdeShardConfig) -> Self {
        self.cfg.kde = kde;
        self
    }

    /// Whole-service sliding-window size (split across shards at start).
    pub fn window(mut self, window: u64) -> Self {
        self.cfg.kde.window = window;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.cfg.seed = seed;
        self
    }

    pub fn shard_base(mut self, base: usize) -> Self {
        self.cfg.shard_base = base;
        self
    }

    pub fn use_pjrt(mut self, yes: bool) -> Self {
        self.cfg.use_pjrt = yes;
        self
    }

    pub fn data_dir(mut self, dir: Option<PathBuf>) -> Self {
        self.cfg.data_dir = dir;
        self
    }

    pub fn fsync(mut self, policy: FsyncPolicy) -> Self {
        self.cfg.fsync = policy;
        self
    }

    pub fn checkpoint_every_points(mut self, n: Option<u64>) -> Self {
        self.cfg.checkpoint_every_points = n;
        self
    }

    pub fn checkpoint_every_secs(mut self, secs: Option<u64>) -> Self {
        self.cfg.checkpoint_every_secs = secs;
        self
    }

    pub fn on_durability_loss(mut self, policy: DurabilityLossPolicy) -> Self {
        self.cfg.on_durability_loss = policy;
        self
    }

    /// Validate every field and cross-field constraint; the first
    /// violation wins (ordered roughly most- to least-structural).
    pub fn build(self) -> Result<ServiceConfig, ConfigError> {
        let cfg = self.cfg;
        if cfg.dim == 0 || cfg.ann.dim == 0 {
            return Err(ConfigError::ZeroDim);
        }
        if cfg.shards == 0 {
            return Err(ConfigError::ZeroShards);
        }
        if cfg.replicas == 0 {
            return Err(ConfigError::ZeroReplicas);
        }
        if cfg.queue_cap == 0 {
            return Err(ConfigError::ZeroQueueCap);
        }
        if cfg.ann.n_max == 0 {
            return Err(ConfigError::ZeroNMax);
        }
        if !(0.0..=1.0).contains(&cfg.ann.eta) {
            return Err(ConfigError::BadEta(cfg.ann.eta));
        }
        if cfg.ann.c <= 1.0 {
            return Err(ConfigError::BadApproxC(cfg.ann.c));
        }
        if cfg.ann.r <= 0.0 || cfg.ann.w <= 0.0 {
            return Err(ConfigError::NonPositiveRadius { r: cfg.ann.r, w: cfg.ann.w });
        }
        if cfg.kde.eps_eh <= 0.0 || cfg.kde.eps_eh > 1.0 {
            return Err(ConfigError::BadEpsEh(cfg.kde.eps_eh));
        }
        if cfg.kde.rows == 0 || cfg.kde.p == 0 || cfg.kde.window == 0 {
            return Err(ConfigError::ZeroKdeShape);
        }
        if cfg.data_dir.is_none() {
            if cfg.fsync != FsyncPolicy::default() {
                return Err(ConfigError::DurabilityWithoutDataDir("fsync"));
            }
            if cfg.checkpoint_every_points.is_some() {
                return Err(ConfigError::DurabilityWithoutDataDir("checkpoint_every_points"));
            }
            if cfg.checkpoint_every_secs.is_some() {
                return Err(ConfigError::DurabilityWithoutDataDir("checkpoint_every_secs"));
            }
        }
        Ok(cfg)
    }
}

impl ServiceConfig {
    /// Start building from the defaults for a dim-`dim` stream of up to
    /// `n_max` points. Precedence when layering sources: these defaults,
    /// then anything loaded from a file (see [`ServiceConfig::to_builder`]
    /// on a [`crate::config::Config`]-produced config), then explicit
    /// setter calls — the LAST write to a knob wins, so CLI flags applied
    /// after a file overlay it.
    pub fn builder(dim: usize, n_max: usize) -> ServiceConfigBuilder {
        ServiceConfigBuilder { cfg: ServiceConfig::default_for(dim, n_max) }
    }

    /// Re-open any existing config as a builder — the file→flags overlay
    /// path: `Config::load(..)?.service(..)?.to_builder().shards(8).build()?`.
    pub fn to_builder(self) -> ServiceConfigBuilder {
        ServiceConfigBuilder { cfg: self }
    }

    /// Load `[service]`/`[ann]`/`[kde]` sections from a config file and
    /// validate the result through the builder. CLI flags belong ON TOP:
    /// call `.to_builder()` on the result, apply setters, re-`build()`.
    pub fn from_file(path: &std::path::Path, dim: usize, n_max: usize) -> Result<ServiceConfig> {
        let cfg = crate::config::Config::load(path)?.service(dim, n_max)?;
        cfg.to_builder().build().map_err(anyhow::Error::from)
    }

    /// Reasonable defaults for a dim-`d` stream of up to `n` points.
    pub fn default_for(dim: usize, n: usize) -> Self {
        ServiceConfig {
            dim,
            shards: 4,
            replicas: 1,
            route: RoutePolicy::HashVector,
            queue_cap: 1024,
            overload: Overload::Block,
            ann: SAnnConfig {
                dim,
                n_max: n,
                eta: 0.5,
                r: 1.0,
                c: 2.0,
                w: 4.0,
                l_cap: 32,
                seed: 42,
            },
            kde: KdeShardConfig {
                kernel: super::shard::KdeKernel::Angular,
                rows: 32,
                p: 3,
                range: 0,
                width: 4.0,
                eps_eh: 0.1,
                window: 1024,
            },
            seed: 42,
            shard_base: 0,
            use_pjrt: false,
            data_dir: None,
            fsync: FsyncPolicy::default(),
            checkpoint_every_points: None,
            checkpoint_every_secs: None,
            on_durability_loss: DurabilityLossPolicy::default(),
        }
    }
}

struct ShardHandle {
    /// One shard's replica mailboxes (R ≥ 1; `set.primary()` owns the
    /// WAL and answers stats/snapshots).
    set: ReplicaSet,
    joins: Vec<JoinHandle<()>>,
    /// ANN hash params cloned before the shard moved to its thread:
    /// (projection [dim, k*L], biases, width, k, L). Used by the server to
    /// batch-hash queries through the PJRT artifact. Identical on every
    /// replica (same seed), so one copy per shard suffices.
    hash_params: (Vec<f32>, Vec<f32>, f32, usize, usize),
    /// KDE hash params: (projection [dim, rows*p], biases, width, rows*p,
    /// kernel) — drives the batched PJRT ingest path.
    kde_params: (Vec<f32>, Vec<f32>, f32, usize, super::shard::KdeKernel),
}

/// The running service.
pub struct SketchService {
    cfg: ServiceConfig,
    shards: Vec<ShardHandle>,
    router: Router,
    executor: Option<Executor>,
    /// The native read path (scatter/gather/merge over the shard
    /// mailboxes). Held here so the service's own query calls share the
    /// exact code every `ServiceHandle` clone runs — including the
    /// no-partial-answers degradation contract.
    plane: QueryPlane,
    /// The metrics registry: point-denominated counters, stage/op latency
    /// histograms, and sketch gauges — shared with every [`ServiceHandle`]
    /// so connection threads and the owning thread account into one place.
    registry: Arc<Registry>,
    /// Per-shard pending ingest (batched PJRT path): points accumulate
    /// until a shard's buffer fills one artifact batch, so the hash GEMM
    /// runs at full utilization instead of padding 16 rows to 256.
    pending_ingest: Vec<Vec<Vec<f32>>>,
    /// Epoch of the newest checkpoint (recovered or cut by this process).
    ckpt_epoch: u64,
    /// `registry.inserts` at the last checkpoint (points-based trigger).
    inserts_at_ckpt: u64,
    /// When the last checkpoint was cut (time-based trigger).
    last_ckpt_time: Instant,
    /// Per-shard durability health, written by shard primaries and read
    /// by stats/Hello/admission paths (see [`HealthBoard`]).
    board: Arc<HealthBoard>,
}

/// Rows per batched-ingest flush (the hash artifacts' batch dimension).
const INGEST_FLUSH_ROWS: usize = 256;

impl SketchService {
    /// Spawn shard threads — `replicas` per shard — and the PJRT executor
    /// when `use_pjrt`.
    ///
    /// With `data_dir` set this is also the recovery path: the newest
    /// valid checkpoint restores every shard's S-ANN + SW-AKDE state
    /// (ONE image per shard, decoded once per replica — so any R
    /// rehydrates from the same bytes) and the service counters, then
    /// each shard replays its WAL records past the checkpoint's
    /// high-water mark into every replica BEFORE their threads spawn —
    /// so by the time the service accepts traffic, it answers exactly
    /// like the uninterrupted process would have, from any copy.
    pub fn start(cfg: ServiceConfig) -> Result<Self> {
        let mut cfg = cfg;
        cfg.replicas = cfg.replicas.max(1);
        let per_shard_n = cfg.ann.n_max.div_ceil(cfg.shards).max(2);
        let mut recovered = match &cfg.data_dir {
            Some(dir) => Some(recovery::recover(dir, cfg.dim, cfg.shards)?),
            None => None,
        };
        let registry = Arc::new(Registry::new());
        let board = Arc::new(HealthBoard::new(cfg.shards));
        let (mut replayed_inserts, mut replayed_deletes) = (0u64, 0u64);
        let mut shards = Vec::with_capacity(cfg.shards);
        for i in 0..cfg.shards {
            let kde_cfg = KdeShardConfig {
                window: (cfg.kde.window / cfg.shards as u64).max(1),
                ..cfg.kde.clone()
            };
            // Every replica is built with the SAME seed: replica state is
            // a function of the mutation sequence alone, so R copies fed
            // identical mailbox orders answer bit-identically — and
            // identically to an R=1 shard.
            // Index and seed are GLOBAL (base + i): on a member node of a
            // routed deployment, shard g must be byte-identical to shard g
            // of a single process serving every range — same projections,
            // same sampler stream, same answer ids.
            let g = cfg.shard_base + i;
            let mut members: Vec<Shard> = (0..cfg.replicas)
                .map(|_| {
                    let ann_cfg = SAnnConfig { n_max: per_shard_n, ..cfg.ann.clone() };
                    Shard::new(g, ann_cfg, &kde_cfg, cfg.seed ^ 0xD1E5 ^ g as u64)
                })
                .collect();
            if let (Some(dir), Some(rec)) = (&cfg.data_dir, recovered.as_mut()) {
                let rs = std::mem::take(&mut rec.shards[i]);
                let hwm = rs.hwm;
                for (r, shard) in members.iter_mut().enumerate() {
                    if let Some((ann, kde)) = rs.decode_images().map_err(|e| {
                        e.context(format!("shard {i} replica {r}: decoding checkpoint image"))
                    })? {
                        shard.restore_state(ann, kde, rs.applied_inserts, rs.applied_deletes)?;
                    }
                }
                let report = wal::replay(dir, i, hwm, |r| {
                    match r.op {
                        wal::WalOp::Insert { .. } => replayed_inserts += 1,
                        wal::WalOp::Delete => replayed_deletes += 1,
                    }
                    // The logged sampler decision is honored by every
                    // replica, so replay cannot diverge the copies.
                    for shard in members.iter_mut() {
                        shard.replay(r)?;
                    }
                    Ok(())
                })?;
                if let Some((path, off)) = &report.corrupt_at {
                    // A torn tail from the crash being recovered can only
                    // sit in the FINAL segment (append-only, one writer):
                    // truncate it so the next recovery replays cleanly.
                    // Corruption anywhere else means later segments hold
                    // records whose preceding mutations were lost —
                    // recovering past that hole would silently diverge.
                    let is_final = wal::list_segments(dir, i)?
                        .last()
                        .is_some_and(|(_, last)| last == path);
                    if !is_final {
                        bail!(
                            "shard {i}: WAL corruption in non-final segment {} — \
                             refusing to recover past a hole",
                            path.display()
                        );
                    }
                    log::warn(
                        "coordinator::server",
                        "torn WAL tail; truncating",
                        crate::kv!(
                            shard = i,
                            last_seq = report.last_seq,
                            replayed = report.applied,
                            segment = path.display(),
                            offset = off
                        ),
                    );
                    wal::truncate_segment(path, *off)?;
                }
                let mut writer = wal::WalWriter::open(
                    dir,
                    i,
                    report.last_seq.max(rs.hwm) + 1,
                    cfg.fsync,
                    wal::DEFAULT_SEGMENT_BYTES,
                )?;
                writer.set_fsync_observer(Arc::clone(&registry));
                // The WAL logs once per SHARD: only the primary appends.
                members[0].attach_wal(writer);
            }
            // Only the primary owns durability, so only it publishes
            // health — but every shard gets wired so a policy applies
            // even to non-durable configurations' future failure modes.
            members[0].set_health_reporting(Arc::clone(&board), cfg.on_durability_loss);
            let hash_params = members[0].ann_hash_params();
            let kde_params = members[0].kde_hash_params();
            let mut txs = Vec::with_capacity(cfg.replicas);
            let mut joins = Vec::with_capacity(cfg.replicas);
            for (r, shard) in members.into_iter().enumerate() {
                let (tx, rx) = bounded(cfg.queue_cap, cfg.overload);
                let name = if cfg.replicas == 1 {
                    format!("shard-{i}")
                } else {
                    format!("shard-{i}r{r}")
                };
                let join = std::thread::Builder::new()
                    .name(name)
                    .spawn(move || shard.run(rx))?;
                txs.push(tx);
                joins.push(join);
            }
            let mut set = ReplicaSet::new(txs);
            set.set_health(i, Arc::clone(&board));
            shards.push(ShardHandle { set, joins, hash_params, kde_params });
        }
        let ckpt_epoch = recovered.as_ref().map_or(0, |r| r.epoch);
        if let Some(rec) = &recovered {
            registry.restore(
                rec.counters[0] + replayed_inserts,
                rec.counters[1] + replayed_deletes,
                rec.counters[2],
                rec.counters[3],
                rec.counters[4],
            );
        }
        let executor = if cfg.use_pjrt { Some(Executor::from_default_dir()?) } else { None };
        let router = Router::new(cfg.route, cfg.shards);
        let pending_ingest = vec![Vec::new(); cfg.shards];
        let inserts_at_ckpt = registry.inserts.get();
        let plane = QueryPlane::new(
            local_backends(
                shards.iter().map(|s| s.set.clone()).collect(),
                cfg.shard_base,
                Some(&board),
            ),
            Arc::clone(&registry),
        );
        Ok(SketchService {
            cfg,
            shards,
            router,
            executor,
            plane,
            registry,
            pending_ingest,
            ckpt_epoch,
            inserts_at_ckpt,
            last_ckpt_time: Instant::now(),
            board,
        })
    }

    pub fn config(&self) -> &ServiceConfig {
        &self.cfg
    }

    /// Offer one stream element. Returns false if it was not delivered;
    /// only a genuine shed (queue full) counts toward the shed statistic
    /// — a disconnected mailbox rolls back its insert count instead.
    pub fn insert(&mut self, x: Vec<f32>) -> bool {
        let shard = self.router.route(&x);
        self.registry.inserts.add(1);
        match self.shards[shard].set.offer_write(ShardCmd::Insert(x)) {
            OfferOutcome::Sent => true,
            OfferOutcome::Shed => {
                self.registry.shed(1);
                false
            }
            OfferOutcome::Disconnected => {
                self.registry.inserts.sub(1);
                false
            }
        }
    }

    /// Batched ingest: routes the batch, hashes each shard's slice through
    /// the PJRT artifacts (ANN p-stable + KDE family) in one GEMM each, and
    /// ships precomputed slots so shard threads only touch tables/EHs.
    /// Without an executor, each shard's slice ships as `InsertBatch`
    /// commands (chunked to the front-door batch size) so the shard thread
    /// hashes a whole chunk with one native batched kernel call instead of
    /// a loop of singles.
    ///
    /// Returns the number of points ACCEPTED (offered minus points shed
    /// at flush time) on both paths. On the PJRT path points may sit in
    /// pending buffers past this call; they count as accepted here and any
    /// later shed is visible in `stats().shed`.
    pub fn insert_batch(&mut self, batch: Vec<Vec<f32>>) -> usize {
        if self.executor.is_none() {
            let mut per_shard: Vec<Vec<Vec<f32>>> = vec![Vec::new(); self.shards.len()];
            for x in batch {
                per_shard[self.router.route(&x)].push(x);
            }
            // Chunk (shared core, NATIVE_BATCH_ROWS) so a shed under
            // overload drops at most one kernel-batch worth of points, and
            // queue_cap keeps its per-point meaning within a factor of the
            // batch size.
            return super::handle::ship_native_batch(&self.registry, per_shard, |s, chunk| {
                let m = chunk.len();
                match self.shards[s].set.offer_write(ShardCmd::InsertBatch(chunk)) {
                    OfferOutcome::Sent => IngestOutcome::Accepted { accepted: m, shed: 0 },
                    OfferOutcome::Shed => IngestOutcome::Accepted { accepted: 0, shed: m },
                    OfferOutcome::Disconnected => IngestOutcome::Disconnected,
                }
            });
        }
        // Route into per-shard pending buffers; flush a shard only when a
        // full artifact batch has accumulated (utilization over latency —
        // callers needing immediate visibility call `flush_ingest`).
        // Accepted points are counted at flush time via the shed counter
        // delta, so `ok == batch.len()` holds exactly as on the native
        // path whenever nothing sheds.
        let offered = batch.len();
        let shed_before = self.registry.shed_points.get();
        for x in batch {
            let s = self.router.route(&x);
            self.pending_ingest[s].push(x);
            if self.pending_ingest[s].len() >= INGEST_FLUSH_ROWS {
                self.flush_shard_ingest(s);
            }
        }
        let shed_during = self.registry.shed_points.get() - shed_before;
        offered.saturating_sub(shed_during as usize)
    }

    /// Push all pending batched-ingest points to their shards.
    pub fn flush_ingest(&mut self) {
        for s in 0..self.shards.len() {
            self.flush_shard_ingest(s);
        }
    }

    fn flush_shard_ingest(&mut self, si: usize) {
        let pts = std::mem::take(&mut self.pending_ingest[si]);
        if pts.is_empty() {
            return;
        }
        let dim = self.cfg.dim;
        let m = pts.len();
        self.registry.inserts.add(m as u64);
        let Some(exec) = self.executor.as_mut() else {
            // Points can only accumulate in `pending_ingest` on the PJRT
            // path, so this arm is unreachable today — but an unwrap here
            // would turn a future call-order bug into a panic that drops
            // the flushed points on the floor. Ship them natively instead:
            // same accounting as `ship_native_batch`, batched hashing on
            // the shard thread.
            match self.shards[si].set.offer_write(ShardCmd::InsertBatch(pts)) {
                OfferOutcome::Sent => {}
                OfferOutcome::Shed => self.registry.shed(m as u64),
                OfferOutcome::Disconnected => self.registry.inserts.sub(m as u64),
            }
            return;
        };
        let flat: Vec<f32> = pts.iter().flatten().copied().collect();
        let (proj, bias, w, k, l) = &self.shards[si].hash_params;
        let ann_slots = exec.pstable_hash_tiled(dim, &flat, proj, bias, 1.0 / *w).ok();
        let (kproj, kbias, kw, kh, kernel) = &self.shards[si].kde_params;
        let kde_slots = match kernel {
            super::shard::KdeKernel::Angular => exec.srp_hash_tiled(dim, &flat, kproj, *kh).ok(),
            super::shard::KdeKernel::Euclidean => {
                exec.pstable_hash_tiled(dim, &flat, kproj, kbias, 1.0 / *kw).ok()
            }
        };
        match (ann_slots, kde_slots) {
            (Some(a), Some(kd)) => {
                let h = k * l;
                let items: Vec<(Vec<f32>, Vec<i64>, Vec<i64>)> = pts
                    .into_iter()
                    .enumerate()
                    .map(|(i, x)| {
                        (
                            x,
                            a[i * h..(i + 1) * h].to_vec(),
                            kd[i * kh..(i + 1) * kh].to_vec(),
                        )
                    })
                    .collect();
                match self.shards[si].set.offer_write(ShardCmd::InsertBatchSlots(items)) {
                    OfferOutcome::Sent => {}
                    OfferOutcome::Shed => self.registry.shed(m as u64),
                    OfferOutcome::Disconnected => self.registry.inserts.sub(m as u64),
                }
            }
            _ => {
                // artifact variant missing: native per-item path
                for x in pts {
                    match self.shards[si].set.offer_write(ShardCmd::Insert(x)) {
                        OfferOutcome::Sent => {}
                        OfferOutcome::Shed => self.registry.shed(1),
                        OfferOutcome::Disconnected => self.registry.inserts.sub(1),
                    }
                }
            }
        }
    }

    /// Turnstile deletion (HashVector routing only). The `deletes`
    /// counter tracks ACKNOWLEDGED commands only — a dead mailbox or a
    /// shard dying before the ack must not drift the counter above the
    /// applied work (same point-denominated discipline as `shed`).
    pub fn delete(&mut self, x: Vec<f32>) -> bool {
        let Some(shard) = self.router.route_delete(&x) else {
            return false;
        };
        match self.shards[shard].set.delete(x) {
            Some(removed) => {
                self.registry.deletes.add(1);
                removed
            }
            None => false,
        }
    }

    /// Batched (c, r)-ANN: scatter to all shards, gather, and either merge
    /// native per-shard bests (via the [`QueryPlane`], on this thread) or
    /// re-rank all candidates through PJRT. A dead shard is an `Err`,
    /// never a silently partial merge.
    pub fn query_batch(&mut self, queries: Vec<Vec<f32>>) -> Result<Vec<Option<AnnAnswer>>> {
        if self.executor.is_none() {
            return self.plane.ann_batch(queries);
        }
        let n = queries.len();
        self.registry.ann_queries.add(n as u64);
        if n == 0 {
            return Ok(Vec::new());
        }
        self.query_batch_pjrt(Arc::new(queries))
    }

    fn query_batch_pjrt(&mut self, batch: Arc<Vec<Vec<f32>>>) -> Result<Vec<Option<AnnAnswer>>> {
        let n = batch.len();
        let dim = self.cfg.dim;
        let t0 = std::time::Instant::now();
        // Hash the whole batch per shard through the PJRT artifact (one
        // projection GEMM per shard, §Perf iteration 4), then scatter the
        // precomputed table keys. Falls back to shard-side hashing when the
        // artifact variant is missing. Materialized once: the re-rank GEMM
        // below reuses the same flattened queries.
        let flat_q: Vec<f32> = batch.iter().flatten().copied().collect();
        let mut replies = Vec::with_capacity(self.shards.len());
        for (si, s) in self.shards.iter().enumerate() {
            let (tx, rx) = channel();
            let (proj, bias, w, k, l) = &s.hash_params;
            let Some(exec) = self.executor.as_mut() else {
                bail!("PJRT query path reached without an executor (routing bug)");
            };
            let keys = exec
                .pstable_hash_tiled(dim, &flat_q, proj, bias, 1.0 / *w)
                .ok()
                .map(|slots| {
                    let hasher = crate::lsh::concat::TableHasher::new(*k, *l);
                    let h = k * l;
                    let mut all = Vec::with_capacity(n);
                    let mut keybuf = Vec::new();
                    for qi in 0..n {
                        hasher.keys_from_slots(&slots[qi * h..(qi + 1) * h], &mut keybuf);
                        all.push(std::mem::take(&mut keybuf));
                    }
                    all
                });
            let cmd = match keys {
                Some(all) => ShardCmd::AnnCandidatesKeys(Arc::new(all), tx),
                None => ShardCmd::AnnCandidates(Arc::clone(&batch), tx),
            };
            // A dead shard's candidates are gone with it — returning the
            // surviving shards' merge would silently declare its points
            // "no near neighbor" (the bug this path shared with the old
            // native loop). Candidate reads pick a replica like every
            // other read, so PJRT queries share the replica scaling.
            let Some(guard) = s.set.read(cmd) else {
                bail!("ANN query failed: shard {si} is down (refusing a partial answer)");
            };
            replies.push((rx, guard));
        }
        // Batched queries share candidates heavily (they probe the same
        // LSH tables), so shards reply with DEDUPLICATED pools; the server
        // concatenates them and computes one Q×P distance matrix — a plain
        // GEMM the MXU (and XLA:CPU) loves — instead of per-query GEMV
        // re-ranks (EXPERIMENTS.md §Perf, iterations 1–2).
        let mut pool_flat: Vec<f32> = Vec::new();
        let mut pool_meta: Vec<(usize, u32)> = Vec::new(); // slot -> (shard, id)
        let mut per_query: Vec<Vec<usize>> = vec![Vec::new(); n];
        for (si, (rx, guard)) in replies.into_iter().enumerate() {
            match rx.recv() {
                Ok(cands) => {
                    drop(guard);
                    let base = pool_meta.len();
                    pool_flat.extend_from_slice(&cands.pool);
                    // GLOBAL shard id in the answer, like the native path.
                    let g = self.cfg.shard_base + si;
                    pool_meta.extend(cands.ids.iter().map(|&id| (g, id)));
                    for (qi, idxs) in cands.per_query.into_iter().enumerate() {
                        per_query[qi].extend(idxs.into_iter().map(|s| base + s as usize));
                    }
                }
                Err(_) => bail!("ANN query failed: shard {si} died mid-query"),
            }
        }
        if pool_flat.is_empty() {
            return Ok(vec![None; n]);
        }
        let t_gather = t0.elapsed();
        let Some(exec) = self.executor.as_mut() else {
            bail!("PJRT re-rank reached without an executor (routing bug)");
        };
        let p = pool_flat.len() / dim;
        let dists = match exec.dist_matrix_tiled(dim, &flat_q, &pool_flat) {
            Ok(d) => d,
            Err(_) => crate::runtime::native::dist_matrix(dim, &flat_q, &pool_flat),
        };
        // On the PJRT path the scatter and per-shard service are one
        // interleaved gather (the candidate recv loop above), so the
        // whole pre-rerank span lands in `stage_shard_service`; the
        // distance GEMM is the rerank stage proper.
        let t_rerank = t0.elapsed() - t_gather;
        self.registry.stage_shard_service.record(t_gather);
        self.registry.stage_rerank.record(t_rerank);
        if log::enabled(log::Level::Debug) {
            log::debug(
                "coordinator::server",
                "pjrt batch reranked",
                crate::kv!(
                    n = n,
                    pool = p,
                    gather_us = t_gather.as_micros(),
                    rerank_us = t_rerank.as_micros()
                ),
            );
        }
        let r2 = (self.cfg.ann.c * self.cfg.ann.r) as f32;
        let r2_sq = r2 * r2;
        Ok(per_query
            .iter()
            .enumerate()
            .map(|(qi, slots)| {
                let row = &dists[qi * p..(qi + 1) * p];
                let mut best: Option<AnnAnswer> = None;
                for &slot in slots {
                    let d_sq = row[slot];
                    if d_sq <= r2_sq
                        && best.as_ref().map_or(true, |b| d_sq.sqrt() < b.dist)
                    {
                        let (shard, id) = pool_meta[slot];
                        best = Some(AnnAnswer { shard, id, dist: d_sq.sqrt() });
                    }
                }
                best
            })
            .collect())
    }

    /// Batched sliding-window KDE: summed kernel estimates and density.
    /// Pure scatter/gather — delegated to the [`QueryPlane`] (KDE never
    /// touches the executor), so the degradation contract is inherited.
    pub fn kde_batch(&mut self, queries: Vec<Vec<f32>>) -> Result<(Vec<f64>, Vec<f64>)> {
        self.plane.kde_batch(queries)
    }

    /// Wait until every shard has drained its mailbox (barrier); pending
    /// batched-ingest buffers are pushed first. On a durable service the
    /// barrier also fsyncs each shard's WAL — and a sync failure is
    /// returned, never swallowed: "flush returned Ok" means "applied AND
    /// on disk" under every fsync policy.
    pub fn flush(&mut self) -> Result<()> {
        self.flush_ingest();
        let mut first_err: Option<String> = None;
        // Barrier EVERY replica: reads may land on any copy, so "flush
        // returned Ok" must mean every copy has applied the stream (the
        // WAL sync itself is a no-op on non-primary replicas).
        for s in &self.shards {
            for tx in s.set.txs() {
                let (rtx, rrx) = channel();
                if !tx.force(ShardCmd::SyncWal(rtx)) {
                    continue; // already shut down: nothing left to sync
                }
                match rrx.recv() {
                    Ok(Ok(())) => {}
                    Ok(Err(e)) => {
                        first_err.get_or_insert(e);
                    }
                    Err(_) => {
                        first_err.get_or_insert("shard died during flush".to_string());
                    }
                }
            }
        }
        match first_err {
            None => Ok(()),
            Some(e) => Err(anyhow!("flush barrier failed: {e}")),
        }
    }

    /// Aggregate statistics (drains mailboxes first). `shed` comes from
    /// the point-denominated counters — NOT from the command-denominated
    /// `BoundedSender::shed_count()`, which would undercount every shed
    /// `InsertBatch` as 1 regardless of its size.
    ///
    /// Shards are drained BEFORE the counters are read: a point is
    /// counted in `inserts` before it is offered, so concurrent wire
    /// ingest can only make `inserts >= stored_points + shed` (in-flight
    /// points); the equality is exact once ingest quiesces.
    pub fn stats(&mut self) -> ServiceStats {
        let (mut stored, mut bytes) = (0usize, 0usize);
        let (mut occupied, mut eh_buckets) = (0usize, 0usize);
        let (mut window_pop, mut seen, mut kept) = (0u64, 0u64, 0u64);
        // Primary replicas only: every copy holds the same points, so
        // summing across replicas would double-count the partition
        // (sketch_bytes deliberately reports ONE copy's footprint; the
        // replica multiplier is visible in `replicas`).
        for s in &self.shards {
            let (tx, rx) = channel();
            if s.set.primary().force(ShardCmd::Stats(tx)) {
                if let Ok(st) = rx.recv() {
                    stored += st.stored;
                    bytes += st.sketch_bytes;
                    occupied += st.kde_occupied_cells;
                    eh_buckets += st.eh_buckets;
                    window_pop += st.window_population;
                    seen += st.sampler_seen;
                    kept += st.sampler_kept;
                }
            }
        }
        // Refresh the sketch gauges from the same drain, so a metrics
        // snapshot taken after any stats poll carries live occupancy.
        self.registry.stored_points.set(stored as u64);
        self.registry.sketch_bytes.set(bytes as u64);
        self.registry.race_occupied_cells.set(occupied as u64);
        self.registry.eh_buckets.set(eh_buckets as u64);
        self.registry.window_population.set(window_pop);
        self.registry.sampler_seen.set(seen);
        self.registry.sampler_kept.set(kept);
        let mut out = ServiceStats::from_registry(&self.registry);
        out.stored_points = stored;
        out.sketch_bytes = bytes;
        out.replicas = self.cfg.replicas as u32;
        out.replica_depths = self
            .shards
            .iter()
            .flat_map(|s| s.set.depths())
            .map(|d| d as u32)
            .collect();
        out.health = self.board.vector();
        out.wal_errors = self.board.wal_errors();
        out.refused_writes = self.board.refused_writes();
        out
    }

    /// Commands shed at the QUEUE level, in commands (diagnostics only —
    /// see [`SketchService::stats`] for the point-denominated number).
    pub fn shed_commands(&self) -> u64 {
        // Sheds are decided by the primary alone (see ReplicaSet), so
        // its queue counter is the whole story.
        self.shards.iter().map(|s| s.set.primary().shed_count()).sum()
    }

    /// Cut a whole-service checkpoint: flush pending ingest, have every
    /// shard seal its WAL and serialize its sketches (in mailbox order,
    /// so each shard's image is consistent with its own high-water mark),
    /// write the checkpoint file atomically, then GC the sealed WAL
    /// segments it covers. Returns the number of points covered.
    pub fn checkpoint(&mut self) -> Result<u64> {
        let Some(dir) = self.cfg.data_dir.clone() else {
            bail!("durability is disabled (start the service with a data_dir)");
        };
        let t_ckpt = Instant::now();
        self.flush_ingest();
        let mut shard_ckpts = Vec::with_capacity(self.shards.len());
        for (i, s) in self.shards.iter().enumerate() {
            // The primary owns the WAL, so its snapshot is the one whose
            // image is consistent with the sealed log — and one image per
            // shard is all recovery needs to rehydrate any replica count.
            let (tx, rx) = channel();
            if !s.set.primary().force(ShardCmd::Snapshot(tx)) {
                bail!("shard {i} mailbox is closed");
            }
            let snap = rx
                .recv()
                .map_err(|_| anyhow!("shard {i} died during snapshot"))?
                .map_err(|e| anyhow!("{e}"))?;
            shard_ckpts.push(checkpoint::ShardCheckpoint {
                hwm: snap.hwm,
                applied_inserts: snap.applied_inserts,
                applied_deletes: snap.applied_deletes,
                sann: snap.sann,
                swakde: snap.swakde,
            });
        }
        let counters = ServiceStats::from_registry(&self.registry);
        // The stored insert/delete counters derive from the per-shard
        // APPLIED counts (captured in the same instant as each shard's
        // hwm), not the global offer-time counters — connection threads
        // keep offering while the checkpoint is cut, and recovery adds
        // replayed records on top, so offer-time values would double-count
        // everything applied between the seal and this snapshot.
        let applied_inserts: u64 = shard_ckpts.iter().map(|s| s.applied_inserts).sum();
        let applied_deletes: u64 = shard_ckpts.iter().map(|s| s.applied_deletes).sum();
        let data = checkpoint::CheckpointData {
            epoch: self.ckpt_epoch + 1,
            dim: self.cfg.dim as u64,
            counters: [
                applied_inserts + counters.shed,
                applied_deletes,
                counters.ann_queries,
                counters.kde_queries,
                counters.shed,
            ],
            shards: shard_ckpts,
        };
        checkpoint::write_atomic(&dir, &data)?;
        // Only after the rename is durable do the sealed segments die.
        for (i, sc) in data.shards.iter().enumerate() {
            if let Err(e) = wal::gc_segments(&dir, i, sc.hwm) {
                log::warn(
                    "coordinator::server",
                    "WAL GC failed (will retry next checkpoint)",
                    crate::kv!(shard = i, err = e),
                );
            }
        }
        self.ckpt_epoch = data.epoch;
        // Trigger bookkeeping and the reported coverage both use the
        // hwm-consistent value (what the checkpoint actually contains),
        // not the still-moving offer-time counter: points that landed
        // after the seal count toward the NEXT checkpoint.
        let covered = data.counters[0];
        self.inserts_at_ckpt = covered;
        self.last_ckpt_time = Instant::now();
        self.registry.checkpoint_duration.record(t_ckpt.elapsed());
        Ok(covered)
    }

    /// Fire the background checkpoint when either configured trigger is
    /// due. Time-based triggers only fire if new points arrived — an idle
    /// service must not rewrite identical checkpoints forever.
    fn maybe_background_checkpoint(&mut self) {
        let inserts = self.registry.inserts.get();
        let new_points = inserts.saturating_sub(self.inserts_at_ckpt);
        let due_points = self
            .cfg
            .checkpoint_every_points
            .map_or(false, |n| new_points >= n);
        let due_time = self.cfg.checkpoint_every_secs.map_or(false, |t| {
            new_points > 0 && self.last_ckpt_time.elapsed().as_secs() >= t
        });
        if due_points || due_time {
            if let Err(e) = self.checkpoint() {
                log::warn(
                    "coordinator::server",
                    "background checkpoint failed",
                    crate::kv!(err = e),
                );
                // Push the next attempt a full interval out instead of
                // hot-looping on a persistent error.
                self.last_ckpt_time = Instant::now();
                self.inserts_at_ckpt = inserts;
            }
        }
    }

    /// Detect dead SECONDARY replicas (`JoinHandle::is_finished`) and
    /// heal each one from the primary's live state. The primary is never
    /// auto-restarted: it owns the WAL, so its death (e.g. the `abort`
    /// durability policy doing its job) is fail-stop by design — reads
    /// fail over to the surviving copies and writes start failing loudly.
    fn supervise_replicas(&mut self) {
        for i in 0..self.shards.len() {
            for r in 1..self.cfg.replicas {
                let dead = self.shards[i]
                    .joins
                    .get(r)
                    .is_some_and(|j| j.is_finished());
                if dead {
                    if let Err(e) = self.heal_replica(i, r) {
                        log::error(
                            "coordinator::server",
                            "replica died and could not be healed (will retry)",
                            crate::kv!(shard = i, replica = r, err = e),
                        );
                    }
                }
            }
        }
    }

    /// Rebuild one dead replica from the primary's live state: cut a
    /// `CloneState` image (sketches + applied counts, WAL untouched),
    /// rehydrate a fresh `Shard` built with the replica's original
    /// constructor arguments, and install its mailbox into the shared
    /// slot. The whole sequence runs with write fan-out blocked, so the
    /// image and the installed mailbox see no interleaved write — the
    /// healed copy is bit-identical to the primary by the replica-state
    /// determinism argument (state is a function of the mutation
    /// sequence, which the image captures in full).
    fn heal_replica(&mut self, i: usize, r: usize) -> Result<()> {
        use crate::sketch::snapshot::{load_sann, load_swakde};
        let per_shard_n = self.cfg.ann.n_max.div_ceil(self.cfg.shards).max(2);
        let ann_cfg = SAnnConfig { n_max: per_shard_n, ..self.cfg.ann.clone() };
        let kde_cfg = KdeShardConfig {
            window: (self.cfg.kde.window / self.cfg.shards as u64).max(1),
            ..self.cfg.kde.clone()
        };
        let set = self.shards[i].set.clone();
        let (queue_cap, overload, seed) = (self.cfg.queue_cap, self.cfg.overload, self.cfg.seed);
        // Same GLOBAL index/seed the replica was originally built with.
        let g = self.cfg.shard_base + i;
        let new_join = set.with_writes_blocked(|| -> Result<JoinHandle<()>> {
            let (ctx, crx) = channel();
            if !set.primary().force(ShardCmd::CloneState(ctx)) {
                bail!("shard {i} primary is down; nothing to heal from");
            }
            let img = crx
                .recv()
                .map_err(|_| anyhow!("shard {i} primary died during the clone cut"))?;
            let mut shard = Shard::new(g, ann_cfg, &kde_cfg, seed ^ 0xD1E5 ^ g as u64);
            shard.restore_state(
                load_sann(&img.sann)?,
                load_swakde(&img.swakde)?,
                img.applied_inserts,
                img.applied_deletes,
            )?;
            let (tx, rx) = bounded(queue_cap, overload);
            let join = std::thread::Builder::new()
                .name(format!("shard-{i}r{r}"))
                .spawn(move || shard.run(rx))?;
            set.install(r, tx);
            Ok(join)
        })?;
        let old = std::mem::replace(&mut self.shards[i].joins[r], new_join);
        let _ = old.join(); // reap the panicked thread (Err is expected)
        log::info(
            "coordinator::server",
            "healed replica from the primary's live state",
            crate::kv!(shard = i, replica = r),
        );
        Ok(())
    }

    /// Cloneable ingest/query front for connection threads. Inserts,
    /// deletes, and native ANN/KDE reads run straight against the shard
    /// mailboxes from the calling thread; only what needs the service's
    /// own state (PJRT queries, stats, flush, checkpoint) travels over
    /// `cmd_tx` and must be drained by [`Self::run_cmd_loop`] on the
    /// thread that owns the service.
    pub fn handle(&self, cmd_tx: crate::util::sync::mpsc::Sender<ServiceCmd>) -> ServiceHandle {
        ServiceHandle::new(
            self.shards.iter().map(|s| s.set.clone()).collect(),
            self.cfg.route,
            self.cfg.dim,
            self.cfg.shards,
            self.cfg.shard_base,
            Arc::clone(&self.registry),
            Arc::clone(&self.board),
            cmd_tx,
            self.cfg.use_pjrt,
        )
    }

    /// Drain handle commands until `Shutdown` arrives or every handle is
    /// dropped, then shut the shards down. Neither ingest nor native
    /// reads ever wait here: handles push inserts into the bounded shard
    /// mailboxes and execute native ANN/KDE through their own
    /// [`QueryPlane`], so this loop only sees control-plane commands
    /// (plus `Ann` on PJRT services, where the re-rank needs the
    /// thread-pinned executor).
    ///
    /// With a background checkpoint trigger configured, the loop wakes on
    /// a short timeout so checkpoints fire on a durable-but-idle control
    /// plane too (wire ingest flows through shard mailboxes, never
    /// through this channel). Checkpoints run HERE, on the owning thread,
    /// so the PJRT executor stays thread-pinned.
    pub fn run_cmd_loop(mut self, rx: Receiver<ServiceCmd>) {
        let background = self.cfg.data_dir.is_some()
            && (self.cfg.checkpoint_every_points.is_some()
                || self.cfg.checkpoint_every_secs.is_some());
        // Replica supervision shares the same periodic tick: with R > 1
        // the loop must wake even when no command (and no checkpoint
        // trigger) is flowing, or a crashed replica would sit dead until
        // the next control-plane call.
        let supervise = self.cfg.replicas > 1;
        let tick = background || supervise;
        loop {
            let cmd = if tick {
                match rx.recv_timeout(Duration::from_millis(200)) {
                    Ok(cmd) => Some(cmd),
                    Err(RecvTimeoutError::Timeout) => None,
                    Err(RecvTimeoutError::Disconnected) => break,
                }
            } else {
                match rx.recv() {
                    Ok(cmd) => Some(cmd),
                    Err(_) => break,
                }
            };
            if let Some(cmd) = cmd {
                match cmd {
                    ServiceCmd::Ann(qs, reply) => {
                        let _ = reply.send(self.query_batch(qs).map_err(|e| e.to_string()));
                    }
                    ServiceCmd::Stats(reply) => {
                        let _ = reply.send(self.stats());
                    }
                    ServiceCmd::Flush(reply) => {
                        let _ = reply.send(self.flush().map_err(|e| e.to_string()));
                    }
                    ServiceCmd::Checkpoint(reply) => {
                        let _ = reply.send(self.checkpoint().map_err(|e| e.to_string()));
                    }
                    ServiceCmd::Shutdown => break,
                }
            }
            if supervise {
                self.supervise_replicas();
            }
            if background {
                self.maybe_background_checkpoint();
            }
        }
        self.shutdown();
    }

    /// Start a service on a dedicated owning thread and return a cloneable
    /// [`ServiceHandle`] plus the thread's join handle. The service is
    /// constructed INSIDE the thread because the PJRT executor must stay
    /// on its owning thread (it is deliberately not `Send`). Call
    /// `handle.shutdown()` and then join to stop it.
    pub fn spawn(cfg: ServiceConfig) -> Result<(ServiceHandle, JoinHandle<()>)> {
        let (htx, hrx) = channel();
        let join = std::thread::Builder::new()
            .name("sketch-service".into())
            .spawn(move || {
                let svc = match SketchService::start(cfg) {
                    Ok(svc) => svc,
                    Err(e) => {
                        let _ = htx.send(Err(e));
                        return;
                    }
                };
                let (cmd_tx, cmd_rx) = channel();
                let _ = htx.send(Ok(svc.handle(cmd_tx)));
                svc.run_cmd_loop(cmd_rx);
            })?;
        match hrx.recv() {
            Ok(Ok(handle)) => Ok((handle, join)),
            Ok(Err(e)) => {
                let _ = join.join();
                Err(e)
            }
            Err(_) => {
                let _ = join.join();
                Err(anyhow!("service thread died during startup"))
            }
        }
    }

    /// Graceful shutdown (every replica of every shard).
    pub fn shutdown(mut self) {
        for s in &self.shards {
            for tx in s.set.txs() {
                let _ = tx.force(ShardCmd::Shutdown);
            }
        }
        for s in &mut self.shards {
            for j in s.joins.drain(..) {
                let _ = j.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn small_cfg() -> ServiceConfig {
        let mut kde = ServiceConfig::default_for(8, 1000).kde;
        kde.rows = 8;
        kde.window = 200;
        ServiceConfig::builder(8, 1000)
            .shards(2)
            .eta(0.0)
            .kde(kde)
            .build()
            .expect("small_cfg is valid")
    }

    #[test]
    fn insert_query_shutdown() {
        let mut svc = SketchService::start(small_cfg()).unwrap();
        let mut rng = Rng::new(1);
        let pts: Vec<Vec<f32>> = (0..100)
            .map(|_| (0..8).map(|_| rng.gaussian_f32()).collect())
            .collect();
        for p in &pts {
            assert!(svc.insert(p.clone()));
        }
        svc.flush().unwrap();
        let answers = svc.query_batch(pts[..10].to_vec()).unwrap();
        let hits = answers.iter().filter(|a| a.is_some()).count();
        assert!(hits >= 9, "hits={hits}/10");
        for a in answers.into_iter().flatten() {
            assert!(a.dist <= 2.0 + 1e-5);
        }
        let st = svc.stats();
        assert_eq!(st.inserts, 100);
        assert_eq!(st.stored_points, 100, "eta=0 stores all");
        svc.shutdown();
    }

    #[test]
    fn native_insert_batch_matches_single_inserts() {
        let mut rng = Rng::new(9);
        let pts: Vec<Vec<f32>> = (0..120)
            .map(|_| (0..8).map(|_| rng.gaussian_f32()).collect())
            .collect();
        let mut singles = SketchService::start(small_cfg()).unwrap();
        for p in &pts {
            singles.insert(p.clone());
        }
        singles.flush().unwrap();
        let mut batched = SketchService::start(small_cfg()).unwrap();
        let ok = batched.insert_batch(pts.clone());
        assert_eq!(ok, 120);
        batched.flush().unwrap();
        let a = singles.query_batch(pts[..20].to_vec()).unwrap();
        let b = batched.query_batch(pts[..20].to_vec()).unwrap();
        assert_eq!(a, b, "batched ingest must build the same sketch state");
        assert_eq!(batched.stats().stored_points, 120, "eta=0 stores all");
        singles.shutdown();
        batched.shutdown();
    }

    #[test]
    fn kde_batch_counts_window_population() {
        let mut svc = SketchService::start(small_cfg()).unwrap();
        let mut rng = Rng::new(2);
        for _ in 0..60 {
            let p: Vec<f32> = (0..8).map(|_| rng.gaussian_f32()).collect();
            svc.insert(p);
        }
        svc.flush().unwrap();
        let q: Vec<f32> = (0..8).map(|_| rng.gaussian_f32()).collect();
        let (sums, density) = svc.kde_batch(vec![q]).unwrap();
        assert_eq!(sums.len(), 1);
        assert!(sums[0] >= 0.0);
        assert!(density[0] >= 0.0 && density[0] <= 1.0 + 1e-9);
        svc.shutdown();
    }

    #[test]
    fn delete_routes_to_owning_shard() {
        let mut svc = SketchService::start(small_cfg()).unwrap();
        let p: Vec<f32> = (0..8).map(|i| i as f32 * 0.1).collect();
        svc.insert(p.clone());
        svc.flush().unwrap();
        assert!(svc.delete(p.clone()), "must delete the stored copy");
        assert!(!svc.delete(p.clone()), "second delete no-op");
        svc.flush().unwrap();
        let ans = svc.query_batch(vec![p]).unwrap();
        assert!(ans[0].is_none(), "deleted point must not answer");
        svc.shutdown();
    }

    #[test]
    fn empty_batch_is_fine() {
        let mut svc = SketchService::start(small_cfg()).unwrap();
        assert!(svc.query_batch(vec![]).unwrap().is_empty());
        let (s, d) = svc.kde_batch(vec![]).unwrap();
        assert!(s.is_empty() && d.is_empty());
        svc.shutdown();
    }

    #[test]
    fn shed_policy_counts_drops_without_deadlock() {
        let mut cfg = small_cfg();
        cfg.queue_cap = 2;
        cfg.overload = Overload::Shed;
        let mut svc = SketchService::start(cfg).unwrap();
        let mut rng = Rng::new(3);
        for _ in 0..5000 {
            let p: Vec<f32> = (0..8).map(|_| rng.gaussian_f32()).collect();
            svc.insert(p); // may shed; must never block forever
        }
        svc.flush().unwrap();
        let st = svc.stats();
        assert_eq!(st.inserts, 5000);
        // Point-denominated shed accounting must reconcile EXACTLY: with
        // eta = 0 every offered point is either stored or counted shed.
        assert_eq!(
            st.stored_points as u64 + st.shed,
            5000,
            "shed must be point-denominated: {st:?}"
        );
        svc.shutdown();
    }

    #[test]
    fn batched_shed_accounting_is_point_denominated() {
        // InsertBatch commands carry up to 64 points each; a shed command
        // must count all of its points, not 1. The queue-level command
        // counter stays available as a diagnostic and is necessarily <=
        // the point count whenever batches shed.
        let mut cfg = small_cfg();
        cfg.queue_cap = 1;
        cfg.overload = Overload::Shed;
        let mut svc = SketchService::start(cfg).unwrap();
        let mut rng = Rng::new(7);
        let pts: Vec<Vec<f32>> = (0..4096)
            .map(|_| (0..8).map(|_| rng.gaussian_f32()).collect())
            .collect();
        let ok = svc.insert_batch(pts);
        svc.flush().unwrap();
        let st = svc.stats();
        assert_eq!(st.inserts, 4096);
        assert_eq!(
            st.stored_points as u64 + st.shed,
            4096,
            "point accounting: {st:?}"
        );
        assert_eq!(ok as u64, 4096 - st.shed, "accepted = offered - shed");
        assert!(
            svc.shed_commands() <= st.shed,
            "commands ({}) can never exceed points ({})",
            svc.shed_commands(),
            st.shed
        );
        svc.shutdown();
    }

    #[test]
    fn replicated_service_serves_and_counts_one_copy() {
        let mut cfg = small_cfg();
        cfg.replicas = 2;
        let mut svc = SketchService::start(cfg).unwrap();
        let mut rng = Rng::new(21);
        let pts: Vec<Vec<f32>> = (0..100)
            .map(|_| (0..8).map(|_| rng.gaussian_f32()).collect())
            .collect();
        assert_eq!(svc.insert_batch(pts.clone()), 100);
        svc.flush().unwrap();
        let ans = svc.query_batch(pts[..10].to_vec()).unwrap();
        assert!(ans.iter().filter(|a| a.is_some()).count() >= 9);
        let st = svc.stats();
        assert_eq!(st.inserts, 100);
        assert_eq!(st.stored_points, 100, "replicas must not double-count");
        assert_eq!(st.replicas, 2);
        assert_eq!(st.replica_depths.len(), 2 * 2, "shards x replicas gauges");
        assert!(st.replica_depths.iter().all(|&d| d == 0), "idle service");
        svc.shutdown();
    }

    #[test]
    fn checkpoint_requires_data_dir() {
        let mut svc = SketchService::start(small_cfg()).unwrap();
        let err = svc.checkpoint().unwrap_err().to_string();
        assert!(err.contains("durability"), "{err}");
        svc.shutdown();
    }

    #[test]
    fn durable_service_checkpoints_and_recovers_counters() {
        let dir = std::env::temp_dir().join(format!(
            "sketchd_svc_ckpt_{}_{}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        let mut cfg = small_cfg();
        cfg.data_dir = Some(dir.clone());
        let mut rng = Rng::new(404);
        let pts: Vec<Vec<f32>> = (0..120)
            .map(|_| (0..8).map(|_| rng.gaussian_f32()).collect())
            .collect();
        let mut svc = SketchService::start(cfg.clone()).unwrap();
        for p in &pts[..80] {
            svc.insert(p.clone());
        }
        svc.flush().unwrap();
        assert_eq!(svc.checkpoint().unwrap(), 80, "covers all 80 points");
        for p in &pts[80..] {
            svc.insert(p.clone());
        }
        svc.flush().unwrap(); // barrier also syncs the WAL tail
        svc.shutdown();

        // Restart from the same data_dir: checkpoint + WAL replay.
        let mut back = SketchService::start(cfg).unwrap();
        let st = back.stats();
        assert_eq!(st.inserts, 120, "80 from checkpoint + 40 replayed");
        assert_eq!(st.stored_points, 120, "eta=0 stores all");
        assert_eq!(st.shed, 0);
        // The recovered service keeps serving and checkpointing.
        let ans = back.query_batch(pts[..10].to_vec()).unwrap();
        assert!(ans.iter().filter(|a| a.is_some()).count() >= 9);
        assert_eq!(back.checkpoint().unwrap(), 120);
        back.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn handle_parity_and_shared_counters() {
        // The same stream through a ServiceHandle must build the same
        // sketch state as driving the service directly, and every handle
        // operation must land in the shared counters.
        let mut rng = Rng::new(11);
        let pts: Vec<Vec<f32>> = (0..150)
            .map(|_| (0..8).map(|_| rng.gaussian_f32()).collect())
            .collect();
        let mut direct = SketchService::start(small_cfg()).unwrap();
        direct.insert_batch(pts.clone());
        direct.flush().unwrap();
        let want = direct.query_batch(pts[..20].to_vec()).unwrap();
        let (want_sums, want_dens) = direct.kde_batch(pts[..20].to_vec()).unwrap();
        direct.shutdown();

        let (handle, join) = SketchService::spawn(small_cfg()).unwrap();
        let h2 = handle.clone();
        assert_eq!(handle.insert_batch(pts[..75].to_vec()), 75);
        assert_eq!(h2.insert_batch(pts[75..].to_vec()), 75);
        handle.flush().unwrap();
        let got = handle.query_batch(pts[..20].to_vec()).unwrap();
        assert_eq!(got, want, "handle ingest must build identical state");
        let (sums, dens) = h2.kde_batch(pts[..20].to_vec()).unwrap();
        assert_eq!(sums, want_sums);
        assert_eq!(dens, want_dens);
        let st = handle.stats().unwrap();
        assert_eq!(st.inserts, 150, "clones share one counter set");
        assert_eq!(st.ann_queries, 20);
        assert_eq!(st.kde_queries, 20);
        assert_eq!(st.stored_points as u64 + st.shed, 150);
        handle.shutdown();
        join.join().unwrap();
    }

    #[test]
    fn handle_delete_routes_like_service() {
        let (handle, join) = SketchService::spawn(small_cfg()).unwrap();
        let p: Vec<f32> = (0..8).map(|i| i as f32 * 0.1).collect();
        assert!(handle.insert(p.clone()));
        handle.flush().unwrap();
        assert!(handle.delete(p.clone()), "must delete the stored copy");
        assert!(!handle.delete(p.clone()), "second delete no-op");
        handle.flush().unwrap();
        let ans = handle.query_batch(vec![p]).unwrap();
        assert!(ans[0].is_none(), "deleted point must not answer");
        assert_eq!(handle.stats().unwrap().deletes, 2);
        handle.shutdown();
        join.join().unwrap();
    }
}
