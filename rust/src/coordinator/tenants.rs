//! Named collections: one `sketchd` process, many independent tenants.
//!
//! The paper's compactness results (`O(n^{1+ρ-η})` for S-ANN, polylog
//! per SW-AKDE window) mean a single process has room for many
//! workloads, so the serving layer grows a registry of them. Each
//! collection is a full [`SketchService`] of its own — its own
//! [`ServiceConfig`] (dim, shards, replicas, LSH params, overload
//! policy), its own metrics [`Registry`] (per-tenant point accounting:
//! `inserts == stored + shed + refused` reconciles per collection, not
//! just per process), its own `data_dir/<name>/` subtree under the
//! existing WAL/checkpoint discipline — so tenancy adds NO new sharing:
//! isolation is by construction, and a collection answers bit-identically
//! to a single-tenant process with the same config (pinned by
//! `tests/multi_tenant.rs`).
//!
//! Collection id 0 is the DEFAULT collection: it runs the process's own
//! base config directly on the ROOT data dir, which is exactly the
//! layout a pre-tenancy (protocol v5) server wrote — so old data dirs
//! recover unchanged and v5 clients, whose frames decode as collection
//! 0, keep their semantics bit-for-bit. Named collections live in the
//! durable [`Manifest`] and are rehydrated on startup, each through the
//! same recovery path a single-tenant service uses.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::thread::JoinHandle;

use anyhow::{anyhow, bail, Result};

use crate::durability::manifest::{Manifest, ManifestEntry};
use crate::metrics::registry::Registry;
use crate::obs::log;
use crate::sketch::ann::SAnnConfig;
use crate::util::sync::{lock_unpoisoned, Arc, Mutex};

use super::backpressure::Overload;
use super::handle::ServiceHandle;
use super::server::{ConfigError, ServiceConfig, SketchService};

/// Reserved name (and id 0) of the collection every v5 frame addresses.
pub const DEFAULT_COLLECTION: &str = "default";

/// Wire-visible shape of a collection: everything `CreateCollection`
/// lets a client choose, everything the manifest persists. Field order
/// here is the wire order (`net::frame::put_spec`/`read_spec`).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CollectionSpec {
    pub dim: u32,
    pub shards: u32,
    pub replicas: u32,
    /// Sketch capacity (points) the S-ANN structure sizes itself for.
    pub n_max: u64,
    /// Whole-collection sliding-window size for SW-AKDE.
    pub window: u64,
    /// S-ANN subsampling exponent η ∈ [0, 1].
    pub eta: f64,
    /// Overload policy: 0 = block, 1 = shed.
    pub overload: u8,
    pub seed: u64,
}

impl CollectionSpec {
    /// Defaults matching [`ServiceConfig::default_for`] — what a client
    /// that only knows its dimensionality should send.
    pub fn for_dim(dim: u32, n_max: u64) -> Self {
        CollectionSpec {
            dim,
            shards: 4,
            replicas: 1,
            n_max,
            window: 1024,
            eta: 0.5,
            overload: 0,
            seed: 42,
        }
    }
}

/// What `ListCollections` reports per collection.
#[derive(Clone, Debug, PartialEq)]
pub struct CollectionInfo {
    pub id: u32,
    pub name: String,
    pub dim: u32,
    pub shards: u32,
    pub replicas: u32,
}

/// Derive the full per-tenant [`ServiceConfig`] a spec denotes, layered
/// on the process's base config: geometry and stream knobs come from the
/// SPEC (dim, shards, replicas, n_max, window, eta, overload, seed),
/// operator policy comes from the BASE (route, queue depth, kde kernel
/// shape, fsync cadence, checkpoint triggers, durability-loss policy).
/// Durability knobs only carry over when the collection actually has a
/// `data_dir` — an ephemeral tenant under a durable base must not trip
/// [`ConfigError::DurabilityWithoutDataDir`].
///
/// This function is the tenant-isolation contract: a standalone
/// single-tenant process spawned from the same derivation (with its own
/// dir) is bit-identical to the hosted collection, because the config IS
/// the behavior. `tests/multi_tenant.rs` pins exactly that.
pub fn tenant_config(
    base: &ServiceConfig,
    spec: &CollectionSpec,
    data_dir: Option<PathBuf>,
) -> Result<ServiceConfig, ConfigError> {
    let dim = spec.dim as usize;
    let n_max = spec.n_max as usize;
    let durable = data_dir.is_some();
    let mut b = ServiceConfig::builder(dim, n_max)
        .shards(spec.shards as usize)
        .replicas(spec.replicas as usize)
        .route(base.route)
        .queue_cap(base.queue_cap)
        .overload(if spec.overload == 1 { Overload::Shed } else { Overload::Block })
        .ann(SAnnConfig {
            dim,
            n_max,
            eta: spec.eta,
            ..base.ann.clone()
        })
        .kde(base.kde.clone())
        .window(spec.window)
        .seed(spec.seed)
        .on_durability_loss(base.on_durability_loss)
        .data_dir(data_dir);
    if durable {
        b = b
            .fsync(base.fsync)
            .checkpoint_every_points(base.checkpoint_every_points)
            .checkpoint_every_secs(base.checkpoint_every_secs);
    }
    b.build()
}

/// One live tenant: its spec, its running service's handle, and the
/// owning thread to join on drop/shutdown.
struct Tenant {
    name: String,
    spec: CollectionSpec,
    handle: ServiceHandle,
    join: Option<JoinHandle<()>>,
}

struct Inner {
    /// Monotonic; ids are NEVER reused across create/drop cycles, so a
    /// stale client holding a dropped id gets "unknown collection",
    /// never another tenant's data.
    next_id: u32,
    by_name: BTreeMap<String, u32>,
    tenants: BTreeMap<u32, Tenant>,
}

/// The registry of per-tenant shard sets one process serves. Cheap to
/// share (`Arc`); the lock guards only the maps — every data-plane op
/// runs on a cloned [`ServiceHandle`] outside it.
pub struct Tenants {
    base: ServiceConfig,
    /// Root data dir; named collections live in `<root>/<name>/`,
    /// the default collection and the manifest at the root itself.
    root: Option<PathBuf>,
    inner: Mutex<Inner>,
}

impl Tenants {
    /// Boot the default collection from `base` (recovering the root data
    /// dir exactly as a single-tenant server would), then rehydrate
    /// every named collection in the manifest through the same per-dir
    /// recovery path. Fails if ANY tenant fails to recover — a silently
    /// absent tenant is data loss, not degraded service.
    pub fn open(base: ServiceConfig) -> Result<Tenants> {
        let root = base.data_dir.clone();
        let (handle, join) = SketchService::spawn(base.clone())?;
        let mut inner = Inner {
            next_id: 1,
            by_name: BTreeMap::new(),
            tenants: BTreeMap::new(),
        };
        inner.by_name.insert(DEFAULT_COLLECTION.to_string(), 0);
        inner.tenants.insert(
            0,
            Tenant {
                name: DEFAULT_COLLECTION.to_string(),
                spec: CollectionSpec {
                    dim: base.dim as u32,
                    shards: base.shards as u32,
                    replicas: base.replicas as u32,
                    n_max: base.ann.n_max as u64,
                    window: base.kde.window,
                    eta: base.ann.eta,
                    overload: if base.overload == Overload::Shed { 1 } else { 0 },
                    seed: base.seed,
                },
                handle,
                join: Some(join),
            },
        );
        let tenants = Tenants { base, root, inner: Mutex::new(inner) };
        if let Some(root) = tenants.root.clone() {
            let manifest = Manifest::load(&root)?;
            let mut inner = lock_unpoisoned(&tenants.inner);
            inner.next_id = manifest.next_id;
            for e in manifest.entries {
                let cfg = tenant_config(&tenants.base, &e.spec, Some(root.join(&e.name)))
                    .map_err(|err| {
                        anyhow!("collection {:?}: invalid manifest spec: {err}", e.name)
                    })?;
                let (handle, join) = SketchService::spawn(cfg)
                    .map_err(|err| anyhow!("collection {:?} failed to recover: {err}", e.name))?;
                log::info(
                    "coordinator::tenants",
                    "recovered named collection",
                    crate::kv!(name = e.name, id = e.id, dim = e.spec.dim),
                );
                inner.by_name.insert(e.name.clone(), e.id);
                inner.tenants.insert(
                    e.id,
                    Tenant { name: e.name, spec: e.spec, handle, join: Some(join) },
                );
            }
        }
        Ok(tenants)
    }

    /// The process's base config (named tenants derive from it).
    pub fn base(&self) -> &ServiceConfig {
        &self.base
    }

    /// Handle for a collection id, if it exists. Cloning the handle is
    /// the cheap, lock-free-data-plane way to use it: the registry lock
    /// is held only for the map lookup.
    pub fn resolve(&self, coll: u32) -> Option<ServiceHandle> {
        let inner = lock_unpoisoned(&self.inner);
        inner.tenants.get(&coll).map(|t| t.handle.clone())
    }

    /// Resolve a collection by name.
    pub fn resolve_name(&self, name: &str) -> Option<(u32, ServiceHandle)> {
        let inner = lock_unpoisoned(&self.inner);
        let id = *inner.by_name.get(name)?;
        inner.tenants.get(&id).map(|t| (id, t.handle.clone()))
    }

    /// The default collection's handle (always present).
    pub fn default_handle(&self) -> ServiceHandle {
        let inner = lock_unpoisoned(&self.inner);
        match inner.tenants.get(&0) {
            Some(t) => t.handle.clone(),
            // Unreachable by construction (open() always seeds id 0 and
            // nothing removes it); keep a diagnosable panic over UB.
            None => unreachable!("default collection is never dropped"),
        }
    }

    /// Create a named collection: validate, spawn its service, persist
    /// the manifest, and only then publish it to the maps — so a
    /// manifest-write failure leaves no half-created tenant behind.
    pub fn create(&self, name: &str, spec: &CollectionSpec) -> Result<CollectionInfo> {
        validate_name(name)?;
        if spec.overload > 1 {
            bail!("overload must be 0 (block) or 1 (shed), got {}", spec.overload);
        }
        // Reserve the id under the lock, but spawn OUTSIDE it: recovery
        // of a large dir must not block the data plane of other tenants.
        let id = {
            let mut inner = lock_unpoisoned(&self.inner);
            if inner.by_name.contains_key(name) {
                bail!("collection {name:?} already exists");
            }
            let id = inner.next_id;
            inner.next_id += 1;
            id
        };
        let dir = self.root.as_ref().map(|r| r.join(name));
        let cfg = tenant_config(&self.base, spec, dir)?;
        let (handle, join) = SketchService::spawn(cfg)?;
        let mut inner = lock_unpoisoned(&self.inner);
        if inner.by_name.contains_key(name) {
            // Lost a create race for the same name; back out our spawn.
            drop(inner);
            handle.shutdown();
            let _ = join.join();
            bail!("collection {name:?} already exists");
        }
        inner.by_name.insert(name.to_string(), id);
        inner.tenants.insert(
            id,
            Tenant { name: name.to_string(), spec: spec.clone(), handle, join: Some(join) },
        );
        if let Some(root) = &self.root {
            if let Err(e) = self.persist_locked(&inner, root) {
                // Unpublish: a collection the manifest cannot record
                // would vanish on restart while looking durable now.
                let t = inner.tenants.remove(&id);
                inner.by_name.remove(name);
                drop(inner);
                if let Some(mut t) = t {
                    t.handle.shutdown();
                    if let Some(j) = t.join.take() {
                        let _ = j.join();
                    }
                }
                return Err(e);
            }
        }
        log::info(
            "coordinator::tenants",
            "created collection",
            crate::kv!(name = name, id = id, dim = spec.dim, shards = spec.shards),
        );
        Ok(CollectionInfo {
            id,
            name: name.to_string(),
            dim: spec.dim,
            shards: spec.shards,
            replicas: spec.replicas,
        })
    }

    /// Drop a named collection: unpublish, stop its service, delete its
    /// subtree, persist the manifest. The default collection cannot be
    /// dropped (v5 clients depend on its existence).
    pub fn drop_collection(&self, name: &str) -> Result<()> {
        if name == DEFAULT_COLLECTION {
            bail!("the default collection cannot be dropped");
        }
        let mut t = {
            let mut inner = lock_unpoisoned(&self.inner);
            let Some(id) = inner.by_name.remove(name) else {
                bail!("unknown collection {name:?}");
            };
            let t = inner.tenants.remove(&id);
            if let Some(root) = &self.root {
                self.persist_locked(&inner, root)?;
            }
            t
        };
        if let Some(t) = t.as_mut() {
            t.handle.shutdown();
            if let Some(j) = t.join.take() {
                let _ = j.join();
            }
        }
        if let Some(root) = &self.root {
            let dir = root.join(name);
            if let Err(e) = std::fs::remove_dir_all(&dir) {
                if e.kind() != std::io::ErrorKind::NotFound {
                    log::warn(
                        "coordinator::tenants",
                        "dropped collection's data dir was not fully removed",
                        crate::kv!(dir = dir.display(), err = e),
                    );
                }
            }
        }
        log::info("coordinator::tenants", "dropped collection", crate::kv!(name = name));
        Ok(())
    }

    /// Every collection, default first, then by id.
    pub fn list(&self) -> Vec<CollectionInfo> {
        let inner = lock_unpoisoned(&self.inner);
        inner
            .tenants
            .iter()
            .map(|(&id, t)| CollectionInfo {
                id,
                name: t.name.clone(),
                dim: t.spec.dim,
                shards: t.spec.shards,
                replicas: t.spec.replicas,
            })
            .collect()
    }

    /// Per-tenant metrics registries `(name, registry)`, default first —
    /// the scrape endpoint renders the default unprefixed (v5 dashboards
    /// keep working) and each named tenant under a name prefix.
    pub fn registries(&self) -> Vec<(String, Arc<Registry>)> {
        let inner = lock_unpoisoned(&self.inner);
        inner
            .tenants
            .values()
            .map(|t| (t.name.clone(), Arc::clone(t.handle.registry())))
            .collect()
    }

    /// Tear every tenant down WITHOUT a shutdown command: handles are
    /// dropped (mailboxes disconnect; shard threads exit on their own,
    /// cutting no final checkpoint) and the owning threads joined. As
    /// far as the on-disk state goes this is a `kill -9` — a reopen of
    /// the same data dir must recover from checkpoint + WAL tail alone.
    /// Crash-recovery tests use it; a server has no reason to.
    pub fn crash(&self) {
        let tenants: Vec<Tenant> = {
            let mut inner = lock_unpoisoned(&self.inner);
            let ids: Vec<u32> = inner.tenants.keys().copied().collect();
            ids.into_iter().filter_map(|id| inner.tenants.remove(&id)).collect()
        };
        for t in tenants {
            let Tenant { handle, mut join, .. } = t;
            drop(handle);
            if let Some(j) = join.take() {
                let _ = j.join();
            }
        }
    }

    /// Shut every tenant down and join their owning threads. Idempotent.
    pub fn shutdown(&self) {
        let tenants: Vec<Tenant> = {
            let mut inner = lock_unpoisoned(&self.inner);
            let ids: Vec<u32> = inner.tenants.keys().copied().collect();
            ids.into_iter().filter_map(|id| inner.tenants.remove(&id)).collect()
        };
        for mut t in tenants {
            t.handle.shutdown();
            if let Some(j) = t.join.take() {
                let _ = j.join();
            }
        }
    }

    fn persist_locked(&self, inner: &Inner, root: &std::path::Path) -> Result<()> {
        let manifest = Manifest {
            next_id: inner.next_id,
            entries: inner
                .tenants
                .iter()
                .filter(|(&id, _)| id != 0)
                .map(|(&id, t)| ManifestEntry {
                    id,
                    name: t.name.clone(),
                    spec: t.spec.clone(),
                })
                .collect(),
        };
        manifest.store(root)
    }
}

/// Collection names are path components (each names a `data_dir`
/// subtree) and metric-name prefixes, so the alphabet is tight:
/// `[A-Za-z0-9_]` first, `[A-Za-z0-9_-]` after, at most 64 chars. The
/// leading character rule keeps names disjoint from the root dir's own
/// `wal-*`/`checkpoint-*` files and from dotfiles.
pub fn validate_name(name: &str) -> Result<()> {
    if name.is_empty() || name.len() > 64 {
        bail!("collection name must be 1..=64 characters");
    }
    if name == DEFAULT_COLLECTION {
        bail!("{DEFAULT_COLLECTION:?} is reserved for the default collection");
    }
    let mut chars = name.chars();
    let ok_first = chars
        .next()
        .is_some_and(|c| c.is_ascii_alphanumeric() || c == '_');
    if !ok_first || !name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-') {
        bail!(
            "collection name {name:?} is invalid: [A-Za-z0-9_] first, \
             then [A-Za-z0-9_-] only"
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base_cfg() -> ServiceConfig {
        ServiceConfig::builder(6, 500)
            .shards(2)
            .eta(0.0)
            .window(200)
            .build()
            .unwrap()
    }

    #[test]
    fn name_validation_guards_the_filesystem() {
        assert!(validate_name("news").is_ok());
        assert!(validate_name("turnstile-9").is_ok());
        assert!(validate_name("_x").is_ok());
        assert!(validate_name("").is_err());
        assert!(validate_name("default").is_err(), "reserved");
        assert!(validate_name("-leading-dash").is_err());
        assert!(validate_name("has/slash").is_err());
        assert!(validate_name("has space").is_err());
        assert!(validate_name("..").is_err());
        assert!(validate_name(&"x".repeat(65)).is_err());
    }

    #[test]
    fn create_list_drop_roundtrip() {
        let tenants = Tenants::open(base_cfg()).unwrap();
        assert_eq!(tenants.list().len(), 1, "default collection only");
        let info = tenants.create("news", &CollectionSpec::for_dim(4, 100)).unwrap();
        assert_eq!(info.id, 1);
        assert_eq!(info.dim, 4);
        let err = tenants
            .create("news", &CollectionSpec::for_dim(4, 100))
            .unwrap_err()
            .to_string();
        assert!(err.contains("already exists"), "{err}");
        let names: Vec<String> = tenants.list().into_iter().map(|c| c.name).collect();
        assert_eq!(names, vec!["default".to_string(), "news".to_string()]);
        // Per-tenant handles have the per-tenant dim.
        assert_eq!(tenants.resolve(0).unwrap().dim(), 6);
        assert_eq!(tenants.resolve(1).unwrap().dim(), 4);
        assert!(tenants.resolve(2).is_none());
        tenants.drop_collection("news").unwrap();
        assert!(tenants.resolve(1).is_none(), "dropped ids never resolve again");
        assert!(tenants.drop_collection("news").is_err());
        assert!(tenants.drop_collection("default").is_err());
        // Ids are never reused.
        let again = tenants.create("news", &CollectionSpec::for_dim(4, 100)).unwrap();
        assert_eq!(again.id, 2);
        tenants.shutdown();
    }

    #[test]
    fn invalid_specs_are_typed_errors_not_panics() {
        let tenants = Tenants::open(base_cfg()).unwrap();
        let mut spec = CollectionSpec::for_dim(4, 100);
        spec.shards = 0;
        assert!(tenants.create("bad", &spec).is_err());
        let mut spec = CollectionSpec::for_dim(0, 100);
        spec.dim = 0;
        assert!(tenants.create("bad", &spec).is_err());
        let mut spec = CollectionSpec::for_dim(4, 100);
        spec.eta = 1.5;
        assert!(tenants.create("bad", &spec).is_err());
        let mut spec = CollectionSpec::for_dim(4, 100);
        spec.overload = 9;
        assert!(tenants.create("bad", &spec).is_err());
        assert_eq!(tenants.list().len(), 1, "failed creates leave no tenant behind");
        tenants.shutdown();
    }

    #[test]
    fn named_collections_survive_reopen() {
        let root = std::env::temp_dir().join(format!(
            "sketchd-tenants-reopen-{}",
            std::process::id()
        ));
        std::fs::remove_dir_all(&root).ok();
        let mut base = base_cfg();
        base.data_dir = Some(root.clone());
        {
            let tenants = Tenants::open(base.clone()).unwrap();
            tenants.create("news", &CollectionSpec::for_dim(4, 100)).unwrap();
            let h = tenants.resolve(1).unwrap();
            assert!(h.insert(vec![0.5; 4]));
            h.flush().unwrap();
            tenants.shutdown();
        }
        {
            let tenants = Tenants::open(base).unwrap();
            let listed = tenants.list();
            assert_eq!(listed.len(), 2, "manifest rehydrates named tenants");
            assert_eq!(listed[1].name, "news");
            assert_eq!(listed[1].id, 1);
            let st = tenants.resolve(1).unwrap().stats().unwrap();
            assert_eq!(st.stored_points, 1, "eta=0 stores all; WAL replay recovered it");
            tenants.shutdown();
        }
        std::fs::remove_dir_all(&root).ok();
    }
}
