//! Insert routing: which shard owns an arriving stream element.
//!
//! Routing must be a *partition* (each point to exactly one shard) and
//! deterministic for the turnstile model — a deletion must route to the
//! shard that holds the point, so hashing the vector's bytes is the
//! default. Round-robin is available for pure insert-only workloads where
//! per-shard balance matters more than delete-addressability.
//!
//! In a multi-node deployment the same hash picks a *global* shard and
//! [`super::topology`] maps that shard to the owning node (rendezvous
//! hashing when nodes don't advertise contiguous ranges), so inserts and
//! deletes co-route across the router hop exactly as they do in-process.

/// Routing policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoutePolicy {
    /// FNV-1a over the vector bytes mod shards (turnstile-safe).
    HashVector,
    /// Strict round-robin (insert-only streams).
    RoundRobin,
}

/// The router state.
pub struct Router {
    policy: RoutePolicy,
    shards: usize,
    rr_next: usize,
}

impl Router {
    pub fn new(policy: RoutePolicy, shards: usize) -> Self {
        assert!(shards > 0);
        Router { policy, shards, rr_next: 0 }
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Shard for an arriving vector.
    pub fn route(&mut self, x: &[f32]) -> usize {
        match self.policy {
            RoutePolicy::HashVector => hash_vector(x) as usize % self.shards,
            RoutePolicy::RoundRobin => {
                let s = self.rr_next;
                self.rr_next = (self.rr_next + 1) % self.shards;
                s
            }
        }
    }

    /// Shard that holds `x` (deletes); only meaningful under HashVector.
    pub fn route_delete(&self, x: &[f32]) -> Option<usize> {
        match self.policy {
            RoutePolicy::HashVector => Some(hash_vector(x) as usize % self.shards),
            RoutePolicy::RoundRobin => None,
        }
    }
}

/// FNV-1a over the f32 bit patterns.
pub fn hash_vector(x: &[f32]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &v in x {
        for b in v.to_bits().to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hash_routing_is_deterministic() {
        let mut r = Router::new(RoutePolicy::HashVector, 4);
        let x = vec![1.0f32, 2.0, 3.0];
        let s = r.route(&x);
        for _ in 0..10 {
            assert_eq!(r.route(&x), s);
        }
        assert_eq!(r.route_delete(&x), Some(s), "delete must co-route");
    }

    #[test]
    fn round_robin_cycles() {
        let mut r = Router::new(RoutePolicy::RoundRobin, 3);
        let x = vec![0.0f32];
        assert_eq!(r.route(&x), 0);
        assert_eq!(r.route(&x), 1);
        assert_eq!(r.route(&x), 2);
        assert_eq!(r.route(&x), 0);
        assert_eq!(r.route_delete(&x), None);
    }

    #[test]
    fn hash_routing_is_balanced() {
        let mut r = Router::new(RoutePolicy::HashVector, 4);
        let mut rng = crate::util::rng::Rng::new(1);
        let mut counts = [0usize; 4];
        for _ in 0..4000 {
            let x: Vec<f32> = (0..8).map(|_| rng.gaussian_f32()).collect();
            counts[r.route(&x)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 1000.0).abs() < 150.0, "counts={counts:?}");
        }
    }

    #[test]
    fn routing_is_a_partition() {
        // The same vector can never land on two shards.
        let mut r = Router::new(RoutePolicy::HashVector, 7);
        let mut rng = crate::util::rng::Rng::new(2);
        for _ in 0..100 {
            let x: Vec<f32> = (0..4).map(|_| rng.gaussian_f32()).collect();
            let a = r.route(&x);
            let b = r.route(&x);
            assert_eq!(a, b);
            assert!(a < 7);
        }
    }
}
