//! Per-shard durability health: the state machine that replaces the old
//! silent `wal_failed` flag. A shard starts `Healthy`; the first WAL or
//! checkpoint failure moves it to `DurabilityDegraded` (still serving,
//! loudly undurable) or — under the `read_only` policy — straight to
//! `ReadOnly` (writes refused, reads keep serving). Health only ever
//! escalates; the way back to `Healthy` is a restart that recovers from
//! disk.
//!
//! The [`HealthBoard`] is the lock-free publication side: one atomic cell
//! per shard, written by the shard thread that owns the failure and read
//! by stats/Hello/checkpoint paths on other threads without a mailbox
//! round-trip. Replicas of one shard share the shard's cell — only the
//! primary owns the WAL, so only the primary publishes.

use crate::util::sync::atomic::{AtomicU64, AtomicU8, Ordering};

use anyhow::{bail, Result};

/// One shard's durability state, ordered by severity. The `u8` values
/// are the wire encoding (protocol v3 Stats carries one per shard).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum ShardHealth {
    /// WAL attached (or durability not configured) and appending cleanly.
    #[default]
    Healthy = 0,
    /// A WAL/checkpoint failure was observed: the shard still applies
    /// writes but they are NOT durable, and its snapshots are refused.
    DurabilityDegraded = 1,
    /// Writes are refused (dropped and counted); reads keep serving.
    ReadOnly = 2,
}

impl ShardHealth {
    pub fn from_u8(v: u8) -> ShardHealth {
        match v {
            2 => ShardHealth::ReadOnly,
            1 => ShardHealth::DurabilityDegraded,
            _ => ShardHealth::Healthy,
        }
    }

    pub fn as_u8(self) -> u8 {
        self as u8
    }
}

impl std::fmt::Display for ShardHealth {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ShardHealth::Healthy => write!(f, "healthy"),
            ShardHealth::DurabilityDegraded => write!(f, "durability-degraded"),
            ShardHealth::ReadOnly => write!(f, "read-only"),
        }
    }
}

/// What a shard does when its durability fails mid-stream
/// (`[service] on_durability_loss`, `--on-durability-loss`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum DurabilityLossPolicy {
    /// Keep serving reads AND writes, loudly undurable (the pre-health
    /// behavior, minus the silence).
    #[default]
    Degrade,
    /// Refuse further writes on the failed shard; reads keep serving.
    ReadOnly,
    /// Panic the shard thread: the operator asked for fail-stop.
    Abort,
}

impl DurabilityLossPolicy {
    pub fn parse(s: &str) -> Result<Self> {
        match s.trim() {
            "degrade" => Ok(DurabilityLossPolicy::Degrade),
            "read_only" | "read-only" => Ok(DurabilityLossPolicy::ReadOnly),
            "abort" => Ok(DurabilityLossPolicy::Abort),
            other => bail!("on_durability_loss must be degrade|read_only|abort, got {other:?}"),
        }
    }
}

impl std::fmt::Display for DurabilityLossPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DurabilityLossPolicy::Degrade => write!(f, "degrade"),
            DurabilityLossPolicy::ReadOnly => write!(f, "read_only"),
            DurabilityLossPolicy::Abort => write!(f, "abort"),
        }
    }
}

/// Lock-free per-shard health vector plus failure counters, shared as an
/// `Arc` between the shard primaries (writers) and every stats/serving
/// path (readers).
///
/// # Memory-ordering contract
///
/// The `cells` are the only atomics in this crate that gate *behavior*
/// on another thread (a `ReadOnly` cell makes `ReplicaSet::offer_write`
/// refuse the write), so they are the only ones that carry more than
/// `Relaxed`. The counters are pure stats.
#[derive(Debug)]
pub struct HealthBoard {
    /// One `ShardHealth as u8` per shard. Written by `escalate`
    /// (`AcqRel` `fetch_max`), read by `get`/`vector`/`worst`
    /// (`Acquire`). The Release half publishes everything the failing
    /// shard thread did *before* escalating — in particular the
    /// `Relaxed` `wal_errors` increment that `shard.rs` performs first
    /// in program order — to any thread whose Acquire load observes the
    /// new state; an admission door that sees `ReadOnly` therefore also
    /// sees a `wal_errors` count that explains it. The Acquire half of
    /// the RMW orders a later escalation after the state it is
    /// escalating from. Monotonicity itself needs no ordering — it is
    /// the `max` in `fetch_max`, which is atomic at any `Ordering`.
    cells: Vec<AtomicU8>,
    /// WAL/checkpoint failures since startup. `Relaxed`: a diagnostic
    /// counter that no control path branches on; cross-thread
    /// visibility piggybacks on the `cells` Release as described above,
    /// and exact reconciliation is only asserted at quiescence.
    wal_errors: AtomicU64,
    /// Points refused by `ReadOnly` shards. `Relaxed`: stat only,
    /// folded into `Stats` replies; reconciled against `shed`/`inserts`
    /// only after the writers are joined or the mailboxes drained.
    refused_writes: AtomicU64,
}

impl HealthBoard {
    pub fn new(shards: usize) -> HealthBoard {
        HealthBoard {
            cells: (0..shards.max(1)).map(|_| AtomicU8::new(0)).collect(),
            wal_errors: AtomicU64::new(0),
            refused_writes: AtomicU64::new(0),
        }
    }

    pub fn shards(&self) -> usize {
        self.cells.len()
    }

    pub fn get(&self, shard: usize) -> ShardHealth {
        ShardHealth::from_u8(self.cells[shard].load(Ordering::Acquire))
    }

    /// Move `shard` to `to` if that is strictly worse than its current
    /// state (health never improves in place). Returns true when the
    /// transition happened — callers log exactly on that edge.
    pub fn escalate(&self, shard: usize, to: ShardHealth) -> bool {
        self.cells[shard].fetch_max(to.as_u8(), Ordering::AcqRel) < to.as_u8()
    }

    /// Count one WAL/checkpoint durability failure.
    pub fn record_wal_error(&self) {
        self.wal_errors.fetch_add(1, Ordering::Relaxed);
    }

    pub fn wal_errors(&self) -> u64 {
        self.wal_errors.load(Ordering::Relaxed)
    }

    /// Count writes dropped by a `ReadOnly` shard (point-denominated).
    pub fn record_refused_writes(&self, points: u64) {
        self.refused_writes.fetch_add(points, Ordering::Relaxed);
    }

    pub fn refused_writes(&self) -> u64 {
        self.refused_writes.load(Ordering::Relaxed)
    }

    /// Wire-shaped snapshot: one `ShardHealth as u8` per shard.
    pub fn vector(&self) -> Vec<u8> {
        self.cells.iter().map(|c| c.load(Ordering::Acquire)).collect()
    }

    /// Worst health across all shards (what `Hello` summarizes).
    pub fn worst(&self) -> ShardHealth {
        self.cells
            .iter()
            .map(|c| ShardHealth::from_u8(c.load(Ordering::Acquire)))
            .max()
            .unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn health_orders_by_severity_and_roundtrips() {
        assert!(ShardHealth::Healthy < ShardHealth::DurabilityDegraded);
        assert!(ShardHealth::DurabilityDegraded < ShardHealth::ReadOnly);
        for h in [
            ShardHealth::Healthy,
            ShardHealth::DurabilityDegraded,
            ShardHealth::ReadOnly,
        ] {
            assert_eq!(ShardHealth::from_u8(h.as_u8()), h);
        }
        assert_eq!(ShardHealth::from_u8(250), ShardHealth::Healthy, "unknown maps to default");
    }

    #[test]
    fn board_escalates_monotonically() {
        let b = HealthBoard::new(3);
        assert_eq!(b.worst(), ShardHealth::Healthy);
        assert!(b.escalate(1, ShardHealth::DurabilityDegraded), "first transition fires");
        assert!(
            !b.escalate(1, ShardHealth::DurabilityDegraded),
            "repeat is not a transition (log-once)"
        );
        assert!(b.escalate(1, ShardHealth::ReadOnly));
        assert!(!b.escalate(1, ShardHealth::DurabilityDegraded), "never downgrades");
        assert_eq!(b.get(1), ShardHealth::ReadOnly);
        assert_eq!(b.vector(), vec![0, 2, 0]);
        assert_eq!(b.worst(), ShardHealth::ReadOnly);
    }

    #[test]
    fn policy_parses_and_displays() {
        assert_eq!(
            DurabilityLossPolicy::parse("degrade").unwrap(),
            DurabilityLossPolicy::Degrade
        );
        assert_eq!(
            DurabilityLossPolicy::parse("read_only").unwrap(),
            DurabilityLossPolicy::ReadOnly
        );
        assert_eq!(
            DurabilityLossPolicy::parse("read-only").unwrap(),
            DurabilityLossPolicy::ReadOnly
        );
        assert_eq!(DurabilityLossPolicy::parse("abort").unwrap(), DurabilityLossPolicy::Abort);
        assert!(DurabilityLossPolicy::parse("banana").is_err());
        assert_eq!(DurabilityLossPolicy::ReadOnly.to_string(), "read_only");
    }

    #[test]
    fn counters_accumulate() {
        let b = HealthBoard::new(1);
        b.record_wal_error();
        b.record_wal_error();
        b.record_refused_writes(64);
        assert_eq!(b.wal_errors(), 2);
        assert_eq!(b.refused_writes(), 64);
    }

    /// Every (from, to) pair of the state machine: `escalate` reports a
    /// transition exactly when `to` is strictly worse, and the resident
    /// state afterwards is `max(from, to)` — never a downgrade.
    #[test]
    fn every_transition_edge() {
        use ShardHealth::{DurabilityDegraded, Healthy, ReadOnly};
        let all = [Healthy, DurabilityDegraded, ReadOnly];
        for &from in &all {
            for &to in &all {
                let b = HealthBoard::new(1);
                if from > Healthy {
                    assert!(b.escalate(0, from), "seeding {from} from fresh must fire");
                }
                let fired = b.escalate(0, to);
                assert_eq!(fired, to > from, "edge {from} -> {to}");
                assert_eq!(b.get(0), from.max(to), "state after {from} -> {to}");
            }
        }
    }

    /// The wire byte for each state is its severity rank — the protocol
    /// relies on `max` over raw bytes agreeing with `max` over states.
    #[test]
    fn wire_bytes_are_severity_ranks() {
        assert_eq!(ShardHealth::Healthy.as_u8(), 0);
        assert_eq!(ShardHealth::DurabilityDegraded.as_u8(), 1);
        assert_eq!(ShardHealth::ReadOnly.as_u8(), 2);
        // from_u8 is total: every byte maps to some state, unknowns to
        // Healthy (a newer peer's state must not wedge an older reader).
        for v in 0u8..=255 {
            let _ = ShardHealth::from_u8(v);
        }
        assert_eq!(ShardHealth::from_u8(3), ShardHealth::Healthy);
    }

    /// `worst()` is the max cell under every mixed vector, and agrees
    /// with the byte-wise max of `vector()` (the encoding Hello ships).
    #[test]
    fn worst_shard_tracks_the_max_cell() {
        let b = HealthBoard::new(4);
        assert_eq!(b.worst(), ShardHealth::Healthy, "all-healthy board");
        b.escalate(2, ShardHealth::DurabilityDegraded);
        assert_eq!(b.worst(), ShardHealth::DurabilityDegraded);
        b.escalate(0, ShardHealth::ReadOnly);
        assert_eq!(b.worst(), ShardHealth::ReadOnly);
        b.escalate(3, ShardHealth::DurabilityDegraded);
        let v = b.vector();
        assert_eq!(v, vec![2, 0, 1, 1]);
        assert_eq!(
            v.iter().copied().max().map(ShardHealth::from_u8),
            Some(b.worst()),
            "byte-wise max IS the worst state"
        );
    }

    /// A zero-shard board clamps to one cell (the constructor's
    /// `.max(1)`) so `worst()` stays total.
    #[test]
    fn empty_board_still_answers() {
        let b = HealthBoard::new(0);
        assert_eq!(b.shards(), 1);
        assert_eq!(b.worst(), ShardHealth::Healthy);
        assert_eq!(b.vector(), vec![0]);
    }
}
