//! Shard-level read replicas: `ReplicaSet` fronts `R ≥ 1` copies of one
//! shard's state (each a full [`Shard`] on its own thread) so read-heavy
//! workloads scale past the single-copy shard-thread ceiling — the
//! natural next lever after the calling-thread `QueryPlane`, because the
//! paper's sketches are cheap enough to duplicate (sublinear memory) and
//! reads dominate the serving mix.
//!
//! Contract: every replica of a shard holds **bit-identical** state.
//! Replicas are constructed with the same seed (the S-ANN sampler Rng and
//! the SW-AKDE window clock are functions of the mutation *sequence*
//! alone), so identity holds as long as every replica's mailbox receives
//! the same write commands in the same order. `offer_write`/`delete`
//! therefore serialize their fan-out through a per-shard order lock when
//! `R > 1`: without it, two connection threads could interleave
//! differently across the mailboxes and the copies would drift apart
//! permanently. With `R = 1` the lock is skipped — a single mailbox
//! already linearizes — so the replica layer costs nothing on the
//! un-replicated path.
//!
//! Overload is decided ONCE per shard: the primary's mailbox runs the
//! configured policy, and only if the primary accepts do the secondaries
//! receive the point (`force`d — they can never shed what the primary
//! kept, which would desynchronize the copies). Deliberate trade-off
//! under `Overload::Shed` with `R > 1`: a secondary whose mailbox is
//! momentarily full (e.g. it is mid-way through a long read batch)
//! back-pressures the writer until it drains — replication bounds
//! DIVERGENCE at the cost of the pure non-blocking shed guarantee,
//! which only the primary's queue still provides. The stall is bounded
//! by the secondary's drain rate, and the least-loaded picker stops
//! routing new reads at a backed-up copy, which is what lets it drain.
//!
//! Reads go to the least-loaded replica: the picker scans in-flight read
//! depth per replica (a gauge held while a scatter's reply is pending)
//! and breaks ties round-robin, so a replica stuck on a slow query stops
//! receiving new ones until it drains.
//!
//! Durability stays per-SHARD, not per-replica: the primary alone logs
//! to the WAL and serializes checkpoints; recovery rehydrates all `R`
//! copies from that one image + log (see `SketchService::start`).
//!
//! [`Shard`]: super::shard::Shard

use crate::util::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use crate::util::sync::mpsc::channel;
use crate::util::sync::{lock_unpoisoned, read_unpoisoned, write_unpoisoned, Arc, Mutex, RwLock};

use super::backpressure::{BoundedSender, OfferOutcome};
use super::health::{HealthBoard, ShardHealth};
use super::shard::ShardCmd;

/// Decrements its replica's in-flight read gauge on drop. Hold it until
/// the read's reply has been received (or abandoned).
///
/// `Relaxed` on the decrement (and on every other `depth` operation):
/// the gauge is a load-balancing heuristic the picker scans, never a
/// capability — a momentarily stale depth routes a read suboptimally,
/// nothing more. The never-negative/paired-release invariants are
/// structural (acquire+release live in one function, release in `Drop`)
/// and are model-checked in `tests/loom_models.rs`, not enforced by
/// ordering.
pub struct ReadGuard {
    depth: Arc<AtomicUsize>,
}

impl Drop for ReadGuard {
    fn drop(&mut self) {
        self.depth.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Cloneable front over one shard's replica mailboxes.
///
/// Each mailbox sits in a shared swappable slot: the supervisor heals a
/// dead replica by installing a fresh sender into the SAME slot every
/// clone of this set reads through (`Arc<RwLock<_>>`), so query planes
/// and handles cloned before the crash route to the healed copy without
/// being rebuilt.
pub struct ReplicaSet {
    slots: Vec<Arc<RwLock<BoundedSender<ShardCmd>>>>,
    /// In-flight reads per replica (gauge; see [`ReadGuard`] for why
    /// `Relaxed` suffices on every operation).
    depth: Vec<Arc<AtomicUsize>>,
    /// Cumulative reads routed per replica (diagnostics + picker tests).
    /// `Relaxed`: a stat no control path branches on; tests assert it
    /// only after joining the reader threads.
    reads: Vec<Arc<AtomicU64>>,
    /// Round-robin cursor for tie-breaks, shared across clones.
    /// `Relaxed`: only the `fetch_add`'s atomicity matters (distinct
    /// starting offsets) — any interleaving of cursor values is a valid
    /// rotation.
    rr: Arc<AtomicUsize>,
    /// Serializes write fan-out so every replica applies the same order.
    write_order: Arc<Mutex<()>>,
    /// `(shard index, shared board)`: writes are refused at THIS
    /// admission point — not inside the shard threads — when the shard
    /// is `ReadOnly`, so all R copies see identical command streams and
    /// stay bit-identical even while refusing.
    health: Option<(usize, Arc<HealthBoard>)>,
}

impl Clone for ReplicaSet {
    fn clone(&self) -> Self {
        ReplicaSet {
            slots: self.slots.clone(),
            depth: self.depth.iter().map(Arc::clone).collect(),
            reads: self.reads.iter().map(Arc::clone).collect(),
            rr: Arc::clone(&self.rr),
            write_order: Arc::clone(&self.write_order),
            health: self.health.clone(),
        }
    }
}

impl ReplicaSet {
    /// Wrap one shard's replica mailboxes; `txs[0]` is the primary (WAL
    /// owner, snapshot/stats source).
    pub fn new(txs: Vec<BoundedSender<ShardCmd>>) -> Self {
        assert!(!txs.is_empty(), "a shard needs at least one replica");
        let n = txs.len();
        ReplicaSet {
            slots: txs.into_iter().map(|tx| Arc::new(RwLock::new(tx))).collect(),
            depth: (0..n).map(|_| Arc::new(AtomicUsize::new(0))).collect(),
            reads: (0..n).map(|_| Arc::new(AtomicU64::new(0))).collect(),
            rr: Arc::new(AtomicUsize::new(0)),
            write_order: Arc::new(Mutex::new(())),
            health: None,
        }
    }

    /// Wire this set to the service's shared health board (startup only,
    /// before the set is cloned into planes/handles): `shard` is this
    /// set's index into the board.
    pub fn set_health(&mut self, shard: usize, board: Arc<HealthBoard>) {
        self.health = Some((shard, board));
    }

    /// True when the shard is refusing writes (`ReadOnly` health).
    fn read_only(&self) -> bool {
        self.health
            .as_ref()
            .is_some_and(|(s, b)| b.get(*s) == ShardHealth::ReadOnly)
    }

    /// Number of replicas (R) in this set.
    pub fn replicas(&self) -> usize {
        self.slots.len()
    }

    /// The primary replica's mailbox: control ops that must run exactly
    /// once per shard (stats, WAL sync ordering, snapshots) target this.
    /// Cloned out of its slot so the caller never holds the slot lock
    /// across a blocking send.
    pub fn primary(&self) -> BoundedSender<ShardCmd> {
        read_unpoisoned(&self.slots[0]).clone()
    }

    /// Every replica's mailbox (barriers and shutdown fan out to all),
    /// cloned out of their slots.
    pub fn txs(&self) -> Vec<BoundedSender<ShardCmd>> {
        self.slots.iter().map(|s| read_unpoisoned(s).clone()).collect()
    }

    /// Swap replica `r`'s mailbox for a freshly healed copy's and reset
    /// its read gauge (in-flight reads against the dead copy already
    /// released their guards when their `force` failed). Every clone of
    /// this set routes through the shared slot, so the healed replica
    /// serves planes and handles built before the crash.
    pub fn install(&self, r: usize, tx: BoundedSender<ShardCmd>) {
        *write_unpoisoned(&self.slots[r]) = tx;
        self.depth[r].store(0, Ordering::Relaxed);
    }

    /// Run `f` with write fan-out blocked. Replica healing wraps its
    /// whole clone-cut → rehydrate → [`Self::install`] sequence in this,
    /// so no write can land between the image and the installed mailbox
    /// — the one interleaving that would diverge the healed copy.
    pub fn with_writes_blocked<T>(&self, f: impl FnOnce() -> T) -> T {
        let _order = lock_unpoisoned(&self.write_order);
        f()
    }

    /// Fault-injection hook: deliver the injected-crash command straight
    /// into replica `r`'s mailbox (forced past the overload policy), as
    /// if its thread had died in the field. Returns false if the mailbox
    /// is already closed. Test-only by construction — the command it
    /// ships exists only under this feature.
    #[cfg(feature = "fault-injection")]
    pub fn crash_replica(&self, r: usize) -> bool {
        read_unpoisoned(&self.slots[r]).force(ShardCmd::Crash)
    }

    /// Current in-flight read depth per replica.
    pub fn depths(&self) -> Vec<usize> {
        self.depth.iter().map(|d| d.load(Ordering::Relaxed)).collect()
    }

    /// Cumulative reads routed per replica.
    pub fn reads_served(&self) -> Vec<u64> {
        self.reads.iter().map(|r| r.load(Ordering::Relaxed)).collect()
    }

    /// Least-loaded replica, ties broken round-robin: the scan starts at
    /// the rotating cursor and takes a strictly smaller depth to move,
    /// so equal-depth replicas share reads evenly and a backed-up one is
    /// skipped entirely.
    fn pick(&self) -> usize {
        let n = self.slots.len();
        if n == 1 {
            return 0;
        }
        let start = self.rr.fetch_add(1, Ordering::Relaxed);
        let mut best = start % n;
        let mut best_depth = self.depth[best].load(Ordering::Relaxed);
        for k in 1..n {
            let i = (start + k) % n;
            let d = self.depth[i].load(Ordering::Relaxed);
            if d < best_depth {
                best = i;
                best_depth = d;
            }
        }
        best
    }

    /// Route one read command (it carries its own reply channel) to the
    /// least-loaded replica; a dead replica (crashed, awaiting heal)
    /// fails over to the next live copy, so reads keep serving through
    /// the detection-to-heal window. Returns `None` only when EVERY
    /// replica's mailbox is closed — the caller treats the shard as
    /// down. Hold the guard until the reply arrives: it is the load
    /// signal the picker steers by.
    pub fn read(&self, cmd: ShardCmd) -> Option<ReadGuard> {
        let n = self.slots.len();
        let first = self.pick();
        let mut cmd = cmd;
        for k in 0..n {
            let i = (first + k) % n;
            let depth = Arc::clone(&self.depth[i]);
            depth.fetch_add(1, Ordering::Relaxed);
            let sent = read_unpoisoned(&self.slots[i]).force_or_return(cmd);
            match sent {
                Ok(()) => {
                    self.reads[i].fetch_add(1, Ordering::Relaxed);
                    return Some(ReadGuard { depth });
                }
                Err(back) => {
                    depth.fetch_sub(1, Ordering::Relaxed);
                    cmd = back;
                }
            }
        }
        None
    }

    /// Offer one write under the shard's overload policy, fanned out to
    /// every replica. The primary decides the point's fate exactly once;
    /// secondaries then receive the same data unconditionally (forced —
    /// blocking while a copy's queue is full, see the module docs for
    /// this trade-off and for why the fan-out is serialized), so the
    /// copies cannot diverge by shedding differently.
    pub fn offer_write(&self, cmd: ShardCmd) -> OfferOutcome {
        if self.read_only() {
            // Refused at the admission door, BEFORE any mailbox: every
            // replica sees the identical (truncated) command stream, so
            // the copies stay bit-identical while the shard refuses.
            // Reported as `Shed` so point accounting keeps reconciling
            // (inserts == stored + shed); the refused breakdown is on
            // the board.
            if let Some((_, b)) = &self.health {
                b.record_refused_writes(cmd.write_points());
            }
            return OfferOutcome::Shed;
        }
        if self.slots.len() == 1 {
            let primary = self.primary();
            return primary.offer_outcome(cmd);
        }
        let _order = lock_unpoisoned(&self.write_order);
        let copies: Vec<ShardCmd> = (1..self.slots.len())
            .map(|_| {
                cmd.clone_write()
                    .expect("replica fan-out requires a data-only write command")
            })
            .collect();
        match self.primary().offer_outcome(cmd) {
            OfferOutcome::Sent => {
                for (slot, c) in self.slots[1..].iter().zip(copies) {
                    // A dead secondary (crashed, awaiting heal or
                    // mid-shutdown) simply misses the write: the healer
                    // rebuilds it from the primary's live state, which
                    // includes this command.
                    let _ = read_unpoisoned(slot).force(c);
                }
                OfferOutcome::Sent
            }
            other => other,
        }
    }

    /// Turnstile delete, applied on every replica (a delete is a write:
    /// all copies must drop the point). The PRIMARY's acknowledgement is
    /// authoritative — it applies (and, on durable services, WAL-logs)
    /// the delete, so once it has acked, the delete HAPPENED and must be
    /// reported/counted; `None` means the primary never acknowledged and
    /// nothing durable can have been recorded. Secondary acks are still
    /// awaited so a returned delete is visible from every live copy, but
    /// a dead secondary (shutdown race — reads against it already error)
    /// cannot retract an applied delete.
    ///
    /// A `ReadOnly` shard refuses the delete (a delete is a write):
    /// `None`, counted on the board — nothing was applied or logged.
    pub fn delete(&self, x: Vec<f32>) -> Option<bool> {
        if self.read_only() {
            if let Some((_, b)) = &self.health {
                b.record_refused_writes(1);
            }
            return None;
        }
        let order = (self.slots.len() > 1).then(|| lock_unpoisoned(&self.write_order));
        let (ptx, prx) = channel();
        if !self.primary().force(ShardCmd::Delete(x.clone(), ptx)) {
            return None;
        }
        let mut secondary_acks = Vec::with_capacity(self.slots.len().saturating_sub(1));
        for slot in &self.slots[1..] {
            let (rtx, rrx) = channel();
            if read_unpoisoned(slot).force(ShardCmd::Delete(x.clone(), rtx)) {
                secondary_acks.push(rrx);
            }
        }
        // Enqueue order is fixed once every mailbox holds the command;
        // the acks can be awaited without stalling other writers.
        drop(order);
        let removed = prx.recv().ok()?;
        for rrx in secondary_acks {
            let _ = rrx.recv();
        }
        Some(removed)
    }
}

#[cfg(test)]
mod tests {
    use super::super::backpressure::{bounded, Overload};
    use super::super::protocol::ShardAnnResult;
    use super::*;
    use crate::util::sync::mpsc::Receiver;
    use crate::util::sync::Arc;

    fn set_of(caps: &[(usize, Overload)]) -> (ReplicaSet, Vec<Receiver<ShardCmd>>) {
        let (txs, rxs): (Vec<_>, Vec<_>) =
            caps.iter().map(|&(cap, pol)| bounded(cap, pol)).unzip();
        (ReplicaSet::new(txs), rxs)
    }

    fn ann_read(set: &ReplicaSet) -> Option<ReadGuard> {
        let (tx, _rx) = crate::util::sync::mpsc::channel();
        set.read(ShardCmd::AnnBatch(Arc::new(Vec::new()), tx))
    }

    #[test]
    fn equal_depth_reads_round_robin() {
        let (set, rxs) = set_of(&[(16, Overload::Block), (16, Overload::Block)]);
        for _ in 0..4 {
            drop(ann_read(&set).unwrap()); // completes immediately
        }
        assert_eq!(set.reads_served(), vec![2, 2], "ties rotate");
        drop(rxs);
    }

    #[test]
    fn picker_avoids_replica_with_reads_in_flight() {
        let (set, rxs) = set_of(&[(16, Overload::Block), (16, Overload::Block)]);
        // A slow replica: its first read never completes (guard held).
        let slow = ann_read(&set).unwrap();
        assert_eq!(set.depths(), vec![1, 0]);
        for _ in 0..3 {
            drop(ann_read(&set).unwrap());
        }
        assert_eq!(
            set.reads_served(),
            vec![1, 3],
            "all subsequent reads dodge the stuck replica"
        );
        drop(slow);
        assert_eq!(set.depths(), vec![0, 0], "guard releases the gauge");
        drop(rxs);
    }

    #[test]
    fn dead_replica_read_reports_none_and_releases_gauge() {
        let (tx, rx) = bounded::<ShardCmd>(4, Overload::Block);
        drop(rx);
        let set = ReplicaSet::new(vec![tx]);
        assert!(ann_read(&set).is_none());
        assert_eq!(set.depths(), vec![0]);
    }

    #[test]
    fn reads_fail_over_past_a_dead_replica() {
        // Replica 1's thread is gone; every read must land on the live
        // copy instead of erroring for the callers the picker routed at
        // the corpse.
        let (tx0, rx0) = bounded::<ShardCmd>(16, Overload::Block);
        let (tx1, rx1) = bounded::<ShardCmd>(16, Overload::Block);
        drop(rx1);
        let set = ReplicaSet::new(vec![tx0, tx1]);
        for _ in 0..4 {
            drop(ann_read(&set).expect("a live replica must answer"));
        }
        assert_eq!(set.reads_served(), vec![4, 0], "all reads failed over");
        assert_eq!(set.depths(), vec![0, 0], "failed attempts release gauges");
        drop(rx0);
    }

    #[test]
    fn install_swaps_the_slot_for_every_clone() {
        let (tx0, rx0) = bounded::<ShardCmd>(16, Overload::Block);
        let (tx1, rx1) = bounded::<ShardCmd>(16, Overload::Block);
        drop(rx1); // replica 1 "crashed"
        let set = ReplicaSet::new(vec![tx0, tx1]);
        let clone_made_before_heal = set.clone();
        let (fresh_tx, fresh_rx) = bounded::<ShardCmd>(16, Overload::Block);
        set.install(1, fresh_tx);
        // Writes fan out to the healed mailbox through the OLD clone.
        assert_eq!(
            clone_made_before_heal.offer_write(ShardCmd::Insert(vec![1.0])),
            OfferOutcome::Sent
        );
        match fresh_rx.try_recv().unwrap() {
            ShardCmd::Insert(x) => assert_eq!(x, vec![1.0]),
            other => panic!("expected Insert, got {}", cmd_name(&other)),
        }
        drop(rx0);
    }

    #[test]
    fn read_only_board_refuses_writes_and_deletes() {
        use super::super::health::{HealthBoard, ShardHealth};
        let (mut set, rxs) = set_of(&[(16, Overload::Block), (16, Overload::Block)]);
        let board = Arc::new(HealthBoard::new(1));
        set.set_health(0, Arc::clone(&board));
        assert_eq!(
            set.offer_write(ShardCmd::Insert(vec![1.0])),
            OfferOutcome::Sent,
            "healthy shard accepts"
        );
        board.escalate(0, ShardHealth::ReadOnly);
        assert_eq!(
            set.offer_write(ShardCmd::InsertBatch(vec![vec![2.0], vec![3.0]])),
            OfferOutcome::Shed,
            "read-only shard refuses at the door"
        );
        assert_eq!(set.delete(vec![1.0]), None, "a delete is a write");
        assert_eq!(board.refused_writes(), 3, "2 batch points + 1 delete");
        // Reads are untouched; neither mailbox saw the refused commands.
        let drained: Vec<usize> = rxs
            .iter()
            .map(|rx| std::iter::from_fn(|| rx.try_recv().ok()).count())
            .collect();
        assert_eq!(drained, vec![1, 1], "only the healthy-era insert landed");
    }

    #[test]
    fn writes_fan_out_to_every_replica() {
        let (set, rxs) = set_of(&[(16, Overload::Block), (16, Overload::Block)]);
        assert_eq!(
            set.offer_write(ShardCmd::Insert(vec![1.0, 2.0])),
            OfferOutcome::Sent
        );
        assert_eq!(
            set.offer_write(ShardCmd::InsertBatch(vec![vec![3.0], vec![4.0]])),
            OfferOutcome::Sent
        );
        for rx in &rxs {
            match rx.try_recv().unwrap() {
                ShardCmd::Insert(x) => assert_eq!(x, vec![1.0, 2.0]),
                other => panic!("expected Insert, got {}", cmd_name(&other)),
            }
            match rx.try_recv().unwrap() {
                ShardCmd::InsertBatch(b) => assert_eq!(b, vec![vec![3.0], vec![4.0]]),
                other => panic!("expected InsertBatch, got {}", cmd_name(&other)),
            }
        }
    }

    fn cmd_name(cmd: &ShardCmd) -> &'static str {
        match cmd {
            ShardCmd::Insert(_) => "Insert",
            ShardCmd::InsertBatch(_) => "InsertBatch",
            ShardCmd::InsertWithSlots(..) => "InsertWithSlots",
            ShardCmd::InsertBatchSlots(_) => "InsertBatchSlots",
            ShardCmd::Delete(..) => "Delete",
            ShardCmd::AnnBatch(..) => "AnnBatch",
            ShardCmd::AnnCandidates(..) => "AnnCandidates",
            ShardCmd::AnnCandidatesKeys(..) => "AnnCandidatesKeys",
            ShardCmd::KdeBatch(..) => "KdeBatch",
            ShardCmd::Stats(_) => "Stats",
            ShardCmd::SyncWal(_) => "SyncWal",
            ShardCmd::Snapshot(_) => "Snapshot",
            ShardCmd::CloneState(_) => "CloneState",
            ShardCmd::Crash => "Crash",
            ShardCmd::Shutdown => "Shutdown",
        }
    }

    #[test]
    fn shed_is_decided_once_by_the_primary() {
        // Primary queue holds 1 command; the second offer sheds — and the
        // secondary must NOT receive the shed point (copies stay equal).
        let (set, rxs) = set_of(&[(1, Overload::Shed), (16, Overload::Shed)]);
        assert_eq!(set.offer_write(ShardCmd::Insert(vec![1.0])), OfferOutcome::Sent);
        assert_eq!(set.offer_write(ShardCmd::Insert(vec![2.0])), OfferOutcome::Shed);
        let drained: Vec<usize> = rxs
            .iter()
            .map(|rx| std::iter::from_fn(|| rx.try_recv().ok()).count())
            .collect();
        assert_eq!(drained, vec![1, 1], "both replicas saw exactly the kept point");
    }

    #[test]
    fn delete_waits_for_all_replicas() {
        let (set, rxs) = set_of(&[(16, Overload::Block), (16, Overload::Block)]);
        let ackers: Vec<_> = rxs
            .into_iter()
            .map(|rx| {
                std::thread::spawn(move || {
                    match rx.recv().unwrap() {
                        ShardCmd::Delete(x, reply) => {
                            assert_eq!(x, vec![7.0]);
                            reply.send(true).unwrap();
                        }
                        _ => panic!("expected Delete"),
                    }
                })
            })
            .collect();
        assert_eq!(set.delete(vec![7.0]), Some(true));
        for a in ackers {
            a.join().unwrap();
        }
    }

    #[test]
    fn delete_ack_follows_the_primary() {
        // Dead PRIMARY: nothing was applied or logged — unacknowledged.
        let (tx0, rx0) = bounded::<ShardCmd>(16, Overload::Block);
        drop(rx0);
        let (tx1, _rx1) = bounded::<ShardCmd>(16, Overload::Block);
        let set = ReplicaSet::new(vec![tx0, tx1]);
        assert_eq!(set.delete(vec![1.0]), None, "no primary ack, no delete");

        // Dead SECONDARY: the primary applied (and would have WAL-logged)
        // the delete, so it HAPPENED — a shutdown-racing copy must not
        // retract it into a miscount.
        let (tx0, rx0) = bounded::<ShardCmd>(16, Overload::Block);
        let (tx1, rx1) = bounded::<ShardCmd>(16, Overload::Block);
        drop(rx1);
        let primary = std::thread::spawn(move || match rx0.recv().unwrap() {
            ShardCmd::Delete(_, reply) => reply.send(true).unwrap(),
            _ => panic!("expected Delete"),
        });
        let set = ReplicaSet::new(vec![tx0, tx1]);
        assert_eq!(set.delete(vec![1.0]), Some(true), "primary ack is authoritative");
        primary.join().unwrap();
    }

    #[test]
    fn fake_shard_read_roundtrip() {
        let (tx, rx) = bounded::<ShardCmd>(16, Overload::Block);
        let join = std::thread::spawn(move || {
            while let Ok(cmd) = rx.recv() {
                match cmd {
                    ShardCmd::AnnBatch(batch, reply) => {
                        let _ = reply.send(ShardAnnResult {
                            best: vec![None; batch.len()],
                            scanned: 0,
                        });
                    }
                    ShardCmd::Shutdown => break,
                    _ => {}
                }
            }
        });
        let set = ReplicaSet::new(vec![tx]);
        let (rtx, rrx) = crate::util::sync::mpsc::channel();
        let guard = set
            .read(ShardCmd::AnnBatch(Arc::new(vec![vec![0.0; 4]]), rtx))
            .unwrap();
        assert_eq!(set.depths(), vec![1]);
        let ans = rrx.recv().unwrap();
        drop(guard);
        assert_eq!(ans.best.len(), 1);
        assert_eq!(set.depths(), vec![0]);
        assert!(set.primary().force(ShardCmd::Shutdown));
        join.join().unwrap();
    }
}
