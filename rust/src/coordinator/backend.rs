//! `ShardBackend` — the topology seam of the read/write core.
//!
//! The scatter/gather/merge glue in [`super::query::QueryPlane`] and the
//! ingest fan-out in [`super::handle::ServiceHandle`] used to be welded
//! to in-process mailboxes (`ReplicaSet::read(ShardCmd::AnnBatch…)`), so
//! nothing built on them could cross a process boundary. This trait is
//! the cut: a backend owns some contiguous range of the global shard
//! space and knows how to scatter a batch into it, collect typed
//! partials back out, accept ingest, and report health — and NOTHING
//! above it sees a mailbox or a socket.
//!
//! Two implementations:
//!
//! - [`LocalBackend`]: one shard's [`ReplicaSet`] mailboxes, exactly the
//!   in-process path the plane ran before the trait existed. One global
//!   shard per backend, replies collected off the shard's reply channel.
//! - [`RemoteBackend`]: a pooled [`SketchClient`] to another `sketchd`
//!   process. One backend covers ALL of that node's shards; queries go
//!   out as protocol-v5 `AnnPartial`/`KdePartial` ops and come back as
//!   RAW per-shard partials (never node-side merges — f64 kernel sums
//!   are not associative, so pre-merging would break the bit-parity
//!   guarantee between a routed deployment and a single process).
//!
//! The degradation contract crosses the seam intact: a backend that
//! cannot be scattered to returns `None`, a backend that dies mid-query
//! surfaces an `Err` from [`Pending::collect`], and in both cases the
//! error NAMES the backend (`shard 3` / `node 10.0.0.2:4444`) so a
//! partial merge is never silently returned.

use crate::net::client::{ClientOptions, SketchClient};
use crate::obs::log;
use crate::util::sync::mpsc::{channel, Receiver, Sender};
use crate::util::sync::{lock_unpoisoned, Arc, Mutex};

use super::backpressure::OfferOutcome;
use super::health::HealthBoard;
use super::protocol::{QueryBatch, ServiceStats, ShardAnnResult, ShardKdeResult};
use super::replica::{ReadGuard, ReplicaSet};
use super::shard::ShardCmd;
use super::tenants::{CollectionInfo, CollectionSpec};

/// Fate of one offered ingest chunk, point-denominated. Unlike the
/// mailbox-level [`OfferOutcome`] this can report a PARTIAL accept: a
/// remote node applies its own overload policy per point, so a chunk of
/// 64 may come back 60 accepted / 4 shed. `Disconnected` means the
/// points never entered any service — callers roll back their
/// provisional insert count, exactly like a closed local mailbox.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IngestOutcome {
    Accepted { accepted: usize, shed: usize },
    Disconnected,
}

/// An in-flight scatter to one backend. Local replies keep the picked
/// replica's read-depth guard raised until collected; remote replies are
/// a worker-pool future. Either way [`Pending::collect`] yields the
/// backend's partials IN GLOBAL SHARD ORDER (a local backend is one
/// shard; a remote node returns its shards in its own flat order).
pub enum Pending<T> {
    Local { rx: Receiver<T>, guard: ReadGuard },
    Remote { rx: Receiver<Result<Vec<T>, String>> },
}

impl<T> Pending<T> {
    /// Block for the backend's partials. `name` is the backend's
    /// [`ShardBackend::name`], used verbatim in death errors so the
    /// caller's degradation message names who died.
    pub fn collect(self, name: &str) -> Result<Vec<T>, String> {
        match self {
            Pending::Local { rx, guard } => match rx.recv() {
                Ok(part) => {
                    drop(guard);
                    Ok(vec![part])
                }
                Err(_) => Err(format!("{name} died mid-query")),
            },
            Pending::Remote { rx } => match rx.recv() {
                Ok(res) => res,
                Err(_) => Err(format!("{name} died mid-query")),
            },
        }
    }
}

/// One topology-aware member of the query/ingest fan-out. Everything
/// above this trait (plane, handle, merge) is topology-blind.
///
/// Every data-plane method carries the COLLECTION id first (protocol
/// v6): a [`LocalBackend`] ignores it — its shard mailboxes belong to
/// exactly one collection's service, resolved before the call — while a
/// [`RemoteBackend`] forwards it over the wire, so a routed front-end
/// addresses the right tenant on every member node. Collection 0 is the
/// default collection (the only one v5 frames can name).
pub trait ShardBackend: Send + Sync {
    /// Human name used in degradation errors: `"shard 2"` for a local
    /// backend, `"node HOST:PORT"` for a remote one.
    fn name(&self) -> String;
    /// Global shards this backend serves (1 for local, N for a node).
    fn shards(&self) -> usize;
    /// Read replicas behind this backend.
    fn replicas(&self) -> usize;
    /// Health of each served shard (`ShardHealth as u8`), length
    /// [`Self::shards`].
    fn health(&self) -> Vec<u8>;
    /// Scatter an ANN batch into collection `coll`; `None` iff the
    /// backend is unreachable (dead mailboxes / worker pool gone).
    fn scatter_ann(
        &self,
        coll: u32,
        batch: &QueryBatch,
        trace: u64,
    ) -> Option<Pending<ShardAnnResult>>;
    /// Scatter a KDE batch; same contract as [`Self::scatter_ann`].
    fn scatter_kde(
        &self,
        coll: u32,
        batch: &QueryBatch,
        trace: u64,
    ) -> Option<Pending<ShardKdeResult>>;
    /// Offer one pre-routed ingest chunk (every point in it belongs to
    /// this backend) to collection `coll`. Blocking, point-denominated
    /// accounting.
    fn offer(&self, coll: u32, chunk: Vec<Vec<f32>>) -> IngestOutcome;
    /// Turnstile delete of one pre-routed point from collection `coll`.
    /// `None` = unreachable, `Some(removed)` = acknowledged.
    fn delete(&self, coll: u32, x: Vec<f32>) -> Option<bool>;
}

/// One in-process shard (its replica set), behind the trait. `index` is
/// the shard's GLOBAL index — on a multi-node member it already includes
/// the node's `--shard-base`, so error messages and health cells line up
/// with what a single-process deployment of the same total would say.
pub struct LocalBackend {
    index: usize,
    set: ReplicaSet,
    board: Option<Arc<HealthBoard>>,
    /// The board is indexed by LOCAL shard number (durability and
    /// supervision never left the process), which differs from `index`
    /// exactly by the node's shard base.
    local_index: usize,
}

impl LocalBackend {
    pub fn new(index: usize, set: ReplicaSet) -> Self {
        LocalBackend { index, set, board: None, local_index: index }
    }

    /// Attach the owning service's health board so [`ShardBackend::health`]
    /// reads live durability state. `local_index` is the board cell.
    pub fn with_board(mut self, local_index: usize, board: Arc<HealthBoard>) -> Self {
        self.local_index = local_index;
        self.board = Some(board);
        self
    }

    pub fn set(&self) -> &ReplicaSet {
        &self.set
    }
}

impl ShardBackend for LocalBackend {
    fn name(&self) -> String {
        format!("shard {}", self.index)
    }

    fn shards(&self) -> usize {
        1
    }

    fn replicas(&self) -> usize {
        self.set.replicas()
    }

    fn health(&self) -> Vec<u8> {
        match &self.board {
            Some(b) => vec![b.get(self.local_index).as_u8()],
            None => vec![0],
        }
    }

    // The collection id is resolved to a service (and thus to these
    // mailboxes) BEFORE the scatter, so local backends ignore it.
    fn scatter_ann(
        &self,
        _coll: u32,
        batch: &QueryBatch,
        _trace: u64,
    ) -> Option<Pending<ShardAnnResult>> {
        let (rtx, rrx) = channel();
        let guard = self.set.read(ShardCmd::AnnBatch(Arc::clone(batch), rtx))?;
        Some(Pending::Local { rx: rrx, guard })
    }

    fn scatter_kde(
        &self,
        _coll: u32,
        batch: &QueryBatch,
        _trace: u64,
    ) -> Option<Pending<ShardKdeResult>> {
        let (rtx, rrx) = channel();
        let guard = self.set.read(ShardCmd::KdeBatch(Arc::clone(batch), rtx))?;
        Some(Pending::Local { rx: rrx, guard })
    }

    fn offer(&self, _coll: u32, mut chunk: Vec<Vec<f32>>) -> IngestOutcome {
        let m = chunk.len();
        // A singleton chunk ships as the same `Insert` command it always
        // did (single inserts and 1-point batch chunks build identical
        // shard state; keeping the command stream unchanged keeps every
        // replica/WAL byte unchanged too).
        let cmd = if m == 1 {
            ShardCmd::Insert(chunk.swap_remove(0))
        } else {
            ShardCmd::InsertBatch(chunk)
        };
        match self.set.offer_write(cmd) {
            OfferOutcome::Sent => IngestOutcome::Accepted { accepted: m, shed: 0 },
            OfferOutcome::Shed => IngestOutcome::Accepted { accepted: 0, shed: m },
            OfferOutcome::Disconnected => IngestOutcome::Disconnected,
        }
    }

    fn delete(&self, _coll: u32, x: Vec<f32>) -> Option<bool> {
        self.set.delete(x)
    }
}

/// Wrap per-shard replica sets as trait objects: the standard local
/// topology (one [`LocalBackend`] per shard, global index `base + i`).
/// The board, when given, is indexed by LOCAL shard number.
pub fn local_backends(
    sets: Vec<ReplicaSet>,
    base: usize,
    board: Option<&Arc<HealthBoard>>,
) -> Vec<Arc<dyn ShardBackend>> {
    sets.into_iter()
        .enumerate()
        .map(|(i, set)| {
            let be = LocalBackend::new(base + i, set);
            let be = match board {
                Some(b) => be.with_board(i, Arc::clone(b)),
                None => be,
            };
            Arc::new(be) as Arc<dyn ShardBackend>
        })
        .collect()
}

/// A worker-pool request to one remote node. Queries carry the trace id
/// across the hop so both tiers' stage histograms and slow-query logs
/// correlate on one id; every collection-scoped op carries the
/// collection id (protocol v6) so a routed front-end addresses the
/// right tenant on the node.
enum Job {
    Ann(u32, QueryBatch, u64, Sender<Result<Vec<ShardAnnResult>, String>>),
    Kde(u32, QueryBatch, u64, Sender<Result<Vec<ShardKdeResult>, String>>),
    Insert(u32, Vec<Vec<f32>>, Sender<Result<u64, String>>),
    Delete(u32, Vec<f32>, Sender<Result<bool, String>>),
    Stats(u32, Sender<Result<ServiceStats, String>>),
    Flush(u32, Sender<Result<(), String>>),
    Checkpoint(u32, Sender<Result<u64, String>>),
    CreateCollection(String, CollectionSpec, Sender<Result<CollectionInfo, String>>),
    DropCollection(String, Sender<Result<(), String>>),
    ListCollections(Sender<Result<Vec<CollectionInfo>, String>>),
    ShutdownNode(Sender<Result<(), String>>),
}

/// One remote `sketchd serve` process, behind the trait: a shared job
/// queue drained by `pool` worker threads, each owning one lazily
/// (re)connected [`SketchClient`]. Queries ride the client's idempotent
/// retry loop (reconnect + re-handshake + jittered backoff, PR 6), so a
/// node restart mid-load costs a reconnect, not an error; inserts are
/// NOT idempotent and never retry — an ambiguous outcome surfaces as
/// [`IngestOutcome::Disconnected`].
pub struct RemoteBackend {
    addr: String,
    dim: usize,
    shards: usize,
    shard_base: u64,
    replicas: usize,
    /// Worst-shard health from the handshake, one cell per served shard
    /// (a point-in-time seed for the router's board, not a live read).
    health: Vec<u8>,
    jobs: Sender<Job>,
}

impl RemoteBackend {
    /// Probe `addr` (one handshake, fail fast on an unreachable or
    /// protocol-mismatched node), then stand up `pool` workers.
    pub fn connect(addr: &str, opts: ClientOptions, pool: usize) -> anyhow::Result<Arc<Self>> {
        let probe = SketchClient::connect_with(addr, opts)?;
        let (dim, shards, replicas) = (probe.dim(), probe.shards(), probe.replicas());
        let shard_base = probe.shard_base();
        let health = vec![probe.server_health(); shards];
        drop(probe);
        let (jobs, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        for i in 0..pool.max(1) {
            let (a, o, q) = (addr.to_string(), opts, Arc::clone(&rx));
            std::thread::Builder::new()
                .name(format!("remote-w{i}"))
                .spawn(move || worker(&a, &o, &q))?;
        }
        Ok(Arc::new(RemoteBackend {
            addr: addr.to_string(),
            dim,
            shards,
            shard_base,
            replicas,
            health,
            jobs,
        }))
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// First global shard index this node serves (from its v5 Hello).
    pub fn shard_base(&self) -> u64 {
        self.shard_base
    }

    fn call_node<T>(&self, make: impl FnOnce(Sender<Result<T, String>>) -> Job) -> Result<T, String> {
        let (tx, rx) = channel();
        self.jobs
            .send(make(tx))
            .map_err(|_| format!("node {}: worker pool is gone", self.addr))?;
        rx.recv()
            .map_err(|_| format!("node {} died mid-call", self.addr))?
    }

    /// The node's own aggregate stats for one collection (its counters,
    /// its shards).
    pub fn stats(&self, coll: u32) -> Result<ServiceStats, String> {
        self.call_node(|tx| Job::Stats(coll, tx))
    }

    /// Flush barrier for one collection on the node.
    pub fn flush(&self, coll: u32) -> Result<(), String> {
        self.call_node(|tx| Job::Flush(coll, tx))
    }

    /// Cut a checkpoint of one collection on the node; returns covered
    /// points.
    pub fn checkpoint(&self, coll: u32) -> Result<u64, String> {
        self.call_node(|tx| Job::Checkpoint(coll, tx))
    }

    /// Create a named collection on the node (`sketchd route` fans this
    /// out so every member hosts every collection).
    pub fn create_collection(
        &self,
        name: &str,
        spec: &CollectionSpec,
    ) -> Result<CollectionInfo, String> {
        let (name, spec) = (name.to_string(), spec.clone());
        self.call_node(|tx| Job::CreateCollection(name, spec, tx))
    }

    /// Drop a named collection on the node.
    pub fn drop_collection(&self, name: &str) -> Result<(), String> {
        let name = name.to_string();
        self.call_node(|tx| Job::DropCollection(name, tx))
    }

    /// The node's collection listing.
    pub fn list_collections(&self) -> Result<Vec<CollectionInfo>, String> {
        self.call_node(Job::ListCollections)
    }

    /// Ask the node's server to shut down (cascaded from `sketchd route`).
    pub fn shutdown_node(&self) -> Result<(), String> {
        self.call_node(Job::ShutdownNode)
    }
}

impl ShardBackend for RemoteBackend {
    fn name(&self) -> String {
        format!("node {}", self.addr)
    }

    fn shards(&self) -> usize {
        self.shards
    }

    fn replicas(&self) -> usize {
        self.replicas
    }

    fn health(&self) -> Vec<u8> {
        self.health.clone()
    }

    fn scatter_ann(
        &self,
        coll: u32,
        batch: &QueryBatch,
        trace: u64,
    ) -> Option<Pending<ShardAnnResult>> {
        let (tx, rx) = channel();
        self.jobs.send(Job::Ann(coll, Arc::clone(batch), trace, tx)).ok()?;
        Some(Pending::Remote { rx })
    }

    fn scatter_kde(
        &self,
        coll: u32,
        batch: &QueryBatch,
        trace: u64,
    ) -> Option<Pending<ShardKdeResult>> {
        let (tx, rx) = channel();
        self.jobs.send(Job::Kde(coll, Arc::clone(batch), trace, tx)).ok()?;
        Some(Pending::Remote { rx })
    }

    fn offer(&self, coll: u32, chunk: Vec<Vec<f32>>) -> IngestOutcome {
        let m = chunk.len();
        let (tx, rx) = channel();
        if self.jobs.send(Job::Insert(coll, chunk, tx)).is_err() {
            return IngestOutcome::Disconnected;
        }
        match rx.recv() {
            Ok(Ok(accepted)) => {
                let accepted = (accepted as usize).min(m);
                IngestOutcome::Accepted { accepted, shed: m - accepted }
            }
            Ok(Err(e)) => {
                log::warn(
                    "coordinator::backend",
                    "ingest chunk lost to a node failure",
                    crate::kv!(node = self.addr, points = m, err = e),
                );
                IngestOutcome::Disconnected
            }
            Err(_) => IngestOutcome::Disconnected,
        }
    }

    fn delete(&self, coll: u32, x: Vec<f32>) -> Option<bool> {
        self.call_node(|tx| Job::Delete(coll, x, tx)).ok()
    }
}

/// Worker loop: drain the shared job queue with one owned client,
/// reconnecting lazily. Transport errors drop the connection so the next
/// job dials fresh; the error string always names the node.
fn worker(addr: &str, opts: &ClientOptions, jobs: &Mutex<Receiver<Job>>) {
    let mut client: Option<SketchClient> = None;
    loop {
        let job = match lock_unpoisoned(jobs).recv() {
            Ok(job) => job,
            Err(_) => break, // backend dropped: pool drains and exits
        };
        match job {
            Job::Ann(coll, batch, trace, reply) => {
                let res =
                    with_client(addr, opts, &mut client, |c| c.ann_partial(coll, &batch, trace));
                let _ = reply.send(res);
            }
            Job::Kde(coll, batch, trace, reply) => {
                let res =
                    with_client(addr, opts, &mut client, |c| c.kde_partial(coll, &batch, trace));
                let _ = reply.send(res);
            }
            Job::Insert(coll, chunk, reply) => {
                let res =
                    with_client(addr, opts, &mut client, |c| c.insert_batch_in(coll, &chunk));
                let _ = reply.send(res);
            }
            Job::Delete(coll, x, reply) => {
                let res = with_client(addr, opts, &mut client, |c| c.delete_in(coll, &x));
                let _ = reply.send(res);
            }
            Job::Stats(coll, reply) => {
                let res = with_client(addr, opts, &mut client, |c| c.stats_in(coll));
                let _ = reply.send(res);
            }
            Job::Flush(coll, reply) => {
                let res = with_client(addr, opts, &mut client, |c| c.flush_in(coll));
                let _ = reply.send(res);
            }
            Job::Checkpoint(coll, reply) => {
                let res = with_client(addr, opts, &mut client, |c| c.checkpoint_in(coll));
                let _ = reply.send(res);
            }
            Job::CreateCollection(name, spec, reply) => {
                let res =
                    with_client(addr, opts, &mut client, |c| c.create_collection(&name, &spec));
                let _ = reply.send(res);
            }
            Job::DropCollection(name, reply) => {
                let res = with_client(addr, opts, &mut client, |c| c.drop_collection(&name));
                let _ = reply.send(res);
            }
            Job::ListCollections(reply) => {
                let res = with_client(addr, opts, &mut client, SketchClient::list_collections);
                let _ = reply.send(res);
            }
            Job::ShutdownNode(reply) => {
                let res = with_client(addr, opts, &mut client, SketchClient::shutdown_server);
                // The node closes the socket on shutdown; this client is
                // done either way.
                client = None;
                let _ = reply.send(res);
            }
        }
    }
}

fn with_client<T>(
    addr: &str,
    opts: &ClientOptions,
    client: &mut Option<SketchClient>,
    f: impl FnOnce(&mut SketchClient) -> anyhow::Result<T>,
) -> Result<T, String> {
    if client.is_none() {
        match SketchClient::connect_with(addr, *opts) {
            Ok(c) => *client = Some(c),
            Err(e) => return Err(format!("node {addr} is down (refusing a partial answer): {e}")),
        }
    }
    let Some(c) = client.as_mut() else {
        return Err(format!("node {addr} is down (refusing a partial answer)"));
    };
    match f(c) {
        Ok(v) => Ok(v),
        Err(e) => {
            // The client's own retry loop already reconnected for
            // idempotent ops; an error surfacing here means the node is
            // genuinely gone (or replied `Error`). Drop the connection so
            // the next job dials fresh instead of reusing a dead socket.
            *client = None;
            Err(format!("node {addr}: {e}"))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::backpressure::{bounded, Overload};
    use super::*;
    use std::time::Duration;

    fn fake_shard(
        rx: crate::util::sync::mpsc::Receiver<ShardCmd>,
    ) -> std::thread::JoinHandle<()> {
        std::thread::spawn(move || {
            while let Ok(cmd) = rx.recv() {
                match cmd {
                    ShardCmd::AnnBatch(batch, reply) => {
                        let _ = reply.send(ShardAnnResult {
                            best: vec![None; batch.len()],
                            scanned: 0,
                        });
                    }
                    ShardCmd::KdeBatch(batch, reply) => {
                        let _ = reply.send(ShardKdeResult {
                            kernel_sums: vec![1.0; batch.len()],
                            population: 10,
                        });
                    }
                    ShardCmd::Shutdown => break,
                    _ => {}
                }
            }
        })
    }

    #[test]
    fn local_backend_collects_one_partial_and_releases_the_guard() {
        let (tx, rx) = bounded(4, Overload::Block);
        let j = fake_shard(rx);
        let set = ReplicaSet::new(vec![tx.clone()]);
        let be = LocalBackend::new(3, set.clone());
        assert_eq!(be.name(), "shard 3");
        assert_eq!(be.shards(), 1);
        let batch: QueryBatch = Arc::new(vec![vec![0.0; 4], vec![1.0; 4]]);
        let parts = be.scatter_ann(0, &batch, 0).unwrap().collect(&be.name()).unwrap();
        assert_eq!(parts.len(), 1);
        assert_eq!(parts[0].best, vec![None, None]);
        let parts = be.scatter_kde(0, &batch, 7).unwrap().collect(&be.name()).unwrap();
        assert_eq!(parts[0].kernel_sums, vec![1.0, 1.0]);
        assert_eq!(set.depths(), vec![0], "guards released after collect");
        assert!(tx.force(ShardCmd::Shutdown));
        j.join().unwrap();
    }

    #[test]
    fn local_backend_dead_mailbox_refuses_to_scatter() {
        let (tx, rx) = bounded::<ShardCmd>(4, Overload::Block);
        drop(rx);
        let be = LocalBackend::new(1, ReplicaSet::new(vec![tx]));
        let batch: QueryBatch = Arc::new(vec![vec![0.0; 4]]);
        assert!(be.scatter_ann(0, &batch, 0).is_none());
        assert!(be.scatter_kde(0, &batch, 0).is_none());
        assert_eq!(be.offer(0, vec![vec![0.0; 4]]), IngestOutcome::Disconnected);
        assert!(be.delete(0, vec![0.0; 4]).is_none());
    }

    #[test]
    fn local_backend_mid_query_death_names_the_shard() {
        // The shard accepts the scatter, then drops the reply channel
        // without answering (thread death between recv and send).
        let (tx, rx) = bounded(4, Overload::Block);
        let j = std::thread::spawn(move || {
            while let Ok(cmd) = rx.recv_timeout(Duration::from_secs(10)) {
                match cmd {
                    ShardCmd::AnnBatch(_, reply) => drop(reply),
                    ShardCmd::Shutdown => break,
                    _ => {}
                }
            }
        });
        let be = LocalBackend::new(0, ReplicaSet::new(vec![tx.clone()]));
        let batch: QueryBatch = Arc::new(vec![vec![0.0; 4]]);
        let err = be.scatter_ann(0, &batch, 0).unwrap().collect(&be.name()).unwrap_err();
        assert!(err.contains("shard 0 died mid-query"), "{err}");
        assert!(tx.force(ShardCmd::Shutdown));
        j.join().unwrap();
    }

    #[test]
    fn replicated_backend_spreads_reads_and_answers_identically() {
        // One shard, two replicas: sequential singleton scatters must
        // round-robin across the copies (equal depth) and answer the
        // same regardless of which replica served.
        let (tx0, rx0) = bounded(8, Overload::Block);
        let (tx1, rx1) = bounded(8, Overload::Block);
        let (j0, j1) = (fake_shard(rx0), fake_shard(rx1));
        let set = ReplicaSet::new(vec![tx0.clone(), tx1.clone()]);
        let be = LocalBackend::new(0, set.clone());
        assert_eq!(be.replicas(), 2);
        let batch: QueryBatch = Arc::new(vec![vec![0.0; 4]]);
        for _ in 0..4 {
            let parts = be.scatter_ann(0, &batch, 0).unwrap().collect(&be.name()).unwrap();
            assert_eq!(parts[0].best, vec![None]);
        }
        assert_eq!(set.reads_served(), vec![2, 2], "reads alternate on ties");
        assert_eq!(set.depths(), vec![0, 0], "guards released after collect");
        assert!(tx0.force(ShardCmd::Shutdown));
        assert!(tx1.force(ShardCmd::Shutdown));
        j0.join().unwrap();
        j1.join().unwrap();
    }

    #[test]
    fn local_backend_offer_is_point_denominated() {
        let (tx, rx) = bounded(16, Overload::Block);
        let j = fake_shard(rx);
        let be = LocalBackend::new(0, ReplicaSet::new(vec![tx.clone()]));
        assert_eq!(
            be.offer(0, vec![vec![0.0; 4]; 3]),
            IngestOutcome::Accepted { accepted: 3, shed: 0 }
        );
        assert_eq!(
            be.offer(0, vec![vec![0.0; 4]]),
            IngestOutcome::Accepted { accepted: 1, shed: 0 }
        );
        assert!(tx.force(ShardCmd::Shutdown));
        j.join().unwrap();
    }
}
