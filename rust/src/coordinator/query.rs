//! `QueryPlane` — the cloneable scatter/gather/merge read path.
//!
//! The plane is TOPOLOGY-BLIND: it scatters a batch over a list of
//! [`ShardBackend`]s, collects each backend's typed partials, and merges
//! them — without ever seeing a mailbox, a `ShardCmd`, or a socket. The
//! backends own the topology: a single-process service hands the plane
//! one [`LocalBackend`] per shard (the exact in-process path this module
//! ran before the trait existed), and `sketchd route` hands it one
//! [`RemoteBackend`] per member node. Because the sketches are linear
//! (RACE rows and SW-AKDE counters merge by summation), the merge over
//! remote partials is the SAME `merge_ann`/`merge_kde` fold as the
//! in-process merge — backends return raw per-shard partials in global
//! shard order, so a routed deployment answers bit-identically to a
//! single process fed the same stream.
//!
//! Any thread (every wire connection, every `ServiceHandle` clone) can
//! execute a whole ANN or KDE batch on the calling thread — concurrently
//! with every other reader, without a hop through the service-owning
//! thread. The owning thread keeps only what genuinely must stay pinned
//! there: the PJRT executor (re-rank path) and control ops (stats,
//! flush, checkpoint).
//!
//! Degradation contract: a partial answer is an ERROR, never a result.
//! If any backend is unreachable (scatter fails) or dies before replying
//! (collect fails), the batch returns `Err` NAMING the backend — merging
//! the survivors would silently drop every point the dead backend owns,
//! which is indistinguishable from "no near neighbor" to the caller.
//!
//! [`LocalBackend`]: super::backend::LocalBackend
//! [`RemoteBackend`]: super::backend::RemoteBackend

use std::time::Instant;

use crate::metrics::registry::Registry;
use crate::util::sync::Arc;

use anyhow::{bail, Result};

use super::backend::ShardBackend;
use super::protocol::{
    kde_densities, merge_ann, merge_kde, AnnAnswer, ShardAnnResult, ShardKdeResult,
};

/// Cloneable, `Send` scatter/gather front over a set of shard backends.
///
/// Every batch records its stage timings into the shared registry:
/// `stage_scatter` (backend dispatch, whole batch), `stage_shard_service`
/// (per backend: dwell + service until its partials land — the slowest
/// backend gates the batch), and `stage_merge` (global min / kernel-sum
/// reduce). On a routed deployment the member nodes record their own
/// stage histograms under the SAME trace id, carried by the v5 partial
/// ops.
pub struct QueryPlane {
    backends: Vec<Arc<dyn ShardBackend>>,
    registry: Arc<Registry>,
}

impl Clone for QueryPlane {
    fn clone(&self) -> Self {
        QueryPlane {
            backends: self.backends.clone(),
            registry: Arc::clone(&self.registry),
        }
    }
}

impl QueryPlane {
    pub fn new(backends: Vec<Arc<dyn ShardBackend>>, registry: Arc<Registry>) -> Self {
        QueryPlane { backends, registry }
    }

    /// The metrics registry this plane records into (shared with the
    /// service and every handle clone).
    pub fn registry(&self) -> &Arc<Registry> {
        &self.registry
    }

    /// Total GLOBAL shards behind this plane (local backends serve one
    /// each; a remote node serves its whole range).
    pub fn shards(&self) -> usize {
        self.backends.iter().map(|b| b.shards()).sum()
    }

    /// Replicas per shard (R).
    pub fn replicas(&self) -> usize {
        self.backends.first().map_or(1, |b| b.replicas())
    }

    /// Scatter an ANN batch and return the RAW per-shard partials in
    /// global shard order, unmerged — what a front-end needs to merge
    /// exactly what an in-process plane would merge. Counts the batch
    /// and records scatter/shard-service stages; the merge stage belongs
    /// to whoever folds the partials.
    ///
    /// Errors iff any backend is unreachable or dies mid-query — see the
    /// module docs for why a partial set is never returned.
    pub fn ann_partials(
        &self,
        coll: u32,
        queries: Vec<Vec<f32>>,
        trace: u64,
    ) -> Result<Vec<ShardAnnResult>> {
        let n = queries.len();
        self.registry.ann_queries.add(n as u64);
        if n == 0 {
            return Ok(Vec::new());
        }
        let batch = Arc::new(queries);
        // Scatter to ALL backends before collecting anything, so every
        // shard works the batch at the same time.
        let t_scatter = Instant::now();
        let mut pending = Vec::with_capacity(self.backends.len());
        for be in &self.backends {
            let Some(p) = be.scatter_ann(coll, &batch, trace) else {
                bail!(
                    "ANN query failed: {} is down (refusing a partial answer)",
                    be.name()
                );
            };
            pending.push(p);
        }
        self.registry.stage_scatter.record(t_scatter.elapsed());
        let mut partials = Vec::with_capacity(self.backends.len());
        for (be, p) in self.backends.iter().zip(pending) {
            let t_shard = Instant::now();
            match p.collect(&be.name()) {
                Ok(parts) => {
                    self.registry.stage_shard_service.record(t_shard.elapsed());
                    partials.extend(parts);
                }
                Err(e) => bail!("ANN query failed: {e}"),
            }
        }
        Ok(partials)
    }

    /// KDE twin of [`Self::ann_partials`]: raw kernel sums + population
    /// per shard, in global shard order, unmerged.
    pub fn kde_partials(
        &self,
        coll: u32,
        queries: Vec<Vec<f32>>,
        trace: u64,
    ) -> Result<Vec<ShardKdeResult>> {
        let n = queries.len();
        self.registry.kde_queries.add(n as u64);
        if n == 0 {
            return Ok(Vec::new());
        }
        let batch = Arc::new(queries);
        let t_scatter = Instant::now();
        let mut pending = Vec::with_capacity(self.backends.len());
        for be in &self.backends {
            let Some(p) = be.scatter_kde(coll, &batch, trace) else {
                bail!(
                    "KDE query failed: {} is down (refusing a partial answer)",
                    be.name()
                );
            };
            pending.push(p);
        }
        self.registry.stage_scatter.record(t_scatter.elapsed());
        let mut partials = Vec::with_capacity(self.backends.len());
        for (be, p) in self.backends.iter().zip(pending) {
            let t_shard = Instant::now();
            match p.collect(&be.name()) {
                Ok(parts) => {
                    self.registry.stage_shard_service.record(t_shard.elapsed());
                    partials.extend(parts);
                }
                Err(e) => bail!("KDE query failed: {e}"),
            }
        }
        Ok(partials)
    }

    /// Batched (c, r)-ANN with the trace id carried to every backend:
    /// scatter, collect per-shard bests, keep the global minimum per
    /// query. Answers are bit-identical regardless of topology — the
    /// partials arrive in global shard order, so the merge fold visits
    /// shards exactly as an in-process plane would.
    pub fn ann_batch_traced(
        &self,
        coll: u32,
        queries: Vec<Vec<f32>>,
        trace: u64,
    ) -> Result<Vec<Option<AnnAnswer>>> {
        let n = queries.len();
        let partials = self.ann_partials(coll, queries, trace)?;
        if n == 0 {
            return Ok(Vec::new());
        }
        let t_merge = Instant::now();
        let merged = merge_ann(&partials, n);
        self.registry.stage_merge.record(t_merge.elapsed());
        Ok(merged)
    }

    /// [`Self::ann_batch_traced`] against the default collection with no
    /// caller-supplied trace id.
    pub fn ann_batch(&self, queries: Vec<Vec<f32>>) -> Result<Vec<Option<AnnAnswer>>> {
        self.ann_batch_traced(0, queries, 0)
    }

    /// Batched sliding-window KDE (summed kernel estimates, densities)
    /// with the trace id carried to every backend. Same degradation
    /// contract as ANN: a missing backend's kernel mass would silently
    /// bias every estimate low, so it is an error. The kernel-sum fold
    /// runs over per-shard partials in global shard order — f64 addition
    /// is not associative, so this ordering IS the bit-parity guarantee.
    pub fn kde_batch_traced(
        &self,
        coll: u32,
        queries: Vec<Vec<f32>>,
        trace: u64,
    ) -> Result<(Vec<f64>, Vec<f64>)> {
        let n = queries.len();
        let partials = self.kde_partials(coll, queries, trace)?;
        if n == 0 {
            return Ok((Vec::new(), Vec::new()));
        }
        let t_merge = Instant::now();
        let (sums, pop) = merge_kde(&partials, n);
        let density = kde_densities(&sums, pop);
        self.registry.stage_merge.record(t_merge.elapsed());
        Ok((sums, density))
    }

    /// [`Self::kde_batch_traced`] against the default collection with no
    /// caller-supplied trace id.
    pub fn kde_batch(&self, queries: Vec<Vec<f32>>) -> Result<(Vec<f64>, Vec<f64>)> {
        self.kde_batch_traced(0, queries, 0)
    }
}

#[cfg(test)]
mod tests {
    use super::super::backend::Pending;
    use super::super::protocol::QueryBatch;
    use super::*;
    use crate::util::sync::atomic::{AtomicU64, Ordering};
    use crate::util::sync::mpsc::channel;

    const TRACE_ORD: Ordering = Ordering::SeqCst;

    /// Trait-level fake: no mailboxes, no threads. `Dead` refuses the
    /// scatter; `Dying` accepts it and never answers.
    enum Mode {
        Healthy,
        Dead,
        Dying,
    }

    struct FakeBackend {
        name: String,
        shards: usize,
        mode: Mode,
        last_trace: AtomicU64,
        last_coll: AtomicU64,
    }

    impl FakeBackend {
        fn healthy(index: usize) -> Self {
            FakeBackend {
                name: format!("shard {index}"),
                shards: 1,
                mode: Mode::Healthy,
                last_trace: AtomicU64::new(0),
                last_coll: AtomicU64::new(u64::MAX),
            }
        }
    }

    impl ShardBackend for FakeBackend {
        fn name(&self) -> String {
            self.name.clone()
        }

        fn shards(&self) -> usize {
            self.shards
        }

        fn replicas(&self) -> usize {
            1
        }

        fn health(&self) -> Vec<u8> {
            vec![0; self.shards]
        }

        fn scatter_ann(
            &self,
            coll: u32,
            batch: &QueryBatch,
            trace: u64,
        ) -> Option<Pending<ShardAnnResult>> {
            self.last_trace.store(trace, TRACE_ORD);
            self.last_coll.store(coll as u64, TRACE_ORD);
            let (tx, rx) = channel();
            match self.mode {
                Mode::Healthy => {
                    let part = ShardAnnResult { best: vec![None; batch.len()], scanned: 0 };
                    let _ = tx.send(Ok(vec![part; self.shards]));
                }
                Mode::Dead => return None,
                Mode::Dying => drop(tx),
            }
            Some(Pending::Remote { rx })
        }

        fn scatter_kde(
            &self,
            coll: u32,
            batch: &QueryBatch,
            trace: u64,
        ) -> Option<Pending<ShardKdeResult>> {
            self.last_trace.store(trace, TRACE_ORD);
            self.last_coll.store(coll as u64, TRACE_ORD);
            let (tx, rx) = channel();
            match self.mode {
                Mode::Healthy => {
                    let part =
                        ShardKdeResult { kernel_sums: vec![1.0; batch.len()], population: 10 };
                    let _ = tx.send(Ok(vec![part; self.shards]));
                }
                Mode::Dead => return None,
                Mode::Dying => drop(tx),
            }
            Some(Pending::Remote { rx })
        }

        fn offer(&self, _coll: u32, _chunk: Vec<Vec<f32>>) -> super::super::backend::IngestOutcome {
            super::super::backend::IngestOutcome::Disconnected
        }

        fn delete(&self, _coll: u32, _x: Vec<f32>) -> Option<bool> {
            None
        }
    }

    fn plane_of(backends: Vec<FakeBackend>) -> (QueryPlane, Arc<Registry>) {
        let registry = Arc::new(Registry::new());
        let plane = QueryPlane::new(
            backends
                .into_iter()
                .map(|b| Arc::new(b) as Arc<dyn ShardBackend>)
                .collect(),
            Arc::clone(&registry),
        );
        (plane, registry)
    }

    #[test]
    fn empty_batches_short_circuit() {
        let (plane, registry) = plane_of(vec![FakeBackend::healthy(0)]);
        assert!(plane.ann_batch(Vec::new()).unwrap().is_empty());
        let (s, d) = plane.kde_batch(Vec::new()).unwrap();
        assert!(s.is_empty() && d.is_empty());
        assert_eq!(registry.stage_scatter.count(), 0, "nothing scattered");
        assert_eq!(registry.stage_merge.count(), 0, "nothing merged");
    }

    #[test]
    fn healthy_backends_answer_count_and_record_stages() {
        let (plane, registry) = plane_of(vec![FakeBackend::healthy(0), FakeBackend::healthy(1)]);
        let ans = plane.ann_batch(vec![vec![0.0; 4], vec![1.0; 4]]).unwrap();
        assert_eq!(ans, vec![None, None]);
        let (sums, dens) = plane.kde_batch(vec![vec![0.0; 4]]).unwrap();
        assert_eq!(sums, vec![2.0], "kernel sums add across the partition");
        assert_eq!(dens, vec![2.0 / 20.0]);
        assert_eq!(registry.ann_queries.get(), 2);
        assert_eq!(registry.kde_queries.get(), 1);
        // Each batch records scatter/merge once, shard-service per backend.
        assert_eq!(registry.stage_scatter.count(), 2);
        assert_eq!(registry.stage_merge.count(), 2);
        assert_eq!(registry.stage_shard_service.count(), 4);
    }

    #[test]
    fn multi_shard_backend_partials_flatten_in_order() {
        // One backend serving 3 global shards (a remote node) returns 3
        // partials from one collect; the plane must merge all of them.
        let node = FakeBackend {
            name: "node 127.0.0.1:7070".into(),
            shards: 3,
            mode: Mode::Healthy,
            last_trace: AtomicU64::new(0),
            last_coll: AtomicU64::new(u64::MAX),
        };
        let (plane, _) = plane_of(vec![node]);
        assert_eq!(plane.shards(), 3);
        let (sums, dens) = plane.kde_batch(vec![vec![0.0; 4]]).unwrap();
        assert_eq!(sums, vec![3.0], "three shards' kernel mass");
        assert_eq!(dens, vec![3.0 / 30.0]);
    }

    #[test]
    fn trace_id_reaches_every_backend() {
        let (b0, b1) = (
            Arc::new(FakeBackend::healthy(0)),
            Arc::new(FakeBackend::healthy(1)),
        );
        let plane = QueryPlane::new(
            vec![
                Arc::clone(&b0) as Arc<dyn ShardBackend>,
                Arc::clone(&b1) as Arc<dyn ShardBackend>,
            ],
            Arc::new(Registry::new()),
        );
        plane.ann_batch_traced(0, vec![vec![0.0; 4]], 0xBEEF).unwrap();
        assert_eq!(b0.last_trace.load(TRACE_ORD), 0xBEEF);
        assert_eq!(b1.last_trace.load(TRACE_ORD), 0xBEEF);
        plane.kde_batch_traced(0, vec![vec![0.0; 4]], 0xF00D).unwrap();
        assert_eq!(b0.last_trace.load(TRACE_ORD), 0xF00D);
        assert_eq!(b1.last_trace.load(TRACE_ORD), 0xF00D);
    }

    #[test]
    fn collection_id_reaches_every_backend() {
        let (b0, b1) = (
            Arc::new(FakeBackend::healthy(0)),
            Arc::new(FakeBackend::healthy(1)),
        );
        let plane = QueryPlane::new(
            vec![
                Arc::clone(&b0) as Arc<dyn ShardBackend>,
                Arc::clone(&b1) as Arc<dyn ShardBackend>,
            ],
            Arc::new(Registry::new()),
        );
        plane.ann_batch_traced(7, vec![vec![0.0; 4]], 0).unwrap();
        assert_eq!(b0.last_coll.load(TRACE_ORD), 7);
        assert_eq!(b1.last_coll.load(TRACE_ORD), 7);
        plane.kde_batch_traced(9, vec![vec![0.0; 4]], 0).unwrap();
        assert_eq!(b0.last_coll.load(TRACE_ORD), 9);
        assert_eq!(b1.last_coll.load(TRACE_ORD), 9);
        plane.ann_batch(vec![vec![0.0; 4]]).unwrap();
        assert_eq!(
            b0.last_coll.load(TRACE_ORD),
            0,
            "convenience ops address the default collection"
        );
    }

    #[test]
    fn dead_backend_is_an_error_not_a_partial_answer() {
        // Backend 0 is healthy and WOULD answer; backend 1 refuses the
        // scatter. The whole batch must error, naming the dead one.
        let dead = FakeBackend {
            name: "shard 1".into(),
            shards: 1,
            mode: Mode::Dead,
            last_trace: AtomicU64::new(0),
            last_coll: AtomicU64::new(u64::MAX),
        };
        let (plane, _) = plane_of(vec![FakeBackend::healthy(0), dead]);
        let err = plane.ann_batch(vec![vec![0.0; 4]]).unwrap_err().to_string();
        assert!(err.contains("shard 1"), "{err}");
        let err = plane.kde_batch(vec![vec![0.0; 4]]).unwrap_err().to_string();
        assert!(err.contains("shard 1"), "{err}");
    }

    #[test]
    fn backend_dying_mid_query_is_an_error() {
        // The backend accepts the scatter, then drops the reply channel
        // without answering (thread death between recv and send).
        let dying = FakeBackend {
            name: "node 10.0.0.2:4444".into(),
            shards: 2,
            mode: Mode::Dying,
            last_trace: AtomicU64::new(0),
            last_coll: AtomicU64::new(u64::MAX),
        };
        let (plane, _) = plane_of(vec![dying]);
        let err = plane.ann_batch(vec![vec![0.0; 4]]).unwrap_err().to_string();
        assert!(err.contains("died mid-query"), "{err}");
        assert!(err.contains("node 10.0.0.2:4444"), "{err}");
    }
}
