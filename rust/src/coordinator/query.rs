//! `QueryPlane` — the cloneable native read path of the service.
//!
//! Shards answer `AnnBatch`/`KdeBatch` independently; the only thing the
//! native read path ever needed from the owning thread was the scatter/
//! gather/merge glue. This type IS that glue, detached: it holds clones
//! of the per-shard [`ReplicaSet`]s plus the shared counters, so any
//! thread (every wire connection, every `ServiceHandle` clone) can
//! execute a whole ANN or KDE batch on the calling thread — concurrently
//! with every other reader, without a hop through the service-owning
//! thread. The owning thread keeps only what genuinely must stay pinned
//! there: the PJRT executor (re-rank path) and control ops (stats,
//! flush, checkpoint).
//!
//! With replicas (`R > 1`) each shard's scatter lands on that shard's
//! least-loaded replica (in-flight read depth, ties round-robin) — the
//! replicas hold bit-identical state, so WHICH copy answers never
//! changes the answer, only who pays for it.
//!
//! Degradation contract: a partial answer is an ERROR, never a result.
//! If any shard's picked replica is unreachable (scatter fails) or dies
//! before replying (gather fails), the batch returns `Err` — merging the
//! surviving shards would silently drop every point the dead shard owns,
//! which is indistinguishable from "no near neighbor" to the caller.

use std::time::Instant;

use crate::metrics::registry::Registry;
use crate::util::sync::mpsc::channel;
use crate::util::sync::Arc;

use anyhow::{bail, Result};

use super::protocol::{kde_densities, merge_ann, merge_kde, AnnAnswer};
use super::replica::ReplicaSet;
use super::shard::ShardCmd;

/// Cloneable, `Send` scatter/gather front over the shard replica sets.
///
/// Every batch records its stage timings into the shared registry:
/// `stage_scatter` (replica pick + mailbox send, whole batch),
/// `stage_shard_service` (per shard: mailbox dwell + sketch scan until
/// the reply lands — the slowest shard gates the batch), and
/// `stage_merge` (global min / kernel-sum reduce).
pub struct QueryPlane {
    sets: Vec<ReplicaSet>,
    registry: Arc<Registry>,
}

impl Clone for QueryPlane {
    fn clone(&self) -> Self {
        QueryPlane {
            sets: self.sets.clone(),
            registry: Arc::clone(&self.registry),
        }
    }
}

impl QueryPlane {
    pub(super) fn new(sets: Vec<ReplicaSet>, registry: Arc<Registry>) -> Self {
        QueryPlane { sets, registry }
    }

    /// The metrics registry this plane records into (shared with the
    /// service and every handle clone).
    pub fn registry(&self) -> &Arc<Registry> {
        &self.registry
    }

    /// Number of shards this plane scatters over.
    pub fn shards(&self) -> usize {
        self.sets.len()
    }

    /// Replicas per shard (R).
    pub fn replicas(&self) -> usize {
        self.sets.first().map_or(1, ReplicaSet::replicas)
    }

    /// Batched (c, r)-ANN, executed entirely on the calling thread:
    /// scatter `AnnBatch` to one replica of every shard, gather the
    /// per-shard bests, keep the global minimum per query. Answers are
    /// bit-identical to the pre-extraction `SketchService::query_batch`
    /// native path — and to any other replica choice.
    ///
    /// Errors iff any shard is unreachable or dies mid-query — see the
    /// module docs for why a partial merge is never returned.
    pub fn ann_batch(&self, queries: Vec<Vec<f32>>) -> Result<Vec<Option<AnnAnswer>>> {
        let n = queries.len();
        self.registry.ann_queries.add(n as u64);
        if n == 0 {
            return Ok(Vec::new());
        }
        let batch = Arc::new(queries);
        // Scatter to ALL shards before gathering anything, so every shard
        // works the batch at the same time. The read guards keep the
        // picked replicas' depth gauges raised until their replies land.
        let t_scatter = Instant::now();
        let mut pending = Vec::with_capacity(self.sets.len());
        for (si, set) in self.sets.iter().enumerate() {
            let (rtx, rrx) = channel();
            let Some(guard) = set.read(ShardCmd::AnnBatch(Arc::clone(&batch), rtx)) else {
                bail!("ANN query failed: shard {si} is down (refusing a partial answer)");
            };
            pending.push((rrx, guard));
        }
        self.registry.stage_scatter.record(t_scatter.elapsed());
        let mut partials = Vec::with_capacity(pending.len());
        for (si, (rrx, guard)) in pending.into_iter().enumerate() {
            let t_shard = Instant::now();
            match rrx.recv() {
                Ok(part) => {
                    drop(guard);
                    self.registry.stage_shard_service.record(t_shard.elapsed());
                    partials.push(part);
                }
                Err(_) => bail!("ANN query failed: shard {si} died mid-query"),
            }
        }
        let t_merge = Instant::now();
        let merged = merge_ann(&partials, n);
        self.registry.stage_merge.record(t_merge.elapsed());
        Ok(merged)
    }

    /// Batched sliding-window KDE (summed kernel estimates, densities),
    /// executed entirely on the calling thread. Same degradation
    /// contract as [`Self::ann_batch`]: a missing shard's kernel mass
    /// would silently bias every estimate low, so it is an error.
    pub fn kde_batch(&self, queries: Vec<Vec<f32>>) -> Result<(Vec<f64>, Vec<f64>)> {
        let n = queries.len();
        self.registry.kde_queries.add(n as u64);
        if n == 0 {
            return Ok((Vec::new(), Vec::new()));
        }
        let batch = Arc::new(queries);
        let t_scatter = Instant::now();
        let mut pending = Vec::with_capacity(self.sets.len());
        for (si, set) in self.sets.iter().enumerate() {
            let (rtx, rrx) = channel();
            let Some(guard) = set.read(ShardCmd::KdeBatch(Arc::clone(&batch), rtx)) else {
                bail!("KDE query failed: shard {si} is down (refusing a partial answer)");
            };
            pending.push((rrx, guard));
        }
        self.registry.stage_scatter.record(t_scatter.elapsed());
        let mut partials = Vec::with_capacity(pending.len());
        for (si, (rrx, guard)) in pending.into_iter().enumerate() {
            let t_shard = Instant::now();
            match rrx.recv() {
                Ok(part) => {
                    drop(guard);
                    self.registry.stage_shard_service.record(t_shard.elapsed());
                    partials.push(part);
                }
                Err(_) => bail!("KDE query failed: shard {si} died mid-query"),
            }
        }
        let t_merge = Instant::now();
        let (sums, pop) = merge_kde(&partials, n);
        let density = kde_densities(&sums, pop);
        self.registry.stage_merge.record(t_merge.elapsed());
        Ok((sums, density))
    }
}

#[cfg(test)]
mod tests {
    use super::super::backpressure::{bounded, BoundedSender, Overload};
    use super::super::protocol::{ShardAnnResult, ShardKdeResult};
    use super::*;
    use std::time::Duration;

    fn fake_shard(rx: crate::util::sync::mpsc::Receiver<ShardCmd>) -> std::thread::JoinHandle<()> {
        std::thread::spawn(move || {
            while let Ok(cmd) = rx.recv() {
                match cmd {
                    ShardCmd::AnnBatch(batch, reply) => {
                        let _ = reply.send(ShardAnnResult {
                            best: vec![None; batch.len()],
                            scanned: 0,
                        });
                    }
                    ShardCmd::KdeBatch(batch, reply) => {
                        let _ = reply.send(ShardKdeResult {
                            kernel_sums: vec![1.0; batch.len()],
                            population: 10,
                        });
                    }
                    ShardCmd::Shutdown => break,
                    _ => {}
                }
            }
        })
    }

    fn single(tx: BoundedSender<ShardCmd>) -> ReplicaSet {
        ReplicaSet::new(vec![tx])
    }

    #[test]
    fn empty_batches_short_circuit() {
        let (tx, _rx) = bounded(4, Overload::Block);
        let plane = QueryPlane::new(vec![single(tx)], Arc::new(Registry::new()));
        assert!(plane.ann_batch(Vec::new()).unwrap().is_empty());
        let (s, d) = plane.kde_batch(Vec::new()).unwrap();
        assert!(s.is_empty() && d.is_empty());
    }

    #[test]
    fn healthy_shards_answer_count_and_record_stages() {
        let (tx0, rx0) = bounded(4, Overload::Block);
        let (tx1, rx1) = bounded(4, Overload::Block);
        let (j0, j1) = (fake_shard(rx0), fake_shard(rx1));
        let registry = Arc::new(Registry::new());
        let plane = QueryPlane::new(
            vec![single(tx0.clone()), single(tx1.clone())],
            Arc::clone(&registry),
        );
        let ans = plane.ann_batch(vec![vec![0.0; 4], vec![1.0; 4]]).unwrap();
        assert_eq!(ans, vec![None, None]);
        let (sums, dens) = plane.kde_batch(vec![vec![0.0; 4]]).unwrap();
        assert_eq!(sums, vec![2.0], "kernel sums add across the partition");
        assert_eq!(dens, vec![2.0 / 20.0]);
        assert_eq!(registry.ann_queries.get(), 2);
        assert_eq!(registry.kde_queries.get(), 1);
        // Each batch records scatter/merge once, shard-service per shard.
        assert_eq!(registry.stage_scatter.count(), 2);
        assert_eq!(registry.stage_merge.count(), 2);
        assert_eq!(registry.stage_shard_service.count(), 4);
        assert!(tx0.force(ShardCmd::Shutdown));
        assert!(tx1.force(ShardCmd::Shutdown));
        j0.join().unwrap();
        j1.join().unwrap();
    }

    #[test]
    fn replicated_shard_spreads_reads_and_answers_identically() {
        // One shard, two replicas: sequential singleton batches must
        // round-robin across the copies (equal depth) and answer the
        // same regardless of which replica served.
        let (tx0, rx0) = bounded(8, Overload::Block);
        let (tx1, rx1) = bounded(8, Overload::Block);
        let (j0, j1) = (fake_shard(rx0), fake_shard(rx1));
        let set = ReplicaSet::new(vec![tx0.clone(), tx1.clone()]);
        let plane = QueryPlane::new(vec![set.clone()], Arc::new(Registry::new()));
        for _ in 0..4 {
            let ans = plane.ann_batch(vec![vec![0.0; 4]]).unwrap();
            assert_eq!(ans, vec![None]);
        }
        assert_eq!(set.reads_served(), vec![2, 2], "reads alternate on ties");
        assert_eq!(set.depths(), vec![0, 0], "guards released after gather");
        assert!(tx0.force(ShardCmd::Shutdown));
        assert!(tx1.force(ShardCmd::Shutdown));
        j0.join().unwrap();
        j1.join().unwrap();
    }

    #[test]
    fn dead_shard_is_an_error_not_a_partial_answer() {
        // Shard 0 is healthy and WOULD answer; shard 1's mailbox is
        // closed. The pre-fix behavior merged shard 0 alone and returned
        // it as a complete answer — now the whole batch must error.
        let (tx0, rx0) = bounded(4, Overload::Block);
        let (tx1, rx1) = bounded::<ShardCmd>(4, Overload::Block);
        drop(rx1);
        let j0 = fake_shard(rx0);
        let plane = QueryPlane::new(vec![single(tx0.clone()), single(tx1)], Arc::new(Registry::new()));
        let err = plane.ann_batch(vec![vec![0.0; 4]]).unwrap_err().to_string();
        assert!(err.contains("shard 1"), "{err}");
        let err = plane.kde_batch(vec![vec![0.0; 4]]).unwrap_err().to_string();
        assert!(err.contains("shard 1"), "{err}");
        assert!(tx0.force(ShardCmd::Shutdown));
        j0.join().unwrap();
    }

    #[test]
    fn shard_dying_mid_query_is_an_error() {
        // The shard accepts the scatter, then drops the reply channel
        // without answering (thread death between recv and send).
        let (tx, rx) = bounded(4, Overload::Block);
        let j = std::thread::spawn(move || {
            while let Ok(cmd) = rx.recv_timeout(Duration::from_secs(10)) {
                match cmd {
                    ShardCmd::AnnBatch(_, reply) => drop(reply),
                    ShardCmd::Shutdown => break,
                    _ => {}
                }
            }
        });
        let plane = QueryPlane::new(vec![single(tx.clone())], Arc::new(Registry::new()));
        let err = plane.ann_batch(vec![vec![0.0; 4]]).unwrap_err().to_string();
        assert!(err.contains("died mid-query"), "{err}");
        assert!(tx.force(ShardCmd::Shutdown));
        j.join().unwrap();
    }
}
