//! Shard worker: owns one partition of the service state — an S-ANN
//! sketch and an SW-AKDE sketch over the points routed to it — and
//! processes commands from its mailbox on a dedicated thread.

use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;

use crate::lsh::concat::BoundedHasher;
use crate::lsh::pstable::PStableLsh;
use crate::lsh::srp::SrpLsh;
use crate::lsh::LshFamily;
use crate::sketch::ann::{SAnn, SAnnConfig};
use crate::sketch::swakde::SwAkde;
use crate::util::rng::Rng;

use super::protocol::{AnnAnswer, ShardAnnResult, ShardKdeResult};

/// Which LSH kernel the KDE sketch runs (paper evaluates both).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KdeKernel {
    /// SRP / angular with bit-packed cells (range 2^p).
    Angular,
    /// p-stable Euclidean, rehashed to `range` cells.
    Euclidean,
}

/// KDE sketch parameters for a shard.
#[derive(Clone, Debug)]
pub struct KdeShardConfig {
    pub kernel: KdeKernel,
    pub rows: usize,
    pub p: usize,
    /// Cell range for Euclidean (ignored for Angular: 2^p).
    pub range: usize,
    /// p-stable bucket width (Euclidean only).
    pub width: f32,
    pub eps_eh: f64,
    /// Per-shard window (global window / shards under round-robin).
    pub window: u64,
}

/// Commands a shard accepts.
pub enum ShardCmd {
    Insert(Vec<f32>),
    /// Batched native inserts: the shard hashes the whole batch for both
    /// sketches with one GEMM-shaped kernel call each, instead of a loop
    /// of per-point hashing (state-identical to a loop of `Insert`s).
    InsertBatch(Vec<Vec<f32>>),
    /// Insert with precomputed raw ANN hash slots (PJRT bulk-load path).
    InsertWithSlots(Vec<f32>, Vec<i64>),
    /// Batched inserts with precomputed ANN and KDE raw slots — the fully
    /// AOT ingest path: the server hashes whole batches through the PJRT
    /// artifacts, shard threads only update tables and EHs (§Perf it 5).
    InsertBatchSlots(Vec<(Vec<f32>, Vec<i64>, Vec<i64>)>),
    Delete(Vec<f32>, Sender<bool>),
    /// Native ANN: per-query best candidate on this shard.
    AnnBatch(super::protocol::QueryBatch, Sender<ShardAnnResult>),
    /// PJRT ANN: shard-deduplicated candidate pool + per-query indices
    /// into it (the server merges pools and re-ranks via one GEMM).
    AnnCandidates(super::protocol::QueryBatch, Sender<ShardCandidates>),
    /// Like AnnCandidates, but with table keys precomputed by the server
    /// (batched through the PJRT hash artifact): \[query][L\] keys.
    AnnCandidatesKeys(Arc<Vec<Vec<u64>>>, Sender<ShardCandidates>),
    KdeBatch(super::protocol::QueryBatch, Sender<ShardKdeResult>),
    Stats(Sender<ShardStats>),
    Shutdown,
}

/// Deduplicated candidate reply: each candidate vector ships once per
/// batch regardless of how many queries hit it.
#[derive(Clone, Debug, Default)]
pub struct ShardCandidates {
    /// Unique candidate ids, aligned with `pool` rows.
    pub ids: Vec<u32>,
    /// Row-major [ids.len(), dim] vector payload.
    pub pool: Vec<f32>,
    /// Per query: indices into `ids`/`pool`.
    pub per_query: Vec<Vec<u32>>,
}

/// Shard-level statistics.
#[derive(Clone, Debug, Default)]
pub struct ShardStats {
    pub stored: usize,
    pub inserted: u64,
    pub deleted: u64,
    pub sketch_bytes: usize,
    pub kde_occupied_cells: usize,
}

/// The state each shard thread owns.
pub struct Shard {
    pub index: usize,
    ann: SAnn,
    kde: SwAkde,
    kde_family: Box<dyn LshFamily>,
    stats: ShardStats,
}

impl Shard {
    pub fn new(index: usize, ann_cfg: SAnnConfig, kde_cfg: &KdeShardConfig, seed: u64) -> Self {
        let ann = SAnn::new(SAnnConfig { seed: seed ^ (index as u64) << 8, ..ann_cfg });
        let mut rng = Rng::new(seed.wrapping_mul(0x9E3779B97F4A7C15) ^ index as u64);
        let (kde, kde_family): (SwAkde, Box<dyn LshFamily>) = match kde_cfg.kernel {
            KdeKernel::Angular => {
                let hasher = BoundedHasher::new_packed(kde_cfg.p, kde_cfg.rows);
                let fam = SrpLsh::new(ann.config().dim, hasher.funcs_needed(), &mut rng);
                (
                    SwAkde::with_hasher(hasher, kde_cfg.eps_eh, kde_cfg.window),
                    Box::new(fam),
                )
            }
            KdeKernel::Euclidean => {
                let hasher = BoundedHasher::new(kde_cfg.p, kde_cfg.rows, kde_cfg.range);
                let fam =
                    PStableLsh::new(ann.config().dim, hasher.funcs_needed(), kde_cfg.width, &mut rng);
                (
                    SwAkde::with_hasher(hasher, kde_cfg.eps_eh, kde_cfg.window),
                    Box::new(fam),
                )
            }
        };
        Shard { index, ann, kde, kde_family, stats: ShardStats::default() }
    }

    /// ANN hashing parameters of this shard, cloned for the server's
    /// batched PJRT hash path: (projection [dim, k*L], biases, width, k, L).
    pub fn ann_hash_params(&self) -> (Vec<f32>, Vec<f32>, f32, usize, usize) {
        (
            self.ann.family().projection().to_vec(),
            self.ann.family().biases().to_vec(),
            self.ann.family().width(),
            self.ann.params().k,
            self.ann.params().l,
        )
    }

    /// KDE hashing parameters for the server's batched PJRT ingest:
    /// (projection [dim, rows*p], biases-or-empty, width, rows*p, kernel).
    pub fn kde_hash_params(&self) -> (Vec<f32>, Vec<f32>, f32, usize, KdeKernel) {
        let fam = self.kde_family.as_ref();
        let kernel = if fam.as_any_pstable().is_some() {
            KdeKernel::Euclidean
        } else {
            KdeKernel::Angular
        };
        let (bias, w) = match fam.as_any_pstable() {
            Some(ps) => (ps.biases().to_vec(), ps.width()),
            None => (Vec::new(), 0.0),
        };
        (fam.projection().to_vec(), bias, w, fam.n_funcs(), kernel)
    }

    fn intern(
        ids: &mut Vec<u32>,
        pool: &mut Vec<f32>,
        slot_of: &mut std::collections::HashMap<u32, u32>,
        ann: &SAnn,
        cand_ids: Vec<u32>,
    ) -> Vec<u32> {
        let mut idxs = Vec::with_capacity(cand_ids.len());
        for id in cand_ids {
            let slot = *slot_of.entry(id).or_insert_with(|| {
                ids.push(id);
                pool.extend_from_slice(ann.vector(id));
                (ids.len() - 1) as u32
            });
            idxs.push(slot);
        }
        idxs
    }

    pub fn handle(&mut self, cmd: ShardCmd) -> bool {
        match cmd {
            ShardCmd::Insert(x) => {
                self.ann.insert(&x);
                self.kde.add(self.kde_family.as_ref(), &x);
                self.stats.inserted += 1;
            }
            ShardCmd::InsertBatch(batch) => {
                self.stats.inserted += batch.len() as u64;
                self.ann.insert_batch(&batch);
                let flat: Vec<f32> = batch.iter().flatten().copied().collect();
                self.kde.add_each(self.kde_family.as_ref(), &flat);
            }
            ShardCmd::InsertWithSlots(x, slots) => {
                // Sampler decision still applies; slots bypass only hashing.
                if self.ann.sampler_keep() {
                    self.ann.insert_retained_slots(&x, &slots);
                }
                self.kde.add(self.kde_family.as_ref(), &x);
                self.stats.inserted += 1;
            }
            ShardCmd::InsertBatchSlots(batch) => {
                for (x, ann_slots, kde_slots) in batch {
                    if self.ann.sampler_keep() {
                        self.ann.insert_retained_slots(&x, &ann_slots);
                    }
                    self.kde.add_slots(&kde_slots);
                    self.stats.inserted += 1;
                }
            }
            ShardCmd::Delete(x, reply) => {
                let removed = self.ann.delete(&x);
                if removed {
                    self.stats.deleted += 1;
                }
                let _ = reply.send(removed);
            }
            ShardCmd::AnnBatch(batch, reply) => {
                // One batched hashing kernel for the whole query batch.
                let (answers, stats) = self.ann.query_batch_with_stats(&batch);
                let out = ShardAnnResult {
                    best: answers
                        .into_iter()
                        .map(|ans| {
                            ans.map(|(id, dist)| AnnAnswer { shard: self.index, id, dist })
                        })
                        .collect(),
                    scanned: stats.scanned,
                };
                let _ = reply.send(out);
            }
            ShardCmd::AnnCandidates(batch, reply) => {
                let mut out = ShardCandidates::default();
                let mut slot_of: std::collections::HashMap<u32, u32> = Default::default();
                for q in batch.iter() {
                    let ids = self.ann.candidates(q).to_vec();
                    out.per_query.push(Self::intern(&mut out.ids, &mut out.pool, &mut slot_of, &self.ann, ids));
                }
                let _ = reply.send(out);
            }
            ShardCmd::AnnCandidatesKeys(keys, reply) => {
                let mut out = ShardCandidates::default();
                let mut slot_of: std::collections::HashMap<u32, u32> = Default::default();
                for qkeys in keys.iter() {
                    let ids = self.ann.candidates_by_keys(qkeys).to_vec();
                    out.per_query.push(Self::intern(&mut out.ids, &mut out.pool, &mut slot_of, &self.ann, ids));
                }
                let _ = reply.send(out);
            }
            ShardCmd::KdeBatch(batch, reply) => {
                // Flatten once, hash the whole batch with one kernel call.
                let flat: Vec<f32> = batch.iter().flatten().copied().collect();
                let sums = self.kde.query_batch(self.kde_family.as_ref(), &flat);
                let _ = reply.send(ShardKdeResult {
                    kernel_sums: sums,
                    // Point-denominated live population (exact for the
                    // coordinator's per-point ticks; EH-estimated under
                    // add_batch ingest).
                    population: self.kde.population().round() as u64,
                });
            }
            ShardCmd::Stats(reply) => {
                self.stats.stored = self.ann.stored();
                self.stats.sketch_bytes = self.ann.memory_bytes() + self.kde.memory_bytes();
                self.stats.kde_occupied_cells = self.kde.occupied_cells();
                let _ = reply.send(self.stats.clone());
            }
            ShardCmd::Shutdown => return false,
        }
        true
    }

    /// Run the mailbox loop until Shutdown or channel close.
    pub fn run(mut self, rx: Receiver<ShardCmd>) {
        while let Ok(cmd) = rx.recv() {
            if !self.handle(cmd) {
                break;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;
    use std::sync::Arc;

    fn mk_shard() -> Shard {
        let ann_cfg = SAnnConfig {
            dim: 8,
            n_max: 1000,
            eta: 0.0,
            r: 1.0,
            c: 2.0,
            w: 4.0,
            l_cap: 16,
            seed: 7,
        };
        let kde_cfg = KdeShardConfig {
            kernel: KdeKernel::Angular,
            rows: 8,
            p: 3,
            range: 0,
            width: 0.0,
            eps_eh: 0.1,
            window: 100,
        };
        Shard::new(0, ann_cfg, &kde_cfg, 99)
    }

    #[test]
    fn insert_then_query_roundtrip() {
        let mut s = mk_shard();
        let mut rng = Rng::new(1);
        let pts: Vec<Vec<f32>> = (0..50)
            .map(|_| (0..8).map(|_| rng.gaussian_f32()).collect())
            .collect();
        for p in &pts {
            assert!(s.handle(ShardCmd::Insert(p.clone())));
        }
        let (tx, rx) = channel();
        let batch = Arc::new(vec![pts[3].clone()]);
        s.handle(ShardCmd::AnnBatch(batch, tx));
        let res = rx.recv().unwrap();
        assert_eq!(res.best.len(), 1);
        let ans = res.best[0].as_ref().expect("stored point must be found");
        assert!(ans.dist < 1e-5);
        assert_eq!(ans.shard, 0);
    }

    #[test]
    fn insert_batch_cmd_matches_single_inserts() {
        let mut singles = mk_shard();
        let mut batched = mk_shard();
        let mut rng = Rng::new(77);
        let pts: Vec<Vec<f32>> = (0..60)
            .map(|_| (0..8).map(|_| rng.gaussian_f32()).collect())
            .collect();
        for p in &pts {
            singles.handle(ShardCmd::Insert(p.clone()));
        }
        batched.handle(ShardCmd::InsertBatch(pts.clone()));
        let (tx, rx) = channel();
        batched.handle(ShardCmd::Stats(tx));
        assert_eq!(rx.recv().unwrap().inserted, 60);
        // identical state => identical answers on both paths
        let qb = Arc::new(pts[..10].to_vec());
        let (tx_a, rx_a) = channel();
        singles.handle(ShardCmd::AnnBatch(Arc::clone(&qb), tx_a));
        let (tx_b, rx_b) = channel();
        batched.handle(ShardCmd::AnnBatch(Arc::clone(&qb), tx_b));
        assert_eq!(rx_a.recv().unwrap().best, rx_b.recv().unwrap().best);
        let (tx_a, rx_a) = channel();
        singles.handle(ShardCmd::KdeBatch(Arc::clone(&qb), tx_a));
        let (tx_b, rx_b) = channel();
        batched.handle(ShardCmd::KdeBatch(qb, tx_b));
        assert_eq!(rx_a.recv().unwrap().kernel_sums, rx_b.recv().unwrap().kernel_sums);
    }

    #[test]
    fn kde_batch_reports_population() {
        let mut s = mk_shard();
        let mut rng = Rng::new(2);
        for _ in 0..30 {
            let p: Vec<f32> = (0..8).map(|_| rng.gaussian_f32()).collect();
            s.handle(ShardCmd::Insert(p));
        }
        let (tx, rx) = channel();
        let q: Vec<f32> = (0..8).map(|_| rng.gaussian_f32()).collect();
        s.handle(ShardCmd::KdeBatch(Arc::new(vec![q]), tx));
        let res = rx.recv().unwrap();
        assert_eq!(res.population, 30);
        assert_eq!(res.kernel_sums.len(), 1);
        assert!(res.kernel_sums[0] >= 0.0);
    }

    #[test]
    fn delete_roundtrip() {
        let mut s = mk_shard();
        let p: Vec<f32> = (0..8).map(|i| i as f32).collect();
        s.handle(ShardCmd::Insert(p.clone()));
        let (tx, rx) = channel();
        s.handle(ShardCmd::Delete(p.clone(), tx));
        assert!(rx.recv().unwrap());
        let (tx, rx) = channel();
        s.handle(ShardCmd::Delete(p, tx));
        assert!(!rx.recv().unwrap(), "second delete no-op");
    }

    #[test]
    fn shutdown_stops_loop() {
        let s = mk_shard();
        let (tx, rx) = channel();
        let t = std::thread::spawn(move || s.run(rx));
        tx.send(ShardCmd::Insert(vec![0.5; 8])).unwrap();
        tx.send(ShardCmd::Shutdown).unwrap();
        t.join().unwrap();
    }

    #[test]
    fn stats_reflect_activity() {
        let mut s = mk_shard();
        for i in 0..10 {
            s.handle(ShardCmd::Insert(vec![i as f32; 8]));
        }
        let (tx, rx) = channel();
        s.handle(ShardCmd::Stats(tx));
        let st = rx.recv().unwrap();
        assert_eq!(st.inserted, 10);
        assert_eq!(st.stored, 10, "eta=0 retains all");
        assert!(st.sketch_bytes > 0);
        assert!(st.kde_occupied_cells > 0);
    }
}
