//! Shard worker: owns one partition of the service state — an S-ANN
//! sketch and an SW-AKDE sketch over the points routed to it — and
//! processes commands from its mailbox on a dedicated thread.
//!
//! Durability: the shard thread that APPLIES a mutation also appends its
//! WAL record, so log order equals apply order by construction (no
//! cross-thread sequencing), points shed at the mailbox never reach the
//! log, and the recorded sampler decision makes replay deterministic.

use crate::util::sync::mpsc::{Receiver, Sender};
use crate::util::sync::Arc;

use crate::coordinator::health::{DurabilityLossPolicy, HealthBoard, ShardHealth};
use crate::durability::wal::{WalOp, WalRecord, WalWriter};
use crate::lsh::concat::BoundedHasher;
use crate::lsh::pstable::PStableLsh;
use crate::lsh::srp::SrpLsh;
use crate::lsh::LshFamily;
use crate::sketch::ann::{SAnn, SAnnConfig};
use crate::sketch::snapshot;
use crate::sketch::swakde::SwAkde;
use crate::util::rng::Rng;

use super::protocol::{AnnAnswer, ShardAnnResult, ShardKdeResult};

/// Which LSH kernel the KDE sketch runs (paper evaluates both).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KdeKernel {
    /// SRP / angular with bit-packed cells (range 2^p).
    Angular,
    /// p-stable Euclidean, rehashed to `range` cells.
    Euclidean,
}

/// KDE sketch parameters for a shard.
#[derive(Clone, Debug)]
pub struct KdeShardConfig {
    pub kernel: KdeKernel,
    pub rows: usize,
    pub p: usize,
    /// Cell range for Euclidean (ignored for Angular: 2^p).
    pub range: usize,
    /// p-stable bucket width (Euclidean only).
    pub width: f32,
    pub eps_eh: f64,
    /// Per-shard window (global window / shards under round-robin).
    pub window: u64,
}

/// Commands a shard accepts.
pub enum ShardCmd {
    Insert(Vec<f32>),
    /// Batched native inserts: the shard hashes the whole batch for both
    /// sketches with one GEMM-shaped kernel call each, instead of a loop
    /// of per-point hashing (state-identical to a loop of `Insert`s).
    InsertBatch(Vec<Vec<f32>>),
    /// Insert with precomputed raw ANN hash slots (PJRT bulk-load path).
    InsertWithSlots(Vec<f32>, Vec<i64>),
    /// Batched inserts with precomputed ANN and KDE raw slots — the fully
    /// AOT ingest path: the server hashes whole batches through the PJRT
    /// artifacts, shard threads only update tables and EHs (§Perf it 5).
    InsertBatchSlots(Vec<(Vec<f32>, Vec<i64>, Vec<i64>)>),
    Delete(Vec<f32>, Sender<bool>),
    /// Native ANN: per-query best candidate on this shard.
    AnnBatch(super::protocol::QueryBatch, Sender<ShardAnnResult>),
    /// PJRT ANN: shard-deduplicated candidate pool + per-query indices
    /// into it (the server merges pools and re-ranks via one GEMM).
    AnnCandidates(super::protocol::QueryBatch, Sender<ShardCandidates>),
    /// Like AnnCandidates, but with table keys precomputed by the server
    /// (batched through the PJRT hash artifact): \[query][L\] keys.
    AnnCandidatesKeys(Arc<Vec<Vec<u64>>>, Sender<ShardCandidates>),
    KdeBatch(super::protocol::QueryBatch, Sender<ShardKdeResult>),
    Stats(Sender<ShardStats>),
    /// Durability barrier: flush + fsync the WAL, then reply. Kept
    /// separate from `Stats` so a read-only observability poll never pays
    /// an fsync or mutates WAL state. The reply carries the sync outcome:
    /// a flush ack must never claim durability the disk refused.
    SyncWal(Sender<Result<(), String>>),
    /// Serialize this shard's full sketch state for a checkpoint. The
    /// shard seals (syncs + rotates) its WAL first, so the reply's
    /// high-water mark covers exactly the sealed segments and the
    /// checkpoint coordinator can GC them after a successful write.
    Snapshot(Sender<Result<ShardSnapshot, String>>),
    /// Serialize the LIVE sketch state for replica healing. Unlike
    /// `Snapshot` this never touches the WAL and works in any health
    /// state: a healed copy must converge to the primary's current
    /// state, durable or not, so it cannot be gated on durability.
    CloneState(Sender<CloneImage>),
    /// Test-only: panic the shard thread, simulating a replica crash so
    /// the supervisor's detect-and-heal path can be exercised without
    /// reaching into thread internals.
    #[cfg(any(test, feature = "fault-injection"))]
    Crash,
    Shutdown,
}

impl ShardCmd {
    /// Duplicate a data-only write command for replica fan-out. Commands
    /// carrying reply channels (or control commands) have no meaningful
    /// copy and return `None` — the replica layer handles them per-copy.
    pub(crate) fn clone_write(&self) -> Option<ShardCmd> {
        match self {
            ShardCmd::Insert(x) => Some(ShardCmd::Insert(x.clone())),
            ShardCmd::InsertBatch(b) => Some(ShardCmd::InsertBatch(b.clone())),
            ShardCmd::InsertWithSlots(x, s) => {
                Some(ShardCmd::InsertWithSlots(x.clone(), s.clone()))
            }
            ShardCmd::InsertBatchSlots(b) => Some(ShardCmd::InsertBatchSlots(b.clone())),
            _ => None,
        }
    }

    /// Point count carried by a data write command (0 for reads/control)
    /// — refused-write accounting is point-denominated like `shed`.
    pub(crate) fn write_points(&self) -> u64 {
        match self {
            ShardCmd::Insert(_) | ShardCmd::InsertWithSlots(..) => 1,
            ShardCmd::InsertBatch(b) => b.len() as u64,
            ShardCmd::InsertBatchSlots(b) => b.len() as u64,
            _ => 0,
        }
    }
}

/// One shard's serialized state, cut at a quiesced point in its mailbox
/// order (the snapshot command is processed like any other command, so it
/// reflects exactly the mutations applied — and logged — before it).
pub struct ShardSnapshot {
    /// Every WAL record with `seq <= hwm` is captured by this snapshot.
    pub hwm: u64,
    /// Points applied by this shard at the same instant as `hwm` —
    /// consistent with the sealed log by construction, unlike the global
    /// offer-time counters, which other threads keep incrementing while
    /// the checkpoint is cut.
    pub applied_inserts: u64,
    /// Successful deletes applied at the same instant as `hwm`.
    pub applied_deletes: u64,
    /// `sketch::snapshot::save_sann` image.
    pub sann: Vec<u8>,
    /// `sketch::snapshot::save_swakde` image.
    pub swakde: Vec<u8>,
}

/// A live-state image for replica healing: the same serialized sketches
/// a [`ShardSnapshot`] carries, minus any WAL bookkeeping — rehydrating
/// from it reproduces the source replica's state bit-identically (the
/// sampler Rng and window clock are functions of the mutation sequence,
/// which the image captures in full).
pub struct CloneImage {
    pub applied_inserts: u64,
    pub applied_deletes: u64,
    /// `sketch::snapshot::save_sann` image.
    pub sann: Vec<u8>,
    /// `sketch::snapshot::save_swakde` image.
    pub swakde: Vec<u8>,
}

/// Deduplicated candidate reply: each candidate vector ships once per
/// batch regardless of how many queries hit it.
#[derive(Clone, Debug, Default)]
pub struct ShardCandidates {
    /// Unique candidate ids, aligned with `pool` rows.
    pub ids: Vec<u32>,
    /// Row-major [ids.len(), dim] vector payload.
    pub pool: Vec<f32>,
    /// Per query: indices into `ids`/`pool`.
    pub per_query: Vec<Vec<u32>>,
}

/// Shard-level statistics.
#[derive(Clone, Debug, Default)]
pub struct ShardStats {
    pub stored: usize,
    pub inserted: u64,
    pub deleted: u64,
    pub sketch_bytes: usize,
    pub kde_occupied_cells: usize,
    /// Live EH buckets across the SW-AKDE rows (compaction health: grows
    /// logarithmically with the window when the ε-merge is keeping up).
    pub eh_buckets: usize,
    /// Estimated points inside the sliding window right now.
    pub window_population: u64,
    /// S-ANN sampler offers since startup (denominator of the keep rate).
    pub sampler_seen: u64,
    /// S-ANN sampler keeps since startup; the eviction/thinning rate is
    /// `1 - kept/seen`.
    pub sampler_kept: u64,
}

/// The state each shard thread owns.
pub struct Shard {
    pub index: usize,
    ann: SAnn,
    kde: SwAkde,
    kde_family: Box<dyn LshFamily>,
    stats: ShardStats,
    /// Write-ahead log of applied mutations (None = durability off).
    wal: Option<WalWriter>,
    /// This shard's durability state. A WAL I/O error leaves a hole in
    /// the log: further appends are pointless and a checkpoint cut past
    /// the hole would lie, so the first failure escalates this (per the
    /// policy) and [`Self::snapshot`] refuses while it is not `Healthy`.
    health: ShardHealth,
    /// What a durability failure does to this shard (degrade / refuse
    /// writes / abort).
    policy: DurabilityLossPolicy,
    /// Shared publication side of `health` (primaries only): stats,
    /// Hello, and the write-admission path read it without a mailbox
    /// round-trip.
    board: Option<Arc<HealthBoard>>,
}

impl Shard {
    pub fn new(index: usize, ann_cfg: SAnnConfig, kde_cfg: &KdeShardConfig, seed: u64) -> Self {
        let ann = SAnn::new(SAnnConfig { seed: seed ^ (index as u64) << 8, ..ann_cfg });
        let mut rng = Rng::new(seed.wrapping_mul(0x9E3779B97F4A7C15) ^ index as u64);
        let (kde, kde_family): (SwAkde, Box<dyn LshFamily>) = match kde_cfg.kernel {
            KdeKernel::Angular => {
                let hasher = BoundedHasher::new_packed(kde_cfg.p, kde_cfg.rows);
                let fam = SrpLsh::new(ann.config().dim, hasher.funcs_needed(), &mut rng);
                (
                    SwAkde::with_hasher(hasher, kde_cfg.eps_eh, kde_cfg.window),
                    Box::new(fam),
                )
            }
            KdeKernel::Euclidean => {
                let hasher = BoundedHasher::new(kde_cfg.p, kde_cfg.rows, kde_cfg.range);
                let fam =
                    PStableLsh::new(ann.config().dim, hasher.funcs_needed(), kde_cfg.width, &mut rng);
                (
                    SwAkde::with_hasher(hasher, kde_cfg.eps_eh, kde_cfg.window),
                    Box::new(fam),
                )
            }
        };
        Shard {
            index,
            ann,
            kde,
            kde_family,
            stats: ShardStats::default(),
            wal: None,
            health: ShardHealth::Healthy,
            policy: DurabilityLossPolicy::default(),
            board: None,
        }
    }

    /// Attach the shard's write-ahead log (recovery/startup only, before
    /// the shard moves to its thread).
    pub fn attach_wal(&mut self, wal: WalWriter) {
        self.wal = Some(wal);
        self.health = ShardHealth::Healthy;
    }

    /// Wire this shard (primaries only) to the service's shared health
    /// board and durability-loss policy (startup only, before the shard
    /// moves to its thread).
    pub fn set_health_reporting(&mut self, board: Arc<HealthBoard>, policy: DurabilityLossPolicy) {
        self.board = Some(board);
        self.policy = policy;
    }

    /// This shard's current durability health.
    pub fn health(&self) -> ShardHealth {
        self.health
    }

    /// React to a durability failure: drop the (now holed) WAL, count
    /// the error, escalate health per the configured policy, and log
    /// exactly once per transition. Under `abort` the shard thread
    /// panics — the operator asked for fail-stop over silent data loss.
    fn durability_lost(&mut self, what: &str, err: &str) {
        self.wal = None;
        if let Some(b) = &self.board {
            b.record_wal_error();
        }
        let to = match self.policy {
            DurabilityLossPolicy::Abort => panic!(
                "[shard-{}] {what} failed with on_durability_loss=abort: {err}",
                self.index
            ),
            DurabilityLossPolicy::Degrade => ShardHealth::DurabilityDegraded,
            DurabilityLossPolicy::ReadOnly => ShardHealth::ReadOnly,
        };
        if self.health < to {
            self.health = to;
            crate::obs::log::error(
                "coordinator::shard",
                "durability lost",
                crate::kv!(
                    shard = self.index,
                    what = what,
                    now = self.health,
                    policy = self.policy,
                    err = err
                ),
            );
        }
        if let Some(b) = &self.board {
            b.escalate(self.index, to);
        }
    }

    /// Replace the sketch state with checkpoint-restored images, and the
    /// applied-mutation counts with the checkpoint's (so the NEXT
    /// checkpoint's counts stay correct). The images must have been saved
    /// under the SAME config this shard was constructed with — the S-ANN
    /// family and the KDE family are re-derived from the config seed, so
    /// a shape mismatch means the data_dir belongs to a
    /// differently-configured service.
    pub fn restore_state(
        &mut self,
        ann: SAnn,
        kde: SwAkde,
        applied_inserts: u64,
        applied_deletes: u64,
    ) -> anyhow::Result<()> {
        if ann.config() != self.ann.config() {
            anyhow::bail!(
                "shard {}: checkpoint S-ANN config {:?} does not match the running config {:?}",
                self.index,
                ann.config(),
                self.ann.config()
            );
        }
        let (theirs, mine) = (kde.hasher(), self.kde.hasher());
        if theirs.p != mine.p
            || theirs.rows != mine.rows
            || theirs.range != mine.range
            || theirs.map != mine.map
            || kde.window() != self.kde.window()
            || kde.eps_eh() != self.kde.eps_eh()
        {
            anyhow::bail!(
                "shard {}: checkpoint SW-AKDE shape does not match the running config",
                self.index
            );
        }
        self.ann = ann;
        self.kde = kde;
        self.stats.inserted = applied_inserts;
        self.stats.deleted = applied_deletes;
        Ok(())
    }

    /// Apply one recovered WAL record — the exact code path that applied
    /// it originally, minus randomness: the logged sampler decision is
    /// honored instead of re-drawn, so replay is deterministic.
    pub fn replay(&mut self, rec: &WalRecord) -> anyhow::Result<()> {
        if rec.vec.len() != self.ann.config().dim {
            anyhow::bail!(
                "shard {}: WAL record seq {} has dim {} against a dim-{} shard",
                self.index,
                rec.seq,
                rec.vec.len(),
                self.ann.config().dim
            );
        }
        match rec.op {
            WalOp::Insert { retained } => {
                if retained {
                    self.ann.insert_retained(&rec.vec);
                }
                self.kde.add(self.kde_family.as_ref(), &rec.vec);
                self.stats.inserted += 1;
            }
            WalOp::Delete => {
                if self.ann.delete(&rec.vec) {
                    self.stats.deleted += 1;
                }
            }
        }
        Ok(())
    }

    /// Append one applied mutation to the WAL (no-op with durability off;
    /// an I/O failure escalates health per the policy — see
    /// [`Self::durability_lost`] and [`Self::snapshot`]).
    fn log_wal(&mut self, op: WalOp, x: &[f32]) {
        let err = match self.wal.as_mut() {
            Some(w) => match w.append(op, x) {
                Ok(_) => return,
                Err(e) => e.to_string(),
            },
            None => return,
        };
        self.durability_lost("WAL append", &err);
    }

    /// Seal the WAL and serialize the sketch state for a checkpoint.
    fn snapshot(&mut self) -> Result<ShardSnapshot, String> {
        if self.health != ShardHealth::Healthy {
            return Err(format!(
                "shard {}: {} after a durability failure; refusing to checkpoint past a hole",
                self.index, self.health
            ));
        }
        let seal_err = match self.wal.as_mut() {
            Some(w) => w.sync().and_then(|()| w.rotate()).err(),
            None => None,
        };
        if let Some(e) = seal_err {
            let msg = format!("shard {}: sealing WAL for checkpoint: {e}", self.index);
            self.durability_lost("WAL seal", &e.to_string());
            return Err(msg);
        }
        Ok(ShardSnapshot {
            hwm: self.wal.as_ref().map_or(0, |w| w.last_seq()),
            applied_inserts: self.stats.inserted,
            applied_deletes: self.stats.deleted,
            sann: snapshot::save_sann(&self.ann),
            swakde: snapshot::save_swakde(&self.kde),
        })
    }

    /// ANN hashing parameters of this shard, cloned for the server's
    /// batched PJRT hash path: (projection [dim, k*L], biases, width, k, L).
    pub fn ann_hash_params(&self) -> (Vec<f32>, Vec<f32>, f32, usize, usize) {
        (
            self.ann.family().projection().to_vec(),
            self.ann.family().biases().to_vec(),
            self.ann.family().width(),
            self.ann.params().k,
            self.ann.params().l,
        )
    }

    /// KDE hashing parameters for the server's batched PJRT ingest:
    /// (projection [dim, rows*p], biases-or-empty, width, rows*p, kernel).
    pub fn kde_hash_params(&self) -> (Vec<f32>, Vec<f32>, f32, usize, KdeKernel) {
        let fam = self.kde_family.as_ref();
        let kernel = if fam.as_any_pstable().is_some() {
            KdeKernel::Euclidean
        } else {
            KdeKernel::Angular
        };
        let (bias, w) = match fam.as_any_pstable() {
            Some(ps) => (ps.biases().to_vec(), ps.width()),
            None => (Vec::new(), 0.0),
        };
        (fam.projection().to_vec(), bias, w, fam.n_funcs(), kernel)
    }

    fn intern(
        ids: &mut Vec<u32>,
        pool: &mut Vec<f32>,
        slot_of: &mut std::collections::HashMap<u32, u32>,
        ann: &SAnn,
        cand_ids: Vec<u32>,
    ) -> Vec<u32> {
        let mut idxs = Vec::with_capacity(cand_ids.len());
        for id in cand_ids {
            let slot = *slot_of.entry(id).or_insert_with(|| {
                ids.push(id);
                pool.extend_from_slice(ann.vector(id));
                (ids.len() - 1) as u32
            });
            idxs.push(slot);
        }
        idxs
    }

    pub fn handle(&mut self, cmd: ShardCmd) -> bool {
        match cmd {
            ShardCmd::Insert(x) => {
                let retained = self.ann.insert(&x).is_some();
                self.kde.add(self.kde_family.as_ref(), &x);
                self.stats.inserted += 1;
                self.log_wal(WalOp::Insert { retained }, &x);
            }
            ShardCmd::InsertBatch(batch) => {
                self.stats.inserted += batch.len() as u64;
                let kept = self.ann.insert_batch(&batch);
                let flat: Vec<f32> = batch.iter().flatten().copied().collect();
                self.kde.add_each(self.kde_family.as_ref(), &flat);
                if self.wal.is_some() {
                    for (x, k) in batch.iter().zip(&kept) {
                        self.log_wal(WalOp::Insert { retained: k.is_some() }, x);
                    }
                }
            }
            ShardCmd::InsertWithSlots(x, slots) => {
                // Sampler decision still applies; slots bypass only hashing.
                let retained = self.ann.sampler_keep();
                if retained {
                    self.ann.insert_retained_slots(&x, &slots);
                }
                self.kde.add(self.kde_family.as_ref(), &x);
                self.stats.inserted += 1;
                self.log_wal(WalOp::Insert { retained }, &x);
            }
            ShardCmd::InsertBatchSlots(batch) => {
                for (x, ann_slots, kde_slots) in batch {
                    let retained = self.ann.sampler_keep();
                    if retained {
                        self.ann.insert_retained_slots(&x, &ann_slots);
                    }
                    self.kde.add_slots(&kde_slots);
                    self.stats.inserted += 1;
                    self.log_wal(WalOp::Insert { retained }, &x);
                }
            }
            ShardCmd::Delete(x, reply) => {
                let removed = self.ann.delete(&x);
                if removed {
                    self.stats.deleted += 1;
                    // Logged before the ack travels back to the caller.
                    self.log_wal(WalOp::Delete, &x);
                }
                let _ = reply.send(removed);
            }
            ShardCmd::AnnBatch(batch, reply) => {
                // One batched hashing kernel for the whole query batch.
                let (answers, stats) = self.ann.query_batch_with_stats(&batch);
                let out = ShardAnnResult {
                    best: answers
                        .into_iter()
                        .map(|ans| {
                            ans.map(|(id, dist)| AnnAnswer { shard: self.index, id, dist })
                        })
                        .collect(),
                    scanned: stats.scanned,
                };
                let _ = reply.send(out);
            }
            ShardCmd::AnnCandidates(batch, reply) => {
                let mut out = ShardCandidates::default();
                let mut slot_of: std::collections::HashMap<u32, u32> = Default::default();
                for q in batch.iter() {
                    let ids = self.ann.candidates(q).to_vec();
                    out.per_query.push(Self::intern(&mut out.ids, &mut out.pool, &mut slot_of, &self.ann, ids));
                }
                let _ = reply.send(out);
            }
            ShardCmd::AnnCandidatesKeys(keys, reply) => {
                let mut out = ShardCandidates::default();
                let mut slot_of: std::collections::HashMap<u32, u32> = Default::default();
                for qkeys in keys.iter() {
                    let ids = self.ann.candidates_by_keys(qkeys).to_vec();
                    out.per_query.push(Self::intern(&mut out.ids, &mut out.pool, &mut slot_of, &self.ann, ids));
                }
                let _ = reply.send(out);
            }
            ShardCmd::KdeBatch(batch, reply) => {
                // Flatten once, hash the whole batch with one kernel call.
                let flat: Vec<f32> = batch.iter().flatten().copied().collect();
                let sums = self.kde.query_batch(self.kde_family.as_ref(), &flat);
                let _ = reply.send(ShardKdeResult {
                    kernel_sums: sums,
                    // Point-denominated live population (exact for the
                    // coordinator's per-point ticks; EH-estimated under
                    // add_batch ingest).
                    population: self.kde.population().round() as u64,
                });
            }
            ShardCmd::Stats(reply) => {
                self.stats.stored = self.ann.stored();
                self.stats.sketch_bytes = self.ann.memory_bytes() + self.kde.memory_bytes();
                self.stats.kde_occupied_cells = self.kde.occupied_cells();
                self.stats.eh_buckets = self.kde.eh_buckets();
                self.stats.window_population = self.kde.population().round() as u64;
                self.stats.sampler_seen = self.ann.sampler_seen();
                self.stats.sampler_kept = self.ann.sampler_kept();
                let _ = reply.send(self.stats.clone());
            }
            ShardCmd::SyncWal(reply) => {
                // The service's flush barrier: make every applied record
                // durable, so "flush returned Ok" means "applied AND on
                // disk" under every fsync policy — and a failure reaches
                // the caller instead of being swallowed.
                let res = if self.health != ShardHealth::Healthy {
                    Err(format!(
                        "shard {}: {} after an earlier durability failure",
                        self.index, self.health
                    ))
                } else {
                    match self.wal.as_mut().map(|w| w.sync()) {
                        None | Some(Ok(())) => Ok(()),
                        Some(Err(e)) => {
                            self.durability_lost("WAL sync", &e.to_string());
                            Err(format!("shard {}: WAL sync failed: {e}", self.index))
                        }
                    }
                };
                let _ = reply.send(res);
            }
            ShardCmd::Snapshot(reply) => {
                let _ = reply.send(self.snapshot());
            }
            ShardCmd::CloneState(reply) => {
                let _ = reply.send(CloneImage {
                    applied_inserts: self.stats.inserted,
                    applied_deletes: self.stats.deleted,
                    sann: snapshot::save_sann(&self.ann),
                    swakde: snapshot::save_swakde(&self.kde),
                });
            }
            #[cfg(any(test, feature = "fault-injection"))]
            ShardCmd::Crash => panic!("[shard-{}] injected crash (test command)", self.index),
            ShardCmd::Shutdown => return false,
        }
        true
    }

    /// Run the mailbox loop until Shutdown or channel close.
    pub fn run(mut self, rx: Receiver<ShardCmd>) {
        while let Ok(cmd) = rx.recv() {
            if !self.handle(cmd) {
                break;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::sync::mpsc::channel;
    use crate::util::sync::Arc;

    fn mk_shard() -> Shard {
        let ann_cfg = SAnnConfig {
            dim: 8,
            n_max: 1000,
            eta: 0.0,
            r: 1.0,
            c: 2.0,
            w: 4.0,
            l_cap: 16,
            seed: 7,
        };
        let kde_cfg = KdeShardConfig {
            kernel: KdeKernel::Angular,
            rows: 8,
            p: 3,
            range: 0,
            width: 0.0,
            eps_eh: 0.1,
            window: 100,
        };
        Shard::new(0, ann_cfg, &kde_cfg, 99)
    }

    #[test]
    fn insert_then_query_roundtrip() {
        let mut s = mk_shard();
        let mut rng = Rng::new(1);
        let pts: Vec<Vec<f32>> = (0..50)
            .map(|_| (0..8).map(|_| rng.gaussian_f32()).collect())
            .collect();
        for p in &pts {
            assert!(s.handle(ShardCmd::Insert(p.clone())));
        }
        let (tx, rx) = channel();
        let batch = Arc::new(vec![pts[3].clone()]);
        s.handle(ShardCmd::AnnBatch(batch, tx));
        let res = rx.recv().unwrap();
        assert_eq!(res.best.len(), 1);
        let ans = res.best[0].as_ref().expect("stored point must be found");
        assert!(ans.dist < 1e-5);
        assert_eq!(ans.shard, 0);
    }

    #[test]
    fn insert_batch_cmd_matches_single_inserts() {
        let mut singles = mk_shard();
        let mut batched = mk_shard();
        let mut rng = Rng::new(77);
        let pts: Vec<Vec<f32>> = (0..60)
            .map(|_| (0..8).map(|_| rng.gaussian_f32()).collect())
            .collect();
        for p in &pts {
            singles.handle(ShardCmd::Insert(p.clone()));
        }
        batched.handle(ShardCmd::InsertBatch(pts.clone()));
        let (tx, rx) = channel();
        batched.handle(ShardCmd::Stats(tx));
        assert_eq!(rx.recv().unwrap().inserted, 60);
        // identical state => identical answers on both paths
        let qb = Arc::new(pts[..10].to_vec());
        let (tx_a, rx_a) = channel();
        singles.handle(ShardCmd::AnnBatch(Arc::clone(&qb), tx_a));
        let (tx_b, rx_b) = channel();
        batched.handle(ShardCmd::AnnBatch(Arc::clone(&qb), tx_b));
        assert_eq!(rx_a.recv().unwrap().best, rx_b.recv().unwrap().best);
        let (tx_a, rx_a) = channel();
        singles.handle(ShardCmd::KdeBatch(Arc::clone(&qb), tx_a));
        let (tx_b, rx_b) = channel();
        batched.handle(ShardCmd::KdeBatch(qb, tx_b));
        assert_eq!(rx_a.recv().unwrap().kernel_sums, rx_b.recv().unwrap().kernel_sums);
    }

    #[test]
    fn kde_batch_reports_population() {
        let mut s = mk_shard();
        let mut rng = Rng::new(2);
        for _ in 0..30 {
            let p: Vec<f32> = (0..8).map(|_| rng.gaussian_f32()).collect();
            s.handle(ShardCmd::Insert(p));
        }
        let (tx, rx) = channel();
        let q: Vec<f32> = (0..8).map(|_| rng.gaussian_f32()).collect();
        s.handle(ShardCmd::KdeBatch(Arc::new(vec![q]), tx));
        let res = rx.recv().unwrap();
        assert_eq!(res.population, 30);
        assert_eq!(res.kernel_sums.len(), 1);
        assert!(res.kernel_sums[0] >= 0.0);
    }

    #[test]
    fn delete_roundtrip() {
        let mut s = mk_shard();
        let p: Vec<f32> = (0..8).map(|i| i as f32).collect();
        s.handle(ShardCmd::Insert(p.clone()));
        let (tx, rx) = channel();
        s.handle(ShardCmd::Delete(p.clone(), tx));
        assert!(rx.recv().unwrap());
        let (tx, rx) = channel();
        s.handle(ShardCmd::Delete(p, tx));
        assert!(!rx.recv().unwrap(), "second delete no-op");
    }

    #[test]
    fn shutdown_stops_loop() {
        let s = mk_shard();
        let (tx, rx) = channel();
        let t = std::thread::spawn(move || s.run(rx));
        tx.send(ShardCmd::Insert(vec![0.5; 8])).unwrap();
        tx.send(ShardCmd::Shutdown).unwrap();
        t.join().unwrap();
    }

    #[test]
    fn wal_replay_rebuilds_identical_shard_state() {
        use crate::durability::{wal, FsyncPolicy};
        let dir = std::env::temp_dir().join(format!(
            "sketchd_shard_wal_{}_{}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let mut live = mk_shard();
        live.attach_wal(
            wal::WalWriter::open(&dir, 0, 1, FsyncPolicy::Off, u64::MAX).unwrap(),
        );
        let mut rng = Rng::new(5150);
        let pts: Vec<Vec<f32>> = (0..60)
            .map(|_| (0..8).map(|_| rng.gaussian_f32()).collect())
            .collect();
        // Mixed ingest through every mutation path the WAL covers.
        for p in &pts[..20] {
            live.handle(ShardCmd::Insert(p.clone()));
        }
        live.handle(ShardCmd::InsertBatch(pts[20..55].to_vec()));
        let (dtx, drx) = channel();
        live.handle(ShardCmd::Delete(pts[3].clone(), dtx));
        assert!(drx.recv().unwrap());
        for p in &pts[55..] {
            live.handle(ShardCmd::Insert(p.clone()));
        }
        // SyncWal is the durability barrier (Stats stays side-effect free).
        let (wtx, wrx) = channel();
        live.handle(ShardCmd::SyncWal(wtx));
        wrx.recv().unwrap().unwrap();
        let (stx, srx) = channel();
        live.handle(ShardCmd::Stats(stx));
        let st = srx.recv().unwrap();
        assert_eq!(st.inserted, 60);
        assert_eq!(st.deleted, 1);

        // A fresh shard + full replay must answer identically.
        let mut rec = mk_shard();
        let report = wal::replay(&dir, 0, 0, |r| rec.replay(r)).unwrap();
        assert_eq!(report.applied, 61, "60 inserts + 1 delete");
        assert!(!report.corrupt_tail);
        let qb = Arc::new(pts[..12].to_vec());
        let (tx_a, rx_a) = channel();
        live.handle(ShardCmd::AnnBatch(Arc::clone(&qb), tx_a));
        let (tx_b, rx_b) = channel();
        rec.handle(ShardCmd::AnnBatch(Arc::clone(&qb), tx_b));
        assert_eq!(rx_a.recv().unwrap().best, rx_b.recv().unwrap().best);
        let (tx_a, rx_a) = channel();
        live.handle(ShardCmd::KdeBatch(Arc::clone(&qb), tx_a));
        let (tx_b, rx_b) = channel();
        rec.handle(ShardCmd::KdeBatch(qb, tx_b));
        let (ka, kb) = (rx_a.recv().unwrap(), rx_b.recv().unwrap());
        assert_eq!(ka.kernel_sums, kb.kernel_sums);
        assert_eq!(ka.population, kb.population);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shard_snapshot_seals_wal_and_serializes_state() {
        use crate::durability::{wal, FsyncPolicy};
        use crate::sketch::snapshot::{load_sann, load_swakde};
        let dir = std::env::temp_dir().join(format!(
            "sketchd_shard_snap_{}_{}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let mut s = mk_shard();
        s.attach_wal(wal::WalWriter::open(&dir, 0, 1, FsyncPolicy::Off, u64::MAX).unwrap());
        for i in 0..10 {
            s.handle(ShardCmd::Insert(vec![i as f32; 8]));
        }
        let (tx, rx) = channel();
        s.handle(ShardCmd::Snapshot(tx));
        let snap = rx.recv().unwrap().expect("snapshot must succeed");
        assert_eq!(snap.hwm, 10);
        assert_eq!(load_sann(&snap.sann).unwrap().stored(), 10);
        assert!(load_swakde(&snap.swakde).is_ok());
        // Post-rotation, all sealed segments are ≤ hwm and GC-able.
        assert_eq!(wal::gc_segments(&dir, 0, snap.hwm).unwrap(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn stats_reflect_activity() {
        let mut s = mk_shard();
        for i in 0..10 {
            s.handle(ShardCmd::Insert(vec![i as f32; 8]));
        }
        let (tx, rx) = channel();
        s.handle(ShardCmd::Stats(tx));
        let st = rx.recv().unwrap();
        assert_eq!(st.inserted, 10);
        assert_eq!(st.stored, 10, "eta=0 retains all");
        assert!(st.sketch_bytes > 0);
        assert!(st.kde_occupied_cells > 0);
    }
}
