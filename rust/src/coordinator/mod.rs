//! The L3 coordinator: a thread-per-shard streaming sketch service with
//! routing, bounded ingestion, dynamic query batching, a cloneable
//! calling-thread read path ([`query::QueryPlane`]), and an optional
//! PJRT re-rank stage. See DESIGN.md §1 for the layer diagram.

pub mod backend;
pub mod backpressure;
pub mod batcher;
pub mod handle;
pub mod health;
pub mod protocol;
pub mod query;
pub mod replica;
pub mod router;
pub mod server;
pub mod shard;
pub mod tenants;
pub mod topology;

/// Points per native `InsertBatch` command. One definition shared by the
/// service's batch path and `ServiceHandle` ingest: identical chunking is
/// part of the wire ⇔ in-process state-parity guarantee.
pub(crate) const NATIVE_BATCH_ROWS: usize = 64;

pub use backend::{IngestOutcome, LocalBackend, Pending, RemoteBackend, ShardBackend};
pub use backpressure::{bounded, BoundedSender, OfferOutcome, Overload};
pub use batcher::{BatchPolicy, Batcher};
pub use handle::{ServiceCmd, ServiceHandle};
pub use health::{DurabilityLossPolicy, HealthBoard, ShardHealth};
pub use protocol::{AnnAnswer, ServiceStats, ShardAnnResult, ShardKdeResult};
pub use query::QueryPlane;
pub use replica::{ReadGuard, ReplicaSet};
pub use router::{RoutePolicy, Router};
pub use server::{ConfigError, ServiceConfig, ServiceConfigBuilder, SketchService};
pub use shard::{KdeKernel, KdeShardConfig};
pub use tenants::{tenant_config, CollectionInfo, CollectionSpec, Tenants, DEFAULT_COLLECTION};
pub use topology::Topology;
