//! The L3 coordinator: a thread-per-shard streaming sketch service with
//! routing, bounded ingestion, dynamic query batching, and an optional
//! PJRT re-rank stage. See DESIGN.md §1 for the layer diagram.

pub mod backpressure;
pub mod batcher;
pub mod protocol;
pub mod router;
pub mod server;
pub mod shard;

pub use backpressure::{bounded, BoundedSender, Overload};
pub use batcher::{BatchPolicy, Batcher};
pub use protocol::{AnnAnswer, ServiceStats};
pub use router::{RoutePolicy, Router};
pub use server::{ServiceConfig, SketchService};
pub use shard::{KdeKernel, KdeShardConfig};
