//! Bounds-checked little-endian byte reading, shared by every on-disk
//! decoder (sketch snapshots, durability checkpoints). One implementation
//! of "claimed length vs bytes actually present" so a hardening fix in
//! one format reaches all of them. (The wire protocol keeps its own
//! cursor in `net/frame.rs` — it additionally owns the protocol-version
//! byte and count-amplification rules.)

use anyhow::{bail, Result};

/// Little-endian write helpers — the one implementation every on-disk
/// encoder uses, mirroring [`Reader`] on the write side so a format
/// change cannot drift between writers.
pub fn put_u8(out: &mut Vec<u8>, v: u8) {
    out.push(v);
}

pub fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub fn put_i64(out: &mut Vec<u8>, v: i64) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub fn put_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Fixed-width little-endian read at an offset, validated against the
/// bytes present. The unwrap-free primitive the WAL and checkpoint
/// decoders frame-check with (slice-pattern matching instead of
/// `try_into().unwrap()` — corrupt input must error, never panic).
pub fn u32_le_at(b: &[u8], at: usize) -> Result<u32> {
    match b.get(at..).and_then(|s| s.get(..4)) {
        Some(&[x0, x1, x2, x3]) => Ok(u32::from_le_bytes([x0, x1, x2, x3])),
        _ => bail!("truncated u32 at byte {at}"),
    }
}

/// [`u32_le_at`], eight bytes wide.
pub fn u64_le_at(b: &[u8], at: usize) -> Result<u64> {
    match b.get(at..).and_then(|s| s.get(..8)) {
        Some(&[x0, x1, x2, x3, x4, x5, x6, x7]) => {
            Ok(u64::from_le_bytes([x0, x1, x2, x3, x4, x5, x6, x7]))
        }
        _ => bail!("truncated u64 at byte {at}"),
    }
}

/// Cursor over untrusted input: every read is validated against the
/// bytes present BEFORE any slicing or allocation.
pub struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    pub fn new(b: &'a [u8]) -> Self {
        Reader { b, i: 0 }
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.b.len() - self.i
    }

    /// Current position (error reporting / exact-consumption checks).
    pub fn pos(&self) -> usize {
        self.i
    }

    pub fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if n > self.remaining() {
            bail!("input truncated at byte {}", self.i);
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    /// A length-prefixed block: the claimed length must fit in the bytes
    /// actually present (no allocation from the claim alone).
    pub fn take_len(&mut self, len: u64) -> Result<&'a [u8]> {
        if len > self.remaining() as u64 {
            bail!(
                "claimed block of {len} bytes exceeds the {} present",
                self.remaining()
            );
        }
        self.take(len as usize)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn i64(&mut self) -> Result<i64> {
        Ok(i64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// Error unless every byte was consumed (formats are exact: trailing
    /// garbage means a corrupt or hostile image).
    pub fn finish(&self) -> Result<()> {
        if self.remaining() != 0 {
            bail!("input has {} trailing bytes", self.remaining());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reads_are_bounds_checked() {
        let bytes = 7u64.to_le_bytes();
        let mut r = Reader::new(&bytes);
        assert_eq!(r.u64().unwrap(), 7);
        assert!(r.u8().is_err(), "past the end");
        r.finish().unwrap();
    }

    #[test]
    fn offset_reads_validate_bounds() {
        let mut bytes = Vec::new();
        put_u32(&mut bytes, 0xDEAD_BEEF);
        put_u64(&mut bytes, 42);
        assert_eq!(u32_le_at(&bytes, 0).unwrap(), 0xDEAD_BEEF);
        assert_eq!(u64_le_at(&bytes, 4).unwrap(), 42);
        assert!(u32_le_at(&bytes, 9).is_err(), "only 3 bytes left");
        assert!(u64_le_at(&bytes, usize::MAX).is_err(), "offset past the end");
    }

    #[test]
    fn hostile_block_length_is_rejected() {
        let mut r = Reader::new(&[1, 2, 3]);
        assert!(r.take_len(u64::MAX).is_err());
        assert_eq!(r.take_len(3).unwrap(), &[1, 2, 3]);
    }

    #[test]
    fn finish_rejects_trailing_bytes() {
        let mut r = Reader::new(&[0; 9]);
        let _ = r.u64().unwrap();
        assert!(r.finish().is_err());
        let _ = r.u8().unwrap();
        r.finish().unwrap();
    }
}
