//! Minimal JSON substrate (no `serde`/`serde_json` offline).
//!
//! Covers exactly what this repo needs: parsing the artifact
//! `manifest.json`/`goldens.json` written by `python/compile/aot.py`, and
//! writing bench/experiment result files. Full RFC 8259 value model, UTF-8
//! input, `\uXXXX` escapes (incl. surrogate pairs).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset.
#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {at}: {msg}")]
pub struct JsonError {
    pub at: usize,
    pub msg: String,
}

impl Json {
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: src.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    /// `obj["a"]["b"][2]`-style traversal for tests and loaders.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for p in path {
            cur = match (cur, p.parse::<usize>()) {
                (Json::Arr(a), Ok(i)) => a.get(i)?,
                (obj, _) => obj.get(p)?,
            };
        }
        Some(cur)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { at: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            out.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{0008}'),
                        b'f' => s.push('\u{000C}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hi = self.hex4()?;
                            let cp = if (0xD800..0xDC00).contains(&hi) {
                                // surrogate pair
                                if self.peek() == Some(b'\\') {
                                    self.i += 1;
                                    self.eat(b'u')?;
                                    let lo = self.hex4()?;
                                    0x10000 + (((hi - 0xD800) as u32) << 10) + (lo - 0xDC00) as u32
                                } else {
                                    return Err(self.err("lone surrogate"));
                                }
                            } else {
                                hi as u32
                            };
                            s.push(char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?);
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                _ => {
                    // raw UTF-8 byte run: copy verbatim up to next " or backslash
                    let start = self.i - 1;
                    while let Some(n) = self.peek() {
                        if n == b'"' || n == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u16, JsonError> {
        if self.i + 4 > self.b.len() {
            return Err(self.err("short \\u escape"));
        }
        let s = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| self.err("bad hex"))?;
        let v = u16::from_str_radix(s, 16).map_err(|_| self.err("bad hex"))?;
        self.i += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

// ------------------------------------------------------------------ writer

fn escape_into(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write_into(&mut s);
        f.write_str(&s)
    }
}

impl Json {
    pub fn write_into(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => escape_into(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write_into(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    escape_into(k, out);
                    out.push(':');
                    v.write_into(out);
                }
                out.push('}');
            }
        }
    }
}

/// Builder helpers for result files.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}
pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
    Json::Arr(items.into_iter().collect())
}
pub fn num(n: f64) -> Json {
    Json::Num(n)
}
pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}
pub fn nums<'a, I: IntoIterator<Item = &'a f64>>(it: I) -> Json {
    Json::Arr(it.into_iter().map(|&x| Json::Num(x)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -12.5e2 ").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.at(&["a", "2", "b"]).unwrap().as_str(), Some("x"));
        assert_eq!(v.at(&["a", "0"]).unwrap().as_f64(), Some(1.0));
        assert_eq!(v.get("c"), Some(&Json::Null));
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let v = Json::parse(r#""a\n\t\"\\ é 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\ é 😀");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("\"\\x\"").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,-3],"b":false,"s":"q\"uote","z":null}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn integers_render_without_fraction() {
        assert_eq!(num(3.0).to_string(), "3");
        assert_eq!(num(3.25).to_string(), "3.25");
    }

    #[test]
    fn parses_large_flat_array_fast() {
        let src = format!("[{}]", (0..10_000).map(|i| i.to_string()).collect::<Vec<_>>().join(","));
        let v = Json::parse(&src).unwrap();
        assert_eq!(v.as_arr().unwrap().len(), 10_000);
    }

    #[test]
    fn builder_helpers() {
        let v = obj(vec![("k", arr(vec![num(1.0), s("two")]))]);
        assert_eq!(v.to_string(), r#"{"k":[1,"two"]}"#);
    }
}
