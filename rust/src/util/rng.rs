//! Deterministic PRNG substrate (no `rand` crate offline).
//!
//! `Rng` is xoshiro256** seeded through splitmix64 — fast, high-quality,
//! and reproducible across runs and platforms. Every experiment takes an
//! explicit seed; sub-streams are derived with [`Rng::fork`] so concurrent
//! shards never share a sequence.

/// xoshiro256** generator with gaussian/cauchy/uniform samplers.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box–Muller deviate.
    gauss_spare: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed the generator; any u64 (including 0) is a valid seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Derive an independent sub-stream (e.g. per shard / per hash table).
    pub fn fork(&mut self, tag: u64) -> Rng {
        let mixed = self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        Rng::new(mixed)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 high bits -> [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn uniform_f32(&mut self) -> f32 {
        self.uniform() as f32
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Bernoulli(p) draw.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(v) = self.gauss_spare.take() {
            return v;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.gauss_spare = Some(r * s);
            return r * c;
        }
    }

    #[inline]
    pub fn gaussian_f32(&mut self) -> f32 {
        self.gaussian() as f32
    }

    /// Standard Cauchy deviate (1-stable; would be used for L1 LSH).
    pub fn cauchy(&mut self) -> f64 {
        (std::f64::consts::PI * (self.uniform() - 0.5)).tan()
    }

    /// Poisson(lambda) draw. Knuth for small lambda, normal approx above 64.
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda < 64.0 {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.uniform();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            // Normal approximation with continuity correction; exact enough
            // for the ball-occupancy simulations (lambda >= 64).
            let x = lambda + lambda.sqrt() * self.gaussian() + 0.5;
            if x < 0.0 {
                0
            } else {
                x as u64
            }
        }
    }

    /// Fill a slice with standard gaussians (projection matrices).
    pub fn fill_gaussian_f32(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.gaussian_f32();
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample k distinct indices from [0, n) (k <= n), order unspecified.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        // Floyd's algorithm: O(k) expected.
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.below((j + 1) as u64) as usize;
            let pick = if chosen.contains(&t) { j } else { t };
            chosen.insert(pick);
            out.push(pick);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_in_unit_interval_and_roughly_uniform() {
        let mut r = Rng::new(7);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_unbiased_small_range() {
        let mut r = Rng::new(3);
        let mut counts = [0u32; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "counts={counts:?}");
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut m1, mut m2) = (0.0, 0.0);
        for _ in 0..n {
            let g = r.gaussian();
            m1 += g;
            m2 += g * g;
        }
        m1 /= n as f64;
        m2 /= n as f64;
        assert!(m1.abs() < 0.01, "mean={m1}");
        assert!((m2 - 1.0).abs() < 0.02, "var={m2}");
    }

    #[test]
    fn poisson_mean_small_and_large() {
        let mut r = Rng::new(13);
        for &lambda in &[0.5, 3.0, 20.0, 200.0] {
            let n = 20_000;
            let mut sum = 0.0;
            for _ in 0..n {
                sum += r.poisson(lambda) as f64;
            }
            let mean = sum / n as f64;
            assert!(
                (mean - lambda).abs() < lambda.max(1.0) * 0.05,
                "lambda={lambda} mean={mean}"
            );
        }
    }

    #[test]
    fn bernoulli_rate() {
        let mut r = Rng::new(17);
        let hits = (0..100_000).filter(|_| r.bernoulli(0.3)).count();
        assert!((hits as f64 / 100_000.0 - 0.3).abs() < 0.01);
    }

    #[test]
    fn fork_is_independent() {
        let mut base = Rng::new(5);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut r = Rng::new(23);
        let s = r.sample_indices(100, 30);
        assert_eq!(s.len(), 30);
        let set: std::collections::HashSet<_> = s.iter().collect();
        assert_eq!(set.len(), 30);
        assert!(s.iter().all(|&i| i < 100));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(29);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn cauchy_median_near_zero() {
        let mut r = Rng::new(31);
        let mut v: Vec<f64> = (0..10_001).map(|_| r.cauchy()).collect();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert!(v[5000].abs() < 0.05, "median={}", v[5000]);
    }
}
