//! The crate's single synchronization facade.
//!
//! Every concurrent module imports its primitives from here instead of
//! from `std::sync` (enforced by `cargo run -p xtask -- lint`, lint
//! `sync-facade`). Under a normal build the re-exports are exactly
//! `std::sync`; under `RUSTFLAGS="--cfg loom"` they swap to
//! `loom::sync`, so the loom models in `tests/loom_models.rs` exercise
//! the *same* `ReplicaSet`/`HealthBoard`/coalescer code the server runs,
//! with preemption points injected at every atomic and lock operation.
//!
//! Channels are the one deliberate exception: loom does not model
//! `mpsc` (neither the real crate nor the vendored stub), so [`mpsc`]
//! is pinned to std under every cfg and the models treat mailboxes as
//! opaque. The interleavings under test are the ones *around* the
//! channels — admission gates, depth gauges, health escalation — which
//! is where the hand-rolled atomics live.

#[cfg(not(loom))]
pub use std::sync::{Arc, Condvar, Mutex, RwLock, Weak};

#[cfg(loom)]
pub use loom::sync::{Arc, Condvar, Mutex, RwLock, Weak};

// Guard and error types are std's under both cfgs: the vendored loom
// wraps std primitives and hands back their guards unchanged.
pub use std::sync::{
    LockResult, MutexGuard, PoisonError, RwLockReadGuard, RwLockWriteGuard, TryLockError,
};

pub mod atomic {
    //! Atomic types + `Ordering`, cfg-switched like the lock types.
    #[cfg(not(loom))]
    pub use std::sync::atomic::{
        AtomicBool, AtomicU32, AtomicU64, AtomicU8, AtomicUsize, Ordering,
    };

    #[cfg(loom)]
    pub use loom::sync::atomic::{
        AtomicBool, AtomicU32, AtomicU64, AtomicU8, AtomicUsize, Ordering,
    };
}

pub mod mpsc {
    //! Std channels under every cfg (loom does not model them).
    pub use std::sync::mpsc::*;
}

// `OnceLock` is pinned to std under both cfgs, like `mpsc`: the vendored
// loom does not model it, and its one consumer (the `obs::log` global
// sink) is write-once process configuration, not a racing interleaving
// the models need to explore.
pub use std::sync::OnceLock;

/// Lock a mutex, recovering from poisoning. Every mutex in this crate
/// guards plain data whose invariants hold between operations (pending
/// query batches, a fan-out order token, an injected-fault slot), so a
/// panic on another thread mid-critical-section cannot leave torn state
/// worth refusing — propagating the poison would only convert one
/// thread's panic into a crate-wide denial of service.
pub fn lock_unpoisoned<T: ?Sized>(mutex: &Mutex<T>) -> MutexGuard<'_, T> {
    match mutex.lock() {
        Ok(guard) => guard,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// [`lock_unpoisoned`], for read-locking an `RwLock`.
pub fn read_unpoisoned<T: ?Sized>(lock: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    match lock.read() {
        Ok(guard) => guard,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// [`lock_unpoisoned`], for write-locking an `RwLock`.
pub fn write_unpoisoned<T: ?Sized>(lock: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    match lock.write() {
        Ok(guard) => guard,
        Err(poisoned) => poisoned.into_inner(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lock_unpoisoned_recovers_after_a_panicking_holder() {
        let m = Arc::new(Mutex::new(7u32));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock();
            panic!("poison the lock");
        })
        .join();
        assert_eq!(*lock_unpoisoned(&m), 7, "data survives the poison");
        *lock_unpoisoned(&m) = 8;
        assert_eq!(*lock_unpoisoned(&m), 8);
    }

    #[test]
    fn rwlock_helpers_round_trip() {
        let l = RwLock::new(vec![1, 2, 3]);
        assert_eq!(read_unpoisoned(&l).len(), 3);
        write_unpoisoned(&l).push(4);
        assert_eq!(read_unpoisoned(&l).len(), 4);
    }
}
