//! Small statistics helpers shared by metrics, benches and tests.

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population variance.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Exact percentile (nearest-rank on a sorted copy), q in [0, 100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((q / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Median of means over `groups` near-equal chunks (RACE-style robust
/// estimator). When `len % groups != 0` the remainder folds into the
/// FINAL group — every sample participates. (The old exact-`per` chunking
/// silently dropped the last `len % groups` samples, biasing the
/// estimator whenever the row count wasn't a multiple of the group
/// count.)
pub fn median_of_means(xs: &[f64], groups: usize) -> f64 {
    if xs.is_empty() || groups == 0 {
        return 0.0;
    }
    let g = groups.min(xs.len());
    let per = xs.len() / g;
    let means: Vec<f64> = (0..g)
        .map(|i| {
            let start = i * per;
            let end = if i + 1 == g { xs.len() } else { start + per };
            mean(&xs[start..end])
        })
        .collect();
    median(&means)
}

/// Relative error |est - truth| / truth (truth must be > 0).
pub fn relative_error(est: f64, truth: f64) -> f64 {
    debug_assert!(truth > 0.0);
    (est - truth).abs() / truth
}

/// log10 with a floor to keep plots finite when error hits zero.
pub fn log10_floored(x: f64) -> f64 {
    x.max(1e-12).log10()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_variance() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((variance(&xs) - 1.25).abs() < 1e-12);
        assert!((stddev(&xs) - 1.25f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentile_basics() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(median(&xs), 3.0);
    }

    #[test]
    fn empty_slices_do_not_panic() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(median_of_means(&[], 4), 0.0);
    }

    #[test]
    fn median_of_means_resists_outliers() {
        let mut xs = vec![1.0; 30];
        xs.push(1000.0);
        let mom = median_of_means(&xs, 5);
        assert!(mom < 10.0, "mom={mom}");
    }

    #[test]
    fn median_of_means_uses_every_sample() {
        // len=7, groups=3 → chunks [0,0], [10,10], [0,0,100]: the tail
        // sample (100) must fold into the final group. The old exact-
        // `per` chunking dropped it, producing group means [0, 10, 0]
        // and a median of 0 — an estimator that never saw the heaviest
        // sample.
        let xs = [0.0, 0.0, 10.0, 10.0, 0.0, 0.0, 100.0];
        let mom = median_of_means(&xs, 3);
        assert!((mom - 10.0).abs() < 1e-12, "mom={mom}");
        // Exact division is unchanged: [1,2],[3,4],[5,6] → median 3.5.
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        assert!((median_of_means(&xs, 3) - 3.5).abs() < 1e-12);
        // groups > len degenerates to one sample per group, all used.
        let xs = [7.0, 9.0];
        assert_eq!(median_of_means(&xs, 10), median(&xs));
    }

    #[test]
    fn relative_error_symmetric_in_magnitude() {
        assert!((relative_error(1.2, 1.0) - 0.2).abs() < 1e-12);
        assert!((relative_error(0.8, 1.0) - 0.2).abs() < 1e-12);
    }
}
