//! Substrate utilities built in-repo (offline environment; see DESIGN.md §2).

pub mod bytes;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod sync;

/// Lanes of the unrolled reductions below. Eight f32 accumulators break
/// the sequential-FMA dependency chain so LLVM can keep the loop in SIMD
/// registers; every batched hashing kernel funnels through these, so the
/// accumulation order here IS the crate's hashing semantics (batch and
/// single-point paths must agree bit-for-bit).
const LANES: usize = 8;

#[inline]
fn reduce(acc: [f32; LANES], tail: f32) -> f32 {
    ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7])) + tail
}

/// Squared L2 distance between two equal-length f32 slices.
#[inline]
pub fn l2_sq(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let ca = a.chunks_exact(LANES);
    let cb = b.chunks_exact(LANES);
    let (ra, rb) = (ca.remainder(), cb.remainder());
    let mut acc = [0.0f32; LANES];
    for (xa, xb) in ca.zip(cb) {
        for i in 0..LANES {
            let d = xa[i] - xb[i];
            acc[i] += d * d;
        }
    }
    let mut tail = 0.0f32;
    for (x, y) in ra.iter().zip(rb) {
        let d = x - y;
        tail += d * d;
    }
    reduce(acc, tail)
}

/// L2 distance.
#[inline]
pub fn l2(a: &[f32], b: &[f32]) -> f32 {
    l2_sq(a, b).sqrt()
}

/// Dot product.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let ca = a.chunks_exact(LANES);
    let cb = b.chunks_exact(LANES);
    let (ra, rb) = (ca.remainder(), cb.remainder());
    let mut acc = [0.0f32; LANES];
    for (xa, xb) in ca.zip(cb) {
        for i in 0..LANES {
            acc[i] += xa[i] * xb[i];
        }
    }
    let mut tail = 0.0f32;
    for (x, y) in ra.iter().zip(rb) {
        tail += x * y;
    }
    reduce(acc, tail)
}

/// Cosine similarity (0 when either vector is zero).
pub fn cosine(a: &[f32], b: &[f32]) -> f32 {
    let na = dot(a, a).sqrt();
    let nb = dot(b, b).sqrt();
    if na == 0.0 || nb == 0.0 {
        return 0.0;
    }
    (dot(a, b) / (na * nb)).clamp(-1.0, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn distances() {
        let a = [0.0f32, 0.0];
        let b = [3.0f32, 4.0];
        assert_eq!(l2_sq(&a, &b), 25.0);
        assert_eq!(l2(&a, &b), 5.0);
    }

    #[test]
    fn unrolled_reductions_cover_all_lengths() {
        // Lengths straddling the 8-lane boundary: the lane + tail split must
        // see every element exactly once.
        for len in 0..=33usize {
            let a: Vec<f32> = (0..len).map(|i| (i as f32 + 1.0) * 0.5).collect();
            let b: Vec<f32> = (0..len).map(|i| (i as f32) - 3.0).collect();
            let want_dot: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            let want_sq: f32 = a.iter().zip(&b).map(|(x, y)| (x - y) * (x - y)).sum();
            assert!((dot(&a, &b) - want_dot).abs() <= 1e-3 * want_dot.abs().max(1.0), "len={len}");
            assert!((l2_sq(&a, &b) - want_sq).abs() <= 1e-3 * want_sq.max(1.0), "len={len}");
        }
    }

    #[test]
    fn cosine_bounds_and_zero() {
        let a = [1.0f32, 0.0];
        assert_eq!(cosine(&a, &a), 1.0);
        assert_eq!(cosine(&a, &[-1.0, 0.0]), -1.0);
        assert_eq!(cosine(&a, &[0.0, 0.0]), 0.0);
    }
}
