//! Substrate utilities built in-repo (offline environment; see DESIGN.md §2).

pub mod json;
pub mod proptest;
pub mod rng;
pub mod stats;

/// Squared L2 distance between two equal-length f32 slices.
#[inline]
pub fn l2_sq(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f32;
    for i in 0..a.len() {
        let d = a[i] - b[i];
        acc += d * d;
    }
    acc
}

/// L2 distance.
#[inline]
pub fn l2(a: &[f32], b: &[f32]) -> f32 {
    l2_sq(a, b).sqrt()
}

/// Dot product.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f32;
    for i in 0..a.len() {
        acc += a[i] * b[i];
    }
    acc
}

/// Cosine similarity (0 when either vector is zero).
pub fn cosine(a: &[f32], b: &[f32]) -> f32 {
    let na = dot(a, a).sqrt();
    let nb = dot(b, b).sqrt();
    if na == 0.0 || nb == 0.0 {
        return 0.0;
    }
    (dot(a, b) / (na * nb)).clamp(-1.0, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn distances() {
        let a = [0.0f32, 0.0];
        let b = [3.0f32, 4.0];
        assert_eq!(l2_sq(&a, &b), 25.0);
        assert_eq!(l2(&a, &b), 5.0);
    }

    #[test]
    fn cosine_bounds_and_zero() {
        let a = [1.0f32, 0.0];
        assert_eq!(cosine(&a, &a), 1.0);
        assert_eq!(cosine(&a, &[-1.0, 0.0]), -1.0);
        assert_eq!(cosine(&a, &[0.0, 0.0]), 0.0);
    }
}
