//! Minimal property-testing harness (proptest is unavailable offline).
//!
//! A property is a closure over a [`Gen`] (a seeded random-input source).
//! [`check`] runs it for `cases` seeds and, on failure, re-runs the failing
//! seed to confirm and reports it so the case can be pinned as a regression
//! test. No structural shrinking — generators are encouraged to draw sizes
//! small-biased instead (see [`Gen::size`]).

use super::rng::Rng;

/// Seeded input source handed to properties.
pub struct Gen {
    pub rng: Rng,
    pub seed: u64,
}

impl Gen {
    /// Small-biased size in [lo, hi]: half the mass below the 25% point.
    pub fn size(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        let span = (hi - lo + 1) as u64;
        if self.rng.bernoulli(0.5) {
            lo + self.rng.below(span.div_ceil(4).max(1)) as usize
        } else {
            lo + self.rng.below(span) as usize
        }
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.below((hi - lo + 1) as u64) as usize
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.bernoulli(0.5)
    }

    /// Random f32 vector with entries ~ N(0, scale²).
    pub fn vector(&mut self, dim: usize, scale: f32) -> Vec<f32> {
        (0..dim).map(|_| self.rng.gaussian_f32() * scale).collect()
    }

    /// Random 0/1 stream of the given length with P(1) = p.
    pub fn bit_stream(&mut self, len: usize, p: f64) -> Vec<bool> {
        (0..len).map(|_| self.rng.bernoulli(p)).collect()
    }
}

/// Run `prop` for `cases` derived seeds; panic with the failing seed.
///
/// `name` labels the property in the failure message. Properties signal
/// failure by returning `Err(description)`.
pub fn check<F>(name: &str, cases: u64, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    check_seeded(name, 0xC0FFEE, cases, &mut prop);
}

/// Like [`check`] with an explicit base seed (to pin regressions).
pub fn check_seeded<F>(name: &str, base_seed: u64, cases: u64, prop: &mut F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut g = Gen { rng: Rng::new(seed), seed };
        if let Err(msg) = prop(&mut g) {
            // Confirm reproducibility before reporting.
            let mut g2 = Gen { rng: Rng::new(seed), seed };
            let confirmed = prop(&mut g2).is_err();
            panic!(
                "property '{name}' failed (case {case}, seed {seed:#x}, \
                 reproducible={confirmed}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check("trivial", 50, |_| {
            n += 1;
            Ok(())
        });
        assert_eq!(n, 50);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_panics_with_seed() {
        check("fails", 10, |g| {
            if g.usize_in(0, 100) <= 100 {
                Err("always".into())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn size_is_small_biased() {
        let mut g = Gen { rng: Rng::new(1), seed: 1 };
        let small = (0..1000).filter(|_| g.size(0, 100) <= 25).count();
        assert!(small > 400, "small={small}");
    }

    #[test]
    fn bit_stream_rate() {
        let mut g = Gen { rng: Rng::new(2), seed: 2 };
        let ones = g.bit_stream(20_000, 0.25).iter().filter(|&&b| b).count();
        assert!((ones as f64 / 20_000.0 - 0.25).abs() < 0.02);
    }
}
