//! Shared harness for the `benches/` targets (criterion is unavailable
//! offline; this provides timing, aligned table printing and JSON dumps).
//!
//! Every figure bench prints the paper's rows/series as a table and writes
//! the same data to `bench_out/<name>.json` for downstream plotting.

use std::time::Instant;

use crate::util::json::{arr, num, obj, s, Json};

/// Time a closure: `warmup` throwaway calls then `iters` timed calls;
/// returns mean nanoseconds per call.
pub fn time_ns<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> f64 {
    for _ in 0..warmup {
        f();
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_nanos() as f64 / iters.max(1) as f64
}

/// Aligned-table printer for bench output.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|h| h.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let joined: Vec<String> = cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect();
            println!("  {}", joined.join("  "));
        };
        line(&self.headers);
        let total: usize = widths.iter().sum::<usize>() + 2 * widths.len();
        println!("  {}", "-".repeat(total));
        for row in &self.rows {
            line(row);
        }
    }
}

/// Collects one figure's series and dumps them to bench_out/<name>.json.
pub struct FigureOutput {
    name: String,
    series: Vec<(String, Vec<(f64, f64)>)>,
    meta: Vec<(String, String)>,
}

impl FigureOutput {
    pub fn new(name: &str) -> Self {
        FigureOutput { name: name.to_string(), series: Vec::new(), meta: Vec::new() }
    }

    pub fn meta(&mut self, key: &str, value: &str) {
        self.meta.push((key.to_string(), value.to_string()));
    }

    pub fn push(&mut self, series: &str, x: f64, y: f64) {
        if let Some(e) = self.series.iter_mut().find(|(n, _)| n == series) {
            e.1.push((x, y));
        } else {
            self.series.push((series.to_string(), vec![(x, y)]));
        }
    }

    pub fn series(&self, name: &str) -> Option<&[(f64, f64)]> {
        self.series.iter().find(|(n, _)| n == name).map(|(_, v)| v.as_slice())
    }

    /// Write bench_out/<name>.json.
    pub fn save(&self) -> std::io::Result<std::path::PathBuf> {
        std::fs::create_dir_all("bench_out")?;
        let path = std::path::PathBuf::from(format!("bench_out/{}.json", self.name));
        let series_json: Vec<Json> = self
            .series
            .iter()
            .map(|(name, pts)| {
                obj(vec![
                    ("name", s(name)),
                    ("x", arr(pts.iter().map(|(x, _)| num(*x)))),
                    ("y", arr(pts.iter().map(|(_, y)| num(*y)))),
                ])
            })
            .collect();
        let meta_json = obj(self.meta.iter().map(|(k, v)| (k.as_str(), s(v))).collect());
        let root = obj(vec![
            ("figure", s(&self.name)),
            ("meta", meta_json),
            ("series", Json::Arr(series_json)),
        ]);
        std::fs::write(&path, root.to_string())?;
        Ok(path)
    }
}

/// `--full` on the bench command line selects paper-scale parameters.
pub fn full_scale() -> bool {
    std::env::args().any(|a| a == "--full")
}

/// Standard bench banner.
pub fn banner(fig: &str, what: &str) {
    println!("\n=== {fig}: {what} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_ns_is_positive() {
        let ns = time_ns(2, 10, || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert!(ns > 0.0);
    }

    #[test]
    fn figure_output_roundtrip() {
        let mut f = FigureOutput::new("test_fig");
        f.meta("dataset", "unit");
        f.push("a", 1.0, 2.0);
        f.push("a", 2.0, 3.0);
        f.push("b", 1.0, 9.0);
        assert_eq!(f.series("a").unwrap().len(), 2);
        assert_eq!(f.series("b").unwrap(), &[(1.0, 9.0)]);
        assert!(f.series("c").is_none());
    }

    #[test]
    fn table_prints_without_panic() {
        let mut t = Table::new(&["a", "long_header"]);
        t.row(vec!["1".into(), "2".into()]);
        t.print();
    }
}
