//! The collection manifest — the durable registry of named collections.
//!
//! Multi-tenant recovery needs one more fact than the per-collection
//! WAL/checkpoint pair can carry: *which collections exist at all*, and
//! with what shape (dim, shards, replicas, sketch params). That lives
//! here, as `collections.manifest` at the ROOT of the data dir, in the
//! same TOML subset the experiment configs use ([`ConfigFile`]): one
//! top-level `next_id` counter plus one `[name]` section per named
//! collection. The default collection (id 0) is NOT listed — it is
//! implied by the service's own config and keeps the root-dir layout a
//! v5 single-tenant server would have written, so pre-tenancy data dirs
//! recover unchanged.
//!
//! Writes are atomic in the WAL sense: temp file in the same directory,
//! fsync, rename over the live name, fsync the directory. A crash
//! between `CreateCollection` being acked and its first WAL append can
//! therefore never lose the collection's *existence*, and a torn write
//! can never produce a half-parsed manifest (the old file survives the
//! rename intact).
//!
//! Collection ids are never reused: `next_id` is monotonic across
//! create/drop cycles, so a stale client holding a dropped collection's
//! id gets "unknown collection", never someone else's data.

use std::fs;
use std::io::Write;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::config::file::ConfigFile;
use crate::coordinator::CollectionSpec;

use super::sync_dir;

/// Manifest file name, directly under the root data dir (sibling of the
/// default collection's `wal-*` / `checkpoint-*` files).
pub const MANIFEST_FILE: &str = "collections.manifest";

/// One named collection's durable identity + shape.
#[derive(Clone, Debug, PartialEq)]
pub struct ManifestEntry {
    pub id: u32,
    pub name: String,
    pub spec: CollectionSpec,
}

/// Everything the tenant registry must rehydrate on restart.
#[derive(Clone, Debug, PartialEq)]
pub struct Manifest {
    /// Next collection id to hand out (ids are never reused).
    pub next_id: u32,
    pub entries: Vec<ManifestEntry>,
}

impl Default for Manifest {
    fn default() -> Self {
        // Id 0 is the default collection, so named ids start at 1.
        Manifest { next_id: 1, entries: Vec::new() }
    }
}

impl Manifest {
    /// Load the manifest from `root`, or the empty default if none was
    /// ever written (a fresh dir, or a v5 single-tenant dir).
    pub fn load(root: &Path) -> Result<Manifest> {
        let path = root.join(MANIFEST_FILE);
        let src = match fs::read_to_string(&path) {
            Ok(s) => s,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                return Ok(Manifest::default())
            }
            Err(e) => return Err(e).context(format!("reading {}", path.display())),
        };
        let f = ConfigFile::parse(&src).context(format!("parsing {}", path.display()))?;
        let next_id: u32 = f
            .get("", "next_id")
            .context("manifest is missing top-level next_id")?
            .parse()
            .context("manifest next_id is not a u32")?;
        let mut entries = Vec::new();
        for name in f.sections() {
            if name.is_empty() {
                continue; // the top-level pseudo-section holding next_id
            }
            entries.push(ManifestEntry {
                id: section_u32(&f, name, "id")?,
                name: name.to_string(),
                spec: CollectionSpec {
                    dim: section_u32(&f, name, "dim")?,
                    shards: section_u32(&f, name, "shards")?,
                    replicas: section_u32(&f, name, "replicas")?,
                    n_max: section_u64(&f, name, "n_max")?,
                    window: section_u64(&f, name, "window")?,
                    eta: section_f64(&f, name, "eta")?,
                    overload: match f.get(name, "overload") {
                        Some("shed") => 1,
                        Some("block") | None => 0,
                        Some(other) => {
                            bail!("collection [{name}]: overload must be block|shed, got {other}")
                        }
                    },
                    seed: section_u64(&f, name, "seed")?,
                },
            });
        }
        for e in &entries {
            if e.id == 0 {
                bail!("collection [{}]: id 0 is reserved for the default collection", e.name);
            }
            if e.id >= next_id {
                bail!("collection [{}]: id {} >= next_id {next_id}", e.name, e.id);
            }
        }
        Ok(Manifest { next_id, entries })
    }

    /// Atomically replace the manifest at `root` (temp + fsync + rename
    /// + dir fsync). The previous manifest survives any crash intact.
    pub fn store(&self, root: &Path) -> Result<()> {
        let mut body = String::new();
        body.push_str("# Named-collection registry; rewritten atomically on every\n");
        body.push_str("# create/drop. The default collection (id 0) is implicit.\n");
        body.push_str(&format!("next_id = {}\n", self.next_id));
        for e in &self.entries {
            body.push_str(&format!(
                "\n[{}]\nid = {}\ndim = {}\nshards = {}\nreplicas = {}\nn_max = {}\n\
                 window = {}\neta = {}\noverload = \"{}\"\nseed = {}\n",
                e.name,
                e.id,
                e.spec.dim,
                e.spec.shards,
                e.spec.replicas,
                e.spec.n_max,
                e.spec.window,
                e.spec.eta,
                if e.spec.overload == 1 { "shed" } else { "block" },
                e.spec.seed,
            ));
        }
        fs::create_dir_all(root).context(format!("creating {}", root.display()))?;
        let tmp = root.join(format!("{MANIFEST_FILE}.tmp"));
        let live = root.join(MANIFEST_FILE);
        {
            let mut f =
                fs::File::create(&tmp).context(format!("creating {}", tmp.display()))?;
            f.write_all(body.as_bytes())
                .context(format!("writing {}", tmp.display()))?;
            f.sync_all().context(format!("fsyncing {}", tmp.display()))?;
        }
        fs::rename(&tmp, &live)
            .context(format!("renaming {} over {}", tmp.display(), live.display()))?;
        sync_dir(root)
    }
}

fn section_u32(f: &ConfigFile, section: &str, key: &str) -> Result<u32> {
    f.get(section, key)
        .with_context(|| format!("collection [{section}] is missing {key}"))?
        .parse()
        .with_context(|| format!("collection [{section}]: {key} is not a u32"))
}

fn section_u64(f: &ConfigFile, section: &str, key: &str) -> Result<u64> {
    f.get(section, key)
        .with_context(|| format!("collection [{section}] is missing {key}"))?
        .parse()
        .with_context(|| format!("collection [{section}]: {key} is not a u64"))
}

fn section_f64(f: &ConfigFile, section: &str, key: &str) -> Result<f64> {
    f.get(section, key)
        .with_context(|| format!("collection [{section}] is missing {key}"))?
        .parse()
        .with_context(|| format!("collection [{section}]: {key} is not an f64"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(dim: u32) -> CollectionSpec {
        CollectionSpec {
            dim,
            shards: 2,
            replicas: 1,
            n_max: 1000,
            window: 256,
            eta: 0.5,
            overload: 0,
            seed: 42,
        }
    }

    #[test]
    fn missing_manifest_is_the_empty_default() {
        let dir = tempdir("manifest-missing");
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m, Manifest::default());
        assert_eq!(m.next_id, 1, "named ids start above the default collection's 0");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn roundtrips_entries_and_next_id() {
        let dir = tempdir("manifest-roundtrip");
        let mut m = Manifest::default();
        m.entries.push(ManifestEntry { id: 1, name: "news".into(), spec: spec(16) });
        let mut shed = spec(8);
        shed.overload = 1;
        shed.eta = 0.25;
        m.entries.push(ManifestEntry { id: 3, name: "turnstile-9".into(), spec: shed });
        m.next_id = 4;
        m.store(&dir).unwrap();
        assert_eq!(Manifest::load(&dir).unwrap(), m);
        // Overwrite survives (atomic replace, not append).
        m.entries.pop();
        m.next_id = 5;
        m.store(&dir).unwrap();
        assert_eq!(Manifest::load(&dir).unwrap(), m);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_corrupt_ids() {
        let dir = tempdir("manifest-corrupt");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join(MANIFEST_FILE),
            "next_id = 2\n[x]\nid = 0\ndim = 4\nshards = 1\nreplicas = 1\n\
             n_max = 10\nwindow = 8\neta = 0.5\noverload = \"block\"\nseed = 1\n",
        )
        .unwrap();
        let err = Manifest::load(&dir).unwrap_err().to_string();
        assert!(err.contains("id 0 is reserved"), "{err}");
        std::fs::write(
            dir.join(MANIFEST_FILE),
            "next_id = 2\n[x]\nid = 7\ndim = 4\nshards = 1\nreplicas = 1\n\
             n_max = 10\nwindow = 8\neta = 0.5\noverload = \"block\"\nseed = 1\n",
        )
        .unwrap();
        let err = Manifest::load(&dir).unwrap_err().to_string();
        assert!(err.contains(">= next_id"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    fn tempdir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!(
            "sketchd-{tag}-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        std::fs::remove_dir_all(&d).ok();
        d
    }
}
