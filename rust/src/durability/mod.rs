//! L5 — the durability engine: the paper's whole premise is that the
//! sketch, not the stream, is the state worth keeping (O(n^{1+ρ−η})
//! memory, Thm 3.1) — so a serving process must be able to crash and come
//! back with the same sketch instead of replaying the full stream.
//!
//! Three cooperating pieces:
//!
//! * [`wal`] — a per-shard write-ahead log of applied insert/delete
//!   records (length-prefixed, CRC32-framed, versioned) with segment
//!   rotation and a configurable [`FsyncPolicy`]. The shard thread that
//!   applies a mutation also appends its record, so WAL order equals
//!   apply order by construction — no cross-thread sequencing.
//! * [`checkpoint`] — atomic whole-service snapshots (write-temp +
//!   rename) serializing every shard's S-ANN and SW-AKDE state (via
//!   `sketch::snapshot`) plus the service counters and each shard's WAL
//!   high-water mark.
//! * [`recovery`] — on startup, load the newest valid checkpoint and
//!   replay WAL records past its high-water mark; record sequence
//!   numbers make replay idempotent. Sealed segments are GC'd after the
//!   next successful checkpoint.
//! * [`manifest`] — the multi-tenant registry file at the data-dir root:
//!   which named collections exist, with what shape, under which never-
//!   reused ids. Each collection keeps its own WAL/checkpoint subtree
//!   (`<root>/<name>/`) under the exact discipline above; the manifest
//!   only records existence, atomically (temp + rename + dir fsync).
//!
//! Durability points: with `FsyncPolicy::Always` every applied record is
//! synced before the next command; otherwise flush barriers and every
//! checkpoint sync the WAL, so "flush returned" means "applied AND
//! durable" under every policy. Directory entries are fsynced alongside
//! the files ([`sync_dir`]) — a checkpoint rename or fresh WAL segment
//! that survives only in a lost directory entry saved nothing.

pub mod checkpoint;
pub mod io;
pub mod manifest;
pub mod recovery;
pub mod wal;

pub use checkpoint::CheckpointData;
pub use manifest::{Manifest, ManifestEntry};
pub use recovery::Recovered;
pub use wal::{WalOp, WalRecord, WalWriter};

use anyhow::{bail, Result};

/// Fsync a directory, making the renames/creates/unlinks inside it
/// durable — file-content fsync alone does not persist the directory
/// entry, so a checkpoint rename or a fresh WAL segment could vanish on
/// power loss without this. No-op on platforms where directories cannot
/// be opened for syncing (non-unix).
pub fn sync_dir(dir: &std::path::Path) -> Result<()> {
    #[cfg(unix)]
    {
        use anyhow::Context;
        let f = std::fs::File::open(dir)
            .with_context(|| format!("opening directory {dir:?} for fsync"))?;
        io::sync_all(&f)
            .with_context(|| format!("fsyncing directory {dir:?}"))?;
    }
    #[cfg(not(unix))]
    {
        let _ = dir;
    }
    Ok(())
}

/// When the WAL fsyncs (buffered bytes always reach the OS at record
/// granularity under `Always`, and at sync barriers otherwise; fsync is
/// what survives power loss, the OS page cache is what survives SIGKILL).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FsyncPolicy {
    /// fsync after every appended record (durable acks, slowest).
    Always,
    /// fsync every N appended records (bounded loss window).
    EveryN(u32),
    /// Never fsync on append; only explicit barriers (flush, checkpoint)
    /// flush + sync.
    Off,
}

impl FsyncPolicy {
    /// Parse a CLI/config spelling: `always`, `off`, `every`,
    /// `every:N` / `every=N`, or a bare integer N (= every N records).
    pub fn parse(s: &str) -> Result<Self> {
        let s = s.trim();
        match s {
            "always" => return Ok(FsyncPolicy::Always),
            "off" => return Ok(FsyncPolicy::Off),
            "every" => return Ok(FsyncPolicy::EveryN(256)),
            _ => {}
        }
        let n = s
            .strip_prefix("every:")
            .or_else(|| s.strip_prefix("every="))
            .unwrap_or(s);
        match n.parse::<u32>() {
            Ok(n) if n > 0 => Ok(FsyncPolicy::EveryN(n)),
            _ => bail!("--fsync expects always|off|every:N, got {s:?}"),
        }
    }
}

impl Default for FsyncPolicy {
    fn default() -> Self {
        FsyncPolicy::EveryN(256)
    }
}

impl std::fmt::Display for FsyncPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FsyncPolicy::Always => write!(f, "always"),
            FsyncPolicy::EveryN(n) => write!(f, "every:{n}"),
            FsyncPolicy::Off => write!(f, "off"),
        }
    }
}

/// CRC-32 (IEEE 802.3, reflected polynomial 0xEDB88320) — table-driven,
/// dependency-free. Frames every WAL record and the checkpoint file.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC32_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    !c
}

static CRC32_TABLE: [u32; 256] = make_crc32_table();

const fn make_crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut j = 0;
        while j < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            j += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_known_vectors() {
        // The canonical CRC-32/IEEE check value.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_ne!(crc32(b"a"), crc32(b"b"));
    }

    #[test]
    fn crc32_detects_single_byte_flips() {
        let base = b"the sketch is the state worth keeping".to_vec();
        let want = crc32(&base);
        for i in 0..base.len() {
            let mut m = base.clone();
            m[i] ^= 0x01;
            assert_ne!(crc32(&m), want, "flip at byte {i} must change the crc");
        }
    }

    #[test]
    fn fsync_policy_parses_all_spellings() {
        assert_eq!(FsyncPolicy::parse("always").unwrap(), FsyncPolicy::Always);
        assert_eq!(FsyncPolicy::parse("off").unwrap(), FsyncPolicy::Off);
        assert_eq!(FsyncPolicy::parse("every").unwrap(), FsyncPolicy::EveryN(256));
        assert_eq!(FsyncPolicy::parse("every:64").unwrap(), FsyncPolicy::EveryN(64));
        assert_eq!(FsyncPolicy::parse("every=8").unwrap(), FsyncPolicy::EveryN(8));
        assert_eq!(FsyncPolicy::parse("512").unwrap(), FsyncPolicy::EveryN(512));
        assert!(FsyncPolicy::parse("sometimes").is_err());
        assert!(FsyncPolicy::parse("every:0").is_err());
        assert_eq!(FsyncPolicy::parse("every:64").unwrap().to_string(), "every:64");
    }
}
