//! Per-shard write-ahead log.
//!
//! Record frame (all little-endian, mirroring `net/frame.rs` framing):
//!
//! ```text
//! record  := u32 payload_len | u32 crc32(payload) | payload
//! payload := u8 version (=1) | u8 op | u64 seq | u32 dim | dim × f32
//! op      := 1 insert(retained) | 2 insert(dropped by sampler) | 3 delete
//! ```
//!
//! The `retained` bit records the shard's own Bernoulli sampler decision
//! at apply time, so replay is fully deterministic — it never re-draws
//! randomness: a retained insert re-enters the S-ANN arena (re-hashing is
//! deterministic from the config seed), a dropped one still ticks the
//! SW-AKDE window, exactly as the original apply did.
//!
//! Segments are `wal/shard{SSSS}-{FIRSTSEQ}.wal` under the data dir; the
//! file name carries the first sequence number it contains, so a segment
//! is GC-able exactly when the next segment's first seq is ≤ hwm + 1.
//! Writers rotate on a size cap and at every checkpoint (so freshly
//! sealed segments become GC-able immediately). Readers stop at the
//! first corrupt record: a torn tail can only exist in the final
//! segment (writes are append-only and single-threaded per shard), and
//! anything else is real corruption where replaying further records
//! against un-captured state would silently diverge.

use std::fs::{File, OpenOptions};
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::{crc32, io, FsyncPolicy};
use crate::util::bytes::{put_f32, put_u32, put_u64, u32_le_at, u64_le_at};

/// First payload byte of every record.
pub const WAL_VERSION: u8 = 1;

/// Hard cap on one record's payload (a dim-2^20 f32 vector fits).
pub const MAX_RECORD_BYTES: usize = 1 << 23;

/// Default segment rotation size (bytes of encoded records).
pub const DEFAULT_SEGMENT_BYTES: u64 = 16 << 20;

mod op {
    pub(super) const INSERT_RETAINED: u8 = 1;
    pub(super) const INSERT_DROPPED: u8 = 2;
    pub(super) const DELETE: u8 = 3;
}

/// A logged, applied mutation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WalOp {
    /// Stream insert; `retained` is the sampler decision that was made.
    Insert { retained: bool },
    /// Turnstile delete that removed a stored copy.
    Delete,
}

/// One WAL record: a per-shard sequence number, the operation, and the
/// point it applied to.
#[derive(Clone, Debug, PartialEq)]
pub struct WalRecord {
    pub seq: u64,
    pub op: WalOp,
    pub vec: Vec<f32>,
}

fn op_byte(op: WalOp) -> u8 {
    match op {
        WalOp::Insert { retained: true } => op::INSERT_RETAINED,
        WalOp::Insert { retained: false } => op::INSERT_DROPPED,
        WalOp::Delete => op::DELETE,
    }
}

/// The ONE payload encoder, shared by [`WalRecord::encode`] and the
/// writer's allocation-free append path, so the two can never drift.
fn encode_payload_into(out: &mut Vec<u8>, seq: u64, op: WalOp, vec: &[f32]) {
    out.push(WAL_VERSION);
    out.push(op_byte(op));
    put_u64(out, seq);
    put_u32(out, vec.len() as u32);
    for &v in vec {
        put_f32(out, v);
    }
}

impl WalRecord {
    /// Encode as one framed record (len | crc | payload).
    pub fn encode(&self) -> Vec<u8> {
        let mut payload = Vec::with_capacity(14 + self.vec.len() * 4);
        encode_payload_into(&mut payload, self.seq, self.op, &self.vec);
        let mut out = Vec::with_capacity(8 + payload.len());
        put_u32(&mut out, payload.len() as u32);
        put_u32(&mut out, crc32(&payload));
        out.extend_from_slice(&payload);
        out
    }

    /// Decode ONE record from the front of `bytes`; returns the record
    /// and the bytes consumed. Every length is validated against the
    /// bytes actually present before any allocation, and the CRC must
    /// match — corrupt input errors, it never panics.
    pub fn decode(bytes: &[u8]) -> Result<(WalRecord, usize)> {
        if bytes.len() < 8 {
            bail!("WAL record header truncated ({} bytes)", bytes.len());
        }
        let len = u32_le_at(bytes, 0)? as usize;
        if len == 0 || len > MAX_RECORD_BYTES {
            bail!("WAL record payload of {len} bytes outside (0, {MAX_RECORD_BYTES}]");
        }
        let want_crc = u32_le_at(bytes, 4)?;
        if bytes.len() < 8 + len {
            bail!("WAL record truncated: header claims {len} payload bytes");
        }
        let payload = &bytes[8..8 + len];
        if crc32(payload) != want_crc {
            bail!("WAL record CRC mismatch");
        }
        if len < 14 {
            bail!("WAL record payload too short ({len} bytes)");
        }
        if payload[0] != WAL_VERSION {
            bail!("WAL record version {} (this build speaks {WAL_VERSION})", payload[0]);
        }
        let walop = match payload[1] {
            op::INSERT_RETAINED => WalOp::Insert { retained: true },
            op::INSERT_DROPPED => WalOp::Insert { retained: false },
            op::DELETE => WalOp::Delete,
            other => bail!("unknown WAL op {other}"),
        };
        let seq = u64_le_at(payload, 2)?;
        let dim = u32_le_at(payload, 10)? as usize;
        if dim == 0 {
            bail!("WAL record has a zero-dimensional vector");
        }
        // The payload length already bounds dim (dim*4 must fit in what
        // the CRC covered), so this allocation is paid for by real bytes.
        if payload.len() - 14 != dim * 4 {
            bail!(
                "WAL record dim {dim} implies {} payload bytes, {} present",
                14 + dim * 4,
                payload.len()
            );
        }
        let vec: Vec<f32> = payload[14..]
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok((WalRecord { seq, op: walop, vec }, 8 + len))
    }
}

/// `<data_dir>/wal`
pub fn wal_dir(data_dir: &Path) -> PathBuf {
    data_dir.join("wal")
}

fn segment_path(data_dir: &Path, shard: usize, first_seq: u64) -> PathBuf {
    wal_dir(data_dir).join(format!("shard{shard:04}-{first_seq:020}.wal"))
}

/// All of one shard's segments, sorted ascending by first sequence number.
pub fn list_segments(data_dir: &Path, shard: usize) -> Result<Vec<(u64, PathBuf)>> {
    let dir = wal_dir(data_dir);
    let prefix = format!("shard{shard:04}-");
    let mut out = Vec::new();
    let entries = match std::fs::read_dir(&dir) {
        Ok(e) => e,
        Err(_) => return Ok(out), // no wal dir yet: empty log
    };
    for entry in entries {
        let entry = entry?;
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if let Some(rest) = name.strip_prefix(&prefix) {
            if let Some(seq_str) = rest.strip_suffix(".wal") {
                if let Ok(first_seq) = seq_str.parse::<u64>() {
                    out.push((first_seq, entry.path()));
                }
            }
        }
    }
    out.sort();
    Ok(out)
}

/// Delete every sealed segment whose records are all ≤ `hwm` (covered by
/// a successful checkpoint). Returns the number of files removed.
pub fn gc_segments(data_dir: &Path, shard: usize, hwm: u64) -> Result<usize> {
    let segs = list_segments(data_dir, shard)?;
    let mut removed = 0;
    for w in segs.windows(2) {
        let (first, ref path) = w[0];
        let (next_first, _) = w[1];
        // Segment covers [first, next_first - 1]; GC-able iff that whole
        // range is ≤ hwm. The newest segment (no successor) always stays.
        if first <= hwm && next_first <= hwm + 1 {
            std::fs::remove_file(path)
                .with_context(|| format!("removing sealed WAL segment {path:?}"))?;
            removed += 1;
        }
    }
    if removed > 0 {
        // Persist the unlinks (the checkpoint covering them was made
        // durable — rename + dir fsync — before GC ran).
        super::sync_dir(&wal_dir(data_dir))?;
    }
    Ok(removed)
}

/// Outcome of a replay pass over one shard's segments.
#[derive(Clone, Debug, Default)]
pub struct ReplayReport {
    /// Records applied (seq > hwm).
    pub applied: u64,
    /// Highest sequence number seen across all valid records.
    pub last_seq: u64,
    /// True if replay stopped at a corrupt/torn record.
    pub corrupt_tail: bool,
    /// Where the torn record sits: (segment, offset of the valid prefix).
    /// Recovery truncates here so the NEXT recovery replays cleanly past
    /// this point instead of stopping at stale garbage.
    pub corrupt_at: Option<(PathBuf, u64)>,
}

/// Cut a torn tail off a segment (recovery, after a `corrupt_at` report):
/// everything before `len` is valid records, everything after is garbage
/// from a torn write.
pub fn truncate_segment(path: &Path, len: u64) -> Result<()> {
    let f = OpenOptions::new()
        .write(true)
        .open(path)
        .with_context(|| format!("opening {path:?} for truncation"))?;
    f.set_len(len)
        .with_context(|| format!("truncating {path:?} to {len} bytes"))?;
    f.sync_data()?;
    Ok(())
}

/// Replay one shard's WAL: every valid record with `seq > hwm` is handed
/// to `apply`, in log order (idempotence: records ≤ hwm — already inside
/// the checkpoint — are skipped by sequence number). Stops cleanly at the
/// first corrupt record (a torn tail from the crash being recovered).
pub fn replay(
    data_dir: &Path,
    shard: usize,
    hwm: u64,
    mut apply: impl FnMut(&WalRecord) -> Result<()>,
) -> Result<ReplayReport> {
    let mut report =
        ReplayReport { applied: 0, last_seq: hwm, corrupt_tail: false, corrupt_at: None };
    'segments: for (_, path) in list_segments(data_dir, shard)? {
        let bytes =
            std::fs::read(&path).with_context(|| format!("reading WAL segment {path:?}"))?;
        let mut off = 0usize;
        while off < bytes.len() {
            let (rec, used) = match WalRecord::decode(&bytes[off..]) {
                Ok(r) => r,
                Err(_) => {
                    report.corrupt_tail = true;
                    report.corrupt_at = Some((path.clone(), off as u64));
                    break 'segments;
                }
            };
            off += used;
            if rec.seq > hwm && rec.seq > report.last_seq {
                apply(&rec)?;
                report.applied += 1;
            }
            report.last_seq = report.last_seq.max(rec.seq);
        }
    }
    Ok(report)
}

/// Append-side of one shard's WAL: owns the active segment, assigns
/// sequence numbers, rotates on the size cap, and fsyncs per policy.
pub struct WalWriter {
    data_dir: PathBuf,
    shard: usize,
    policy: FsyncPolicy,
    segment_cap: u64,
    file: BufWriter<File>,
    seg_bytes: u64,
    seg_records: u64,
    pending_sync: u32,
    next_seq: u64,
    /// Payload scratch reused across appends: the per-record hot path
    /// allocates nothing in steady state.
    scratch: Vec<u8>,
    /// Optional metrics registry: when wired (the service does this at
    /// startup), every `sync` records its wall time into the shared
    /// `wal_fsync` histogram. Tests and standalone writers run
    /// unobserved.
    registry: Option<crate::util::sync::Arc<crate::metrics::registry::Registry>>,
}

impl WalWriter {
    /// Open a fresh active segment starting at `next_seq` (recovery has
    /// already consumed any earlier segments; a leftover file with this
    /// exact first-seq can only be an empty rotation artifact and is
    /// truncated).
    pub fn open(
        data_dir: &Path,
        shard: usize,
        next_seq: u64,
        policy: FsyncPolicy,
        segment_cap: u64,
    ) -> Result<Self> {
        let next_seq = next_seq.max(1); // sequence numbers start at 1
        std::fs::create_dir_all(wal_dir(data_dir))
            .with_context(|| format!("creating WAL dir under {data_dir:?}"))?;
        let path = segment_path(data_dir, shard, next_seq);
        let mut opts = OpenOptions::new();
        opts.write(true).create(true).truncate(true);
        let file = io::open(&opts, &path)
            .with_context(|| format!("opening WAL segment {path:?}"))?;
        // Make the new directory entry durable: syncing record bytes into
        // a file whose entry is lost on power failure durably saves nothing.
        super::sync_dir(&wal_dir(data_dir))?;
        Ok(WalWriter {
            data_dir: data_dir.to_path_buf(),
            shard,
            policy,
            segment_cap: segment_cap.max(1),
            file: BufWriter::new(file),
            seg_bytes: 0,
            seg_records: 0,
            pending_sync: 0,
            next_seq,
            scratch: Vec::new(),
            registry: None,
        })
    }

    /// Record every future [`Self::sync`]'s wall time into the shared
    /// registry's `wal_fsync` histogram.
    pub fn set_fsync_observer(
        &mut self,
        registry: crate::util::sync::Arc<crate::metrics::registry::Registry>,
    ) {
        self.registry = Some(registry);
    }

    /// Highest sequence number assigned so far (0 before the first append).
    pub fn last_seq(&self) -> u64 {
        self.next_seq - 1
    }

    /// Append one applied mutation; returns its sequence number.
    /// Allocation-free in steady state: the payload is framed into a
    /// reused scratch buffer and written straight to the `BufWriter`.
    pub fn append(&mut self, op: WalOp, vec: &[f32]) -> Result<u64> {
        let seq = self.next_seq;
        self.scratch.clear();
        encode_payload_into(&mut self.scratch, seq, op, vec);
        io::write_all(&mut self.file, &(self.scratch.len() as u32).to_le_bytes())?;
        io::write_all(&mut self.file, &crc32(&self.scratch).to_le_bytes())?;
        io::write_all(&mut self.file, &self.scratch)?;
        self.next_seq += 1;
        self.seg_bytes += 8 + self.scratch.len() as u64;
        self.seg_records += 1;
        self.pending_sync += 1;
        match self.policy {
            FsyncPolicy::Always => self.sync()?,
            FsyncPolicy::EveryN(n) => {
                if self.pending_sync >= n {
                    self.sync()?;
                }
            }
            FsyncPolicy::Off => {}
        }
        if self.seg_bytes >= self.segment_cap {
            self.rotate()?;
        }
        Ok(seq)
    }

    /// Flush buffered records to the OS and fsync them to disk. Explicit
    /// barriers (service flush, checkpoints) call this regardless of the
    /// per-append policy.
    pub fn sync(&mut self) -> Result<()> {
        let t = std::time::Instant::now();
        self.file.flush()?;
        io::sync_data(self.file.get_ref())?;
        self.pending_sync = 0;
        if let Some(reg) = &self.registry {
            reg.wal_fsync.record(t.elapsed());
        }
        Ok(())
    }

    /// Seal the active segment and start a new one at the next sequence
    /// number (no-op while the active segment is empty — checkpoints on
    /// an idle service must not litter empty files).
    pub fn rotate(&mut self) -> Result<()> {
        if self.seg_records == 0 {
            return Ok(());
        }
        self.sync()?;
        let path = segment_path(&self.data_dir, self.shard, self.next_seq);
        let mut opts = OpenOptions::new();
        opts.write(true).create(true).truncate(true);
        let file = io::open(&opts, &path)
            .with_context(|| format!("rotating to WAL segment {path:?}"))?;
        super::sync_dir(&wal_dir(&self.data_dir))?;
        self.file = BufWriter::new(file);
        self.seg_bytes = 0;
        self.seg_records = 0;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, Gen};

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "sketchd_wal_{tag}_{}_{}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn gen_record(g: &mut Gen, seq: u64) -> WalRecord {
        let dim = g.usize_in(1, 24);
        let op = match g.usize_in(0, 2) {
            0 => WalOp::Insert { retained: true },
            1 => WalOp::Insert { retained: false },
            _ => WalOp::Delete,
        };
        WalRecord { seq, op, vec: g.vector(dim, 3.0) }
    }

    #[test]
    fn property_record_roundtrip() {
        check("wal_record_roundtrip", 300, |g| {
            let rec = gen_record(g, g.usize_in(0, 1 << 40) as u64);
            let bytes = rec.encode();
            let (back, used) =
                WalRecord::decode(&bytes).map_err(|e| e.to_string())?;
            if used != bytes.len() {
                return Err(format!("consumed {used} of {}", bytes.len()));
            }
            if back != rec {
                return Err(format!("{rec:?} != {back:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn property_single_byte_mutations_never_panic_or_misdecode() {
        // Satellite contract: every 1-byte mutation of a valid record
        // either errors (CRC32 catches all single-byte payload flips) or
        // decodes to a DIFFERENT valid record — and never panics or
        // allocates past the record cap.
        check("wal_record_mutation", 60, |g| {
            let rec = gen_record(g, g.usize_in(0, 1 << 30) as u64);
            let bytes = rec.encode();
            let i = g.usize_in(0, bytes.len() - 1);
            let flip = (g.usize_in(1, 255)) as u8;
            let mut m = bytes.clone();
            m[i] ^= flip;
            match WalRecord::decode(&m) {
                Err(_) => Ok(()),
                Ok((back, _)) if back != rec => Ok(()),
                Ok(_) => Err(format!(
                    "mutation at byte {i} (xor {flip:#x}) decoded back to the original"
                )),
            }
        });
    }

    #[test]
    fn append_frames_bytes_identical_to_encode() {
        let dir = tmp_dir("frames");
        let mut w = WalWriter::open(&dir, 0, 1, FsyncPolicy::Off, u64::MAX).unwrap();
        let rec = WalRecord { seq: 1, op: WalOp::Delete, vec: vec![1.5, -2.5] };
        w.append(rec.op, &rec.vec).unwrap();
        w.sync().unwrap();
        let (_, path) = list_segments(&dir, 0).unwrap().pop().unwrap();
        assert_eq!(
            std::fs::read(&path).unwrap(),
            rec.encode(),
            "the writer's scratch path and WalRecord::encode share one framing"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn truncations_and_garbage_are_clean_errors() {
        let rec = WalRecord {
            seq: 7,
            op: WalOp::Insert { retained: true },
            vec: vec![1.0, -2.0, 0.5],
        };
        let bytes = rec.encode();
        for cut in 0..bytes.len() {
            assert!(WalRecord::decode(&bytes[..cut]).is_err(), "prefix {cut}");
        }
        assert!(WalRecord::decode(&[]).is_err());
        // A header claiming a huge payload must be rejected by the cap,
        // not by attempting the allocation.
        let mut huge = Vec::new();
        huge.extend_from_slice(&(u32::MAX).to_le_bytes());
        huge.extend_from_slice(&[0u8; 12]);
        let err = WalRecord::decode(&huge).unwrap_err().to_string();
        assert!(err.contains("outside"), "{err}");
    }

    #[test]
    fn writer_reader_roundtrip_with_rotation_and_gc() {
        let dir = tmp_dir("rotate");
        // Tiny segment cap: every few records forces a rotation.
        let mut w = WalWriter::open(&dir, 0, 1, FsyncPolicy::Off, 128).unwrap();
        let mut want = Vec::new();
        for i in 0..40u32 {
            let vec = vec![i as f32, -(i as f32)];
            let op = if i % 5 == 0 { WalOp::Delete } else { WalOp::Insert { retained: true } };
            let seq = w.append(op, &vec).unwrap();
            assert_eq!(seq, i as u64 + 1);
            want.push(WalRecord { seq, op, vec });
        }
        w.sync().unwrap();
        assert!(list_segments(&dir, 0).unwrap().len() > 1, "cap must rotate");

        let mut got = Vec::new();
        let report = replay(&dir, 0, 0, |r| {
            got.push(r.clone());
            Ok(())
        })
        .unwrap();
        assert_eq!(got, want);
        assert_eq!(report.applied, 40);
        assert_eq!(report.last_seq, 40);
        assert!(!report.corrupt_tail);

        // Replay past a high-water mark skips covered records.
        let mut tail = Vec::new();
        let report = replay(&dir, 0, 25, |r| {
            tail.push(r.seq);
            Ok(())
        })
        .unwrap();
        assert_eq!(tail, (26..=40).collect::<Vec<u64>>());
        assert_eq!(report.applied, 15);

        // GC with hwm below the newest segment's range keeps the tail.
        let before = list_segments(&dir, 0).unwrap().len();
        let removed = gc_segments(&dir, 0, 40).unwrap();
        assert_eq!(removed, before - 1, "all sealed segments covered by hwm=40");
        let mut survivors = Vec::new();
        replay(&dir, 0, 40, |r| {
            survivors.push(r.seq);
            Ok(())
        })
        .unwrap();
        assert!(survivors.is_empty(), "nothing past hwm survives: {survivors:?}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn torn_tail_stops_replay_cleanly() {
        let dir = tmp_dir("torn");
        let mut w = WalWriter::open(&dir, 3, 1, FsyncPolicy::Off, u64::MAX).unwrap();
        for i in 0..10u32 {
            w.append(WalOp::Insert { retained: true }, &[i as f32]).unwrap();
        }
        w.sync().unwrap();
        drop(w);
        // Simulate a torn write: garbage appended to the active segment.
        let (_, path) = list_segments(&dir, 3).unwrap().pop().unwrap();
        let mut f = OpenOptions::new().append(true).open(&path).unwrap();
        f.write_all(&[0xDE, 0xAD, 0xBE]).unwrap();
        drop(f);
        let mut seqs = Vec::new();
        let report = replay(&dir, 3, 0, |r| {
            seqs.push(r.seq);
            Ok(())
        })
        .unwrap();
        assert_eq!(seqs, (1..=10).collect::<Vec<u64>>());
        assert!(report.corrupt_tail);
        assert_eq!(report.last_seq, 10);

        // Recovery's follow-up: truncate the garbage, append more records
        // in a fresh segment, and the NEXT replay covers everything.
        let (path, off) = report.corrupt_at.clone().unwrap();
        truncate_segment(&path, off).unwrap();
        let mut w = WalWriter::open(&dir, 3, report.last_seq + 1, FsyncPolicy::Off, u64::MAX)
            .unwrap();
        w.append(WalOp::Insert { retained: true }, &[99.0]).unwrap();
        w.sync().unwrap();
        let mut seqs = Vec::new();
        let report = replay(&dir, 3, 0, |r| {
            seqs.push(r.seq);
            Ok(())
        })
        .unwrap();
        assert_eq!(seqs, (1..=11).collect::<Vec<u64>>());
        assert!(!report.corrupt_tail, "truncation heals the log");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shards_do_not_share_segments() {
        let dir = tmp_dir("shards");
        let mut w0 = WalWriter::open(&dir, 0, 1, FsyncPolicy::Off, u64::MAX).unwrap();
        let mut w1 = WalWriter::open(&dir, 1, 1, FsyncPolicy::Off, u64::MAX).unwrap();
        w0.append(WalOp::Insert { retained: true }, &[0.0]).unwrap();
        w1.append(WalOp::Delete, &[1.0]).unwrap();
        w1.append(WalOp::Delete, &[2.0]).unwrap();
        w0.sync().unwrap();
        w1.sync().unwrap();
        let mut n0 = 0;
        replay(&dir, 0, 0, |_| {
            n0 += 1;
            Ok(())
        })
        .unwrap();
        let mut n1 = 0;
        replay(&dir, 1, 0, |_| {
            n1 += 1;
            Ok(())
        })
        .unwrap();
        assert_eq!((n0, n1), (1, 2));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn every_n_policy_counts_appends() {
        let dir = tmp_dir("everyn");
        let mut w = WalWriter::open(&dir, 0, 1, FsyncPolicy::EveryN(4), u64::MAX).unwrap();
        for i in 0..9u32 {
            w.append(WalOp::Insert { retained: false }, &[i as f32]).unwrap();
        }
        // 9 appends with N=4: at least the first 8 are already synced;
        // after an explicit sync everything is readable.
        w.sync().unwrap();
        let mut n = 0;
        replay(&dir, 0, 0, |_| {
            n += 1;
            Ok(())
        })
        .unwrap();
        assert_eq!(n, 9);
        std::fs::remove_dir_all(&dir).ok();
    }
}
