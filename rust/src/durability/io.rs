//! The durability engine's fault seam: every fallible filesystem call the
//! WAL and checkpoint writers make (open, write, fsync, rename, directory
//! sync) goes through the free functions here. In a normal build they are
//! direct passthroughs with no state and no branching beyond what the call
//! itself does. Under the `fault-injection` cargo feature a process-global
//! [`Io`] implementation can be installed, and the bundled deterministic
//! [`FaultInjector`] scripts disk failures for tests and the CI chaos job:
//! fail the Nth fsync, return ENOSPC once a byte budget is spent (with a
//! seeded torn prefix at the boundary), fail the Nth rename or open.
//!
//! The seam deliberately sits ABOVE the `BufWriter` (appends are
//! intercepted as whole framed records, not as whatever flush pattern the
//! buffer produces), so an injected tear lands on a record boundary the
//! way a real torn append does after a crash — the same torn-tail shape
//! `wal::replay` already knows how to stop at.

use std::fs::{File, OpenOptions};
use std::io::{Error, ErrorKind, Result, Write};
use std::path::Path;

/// What an intercepted write should do.
pub enum WriteDecision {
    /// Perform the write normally.
    Pass,
    /// Write only the first `n` bytes, then fail: a torn write.
    TornAfter(usize),
    /// Write nothing and fail with this error.
    Fail(Error),
}

/// Interception points, one per fallible filesystem call in the
/// durability engine. Every hook defaults to "no fault" so an injector
/// only overrides the calls it wants to break.
pub trait Io: Send {
    /// Before `OpenOptions::open` / `File::create` (WAL segment create,
    /// rotation, checkpoint temp file).
    fn before_open(&mut self, path: &Path) -> Result<()> {
        let _ = path;
        Ok(())
    }
    /// Before a content write of `len` bytes (WAL record frame,
    /// checkpoint image).
    fn before_write(&mut self, len: usize) -> WriteDecision {
        let _ = len;
        WriteDecision::Pass
    }
    /// Before a file or directory fsync.
    fn before_sync(&mut self) -> Result<()> {
        Ok(())
    }
    /// Before the checkpoint's atomic temp → final rename.
    fn before_rename(&mut self, from: &Path, to: &Path) -> Result<()> {
        let _ = (from, to);
        Ok(())
    }
}

#[cfg(feature = "fault-injection")]
static INJECTOR: crate::util::sync::Mutex<Option<Box<dyn Io>>> =
    crate::util::sync::Mutex::new(None);

/// Install a process-global injector; returns the one it replaced.
/// Faults are process-global state — tests that install one must
/// serialize on their own lock and [`clear`] when done.
#[cfg(feature = "fault-injection")]
pub fn install(io: Box<dyn Io>) -> Option<Box<dyn Io>> {
    crate::util::sync::lock_unpoisoned(&INJECTOR).replace(io)
}

/// Remove the installed injector (subsequent calls pass through).
#[cfg(feature = "fault-injection")]
pub fn clear() -> Option<Box<dyn Io>> {
    crate::util::sync::lock_unpoisoned(&INJECTOR).take()
}

#[cfg(feature = "fault-injection")]
fn with_injector<T>(default: T, f: impl FnOnce(&mut dyn Io) -> T) -> T {
    match crate::util::sync::lock_unpoisoned(&INJECTOR).as_mut() {
        Some(io) => f(io.as_mut()),
        None => default,
    }
}

/// Seam over `opts.open(path)`.
pub fn open(opts: &OpenOptions, path: &Path) -> Result<File> {
    #[cfg(feature = "fault-injection")]
    with_injector(Ok(()), |io| io.before_open(path))?;
    opts.open(path)
}

/// Seam over `writer.write_all(bytes)`. Generic over the writer so the
/// WAL's `BufWriter` path stays buffered and allocation-free.
pub fn write_all<W: Write>(w: &mut W, bytes: &[u8]) -> Result<()> {
    #[cfg(feature = "fault-injection")]
    match with_injector(WriteDecision::Pass, |io| io.before_write(bytes.len())) {
        WriteDecision::Pass => {}
        WriteDecision::TornAfter(n) => {
            w.write_all(&bytes[..n.min(bytes.len())])?;
            return Err(Error::new(ErrorKind::WriteZero, "injected torn write"));
        }
        WriteDecision::Fail(e) => return Err(e),
    }
    w.write_all(bytes)
}

/// Seam over `file.sync_data()`.
pub fn sync_data(f: &File) -> Result<()> {
    #[cfg(feature = "fault-injection")]
    with_injector(Ok(()), |io| io.before_sync())?;
    f.sync_data()
}

/// Seam over `file.sync_all()` (directory fsyncs).
pub fn sync_all(f: &File) -> Result<()> {
    #[cfg(feature = "fault-injection")]
    with_injector(Ok(()), |io| io.before_sync())?;
    f.sync_all()
}

/// Seam over `std::fs::rename`.
pub fn rename(from: &Path, to: &Path) -> Result<()> {
    #[cfg(feature = "fault-injection")]
    with_injector(Ok(()), |io| io.before_rename(from, to))?;
    std::fs::rename(from, to)
}

/// An ENOSPC-shaped error, shared by the injector and its tests.
pub fn disk_full() -> Error {
    Error::other("injected fault: no space left on device")
}

/// One scripted failure. Counts are 1-based and each rule fires from its
/// trigger point onward (a full disk stays full; a dying device keeps
/// failing fsync), which is how the real faults they model behave.
#[cfg(feature = "fault-injection")]
#[derive(Clone, Copy, Debug)]
pub enum FaultRule {
    /// Fail the Nth and every later fsync (file or directory).
    FailNthSync(u64),
    /// After this many content bytes have been written, every further
    /// write fails with ENOSPC; the write that crosses the boundary is
    /// torn at a seeded offset inside the remaining budget.
    DiskFullAfter(u64),
    /// Fail the Nth and every later rename.
    FailNthRename(u64),
    /// Fail the Nth and every later open/create.
    FailNthOpen(u64),
}

/// Live counters shared with the installing test via `Arc`, so
/// assertions can see how far the script ran after the injector itself
/// was moved into [`install`].
#[cfg(feature = "fault-injection")]
#[derive(Debug, Default)]
pub struct FaultStats {
    pub syncs: crate::util::sync::atomic::AtomicU64,
    pub writes: crate::util::sync::atomic::AtomicU64,
    pub bytes_written: crate::util::sync::atomic::AtomicU64,
    pub renames: crate::util::sync::atomic::AtomicU64,
    pub opens: crate::util::sync::atomic::AtomicU64,
    pub injected: crate::util::sync::atomic::AtomicU64,
}

/// Deterministic, rule-driven [`Io`]: replays the same failures for the
/// same seed and call sequence. The seed only feeds the torn-write
/// offset; everything else is exact counting.
#[cfg(feature = "fault-injection")]
pub struct FaultInjector {
    rules: Vec<FaultRule>,
    stats: crate::util::sync::Arc<FaultStats>,
    rng_state: u64,
}

#[cfg(feature = "fault-injection")]
impl FaultInjector {
    pub fn new(seed: u64, rules: Vec<FaultRule>) -> Self {
        FaultInjector {
            rules,
            stats: crate::util::sync::Arc::new(FaultStats::default()),
            rng_state: seed | 1,
        }
    }

    /// Handle onto the live counters (clone before [`install`]).
    pub fn stats(&self) -> crate::util::sync::Arc<FaultStats> {
        crate::util::sync::Arc::clone(&self.stats)
    }

    fn next_rand(&mut self) -> u64 {
        // xorshift64* — deterministic, dependency-free.
        let mut x = self.rng_state;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.rng_state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    fn hit(&self) {
        use crate::util::sync::atomic::Ordering;
        self.stats.injected.fetch_add(1, Ordering::Relaxed);
    }
}

#[cfg(feature = "fault-injection")]
impl Io for FaultInjector {
    fn before_open(&mut self, _path: &Path) -> Result<()> {
        use crate::util::sync::atomic::Ordering;
        let n = self.stats.opens.fetch_add(1, Ordering::Relaxed) + 1;
        for r in &self.rules {
            if let FaultRule::FailNthOpen(at) = r {
                if n >= *at {
                    self.hit();
                    return Err(Error::other("injected fault: open failed"));
                }
            }
        }
        Ok(())
    }

    fn before_write(&mut self, len: usize) -> WriteDecision {
        use crate::util::sync::atomic::Ordering;
        let before = self.stats.bytes_written.load(Ordering::Relaxed);
        self.stats.writes.fetch_add(1, Ordering::Relaxed);
        for r in &self.rules {
            if let FaultRule::DiskFullAfter(budget) = r {
                if before >= *budget {
                    self.hit();
                    return WriteDecision::Fail(disk_full());
                }
                if before + len as u64 > *budget {
                    // Crossing the boundary: tear somewhere inside what
                    // the budget still allows, then go read-only-disk.
                    let room = (*budget - before) as usize;
                    let torn = if room == 0 { 0 } else { (self.next_rand() % room as u64) as usize };
                    self.stats.bytes_written.fetch_add(torn as u64, Ordering::Relaxed);
                    // Pin the budget as spent so every later write fails.
                    self.stats.bytes_written.fetch_max(*budget, Ordering::Relaxed);
                    self.hit();
                    return WriteDecision::TornAfter(torn);
                }
            }
        }
        self.stats.bytes_written.fetch_add(len as u64, Ordering::Relaxed);
        WriteDecision::Pass
    }

    fn before_sync(&mut self) -> Result<()> {
        use crate::util::sync::atomic::Ordering;
        let n = self.stats.syncs.fetch_add(1, Ordering::Relaxed) + 1;
        for r in &self.rules {
            if let FaultRule::FailNthSync(at) = r {
                if n >= *at {
                    self.hit();
                    return Err(Error::other("injected fault: fsync failed"));
                }
            }
        }
        Ok(())
    }

    fn before_rename(&mut self, _from: &Path, _to: &Path) -> Result<()> {
        use crate::util::sync::atomic::Ordering;
        let n = self.stats.renames.fetch_add(1, Ordering::Relaxed) + 1;
        for r in &self.rules {
            if let FaultRule::FailNthRename(at) = r {
                if n >= *at {
                    self.hit();
                    return Err(Error::other("injected fault: rename failed"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passthrough_write_reaches_the_writer() {
        let mut buf = Vec::new();
        write_all(&mut buf, b"records").unwrap();
        assert_eq!(buf, b"records");
    }

    #[cfg(feature = "fault-injection")]
    #[test]
    fn injector_is_deterministic_per_seed() {
        // Same seed + same call sequence → identical torn offsets.
        let mut torn = Vec::new();
        for _ in 0..2 {
            let mut inj = FaultInjector::new(99, vec![FaultRule::DiskFullAfter(10)]);
            match inj.before_write(64) {
                WriteDecision::TornAfter(n) => torn.push(n),
                _ => panic!("boundary-crossing write must tear"),
            }
            assert!(matches!(inj.before_write(1), WriteDecision::Fail(_)), "disk stays full");
        }
        assert_eq!(torn[0], torn[1]);
        assert!(torn[0] < 10, "tear fits in the remaining budget");
    }

    #[cfg(feature = "fault-injection")]
    #[test]
    fn nth_sync_rule_counts_exactly() {
        let mut inj = FaultInjector::new(1, vec![FaultRule::FailNthSync(3)]);
        let stats = inj.stats();
        assert!(inj.before_sync().is_ok());
        assert!(inj.before_sync().is_ok());
        assert!(inj.before_sync().is_err(), "third sync fails");
        assert!(inj.before_sync().is_err(), "and the device stays failed");
        assert_eq!(stats.syncs.load(crate::util::sync::atomic::Ordering::Relaxed), 4);
        assert_eq!(stats.injected.load(crate::util::sync::atomic::Ordering::Relaxed), 2);
    }
}
