//! Atomic whole-service checkpoints.
//!
//! File layout (little-endian), CRC32-framed like the WAL:
//!
//! ```text
//! checkpoint := magic "SKCKPT01" | u64 epoch | u64 dim | u64 shards
//!             | 5 × u64 counters (inserts, deletes, ann_q, kde_q, shed)
//!             | shards × shard | u32 crc32(everything before)
//! shard      := u64 wal_hwm | u64 applied_inserts | u64 applied_deletes
//!             | u64 sann_len | sann bytes | u64 swakde_len | swakde bytes
//! ```
//!
//! The per-shard applied counts are captured by the shard thread in the
//! same instant as its `wal_hwm` (one mailbox command), so they are
//! exactly consistent with the sealed log — unlike the global counters,
//! which connection threads keep incrementing while the checkpoint is
//! being cut and which therefore only carry the query/shed fields
//! authoritatively.
//!
//! The sann/swakde byte blocks are `sketch::snapshot` images and carry
//! their own magic + hostile-header validation; this layer only checks
//! framing (lengths against bytes present, whole-file CRC) and identity
//! (dim / shard count against the running config).
//!
//! Atomicity: the file is written to `checkpoint-<epoch>.ckpt.tmp` and
//! renamed into place — a crash mid-write leaves a `.tmp` that recovery
//! ignores, never a half-valid checkpoint. The newest previous checkpoint
//! is kept as a safety margin; anything older is pruned.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::{crc32, io, sync_dir};
use crate::util::bytes::{put_u32, put_u64, u32_le_at, Reader};

const MAGIC: &[u8; 8] = b"SKCKPT01";

/// Shards a checkpoint may claim (framing sanity; real services run a
/// handful).
const MAX_SHARDS: u64 = 1 << 12;

/// One shard's serialized state.
#[derive(Clone, Debug, PartialEq)]
pub struct ShardCheckpoint {
    /// WAL high-water mark: every record with `seq <= hwm` is inside this
    /// checkpoint; replay starts after it.
    pub hwm: u64,
    /// Points this shard had APPLIED at the hwm instant (including
    /// sampler-dropped ones — they tick the KDE window and are logged).
    pub applied_inserts: u64,
    /// Successful turnstile deletes applied at the hwm instant.
    pub applied_deletes: u64,
    /// `sketch::snapshot::save_sann` image.
    pub sann: Vec<u8>,
    /// `sketch::snapshot::save_swakde` image.
    pub swakde: Vec<u8>,
}

/// A decoded (but not yet sketch-validated) checkpoint.
#[derive(Clone, Debug, PartialEq)]
pub struct CheckpointData {
    pub epoch: u64,
    pub dim: u64,
    /// inserts, deletes, ann_queries, kde_queries, shed — the service's
    /// point-denominated counters at checkpoint time.
    pub counters: [u64; 5],
    pub shards: Vec<ShardCheckpoint>,
}

impl CheckpointData {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        put_u64(&mut out, self.epoch);
        put_u64(&mut out, self.dim);
        put_u64(&mut out, self.shards.len() as u64);
        for c in self.counters {
            put_u64(&mut out, c);
        }
        for s in &self.shards {
            put_u64(&mut out, s.hwm);
            put_u64(&mut out, s.applied_inserts);
            put_u64(&mut out, s.applied_deletes);
            put_u64(&mut out, s.sann.len() as u64);
            out.extend_from_slice(&s.sann);
            put_u64(&mut out, s.swakde.len() as u64);
            out.extend_from_slice(&s.swakde);
        }
        let crc = crc32(&out);
        put_u32(&mut out, crc);
        out
    }

    /// Decode + validate framing. Untrusted input: lengths are checked
    /// against the bytes present before anything is sliced, and the
    /// whole-file CRC must match.
    pub fn decode(bytes: &[u8]) -> Result<CheckpointData> {
        if bytes.len() < MAGIC.len() + 4 || &bytes[..8] != MAGIC {
            bail!("not a checkpoint file (bad magic)");
        }
        let body = &bytes[..bytes.len() - 4];
        let want_crc = u32_le_at(bytes, bytes.len() - 4)?;
        if crc32(body) != want_crc {
            bail!("checkpoint CRC mismatch");
        }
        let mut r = Reader::new(&body[8..]);
        let epoch = r.u64()?;
        let dim = r.u64()?;
        let n_shards = r.u64()?;
        if n_shards == 0 || n_shards > MAX_SHARDS {
            bail!("checkpoint claims {n_shards} shards (cap {MAX_SHARDS})");
        }
        let counters = [r.u64()?, r.u64()?, r.u64()?, r.u64()?, r.u64()?];
        let mut shards = Vec::with_capacity(n_shards.min(64) as usize);
        for _ in 0..n_shards {
            let hwm = r.u64()?;
            let applied_inserts = r.u64()?;
            let applied_deletes = r.u64()?;
            let sann_len = r.u64()?;
            let sann = r.take_len(sann_len)?.to_vec();
            let swakde_len = r.u64()?;
            let swakde = r.take_len(swakde_len)?.to_vec();
            shards.push(ShardCheckpoint {
                hwm,
                applied_inserts,
                applied_deletes,
                sann,
                swakde,
            });
        }
        r.finish()?;
        Ok(CheckpointData { epoch, dim, counters, shards })
    }
}

fn path_for(data_dir: &Path, epoch: u64) -> PathBuf {
    data_dir.join(format!("checkpoint-{epoch:020}.ckpt"))
}

/// All checkpoint files, sorted ascending by epoch.
pub fn list(data_dir: &Path) -> Result<Vec<(u64, PathBuf)>> {
    let mut out = Vec::new();
    let entries = match std::fs::read_dir(data_dir) {
        Ok(e) => e,
        Err(_) => return Ok(out),
    };
    for entry in entries {
        let entry = entry?;
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if let Some(rest) = name.strip_prefix("checkpoint-") {
            if let Some(epoch_str) = rest.strip_suffix(".ckpt") {
                if let Ok(epoch) = epoch_str.parse::<u64>() {
                    out.push((epoch, entry.path()));
                }
            }
        }
    }
    out.sort();
    Ok(out)
}

/// Write atomically: temp file, fsync its contents, rename into place,
/// then fsync the directory — the rename itself is not durable until the
/// directory entry is, and WAL GC runs right after this returns, so a
/// power loss must never persist the unlinks without the rename. Finally
/// prune all but the newest previous checkpoint.
pub fn write_atomic(data_dir: &Path, data: &CheckpointData) -> Result<PathBuf> {
    std::fs::create_dir_all(data_dir)
        .with_context(|| format!("creating data dir {data_dir:?}"))?;
    let final_path = path_for(data_dir, data.epoch);
    let tmp_path = final_path.with_extension("ckpt.tmp");
    let bytes = data.encode();
    {
        let mut opts = std::fs::OpenOptions::new();
        opts.write(true).create(true).truncate(true);
        let mut f = io::open(&opts, &tmp_path)
            .with_context(|| format!("creating {tmp_path:?}"))?;
        io::write_all(&mut f, &bytes)?;
        io::sync_data(&f)?;
    }
    io::rename(&tmp_path, &final_path)
        .with_context(|| format!("renaming checkpoint into place at {final_path:?}"))?;
    sync_dir(data_dir)?;
    // Prune: keep this one and the newest predecessor (safety margin —
    // WAL GC only ever trusts the newest, so older files are dead weight).
    let all = list(data_dir)?;
    if all.len() > 2 {
        for (_, path) in &all[..all.len() - 2] {
            let _ = std::fs::remove_file(path);
        }
        let _ = sync_dir(data_dir);
    }
    Ok(final_path)
}

/// Load the newest checkpoint that decodes cleanly; invalid files are
/// skipped with a warning (rename atomicity means this only happens under
/// real disk corruption).
pub fn load_latest(data_dir: &Path) -> Result<Option<CheckpointData>> {
    let mut all = list(data_dir)?;
    all.reverse();
    for (epoch, path) in all {
        let bytes =
            std::fs::read(&path).with_context(|| format!("reading checkpoint {path:?}"))?;
        match CheckpointData::decode(&bytes) {
            Ok(data) => return Ok(Some(data)),
            Err(e) => {
                crate::obs::log::warn(
                    "durability::checkpoint",
                    "skipping invalid checkpoint",
                    crate::kv!(epoch = epoch, err = e),
                );
            }
        }
    }
    Ok(None)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "sketchd_ckpt_{tag}_{}_{}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn sample(epoch: u64) -> CheckpointData {
        CheckpointData {
            epoch,
            dim: 8,
            counters: [100, 2, 30, 40, 5],
            shards: vec![
                ShardCheckpoint {
                    hwm: 50,
                    applied_inserts: 49,
                    applied_deletes: 1,
                    sann: vec![1, 2, 3],
                    swakde: vec![9; 10],
                },
                ShardCheckpoint {
                    hwm: 48,
                    applied_inserts: 48,
                    applied_deletes: 0,
                    sann: vec![],
                    swakde: vec![7],
                },
            ],
        }
    }

    #[test]
    fn roundtrip() {
        let data = sample(3);
        let back = CheckpointData::decode(&data.encode()).unwrap();
        assert_eq!(back, data);
    }

    #[test]
    fn corruption_and_truncation_are_rejected() {
        let bytes = sample(1).encode();
        for cut in 0..bytes.len() {
            assert!(CheckpointData::decode(&bytes[..cut]).is_err(), "prefix {cut}");
        }
        for i in 0..bytes.len() {
            let mut m = bytes.clone();
            m[i] ^= 0x40;
            assert!(
                CheckpointData::decode(&m).is_err(),
                "whole-file CRC must catch a flip at byte {i}"
            );
        }
        let mut extra = bytes.clone();
        extra.push(0);
        assert!(CheckpointData::decode(&extra).is_err(), "CRC covers length too");
    }

    #[test]
    fn hostile_lengths_are_rejected_before_allocation() {
        // Hand-build a frame claiming a huge shard count / block length
        // with a VALID CRC, so the length checks themselves are exercised.
        let mut body = Vec::new();
        body.extend_from_slice(MAGIC);
        body.extend_from_slice(&1u64.to_le_bytes()); // epoch
        body.extend_from_slice(&8u64.to_le_bytes()); // dim
        body.extend_from_slice(&u64::MAX.to_le_bytes()); // shards
        for _ in 0..5 {
            body.extend_from_slice(&0u64.to_le_bytes());
        }
        let crc = crc32(&body);
        body.extend_from_slice(&crc.to_le_bytes());
        let err = CheckpointData::decode(&body).unwrap_err().to_string();
        assert!(err.contains("shards"), "{err}");

        let mut body = Vec::new();
        body.extend_from_slice(MAGIC);
        body.extend_from_slice(&1u64.to_le_bytes());
        body.extend_from_slice(&8u64.to_le_bytes());
        body.extend_from_slice(&1u64.to_le_bytes()); // one shard
        for _ in 0..5 {
            body.extend_from_slice(&0u64.to_le_bytes());
        }
        body.extend_from_slice(&0u64.to_le_bytes()); // hwm
        body.extend_from_slice(&0u64.to_le_bytes()); // applied_inserts
        body.extend_from_slice(&0u64.to_le_bytes()); // applied_deletes
        body.extend_from_slice(&u64::MAX.to_le_bytes()); // sann_len: hostile
        let crc = crc32(&body);
        body.extend_from_slice(&crc.to_le_bytes());
        let err = CheckpointData::decode(&body).unwrap_err().to_string();
        assert!(err.contains("exceeds"), "{err}");
    }

    #[test]
    fn write_load_prune_cycle() {
        let dir = tmp_dir("cycle");
        assert!(load_latest(&dir).unwrap().is_none());
        for epoch in 1..=4 {
            write_atomic(&dir, &sample(epoch)).unwrap();
        }
        let latest = load_latest(&dir).unwrap().unwrap();
        assert_eq!(latest.epoch, 4);
        let files = list(&dir).unwrap();
        assert_eq!(files.len(), 2, "older checkpoints pruned: {files:?}");
        assert_eq!(files[0].0, 3);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupt_newest_falls_back_to_predecessor() {
        let dir = tmp_dir("fallback");
        write_atomic(&dir, &sample(1)).unwrap();
        write_atomic(&dir, &sample(2)).unwrap();
        // Corrupt epoch 2 on disk.
        let (_, newest) = list(&dir).unwrap().pop().unwrap();
        let mut bytes = std::fs::read(&newest).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&newest, &bytes).unwrap();
        let got = load_latest(&dir).unwrap().unwrap();
        assert_eq!(got.epoch, 1, "newest is corrupt, predecessor wins");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn tmp_files_are_ignored() {
        let dir = tmp_dir("tmp");
        write_atomic(&dir, &sample(5)).unwrap();
        std::fs::write(dir.join("checkpoint-00000000000000000009.ckpt.tmp"), b"junk")
            .unwrap();
        assert_eq!(load_latest(&dir).unwrap().unwrap().epoch, 5);
        std::fs::remove_dir_all(&dir).ok();
    }
}
