//! Startup recovery: newest valid checkpoint + WAL replay past its
//! high-water mark.
//!
//! The split of labor with the coordinator: this module turns on-disk
//! state into validated per-shard checkpoint images (raw `save_sann` /
//! `save_swakde` bytes, counters, per-shard hwm); the coordinator
//! (`SketchService::start`) decodes each image once PER REPLICA — the
//! checkpoint stores exactly one image per shard regardless of the
//! replica count, and rehydration fans it out into `R` bit-identical
//! copies — and drives `wal::replay` with each replica's own apply
//! callback, so replayed records run through exactly the code path that
//! applied them originally (S-ANN re-insert of retained points, SW-AKDE
//! window tick for every point, turnstile delete).

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::sketch::snapshot::{load_sann, load_swakde};
use crate::sketch::{SAnn, SwAkde};

use super::checkpoint;

/// One shard's recovered (checkpoint-resident) state: the raw sketch
/// images, kept serialized so the coordinator can decode one copy per
/// replica. `None` images mean "no checkpoint yet — start empty and
/// replay the whole WAL".
#[derive(Default)]
pub struct RecoveredShard {
    /// `(save_sann, save_swakde)` image bytes, covered by the
    /// checkpoint's whole-file CRC; [`Self::decode_images`] runs the
    /// sketch-level validation when a replica is built from them.
    pub images: Option<(Vec<u8>, Vec<u8>)>,
    /// Replay starts after this sequence number.
    pub hwm: u64,
    /// Applied mutation counts at the hwm instant (restored into the
    /// shard so its NEXT checkpoint stays consistent).
    pub applied_inserts: u64,
    pub applied_deletes: u64,
}

impl RecoveredShard {
    /// Decode one fresh `(S-ANN, SW-AKDE)` pair from the checkpoint
    /// images — called once per replica, so every copy rehydrates from
    /// the same bytes.
    pub fn decode_images(&self) -> Result<Option<(SAnn, SwAkde)>> {
        let Some((sann_img, swakde_img)) = &self.images else {
            return Ok(None);
        };
        Ok(Some((load_sann(sann_img)?, load_swakde(swakde_img)?)))
    }
}

/// Whole-service recovered state.
pub struct Recovered {
    /// Checkpoint epoch the state came from (0 = no checkpoint found).
    pub epoch: u64,
    /// inserts, deletes, ann_queries, kde_queries, shed at checkpoint
    /// time (WAL replay adds on top).
    pub counters: [u64; 5],
    pub shards: Vec<RecoveredShard>,
}

/// Load the newest valid checkpoint under `data_dir` (whole-file CRC
/// and shape validated by `checkpoint::load_latest`) and hand the shard
/// images out serialized; the sketch-level decode — and its hostile-
/// header validation — happens exactly once per replica in
/// [`RecoveredShard::decode_images`], so recovery never deserializes an
/// image it won't use. `dim`/`shards` are the RUNNING config — a
/// checkpoint written under a different shape is an operator error, not
/// something to silently reinterpret. The replica count is deliberately
/// NOT part of the on-disk shape: one image per shard rehydrates any R.
pub fn recover(data_dir: &Path, dim: usize, shards: usize) -> Result<Recovered> {
    std::fs::create_dir_all(data_dir)
        .with_context(|| format!("creating data dir {data_dir:?}"))?;
    let Some(data) = checkpoint::load_latest(data_dir)? else {
        return Ok(Recovered {
            epoch: 0,
            counters: [0; 5],
            shards: (0..shards).map(|_| RecoveredShard::default()).collect(),
        });
    };
    if data.dim != dim as u64 {
        bail!(
            "checkpoint epoch {} is for dim {}, service configured with dim {dim}",
            data.epoch,
            data.dim
        );
    }
    if data.shards.len() != shards {
        bail!(
            "checkpoint epoch {} has {} shards, service configured with {shards} \
             (resharding a data_dir is not supported)",
            data.epoch,
            data.shards.len()
        );
    }
    let mut out = Vec::with_capacity(shards);
    for sc in data.shards {
        out.push(RecoveredShard {
            images: Some((sc.sann, sc.swakde)),
            hwm: sc.hwm,
            applied_inserts: sc.applied_inserts,
            applied_deletes: sc.applied_deletes,
        });
    }
    Ok(Recovered { epoch: data.epoch, counters: data.counters, shards: out })
}
