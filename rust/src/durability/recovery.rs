//! Startup recovery: newest valid checkpoint + WAL replay past its
//! high-water mark.
//!
//! The split of labor with the coordinator: this module turns on-disk
//! state into validated in-memory sketch states (`load_sann` /
//! `load_swakde` images per shard, counters, per-shard hwm); the
//! coordinator (`SketchService::start`) owns the shards and drives
//! `wal::replay` with each shard's own apply callback, so replayed
//! records run through exactly the code path that applied them
//! originally (S-ANN re-insert of retained points, SW-AKDE window tick
//! for every point, turnstile delete).

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::sketch::snapshot::{load_sann, load_swakde};
use crate::sketch::{SAnn, SwAkde};

use super::checkpoint;

/// One shard's recovered (checkpoint-resident) state. `None` sketches
/// mean "no checkpoint yet — start empty and replay the whole WAL".
#[derive(Default)]
pub struct RecoveredShard {
    pub sann: Option<SAnn>,
    pub swakde: Option<SwAkde>,
    /// Replay starts after this sequence number.
    pub hwm: u64,
    /// Applied mutation counts at the hwm instant (restored into the
    /// shard so its NEXT checkpoint stays consistent).
    pub applied_inserts: u64,
    pub applied_deletes: u64,
}

/// Whole-service recovered state.
pub struct Recovered {
    /// Checkpoint epoch the state came from (0 = no checkpoint found).
    pub epoch: u64,
    /// inserts, deletes, ann_queries, kde_queries, shed at checkpoint
    /// time (WAL replay adds on top).
    pub counters: [u64; 5],
    pub shards: Vec<RecoveredShard>,
}

/// Load the newest valid checkpoint under `data_dir` and decode every
/// shard's sketch images. `dim`/`shards` are the RUNNING config — a
/// checkpoint written under a different shape is an operator error, not
/// something to silently reinterpret.
pub fn recover(data_dir: &Path, dim: usize, shards: usize) -> Result<Recovered> {
    std::fs::create_dir_all(data_dir)
        .with_context(|| format!("creating data dir {data_dir:?}"))?;
    let Some(data) = checkpoint::load_latest(data_dir)? else {
        return Ok(Recovered {
            epoch: 0,
            counters: [0; 5],
            shards: (0..shards).map(|_| RecoveredShard::default()).collect(),
        });
    };
    if data.dim != dim as u64 {
        bail!(
            "checkpoint epoch {} is for dim {}, service configured with dim {dim}",
            data.epoch,
            data.dim
        );
    }
    if data.shards.len() != shards {
        bail!(
            "checkpoint epoch {} has {} shards, service configured with {shards} \
             (resharding a data_dir is not supported)",
            data.epoch,
            data.shards.len()
        );
    }
    let mut out = Vec::with_capacity(shards);
    for (i, sc) in data.shards.iter().enumerate() {
        let sann = load_sann(&sc.sann).map_err(|e| {
            e.context(format!("shard {i}: S-ANN image in checkpoint {}", data.epoch))
        })?;
        let swakde = load_swakde(&sc.swakde).map_err(|e| {
            e.context(format!("shard {i}: SW-AKDE image in checkpoint {}", data.epoch))
        })?;
        out.push(RecoveredShard {
            sann: Some(sann),
            swakde: Some(swakde),
            hwm: sc.hwm,
            applied_inserts: sc.applied_inserts,
            applied_deletes: sc.applied_deletes,
        });
    }
    Ok(Recovered { epoch: data.epoch, counters: data.counters, shards: out })
}
