//! S-ANN — the paper's streaming (c, r)-Approximate Near Neighbor sketch
//! (Algorithm 1, Theorem 3.1).
//!
//! Insert path: each arriving point is retained with probability n^{−η}
//! (the sublinear sample); a retained point is stored in the arena and
//! inserted into L bucket tables under g_j = (h_{jk+1}…h_{jk+k}).
//!
//! Query path: probe g_j(q) for j = 1…L, collecting candidates until the
//! 3L cap (event E₂'s budget), dedupe, re-rank by true distance, and
//! return the best candidate iff it lies within r₂ = c·r — otherwise NULL,
//! exactly as Algorithm 1 specifies.
//!
//! Deletions (turnstile model, §3.4) tombstone the arena entry and remove
//! postings; guarantees hold while ≤ d deletions hit any r-ball
//! (Theorem 3.3) — see `turnstile.rs` for budget accounting.

use crate::lsh::concat::TableHasher;
use crate::lsh::params::{AnnParams, Sensitivity};
use crate::lsh::pstable::PStableLsh;
use crate::lsh::LshFamily;
use crate::sketch::sampler::BernoulliSampler;
use crate::storage::{TableSet, VecStore};
use crate::util::l2_sq;

/// Construction parameters for an S-ANN sketch.
#[derive(Clone, Debug, PartialEq)]
pub struct SAnnConfig {
    pub dim: usize,
    /// Stream-size upper bound n.
    pub n_max: usize,
    /// Sampling exponent η ∈ \[0, 1\]; retention probability is n^{−η}.
    pub eta: f64,
    /// Near radius r.
    pub r: f64,
    /// Approximation factor c > 1 (r₂ = c·r).
    pub c: f64,
    /// p-stable bucket width w.
    pub w: f64,
    /// Practical cap on L (Lemma 3.3 can demand large L at big n).
    pub l_cap: usize,
    pub seed: u64,
}

impl SAnnConfig {
    pub fn sensitivity(&self) -> Sensitivity {
        Sensitivity::pstable(self.r, self.c, self.w)
    }
}

/// Per-query diagnostics.
#[derive(Clone, Copy, Debug, Default)]
pub struct QueryStats {
    /// Bucket postings scanned (before dedupe).
    pub scanned: usize,
    /// Distinct candidates re-ranked.
    pub candidates: usize,
    /// Tables probed before the 3L cap fired.
    pub tables_probed: usize,
}

/// The streaming sketch.
pub struct SAnn {
    cfg: SAnnConfig,
    params: AnnParams,
    family: PStableLsh,
    hasher: TableHasher,
    tables: TableSet,
    store: VecStore,
    sampler: BernoulliSampler,
    /// Scratch reused across inserts/queries (hot path: no allocation).
    key_scratch: Vec<u64>,
    slot_scratch: Vec<i64>,
    flat_scratch: Vec<f32>,
    cand_scratch: Vec<u32>,
    /// Generation-stamped seen-bitmap keyed by arena id: `seen_stamp[id] ==
    /// seen_gen` means id was already collected this query. Replaces the
    /// per-query `HashSet<u32>` — dedupe becomes one indexed load/store
    /// with no hashing and no rehash growth on the query path.
    seen_stamp: Vec<u32>,
    seen_gen: u32,
}

impl SAnn {
    pub fn new(cfg: SAnnConfig) -> Self {
        let sens = cfg.sensitivity();
        let params = AnnParams::derive(&sens, cfg.n_max, cfg.eta, cfg.l_cap);
        let mut rng = crate::util::rng::Rng::new(cfg.seed);
        let family = PStableLsh::new(cfg.dim, params.k * params.l, cfg.w as f32, &mut rng);
        let hasher = TableHasher::new(params.k, params.l);
        let tables = TableSet::new(params.l);
        let store = VecStore::new(cfg.dim);
        let sampler = BernoulliSampler::with_prob(params.keep_prob, cfg.seed ^ 0xA5A5);
        SAnn {
            cfg,
            params,
            family,
            hasher,
            tables,
            store,
            sampler,
            key_scratch: Vec::new(),
            slot_scratch: Vec::new(),
            flat_scratch: Vec::new(),
            cand_scratch: Vec::new(),
            seen_stamp: Vec::new(),
            seen_gen: 0,
        }
    }

    pub fn params(&self) -> &AnnParams {
        &self.params
    }

    pub fn config(&self) -> &SAnnConfig {
        &self.cfg
    }

    pub fn family(&self) -> &PStableLsh {
        &self.family
    }

    pub fn hasher(&self) -> &TableHasher {
        &self.hasher
    }

    /// Points currently stored (retained and not deleted).
    pub fn stored(&self) -> usize {
        self.store.live()
    }

    /// Draw the next sampler decision (exposed for insert paths where the
    /// hashing was done externally, e.g. the PJRT bulk-load).
    pub fn sampler_keep(&mut self) -> bool {
        self.sampler.keep()
    }

    /// Stream elements offered to the Bernoulli sampler so far
    /// (observability: the eviction rate is `1 - kept/seen`).
    pub fn sampler_seen(&self) -> u64 {
        self.sampler.seen()
    }

    /// Sampler decisions that retained the element.
    pub fn sampler_kept(&self) -> u64 {
        self.sampler.kept()
    }

    /// Offer a stream element; returns the id if it was retained.
    pub fn insert(&mut self, x: &[f32]) -> Option<u32> {
        if !self.sampler.keep() {
            return None;
        }
        Some(self.insert_retained(x))
    }

    /// Insert bypassing the sampler (bulk loads where sampling was already
    /// applied upstream, and η = 0 contract tests).
    pub fn insert_retained(&mut self, x: &[f32]) -> u32 {
        let id = self.store.push(x);
        let (hasher, family) = (&self.hasher, &self.family);
        hasher.keys(family, x, &mut self.key_scratch, &mut self.slot_scratch);
        self.tables.insert(&self.key_scratch, id);
        id
    }

    /// Batched stream offer: sampler decisions are drawn in stream order,
    /// then every retained point hashes through one GEMM-shaped kernel
    /// call. State-identical to a loop of `insert`.
    pub fn insert_batch(&mut self, xs: &[Vec<f32>]) -> Vec<Option<u32>> {
        let mut out = vec![None; xs.len()];
        let mut kept: Vec<usize> = Vec::with_capacity(xs.len());
        for i in 0..xs.len() {
            if self.sampler.keep() {
                kept.push(i);
            }
        }
        if kept.is_empty() {
            return out;
        }
        let mut flat = std::mem::take(&mut self.flat_scratch);
        flat.clear();
        for &i in &kept {
            debug_assert_eq!(xs[i].len(), self.cfg.dim);
            flat.extend_from_slice(&xs[i]);
        }
        let h = self.params.k * self.params.l;
        let mut slots = std::mem::take(&mut self.slot_scratch);
        slots.clear();
        slots.resize(kept.len() * h, 0);
        self.family.hash_batch(0, &flat, &mut slots);
        for (bi, &i) in kept.iter().enumerate() {
            out[i] = Some(self.insert_retained_slots(&xs[i], &slots[bi * h..(bi + 1) * h]));
        }
        self.slot_scratch = slots;
        self.flat_scratch = flat;
        out
    }

    /// Insert with externally precomputed raw hash slots (PJRT batch path;
    /// slots laid out `\[k*L\]` exactly as the `pstable_hash_*` artifact emits).
    pub fn insert_retained_slots(&mut self, x: &[f32], slots: &[i64]) -> u32 {
        let id = self.store.push(x);
        self.hasher.keys_from_slots(slots, &mut self.key_scratch);
        self.tables.insert(&self.key_scratch, id);
        id
    }

    /// Turnstile deletion of a point equal to `x` (no-op if no stored copy;
    /// the sampler may have dropped it). Returns whether a copy was removed.
    pub fn delete(&mut self, x: &[f32]) -> bool {
        let (hasher, family) = (&self.hasher, &self.family);
        hasher.keys(family, x, &mut self.key_scratch, &mut self.slot_scratch);
        // Find a live stored copy via table 0's bucket.
        let bucket = self.tables.probe(0, self.key_scratch[0]);
        let mut found: Option<u32> = None;
        for &id in bucket {
            if self.store.is_live(id) && self.store.get(id) == x {
                found = Some(id);
                break;
            }
        }
        match found {
            Some(id) => {
                self.tables.remove(&self.key_scratch, id);
                self.store.delete(id);
                true
            }
            None => false,
        }
    }

    /// Algorithm 1 query: nearest candidate within r₂ = c·r, else None.
    pub fn query(&mut self, q: &[f32]) -> Option<(u32, f32)> {
        let (best, _) = self.query_with_stats(q);
        best
    }

    /// Query returning diagnostics (bench instrumentation).
    pub fn query_with_stats(&mut self, q: &[f32]) -> (Option<(u32, f32)>, QueryStats) {
        let mut stats = QueryStats::default();
        self.collect_candidates(q, &mut stats);
        let r2_sq = (self.cfg.c * self.cfg.r) as f32 * (self.cfg.c * self.cfg.r) as f32;
        let mut best: Option<(u32, f32)> = None;
        for &id in &self.cand_scratch {
            let d = l2_sq(self.store.get(id), q);
            if best.map_or(true, |(_, bd)| d < bd) {
                best = Some((id, d));
            }
        }
        stats.candidates = self.cand_scratch.len();
        let ans = match best {
            Some((id, d_sq)) if d_sq <= r2_sq => Some((id, d_sq.sqrt())),
            _ => None,
        };
        (ans, stats)
    }

    /// Batched Algorithm 1 query: hash all queries' k·L raw functions with
    /// one GEMM-shaped kernel call, then probe/re-rank per query. Returns
    /// exactly the same answers as N sequential `query` calls.
    pub fn query_batch(&mut self, qs: &[Vec<f32>]) -> Vec<Option<(u32, f32)>> {
        let (answers, _) = self.query_batch_with_stats(qs);
        answers
    }

    /// Batched query returning aggregated diagnostics across the batch.
    pub fn query_batch_with_stats(
        &mut self,
        qs: &[Vec<f32>],
    ) -> (Vec<Option<(u32, f32)>>, QueryStats) {
        let mut agg = QueryStats::default();
        if qs.is_empty() {
            return (Vec::new(), agg);
        }
        let l = self.params.l;
        let mut flat = std::mem::take(&mut self.flat_scratch);
        flat.clear();
        for q in qs {
            debug_assert_eq!(q.len(), self.cfg.dim);
            flat.extend_from_slice(q);
        }
        let mut keys = std::mem::take(&mut self.key_scratch);
        {
            let (hasher, family) = (&self.hasher, &self.family);
            hasher.keys_batch(family, &flat, &mut keys, &mut self.slot_scratch);
        }
        let r2_sq = (self.cfg.c * self.cfg.r) as f32 * (self.cfg.c * self.cfg.r) as f32;
        let mut out = Vec::with_capacity(qs.len());
        for (qi, q) in qs.iter().enumerate() {
            let mut stats = QueryStats::default();
            self.probe_candidates(&keys[qi * l..(qi + 1) * l], &mut stats);
            let mut best: Option<(u32, f32)> = None;
            for &id in &self.cand_scratch {
                let d = l2_sq(self.store.get(id), q);
                if best.map_or(true, |(_, bd)| d < bd) {
                    best = Some((id, d));
                }
            }
            agg.scanned += stats.scanned;
            agg.candidates += self.cand_scratch.len();
            agg.tables_probed = agg.tables_probed.max(stats.tables_probed);
            out.push(match best {
                Some((id, d_sq)) if d_sq <= r2_sq => Some((id, d_sq.sqrt())),
                _ => None,
            });
        }
        self.key_scratch = keys;
        self.flat_scratch = flat;
        (out, agg)
    }

    /// Top-k candidates by true distance (for recall@k metrics); returns
    /// (id, distance) sorted ascending, at most k entries, from the same
    /// 3L-capped candidate set Algorithm 1 scans.
    pub fn query_topk(&mut self, q: &[f32], k: usize) -> Vec<(u32, f32)> {
        let mut stats = QueryStats::default();
        self.collect_candidates(q, &mut stats);
        let mut scored: Vec<(u32, f32)> = self
            .cand_scratch
            .iter()
            .map(|&id| (id, l2_sq(self.store.get(id), q).sqrt()))
            .collect();
        scored.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        scored.truncate(k);
        scored
    }

    /// Candidate ids for `q` under the 3L cap (exposed for the coordinator's
    /// batched rerank path, which re-ranks via the PJRT artifact instead).
    pub fn candidates(&mut self, q: &[f32]) -> &[u32] {
        let mut stats = QueryStats::default();
        self.collect_candidates(q, &mut stats);
        &self.cand_scratch
    }

    /// Candidates from PRECOMPUTED table keys (len = L) — the batched
    /// serving path hashes whole query batches through the batched kernel
    /// (or the PJRT `pstable_hash` artifact) and probes with the resulting
    /// keys, so the probe loop never touches the projection matrix.
    pub fn candidates_by_keys(&mut self, keys: &[u64]) -> &[u32] {
        debug_assert_eq!(keys.len(), self.params.l);
        let mut stats = QueryStats::default();
        self.probe_candidates(keys, &mut stats);
        &self.cand_scratch
    }

    /// Start a fresh seen-generation; stamps from earlier queries become
    /// stale automatically (one u32 compare instead of a hash probe).
    fn reset_seen(&mut self) {
        self.seen_gen = self.seen_gen.wrapping_add(1);
        if self.seen_gen == 0 {
            // u32 wrap: old stamps could alias the restarted generation.
            self.seen_stamp.clear();
            self.seen_gen = 1;
        }
        self.seen_stamp.resize(self.store.len(), 0);
    }

    /// Probe tables j = 1…L with precomputed keys, collecting deduped live
    /// candidates under the 3L cap (Algorithm 1's budget) into
    /// `cand_scratch`. Allocation-free: dedupe is the generation-stamped
    /// seen-bitmap keyed by arena id.
    fn probe_candidates(&mut self, keys: &[u64], stats: &mut QueryStats) {
        let cap = self.params.candidate_cap();
        self.reset_seen();
        self.cand_scratch.clear();
        let gen = self.seen_gen;
        'outer: for (j, &key) in keys.iter().enumerate() {
            stats.tables_probed = j + 1;
            for &id in self.tables.probe(j, key) {
                stats.scanned += 1;
                if self.store.is_live(id) {
                    let stamp = &mut self.seen_stamp[id as usize];
                    if *stamp != gen {
                        *stamp = gen;
                        self.cand_scratch.push(id);
                    }
                }
                // Algorithm 1: stop once 3L candidates are gathered.
                if self.cand_scratch.len() >= cap {
                    break 'outer;
                }
            }
        }
    }

    fn collect_candidates(&mut self, q: &[f32], stats: &mut QueryStats) {
        // One blocked kernel pass over the full [k·L, dim] projection block
        // computes every table key (instead of k·L separate strided dots),
        // then the probe loop walks buckets with zero further hashing.
        let mut keys = std::mem::take(&mut self.key_scratch);
        let (hasher, family) = (&self.hasher, &self.family);
        hasher.keys(family, q, &mut keys, &mut self.slot_scratch);
        self.probe_candidates(&keys, stats);
        self.key_scratch = keys;
    }

    /// Sketch memory: stored vectors + bucket tables (+ fixed overhead).
    /// The paper's compression metric divides this by N·d·4 bytes.
    pub fn memory_bytes(&self) -> usize {
        self.store.payload_bytes() + self.tables.memory_bytes() + std::mem::size_of::<Self>()
    }

    /// The raw stream footprint the paper normalizes against (bytes).
    pub fn raw_stream_bytes(&self) -> usize {
        self.cfg.n_max * self.cfg.dim * 4
    }

    /// Direct access to a stored vector (metric evaluation).
    pub fn vector(&self, id: u32) -> &[f32] {
        self.store.get(id)
    }

    /// Live (retained, undeleted) point ids (snapshot/persistence).
    pub fn live_ids(&self) -> impl Iterator<Item = u32> + '_ {
        self.store.live_ids()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lsh::LshFamily;
    use crate::util::rng::Rng;

    fn cfg(n: usize, eta: f64, dim: usize, seed: u64) -> SAnnConfig {
        SAnnConfig {
            dim,
            n_max: n,
            eta,
            r: 1.0,
            c: 2.0,
            w: 4.0,
            l_cap: 32,
            seed,
        }
    }

    fn random_point(rng: &mut Rng, dim: usize, scale: f32) -> Vec<f32> {
        (0..dim).map(|_| rng.gaussian_f32() * scale).collect()
    }

    #[test]
    fn eta_zero_stores_everything_and_finds_exact_duplicates() {
        let mut ann = SAnn::new(cfg(1000, 0.0, 8, 1));
        let mut rng = Rng::new(2);
        let pts: Vec<Vec<f32>> = (0..200).map(|_| random_point(&mut rng, 8, 5.0)).collect();
        for p in &pts {
            assert!(ann.insert(p).is_some(), "eta=0 must retain all");
        }
        assert_eq!(ann.stored(), 200);
        // Querying a stored point must find something within c*r = 2
        // (the point itself collides in every table).
        let mut hits = 0;
        for p in pts.iter().take(50) {
            if let Some((_, d)) = ann.query(p) {
                assert!(d <= 2.0 + 1e-5);
                hits += 1;
            }
        }
        assert!(hits >= 48, "hits={hits}/50");
    }

    #[test]
    fn query_returns_none_when_nothing_is_near() {
        let mut ann = SAnn::new(cfg(1000, 0.0, 8, 3));
        let mut rng = Rng::new(4);
        for _ in 0..100 {
            let mut p = random_point(&mut rng, 8, 1.0);
            p[0] += 100.0; // cluster far away
            ann.insert(&p);
        }
        let q = vec![0.0f32; 8];
        assert!(ann.query(&q).is_none());
    }

    #[test]
    fn sampling_rate_is_sublinear() {
        let n = 10_000;
        let mut ann = SAnn::new(cfg(n, 0.5, 4, 5));
        let mut rng = Rng::new(6);
        for _ in 0..n {
            ann.insert(&random_point(&mut rng, 4, 1.0));
        }
        let expect = (n as f64).powf(0.5);
        assert!(
            (ann.stored() as f64) < 3.0 * expect,
            "stored={} expect~{expect}",
            ann.stored()
        );
        assert!((ann.stored() as f64) > expect / 3.0);
    }

    #[test]
    fn candidate_cap_is_3l() {
        // Flood one location so every bucket is huge; candidates must cap.
        let mut ann = SAnn::new(cfg(1000, 0.0, 4, 7));
        let mut rng = Rng::new(8);
        for _ in 0..500 {
            let p: Vec<f32> = (0..4).map(|_| rng.gaussian_f32() * 0.01).collect();
            ann.insert(&p);
        }
        let q = vec![0.0f32; 4];
        let (ans, stats) = ann.query_with_stats(&q);
        assert!(ans.is_some());
        assert!(
            stats.candidates <= ann.params().candidate_cap(),
            "candidates={} cap={}",
            stats.candidates,
            ann.params().candidate_cap()
        );
    }

    #[test]
    fn delete_removes_the_point() {
        let mut ann = SAnn::new(cfg(100, 0.0, 6, 9));
        let mut rng = Rng::new(10);
        let p = random_point(&mut rng, 6, 1.0);
        ann.insert(&p);
        assert_eq!(ann.stored(), 1);
        assert!(ann.delete(&p));
        assert_eq!(ann.stored(), 0);
        assert!(ann.query(&p).is_none(), "deleted point must not be returned");
        assert!(!ann.delete(&p), "double delete is a no-op");
    }

    #[test]
    fn delete_unstored_point_is_noop() {
        let mut ann = SAnn::new(cfg(100, 1.0, 6, 11)); // eta=1: keeps ~nothing
        let mut rng = Rng::new(12);
        let p = random_point(&mut rng, 6, 1.0);
        ann.insert(&p); // almost surely dropped
        let removed = ann.delete(&p);
        // Either it was retained (and removed) or the delete is a no-op.
        assert_eq!(removed, ann.store.len() > ann.stored());
    }

    #[test]
    fn duplicate_inserts_delete_one_copy_at_a_time() {
        let mut ann = SAnn::new(cfg(100, 0.0, 4, 13));
        let p = vec![1.0f32, 2.0, 3.0, 4.0];
        ann.insert(&p);
        ann.insert(&p);
        assert_eq!(ann.stored(), 2);
        assert!(ann.delete(&p));
        assert_eq!(ann.stored(), 1);
        assert!(ann.query(&p).is_some(), "second copy still answers");
        assert!(ann.delete(&p));
        assert_eq!(ann.stored(), 0);
    }

    #[test]
    fn topk_is_sorted_and_bounded() {
        let mut ann = SAnn::new(cfg(1000, 0.0, 8, 15));
        let mut rng = Rng::new(16);
        for _ in 0..300 {
            ann.insert(&random_point(&mut rng, 8, 2.0));
        }
        let q = random_point(&mut rng, 8, 2.0);
        let top = ann.query_topk(&q, 10);
        assert!(top.len() <= 10);
        for w in top.windows(2) {
            assert!(w[0].1 <= w[1].1);
        }
    }

    #[test]
    fn insert_retained_slots_matches_native_hashing() {
        let mut a = SAnn::new(cfg(100, 0.0, 6, 17));
        let mut b = SAnn::new(cfg(100, 0.0, 6, 17));
        let mut rng = Rng::new(18);
        let funcs = a.params().k * a.params().l;
        for _ in 0..50 {
            let p = random_point(&mut rng, 6, 1.0);
            a.insert_retained(&p);
            let mut slots = vec![0i64; funcs];
            b.family.hash_range(0, &p, &mut slots);
            b.insert_retained_slots(&p, &slots);
        }
        // identical structures => identical query behavior
        for _ in 0..20 {
            let q = random_point(&mut rng, 6, 1.0);
            assert_eq!(a.query(&q), b.query(&q));
        }
    }

    #[test]
    fn insert_batch_matches_sequential_inserts() {
        // Same seed -> same sampler stream, so a batched insert must build
        // the exact same sketch as the sequential loop.
        let mut a = SAnn::new(cfg(1000, 0.4, 8, 21));
        let mut b = SAnn::new(cfg(1000, 0.4, 8, 21));
        let mut rng = Rng::new(22);
        let pts: Vec<Vec<f32>> = (0..120).map(|_| random_point(&mut rng, 8, 2.0)).collect();
        let seq: Vec<Option<u32>> = pts.iter().map(|p| a.insert(p)).collect();
        let bat = b.insert_batch(&pts);
        assert_eq!(seq, bat);
        assert_eq!(a.stored(), b.stored());
        for _ in 0..30 {
            let q = random_point(&mut rng, 8, 2.0);
            assert_eq!(a.query(&q), b.query(&q));
        }
    }

    #[test]
    fn query_batch_matches_sequential_queries() {
        let mut ann = SAnn::new(cfg(1000, 0.0, 8, 23));
        let mut rng = Rng::new(24);
        for _ in 0..200 {
            ann.insert(&random_point(&mut rng, 8, 2.0));
        }
        let qs: Vec<Vec<f32>> = (0..40).map(|_| random_point(&mut rng, 8, 2.0)).collect();
        let seq: Vec<_> = qs.iter().map(|q| ann.query(q)).collect();
        let bat = ann.query_batch(&qs);
        assert_eq!(seq, bat);
        assert!(ann.query_batch(&[]).is_empty());
    }

    #[test]
    fn seen_bitmap_survives_interleaved_inserts_and_queries() {
        // Inserts grow the arena between queries; the stamp vector must
        // track it and never double-count or panic.
        let mut ann = SAnn::new(cfg(1000, 0.0, 4, 25));
        let mut rng = Rng::new(26);
        for round in 0..8 {
            for _ in 0..40 {
                let p: Vec<f32> = (0..4).map(|_| rng.gaussian_f32() * 0.01).collect();
                ann.insert(&p);
            }
            let q = vec![0.0f32; 4];
            let (ans, stats) = ann.query_with_stats(&q);
            assert!(ans.is_some(), "round {round}");
            assert!(stats.candidates <= ann.params().candidate_cap());
            let cands = ann.candidates(&q).to_vec();
            let dedup: std::collections::HashSet<_> = cands.iter().collect();
            assert_eq!(dedup.len(), cands.len(), "no duplicate candidates");
        }
    }

    #[test]
    fn memory_accounting_sublinear_in_eta() {
        let n = 20_000;
        let build = |eta: f64| {
            let mut ann = SAnn::new(cfg(n, eta, 16, 19));
            let mut rng = Rng::new(20);
            for _ in 0..n {
                ann.insert(&random_point(&mut rng, 16, 1.0));
            }
            ann.memory_bytes()
        };
        let dense = build(0.0);
        let sparse = build(0.7);
        assert!(
            (sparse as f64) < dense as f64 / 10.0,
            "sparse={sparse} dense={dense}"
        );
    }
}
