//! Turnstile-model support for S-ANN (§3.4, Theorem 3.3).
//!
//! The theorem's assumption is that an adversary deletes at most d points
//! from any r-ball. [`DeletionBudget`] audits that assumption over a run:
//! it coarsens space into r-sized grid cells (a ball of radius r touches at
//! most 2^dim' cells of side r on its own axes — we track per-cell totals,
//! which upper-bound per-ball deletions within a constant) and reports the
//! worst cell. Experiments use it to *verify* the precondition of
//! Theorem 3.3 rather than trust it.

use std::collections::HashMap;

/// Tracks deletions per r-grid cell and flags budget violations.
pub struct DeletionBudget {
    r: f64,
    d_max: u64,
    counts: HashMap<Vec<i32>, u64>,
    /// Dimensions used for the grid key (high dims are truncated: grid
    /// occupancy in the first `key_dims` coordinates upper-bounds ball
    /// deletion counts more loosely but stays tractable).
    key_dims: usize,
    violations: u64,
}

impl DeletionBudget {
    pub fn new(r: f64, d_max: u64) -> Self {
        assert!(r > 0.0);
        DeletionBudget { r, d_max, counts: HashMap::new(), key_dims: 8, violations: 0 }
    }

    fn key(&self, x: &[f32]) -> Vec<i32> {
        x.iter()
            .take(self.key_dims)
            .map(|&v| (v as f64 / self.r).floor() as i32)
            .collect()
    }

    /// Record a deletion at `x`; returns false if the cell exceeded d_max.
    pub fn record(&mut self, x: &[f32]) -> bool {
        let k = self.key(x);
        let c = self.counts.entry(k).or_insert(0);
        *c += 1;
        if *c > self.d_max {
            self.violations += 1;
            false
        } else {
            true
        }
    }

    /// Largest per-cell deletion count seen.
    pub fn worst_cell(&self) -> u64 {
        self.counts.values().copied().max().unwrap_or(0)
    }

    pub fn violations(&self) -> u64 {
        self.violations
    }

    pub fn d_max(&self) -> u64 {
        self.d_max
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn within_budget_passes() {
        let mut b = DeletionBudget::new(1.0, 3);
        let p = [0.5f32, 0.5];
        assert!(b.record(&p));
        assert!(b.record(&p));
        assert!(b.record(&p));
        assert_eq!(b.violations(), 0);
        assert_eq!(b.worst_cell(), 3);
    }

    #[test]
    fn exceeding_budget_flags() {
        let mut b = DeletionBudget::new(1.0, 2);
        let p = [0.1f32, 0.1];
        b.record(&p);
        b.record(&p);
        assert!(!b.record(&p), "third delete in one cell must flag");
        assert_eq!(b.violations(), 1);
    }

    #[test]
    fn distant_points_use_separate_cells() {
        let mut b = DeletionBudget::new(1.0, 1);
        assert!(b.record(&[0.0f32, 0.0]));
        assert!(b.record(&[10.0f32, 10.0]));
        assert!(b.record(&[-10.0f32, 3.0]));
        assert_eq!(b.violations(), 0);
        assert_eq!(b.worst_cell(), 1);
    }

    #[test]
    fn grid_scales_with_r() {
        // Same two points: one cell under a coarse grid, two under a fine one.
        let a = [0.2f32, 0.2];
        let b_ = [0.8f32, 0.8];
        let mut coarse = DeletionBudget::new(1.0, 1);
        coarse.record(&a);
        assert!(!coarse.record(&b_), "both in the unit cell");
        let mut fine = DeletionBudget::new(0.5, 1);
        fine.record(&a);
        assert!(fine.record(&b_), "separate cells at r=0.5");
    }
}
