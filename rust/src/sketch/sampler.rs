//! The uniform retention sampler of Algorithm 1: each stream element is
//! kept independently with probability p = n^{−η} (Lemma 3.3's "uniform
//! sampling"). Seeded, so a run is reproducible, and stateless per element,
//! so shards can sample independently without coordination.

use crate::util::rng::Rng;

/// Bernoulli(n^{−η}) retention decisions.
pub struct BernoulliSampler {
    keep_prob: f64,
    rng: Rng,
    seen: u64,
    kept: u64,
}

impl BernoulliSampler {
    /// `n` is the stream-size upper bound N, `eta` the sampling exponent.
    pub fn new(n: usize, eta: f64, seed: u64) -> Self {
        assert!(n > 0);
        assert!((0.0..=1.0).contains(&eta));
        BernoulliSampler {
            keep_prob: (n as f64).powf(-eta),
            rng: Rng::new(seed),
            seen: 0,
            kept: 0,
        }
    }

    /// Explicit probability constructor (tests, η-sweeps).
    pub fn with_prob(keep_prob: f64, seed: u64) -> Self {
        assert!((0.0..=1.0).contains(&keep_prob));
        BernoulliSampler { keep_prob, rng: Rng::new(seed), seen: 0, kept: 0 }
    }

    pub fn keep_prob(&self) -> f64 {
        self.keep_prob
    }

    /// Decide whether to retain the next stream element.
    pub fn keep(&mut self) -> bool {
        self.seen += 1;
        let k = self.rng.bernoulli(self.keep_prob);
        self.kept += k as u64;
        k
    }

    pub fn seen(&self) -> u64 {
        self.seen
    }

    pub fn kept(&self) -> u64 {
        self.kept
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn eta_zero_keeps_everything() {
        let mut s = BernoulliSampler::new(1000, 0.0, 1);
        assert!((0..500).all(|_| s.keep()));
        assert_eq!(s.kept(), 500);
    }

    #[test]
    fn eta_one_keeps_one_over_n() {
        let mut s = BernoulliSampler::new(1000, 1.0, 2);
        let kept = (0..100_000).filter(|_| s.keep()).count();
        // E[kept] = 100. Allow 5 sigma.
        assert!((kept as f64 - 100.0).abs() < 50.0, "kept={kept}");
    }

    #[test]
    fn retention_rate_matches_n_pow_minus_eta() {
        let n = 10_000usize;
        let eta = 0.5;
        let mut s = BernoulliSampler::new(n, eta, 3);
        let trials = 200_000;
        let kept = (0..trials).filter(|_| s.keep()).count();
        let expect = trials as f64 * (n as f64).powf(-eta);
        assert!(
            (kept as f64 - expect).abs() < 5.0 * expect.sqrt() + 5.0,
            "kept={kept} expect={expect}"
        );
    }

    #[test]
    fn deterministic_for_seed() {
        let mut a = BernoulliSampler::new(100, 0.5, 9);
        let mut b = BernoulliSampler::new(100, 0.5, 9);
        for _ in 0..1000 {
            assert_eq!(a.keep(), b.keep());
        }
    }

    #[test]
    fn property_binomial_concentration() {
        // Retention counts concentrate like Binomial(n, p) — the premise of
        // Lemma 3.3's thinning argument.
        check("sampler_binomial", 20, |g| {
            let p = g.f64_in(0.01, 0.9);
            let n = g.size(1000, 20_000);
            let mut s = BernoulliSampler::with_prob(p, g.seed);
            let kept = (0..n).filter(|_| s.keep()).count() as f64;
            let mean = n as f64 * p;
            let sd = (n as f64 * p * (1.0 - p)).sqrt();
            if (kept - mean).abs() > 6.0 * sd + 1.0 {
                return Err(format!("n={n} p={p} kept={kept} mean={mean} sd={sd}"));
            }
            Ok(())
        });
    }
}
