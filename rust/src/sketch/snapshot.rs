//! Sketch persistence: save/restore S-ANN state across process restarts
//! (a serving system must not need a full stream replay to come back).
//!
//! Format (little-endian, versioned): the sketch CONFIG plus the retained
//! live vectors. Hash tables are rebuilt on load by re-hashing — the LSH
//! family is a deterministic function of the config seed, so the restored
//! structure is bit-identical to the saved one; the file stays small
//! (O(stored · dim) instead of O(tables)). Post-restore ingestion draws
//! fresh sampler randomness: Bernoulli retention is i.i.d., so the
//! distributional guarantees (Theorem 3.1) are unaffected.

use std::io::{Read, Write};

use anyhow::{bail, Context, Result};

use super::ann::{SAnn, SAnnConfig};

const MAGIC: &[u8; 8] = b"SANNSNP1";

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}

struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.i + n > self.b.len() {
            bail!("snapshot truncated at byte {}", self.i);
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

/// Serialize an S-ANN sketch (config + live vectors).
pub fn save_sann(ann: &SAnn) -> Vec<u8> {
    let cfg = ann.config();
    let mut out = Vec::new();
    out.extend_from_slice(MAGIC);
    put_u64(&mut out, cfg.dim as u64);
    put_u64(&mut out, cfg.n_max as u64);
    put_f64(&mut out, cfg.eta);
    put_f64(&mut out, cfg.r);
    put_f64(&mut out, cfg.c);
    put_f64(&mut out, cfg.w);
    put_u64(&mut out, cfg.l_cap as u64);
    put_u64(&mut out, cfg.seed);
    let live: Vec<u32> = ann.live_ids().collect();
    put_u64(&mut out, live.len() as u64);
    for id in live {
        for &v in ann.vector(id) {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }
    out
}

/// Caps on header-controlled sizes. Snapshots are restored from files a
/// serving process did not necessarily write itself, so every allocation
/// the header implies must be bounded BEFORE it happens: a hostile u64
/// `dim` would otherwise overflow `dim * 4` or drive `vec![0f32; dim]` /
/// `SAnn::new` (projection is `dim · k · L` floats) into absurd requests.
const MAX_DIM: u64 = 1 << 20;
const MAX_N_MAX: u64 = 1 << 44;
const MAX_L_CAP: u64 = 1 << 16;
/// Projection-matrix elements (`dim · k · L`) the derived params may imply
/// (1 GiB of f32 — far above any legitimate config, far below a DoS).
const MAX_PROJ_ELEMS: u64 = 1 << 28;

/// Raw (untrusted) header fields as read off the wire.
struct RawHeader {
    dim: u64,
    n_max: u64,
    eta: f64,
    r: f64,
    c: f64,
    w: f64,
    l_cap: u64,
    seed: u64,
}

/// Reject headers whose config cannot have come from [`save_sann`] (which
/// serializes an `SAnn` that was constructed, i.e. passed the library's
/// own asserts) or whose derived table parameters imply absurd
/// allocations. Returns the validated config.
fn validate_header(h: &RawHeader) -> Result<SAnnConfig> {
    if h.dim == 0 || h.dim > MAX_DIM {
        bail!("snapshot dim {} outside (0, {MAX_DIM}]", h.dim);
    }
    if h.n_max < 2 || h.n_max > MAX_N_MAX {
        bail!("snapshot n_max {} outside [2, {MAX_N_MAX}]", h.n_max);
    }
    if h.l_cap == 0 || h.l_cap > MAX_L_CAP {
        bail!("snapshot l_cap {} outside (0, {MAX_L_CAP}]", h.l_cap);
    }
    for (name, v) in [("eta", h.eta), ("r", h.r), ("c", h.c), ("w", h.w)] {
        if !v.is_finite() {
            bail!("snapshot {name} is not finite");
        }
    }
    if !(0.0..=1.0).contains(&h.eta) {
        bail!("snapshot eta {} outside [0, 1]", h.eta);
    }
    if h.r <= 0.0 || h.w <= 0.0 {
        bail!("snapshot r/w must be positive (r={}, w={})", h.r, h.w);
    }
    if h.c <= 1.0 {
        bail!("snapshot c {} must be > 1", h.c);
    }
    let cfg = SAnnConfig {
        dim: h.dim as usize,
        n_max: h.n_max as usize,
        eta: h.eta,
        r: h.r,
        c: h.c,
        w: h.w,
        l_cap: h.l_cap as usize,
        seed: h.seed,
    };
    // Derive the table parameters the constructor would (cheap, no
    // allocation) and bound the projection they imply: a near-1 p₂ (e.g. a
    // huge w relative to c·r) drives k → enormous even with sane fields.
    let params = crate::lsh::params::AnnParams::derive(
        &cfg.sensitivity(),
        cfg.n_max,
        cfg.eta,
        cfg.l_cap,
    );
    let proj = (params.k as u64)
        .checked_mul(params.l as u64)
        .and_then(|f| f.checked_mul(h.dim));
    match proj {
        Some(p) if p <= MAX_PROJ_ELEMS => Ok(cfg),
        _ => bail!(
            "snapshot config implies a {}x{} hash projection over dim {} (> {MAX_PROJ_ELEMS} elements)",
            params.k,
            params.l,
            h.dim
        ),
    }
}

/// Restore an S-ANN sketch from [`save_sann`] bytes. Headers are
/// untrusted: sizes use checked arithmetic and the implied payload must
/// match the snapshot length exactly before anything is allocated.
pub fn load_sann(bytes: &[u8]) -> Result<SAnn> {
    let mut r = Reader { b: bytes, i: 0 };
    if r.take(8)? != MAGIC {
        bail!("not an S-ANN snapshot (bad magic)");
    }
    let header = RawHeader {
        dim: r.u64()?,
        n_max: r.u64()?,
        eta: r.f64()?,
        r: r.f64()?,
        c: r.f64()?,
        w: r.f64()?,
        l_cap: r.u64()?,
        seed: r.u64()?,
    };
    let cfg = validate_header(&header)?;
    let n_live = r.u64()?;
    let implied = n_live
        .checked_mul(header.dim)
        .and_then(|v| v.checked_mul(4))
        .with_context(|| format!("snapshot payload size overflows (n_live={n_live})"))?;
    let present = (bytes.len() - r.i) as u64;
    if implied != present {
        bail!("snapshot header implies {implied} payload bytes, {present} present");
    }
    let dim = cfg.dim;
    let mut ann = SAnn::new(cfg);
    let mut buf = vec![0f32; dim];
    for _ in 0..n_live {
        let raw = r.take(dim * 4)?;
        for (j, c) in raw.chunks_exact(4).enumerate() {
            buf[j] = f32::from_le_bytes(c.try_into().unwrap());
        }
        ann.insert_retained(&buf);
    }
    if r.i != bytes.len() {
        bail!("snapshot has {} trailing bytes", bytes.len() - r.i);
    }
    Ok(ann)
}

/// Save to a file.
pub fn save_sann_file(ann: &SAnn, path: &std::path::Path) -> Result<()> {
    let bytes = save_sann(ann);
    std::fs::File::create(path)
        .and_then(|mut f| f.write_all(&bytes))
        .with_context(|| format!("writing snapshot {path:?}"))
}

/// Load from a file.
pub fn load_sann_file(path: &std::path::Path) -> Result<SAnn> {
    let mut bytes = Vec::new();
    std::fs::File::open(path)
        .and_then(|mut f| f.read_to_end(&mut bytes))
        .with_context(|| format!("reading snapshot {path:?}"))?;
    load_sann(&bytes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn build(n: usize) -> SAnn {
        let mut ann = SAnn::new(SAnnConfig {
            dim: 8,
            n_max: 1000,
            eta: 0.0,
            r: 1.0,
            c: 2.0,
            w: 4.0,
            l_cap: 16,
            seed: 77,
        });
        let mut rng = Rng::new(5);
        for _ in 0..n {
            let p: Vec<f32> = (0..8).map(|_| rng.gaussian_f32()).collect();
            ann.insert(&p);
        }
        ann
    }

    #[test]
    fn roundtrip_preserves_queries() {
        let mut ann = build(120);
        let bytes = save_sann(&ann);
        let mut restored = load_sann(&bytes).unwrap();
        assert_eq!(restored.stored(), ann.stored());
        let mut rng = Rng::new(6);
        for _ in 0..40 {
            let q: Vec<f32> = (0..8).map(|_| rng.gaussian_f32()).collect();
            assert_eq!(ann.query(&q), restored.query(&q), "restored sketch must answer identically");
        }
    }

    #[test]
    fn roundtrip_preserves_deletions() {
        let mut ann = build(50);
        // delete some points, snapshot, restore: tombstoned points gone
        let victim = ann.vector(3).to_vec();
        assert!(ann.delete(&victim));
        let before = ann.stored();
        let restored = load_sann(&save_sann(&ann)).unwrap();
        assert_eq!(restored.stored(), before);
    }

    #[test]
    fn file_roundtrip() {
        let ann = build(30);
        let path = std::env::temp_dir().join("sann_snapshot_test.bin");
        save_sann_file(&ann, &path).unwrap();
        let restored = load_sann_file(&path).unwrap();
        assert_eq!(restored.stored(), ann.stored());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn corrupt_snapshots_are_rejected() {
        let ann = build(10);
        let mut bytes = save_sann(&ann);
        assert!(load_sann(&bytes[..bytes.len() - 3]).is_err(), "truncated");
        bytes[0] = b'X';
        assert!(load_sann(&bytes).is_err(), "bad magic");
        let mut extra = save_sann(&ann);
        extra.push(0);
        assert!(load_sann(&extra).is_err(), "trailing bytes");
    }

    // Header byte offsets (after the 8-byte magic).
    const OFF_DIM: usize = 8;
    const OFF_ETA: usize = 24;
    const OFF_R: usize = 32;
    const OFF_C: usize = 40;
    const OFF_W: usize = 48;
    const OFF_L_CAP: usize = 56;
    const OFF_N_LIVE: usize = 72;

    fn patch_u64(bytes: &mut [u8], off: usize, v: u64) {
        bytes[off..off + 8].copy_from_slice(&v.to_le_bytes());
    }

    fn patch_f64(bytes: &mut [u8], off: usize, v: f64) {
        bytes[off..off + 8].copy_from_slice(&v.to_le_bytes());
    }

    #[test]
    fn hostile_dim_is_rejected_before_allocation() {
        let ann = build(5);
        // dim * 4 overflows u64; naive code would wrap, slice garbage, or
        // try a monstrous vec![0f32; dim].
        for dim in [u64::MAX, u64::MAX / 4 + 1, 1 << 32, 0] {
            let mut bytes = save_sann(&ann);
            patch_u64(&mut bytes, OFF_DIM, dim);
            assert!(load_sann(&bytes).is_err(), "dim={dim} must be rejected");
        }
    }

    #[test]
    fn hostile_n_live_is_rejected_by_payload_check() {
        let ann = build(5);
        for n_live in [u64::MAX, u64::MAX / 4, 1 << 40, 6, 4] {
            let mut bytes = save_sann(&ann);
            patch_u64(&mut bytes, OFF_N_LIVE, n_live);
            assert!(
                load_sann(&bytes).is_err(),
                "n_live={n_live} disagrees with the 5-vector payload"
            );
        }
    }

    #[test]
    fn hostile_config_fields_are_rejected() {
        let ann = build(3);
        let cases: [fn(&mut [u8]); 9] = [
            |b| patch_u64(b, OFF_L_CAP, u64::MAX),
            |b| patch_u64(b, OFF_L_CAP, 0),
            |b| patch_f64(b, OFF_ETA, f64::NAN),
            |b| patch_f64(b, OFF_ETA, 2.0),
            |b| patch_f64(b, OFF_R, -1.0),
            |b| patch_f64(b, OFF_R, f64::INFINITY),
            |b| patch_f64(b, OFF_C, 0.5),
            |b| patch_f64(b, OFF_W, 0.0),
            // Near-1 p2: w >> c*r explodes k; must trip the projection cap.
            |b| patch_f64(b, OFF_W, 1e9),
        ];
        for (i, patch) in cases.iter().enumerate() {
            let mut bytes = save_sann(&ann);
            patch(&mut bytes);
            assert!(load_sann(&bytes).is_err(), "case {i} must be rejected");
        }
    }

    #[test]
    fn legitimate_snapshots_still_load_after_hardening() {
        let ann = build(80);
        let restored = load_sann(&save_sann(&ann)).unwrap();
        assert_eq!(restored.stored(), ann.stored());
    }

    #[test]
    fn restored_sketch_accepts_new_inserts() {
        let ann = build(40);
        let mut restored = load_sann(&save_sann(&ann)).unwrap();
        let p = vec![9.0f32; 8];
        restored.insert(&p);
        assert_eq!(restored.stored(), 41);
        assert!(restored.query(&p).is_some());
    }
}
