//! Sketch persistence: save/restore sketch state across process restarts
//! (a serving system must not need a full stream replay to come back).
//! Three image formats, all little-endian, all magic-versioned, all
//! validated against hostile headers before any allocation:
//!
//! * **S-ANN** (`save_sann`/`load_sann`): the sketch CONFIG plus the
//!   retained live vectors. Hash tables are rebuilt on load by re-hashing
//!   — the LSH family is a deterministic function of the config seed, so
//!   the restored structure is bit-identical to the saved one; the file
//!   stays small (O(stored · dim) instead of O(tables)). Post-restore
//!   ingestion draws fresh sampler randomness: Bernoulli retention is
//!   i.i.d., so the distributional guarantees (Theorem 3.1) are
//!   unaffected.
//! * **RACE** (`save_race`/`load_race`): the bounded-hasher shape plus
//!   the raw R×W counter grid and net population — RACE's mergeable
//!   compact state is exactly what makes it worth persisting (CS20).
//! * **SW-AKDE** (`save_swakde`/`load_swakde`): hasher shape, ε'/window/
//!   clock, and every occupied cell's Exponential Histogram buckets
//!   verbatim, so a restored sketch answers windowed queries (and keeps
//!   expiring) bit-identically to the saved one.

use std::io::{Read, Write};

use anyhow::{anyhow, bail, Context, Result};

use super::ann::{SAnn, SAnnConfig};
use super::eh::ExpHistogram;
use super::race::Race;
use super::swakde::SwAkde;
use crate::lsh::concat::{BoundedHasher, CellMap};
use crate::util::bytes::{put_f64, put_i64, put_u32, put_u64, put_u8, Reader};

const MAGIC: &[u8; 8] = b"SANNSNP1";

/// Serialize an S-ANN sketch (config + live vectors).
pub fn save_sann(ann: &SAnn) -> Vec<u8> {
    let cfg = ann.config();
    let mut out = Vec::new();
    out.extend_from_slice(MAGIC);
    put_u64(&mut out, cfg.dim as u64);
    put_u64(&mut out, cfg.n_max as u64);
    put_f64(&mut out, cfg.eta);
    put_f64(&mut out, cfg.r);
    put_f64(&mut out, cfg.c);
    put_f64(&mut out, cfg.w);
    put_u64(&mut out, cfg.l_cap as u64);
    put_u64(&mut out, cfg.seed);
    let live: Vec<u32> = ann.live_ids().collect();
    put_u64(&mut out, live.len() as u64);
    for id in live {
        for &v in ann.vector(id) {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }
    out
}

/// Caps on header-controlled sizes. Snapshots are restored from files a
/// serving process did not necessarily write itself, so every allocation
/// the header implies must be bounded BEFORE it happens: a hostile u64
/// `dim` would otherwise overflow `dim * 4` or drive `vec![0f32; dim]` /
/// `SAnn::new` (projection is `dim · k · L` floats) into absurd requests.
const MAX_DIM: u64 = 1 << 20;
const MAX_N_MAX: u64 = 1 << 44;
const MAX_L_CAP: u64 = 1 << 16;
/// Projection-matrix elements (`dim · k · L`) the derived params may imply
/// (1 GiB of f32 — far above any legitimate config, far below a DoS).
const MAX_PROJ_ELEMS: u64 = 1 << 28;

/// Raw (untrusted) header fields as read off the wire.
struct RawHeader {
    dim: u64,
    n_max: u64,
    eta: f64,
    r: f64,
    c: f64,
    w: f64,
    l_cap: u64,
    seed: u64,
}

/// Reject headers whose config cannot have come from [`save_sann`] (which
/// serializes an `SAnn` that was constructed, i.e. passed the library's
/// own asserts) or whose derived table parameters imply absurd
/// allocations. Returns the validated config.
fn validate_header(h: &RawHeader) -> Result<SAnnConfig> {
    if h.dim == 0 || h.dim > MAX_DIM {
        bail!("snapshot dim {} outside (0, {MAX_DIM}]", h.dim);
    }
    if h.n_max < 2 || h.n_max > MAX_N_MAX {
        bail!("snapshot n_max {} outside [2, {MAX_N_MAX}]", h.n_max);
    }
    if h.l_cap == 0 || h.l_cap > MAX_L_CAP {
        bail!("snapshot l_cap {} outside (0, {MAX_L_CAP}]", h.l_cap);
    }
    for (name, v) in [("eta", h.eta), ("r", h.r), ("c", h.c), ("w", h.w)] {
        if !v.is_finite() {
            bail!("snapshot {name} is not finite");
        }
    }
    if !(0.0..=1.0).contains(&h.eta) {
        bail!("snapshot eta {} outside [0, 1]", h.eta);
    }
    if h.r <= 0.0 || h.w <= 0.0 {
        bail!("snapshot r/w must be positive (r={}, w={})", h.r, h.w);
    }
    if h.c <= 1.0 {
        bail!("snapshot c {} must be > 1", h.c);
    }
    let cfg = SAnnConfig {
        dim: h.dim as usize,
        n_max: h.n_max as usize,
        eta: h.eta,
        r: h.r,
        c: h.c,
        w: h.w,
        l_cap: h.l_cap as usize,
        seed: h.seed,
    };
    // Derive the table parameters the constructor would (cheap, no
    // allocation) and bound the projection they imply: a near-1 p₂ (e.g. a
    // huge w relative to c·r) drives k → enormous even with sane fields.
    let params = crate::lsh::params::AnnParams::derive(
        &cfg.sensitivity(),
        cfg.n_max,
        cfg.eta,
        cfg.l_cap,
    );
    let proj = (params.k as u64)
        .checked_mul(params.l as u64)
        .and_then(|f| f.checked_mul(h.dim));
    match proj {
        Some(p) if p <= MAX_PROJ_ELEMS => Ok(cfg),
        _ => bail!(
            "snapshot config implies a {}x{} hash projection over dim {} (> {MAX_PROJ_ELEMS} elements)",
            params.k,
            params.l,
            h.dim
        ),
    }
}

/// Restore an S-ANN sketch from [`save_sann`] bytes. Headers are
/// untrusted: sizes use checked arithmetic and the implied payload must
/// match the snapshot length exactly before anything is allocated.
pub fn load_sann(bytes: &[u8]) -> Result<SAnn> {
    let mut r = Reader::new(bytes);
    if r.take(8)? != MAGIC {
        bail!("not an S-ANN snapshot (bad magic)");
    }
    let header = RawHeader {
        dim: r.u64()?,
        n_max: r.u64()?,
        eta: r.f64()?,
        r: r.f64()?,
        c: r.f64()?,
        w: r.f64()?,
        l_cap: r.u64()?,
        seed: r.u64()?,
    };
    let cfg = validate_header(&header)?;
    let n_live = r.u64()?;
    let implied = n_live
        .checked_mul(header.dim)
        .and_then(|v| v.checked_mul(4))
        .with_context(|| format!("snapshot payload size overflows (n_live={n_live})"))?;
    let present = r.remaining() as u64;
    if implied != present {
        bail!("snapshot header implies {implied} payload bytes, {present} present");
    }
    let dim = cfg.dim;
    let mut ann = SAnn::new(cfg);
    let mut buf = vec![0f32; dim];
    for _ in 0..n_live {
        let raw = r.take(dim * 4)?;
        for (j, c) in raw.chunks_exact(4).enumerate() {
            buf[j] = f32::from_le_bytes(c.try_into().unwrap());
        }
        ann.insert_retained(&buf);
    }
    r.finish()?;
    Ok(ann)
}

/// Save to a file.
pub fn save_sann_file(ann: &SAnn, path: &std::path::Path) -> Result<()> {
    let bytes = save_sann(ann);
    std::fs::File::create(path)
        .and_then(|mut f| f.write_all(&bytes))
        .with_context(|| format!("writing snapshot {path:?}"))
}

/// Load from a file.
pub fn load_sann_file(path: &std::path::Path) -> Result<SAnn> {
    let mut bytes = Vec::new();
    std::fs::File::open(path)
        .and_then(|mut f| f.read_to_end(&mut bytes))
        .with_context(|| format!("reading snapshot {path:?}"))?;
    load_sann(&bytes)
}

// --------------------------------------------------------- RACE / SW-AKDE

const RACE_MAGIC: &[u8; 8] = b"RACESNP1";
const SWAKDE_MAGIC: &[u8; 8] = b"SWAKSNP1";

/// Bounded-hasher shape caps (shared by the RACE and SW-AKDE images):
/// generous versus any legitimate config, far below a DoS allocation.
const MAX_BH_P: u64 = 64;
const MAX_BH_ROWS: u64 = 1 << 16;
const MAX_BH_RANGE: u64 = 1 << 26;
/// Grid cap rows·range (4M cells: 32 MB of RACE counters, 64 MB of
/// SW-AKDE cell slots).
const MAX_BH_CELLS: u64 = 1 << 22;

fn save_bounded_hasher(out: &mut Vec<u8>, h: &BoundedHasher) {
    put_u8(
        out,
        match h.map {
            CellMap::PackBits => 0,
            CellMap::Rehash => 1,
        },
    );
    put_u64(out, h.p as u64);
    put_u64(out, h.rows as u64);
    put_u64(out, h.range as u64);
}

/// Read + validate a bounded-hasher shape. Returns a hasher whose
/// constructor asserts are all guaranteed to hold (the validation here is
/// strictly stronger), so hostile headers error instead of panicking.
fn load_bounded_hasher(r: &mut Reader<'_>) -> Result<BoundedHasher> {
    let map = r.u8()?;
    let p = r.u64()?;
    let rows = r.u64()?;
    let range = r.u64()?;
    if p == 0 || p > MAX_BH_P {
        bail!("snapshot hasher p {p} outside (0, {MAX_BH_P}]");
    }
    if rows == 0 || rows > MAX_BH_ROWS {
        bail!("snapshot hasher rows {rows} outside (0, {MAX_BH_ROWS}]");
    }
    if range == 0 || range > MAX_BH_RANGE {
        bail!("snapshot hasher range {range} outside (0, {MAX_BH_RANGE}]");
    }
    match rows.checked_mul(range) {
        Some(c) if c <= MAX_BH_CELLS => {}
        _ => bail!("snapshot grid {rows}x{range} exceeds {MAX_BH_CELLS} cells"),
    }
    match map {
        0 => {
            if p >= 32 || range != 1u64 << p {
                bail!("packed-cell snapshot has range {range}, want 2^{p}");
            }
            Ok(BoundedHasher::new_packed(p as usize, rows as usize))
        }
        1 => Ok(BoundedHasher::new(p as usize, rows as usize, range as usize)),
        other => bail!("unknown cell-map tag {other}"),
    }
}

/// Serialize a RACE sketch (hasher shape + counter grid + population).
/// The LSH family is externally owned (callers pass it to every RACE
/// call), so — like `save_sann` — only the shape needed to re-attach to
/// the same family is stored.
pub fn save_race(race: &Race) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(RACE_MAGIC);
    save_bounded_hasher(&mut out, race.hasher());
    put_i64(&mut out, race.population());
    for ace in race.aces() {
        for &c in ace.counts() {
            put_i64(&mut out, c);
        }
    }
    out
}

/// Restore a RACE sketch from [`save_race`] bytes. Headers are untrusted:
/// the shape is capped and the counter payload must match it exactly
/// before anything is allocated.
pub fn load_race(bytes: &[u8]) -> Result<Race> {
    let mut r = Reader::new(bytes);
    if r.take(8)? != RACE_MAGIC {
        bail!("not a RACE snapshot (bad magic)");
    }
    let hasher = load_bounded_hasher(&mut r)?;
    let population = r.i64()?;
    let cells = hasher.rows * hasher.range;
    let implied = (cells as u64) * 8; // cells ≤ MAX_BH_CELLS: no overflow
    let present = r.remaining() as u64;
    if implied != present {
        bail!("RACE snapshot implies {implied} counter bytes, {present} present");
    }
    let mut counts = Vec::with_capacity(cells);
    for _ in 0..cells {
        counts.push(r.i64()?);
    }
    Ok(Race::from_parts(hasher, &counts, population))
}

/// One Exponential Histogram: `u64 last_ts | u32 n_levels | n_levels ×
/// (u32 count | count × u64 ts)` — bucket timestamps verbatim, front
/// (newest) first, so the restored EH expires identically.
fn save_eh(out: &mut Vec<u8>, eh: &ExpHistogram) {
    put_u64(out, eh.last_ts());
    put_u32(out, eh.levels().len() as u32);
    for level in eh.levels() {
        put_u32(out, level.len() as u32);
        for &ts in level {
            put_u64(out, ts);
        }
    }
}

fn load_eh(r: &mut Reader<'_>, eps: f64, window: u64) -> Result<ExpHistogram> {
    let last_ts = r.u64()?;
    let n_levels = r.u32()? as usize;
    if n_levels > 63 {
        bail!("EH image claims {n_levels} bucket levels (max 63)");
    }
    let mut levels = Vec::with_capacity(n_levels);
    for _ in 0..n_levels {
        let count = r.u32()? as usize;
        if count.saturating_mul(8) > r.remaining() {
            bail!(
                "EH level of {count} buckets exceeds the {} bytes present",
                r.remaining()
            );
        }
        let mut level = Vec::with_capacity(count);
        for _ in 0..count {
            level.push(r.u64()?);
        }
        levels.push(level);
    }
    ExpHistogram::from_parts(eps, window, levels, last_ts)
        .map_err(|e| anyhow!("EH image invalid: {e}"))
}

/// Serialize an SW-AKDE sketch: hasher shape, ε'/window/stream clock, the
/// population EH, and every occupied cell's EH (index + buckets).
pub fn save_swakde(sw: &SwAkde) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(SWAKDE_MAGIC);
    save_bounded_hasher(&mut out, sw.hasher());
    put_f64(&mut out, sw.eps_eh());
    put_u64(&mut out, sw.window());
    put_u64(&mut out, sw.now());
    put_u8(&mut out, u8::from(sw.had_batch_tick()));
    save_eh(&mut out, sw.pop_eh());
    let occupied: Vec<(usize, &ExpHistogram)> = sw
        .cells_raw()
        .iter()
        .enumerate()
        .filter_map(|(i, c)| c.as_deref().map(|eh| (i, eh)))
        .collect();
    put_u64(&mut out, occupied.len() as u64);
    for (idx, eh) in occupied {
        put_u64(&mut out, idx as u64);
        save_eh(&mut out, eh);
    }
    out
}

/// Restore an SW-AKDE sketch from [`save_swakde`] bytes. Untrusted input:
/// shape caps, per-level byte accounting, EH structural validation
/// ([`ExpHistogram::from_parts`]), strictly-increasing cell indices, and
/// an exact trailing-bytes check.
pub fn load_swakde(bytes: &[u8]) -> Result<SwAkde> {
    let mut r = Reader::new(bytes);
    if r.take(8)? != SWAKDE_MAGIC {
        bail!("not an SW-AKDE snapshot (bad magic)");
    }
    let hasher = load_bounded_hasher(&mut r)?;
    let eps = r.f64()?;
    if !eps.is_finite() || !(eps > 0.0 && eps <= 1.0) {
        bail!("SW-AKDE snapshot eps {eps} outside (0, 1]");
    }
    let window = r.u64()?;
    if window == 0 {
        bail!("SW-AKDE snapshot window must be >= 1");
    }
    let now = r.u64()?;
    let had_batch_tick = match r.u8()? {
        0 => false,
        1 => true,
        other => bail!("bad batch-tick flag {other}"),
    };
    let pop = load_eh(&mut r, eps, window)?;
    // Every EH must sit at or behind the stream clock, or the first
    // post-restore add (now + 1) would violate the EH's monotonic-
    // timestamp invariant — a debug panic and silent estimate corruption
    // a CRC-valid hostile image could otherwise smuggle in.
    if pop.last_ts() > now {
        bail!(
            "SW-AKDE snapshot population EH is ahead of the stream clock ({} > {now})",
            pop.last_ts()
        );
    }
    let n_cells = hasher.rows * hasher.range;
    let n_occ = r.u64()?;
    if n_occ > n_cells as u64 {
        bail!("SW-AKDE snapshot claims {n_occ} occupied cells of a {n_cells}-cell grid");
    }
    let mut cells: Vec<Option<Box<ExpHistogram>>> = (0..n_cells).map(|_| None).collect();
    let mut next_min = 0u64;
    for _ in 0..n_occ {
        let idx = r.u64()?;
        if idx >= n_cells as u64 {
            bail!("cell index {idx} outside the {n_cells}-cell grid");
        }
        if idx < next_min {
            bail!("cell indices must be strictly increasing (saw {idx} after {next_min})");
        }
        next_min = idx + 1;
        let eh = load_eh(&mut r, eps, window)?;
        if eh.last_ts() > now {
            bail!(
                "SW-AKDE snapshot cell {idx} EH is ahead of the stream clock ({} > {now})",
                eh.last_ts()
            );
        }
        cells[idx as usize] = Some(Box::new(eh));
    }
    r.finish()?;
    Ok(SwAkde::from_parts(hasher, eps, window, now, pop, had_batch_tick, cells))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn build(n: usize) -> SAnn {
        let mut ann = SAnn::new(SAnnConfig {
            dim: 8,
            n_max: 1000,
            eta: 0.0,
            r: 1.0,
            c: 2.0,
            w: 4.0,
            l_cap: 16,
            seed: 77,
        });
        let mut rng = Rng::new(5);
        for _ in 0..n {
            let p: Vec<f32> = (0..8).map(|_| rng.gaussian_f32()).collect();
            ann.insert(&p);
        }
        ann
    }

    #[test]
    fn roundtrip_preserves_queries() {
        let mut ann = build(120);
        let bytes = save_sann(&ann);
        let mut restored = load_sann(&bytes).unwrap();
        assert_eq!(restored.stored(), ann.stored());
        let mut rng = Rng::new(6);
        for _ in 0..40 {
            let q: Vec<f32> = (0..8).map(|_| rng.gaussian_f32()).collect();
            assert_eq!(ann.query(&q), restored.query(&q), "restored sketch must answer identically");
        }
    }

    #[test]
    fn roundtrip_preserves_deletions() {
        let mut ann = build(50);
        // delete some points, snapshot, restore: tombstoned points gone
        let victim = ann.vector(3).to_vec();
        assert!(ann.delete(&victim));
        let before = ann.stored();
        let restored = load_sann(&save_sann(&ann)).unwrap();
        assert_eq!(restored.stored(), before);
    }

    #[test]
    fn file_roundtrip() {
        let ann = build(30);
        let path = std::env::temp_dir().join("sann_snapshot_test.bin");
        save_sann_file(&ann, &path).unwrap();
        let restored = load_sann_file(&path).unwrap();
        assert_eq!(restored.stored(), ann.stored());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn corrupt_snapshots_are_rejected() {
        let ann = build(10);
        let mut bytes = save_sann(&ann);
        assert!(load_sann(&bytes[..bytes.len() - 3]).is_err(), "truncated");
        bytes[0] = b'X';
        assert!(load_sann(&bytes).is_err(), "bad magic");
        let mut extra = save_sann(&ann);
        extra.push(0);
        assert!(load_sann(&extra).is_err(), "trailing bytes");
    }

    // Header byte offsets (after the 8-byte magic).
    const OFF_DIM: usize = 8;
    const OFF_ETA: usize = 24;
    const OFF_R: usize = 32;
    const OFF_C: usize = 40;
    const OFF_W: usize = 48;
    const OFF_L_CAP: usize = 56;
    const OFF_N_LIVE: usize = 72;

    fn patch_u64(bytes: &mut [u8], off: usize, v: u64) {
        bytes[off..off + 8].copy_from_slice(&v.to_le_bytes());
    }

    fn patch_f64(bytes: &mut [u8], off: usize, v: f64) {
        bytes[off..off + 8].copy_from_slice(&v.to_le_bytes());
    }

    #[test]
    fn hostile_dim_is_rejected_before_allocation() {
        let ann = build(5);
        // dim * 4 overflows u64; naive code would wrap, slice garbage, or
        // try a monstrous vec![0f32; dim].
        for dim in [u64::MAX, u64::MAX / 4 + 1, 1 << 32, 0] {
            let mut bytes = save_sann(&ann);
            patch_u64(&mut bytes, OFF_DIM, dim);
            assert!(load_sann(&bytes).is_err(), "dim={dim} must be rejected");
        }
    }

    #[test]
    fn hostile_n_live_is_rejected_by_payload_check() {
        let ann = build(5);
        for n_live in [u64::MAX, u64::MAX / 4, 1 << 40, 6, 4] {
            let mut bytes = save_sann(&ann);
            patch_u64(&mut bytes, OFF_N_LIVE, n_live);
            assert!(
                load_sann(&bytes).is_err(),
                "n_live={n_live} disagrees with the 5-vector payload"
            );
        }
    }

    #[test]
    fn hostile_config_fields_are_rejected() {
        let ann = build(3);
        let cases: [fn(&mut [u8]); 9] = [
            |b| patch_u64(b, OFF_L_CAP, u64::MAX),
            |b| patch_u64(b, OFF_L_CAP, 0),
            |b| patch_f64(b, OFF_ETA, f64::NAN),
            |b| patch_f64(b, OFF_ETA, 2.0),
            |b| patch_f64(b, OFF_R, -1.0),
            |b| patch_f64(b, OFF_R, f64::INFINITY),
            |b| patch_f64(b, OFF_C, 0.5),
            |b| patch_f64(b, OFF_W, 0.0),
            // Near-1 p2: w >> c*r explodes k; must trip the projection cap.
            |b| patch_f64(b, OFF_W, 1e9),
        ];
        for (i, patch) in cases.iter().enumerate() {
            let mut bytes = save_sann(&ann);
            patch(&mut bytes);
            assert!(load_sann(&bytes).is_err(), "case {i} must be rejected");
        }
    }

    #[test]
    fn legitimate_snapshots_still_load_after_hardening() {
        let ann = build(80);
        let restored = load_sann(&save_sann(&ann)).unwrap();
        assert_eq!(restored.stored(), ann.stored());
    }

    #[test]
    fn restored_sketch_accepts_new_inserts() {
        let ann = build(40);
        let mut restored = load_sann(&save_sann(&ann)).unwrap();
        let p = vec![9.0f32; 8];
        restored.insert(&p);
        assert_eq!(restored.stored(), 41);
        assert!(restored.query(&p).is_some());
    }

    // ------------------------------------------------- RACE / SW-AKDE

    use crate::lsh::pstable::PStableLsh;
    use crate::lsh::srp::SrpLsh;
    use crate::lsh::LshFamily;
    use crate::util::proptest::{check, Gen};

    /// Random family matching a bounded hasher's mode/shape.
    fn gen_family(
        g: &mut Gen,
        dim: usize,
        funcs: usize,
        packed: bool,
    ) -> Box<dyn LshFamily> {
        let mut rng = Rng::new(g.seed ^ 0xFA111);
        if packed {
            Box::new(SrpLsh::new(dim, funcs, &mut rng))
        } else {
            Box::new(PStableLsh::new(dim, funcs, 2.0, &mut rng))
        }
    }

    #[test]
    fn property_race_roundtrip_is_bit_identical() {
        check("race_snapshot_roundtrip", 30, |g| {
            let dim = g.usize_in(2, 12);
            let rows = g.usize_in(1, 12);
            let p = g.usize_in(1, 4);
            let packed = g.bool();
            let mut race = if packed {
                Race::new_srp(rows, p)
            } else {
                Race::new(rows, g.usize_in(2, 32), p)
            };
            let fam = gen_family(g, dim, rows * p, packed);
            for _ in 0..g.size(0, 120) {
                let x = g.vector(dim, 2.0);
                let delta = if g.bool() { 1 } else { -1 };
                race.update(fam.as_ref(), &x, delta);
            }
            let bytes = save_race(&race);
            let mut back = load_race(&bytes).map_err(|e| e.to_string())?;
            if back.population() != race.population() {
                return Err(format!(
                    "population {} != {}",
                    back.population(),
                    race.population()
                ));
            }
            let (mut a, mut b) = (vec![0.0; rows], vec![0.0; rows]);
            for _ in 0..8 {
                let q = g.vector(dim, 2.0);
                race.row_counts_into(fam.as_ref(), &q, &mut a);
                back.row_counts_into(fam.as_ref(), &q, &mut b);
                if a != b {
                    return Err(format!("row counts diverge: {a:?} vs {b:?}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn property_swakde_roundtrip_is_bit_identical() {
        check("swakde_snapshot_roundtrip", 25, |g| {
            let dim = g.usize_in(2, 10);
            let rows = g.usize_in(1, 8);
            let p = g.usize_in(1, 3);
            let window = [8u64, 32, 100][g.usize_in(0, 2)];
            let eps = [0.1, 0.25, 0.5][g.usize_in(0, 2)];
            let packed = g.bool();
            let mut sw = if packed {
                SwAkde::new_srp(rows, p, eps, window)
            } else {
                SwAkde::new(rows, g.usize_in(2, 16), p, eps, window)
            };
            let fam = gen_family(g, dim, rows * p, packed);
            // Mixed ingest: per-point ticks AND shared-timestamp batches,
            // so both population paths (exact and EH) get serialized.
            for _ in 0..g.size(0, 100) {
                if g.bool() {
                    sw.add(fam.as_ref(), &g.vector(dim, 2.0));
                } else {
                    let batch: Vec<Vec<f32>> =
                        (0..g.usize_in(1, 4)).map(|_| g.vector(dim, 2.0)).collect();
                    let refs: Vec<&[f32]> = batch.iter().map(|v| v.as_slice()).collect();
                    sw.add_batch(fam.as_ref(), &refs);
                }
            }
            let mut back = load_swakde(&save_swakde(&sw)).map_err(|e| e.to_string())?;
            if back.now() != sw.now() {
                return Err(format!("clock {} != {}", back.now(), sw.now()));
            }
            if back.population() != sw.population() {
                return Err(format!(
                    "population {} != {}",
                    back.population(),
                    sw.population()
                ));
            }
            let (mut a, mut b) = (vec![0.0; rows], vec![0.0; rows]);
            let mut compare = |sw: &mut SwAkde, back: &mut SwAkde, g: &mut Gen| {
                for _ in 0..6 {
                    let q = g.vector(dim, 2.0);
                    sw.row_estimates_into(fam.as_ref(), &q, &mut a);
                    back.row_estimates_into(fam.as_ref(), &q, &mut b);
                    if a != b {
                        return Err(format!("row estimates diverge: {a:?} vs {b:?}"));
                    }
                }
                Ok(())
            };
            compare(&mut sw, &mut back, g)?;
            // A restored sketch must keep ingesting and expiring in
            // lockstep with the original (the crash-recovery contract).
            for _ in 0..(2 * window as usize).min(80) {
                let x = g.vector(dim, 2.0);
                sw.add(fam.as_ref(), &x);
                back.add(fam.as_ref(), &x);
            }
            compare(&mut sw, &mut back, g)?;
            Ok(())
        });
    }

    // RACE header byte offsets (after the 8-byte magic).
    const ROFF_MAP: usize = 8;
    const ROFF_P: usize = 9;
    const ROFF_ROWS: usize = 17;
    const ROFF_RANGE: usize = 25;

    fn build_race() -> (Race, SrpLsh) {
        let (rows, p, dim) = (4, 3, 6);
        let fam = SrpLsh::new(dim, rows * p, &mut Rng::new(31));
        let mut race = Race::new_srp(rows, p);
        let mut rng = Rng::new(32);
        for _ in 0..25 {
            let x: Vec<f32> = (0..dim).map(|_| rng.gaussian_f32()).collect();
            race.add(&fam, &x);
        }
        (race, fam)
    }

    #[test]
    fn race_corrupt_snapshots_are_rejected() {
        let (race, _) = build_race();
        let bytes = save_race(&race);
        for cut in 0..bytes.len() {
            assert!(load_race(&bytes[..cut]).is_err(), "prefix {cut} must fail");
        }
        let mut bad = bytes.clone();
        bad[0] = b'X';
        assert!(load_race(&bad).is_err(), "bad magic");
        let mut extra = bytes.clone();
        extra.push(0);
        assert!(load_race(&extra).is_err(), "trailing bytes");
    }

    #[test]
    fn race_hostile_headers_are_rejected_before_allocation() {
        let (race, _) = build_race();
        let cases: [fn(&mut [u8]); 8] = [
            |b| b[ROFF_MAP] = 9,
            |b| patch_u64(b, ROFF_P, 0),
            |b| patch_u64(b, ROFF_P, u64::MAX),
            |b| patch_u64(b, ROFF_ROWS, 0),
            |b| patch_u64(b, ROFF_ROWS, u64::MAX),
            |b| patch_u64(b, ROFF_RANGE, 0),
            // rows*range overflow / grid cap
            |b| {
                patch_u64(b, ROFF_ROWS, 1 << 15);
                patch_u64(b, ROFF_RANGE, 1 << 25);
            },
            // packed-cell range must equal 2^p
            |b| patch_u64(b, ROFF_RANGE, 7),
        ];
        for (i, patch) in cases.iter().enumerate() {
            let mut bytes = save_race(&race);
            patch(&mut bytes);
            assert!(load_race(&bytes).is_err(), "case {i} must be rejected");
        }
    }

    // SW-AKDE header byte offsets (after the 8-byte magic).
    const SOFF_MAP: usize = 8;
    const SOFF_P: usize = 9;
    const SOFF_ROWS: usize = 17;
    const SOFF_RANGE: usize = 25;
    const SOFF_EPS: usize = 33;
    const SOFF_WINDOW: usize = 41;
    const SOFF_NOW: usize = 49;
    const SOFF_FLAG: usize = 57;

    fn build_swakde() -> (SwAkde, SrpLsh) {
        let (rows, p, dim) = (4, 3, 6);
        let fam = SrpLsh::new(dim, rows * p, &mut Rng::new(33));
        let mut sw = SwAkde::new_srp(rows, p, 0.2, 40);
        let mut rng = Rng::new(34);
        for _ in 0..60 {
            let x: Vec<f32> = (0..dim).map(|_| rng.gaussian_f32()).collect();
            sw.add(&fam, &x);
        }
        (sw, fam)
    }

    #[test]
    fn swakde_corrupt_snapshots_are_rejected() {
        let (sw, _) = build_swakde();
        let bytes = save_swakde(&sw);
        for cut in 0..bytes.len() {
            assert!(load_swakde(&bytes[..cut]).is_err(), "prefix {cut} must fail");
        }
        let mut bad = bytes.clone();
        bad[3] = b'?';
        assert!(load_swakde(&bad).is_err(), "bad magic");
        let mut extra = bytes.clone();
        extra.push(0);
        assert!(load_swakde(&extra).is_err(), "trailing bytes");
    }

    #[test]
    fn swakde_hostile_headers_are_rejected() {
        let (sw, _) = build_swakde();
        let cases: [fn(&mut [u8]); 10] = [
            |b| b[SOFF_MAP] = 3,
            |b| patch_u64(b, SOFF_P, 0),
            |b| patch_u64(b, SOFF_ROWS, u64::MAX),
            |b| patch_u64(b, SOFF_RANGE, 0),
            |b| patch_u64(b, SOFF_RANGE, 9), // packed: range != 2^p
            |b| patch_f64(b, SOFF_EPS, f64::NAN),
            |b| patch_f64(b, SOFF_EPS, 0.0),
            |b| patch_u64(b, SOFF_WINDOW, 0),
            // Clock rewound behind the EH timestamps: the first
            // post-restore add would violate EH monotonicity.
            |b| patch_u64(b, SOFF_NOW, 0),
            |b| b[SOFF_FLAG] = 2,
        ];
        for (i, patch) in cases.iter().enumerate() {
            let mut bytes = save_swakde(&sw);
            patch(&mut bytes);
            assert!(load_swakde(&bytes).is_err(), "case {i} must be rejected");
        }
    }

    #[test]
    fn swakde_hostile_cell_directory_is_rejected() {
        let (sw, _) = build_swakde();
        let base = save_swakde(&sw);
        assert!(sw.occupied_cells() > 0, "fixture must have occupied cells");
        // The occupied-cell count sits right after the population EH;
        // locate it by re-encoding the prefix.
        let mut prefix = Vec::new();
        prefix.extend_from_slice(SWAKDE_MAGIC);
        save_bounded_hasher(&mut prefix, sw.hasher());
        put_f64(&mut prefix, sw.eps_eh());
        put_u64(&mut prefix, sw.window());
        put_u64(&mut prefix, sw.now());
        put_u8(&mut prefix, u8::from(sw.had_batch_tick()));
        save_eh(&mut prefix, sw.pop_eh());
        let off_nocc = prefix.len();
        // Claimed occupied count above the grid size.
        let mut bytes = base.clone();
        patch_u64(&mut bytes, off_nocc, u64::MAX);
        assert!(load_swakde(&bytes).is_err(), "hostile occupied count");
        // First cell index out of range / not increasing.
        let off_idx0 = off_nocc + 8;
        let mut bytes = base.clone();
        patch_u64(&mut bytes, off_idx0, u64::MAX);
        assert!(load_swakde(&bytes).is_err(), "out-of-grid cell index");
    }

    #[test]
    fn loaders_never_panic_on_garbage() {
        check("snapshot_loaders_garbage", 200, |g| {
            let n = g.size(0, 240);
            let junk: Vec<u8> = (0..n).map(|_| g.rng.next_u64() as u8).collect();
            let _ = load_sann(&junk);
            let _ = load_race(&junk);
            let _ = load_swakde(&junk);
            // Valid magics with garbage bodies must also fail cleanly.
            for magic in [MAGIC, RACE_MAGIC, SWAKDE_MAGIC] {
                let mut framed = magic.to_vec();
                framed.extend_from_slice(&junk);
                let _ = load_sann(&framed);
                let _ = load_race(&framed);
                let _ = load_swakde(&framed);
            }
            Ok(())
        });
    }
}
