//! Sketch persistence: save/restore S-ANN state across process restarts
//! (a serving system must not need a full stream replay to come back).
//!
//! Format (little-endian, versioned): the sketch CONFIG plus the retained
//! live vectors. Hash tables are rebuilt on load by re-hashing — the LSH
//! family is a deterministic function of the config seed, so the restored
//! structure is bit-identical to the saved one; the file stays small
//! (O(stored · dim) instead of O(tables)). Post-restore ingestion draws
//! fresh sampler randomness: Bernoulli retention is i.i.d., so the
//! distributional guarantees (Theorem 3.1) are unaffected.

use std::io::{Read, Write};

use anyhow::{bail, Context, Result};

use super::ann::{SAnn, SAnnConfig};

const MAGIC: &[u8; 8] = b"SANNSNP1";

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}

struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.i + n > self.b.len() {
            bail!("snapshot truncated at byte {}", self.i);
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

/// Serialize an S-ANN sketch (config + live vectors).
pub fn save_sann(ann: &SAnn) -> Vec<u8> {
    let cfg = ann.config();
    let mut out = Vec::new();
    out.extend_from_slice(MAGIC);
    put_u64(&mut out, cfg.dim as u64);
    put_u64(&mut out, cfg.n_max as u64);
    put_f64(&mut out, cfg.eta);
    put_f64(&mut out, cfg.r);
    put_f64(&mut out, cfg.c);
    put_f64(&mut out, cfg.w);
    put_u64(&mut out, cfg.l_cap as u64);
    put_u64(&mut out, cfg.seed);
    let live: Vec<u32> = ann.live_ids().collect();
    put_u64(&mut out, live.len() as u64);
    for id in live {
        for &v in ann.vector(id) {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }
    out
}

/// Restore an S-ANN sketch from [`save_sann`] bytes.
pub fn load_sann(bytes: &[u8]) -> Result<SAnn> {
    let mut r = Reader { b: bytes, i: 0 };
    if r.take(8)? != MAGIC {
        bail!("not an S-ANN snapshot (bad magic)");
    }
    let dim = r.u64()? as usize;
    let n_max = r.u64()? as usize;
    let eta = r.f64()?;
    let cfg = SAnnConfig {
        dim,
        n_max,
        eta,
        r: r.f64()?,
        c: r.f64()?,
        w: r.f64()?,
        l_cap: r.u64()? as usize,
        seed: r.u64()?,
    };
    let n_live = r.u64()? as usize;
    let mut ann = SAnn::new(cfg);
    let mut buf = vec![0f32; dim];
    for _ in 0..n_live {
        let raw = r.take(dim * 4)?;
        for (j, c) in raw.chunks_exact(4).enumerate() {
            buf[j] = f32::from_le_bytes(c.try_into().unwrap());
        }
        ann.insert_retained(&buf);
    }
    if r.i != bytes.len() {
        bail!("snapshot has {} trailing bytes", bytes.len() - r.i);
    }
    Ok(ann)
}

/// Save to a file.
pub fn save_sann_file(ann: &SAnn, path: &std::path::Path) -> Result<()> {
    let bytes = save_sann(ann);
    std::fs::File::create(path)
        .and_then(|mut f| f.write_all(&bytes))
        .with_context(|| format!("writing snapshot {path:?}"))
}

/// Load from a file.
pub fn load_sann_file(path: &std::path::Path) -> Result<SAnn> {
    let mut bytes = Vec::new();
    std::fs::File::open(path)
        .and_then(|mut f| f.read_to_end(&mut bytes))
        .with_context(|| format!("reading snapshot {path:?}"))?;
    load_sann(&bytes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn build(n: usize) -> SAnn {
        let mut ann = SAnn::new(SAnnConfig {
            dim: 8,
            n_max: 1000,
            eta: 0.0,
            r: 1.0,
            c: 2.0,
            w: 4.0,
            l_cap: 16,
            seed: 77,
        });
        let mut rng = Rng::new(5);
        for _ in 0..n {
            let p: Vec<f32> = (0..8).map(|_| rng.gaussian_f32()).collect();
            ann.insert(&p);
        }
        ann
    }

    #[test]
    fn roundtrip_preserves_queries() {
        let mut ann = build(120);
        let bytes = save_sann(&ann);
        let mut restored = load_sann(&bytes).unwrap();
        assert_eq!(restored.stored(), ann.stored());
        let mut rng = Rng::new(6);
        for _ in 0..40 {
            let q: Vec<f32> = (0..8).map(|_| rng.gaussian_f32()).collect();
            assert_eq!(ann.query(&q), restored.query(&q), "restored sketch must answer identically");
        }
    }

    #[test]
    fn roundtrip_preserves_deletions() {
        let mut ann = build(50);
        // delete some points, snapshot, restore: tombstoned points gone
        let victim = ann.vector(3).to_vec();
        assert!(ann.delete(&victim));
        let before = ann.stored();
        let restored = load_sann(&save_sann(&ann)).unwrap();
        assert_eq!(restored.stored(), before);
    }

    #[test]
    fn file_roundtrip() {
        let ann = build(30);
        let path = std::env::temp_dir().join("sann_snapshot_test.bin");
        save_sann_file(&ann, &path).unwrap();
        let restored = load_sann_file(&path).unwrap();
        assert_eq!(restored.stored(), ann.stored());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn corrupt_snapshots_are_rejected() {
        let ann = build(10);
        let mut bytes = save_sann(&ann);
        assert!(load_sann(&bytes[..bytes.len() - 3]).is_err(), "truncated");
        bytes[0] = b'X';
        assert!(load_sann(&bytes).is_err(), "bad magic");
        let mut extra = save_sann(&ann);
        extra.push(0);
        assert!(load_sann(&extra).is_err(), "trailing bytes");
    }

    #[test]
    fn restored_sketch_accepts_new_inserts() {
        let ann = build(40);
        let mut restored = load_sann(&save_sann(&ann)).unwrap();
        let p = vec![9.0f32; 8];
        restored.insert(&p);
        assert_eq!(restored.stored(), 41);
        assert!(restored.query(&p).is_some());
    }
}
