//! Exponential Histogram (DGIM02) — Basic Counting over a sliding window
//! (paper §2.4), the per-cell engine of SW-AKDE (§4).
//!
//! Maintains the number of 1s among the last N stream positions with
//! relative error ≤ ε' using O((1/ε') log² N) bits. Invariants (paper §2.4):
//!
//! 1. c_m / (2 (1 + Σ_{j<m} c_j)) ≤ 1/k with k = ⌈1/ε'⌉,
//! 2. sizes are powers of two, non-decreasing with age, with a bounded
//!    number of buckets per size (except the largest size).
//!
//! We run the conservative variant with k..k+1 buckets per level (the
//! paper's ⌈k/2⌉..⌈k/2⌉+1 yields worst-case error 2/k ≈ 2ε'; doubling the
//! per-level count restores a strict ≤ε' guarantee at the same
//! O((1/ε')log²N) asymptotics — DESIGN.md §5).
//!
//! Layout: one timestamp deque per size-exponent (front = newest). The
//! merged bucket of two size-2ᵉ buckets is newer than every existing
//! size-2ᵉ⁺¹ bucket (sizes are non-decreasing with age), so merging is a
//! pop-back×2 / push-front — O(1) per level, O(1) amortized per add.
//!
//! The estimate at any instant is TOTAL − LAST/2 (half of the oldest,
//! straddling bucket), giving relative error ≤ 1/k ≤ ε'.

/// Exponential histogram over a fixed-size sliding window.
#[derive(Clone, Debug)]
pub struct ExpHistogram {
    /// k = ⌈1/ε'⌉; per-size bucket cap is k + 1.
    k: usize,
    cap: usize,
    window: u64,
    /// buckets[e]: timestamps of size-2ᵉ buckets, front = newest.
    buckets: Vec<std::collections::VecDeque<u64>>,
    /// Sum of all bucket sizes (the TOTAL counter).
    total: u64,
    /// Most recent timestamp seen (adds must be non-decreasing in time).
    last_ts: u64,
}

impl ExpHistogram {
    /// `eps` is the target relative error ε' ∈ (0, 1]; `window` is N ≥ 1.
    pub fn new(eps: f64, window: u64) -> Self {
        assert!(eps > 0.0 && eps <= 1.0, "eps must be in (0,1]");
        assert!(window >= 1);
        let k = (1.0 / eps).ceil() as usize;
        ExpHistogram {
            k,
            cap: k + 1,
            window,
            buckets: Vec::new(),
            total: 0,
            last_ts: 0,
        }
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn window(&self) -> u64 {
        self.window
    }

    /// Record a 1 at time `ts` (monotone non-decreasing across calls).
    pub fn add(&mut self, ts: u64) {
        debug_assert!(ts >= self.last_ts, "timestamps must be non-decreasing");
        self.last_ts = ts;
        self.expire(ts);
        if self.buckets.is_empty() {
            self.buckets.push(Default::default());
        }
        self.buckets[0].push_front(ts);
        self.total += 1;
        self.canonicalize();
    }

    /// Record `count` 1s at time `ts` (batch updates, Corollary 4.2).
    ///
    /// Semantically identical to `count` consecutive `add(ts)` calls —
    /// O(count) amortized, where count is bounded by the batch size R.
    pub fn add_count(&mut self, ts: u64, count: u64) {
        for _ in 0..count {
            self.add(ts);
        }
    }

    /// (1 ± ε')-estimate of the number of 1s in (now − N, now].
    pub fn estimate(&mut self, now: u64) -> f64 {
        self.expire(now);
        if self.total == 0 {
            return 0.0;
        }
        let last = self.oldest_size();
        if last == 1 {
            // A size-1 straddling bucket is fully live (its only element is
            // its most-recent timestamp, which survived expiry): exact.
            return self.total as f64;
        }
        self.total as f64 - last as f64 / 2.0
    }

    /// Exact upper bound: the TOTAL counter (counts possibly-expired 1s in
    /// the straddling bucket).
    pub fn total(&self) -> u64 {
        self.total
    }

    /// Number of live buckets.
    pub fn num_buckets(&self) -> usize {
        self.buckets.iter().map(|q| q.len()).sum()
    }

    /// Actual resident bytes of the bucket structure.
    pub fn memory_bytes(&self) -> usize {
        std::mem::size_of::<Self>()
            + self
                .buckets
                .iter()
                .map(|q| q.capacity() * std::mem::size_of::<u64>())
                .sum::<usize>()
    }

    /// Theoretical footprint in bits: each bucket stores a timestamp
    /// (log N bits) and a size exponent (log log N bits) — the accounting
    /// Lemma 4.4 uses.
    pub fn theory_bits(&self) -> usize {
        let logn = (64 - self.window.leading_zeros()) as usize;
        let loglogn = (usize::BITS - logn.leading_zeros()) as usize;
        self.num_buckets() * (logn + loglogn.max(1))
    }

    fn oldest_size(&self) -> u64 {
        for e in (0..self.buckets.len()).rev() {
            if !self.buckets[e].is_empty() {
                return 1u64 << e;
            }
        }
        0
    }

    fn expire(&mut self, now: u64) {
        let cutoff = now.saturating_sub(self.window); // live: ts > cutoff
        for e in 0..self.buckets.len() {
            while let Some(&ts) = self.buckets[e].back() {
                if ts <= cutoff {
                    self.buckets[e].pop_back();
                    self.total -= 1u64 << e;
                } else {
                    break;
                }
            }
        }
    }

    fn canonicalize(&mut self) {
        let mut e = 0;
        while e < self.buckets.len() {
            if self.buckets[e].len() > self.cap {
                // Merge the two OLDEST buckets of this size; the result is
                // newer than all existing size-2^{e+1} buckets.
                let t_old = self.buckets[e].pop_back().unwrap();
                let t_new = self.buckets[e].pop_back().unwrap();
                debug_assert!(t_new >= t_old);
                if e + 1 == self.buckets.len() {
                    self.buckets.push(Default::default());
                }
                self.buckets[e + 1].push_front(t_new);
            }
            e += 1;
        }
    }

    /// Bucket levels — `levels()[e]` holds the timestamps of the size-2ᵉ
    /// buckets, front = newest (snapshot/persistence access).
    pub fn levels(&self) -> &[std::collections::VecDeque<u64>] {
        &self.buckets
    }

    /// Most recent timestamp seen (snapshot/persistence access).
    pub fn last_ts(&self) -> u64 {
        self.last_ts
    }

    /// Rebuild from serialized parts (the snapshot restore path). Unlike
    /// [`ExpHistogram::new`] this never panics: every structural invariant
    /// a hostile image could violate is validated — level count, per-level
    /// bucket caps, intra-level timestamp ordering, timestamps vs
    /// `last_ts` — and TOTAL is recomputed with checked arithmetic.
    pub fn from_parts(
        eps: f64,
        window: u64,
        levels: Vec<Vec<u64>>,
        last_ts: u64,
    ) -> Result<Self, String> {
        if !(eps > 0.0 && eps <= 1.0) || !eps.is_finite() {
            return Err(format!("eps {eps} outside (0, 1]"));
        }
        if window == 0 {
            return Err("window must be >= 1".into());
        }
        let k = (1.0 / eps).ceil() as usize;
        let cap = k + 1;
        if levels.len() > 63 {
            return Err(format!("{} bucket levels (max 63)", levels.len()));
        }
        let mut total: u64 = 0;
        let mut buckets = Vec::with_capacity(levels.len());
        for (e, level) in levels.into_iter().enumerate() {
            if level.len() > cap {
                return Err(format!("level {e}: {} buckets > cap {cap}", level.len()));
            }
            let mut prev = u64::MAX;
            for &ts in &level {
                if ts > prev {
                    return Err(format!("level {e}: timestamps out of order"));
                }
                if ts > last_ts {
                    return Err(format!("level {e}: timestamp {ts} after last_ts {last_ts}"));
                }
                prev = ts;
            }
            let size = (level.len() as u64)
                .checked_mul(1u64 << e)
                .ok_or_else(|| format!("level {e}: bucket mass overflows"))?;
            total = total
                .checked_add(size)
                .ok_or_else(|| format!("level {e}: TOTAL overflows"))?;
            buckets.push(std::collections::VecDeque::from(level));
        }
        Ok(ExpHistogram { k, cap, window, buckets, total, last_ts })
    }

    /// Check invariants 1 & 2 (test/debug hook; O(buckets)).
    pub fn check_invariants(&self) -> Result<(), String> {
        // sizes non-decreasing with age + per-size counts
        let mut newer_sum: u64 = 0;
        let nonempty: Vec<usize> = (0..self.buckets.len())
            .filter(|&e| !self.buckets[e].is_empty())
            .collect();
        for (pos, &e) in nonempty.iter().enumerate() {
            let q = &self.buckets[e];
            // within a level, timestamps non-increasing front->back
            let mut prev = u64::MAX;
            for &ts in q.iter() {
                if ts > prev {
                    return Err(format!("level {e}: timestamps out of order"));
                }
                prev = ts;
            }
            let is_largest = pos == nonempty.len() - 1;
            if q.len() > self.cap {
                return Err(format!("level {e}: {} buckets > cap {}", q.len(), self.cap));
            }
            if !is_largest && q.len() < self.cap - 1 && self.total > (1 << (e + 1)) {
                // between ceil(k/2) and cap buckets per full level
                // (level may be legitimately sparse right after expiry —
                // only enforce the upper bound strictly; record soft note)
            }
            // Invariant 1 on the OLDEST bucket — the one whose half-size is
            // the estimate's error. (For small/new buckets the literal
            // c_j/(2(1+Σ)) ≤ 1/k inequality is vacuously violated — a fresh
            // size-1 bucket has lhs = 1/2 — which is why DGIM's guarantee
            // only leans on it for the straddling bucket. A size-1 oldest
            // bucket is exact, see `estimate`.)
            let c = 1u64 << e;
            if is_largest && c > 1 {
                let newer = newer_sum + (q.len() - 1) as u64 * c;
                let lhs = c as f64 / (2.0 * (1.0 + newer as f64));
                if lhs > 1.0 / self.k as f64 + 1e-12 {
                    return Err(format!(
                        "oldest bucket (size {c}): invariant1 lhs={lhs} > 1/k"
                    ));
                }
            }
            newer_sum += (q.len() as u64) << e;
        }
        if newer_sum != self.total {
            return Err(format!("TOTAL {} != bucket sum {}", self.total, newer_sum));
        }
        Ok(())
    }

}

/// Exact sliding-window counter (test oracle; O(window) memory).
#[derive(Clone, Debug, Default)]
pub struct ExactWindowCounter {
    times: std::collections::VecDeque<u64>,
}

impl ExactWindowCounter {
    pub fn new() -> Self {
        Default::default()
    }
    pub fn add(&mut self, ts: u64) {
        self.times.push_back(ts);
    }
    pub fn add_count(&mut self, ts: u64, count: u64) {
        for _ in 0..count {
            self.times.push_back(ts);
        }
    }
    pub fn count(&mut self, now: u64, window: u64) -> u64 {
        let cutoff = now.saturating_sub(window);
        while let Some(&t) = self.times.front() {
            if t <= cutoff {
                self.times.pop_front();
            } else {
                break;
            }
        }
        self.times.len() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, Gen};

    #[test]
    fn empty_estimates_zero() {
        let mut eh = ExpHistogram::new(0.1, 100);
        assert_eq!(eh.estimate(50), 0.0);
    }

    #[test]
    fn dense_stream_estimate_within_eps() {
        let eps = 0.1;
        let window = 500;
        let mut eh = ExpHistogram::new(eps, window);
        let mut exact = ExactWindowCounter::new();
        for t in 1..=5000u64 {
            eh.add(t);
            exact.add(t);
            if t % 37 == 0 {
                let est = eh.estimate(t);
                let truth = exact.count(t, window) as f64;
                assert!(
                    (est - truth).abs() <= eps * truth + 1e-9,
                    "t={t} est={est} truth={truth}"
                );
            }
        }
    }

    #[test]
    fn sparse_stream_estimate_within_eps() {
        let eps = 0.2;
        let window = 1000;
        let mut eh = ExpHistogram::new(eps, window);
        let mut exact = ExactWindowCounter::new();
        let mut rng = crate::util::rng::Rng::new(77);
        for t in 1..=20_000u64 {
            if rng.bernoulli(0.05) {
                eh.add(t);
                exact.add(t);
            }
            if t % 101 == 0 {
                let est = eh.estimate(t);
                let truth = exact.count(t, window) as f64;
                assert!(
                    (est - truth).abs() <= eps * truth + 1e-9,
                    "t={t} est={est} truth={truth}"
                );
            }
        }
    }

    #[test]
    fn everything_expires() {
        let mut eh = ExpHistogram::new(0.1, 10);
        for t in 1..=100u64 {
            eh.add(t);
        }
        assert_eq!(eh.estimate(1000), 0.0);
        assert_eq!(eh.num_buckets(), 0);
    }

    #[test]
    fn batch_add_equals_repeated_add() {
        let mut a = ExpHistogram::new(0.1, 64);
        let mut b = ExpHistogram::new(0.1, 64);
        for t in 1..=50u64 {
            a.add_count(t, 7);
            for _ in 0..7 {
                b.add(t);
            }
            assert_eq!(a.estimate(t), b.estimate(t));
            assert_eq!(a.num_buckets(), b.num_buckets());
        }
    }

    #[test]
    fn invariants_hold_on_dense_stream() {
        let mut eh = ExpHistogram::new(0.125, 256);
        for t in 1..=4096u64 {
            eh.add(t);
            eh.check_invariants().unwrap();
        }
    }

    #[test]
    fn bucket_count_is_logarithmic() {
        let window = 100_000u64;
        let eps = 0.1;
        let mut eh = ExpHistogram::new(eps, window);
        for t in 1..=window {
            eh.add(t);
        }
        let k = (1.0 / eps).ceil();
        // paper §2.4: n <= (k/2+1)(log(2N/k+1)+1); our conservative variant
        // doubles the per-level count, so allow (k+1)(...)
        let bound = (k + 1.0) * ((2.0 * window as f64 / k + 1.0).log2() + 1.0);
        assert!(
            (eh.num_buckets() as f64) <= bound + 1.0,
            "buckets={} bound={bound}",
            eh.num_buckets()
        );
    }

    #[test]
    fn memory_matches_theory_scaling() {
        // doubling the window should add O(1/eps * log) bits, not double
        let mut small = ExpHistogram::new(0.1, 1_000);
        let mut large = ExpHistogram::new(0.1, 64_000);
        for t in 1..=64_000u64 {
            if t <= 1_000 {
                small.add(t);
            }
            large.add(t);
        }
        let ratio = large.theory_bits() as f64 / small.theory_bits() as f64;
        assert!(ratio < 4.0, "ratio={ratio} (64x window must be < 4x bits)");
    }

    #[test]
    fn property_error_bound_random_streams() {
        check("eh_error_bound", 40, |g: &mut Gen| {
            let eps = [0.05, 0.1, 0.2, 0.5][g.usize_in(0, 3)];
            let window = [16u64, 64, 256, 1024][g.usize_in(0, 3)];
            let density = g.f64_in(0.01, 1.0);
            let len = g.size(10, 4000) as u64;
            let mut eh = ExpHistogram::new(eps, window);
            let mut exact = ExactWindowCounter::new();
            for t in 1..=len {
                if g.rng.bernoulli(density) {
                    eh.add(t);
                    exact.add(t);
                }
            }
            let est = eh.estimate(len);
            let truth = exact.count(len, window) as f64;
            if (est - truth).abs() > eps * truth + 1e-9 {
                return Err(format!(
                    "eps={eps} window={window} density={density} len={len} \
                     est={est} truth={truth}"
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn property_invariants_random_batches() {
        check("eh_invariants_batch", 30, |g: &mut Gen| {
            let mut eh = ExpHistogram::new(0.1, 128);
            let steps = g.size(1, 500) as u64;
            for t in 1..=steps {
                let c = g.usize_in(0, 9) as u64;
                eh.add_count(t, c);
                eh.check_invariants().map_err(|e| format!("t={t}: {e}"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn from_parts_roundtrips_live_state() {
        let mut eh = ExpHistogram::new(0.1, 128);
        for t in 1..=1000u64 {
            if t % 3 != 0 {
                eh.add(t);
            }
        }
        let levels: Vec<Vec<u64>> =
            eh.levels().iter().map(|q| q.iter().copied().collect()).collect();
        let mut back = ExpHistogram::from_parts(0.1, 128, levels, eh.last_ts()).unwrap();
        assert_eq!(back.total(), eh.total());
        assert_eq!(back.num_buckets(), eh.num_buckets());
        for now in [1000u64, 1040, 1100, 1500] {
            assert_eq!(back.estimate(now), eh.estimate(now), "now={now}");
        }
    }

    #[test]
    fn from_parts_rejects_malformed_levels() {
        assert!(ExpHistogram::from_parts(0.0, 10, vec![], 0).is_err(), "eps 0");
        assert!(ExpHistogram::from_parts(1.5, 10, vec![], 0).is_err(), "eps > 1");
        assert!(ExpHistogram::from_parts(0.1, 0, vec![], 0).is_err(), "window 0");
        assert!(
            ExpHistogram::from_parts(0.1, 10, vec![vec![1, 5]], 5).is_err(),
            "timestamps out of order"
        );
        assert!(
            ExpHistogram::from_parts(0.1, 10, vec![vec![9]], 5).is_err(),
            "timestamp after last_ts"
        );
        assert!(
            ExpHistogram::from_parts(0.5, 10, vec![vec![5; 50]], 5).is_err(),
            "overfull level"
        );
        assert!(
            ExpHistogram::from_parts(0.1, 10, vec![Vec::new(); 64], 5).is_err(),
            "too many levels"
        );
    }

    #[test]
    fn same_timestamp_burst_tracks_count_within_eps() {
        // All adds inside the window: truth is exactly n after n adds.
        let eps = 0.1;
        let mut eh = ExpHistogram::new(eps, 1_000);
        for n in 1..=500u64 {
            eh.add(50);
            let est = eh.estimate(50);
            assert!(
                (est - n as f64).abs() <= eps * n as f64 + 1e-9,
                "n={n} est={est}"
            );
        }
    }
}
