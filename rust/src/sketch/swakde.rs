//! SW-AKDE — Sliding-Window Approximate KDE (Algorithm 2, Theorem 4.1).
//!
//! The paper's second contribution: a RACE grid whose integer counters are
//! replaced by Exponential Histograms, so each cell answers "how many
//! elements of the last N updates hashed here" with relative error ε'
//! (the EH guarantee), yielding a (1±ε) KDE approximation with
//! ε = 2ε' + ε'² (Lemma 4.3) in space O(RW · (1/ε')·log²N) (Lemma 4.4).
//!
//! Cells are created lazily ("if A\[i,j\] is empty, create an EH" —
//! Algorithm 2), so the resident footprint tracks occupied cells.
//! Batch updates (Corollary 4.2) add `count` 1s per cell per tick.

use crate::lsh::concat::BoundedHasher;
use crate::lsh::LshFamily;
use crate::sketch::eh::ExpHistogram;
use crate::util::stats;

/// Sliding-window KDE sketch: R rows × W cells of lazily-built EHs.
pub struct SwAkde {
    cells: Vec<Option<Box<ExpHistogram>>>,
    hasher: BoundedHasher,
    /// EH relative error ε' (KDE error ε = 2ε' + ε'²).
    eps_eh: f64,
    /// Window size N (stream positions or batches).
    window: u64,
    /// Current stream time (monotone).
    now: u64,
    /// Live window POPULATION in points (not ticks): one more EH counting
    /// every ingested element, so batch ticks (Corollary 4.2, B points at
    /// one timestamp) debias and normalize correctly. `now.min(window)`
    /// would undercount by the batch size B.
    pop: ExpHistogram,
    /// True once any tick carried ≠ 1 point. While false, the population
    /// is exactly `now.min(window)` and the EH estimate (±ε') is skipped.
    had_batch_tick: bool,
    /// Raw-slot scratch reused across updates/queries (no per-op alloc).
    scratch: Vec<i64>,
    /// Cell-index scratch for the single-point kernel path.
    cells_scratch: Vec<usize>,
    /// Per-row estimate scratch for the query read path.
    est_scratch: Vec<f64>,
    /// Flattened-batch scratch for `add_batch` over non-contiguous points.
    flat_scratch: Vec<f32>,
}

impl SwAkde {
    /// Rehash-mode constructor (p-stable style cells).
    pub fn new(rows: usize, range: usize, p: usize, eps_eh: f64, window: u64) -> Self {
        Self::with_hasher(BoundedHasher::new(p, rows, range), eps_eh, window)
    }

    /// SRP variant: bit-packed cells, range 2^p (exact ACE structure).
    pub fn new_srp(rows: usize, p: usize, eps_eh: f64, window: u64) -> Self {
        Self::with_hasher(BoundedHasher::new_packed(p, rows), eps_eh, window)
    }

    pub fn with_hasher(hasher: BoundedHasher, eps_eh: f64, window: u64) -> Self {
        SwAkde {
            cells: (0..hasher.rows * hasher.range).map(|_| None).collect(),
            hasher,
            eps_eh,
            window,
            now: 0,
            pop: ExpHistogram::new(eps_eh, window),
            had_batch_tick: false,
            scratch: Vec::new(),
            cells_scratch: Vec::new(),
            est_scratch: Vec::new(),
            flat_scratch: Vec::new(),
        }
    }

    pub fn rows(&self) -> usize {
        self.hasher.rows
    }

    pub fn range(&self) -> usize {
        self.hasher.range
    }

    pub fn p(&self) -> usize {
        self.hasher.p
    }

    pub fn window(&self) -> u64 {
        self.window
    }

    pub fn now(&self) -> u64 {
        self.now
    }

    pub fn funcs_needed(&self) -> usize {
        self.hasher.funcs_needed()
    }

    /// The concatenated-hash configuration (snapshot/persistence access).
    pub fn hasher(&self) -> &BoundedHasher {
        &self.hasher
    }

    /// The per-cell EH relative error ε' (snapshot/persistence access).
    pub fn eps_eh(&self) -> f64 {
        self.eps_eh
    }

    /// Whether any tick has carried more than one point (persistence:
    /// governs the exact-vs-EH population fast path, see [`Self::population`]).
    pub fn had_batch_tick(&self) -> bool {
        self.had_batch_tick
    }

    /// The window-population EH (snapshot/persistence access).
    pub(crate) fn pop_eh(&self) -> &ExpHistogram {
        &self.pop
    }

    /// The flat [rows × range] cell grid (snapshot/persistence access).
    pub(crate) fn cells_raw(&self) -> &[Option<Box<ExpHistogram>>] {
        &self.cells
    }

    /// Rebuild from snapshot parts. The caller (snapshot restore) has
    /// already validated the hasher shape and that
    /// `cells.len() == rows * range`.
    pub(crate) fn from_parts(
        hasher: BoundedHasher,
        eps_eh: f64,
        window: u64,
        now: u64,
        pop: ExpHistogram,
        had_batch_tick: bool,
        cells: Vec<Option<Box<ExpHistogram>>>,
    ) -> Self {
        assert_eq!(cells.len(), hasher.rows * hasher.range);
        SwAkde {
            cells,
            hasher,
            eps_eh,
            window,
            now,
            pop,
            had_batch_tick,
            scratch: Vec::new(),
            cells_scratch: Vec::new(),
            est_scratch: Vec::new(),
            flat_scratch: Vec::new(),
        }
    }

    /// KDE relative error ε = 2ε' + ε'² implied by the EH error (Lemma 4.3).
    pub fn kde_eps(&self) -> f64 {
        2.0 * self.eps_eh + self.eps_eh * self.eps_eh
    }

    #[inline]
    fn cell_mut(&mut self, row: usize, idx: usize) -> &mut ExpHistogram {
        let flat = row * self.hasher.range + idx;
        let (eps, window) = (self.eps_eh, self.window);
        self.cells[flat]
            .get_or_insert_with(|| Box::new(ExpHistogram::new(eps, window)))
    }

    /// Ingest one stream element at the next time step. All R·p raw hashes
    /// run as one blocked kernel pass over the projection matrix.
    pub fn add<F: LshFamily + ?Sized>(&mut self, fam: &F, x: &[f32]) {
        self.now += 1;
        let t = self.now;
        self.pop.add(t);
        let mut idxs = std::mem::take(&mut self.cells_scratch);
        let mut scratch = std::mem::take(&mut self.scratch);
        idxs.resize(self.hasher.rows, 0);
        self.hasher.cells(fam, x, &mut idxs, &mut scratch);
        for (i, &idx) in idxs.iter().enumerate() {
            self.cell_mut(i, idx).add(t);
        }
        self.scratch = scratch;
        self.cells_scratch = idxs;
    }

    /// Ingest a batch of elements sharing one time step (Corollary 4.2:
    /// the window is then measured in batches). The whole batch hashes
    /// through one GEMM-shaped kernel call.
    pub fn add_batch<F: LshFamily + ?Sized>(&mut self, fam: &F, batch: &[&[f32]]) {
        if batch.is_empty() {
            return; // an empty flush is not a window tick
        }
        self.now += 1;
        let t = self.now;
        self.pop.add_count(t, batch.len() as u64);
        if batch.len() > 1 {
            self.had_batch_tick = true;
        }
        let rows = self.hasher.rows;
        let mut flat = std::mem::take(&mut self.flat_scratch);
        flat.clear();
        for x in batch {
            flat.extend_from_slice(x);
        }
        let mut idxs = std::mem::take(&mut self.cells_scratch);
        let mut slots = std::mem::take(&mut self.scratch);
        self.hasher.cells_batch(fam, &flat, &mut idxs, &mut slots);
        // Aggregate per-cell increments first so each touched EH gets one
        // add_count call (R elements hashing to one cell is the worst case
        // the corollary's space bound covers).
        let mut incs: std::collections::HashMap<(usize, usize), u64> = Default::default();
        for row_cells in idxs.chunks_exact(rows) {
            for (i, &idx) in row_cells.iter().enumerate() {
                *incs.entry((i, idx)).or_insert(0) += 1;
            }
        }
        for ((i, idx), c) in incs {
            self.cell_mut(i, idx).add_count(t, c);
        }
        self.scratch = slots;
        self.cells_scratch = idxs;
        self.flat_scratch = flat;
    }

    /// Batched ingest where each point advances the stream clock by one
    /// tick — state-identical to a loop of `add`, but the whole batch
    /// (row-major [n, dim]) hashes through one GEMM-shaped kernel call.
    /// This is the coordinator's native batched-insert path.
    pub fn add_each<F: LshFamily + ?Sized>(&mut self, fam: &F, xs: &[f32]) {
        let d = fam.dim();
        debug_assert!(d > 0 && xs.len() % d == 0);
        if xs.is_empty() {
            return;
        }
        let rows = self.hasher.rows;
        let mut idxs = std::mem::take(&mut self.cells_scratch);
        let mut slots = std::mem::take(&mut self.scratch);
        self.hasher.cells_batch(fam, xs, &mut idxs, &mut slots);
        for row_cells in idxs.chunks_exact(rows) {
            self.now += 1;
            let t = self.now;
            self.pop.add(t);
            for (i, &idx) in row_cells.iter().enumerate() {
                self.cell_mut(i, idx).add(t);
            }
        }
        self.scratch = slots;
        self.cells_scratch = idxs;
    }

    /// Ingest from precomputed raw slots (PJRT batch path, layout `\[rows*p\]`).
    pub fn add_slots(&mut self, slots: &[i64]) {
        self.now += 1;
        let t = self.now;
        self.pop.add(t);
        for i in 0..self.hasher.rows {
            let idx = self.hasher.cell_from_slots(i, slots);
            self.cell_mut(i, idx).add(t);
        }
    }

    /// Per-row windowed count estimates at the query's cells, written into
    /// caller storage (`out.len()` must equal R) — the allocation-free
    /// SW-AKDE read path, mirroring `Race::row_counts_into`. One kernel
    /// pass hashes all R·p functions.
    pub fn row_estimates_into<F: LshFamily + ?Sized>(
        &mut self,
        fam: &F,
        q: &[f32],
        out: &mut [f64],
    ) {
        debug_assert_eq!(out.len(), self.hasher.rows);
        let now = self.now;
        let mut idxs = std::mem::take(&mut self.cells_scratch);
        let mut scratch = std::mem::take(&mut self.scratch);
        idxs.resize(self.hasher.rows, 0);
        self.hasher.cells(fam, q, &mut idxs, &mut scratch);
        for (i, o) in out.iter_mut().enumerate() {
            let flat = i * self.hasher.range + idxs[i];
            *o = match &mut self.cells[flat] {
                Some(eh) => eh.estimate(now),
                None => 0.0,
            };
        }
        self.scratch = scratch;
        self.cells_scratch = idxs;
    }

    /// Per-row windowed count estimates (allocating convenience).
    pub fn row_estimates<F: LshFamily + ?Sized>(&mut self, fam: &F, q: &[f32]) -> Vec<f64> {
        let mut out = vec![0.0; self.hasher.rows];
        self.row_estimates_into(fam, q, &mut out);
        out
    }

    /// Algorithm 2 query: average of per-row EH estimates — the
    /// un-normalized windowed kernel sum Σ_{x∈window} k^p(x, q).
    pub fn query<F: LshFamily + ?Sized>(&mut self, fam: &F, q: &[f32]) -> f64 {
        let mut est = std::mem::take(&mut self.est_scratch);
        est.resize(self.hasher.rows, 0.0);
        self.row_estimates_into(fam, q, &mut est);
        let out = stats::mean(&est);
        self.est_scratch = est;
        out
    }

    /// Batched Algorithm 2 query: hash all queries (row-major [n, dim])
    /// with one GEMM-shaped kernel call, then read each query's R cells.
    /// Identical values to n sequential `query` calls.
    pub fn query_batch<F: LshFamily + ?Sized>(&mut self, fam: &F, qs: &[f32]) -> Vec<f64> {
        let d = fam.dim();
        debug_assert!(d > 0 && qs.len() % d == 0);
        let n = qs.len() / d;
        if n == 0 {
            return Vec::new();
        }
        let now = self.now;
        let rows = self.hasher.rows;
        let mut idxs = std::mem::take(&mut self.cells_scratch);
        let mut slots = std::mem::take(&mut self.scratch);
        self.hasher.cells_batch(fam, qs, &mut idxs, &mut slots);
        let mut est = std::mem::take(&mut self.est_scratch);
        est.resize(rows, 0.0);
        let mut out = Vec::with_capacity(n);
        for row_cells in idxs.chunks_exact(rows) {
            for (i, e) in est.iter_mut().enumerate() {
                let flat = i * self.hasher.range + row_cells[i];
                *e = match &mut self.cells[flat] {
                    Some(eh) => eh.estimate(now),
                    None => 0.0,
                };
            }
            out.push(stats::mean(&est));
        }
        self.est_scratch = est;
        self.scratch = slots;
        self.cells_scratch = idxs;
        out
    }

    /// Number of POINTS in the live window: exact (`now.min(window)`)
    /// while every tick has carried exactly one point, a (1±ε') EH
    /// estimate once `add_batch` has put B > 1 points on one tick.
    pub fn population(&mut self) -> f64 {
        if self.had_batch_tick {
            self.pop.estimate(self.now)
        } else {
            self.now.min(self.window) as f64
        }
    }

    /// Rehash-debiased estimator (mirror of `Race::query_debiased`): under
    /// rehash cells, distinct tuples collide spuriously w.p. ≈ 1/range, so
    /// E\[estimate\] = (1−1/W)·KDE + pop/W over the live window; inverting
    /// removes the bias. `pop` is the window population in POINTS
    /// ([`Self::population`]) — ticks would undercount batch ingest by the
    /// batch size. PackBits cells are exact and pass through.
    pub fn query_debiased<F: LshFamily + ?Sized>(&mut self, fam: &F, q: &[f32]) -> f64 {
        let raw = self.query(fam, q);
        match self.hasher.map {
            crate::lsh::concat::CellMap::PackBits => raw,
            crate::lsh::concat::CellMap::Rehash => {
                let w = self.hasher.range as f64;
                let pop = self.population();
                ((raw - pop / w) / (1.0 - 1.0 / w)).max(0.0)
            }
        }
    }

    /// Normalized density: kernel sum / window population (in points).
    pub fn density<F: LshFamily + ?Sized>(&mut self, fam: &F, q: &[f32]) -> f64 {
        let live = self.population();
        if live <= 0.0 {
            return 0.0;
        }
        self.query(fam, q) / live
    }

    /// Occupied (materialized) cells.
    pub fn occupied_cells(&self) -> usize {
        self.cells.iter().filter(|c| c.is_some()).count()
    }

    /// Buckets currently held by the window-population EH
    /// (observability: tracks the O(log w / ε) bucket bound of §4).
    pub fn eh_buckets(&self) -> usize {
        self.pop.num_buckets()
    }

    /// Resident bytes: grid slots + live EH structures (+ population EH).
    pub fn memory_bytes(&self) -> usize {
        std::mem::size_of::<Self>()
            + self.cells.len() * std::mem::size_of::<Option<Box<ExpHistogram>>>()
            + self.pop.memory_bytes()
            + self
                .cells
                .iter()
                .filter_map(|c| c.as_ref().map(|eh| eh.memory_bytes()))
                .sum::<usize>()
    }

    /// Theoretical bits per Lemma 4.4 accounting (Σ over live EHs).
    pub fn theory_bits(&self) -> usize {
        self.cells
            .iter()
            .filter_map(|c| c.as_ref().map(|eh| eh.theory_bits()))
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lsh::srp::SrpLsh;
    use crate::sketch::race::Race;
    use crate::util::rng::Rng;

    fn random_points(rng: &mut Rng, n: usize, dim: usize) -> Vec<Vec<f32>> {
        (0..n)
            .map(|_| (0..dim).map(|_| rng.gaussian_f32()).collect())
            .collect()
    }

    /// Brute-force windowed kernel sum with the same hashes (the quantity
    /// SW-AKDE estimates before EH error).
    fn windowed_race_truth(
        fam: &SrpLsh,
        rows: usize,
        range: usize,
        p: usize,
        window_pts: &[Vec<f32>],
        q: &[f32],
    ) -> f64 {
        let mut race = Race::new(rows, range, p);
        for x in window_pts {
            race.add(fam, x);
        }
        race.query(fam, q)
    }

    #[test]
    fn matches_race_on_window_within_eh_error() {
        // With everything inside the window, SW-AKDE must equal RACE
        // restricted to the window up to the EH estimate error.
        let (dim, rows, range, p) = (8, 16, 16, 2);
        let eps = 0.1;
        let window = 64u64;
        let fam = SrpLsh::new(dim, rows * p, &mut Rng::new(1));
        let mut rng = Rng::new(2);
        let stream = random_points(&mut rng, 200, dim);
        let mut sw = SwAkde::new(rows, range, p, eps, window);
        for x in &stream {
            sw.add(&fam, x);
        }
        let live = &stream[stream.len() - window as usize..];
        let q: Vec<f32> = (0..dim).map(|_| rng.gaussian_f32()).collect();
        let truth = windowed_race_truth(&fam, rows, range, p, live, &q);
        let est = sw.query(&fam, &q);
        assert!(
            (est - truth).abs() <= eps * truth + 1e-9,
            "est={est} truth={truth}"
        );
    }

    #[test]
    fn expired_data_stops_counting() {
        let (dim, rows, range, p) = (6, 8, 8, 2);
        let fam = SrpLsh::new(dim, rows * p, &mut Rng::new(3));
        let mut sw = SwAkde::new(rows, range, p, 0.1, 10);
        let mut rng = Rng::new(4);
        let q: Vec<f32> = (0..dim).map(|_| rng.gaussian_f32()).collect();
        // Fill with points identical to q (max kernel value), then push
        // unrelated points until the window rolls past them.
        for _ in 0..10 {
            sw.add(&fam, &q);
        }
        let peak = sw.query(&fam, &q);
        assert!(peak > 5.0, "peak={peak}");
        let far: Vec<f32> = q.iter().map(|v| -v).collect();
        for _ in 0..20 {
            sw.add(&fam, &far);
        }
        let after = sw.query(&fam, &q);
        assert!(after < peak / 2.0, "peak={peak} after={after}");
    }

    #[test]
    fn batch_updates_match_sequential_window_of_batches() {
        // Cor 4.2: window counts batches; a batch of size B at one tick is
        // B same-timestamp increments.
        let (dim, rows, range, p) = (6, 8, 8, 2);
        let fam = SrpLsh::new(dim, rows * p, &mut Rng::new(5));
        let mut rng = Rng::new(6);
        let mut sw = SwAkde::new(rows, range, p, 0.1, 4); // window: 4 batches
        let batches: Vec<Vec<Vec<f32>>> =
            (0..8).map(|_| random_points(&mut rng, 5, dim)).collect();
        for b in &batches {
            let refs: Vec<&[f32]> = b.iter().map(|v| v.as_slice()).collect();
            sw.add_batch(&fam, &refs);
        }
        // Truth: RACE over the last 4 batches.
        let live: Vec<Vec<f32>> =
            batches[4..].iter().flatten().cloned().collect();
        let q: Vec<f32> = (0..dim).map(|_| rng.gaussian_f32()).collect();
        let truth = windowed_race_truth(&fam, rows, range, p, &live, &q);
        let est = sw.query(&fam, &q);
        assert!(
            (est - truth).abs() <= 0.1 * truth + 1e-9,
            "est={est} truth={truth}"
        );
    }

    #[test]
    fn add_slots_matches_native() {
        let (dim, rows, range, p) = (8, 4, 16, 2);
        let fam = SrpLsh::new(dim, rows * p, &mut Rng::new(7));
        let mut a = SwAkde::new(rows, range, p, 0.1, 32);
        let mut b = SwAkde::new(rows, range, p, 0.1, 32);
        let mut rng = Rng::new(8);
        for x in random_points(&mut rng, 50, dim) {
            a.add(&fam, &x);
            let mut slots = vec![0i64; rows * p];
            fam.hash_range(0, &x, &mut slots);
            b.add_slots(&slots);
        }
        let q: Vec<f32> = (0..dim).map(|_| rng.gaussian_f32()).collect();
        assert_eq!(a.query(&fam, &q), b.query(&fam, &q));
    }

    #[test]
    fn add_each_and_query_batch_match_sequential() {
        let (dim, rows, range, p) = (8, 8, 16, 2);
        let fam = SrpLsh::new(dim, rows * p, &mut Rng::new(60));
        let mut seq = SwAkde::new(rows, range, p, 0.1, 40);
        let mut bat = SwAkde::new(rows, range, p, 0.1, 40);
        let mut rng = Rng::new(61);
        let pts = random_points(&mut rng, 30, dim);
        let flat: Vec<f32> = pts.iter().flatten().copied().collect();
        for x in &pts {
            seq.add(&fam, x);
        }
        bat.add_each(&fam, &flat);
        assert_eq!(seq.now(), bat.now());
        let qs = random_points(&mut rng, 6, dim);
        let qflat: Vec<f32> = qs.iter().flatten().copied().collect();
        let batch_est = bat.query_batch(&fam, &qflat);
        for (q, &be) in qs.iter().zip(&batch_est) {
            assert_eq!(seq.query(&fam, q), be);
            assert_eq!(bat.query(&fam, q), be);
        }
    }

    #[test]
    fn lazy_cells_track_occupancy() {
        let (dim, rows, range, p) = (6, 4, 64, 3);
        let fam = SrpLsh::new(dim, rows * p, &mut Rng::new(9));
        let mut sw = SwAkde::new(rows, range, p, 0.1, 100);
        assert_eq!(sw.occupied_cells(), 0);
        let mut rng = Rng::new(10);
        // One point -> exactly `rows` occupied cells.
        sw.add(&fam, &random_points(&mut rng, 1, dim)[0]);
        assert_eq!(sw.occupied_cells(), rows);
        for x in random_points(&mut rng, 100, dim) {
            sw.add(&fam, &x);
        }
        assert!(sw.occupied_cells() <= rows * (1 << p));
    }

    #[test]
    fn density_normalizes_by_live_window() {
        let (dim, rows, range, p) = (6, 8, 8, 1);
        let fam = SrpLsh::new(dim, rows * p, &mut Rng::new(11));
        let mut sw = SwAkde::new(rows, range, p, 0.1, 50);
        let mut rng = Rng::new(12);
        let q: Vec<f32> = (0..dim).map(|_| rng.gaussian_f32()).collect();
        assert_eq!(sw.density(&fam, &q), 0.0, "empty sketch -> 0 density");
        for _ in 0..10 {
            sw.add(&fam, &q);
        }
        // All 10 points are q itself: kernel sum = 10, density = 1.
        let d = sw.density(&fam, &q);
        assert!((d - 1.0).abs() < 0.15, "density={d}");
    }

    #[test]
    fn population_counts_points_not_ticks_under_batches() {
        let (dim, rows, range, p) = (6, 8, 8, 2);
        let fam = SrpLsh::new(dim, rows * p, &mut Rng::new(40));
        let mut rng = Rng::new(41);
        // window = 4 ticks, batches of 5 points: live population is 20
        // points even though only 4 ticks are live.
        let mut sw = SwAkde::new(rows, range, p, 0.1, 4);
        for _ in 0..8 {
            let b = random_points(&mut rng, 5, dim);
            let refs: Vec<&[f32]> = b.iter().map(|v| v.as_slice()).collect();
            sw.add_batch(&fam, &refs);
        }
        let pop = sw.population();
        assert!(
            (pop - 20.0).abs() <= 0.1 * 20.0 + 1e-9,
            "pop={pop}, want ~20 points (not 4 ticks)"
        );
        // Single-point ticks: population is exactly min(now, window).
        let mut single = SwAkde::new(rows, range, p, 0.1, 100);
        for x in random_points(&mut rng, 50, dim) {
            single.add(&fam, &x);
        }
        assert_eq!(single.population(), 50.0);
    }

    #[test]
    fn density_with_batches_normalizes_by_points() {
        // 8 batches x 5 copies of q, window = 4 batches: the live window
        // holds 20 points all equal to q, so density(q) ~ 1. Normalizing
        // by ticks would report ~5.
        let (dim, rows, p) = (6, 8, 1);
        let fam = SrpLsh::new(dim, rows * p, &mut Rng::new(42));
        let mut rng = Rng::new(43);
        let q: Vec<f32> = (0..dim).map(|_| rng.gaussian_f32()).collect();
        let mut sw = SwAkde::new_srp(rows, p, 0.1, 4);
        for _ in 0..8 {
            let refs: Vec<&[f32]> = (0..5).map(|_| q.as_slice()).collect();
            sw.add_batch(&fam, &refs);
        }
        let d = sw.density(&fam, &q);
        assert!((d - 1.0).abs() < 0.25, "density={d}, want ~1");
    }

    #[test]
    fn debias_uses_point_population_under_batches() {
        // Rehash cells, batch ingest: 32 batches x 16 points, all far from
        // the query, window covers everything (512 live points). Spurious
        // rehash collisions put ~pop/W mass at the query's cells; the
        // debiased estimate must subtract the POINT population (~512/W =
        // 32) and land near the truth (~0). Subtracting ticks (32/W = 2)
        // would leave a residual of ~30.
        use crate::lsh::pstable::PStableLsh;
        let (dim, rows, range, p) = (8, 64, 16, 2);
        let fam = PStableLsh::new(dim, rows * p, 4.0, &mut Rng::new(44));
        let mut rng = Rng::new(45);
        let mut sw = SwAkde::new(rows, range, p, 0.05, 32);
        for _ in 0..32 {
            // Scattered far-away points: mutually distant AND far from q,
            // so true kernel mass at q is ~0 and hash tuples are distinct.
            let b: Vec<Vec<f32>> = (0..16)
                .map(|_| (0..dim).map(|_| rng.gaussian_f32() * 50.0).collect())
                .collect();
            let refs: Vec<&[f32]> = b.iter().map(|v| v.as_slice()).collect();
            sw.add_batch(&fam, &refs);
        }
        let q: Vec<f32> = (0..dim).map(|_| rng.gaussian_f32()).collect();
        let raw = sw.query(&fam, &q);
        assert!(raw > 20.0, "spurious mass must be visible: raw={raw}");
        let deb = sw.query_debiased(&fam, &q);
        assert!(deb < 10.0, "debias must remove ~pop/W: raw={raw} deb={deb}");
    }

    #[test]
    fn memory_grows_with_log_window_not_window() {
        let (dim, rows, range, p) = (8, 8, 16, 2);
        let fam = SrpLsh::new(dim, rows * p, &mut Rng::new(13));
        let mut rng = Rng::new(14);
        let build = |window: u64, rng: &mut Rng| {
            let mut sw = SwAkde::new(rows, range, p, 0.1, window);
            for x in random_points(rng, 4 * window as usize, dim) {
                sw.add(&fam, &x);
            }
            sw.theory_bits() as f64
        };
        let small = build(64, &mut rng);
        let large = build(4096, &mut rng);
        // 64x window must cost far less than 64x bits (log² scaling).
        assert!(large / small < 8.0, "small={small} large={large}");
    }
}
