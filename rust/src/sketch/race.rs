//! RACE — Repeated Array-of-Counts Estimator \[CS20\], paper §2.3 — and the
//! single-row ACE estimator \[LS18\] it repeats.
//!
//! An ACE array indexed by a p-wise concatenated LSH function is an
//! unbiased estimator of the LSH-kernel density Σ_x k^p(x, q)
//! (Theorem 2.3) with variance ≤ (Σ_x k^{p/2})² (Theorem 2.4). RACE
//! repeats R independent rows and aggregates — mean or median-of-means.
//! Counters are i64, so the turnstile model (insert = +1, delete = −1) is
//! native. This is also the baseline SW-AKDE is compared against (Fig 11).

use crate::lsh::concat::BoundedHasher;
use crate::lsh::LshFamily;
use crate::util::stats;

/// A single Array-of-Counts Estimator row.
pub struct Ace {
    counts: Vec<i64>,
}

impl Ace {
    pub fn new(range: usize) -> Self {
        Ace { counts: vec![0; range] }
    }

    #[inline]
    pub fn add(&mut self, cell: usize, delta: i64) {
        self.counts[cell] += delta;
    }

    #[inline]
    pub fn get(&self, cell: usize) -> i64 {
        self.counts[cell]
    }

    pub fn range(&self) -> usize {
        self.counts.len()
    }

    /// The raw counter array (snapshot/persistence access).
    pub fn counts(&self) -> &[i64] {
        &self.counts
    }
}

/// The R×W counter grid with its bounded concatenated hasher.
pub struct Race {
    rows: Vec<Ace>,
    hasher: BoundedHasher,
    /// Net insertions (for density normalization).
    population: i64,
    /// Raw-slot scratch reused across updates/queries (no per-op alloc).
    scratch: Vec<i64>,
    /// Cell-index scratch for the single-point kernel path.
    cells_scratch: Vec<usize>,
    /// Per-row count scratch for the query read path.
    counts_scratch: Vec<f64>,
}

impl Race {
    /// `rows` independent repetitions, each hashing with `p` concatenated
    /// raw functions rehashed into [0, range) (p-stable style).
    pub fn new(rows: usize, range: usize, p: usize) -> Self {
        Self::with_hasher(BoundedHasher::new(p, rows, range))
    }

    /// SRP variant: cells are the packed p hash bits (range 2^p) — the
    /// exact ACE cell structure, with no rehash bias.
    pub fn new_srp(rows: usize, p: usize) -> Self {
        Self::with_hasher(BoundedHasher::new_packed(p, rows))
    }

    pub fn with_hasher(hasher: BoundedHasher) -> Self {
        let (rows, range) = (hasher.rows, hasher.range);
        Race {
            rows: (0..rows).map(|_| Ace::new(range)).collect(),
            hasher,
            population: 0,
            scratch: Vec::new(),
            cells_scratch: Vec::new(),
            counts_scratch: Vec::new(),
        }
    }

    /// Rebuild from snapshot parts: `counts` is the row-major
    /// [rows, range] counter grid. The caller (snapshot restore) has
    /// already validated that `counts.len() == rows * range`.
    pub fn from_parts(hasher: BoundedHasher, counts: &[i64], population: i64) -> Self {
        assert_eq!(counts.len(), hasher.rows * hasher.range);
        let range = hasher.range;
        Race {
            rows: counts.chunks_exact(range).map(|c| Ace { counts: c.to_vec() }).collect(),
            hasher,
            population,
            scratch: Vec::new(),
            cells_scratch: Vec::new(),
            counts_scratch: Vec::new(),
        }
    }

    pub fn rows(&self) -> usize {
        self.rows.len()
    }

    /// The concatenated-hash configuration (snapshot/persistence access).
    pub fn hasher(&self) -> &BoundedHasher {
        &self.hasher
    }

    /// The per-row ACE arrays (snapshot/persistence access).
    pub fn aces(&self) -> &[Ace] {
        &self.rows
    }

    pub fn range(&self) -> usize {
        self.hasher.range
    }

    pub fn p(&self) -> usize {
        self.hasher.p
    }

    /// Raw LSH functions required of the family.
    pub fn funcs_needed(&self) -> usize {
        self.hasher.funcs_needed()
    }

    pub fn population(&self) -> i64 {
        self.population
    }

    /// Insert `x` (turnstile: `delta = -1` deletes). All R·p raw hashes run
    /// as one blocked kernel pass over the projection matrix (the RACE
    /// update IS a matrix–vector product) instead of R strided `cell` calls.
    pub fn update<F: LshFamily + ?Sized>(&mut self, fam: &F, x: &[f32], delta: i64) {
        let mut cells = std::mem::take(&mut self.cells_scratch);
        cells.resize(self.rows.len(), 0);
        self.hasher.cells(fam, x, &mut cells, &mut self.scratch);
        for (row, &cell) in self.rows.iter_mut().zip(&cells) {
            row.add(cell, delta);
        }
        self.cells_scratch = cells;
        self.population += delta;
    }

    pub fn add<F: LshFamily + ?Sized>(&mut self, fam: &F, x: &[f32]) {
        self.update(fam, x, 1);
    }

    pub fn remove<F: LshFamily + ?Sized>(&mut self, fam: &F, x: &[f32]) {
        self.update(fam, x, -1);
    }

    /// Batched turnstile update: hash every point of `xs` (row-major
    /// [n, dim]) through one GEMM-shaped kernel call, then scatter the
    /// counter deltas. Identical end state to n sequential `update`s.
    pub fn update_batch<F: LshFamily + ?Sized>(&mut self, fam: &F, xs: &[f32], delta: i64) {
        let d = fam.dim();
        debug_assert!(d > 0 && xs.len() % d == 0);
        let n = xs.len() / d;
        if n == 0 {
            return;
        }
        let rows = self.rows.len();
        let mut cells = std::mem::take(&mut self.cells_scratch);
        let mut slots = std::mem::take(&mut self.scratch);
        self.hasher.cells_batch(fam, xs, &mut cells, &mut slots);
        for row_cells in cells.chunks_exact(rows) {
            for (row, &cell) in self.rows.iter_mut().zip(row_cells) {
                row.add(cell, delta);
            }
        }
        self.scratch = slots;
        self.cells_scratch = cells;
        self.population += delta * n as i64;
    }

    /// Batched insert (`update_batch` with delta = +1).
    pub fn add_batch<F: LshFamily + ?Sized>(&mut self, fam: &F, xs: &[f32]) {
        self.update_batch(fam, xs, 1);
    }

    /// Update from precomputed raw slots (PJRT batch path; layout `\[rows*p\]`).
    pub fn update_slots(&mut self, slots: &[i64], delta: i64) {
        for i in 0..self.rows.len() {
            let cell = self.hasher.cell_from_slots(i, slots);
            self.rows[i].add(cell, delta);
        }
        self.population += delta;
    }

    /// Per-row counts at the query's cells, written into caller storage —
    /// the allocation-free RACE read path (`out.len()` must equal R). One
    /// kernel pass hashes all R·p functions.
    pub fn row_counts_into<F: LshFamily + ?Sized>(&mut self, fam: &F, q: &[f32], out: &mut [f64]) {
        debug_assert_eq!(out.len(), self.rows.len());
        let mut cells = std::mem::take(&mut self.cells_scratch);
        cells.resize(self.rows.len(), 0);
        self.hasher.cells(fam, q, &mut cells, &mut self.scratch);
        for ((o, &cell), row) in out.iter_mut().zip(&cells).zip(&self.rows) {
            *o = row.get(cell) as f64;
        }
        self.cells_scratch = cells;
    }

    /// Per-row counts at the query's cells (allocating convenience).
    pub fn row_counts<F: LshFamily + ?Sized>(&mut self, fam: &F, q: &[f32]) -> Vec<f64> {
        let mut out = vec![0.0; self.rows.len()];
        self.row_counts_into(fam, q, &mut out);
        out
    }

    /// Mean estimator (1/R)Σ A[i, h_i(q)] — the un-normalized kernel sum.
    pub fn query<F: LshFamily + ?Sized>(&mut self, fam: &F, q: &[f32]) -> f64 {
        let mut counts = std::mem::take(&mut self.counts_scratch);
        counts.resize(self.rows.len(), 0.0);
        self.row_counts_into(fam, q, &mut counts);
        let est = stats::mean(&counts);
        self.counts_scratch = counts;
        est
    }

    /// Median-of-means estimator (the robust aggregation CS20 uses).
    pub fn query_mom<F: LshFamily + ?Sized>(&mut self, fam: &F, q: &[f32], groups: usize) -> f64 {
        let mut counts = std::mem::take(&mut self.counts_scratch);
        counts.resize(self.rows.len(), 0.0);
        self.row_counts_into(fam, q, &mut counts);
        let est = stats::median_of_means(&counts, groups);
        self.counts_scratch = counts;
        est
    }

    /// Batched mean estimator: hash all queries (row-major [n, dim]) with
    /// one GEMM-shaped kernel call, then read each query's R cells.
    /// Identical values to n sequential `query` calls.
    pub fn query_batch<F: LshFamily + ?Sized>(&mut self, fam: &F, qs: &[f32]) -> Vec<f64> {
        let d = fam.dim();
        debug_assert!(d > 0 && qs.len() % d == 0);
        let n = qs.len() / d;
        if n == 0 {
            return Vec::new();
        }
        let rows = self.rows.len();
        let mut cells = std::mem::take(&mut self.cells_scratch);
        let mut slots = std::mem::take(&mut self.scratch);
        self.hasher.cells_batch(fam, qs, &mut cells, &mut slots);
        let mut counts = std::mem::take(&mut self.counts_scratch);
        counts.resize(rows, 0.0);
        let mut out = Vec::with_capacity(n);
        for row_cells in cells.chunks_exact(rows) {
            for (i, c) in counts.iter_mut().enumerate() {
                *c = self.rows[i].get(row_cells[i]) as f64;
            }
            out.push(stats::mean(&counts));
        }
        self.counts_scratch = counts;
        self.scratch = slots;
        self.cells_scratch = cells;
        out
    }

    /// Rehash-debiased estimator: under `CellMap::Rehash`, distinct tuples
    /// collide spuriously w.p. ≈ 1/range, so E\[count\] = (1−1/W)·KDE + n/W;
    /// inverting restores ACE unbiasedness. Under `PackBits` this is the
    /// plain mean (no bias to remove).
    pub fn query_debiased<F: LshFamily + ?Sized>(&mut self, fam: &F, q: &[f32]) -> f64 {
        let raw = self.query(fam, q);
        match self.hasher.map {
            crate::lsh::concat::CellMap::PackBits => raw,
            crate::lsh::concat::CellMap::Rehash => {
                let w = self.hasher.range as f64;
                ((raw - self.population as f64 / w) / (1.0 - 1.0 / w)).max(0.0)
            }
        }
    }

    /// Counter-grid bytes.
    pub fn memory_bytes(&self) -> usize {
        std::mem::size_of::<Self>() + self.rows.len() * self.range() * std::mem::size_of::<i64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lsh::srp::SrpLsh;
    use crate::util::rng::Rng;

    fn random_points(rng: &mut Rng, n: usize, dim: usize) -> Vec<Vec<f32>> {
        (0..n)
            .map(|_| (0..dim).map(|_| rng.gaussian_f32()).collect())
            .collect()
    }

    /// Exact LSH-kernel density Σ k^p(x, q) for the angular kernel.
    fn exact_angular_kde(data: &[Vec<f32>], q: &[f32], p: usize) -> f64 {
        data.iter()
            .map(|x| {
                let cos = crate::util::cosine(x, q) as f64;
                (1.0 - cos.acos() / std::f64::consts::PI).powi(p as i32)
            })
            .sum()
    }

    #[test]
    fn ace_unbiasedness_monte_carlo() {
        // E[A[h(q)]] = sum_x k^p(x, q): average many independent ACEs.
        let dim = 8;
        let p = 2;
        let trials = 400;
        let mut rng = Rng::new(42);
        let data = random_points(&mut rng, 30, dim);
        let q: Vec<f32> = (0..dim).map(|_| rng.gaussian_f32()).collect();
        let truth = exact_angular_kde(&data, &q, p);
        let mut sum = 0.0;
        for t in 0..trials {
            let fam = SrpLsh::new(dim, p, &mut Rng::new(1000 + t));
            let mut race = Race::new_srp(1, p);
            for x in &data {
                race.add(&fam, x);
            }
            sum += race.query(&fam, &q);
        }
        let est = sum / trials as f64;
        // MC error ~ sqrt(var/trials); truth is O(10) here.
        assert!(
            (est - truth).abs() < 0.15 * truth,
            "est={est} truth={truth}"
        );
    }

    #[test]
    fn more_rows_reduce_error() {
        let dim = 16;
        let p = 3;
        let mut rng = Rng::new(7);
        let data = random_points(&mut rng, 200, dim);
        let queries = random_points(&mut rng, 20, dim);
        let mut err_for = |rows: usize| {
            let fam = SrpLsh::new(dim, rows * p, &mut Rng::new(9));
            let mut race = Race::new_srp(rows, p);
            for x in &data {
                race.add(&fam, x);
            }
            let mut errs = Vec::new();
            for q in &queries {
                let truth = exact_angular_kde(&data, q, p);
                let est = race.query(&fam, q);
                errs.push((est - truth).abs() / truth);
            }
            crate::util::stats::mean(&errs)
        };
        let few = err_for(4);
        let many = err_for(256);
        assert!(many < few, "few-rows err {few} vs many-rows err {many}");
        assert!(many < 0.2, "256-row error should be small: {many}");
    }

    #[test]
    fn turnstile_insert_then_delete_is_identity() {
        let dim = 8;
        let fam = SrpLsh::new(dim, 8 * 2, &mut Rng::new(3));
        let mut race = Race::new(8, 4, 2);
        let mut rng = Rng::new(4);
        let keep = random_points(&mut rng, 20, dim);
        let churn = random_points(&mut rng, 20, dim);
        for x in &keep {
            race.add(&fam, x);
        }
        let q: Vec<f32> = (0..dim).map(|_| rng.gaussian_f32()).collect();
        let before = race.query(&fam, &q);
        for x in &churn {
            race.add(&fam, x);
        }
        for x in &churn {
            race.remove(&fam, x);
        }
        let after = race.query(&fam, &q);
        assert_eq!(before, after);
        assert_eq!(race.population(), 20);
    }

    #[test]
    fn update_slots_matches_native() {
        let dim = 8;
        let rows = 4;
        let p = 2;
        let fam = SrpLsh::new(dim, rows * p, &mut Rng::new(5));
        let mut a = Race::new(rows, 16, p);
        let mut b = Race::new(rows, 16, p);
        let mut rng = Rng::new(6);
        let pts = random_points(&mut rng, 30, dim);
        for x in &pts {
            a.add(&fam, x);
            let mut slots = vec![0i64; rows * p];
            fam.hash_range(0, x, &mut slots);
            b.update_slots(&slots, 1);
        }
        let q: Vec<f32> = (0..dim).map(|_| rng.gaussian_f32()).collect();
        assert_eq!(a.query(&fam, &q), b.query(&fam, &q));
    }

    #[test]
    fn batch_paths_match_sequential() {
        let dim = 8;
        let (rows, p) = (16, 2);
        let fam = SrpLsh::new(dim, rows * p, &mut Rng::new(50));
        let mut seq = Race::new(rows, 32, p);
        let mut bat = Race::new(rows, 32, p);
        let mut rng = Rng::new(51);
        let pts = random_points(&mut rng, 40, dim);
        let flat: Vec<f32> = pts.iter().flatten().copied().collect();
        for x in &pts {
            seq.add(&fam, x);
        }
        bat.add_batch(&fam, &flat);
        assert_eq!(seq.population(), bat.population());
        let qs = random_points(&mut rng, 7, dim);
        let qflat: Vec<f32> = qs.iter().flatten().copied().collect();
        let batch_est = bat.query_batch(&fam, &qflat);
        for (q, &be) in qs.iter().zip(&batch_est) {
            assert_eq!(seq.query(&fam, q), be);
            assert_eq!(bat.query(&fam, q), be);
        }
    }

    #[test]
    fn row_counts_into_matches_allocating_variant() {
        let dim = 6;
        let (rows, p) = (8, 2);
        let fam = SrpLsh::new(dim, rows * p, &mut Rng::new(52));
        let mut race = Race::new_srp(rows, p);
        let mut rng = Rng::new(53);
        for x in random_points(&mut rng, 25, dim) {
            race.add(&fam, &x);
        }
        let q: Vec<f32> = (0..dim).map(|_| rng.gaussian_f32()).collect();
        let alloc = race.row_counts(&fam, &q);
        let mut into = vec![0.0; rows];
        race.row_counts_into(&fam, &q, &mut into);
        assert_eq!(alloc, into);
    }

    #[test]
    fn self_density_dominates_far_query() {
        // A query sitting on a dense cluster must see a larger estimate
        // than one far from everything (on the sphere: opposite direction).
        let dim = 12;
        let p = 4;
        let rows = 64;
        let fam = SrpLsh::new(dim, rows * p, &mut Rng::new(8));
        let mut race = Race::new_srp(rows, p);
        let mut rng = Rng::new(9);
        let center: Vec<f32> = (0..dim).map(|_| rng.gaussian_f32()).collect();
        for _ in 0..100 {
            let x: Vec<f32> = center.iter().map(|v| v + 0.05 * rng.gaussian_f32()).collect();
            race.add(&fam, &x);
        }
        let near = race.query(&fam, &center);
        let anti: Vec<f32> = center.iter().map(|v| -v).collect();
        let far = race.query(&fam, &anti);
        assert!(near > 10.0 * far.max(0.1), "near={near} far={far}");
    }

    #[test]
    fn memory_is_rows_times_range() {
        let race = Race::new(10, 32, 2);
        assert!(race.memory_bytes() >= 10 * 32 * 8);
        assert!(race.memory_bytes() < 10 * 32 * 8 + 1024);
    }
}
