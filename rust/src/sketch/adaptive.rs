//! Adaptive window selection — the paper's §6 future-work direction:
//! "adaptive mechanisms for adjusting the window size based on the
//! evolving data distribution".
//!
//! [`AdaptiveSwAkde`] maintains a small bank of SW-AKDE sketches at
//! geometrically spaced window sizes over the same stream (cost: a
//! log-factor in space) and, at query time, scores each window by the
//! *stability* of its density estimate: for each window W it compares the
//! estimate at W against the estimate at W/2. A large discrepancy means
//! the older half of the window disagrees with the newer half — the
//! distribution drifted inside the window — so the smallest window whose
//! halves agree (within `tolerance`) is selected. Under drift this picks
//! short windows (fast adaptation); under stationarity it picks long ones
//! (low variance) — exactly the trade-off Fig 10 exposes manually.

use crate::lsh::LshFamily;
use crate::sketch::SwAkde;

/// A bank of SW-AKDE sketches with adaptive window selection.
pub struct AdaptiveSwAkde {
    /// Sketches at windows w₀, 2w₀, 4w₀, …, front = smallest.
    bank: Vec<SwAkde>,
    /// Relative half-window discrepancy below which a window is "stable".
    tolerance: f64,
}

impl AdaptiveSwAkde {
    /// Bank with `levels` windows: base, 2·base, …, 2^{levels−1}·base.
    /// All sketches share the SRP cell structure (rows, p) and EH ε'.
    pub fn new_srp(rows: usize, p: usize, eps_eh: f64, base_window: u64, levels: usize, tolerance: f64) -> Self {
        assert!(levels >= 2);
        let bank = (0..levels)
            .map(|i| SwAkde::new_srp(rows, p, eps_eh, base_window << i))
            .collect();
        AdaptiveSwAkde { bank, tolerance }
    }

    pub fn levels(&self) -> usize {
        self.bank.len()
    }

    pub fn windows(&self) -> Vec<u64> {
        self.bank.iter().map(|s| s.window()).collect()
    }

    /// Ingest one element into every level.
    pub fn add<F: LshFamily + ?Sized>(&mut self, fam: &F, x: &[f32]) {
        for s in &mut self.bank {
            s.add(fam, x);
        }
    }

    /// Normalized density per level (index 0 = smallest window).
    pub fn densities<F: LshFamily + ?Sized>(&mut self, fam: &F, q: &[f32]) -> Vec<f64> {
        self.bank.iter_mut().map(|s| s.density(fam, q)).collect()
    }

    /// Pick the window: the LARGEST window whose density agrees with the
    /// next-smaller window within `tolerance` (relative), scanning from
    /// small to large and stopping at the first disagreement. Returns
    /// (chosen window size, density estimate at it).
    pub fn query<F: LshFamily + ?Sized>(&mut self, fam: &F, q: &[f32]) -> (u64, f64) {
        let d = self.densities(fam, q);
        let mut chosen = 0usize;
        for i in 1..d.len() {
            let scale = d[i - 1].abs().max(1e-12);
            if (d[i] - d[i - 1]).abs() / scale <= self.tolerance {
                chosen = i;
            } else {
                break; // the larger window mixes in drifted data
            }
        }
        (self.bank[chosen].window(), d[chosen])
    }

    pub fn memory_bytes(&self) -> usize {
        self.bank.iter().map(|s| s.memory_bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lsh::srp::SrpLsh;
    use crate::util::rng::Rng;

    fn gaussian_cloud(rng: &mut Rng, center: f32, n: usize, dim: usize) -> Vec<Vec<f32>> {
        (0..n)
            .map(|_| (0..dim).map(|_| center + 0.3 * rng.gaussian_f32()).collect())
            .collect()
    }

    #[test]
    fn stationary_stream_prefers_large_windows() {
        let dim = 12;
        let (rows, p) = (32, 4);
        let fam = SrpLsh::new(dim, rows * p, &mut Rng::new(1));
        let mut ad = AdaptiveSwAkde::new_srp(rows, p, 0.1, 64, 4, 0.25);
        let mut rng = Rng::new(2);
        let pts = gaussian_cloud(&mut rng, 1.0, 2000, dim);
        for x in &pts {
            ad.add(&fam, x);
        }
        let q = pts[1500].clone();
        let (w, _) = ad.query(&fam, &q);
        assert!(
            w >= 256,
            "stationary data should pick a large window, got {w}"
        );
    }

    #[test]
    fn drifted_stream_prefers_small_windows() {
        let dim = 12;
        let (rows, p) = (32, 4);
        let fam = SrpLsh::new(dim, rows * p, &mut Rng::new(3));
        let mut ad = AdaptiveSwAkde::new_srp(rows, p, 0.1, 64, 4, 0.25);
        let mut rng = Rng::new(4);
        // Old regime far from the new one; drift 100 steps ago.
        for x in gaussian_cloud(&mut rng, -3.0, 2000, dim) {
            ad.add(&fam, &x);
        }
        let recent = gaussian_cloud(&mut rng, 3.0, 100, dim);
        for x in &recent {
            ad.add(&fam, x);
        }
        // Query in the NEW regime: big windows mix in the old regime's
        // (near-zero density) mass, so their estimates disagree.
        let q = recent[50].clone();
        let (w, dens) = ad.query(&fam, &q);
        assert!(w <= 128, "post-drift query should pick a small window, got {w}");
        assert!(dens > 0.1, "density in the live regime should be high: {dens}");
    }

    #[test]
    fn densities_are_per_level_and_bank_grows_geometric() {
        let fam = SrpLsh::new(8, 32 * 3, &mut Rng::new(5));
        let mut ad = AdaptiveSwAkde::new_srp(32, 3, 0.1, 16, 3, 0.3);
        assert_eq!(ad.windows(), vec![16, 32, 64]);
        let mut rng = Rng::new(6);
        for x in gaussian_cloud(&mut rng, 0.0, 100, 8) {
            ad.add(&fam, &x);
        }
        let q: Vec<f32> = (0..8).map(|_| rng.gaussian_f32()).collect();
        assert_eq!(ad.densities(&fam, &q).len(), 3);
        assert!(ad.memory_bytes() > 0);
    }
}
