//! The paper's sketches: S-ANN (§3), RACE/ACE (§2.3), the exponential
//! histogram (§2.4) and SW-AKDE (§4), plus the sampling substrate.

pub mod adaptive;
pub mod ann;
pub mod eh;
pub mod race;
pub mod sampler;
pub mod snapshot;
pub mod swakde;
pub mod turnstile;

pub use ann::{SAnn, SAnnConfig};
pub use eh::ExpHistogram;
pub use race::{Ace, Race};
pub use swakde::SwAkde;
