//! Reusable KDE experiment runners behind the Fig 9–11 benches.
//!
//! Protocol (§5.2): stream the dataset through the sketch, then measure
//! mean relative error of the windowed kernel-sum estimate against the
//! exact LSH-kernel density over the live window (RACE is judged against
//! the full stream, since it never expires data).

use crate::lsh::pstable::PStableLsh;
use crate::lsh::srp::SrpLsh;
use crate::lsh::LshFamily;
use crate::metrics;
use crate::sketch::race::Race;
use crate::sketch::SwAkde;
use crate::util::rng::Rng;

/// Which collision kernel a run uses (paper evaluates both).
#[derive(Clone, Copy, Debug)]
pub enum Kernel {
    /// SRP, packed cells (range 2^p).
    Angular { p: usize },
    /// p-stable with rehash range and bucket width.
    Euclidean { p: usize, width: f32, range: usize },
}

impl Kernel {
    pub fn label(&self) -> &'static str {
        match self {
            Kernel::Angular { .. } => "angular",
            Kernel::Euclidean { .. } => "euclidean",
        }
    }

    fn family(&self, dim: usize, rows: usize, rng: &mut Rng) -> Box<dyn LshFamily> {
        match *self {
            Kernel::Angular { p } => Box::new(SrpLsh::new(dim, rows * p, rng)),
            Kernel::Euclidean { p, width, .. } => {
                Box::new(PStableLsh::new(dim, rows * p, width, rng))
            }
        }
    }

    fn exact(&self, data: &[Vec<f32>], q: &[f32]) -> f64 {
        match *self {
            Kernel::Angular { p } => crate::baselines::exact_kde_angular(data, q, p as u32),
            Kernel::Euclidean { p, width, .. } => {
                crate::baselines::exact_kde_pstable(data, q, width as f64, p as u32)
            }
        }
    }
}

/// One experimental point.
#[derive(Clone, Debug)]
pub struct KdeRunResult {
    pub mre: f64,
    pub log10_mre: f64,
    pub sketch_bytes: usize,
    pub theory_bits: usize,
}

/// SW-AKDE: error over the sliding window.
pub fn run_swakde(
    stream: &[Vec<f32>],
    queries: &[Vec<f32>],
    kernel: Kernel,
    rows: usize,
    window: u64,
    eps_eh: f64,
    seed: u64,
) -> KdeRunResult {
    let dim = stream[0].len();
    let mut rng = Rng::new(seed);
    let fam = kernel.family(dim, rows, &mut rng);
    let mut sw = match kernel {
        Kernel::Angular { p } => SwAkde::new_srp(rows, p, eps_eh, window),
        Kernel::Euclidean { p, range, .. } => SwAkde::new(rows, range, p, eps_eh, window),
    };
    for x in stream {
        sw.add(fam.as_ref(), x);
    }
    let live = &stream[stream.len().saturating_sub(window as usize)..];
    let (mut est, mut truth) = (Vec::new(), Vec::new());
    for q in queries {
        est.push(sw.query_debiased(fam.as_ref(), q));
        truth.push(kernel.exact(live, q));
    }
    let mre = metrics::mean_relative_error(&est, &truth);
    KdeRunResult {
        mre,
        log10_mre: crate::util::stats::log10_floored(mre),
        sketch_bytes: sw.memory_bytes(),
        theory_bits: sw.theory_bits(),
    }
}

/// RACE baseline: error over the whole stream (it never expires data).
pub fn run_race(
    stream: &[Vec<f32>],
    queries: &[Vec<f32>],
    kernel: Kernel,
    rows: usize,
    seed: u64,
) -> KdeRunResult {
    let dim = stream[0].len();
    let mut rng = Rng::new(seed);
    let fam = kernel.family(dim, rows, &mut rng);
    let mut race = match kernel {
        Kernel::Angular { p } => Race::new_srp(rows, p),
        Kernel::Euclidean { p, range, .. } => Race::new(rows, range, p),
    };
    for x in stream {
        race.add(fam.as_ref(), x);
    }
    let (mut est, mut truth) = (Vec::new(), Vec::new());
    for q in queries {
        est.push(race.query_debiased(fam.as_ref(), q));
        truth.push(kernel.exact(stream, q));
    }
    let mre = metrics::mean_relative_error(&est, &truth);
    KdeRunResult {
        mre,
        log10_mre: crate::util::stats::log10_floored(mre),
        sketch_bytes: race.memory_bytes(),
        theory_bits: race.memory_bytes() * 8,
    }
}

/// Paper row-size grid (×/÷ by `scale` for CI-sized runs).
pub fn rows_grid(full: bool) -> Vec<usize> {
    if full {
        vec![100, 200, 400, 800, 1600, 3200]
    } else {
        vec![25, 50, 100, 200, 400]
    }
}

/// Paper window grid (Fig 10).
pub fn window_grid(full: bool) -> Vec<u64> {
    if full {
        vec![64, 128, 256, 512, 1024, 2048]
    } else {
        vec![64, 128, 256, 512, 1024]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::datasets;

    fn workload() -> (Vec<Vec<f32>>, Vec<Vec<f32>>) {
        datasets::kde_synthetic(1_200, 5).split_queries(50)
    }

    #[test]
    fn swakde_error_drops_with_rows() {
        let (stream, queries) = workload();
        let kernel = Kernel::Angular { p: 2 };
        let small = run_swakde(&stream, &queries, kernel, 8, 300, 0.1, 1);
        let large = run_swakde(&stream, &queries, kernel, 128, 300, 0.1, 1);
        assert!(
            large.mre < small.mre,
            "rows=8 mre={} rows=128 mre={}",
            small.mre,
            large.mre
        );
        assert!(large.mre < 0.35, "mre={}", large.mre);
    }

    #[test]
    fn euclidean_kernel_also_converges() {
        let (stream, queries) = workload();
        let kernel = Kernel::Euclidean { p: 2, width: 8.0, range: 128 };
        let res = run_swakde(&stream, &queries, kernel, 128, 300, 0.1, 2);
        assert!(res.mre < 0.5, "mre={}", res.mre);
    }

    #[test]
    fn race_matches_swakde_scale_on_static_window() {
        // When the window covers the whole stream, SW-AKDE and RACE see the
        // same data; errors should be comparable (Fig 11's claim).
        let (stream, queries) = workload();
        let kernel = Kernel::Angular { p: 2 };
        let sw = run_swakde(&stream, &queries, kernel, 64, stream.len() as u64, 0.1, 3);
        let race = run_race(&stream, &queries, kernel, 64, 3);
        assert!(
            (sw.mre - race.mre).abs() < 0.15,
            "sw={} race={}",
            sw.mre,
            race.mre
        );
    }

    #[test]
    fn sketch_memory_grows_with_rows() {
        let (stream, queries) = workload();
        let kernel = Kernel::Angular { p: 2 };
        let a = run_swakde(&stream, &queries, kernel, 8, 300, 0.1, 4);
        let b = run_swakde(&stream, &queries, kernel, 64, 300, 0.1, 4);
        assert!(b.sketch_bytes > a.sketch_bytes);
    }
}
