//! Experiment runners shared by the figure benches (DESIGN.md §4).

pub mod ann;
pub mod kde;

pub use ann::{AnnRunResult, AnnWorkload};
pub use kde::{run_race, run_swakde, Kernel};
