//! Reusable ANN experiment runners behind the Fig 5–8 benches.
//!
//! The paper's protocol (§5.1): store a stream prefix, issue queries, and
//! report approximate recall@50, (c, r)-ANN accuracy, compression rate
//! (vs N·d·4 bytes) and query throughput, sweeping compression via η
//! (S-ANN) or the projection dimension k (JL). ε enters as c = 1 + ε.

use crate::baselines::{ExactNn, JlBaseline};
use crate::metrics;
use crate::metrics::latency::Throughput;
use crate::sketch::ann::{SAnn, SAnnConfig};

/// One experimental point.
#[derive(Clone, Debug)]
pub struct AnnRunResult {
    pub recall50: f64,
    pub cr_accuracy: f64,
    pub compression: f64,
    pub qps: f64,
    pub stored: usize,
    pub sketch_bytes: usize,
}

/// Shared ground truth for one (stream, queries) workload.
pub struct AnnWorkload {
    pub dim: usize,
    pub stream: Vec<Vec<f32>>,
    pub queries: Vec<Vec<f32>>,
    pub exact: ExactNn,
    /// True 50th-NN distance per query (approximate-recall threshold base).
    pub d50: Vec<f32>,
    /// Near radius r, calibrated so r-balls are DENSE: the median distance
    /// to the ⌈n^0.65⌉-th nearest neighbor. Theorem 3.1 requires ball
    /// occupancy m ≥ C·n^η — a radius at the bare NN distance (m ≈ 1)
    /// violates it and makes every sampled sketch vacuously fail. The
    /// paper's fixed r = 0.5 on sift1m plays the same dense-radius role.
    pub r: f64,
}

impl AnnWorkload {
    pub fn new(stream: Vec<Vec<f32>>, queries: Vec<Vec<f32>>) -> Self {
        let dim = stream[0].len();
        let exact = ExactNn::from_points(dim, &stream);
        let n = stream.len();
        let m_star = ((n as f64).powf(0.65).ceil() as usize).clamp(50, n / 2);
        let mut d50 = Vec::with_capacity(queries.len());
        let mut r_samples = Vec::with_capacity(queries.len());
        for q in &queries {
            let top = exact.topk(q, m_star);
            d50.push(top.get(49).map(|&(_, d)| d).unwrap_or(f32::INFINITY));
            r_samples.push(top.last().map(|&(_, d)| d as f64).unwrap_or(0.0));
        }
        r_samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let r = r_samples[r_samples.len() / 2].max(1e-6);
        AnnWorkload { dim, stream, queries, exact, d50, r }
    }

    /// S-ANN at sampling exponent `eta` with approximation ε (c = 1 + ε).
    pub fn run_sann(&self, eps: f64, eta: f64, seed: u64) -> AnnRunResult {
        let sens = crate::lsh::params::default_width(self.r, 1.0 + eps);
        let cfg = SAnnConfig {
            dim: self.dim,
            n_max: self.stream.len(),
            eta,
            r: self.r,
            c: 1.0 + eps,
            w: sens.w,
            l_cap: 32,
            seed,
        };
        let mut ann = SAnn::new(cfg.clone());
        for p in &self.stream {
            ann.insert(p);
        }
        let mut recalls = Vec::new();
        let mut outcomes = Vec::new();
        let mut qps = Throughput::new();
        for (q, &d50) in self.queries.iter().zip(&self.d50) {
            let top = ann.query_topk(q, 50);
            qps.add(1);
            let dists: Vec<f32> = top.iter().map(|&(_, d)| d).collect();
            recalls.push(metrics::approx_recall_at_k(&dists, d50, eps as f32, 50));
            let ans = top.first().map(|&(id, _)| metrics::answer_distance(q, ann.vector(id)));
            // Algorithm 1's contract: answer counts only within c*r.
            let ans = ans.filter(|&d| d <= ((1.0 + eps) * self.r) as f32 + 1e-6);
            outcomes.push(metrics::cr_outcome(
                &self.exact,
                q,
                self.r as f32,
                (1.0 + eps) as f32,
                ans,
            ));
        }
        let bytes = ann.memory_bytes();
        AnnRunResult {
            recall50: crate::util::stats::mean(&recalls),
            cr_accuracy: metrics::cr_accuracy(&outcomes),
            compression: metrics::compression_rate(bytes, self.stream.len(), self.dim),
            qps: qps.per_second(),
            stored: ann.stored(),
            sketch_bytes: bytes,
        }
    }

    /// JL baseline at projection dimension `k` (same ε for the contract).
    pub fn run_jl(&self, eps: f64, k: usize, seed: u64) -> AnnRunResult {
        let mut jl = JlBaseline::new(self.dim, k, seed);
        for p in &self.stream {
            jl.insert(p);
        }
        let mut recalls = Vec::new();
        let mut outcomes = Vec::new();
        let mut qps = Throughput::new();
        for (q, &d50) in self.queries.iter().zip(&self.d50) {
            let top = jl.query_topk(q, 50);
            qps.add(1);
            // Judge retrieved points by their TRUE distances (the sketch
            // only knows projected ones).
            let dists: Vec<f32> = top
                .iter()
                .map(|&(id, _)| metrics::answer_distance(q, &self.stream[id as usize]))
                .collect();
            recalls.push(metrics::approx_recall_at_k(&dists, d50, eps as f32, 50));
            // JL returns the projected-NN; judge by its TRUE distance.
            let ans = top
                .first()
                .map(|&(id, _)| metrics::answer_distance(q, &self.stream[id as usize]))
                .filter(|&d| d <= ((1.0 + eps) * self.r) as f32 + 1e-6);
            outcomes.push(metrics::cr_outcome(
                &self.exact,
                q,
                self.r as f32,
                (1.0 + eps) as f32,
                ans,
            ));
        }
        let bytes = jl.memory_bytes();
        AnnRunResult {
            recall50: crate::util::stats::mean(&recalls),
            cr_accuracy: metrics::cr_accuracy(&outcomes),
            compression: metrics::compression_rate(bytes, self.stream.len(), self.dim),
            qps: qps.per_second(),
            stored: jl.stored(),
            sketch_bytes: bytes,
        }
    }
}

/// Default sweeps (paper §5.1): η and k grids.
///
/// The η grid spans compression rates ~0.9 down to ~0.01: recall@50 is
/// only meaningful while n^{1-η} keeps ≳50 points per dense ball, so the
/// low end of the grid is where the recall comparison lives and the high
/// end is where the sublinearity story (Fig 5) lives.
pub fn eta_grid() -> Vec<f64> {
    vec![0.05, 0.1, 0.15, 0.2, 0.3, 0.5, 0.7]
}

pub fn k_grid(dim: usize) -> Vec<usize> {
    // JL compression = k/d: match the η grid's range of compressions.
    [64, 32, 16, 8, 6, 4, 2]
        .iter()
        .map(|&f| (dim / f).max(1))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::datasets;

    fn workload() -> AnnWorkload {
        let (stream, queries) = datasets::syn32(1_200, 3).split_queries(100);
        AnnWorkload::new(stream, queries)
    }

    #[test]
    fn sann_eta_zero_beats_eta_high() {
        let w = workload();
        let dense = w.run_sann(0.5, 0.0, 1);
        let sparse = w.run_sann(0.5, 0.9, 1);
        assert!(dense.recall50 >= sparse.recall50);
        assert!(dense.stored > sparse.stored);
        assert!(dense.compression > sparse.compression);
    }

    #[test]
    fn jl_recall_improves_with_k_and_accuracy_is_high() {
        // Note: on uniform high-d data, top-50 distances concentrate so
        // even mild distortion reshuffles ranks — recall@50 is inherently
        // modest; what must hold is monotonicity in k and a high
        // (c,r)-accuracy (the projected NN's true distance is almost
        // always within c*r of a median-radius query).
        let w = workload();
        let lo = w.run_jl(0.5, 4, 2);
        let hi = w.run_jl(0.5, 32, 2);
        assert!(hi.recall50 > lo.recall50, "lo={} hi={}", lo.recall50, hi.recall50);
        assert!(hi.cr_accuracy > 0.85, "acc={}", hi.cr_accuracy);
    }

    #[test]
    fn jl_compression_scales_with_k() {
        let w = workload();
        let small = w.run_jl(0.5, 4, 2);
        let big = w.run_jl(0.5, 16, 2);
        assert!(small.compression < big.compression);
    }

    #[test]
    fn radius_gives_dense_balls() {
        // The calibrated radius must put ~n^0.65 points in a typical
        // query ball (Theorem 3.1's m >= C n^eta precondition).
        let w = workload();
        let n = w.stream.len();
        let m_star = (n as f64).powf(0.65);
        let mut occupancies: Vec<f64> = w
            .queries
            .iter()
            .take(20)
            .map(|q| {
                w.stream
                    .iter()
                    .filter(|p| crate::util::l2(p, q) as f64 <= w.r)
                    .count() as f64
            })
            .collect();
        occupancies.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let med = occupancies[occupancies.len() / 2];
        assert!(
            med > m_star * 0.3 && med < m_star * 3.0,
            "median ball occupancy {med} vs target {m_star}"
        );
    }
}
