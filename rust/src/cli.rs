//! Minimal CLI argument parser for the `sketchd` binary (no clap offline).
//! Supports `subcommand --flag value --switch positional` grammars with
//! typed accessors and an auto-generated usage block.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (without argv\[0\]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> anyhow::Result<Args> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                out.subcommand = it.next();
            }
        }
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if name.is_empty() {
                    // `--` terminator: rest is positional
                    out.positional.extend(it);
                    break;
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map_or(false, |n| !n.starts_with("--")) {
                    out.flags.insert(name.to_string(), it.next().unwrap());
                } else {
                    out.switches.push(name.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> anyhow::Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(String::as_str)
    }

    /// Flag that must be present (no sensible default exists).
    pub fn require(&self, name: &str) -> anyhow::Result<&str> {
        self.flag(name)
            .ok_or_else(|| anyhow::anyhow!("--{name} VALUE is required"))
    }

    pub fn has(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name) || self.flags.contains_key(name)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> anyhow::Result<usize> {
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got {v:?}")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> anyhow::Result<f64> {
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects a number, got {v:?}")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> anyhow::Result<u64> {
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got {v:?}")),
        }
    }

    pub fn get_str(&self, name: &str, default: &str) -> String {
        self.flags
            .get(name)
            .cloned()
            .unwrap_or_else(|| default.to_string())
    }

    /// Hard-error on any flag/switch not in `known`. The old behavior —
    /// silently ignoring a typo like `--replica 2` and serving with the
    /// default — cost real debugging time; an unknown flag now fails
    /// fast with a "did you mean" hint when a known flag is close.
    pub fn validate_known(&self, known: &[&str]) -> anyhow::Result<()> {
        for name in self
            .flags
            .keys()
            .map(String::as_str)
            .chain(self.switches.iter().map(String::as_str))
        {
            if known.contains(&name) {
                continue;
            }
            let hint = closest_flag(name, known)
                .map(|k| format!(" (did you mean --{k}?)"))
                .unwrap_or_default();
            anyhow::bail!("unknown flag --{name}{hint}");
        }
        Ok(())
    }
}

/// The known flag closest to `name` by edit distance, when close enough
/// to plausibly be a typo (distance ≤ 2, or ≤ 3 for long names).
fn closest_flag<'a>(name: &str, known: &[&'a str]) -> Option<&'a str> {
    let cap = if name.len() >= 8 { 3 } else { 2 };
    known
        .iter()
        .map(|k| (edit_distance(name, k), *k))
        .filter(|&(d, _)| d <= cap)
        .min_by_key(|&(d, _)| d)
        .map(|(_, k)| k)
}

/// Levenshtein distance, O(|a|·|b|) with a rolling row — flag names are
/// short, so no banding needed.
fn edit_distance(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    for (i, ca) in a.iter().enumerate() {
        let mut row = vec![i + 1];
        for (j, cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            row.push(sub.min(prev[j + 1] + 1).min(row[j] + 1));
        }
        prev = row;
    }
    prev[b.len()]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(str::to_string)).unwrap()
    }

    #[test]
    fn subcommand_flags_switches_positional() {
        let a = parse("serve --shards 4 --use-pjrt --eta=0.5 input.toml");
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.get_usize("shards", 1).unwrap(), 4);
        assert!(a.has("use-pjrt"));
        assert_eq!(a.get_f64("eta", 0.0).unwrap(), 0.5);
        assert_eq!(a.positional, vec!["input.toml"]);
    }

    #[test]
    fn defaults_apply_when_absent() {
        let a = parse("bench");
        assert_eq!(a.get_usize("n", 1000).unwrap(), 1000);
        assert_eq!(a.get_str("dataset", "sift"), "sift");
        assert!(!a.has("verbose"));
    }

    #[test]
    fn require_reports_missing_flags() {
        let a = parse("client --connect 127.0.0.1:4000");
        assert_eq!(a.require("connect").unwrap(), "127.0.0.1:4000");
        let err = a.require("listen").unwrap_err().to_string();
        assert!(err.contains("--listen"), "{err}");
    }

    #[test]
    fn bad_number_is_error() {
        let a = parse("x --n abc");
        // "abc" consumed as value of --n
        assert!(a.get_usize("n", 0).is_err());
    }

    #[test]
    fn double_dash_terminates_flags() {
        let a = parse("run --k 2 -- --not-a-flag file");
        assert_eq!(a.get_usize("k", 0).unwrap(), 2);
        assert_eq!(a.positional, vec!["--not-a-flag", "file"]);
    }

    #[test]
    fn no_subcommand_when_flags_first() {
        let a = parse("--help");
        assert!(a.subcommand.is_none());
        assert!(a.has("help"));
    }

    #[test]
    fn flag_followed_by_flag_becomes_switch() {
        let a = parse("s --verbose --n 3");
        assert!(a.has("verbose"));
        assert_eq!(a.get_usize("n", 0).unwrap(), 3);
    }

    #[test]
    fn unknown_flag_is_a_hard_error_with_a_hint() {
        // The motivating bug: `--replica 2` (singular) was silently
        // ignored and the server ran with the default replica count.
        let a = parse("serve --listen 127.0.0.1:0 --replica 2");
        let err = a.validate_known(&["listen", "replicas", "shards"]).unwrap_err().to_string();
        assert!(err.contains("--replica"), "{err}");
        assert!(err.contains("did you mean --replicas"), "{err}");
        // Switches are validated too, not just valued flags.
        let a = parse("serve --use-pjtr");
        let err = a.validate_known(&["use-pjrt"]).unwrap_err().to_string();
        assert!(err.contains("did you mean --use-pjrt"), "{err}");
        // Valid invocations pass.
        let a = parse("serve --listen 127.0.0.1:0 --replicas 2");
        a.validate_known(&["listen", "replicas"]).unwrap();
        // Nothing close: no misleading hint.
        let a = parse("serve --zzzzzzz 1");
        let err = a.validate_known(&["listen", "replicas"]).unwrap_err().to_string();
        assert!(!err.contains("did you mean"), "{err}");
    }

    #[test]
    fn edit_distance_basics() {
        assert_eq!(edit_distance("replica", "replicas"), 1);
        assert_eq!(edit_distance("", "abc"), 3);
        assert_eq!(edit_distance("kitten", "sitting"), 3);
        assert_eq!(edit_distance("same", "same"), 0);
    }
}
