//! Minimal CLI argument parser for the `sketchd` binary (no clap offline).
//! Supports `subcommand --flag value --switch positional` grammars with
//! typed accessors and an auto-generated usage block.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (without argv\[0\]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> anyhow::Result<Args> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                out.subcommand = it.next();
            }
        }
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if name.is_empty() {
                    // `--` terminator: rest is positional
                    out.positional.extend(it);
                    break;
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map_or(false, |n| !n.starts_with("--")) {
                    out.flags.insert(name.to_string(), it.next().unwrap());
                } else {
                    out.switches.push(name.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> anyhow::Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(String::as_str)
    }

    /// Flag that must be present (no sensible default exists).
    pub fn require(&self, name: &str) -> anyhow::Result<&str> {
        self.flag(name)
            .ok_or_else(|| anyhow::anyhow!("--{name} VALUE is required"))
    }

    pub fn has(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name) || self.flags.contains_key(name)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> anyhow::Result<usize> {
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got {v:?}")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> anyhow::Result<f64> {
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects a number, got {v:?}")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> anyhow::Result<u64> {
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got {v:?}")),
        }
    }

    pub fn get_str(&self, name: &str, default: &str) -> String {
        self.flags
            .get(name)
            .cloned()
            .unwrap_or_else(|| default.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(str::to_string)).unwrap()
    }

    #[test]
    fn subcommand_flags_switches_positional() {
        let a = parse("serve --shards 4 --use-pjrt --eta=0.5 input.toml");
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.get_usize("shards", 1).unwrap(), 4);
        assert!(a.has("use-pjrt"));
        assert_eq!(a.get_f64("eta", 0.0).unwrap(), 0.5);
        assert_eq!(a.positional, vec!["input.toml"]);
    }

    #[test]
    fn defaults_apply_when_absent() {
        let a = parse("bench");
        assert_eq!(a.get_usize("n", 1000).unwrap(), 1000);
        assert_eq!(a.get_str("dataset", "sift"), "sift");
        assert!(!a.has("verbose"));
    }

    #[test]
    fn require_reports_missing_flags() {
        let a = parse("client --connect 127.0.0.1:4000");
        assert_eq!(a.require("connect").unwrap(), "127.0.0.1:4000");
        let err = a.require("listen").unwrap_err().to_string();
        assert!(err.contains("--listen"), "{err}");
    }

    #[test]
    fn bad_number_is_error() {
        let a = parse("x --n abc");
        // "abc" consumed as value of --n
        assert!(a.get_usize("n", 0).is_err());
    }

    #[test]
    fn double_dash_terminates_flags() {
        let a = parse("run --k 2 -- --not-a-flag file");
        assert_eq!(a.get_usize("k", 0).unwrap(), 2);
        assert_eq!(a.positional, vec!["--not-a-flag", "file"]);
    }

    #[test]
    fn no_subcommand_when_flags_first() {
        let a = parse("--help");
        assert!(a.subcommand.is_none());
        assert!(a.has("help"));
    }

    #[test]
    fn flag_followed_by_flag_becomes_switch() {
        let a = parse("s --verbose --n 3");
        assert!(a.has("verbose"));
        assert_eq!(a.get_usize("n", 0).unwrap(), 3);
    }
}
